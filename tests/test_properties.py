"""Property-based tests (hypothesis) for system invariants."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.policies import ALL_POLICIES, BASELINE, COUNTDOWN, COUNTDOWN_SLACK, MINFREQ, Policy
from repro.core.pstate import HwModel
from repro.core.simulator import Workload, coverage_on_trace, simulate
from repro.dist.compression import _quantize


def _workload(draw_comp, copy, n_ranks, n_tasks, p2p_mask, seed):
    rng = np.random.default_rng(seed)
    comp = np.asarray(draw_comp, dtype=np.float64).reshape(n_tasks, n_ranks)
    partner = np.zeros((n_tasks, n_ranks), np.int64)
    for k in range(n_tasks):
        if p2p_mask[k]:
            perm = rng.permutation(n_ranks).reshape(-1, 2)
            p = np.zeros(n_ranks, np.int64)
            p[perm[:, 0]] = perm[:, 1]
            p[perm[:, 1]] = perm[:, 0]
            partner[k] = p
    return Workload(
        name="prop", n_ranks=n_ranks, comp=comp,
        copy=np.asarray(copy), is_p2p=np.asarray(p2p_mask, bool),
        partner=partner, site=rng.integers(0, 4, n_tasks),
        nbytes=np.ones(n_tasks), beta_comp=0.0, beta_copy=0.0,
    )


workloads = st.integers(min_value=0, max_value=10_000).flatmap(
    lambda seed: st.tuples(
        st.just(seed),
        st.integers(min_value=2, max_value=4).map(lambda x: 2 * x),  # ranks (even)
        st.integers(min_value=1, max_value=12),                      # tasks
    )
)


@given(workloads)
@settings(max_examples=40, deadline=None)
def test_zero_beta_policies_never_slow_and_never_cost_energy(args):
    """With beta=0 (memory-bound phases) frequency cannot change duration,
    so every *reactive zero-overhead-cost* policy must preserve wall time
    and use <= baseline energy."""
    seed, n_ranks, n_tasks = args
    rng = np.random.default_rng(seed)
    comp = rng.uniform(1e-4, 5e-3, (n_tasks, n_ranks))
    copy = rng.uniform(0.0, 2e-3, n_tasks)
    p2p = rng.random(n_tasks) < 0.4
    wl = _workload(comp, copy, n_ranks, n_tasks, p2p, seed)
    base, _ = simulate(wl, BASELINE)
    # reactive policies still pay the tiny timer-arming cost per call; the
    # invariant is: no slowdown/energy beyond that fixed cost
    from repro.core.pstate import DEFAULT_HW
    from repro.core.simulator import TIMER_COST

    slack_budget_t = n_tasks * TIMER_COST * 2          # generous
    slack_budget_e = slack_budget_t * n_ranks * DEFAULT_HW.watts_at_fmax
    pure_cntds = Policy("p", comm_mode="timeout", comm_scope="slack", theta=500e-6)
    pure_cntd = Policy("p2", comm_mode="timeout", comm_scope="comm", theta=500e-6)
    for pol in (pure_cntds, pure_cntd, MINFREQ):
        res, _ = simulate(wl, pol)
        assert res.time <= base.time + slack_budget_t
        assert res.energy <= base.energy + slack_budget_e


@given(workloads)
@settings(max_examples=40, deadline=None)
def test_slack_nonnegative_and_critical_rank_exists(args):
    seed, n_ranks, n_tasks = args
    rng = np.random.default_rng(seed)
    comp = rng.uniform(1e-4, 5e-3, (n_tasks, n_ranks))
    copy = rng.uniform(0.0, 2e-3, n_tasks)
    p2p = rng.random(n_tasks) < 0.4
    wl = _workload(comp, copy, n_ranks, n_tasks, p2p, seed)
    _, trace = simulate(wl, BASELINE, collect_trace=True)
    assert np.all(trace.slack >= -1e-12)
    # every synchronization has at least one zero-slack (critical) member
    for k in range(n_tasks):
        if p2p[k]:
            continue
        assert trace.slack[k].min() <= 1e-9


@given(
    st.integers(min_value=0, max_value=10_000),
    st.floats(min_value=1e-4, max_value=5e-3),
    st.floats(min_value=1.2, max_value=4.0),
)
@settings(max_examples=30, deadline=None)
def test_timeout_monotone_in_theta(seed, theta1, factor):
    """A longer timeout can never exploit MORE time (filter monotonicity)."""
    theta2 = theta1 * factor
    rng = np.random.default_rng(seed)
    n_tasks, n_ranks = 10, 6
    comp = rng.uniform(1e-4, 8e-3, (n_tasks, n_ranks))
    copy = rng.uniform(0.0, 3e-3, n_tasks)
    wl = _workload(comp, copy, n_ranks, n_tasks, np.zeros(n_tasks, bool), seed)
    _, trace = simulate(wl, BASELINE, collect_trace=True)
    for scope in ("slack", "comm"):
        c1 = coverage_on_trace(trace, Policy("a", comm_mode="timeout", comm_scope=scope, theta=theta1))
        c2 = coverage_on_trace(trace, Policy("b", comm_mode="timeout", comm_scope=scope, theta=theta2))
        assert c2 <= c1 + 1e-9


@given(workloads)
@settings(max_examples=30, deadline=None)
def test_coverage_nesting(args):
    """slack-scope <= comm-scope <= minfreq coverage on any trace."""
    seed, n_ranks, n_tasks = args
    rng = np.random.default_rng(seed)
    comp = rng.uniform(1e-4, 8e-3, (n_tasks, n_ranks))
    copy = rng.uniform(0.0, 3e-3, n_tasks)
    p2p = rng.random(n_tasks) < 0.3
    wl = _workload(comp, copy, n_ranks, n_tasks, p2p, seed)
    _, trace = simulate(wl, BASELINE, collect_trace=True)
    c_s = coverage_on_trace(trace, COUNTDOWN_SLACK)
    c_c = coverage_on_trace(trace, COUNTDOWN)
    c_m = coverage_on_trace(trace, MINFREQ)
    assert -1e-9 <= c_s <= c_c + 1e-9 <= c_m + 2e-9 <= 100 + 1e-6


@given(
    st.lists(
        st.floats(min_value=-1e6, max_value=1e6, allow_nan=False, width=32),
        min_size=1, max_size=64,
    )
)
@settings(max_examples=60, deadline=None)
def test_int8_quantization_error_bound(values):
    """Gradient compression: roundtrip error <= 1 LSB = max|g|/127."""
    import jax.numpy as jnp

    g = jnp.asarray(np.asarray(values, np.float32))
    q, scale = _quantize(g)
    err = np.abs(np.asarray(q, np.float32) * float(scale) - np.asarray(g))
    assert err.max() <= float(scale) * 0.5 + 1e-6


@given(
    st.integers(min_value=0, max_value=10_000),
    st.floats(min_value=3e-4, max_value=3e-3),
    st.floats(min_value=1.2, max_value=5.0),
)
@settings(max_examples=30, deadline=None)
def test_energy_saving_monotone_nonincreasing_in_theta(seed, theta1, factor):
    """Simulated end-to-end (not just coverage): for a fixed workload, a
    longer timeout can never save MORE energy — energy(theta) is
    non-decreasing in theta for the reactive slack-scope policy."""
    rng = np.random.default_rng(seed)
    n_tasks, n_ranks = 12, 6
    comp = rng.uniform(1e-4, 8e-3, (n_tasks, n_ranks))
    copy = rng.uniform(0.0, 2e-3, n_tasks)
    wl = _workload(comp, copy, n_ranks, n_tasks, np.zeros(n_tasks, bool), seed)
    e1 = simulate(wl, Policy("t1", comm_mode="timeout", comm_scope="slack",
                             theta=theta1))[0].energy
    e2 = simulate(wl, Policy("t2", comm_mode="timeout", comm_scope="slack",
                             theta=theta1 * factor))[0].energy
    assert e2 >= e1 - 1e-12


@given(workloads)
@settings(max_examples=40, deadline=None)
def test_slack_scope_never_slows_copy(args):
    """The paper's isolation contract: with the artificial barrier, the
    timeout applies to the barrier-isolated slack ONLY — for memory-bound
    copies (beta_copy=0, where frequency cannot change duration) the copy
    phase must be bit-identical to baseline, at any theta."""
    seed, n_ranks, n_tasks = args
    rng = np.random.default_rng(seed)
    comp = rng.uniform(1e-4, 8e-3, (n_tasks, n_ranks))
    copy = rng.uniform(0.1e-3, 3e-3, n_tasks)
    p2p = rng.random(n_tasks) < 0.3
    wl = _workload(comp, copy, n_ranks, n_tasks, p2p, seed)
    base, _ = simulate(wl, BASELINE)
    for theta in (100e-6, 500e-6, 2e-3):
        res, _ = simulate(wl, Policy("s", comm_mode="timeout",
                                     comm_scope="slack", theta=theta))
        assert res.tcopy == base.tcopy


@given(
    st.integers(min_value=0, max_value=10_000),
    st.floats(min_value=1e-5, max_value=1e-1),     # theta0 (possibly absurd)
    st.floats(min_value=1e-3, max_value=1e-1),     # theta_max
)
@settings(max_examples=40, deadline=None)
def test_tuner_theta_always_within_hw_bounds(seed, theta0, theta_max):
    """theta_eff stays inside [switch_latency/2, theta_max] after every
    observation, whatever slack/copy stream (incl. AIMD raises) arrives."""
    from repro.core.pstate import DEFAULT_HW
    from repro.core.timeout import ThetaTuner

    lo, hi = DEFAULT_HW.theta_bounds(theta_max)
    tuner = ThetaTuner(theta0=theta0, theta_max=theta_max)
    rng = np.random.default_rng(seed)
    for i in range(60):
        site = int(rng.integers(0, 3))
        tuner.observe_slack(site, float(rng.lognormal(-7, 2.5)), t=float(i),
                            comp=float(rng.uniform(0, 30e-3)))
        tuner.observe_copy(site, float(rng.lognormal(-8, 2.0)), t=float(i),
                           downshifted=bool(rng.random() < 0.5))
        for s in range(3):
            assert lo <= tuner.theta_for(s) <= hi
    for dec in tuner.decisions:
        assert lo <= dec.theta_after <= hi


@given(st.integers(min_value=0, max_value=10_000))
@settings(max_examples=20, deadline=None)
def test_checkpoint_roundtrip(seed):
    import os
    import tempfile

    import jax

    from repro.dist.checkpoint import CheckpointManager

    rng = np.random.default_rng(seed)
    tree = {
        "a": rng.normal(size=(3, 5)).astype(np.float32),
        "b": {"c": rng.integers(0, 10, (4,)).astype(np.int32),
              "d": [rng.normal(size=(2, 2)).astype(np.float32)]},
    }
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        mgr.save(seed % 7, tree)
        step, restored = mgr.restore_latest(tree)
        assert step == seed % 7
        flat_a = jax.tree.leaves(tree)
        flat_b = jax.tree.leaves(restored)
        for x, y in zip(flat_a, flat_b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
