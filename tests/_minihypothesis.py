"""A tiny, dependency-free stand-in for the `hypothesis` API surface that
tests/test_properties.py uses.

The container this repo is developed in does not ship `hypothesis`, and the
environment is pip-frozen.  Rather than skip the property suite, this module
implements the consumed subset — ``given``, ``settings`` and the
``integers/floats/lists/tuples/just`` strategies with ``map``/``flatmap`` —
as deterministic random sampling (seeded per test name).  It is registered
in ``conftest.py`` **only when the real hypothesis is absent**; CI installs
the real library and never sees this file.

Differences from real hypothesis (acceptable for a fallback):
  * sampling is uniform random, with no shrinking and no adversarial corpus;
  * ``deadline`` and other settings besides ``max_examples`` are ignored.
"""
from __future__ import annotations

import types
import zlib
from typing import Any, Callable

import numpy as np

__version__ = "0.0-mini"


class _Strategy:
    def __init__(self, draw: Callable[[np.random.Generator], Any]):
        self._draw = draw

    def example(self, rng: np.random.Generator) -> Any:
        return self._draw(rng)

    def map(self, f: Callable[[Any], Any]) -> "_Strategy":
        return _Strategy(lambda rng: f(self._draw(rng)))

    def flatmap(self, f: Callable[[Any], "_Strategy"]) -> "_Strategy":
        return _Strategy(lambda rng: f(self._draw(rng)).example(rng))

    def filter(self, pred: Callable[[Any], bool]) -> "_Strategy":
        def draw(rng):
            for _ in range(1000):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise ValueError("filter predicate too restrictive")

        return _Strategy(draw)


def integers(min_value: int = 0, max_value: int = 1 << 30) -> _Strategy:
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def floats(
    min_value: float = 0.0,
    max_value: float = 1.0,
    allow_nan: bool = True,
    allow_infinity: bool = True,
    width: int = 64,
) -> _Strategy:
    def draw(rng):
        v = float(rng.uniform(min_value, max_value))
        # include the exact endpoints occasionally (cheap edge coverage)
        r = rng.random()
        if r < 0.05:
            v = min_value
        elif r < 0.10:
            v = max_value
        if width == 32:
            v = float(np.float32(v))
        return v

    return _Strategy(draw)


def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10) -> _Strategy:
    def draw(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements.example(rng) for _ in range(n)]

    return _Strategy(draw)


def tuples(*strats: _Strategy) -> _Strategy:
    return _Strategy(lambda rng: tuple(s.example(rng) for s in strats))


def just(value: Any) -> _Strategy:
    return _Strategy(lambda rng: value)


def booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.integers(0, 2)))


def sampled_from(seq) -> _Strategy:
    seq = list(seq)
    return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])


def settings(max_examples: int = 100, deadline: Any = None, **_ignored):
    def decorate(fn):
        fn._mini_hypothesis_settings = {"max_examples": max_examples}
        return fn

    return decorate


def given(*strats: _Strategy):
    def decorate(fn):
        conf = getattr(fn, "_mini_hypothesis_settings", {"max_examples": 25})
        seed = zlib.crc32(fn.__name__.encode())

        # zero-arg wrapper on purpose: pytest must not mistake the wrapped
        # function's parameters for fixtures
        def wrapper():
            rng = np.random.default_rng(seed)
            for _ in range(conf["max_examples"]):
                args = tuple(s.example(rng) for s in strats)
                try:
                    fn(*args)
                except Exception as e:  # noqa: BLE001 — attach the example
                    raise AssertionError(
                        f"falsifying example (minihypothesis): {fn.__name__}{args!r}"
                    ) from e

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return decorate


# expose a module-like `strategies` so `from hypothesis import strategies as st`
# and `import hypothesis.strategies` both work
strategies = types.ModuleType("hypothesis.strategies")
for _name in (
    "integers", "floats", "lists", "tuples", "just", "booleans", "sampled_from",
):
    setattr(strategies, _name, globals()[_name])
