"""The streaming event core: EventBus semantics, streaming/batch
equivalence of the governor's accounting, reset coverage, and bounded
memory on million-event streams.

The equivalence property test carries a frozen reference implementation
of the *pre-streaming* governor (retain-everything record list + one-shot
batch tally at finalize) and asserts the streaming engine produces an
identical ``GovernorReport.to_dict()`` on arbitrary interleaved event
streams — all 5 phases, occurrence rotations, and ingested phases.  The
accumulation order of the streaming engine was chosen to replicate the
batch walk's float-addition sequence exactly, so the comparison is
``==``, not approx.
"""
import time

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.events import PHASE_NAMES, EventBus, PhaseEvent, PhaseRecord
from repro.core.governor import Governor, GovernorReport
from repro.core.policies import (
    BASELINE, CNTD_ADAPTIVE, COUNTDOWN, COUNTDOWN_SLACK, FERMATA_500US,
    MINFREQ,
)
from repro.core.pstate import DEFAULT_HW
from repro.dist.straggler import StragglerDetector


# --------------------------------------------------------------------------
# EventBus semantics
# --------------------------------------------------------------------------

class _Listener:
    def __init__(self):
        self.events = []
        self.phases = []

    def on_event(self, rank, phase, call_id, t):
        self.events.append((rank, phase, call_id, t))

    def on_phase(self, record):
        self.phases.append(record)


def test_bus_fans_out_to_all_subscribers_in_order():
    bus = EventBus()
    a, b = _Listener(), _Listener()
    seen = []
    bus.subscribe(a)
    bus.subscribe(lambda *e: seen.append(("c",) + e))   # bare callable
    bus.subscribe(b)
    bus.publish(0, "barrier_enter", 7, 1.0)
    bus.publish_phase(PhaseRecord(1, 8, 1.0, 1.1, 1.2, site=9))
    assert a.events == b.events == [(0, "barrier_enter", 7, 1.0)]
    assert seen == [("c", 0, "barrier_enter", 7, 1.0)]
    assert a.phases == b.phases == [PhaseRecord(1, 8, 1.0, 1.1, 1.2, 9)]
    assert len(bus) == 3 and bool(bus)


def test_bus_named_slot_replaces_and_unsubscribes():
    bus = EventBus()
    a, b, c = _Listener(), _Listener(), _Listener()
    bus.subscribe(a, name="sink")
    bus.subscribe(c)
    bus.subscribe(b, name="sink")           # replaces a, keeps c
    bus.publish(0, "barrier_exit", 1, 2.0)
    assert a.events == [] and len(b.events) == 1 and len(c.events) == 1
    assert bus.unsubscribe("sink") and not bus.unsubscribe("sink")
    bus.publish(0, "copy_exit", 1, 3.0)
    assert len(b.events) == 1 and len(c.events) == 2
    assert bus.unsubscribe(c)
    assert len(bus) == 0 and not bus


def test_bus_resubscribe_same_object_does_not_duplicate():
    bus = EventBus()
    a = _Listener()
    bus.subscribe(a)
    bus.subscribe(a)
    bus.publish(0, "barrier_enter", 1, 0.0)
    assert len(a.events) == 1


def test_bus_bound_method_identity_dedups_and_unsubscribes():
    """gov.on_event mints a fresh bound-method object per access; the bus
    must still treat them as one subscriber."""
    bus = EventBus()
    gov = Governor()
    bus.subscribe(gov.sink)
    bus.subscribe(gov.sink)                     # fresh bound method, same target
    assert len(bus) == 1
    bus.publish(0, "barrier_enter", 1, 1.0)
    bus.publish(0, "barrier_exit", 1, 1.002)
    assert gov.finalize().n_calls == 1          # delivered once, not twice
    assert bus.unsubscribe(gov.sink)
    assert len(bus) == 0


def test_bus_one_callable_may_occupy_both_named_slots():
    """Legacy sink+tee semantics: the same callable installed in both slots
    is delivered twice, and vacating one slot leaves the other."""
    from repro.core import instrument

    seen = []
    f = lambda *e: seen.append(e)               # noqa: E731
    instrument.set_event_sink(f)
    instrument.set_event_tee(f)
    instrument._emit(0, 0, 1)
    assert len(seen) == 2
    instrument.set_event_sink(None)
    instrument._emit(0, 1, 1)
    assert len(seen) == 3                       # tee slot still live
    instrument.reset_instrumentation()


def test_bus_rejects_non_subscribers():
    with pytest.raises(TypeError):
        EventBus().subscribe(object())


def test_bus_unsubscribe_none_is_a_noop():
    bus = EventBus()
    a = _Listener()
    bus.subscribe(a)
    assert not bus.unsubscribe(None)            # must NOT strip unnamed entries
    bus.publish(0, "barrier_enter", 1, 0.0)
    assert len(a.events) == 1


def test_publish_event_value_shape_matches_positional():
    bus = EventBus()
    a = _Listener()
    bus.subscribe(a)
    bus.publish_event(PhaseEvent(2, "wait_enter", 5, 4.5))
    assert a.events == [(2, "wait_enter", 5, 4.5)]
    assert set(PHASE_NAMES.values()) >= {"wait_enter"}


def test_instrument_shims_share_the_bus_with_direct_subscribers():
    from repro.core import instrument

    sink_seen, tee_seen = [], []
    direct = _Listener()
    instrument.set_event_sink(lambda *e: sink_seen.append(e))
    instrument.get_event_bus().subscribe(direct)
    instrument.set_event_tee(lambda *e: tee_seen.append(e))
    try:
        instrument._emit(0, 0, 42)
        # replacing the sink slot must not disturb the other two
        instrument.set_event_sink(lambda *e: sink_seen.append(("v2",) + e))
        instrument._emit(1, 1, 42)
    finally:
        instrument.reset_instrumentation()
    assert [e[:3] for e in sink_seen] == [(0, "barrier_enter", 42),
                                          ("v2", 1, "barrier_exit")]
    assert len(tee_seen) == 2 and len(direct.events) == 2
    assert len(instrument.get_event_bus()) == 0      # reset cleared it


def test_governor_on_phase_equals_ingest_phase():
    """The bus path and the legacy kwargs path book identically."""
    g1, g2 = Governor(), Governor()
    bus = EventBus()
    bus.subscribe(g2)
    g1.ingest_phase(0, 1 << 20, 1.0, 1.004, 1.005, site=7)
    bus.publish_phase(PhaseRecord(0, 1 << 20, 1.0, 1.004, 1.005, site=7))
    assert g1.finalize().to_dict() == g2.finalize().to_dict()


# --------------------------------------------------------------------------
# streaming/batch equivalence (the conformance property of the refactor)
# --------------------------------------------------------------------------

class _BatchRecord:
    def __init__(self, call_id, site=None):
        self.call_id = call_id
        self.enter = {}
        self.slack_end = {}
        self.copy_end = {}
        self.dispatch = {}
        self.site = site


class _BatchReferenceGovernor:
    """Frozen pre-streaming semantics: retain every record, tally once at
    finalize.  Fixed-theta only (the tuner path is pinned separately by the
    trace replay differential test)."""

    def __init__(self, policy, hw=DEFAULT_HW):
        self.policy = policy
        self.hw = hw
        self.detector = StragglerDetector()
        self._calls = {}
        self._done = []

    def sink(self, rank, phase, call_id, t):
        rec = self._calls.setdefault(call_id, _BatchRecord(call_id))
        if phase in ("barrier_enter", "dispatch_enter") and (
            rank in rec.enter or rank in rec.dispatch
        ):
            self._done.append(rec)
            rec = _BatchRecord(call_id)
            self._calls[call_id] = rec
        if phase == "barrier_enter":
            rec.enter[rank] = t
        elif phase == "dispatch_enter":
            rec.dispatch[rank] = t
        elif phase == "wait_enter":
            rec.enter[rank] = t
        elif phase == "barrier_exit":
            rec.slack_end[rank] = t
        elif phase == "copy_exit":
            rec.copy_end[rank] = t

    def ingest_phase(self, rank, call_id, t0, t1, t2=None, site=None):
        rec = _BatchRecord(call_id, site=site)
        rec.enter[rank] = t0
        rec.slack_end[rank] = t1
        rec.copy_end[rank] = t1 if t2 is None else t2
        self._done.append(rec)

    def finalize(self):
        hw, pol = self.hw, self.policy
        records = self._done + list(self._calls.values())
        for rec in records:
            if rec.enter:
                self.detector.observe_barrier(rec.enter)
        n_down = 0
        tot_slack = tot_copy = exploited = tot_overlap = 0.0
        e_base = e_pol = 0.0
        theta_eff = hw.theta_eff(pol.theta)
        for rec in records:
            for rank, t0 in rec.enter.items():
                t1 = rec.slack_end.get(rank)
                if t1 is None:
                    continue
                if rank in rec.dispatch:
                    tot_overlap += max(t0 - rec.dispatch[rank], 0.0)
                slack = max(t1 - t0, 0.0)
                tot_slack += slack
                copy = max(rec.copy_end.get(rank, t1) - t1, 0.0)
                tot_copy += copy
                e_base += hw.watts(hw.f_max, hw.act_slack) * slack
                e_base += hw.watts(hw.f_max, hw.act_copy) * copy
                low = max(slack - theta_eff, 0.0)
                if low > 0:
                    n_down += 1
                    exploited += low
                e_pol += hw.watts(hw.f_max, hw.act_slack) * (slack - low)
                e_pol += hw.watts(hw.f_min, hw.act_slack) * low
                if pol.comm_scope == "comm" and low > 0:
                    e_pol += hw.watts(hw.f_min, hw.act_copy) * copy
                else:
                    e_pol += hw.watts(hw.f_max, hw.act_copy) * copy
        return GovernorReport(
            n_calls=len(records),
            n_downshifts=n_down,
            total_slack=tot_slack,
            total_copy=tot_copy,
            exploited_slack=exploited,
            energy_baseline=e_base,
            energy_policy=e_pol,
            straggler_summary=self.detector.summary(),
            stragglers=self.detector.stragglers(),
            total_overlap=tot_overlap,
            n_theta_decisions=0,
        )


_EQ_POLICIES = [BASELINE, MINFREQ, COUNTDOWN, COUNTDOWN_SLACK, FERMATA_500US]


def _random_stream(seed):
    """An adversarial interleaving: all 5 phases, rotations (recurring call
    ids), partial occurrences, and ingested phases, in one ordered list."""
    rng = np.random.default_rng(seed)
    ops = []
    t = 1.0
    n_ranks = int(rng.integers(2, 6))
    call_ids = list(range(int(rng.integers(1, 5))))
    for _ in range(int(rng.integers(5, 40))):
        t += float(rng.uniform(1e-4, 5e-3))
        kind = rng.random()
        if kind < 0.15:                                  # ingested phase
            dur = float(rng.uniform(0.0, 3e-3))
            ops.append(("phase", 0, (1 << 20) + int(rng.integers(0, 3)),
                        t, t + dur, t + dur + float(rng.uniform(0.0, 1e-3))))
            continue
        cid = int(rng.choice(call_ids))
        is_async = kind < 0.4
        ranks = list(rng.permutation(n_ranks)[: int(rng.integers(1, n_ranks + 1))])
        arrivals = {r: t + float(rng.uniform(0.0, 2e-3)) for r in ranks}
        release = max(arrivals.values()) + float(rng.uniform(0.0, 1e-3))
        if is_async:
            for r in ranks:
                ops.append(("ev", r, "dispatch_enter", cid, arrivals[r] - 1e-3))
            for r in ranks:
                ops.append(("ev", r, "wait_enter", cid, arrivals[r]))
        else:
            for r in ranks:
                ops.append(("ev", r, "barrier_enter", cid, arrivals[r]))
        complete = rng.random()
        if complete < 0.85:                              # some never exit
            for r in ranks:
                ops.append(("ev", r, "barrier_exit", cid, release))
            if complete < 0.7:                           # some never copy
                for r in ranks:
                    ops.append(("ev", r, "copy_exit", cid,
                                release + float(rng.uniform(0.0, 2e-3))))
        t = release
    return ops


def _feed(gov, ops):
    for op in ops:
        if op[0] == "ev":
            gov.sink(op[1], op[2], op[3], op[4])
        else:
            gov.ingest_phase(op[1], op[2], op[3], op[4], op[5])


@given(st.integers(min_value=0, max_value=100_000))
@settings(max_examples=60, deadline=None)
def test_streaming_report_equals_batch_reference(seed):
    ops = _random_stream(seed)
    pol = _EQ_POLICIES[seed % len(_EQ_POLICIES)]
    ref = _BatchReferenceGovernor(pol)
    gov = Governor(policy=pol, retention=4)      # tiny ring: eviction exercised
    _feed(ref, ops)
    _feed(gov, ops)
    assert gov.finalize().to_dict() == ref.finalize().to_dict()


def test_streaming_matches_batch_on_golden_streams():
    """The canned conformance streams, compared exactly (not via fixtures)."""
    from golden_common import CANNED, feed

    for kind in CANNED:
        for pol in _EQ_POLICIES:
            gov = Governor(policy=pol)
            ref = _BatchReferenceGovernor(pol)
            feed(gov, kind)
            # golden_common feeds Governors; replay its stream through a
            # recording listener into the reference
            rec = _Listener()
            bus = EventBus()
            bus.subscribe(rec)
            probe = Governor(policy=pol)
            bus.subscribe(probe)
            feed(_BusFeeder(bus), kind)
            for e in rec.events:
                ref.sink(*e)
            for p in rec.phases:
                ref.ingest_phase(p.rank, p.call_id, p.t_enter, p.t_slack_end,
                                 p.t_copy_end, site=p.site)
            assert gov.finalize().to_dict() == ref.finalize().to_dict()
            assert probe.finalize().to_dict() == gov.finalize().to_dict()


class _BusFeeder:
    """Adapter: looks like a Governor to golden_common.feed but republishes
    onto a bus (proving the canned feeders are just one more producer)."""

    def __init__(self, bus):
        self._bus = bus

    def sink(self, rank, phase, call_id, t):
        self._bus.publish(rank, phase, call_id, t)

    def ingest_phase(self, rank, call_id, t0, t1, t2=None, site=None):
        self._bus.publish_phase(
            PhaseRecord(rank, call_id, t0, t1, t1 if t2 is None else t2, site))


# --------------------------------------------------------------------------
# reset coverage
# --------------------------------------------------------------------------

@pytest.mark.parametrize("policy", [COUNTDOWN_SLACK, CNTD_ADAPTIVE])
def test_reset_makes_back_to_back_runs_identical(policy):
    """Governor.reset() must cover every piece of run state — records,
    ring, accumulators, interval mark, per-rank phase ends, logs, straggler
    detector, tuner — so a second identical run reports identically."""
    from golden_common import feed

    gov = Governor(policy=policy)

    def run():
        feed(gov, "straggler")
        feed(gov, "bursty")
        rep = gov.finalize().to_dict()
        fingerprint = (rep, list(gov.actuation_log), gov.n_actuations,
                       list(gov.theta_log), len(gov.recent_records()),
                       gov.interval_snapshot())
        gov.reset()
        return fingerprint

    first, second = run(), run()
    assert first == second
    # and reset truly empties: a finalize right after reset is all-zero
    empty = gov.finalize()
    assert empty.n_calls == 0 and empty.total_slack == 0.0
    assert empty.stragglers == [] and empty.straggler_summary == {}


def test_reset_instrumentation_covers_bus_state():
    from repro.core import instrument

    gov = Governor()
    instrument.get_event_bus().subscribe(gov)
    instrument.set_event_tee(lambda *a: None)
    instrument.reset_instrumentation()
    assert len(instrument.get_event_bus()) == 0


# --------------------------------------------------------------------------
# bounded memory / flat-time finalize (the million-event property)
# --------------------------------------------------------------------------

def _pump(gov, n_calls, n_ranks=4, recurring=25):
    t = 0.0
    for c in range(n_calls):
        cid = c % recurring
        for r in range(n_ranks):
            gov.sink(r, "barrier_enter", cid, t + r * 1e-6)
        for r in range(n_ranks):
            gov.sink(r, "barrier_exit", cid, t + 1e-3)
            gov.sink(r, "copy_exit", cid, t + 1.2e-3)
        t += 2e-3


def test_million_events_bounded_retention_and_flat_finalize():
    gov = Governor(retention=128, log_retention=2048)
    _pump(gov, n_calls=1000)                    # 12k events warm-up
    t0 = time.perf_counter()
    rep_small = gov.finalize()
    t_small = time.perf_counter() - t0

    # to 1M events total
    _pump(gov, n_calls=1_000_000 // 12 - 1000)
    t0 = time.perf_counter()
    rep = gov.finalize()
    t_large = time.perf_counter() - t0

    assert rep.n_calls > rep_small.n_calls
    # memory: in-flight records bounded by distinct call ids, ring by
    # retention, logs by log_retention — never by the 1M-event stream
    assert gov.n_inflight <= 25
    assert len(gov.recent_records()) <= 128
    assert len(gov.actuation_log) <= 2048
    assert gov.n_actuations > 2048              # ...but the count survives
    # time: finalize is an O(in-flight) accumulator read; after 80x more
    # events it must not be meaningfully slower (generous noise floor)
    assert t_large < max(20.0 * t_small, 0.05)


def test_unread_actuation_spine_is_bounded_under_log_retention():
    """log_retention must bound RSS even when nobody ever reads the
    actuation_log property (the normal week-long-run case)."""
    gov = Governor(log_retention=100)
    t = 0.0
    for c in range(2000):                       # 2000 downshifting phases
        gov.ingest_phase(0, (1 << 20) + c, t, t + 5e-3, t + 6e-3, site=1)
        t += 1e-2
    assert len(gov._act_raw) <= 50              # pending spine ring-bounded
    assert gov.n_actuations == 4000
    assert len(gov.actuation_log) <= 100


def test_midrun_finalize_does_not_hide_late_straggler_arrivals():
    """A finalize() taken while an occurrence is partially arrived must not
    permanently exclude ranks that enter afterwards from the detector."""
    gov = Governor()
    t = 10.0
    for call in range(8):
        for r in range(5):                      # ranks 0-4 arrive on time
            gov.sink(r, "barrier_enter", call, t)
        gov.finalize()                          # progress poll mid-barrier
        gov.sink(5, "barrier_enter", call, t + 3e-3)    # the straggler
        for r in range(6):
            gov.sink(r, "barrier_exit", call, t + 3e-3)
        t += 0.1
    rep = gov.finalize()
    assert [r for r, _ in rep.stragglers] == [5]


# --------------------------------------------------------------------------
# overlap plumbing + producers
# --------------------------------------------------------------------------

def _async_occurrence(gov, cid, t, n_ranks=2, overlap=2e-3, slack=1.5e-3):
    for r in range(n_ranks):
        gov.sink(r, "dispatch_enter", cid, t)
    for r in range(n_ranks):
        gov.sink(r, "wait_enter", cid, t + overlap)
    for r in range(n_ranks):
        gov.sink(r, "barrier_exit", cid, t + overlap + slack)
        gov.sink(r, "copy_exit", cid, t + overlap + slack + 1e-4)


def test_interval_snapshot_carries_overlap():
    gov = Governor()
    _async_occurrence(gov, 1, 1.0)
    _async_occurrence(gov, 1, 2.0)              # rotation retires the first
    stats = gov.interval_snapshot()
    assert stats.n_calls == 1
    assert stats.overlap == pytest.approx(2 * 2e-3, rel=1e-9)
    assert 0.0 < stats.overlap_ratio
    # drained: the next snapshot starts from the new mark
    again = gov.interval_snapshot()
    assert again.n_calls == 0 and again.overlap == 0.0


def test_governor_job_surfaces_overlap_ratio():
    from repro.cluster.job import GovernorJob

    gov = Governor()
    job = GovernorJob("ov", gov, n_ranks=2, cap_w=40.0)
    _async_occurrence(gov, 1, 1.0)
    _async_occurrence(gov, 1, 2.0)
    rep = job.run_epoch(40.0)
    assert rep.overlap_ratio > 0.0
    sample = job.last_sample()
    assert sample.overlap_ratio == rep.overlap_ratio


def test_simulator_is_a_bus_producer():
    """simulate(bus=...) publishes the canonical 5-phase stream: a governor
    subscriber re-derives the simulator's slack/copy/overlap totals."""
    from repro.core.simulator import Workload, simulate

    rng = np.random.default_rng(3)
    n_tasks, n_ranks = 8, 4
    wl = Workload(
        name="bus", n_ranks=n_ranks,
        comp=rng.uniform(1e-3, 4e-3, (n_tasks, n_ranks)),
        copy=rng.uniform(1e-4, 1e-3, n_tasks),
        is_p2p=np.zeros(n_tasks, bool),
        partner=np.zeros((n_tasks, n_ranks), np.int64),
        site=np.arange(n_tasks) % 3,
        nbytes=np.zeros(n_tasks),
        beta_comp=0.3, beta_copy=0.15,
        overlap=np.where(np.arange(n_tasks) % 4 == 0, 1e-3, 0.0),
    )
    bus = EventBus()
    gov = Governor(policy=BASELINE)
    bus.subscribe(gov)
    res, _ = simulate(wl, BASELINE, bus=bus)
    rep = gov.finalize()
    assert rep.n_calls == n_tasks
    assert rep.total_slack == pytest.approx(res.tslack, rel=1e-9)
    assert rep.total_copy == pytest.approx(res.tcopy, rel=1e-9)
    assert rep.total_overlap == pytest.approx(res.toverlap, rel=1e-9)

    # naive 3-phase contrast: the published stream must match ITS
    # accounting too — whole window as slack, no overlap split
    bus2 = EventBus()
    gov2 = Governor(policy=BASELINE)
    bus2.subscribe(gov2)
    res2, _ = simulate(wl, BASELINE, overlap_aware=False, bus=bus2)
    rep2 = gov2.finalize()
    assert rep2.total_overlap == 0.0 == res2.toverlap
    assert rep2.total_slack == pytest.approx(res2.tslack, rel=1e-9)
