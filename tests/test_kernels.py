"""Per-kernel shape/dtype sweeps asserting allclose against the pure-jnp
oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(atol=2e-5, rtol=2e-5)


# --------------------------------------------------------------------------
# rmsnorm
# --------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(8, 128), (3, 17, 256), (2, 5, 7, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(shape, dtype):
    x = jnp.asarray(RNG.normal(0, 2, shape), dtype)
    scale = jnp.asarray(RNG.normal(1, 0.2, shape[-1:]), dtype)
    out = ops.rmsnorm(x, scale, row_block=8)
    expect = ref.rmsnorm_ref(x, scale)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), **_tol(dtype)
    )


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------

@pytest.mark.parametrize(
    "b,hq,hkv,s,d,window",
    [
        (1, 4, 4, 64, 32, 0),       # MHA causal
        (2, 8, 2, 96, 64, 0),       # GQA causal, non-multiple seq
        (1, 4, 1, 128, 32, 0),      # MQA
        (1, 4, 2, 128, 32, 48),     # sliding window
    ],
)
def test_flash_attention(b, hq, hkv, s, d, window):
    q = jnp.asarray(RNG.normal(0, 1, (b, hq, s, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (b, hkv, s, d)), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, window=window, block_q=32, block_k=32)
    expect = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5, rtol=2e-4)


def test_flash_attention_bf16():
    b, hq, hkv, s, d = 1, 4, 2, 64, 32
    q = jnp.asarray(RNG.normal(0, 1, (b, hq, s, d)), jnp.bfloat16)
    k = jnp.asarray(RNG.normal(0, 1, (b, hkv, s, d)), jnp.bfloat16)
    v = jnp.asarray(RNG.normal(0, 1, (b, hkv, s, d)), jnp.bfloat16)
    out = ops.flash_attention(q, k, v, block_q=32, block_k=32)
    expect = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), atol=3e-2, rtol=3e-2
    )


def test_flash_matches_model_layer_attention():
    """The kernel semantics mirror the model's chunked XLA attention."""
    from repro.models.layers import chunked_causal_attention

    b, hkv, g, s, d = 1, 2, 3, 80, 32
    q = jnp.asarray(RNG.normal(0, 1, (b, s, hkv, g, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (b, s, hkv, d)), jnp.float32)
    pos = jnp.arange(s, dtype=jnp.int32)
    xla_out = chunked_causal_attention(q, k, v, pos, pos, kv_chunk=32)
    qk = q.transpose(0, 2, 3, 1, 4).reshape(b, hkv * g, s, d)
    pl_out = ops.flash_attention(
        qk, k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3), block_q=32, block_k=32
    )
    pl_out = pl_out.reshape(b, hkv, g, s, d).transpose(0, 3, 1, 2, 4)
    np.testing.assert_allclose(np.asarray(pl_out), np.asarray(xla_out), atol=2e-5, rtol=2e-4)


# --------------------------------------------------------------------------
# SSD (Mamba-2)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("s,chunk", [(64, 16), (100, 32), (32, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd(s, chunk, dtype):
    b, h, p, n = 2, 3, 16, 8
    x = jnp.asarray(RNG.normal(0, 1, (b, s, h, p)), dtype)
    dt = jnp.asarray(RNG.uniform(0.01, 0.5, (b, s, h)), jnp.float32)
    a_log = -jnp.asarray(RNG.uniform(0.5, 2.0, (h,)), jnp.float32)
    bb = jnp.asarray(RNG.normal(0, 1, (b, s, n)), dtype)
    cc = jnp.asarray(RNG.normal(0, 1, (b, s, n)), dtype)
    out = ops.ssd_scan(x, dt, a_log, bb, cc, chunk=chunk)
    expect = ref.ssd_ref(x, dt, a_log, bb, cc)
    tol = dict(atol=3e-1, rtol=5e-2) if dtype == jnp.bfloat16 else dict(atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), **tol
    )


# --------------------------------------------------------------------------
# RG-LRU scan
# --------------------------------------------------------------------------

@pytest.mark.parametrize("s,w,chunk,wb", [(64, 96, 16, 32), (50, 64, 32, 64), (16, 128, 16, 128)])
def test_rglru_scan(s, w, chunk, wb):
    a = jnp.asarray(RNG.uniform(0.3, 0.999, (2, s, w)), jnp.float32)
    b = jnp.asarray(RNG.normal(0, 0.3, (2, s, w)), jnp.float32)
    h0 = jnp.asarray(RNG.normal(0, 1, (2, w)), jnp.float32)
    out = ops.rglru_scan(a, b, h0, chunk=chunk, width_block=wb)
    expect = ref.rglru_scan_ref(a, b, h0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5, rtol=2e-4)


def test_rglru_matches_model_associative_scan():
    from repro.models.rglru import linear_scan

    a = jnp.asarray(RNG.uniform(0.3, 0.999, (2, 40, 64)), jnp.float32)
    b = jnp.asarray(RNG.normal(0, 0.3, (2, 40, 64)), jnp.float32)
    h0 = jnp.asarray(RNG.normal(0, 1, (2, 64)), jnp.float32)
    h_assoc, _ = linear_scan(a, b, h0)
    h_pallas = ops.rglru_scan(a, b, h0, chunk=8, width_block=64)
    np.testing.assert_allclose(np.asarray(h_pallas), np.asarray(h_assoc), atol=2e-5, rtol=2e-4)
