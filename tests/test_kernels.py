"""Per-kernel shape/dtype sweeps asserting allclose against the pure-jnp
oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(atol=2e-5, rtol=2e-5)


# --------------------------------------------------------------------------
# rmsnorm
# --------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(8, 128), (3, 17, 256), (2, 5, 7, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(shape, dtype):
    x = jnp.asarray(RNG.normal(0, 2, shape), dtype)
    scale = jnp.asarray(RNG.normal(1, 0.2, shape[-1:]), dtype)
    out = ops.rmsnorm(x, scale, row_block=8)
    expect = ref.rmsnorm_ref(x, scale)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), **_tol(dtype)
    )


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------

@pytest.mark.parametrize(
    "b,hq,hkv,s,d,window",
    [
        (1, 4, 4, 64, 32, 0),       # MHA causal
        (2, 8, 2, 96, 64, 0),       # GQA causal, non-multiple seq
        (1, 4, 1, 128, 32, 0),      # MQA
        (1, 4, 2, 128, 32, 48),     # sliding window
    ],
)
def test_flash_attention(b, hq, hkv, s, d, window):
    q = jnp.asarray(RNG.normal(0, 1, (b, hq, s, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (b, hkv, s, d)), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, window=window, block_q=32, block_k=32)
    expect = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5, rtol=2e-4)


def test_flash_attention_bf16():
    b, hq, hkv, s, d = 1, 4, 2, 64, 32
    q = jnp.asarray(RNG.normal(0, 1, (b, hq, s, d)), jnp.bfloat16)
    k = jnp.asarray(RNG.normal(0, 1, (b, hkv, s, d)), jnp.bfloat16)
    v = jnp.asarray(RNG.normal(0, 1, (b, hkv, s, d)), jnp.bfloat16)
    out = ops.flash_attention(q, k, v, block_q=32, block_k=32)
    expect = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), atol=3e-2, rtol=3e-2
    )


def test_flash_matches_model_layer_attention():
    """The kernel semantics mirror the model's chunked XLA attention."""
    from repro.models.layers import chunked_causal_attention

    b, hkv, g, s, d = 1, 2, 3, 80, 32
    q = jnp.asarray(RNG.normal(0, 1, (b, s, hkv, g, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (b, s, hkv, d)), jnp.float32)
    pos = jnp.arange(s, dtype=jnp.int32)
    xla_out = chunked_causal_attention(q, k, v, pos, pos, kv_chunk=32)
    qk = q.transpose(0, 2, 3, 1, 4).reshape(b, hkv * g, s, d)
    pl_out = ops.flash_attention(
        qk, k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3), block_q=32, block_k=32
    )
    pl_out = pl_out.reshape(b, hkv, g, s, d).transpose(0, 3, 1, 2, 4)
    np.testing.assert_allclose(np.asarray(pl_out), np.asarray(xla_out), atol=2e-5, rtol=2e-4)


# --------------------------------------------------------------------------
# SSD (Mamba-2)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("s,chunk", [(64, 16), (100, 32), (32, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd(s, chunk, dtype):
    b, h, p, n = 2, 3, 16, 8
    x = jnp.asarray(RNG.normal(0, 1, (b, s, h, p)), dtype)
    dt = jnp.asarray(RNG.uniform(0.01, 0.5, (b, s, h)), jnp.float32)
    a_log = -jnp.asarray(RNG.uniform(0.5, 2.0, (h,)), jnp.float32)
    bb = jnp.asarray(RNG.normal(0, 1, (b, s, n)), dtype)
    cc = jnp.asarray(RNG.normal(0, 1, (b, s, n)), dtype)
    out = ops.ssd_scan(x, dt, a_log, bb, cc, chunk=chunk)
    expect = ref.ssd_ref(x, dt, a_log, bb, cc)
    tol = dict(atol=3e-1, rtol=5e-2) if dtype == jnp.bfloat16 else dict(atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), **tol
    )


# --------------------------------------------------------------------------
# RG-LRU scan
# --------------------------------------------------------------------------

@pytest.mark.parametrize("s,w,chunk,wb", [(64, 96, 16, 32), (50, 64, 32, 64), (16, 128, 16, 128)])
def test_rglru_scan(s, w, chunk, wb):
    a = jnp.asarray(RNG.uniform(0.3, 0.999, (2, s, w)), jnp.float32)
    b = jnp.asarray(RNG.normal(0, 0.3, (2, s, w)), jnp.float32)
    h0 = jnp.asarray(RNG.normal(0, 1, (2, w)), jnp.float32)
    out = ops.rglru_scan(a, b, h0, chunk=chunk, width_block=wb)
    expect = ref.rglru_scan_ref(a, b, h0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5, rtol=2e-4)


def test_rglru_matches_model_associative_scan():
    from repro.models.rglru import linear_scan

    a = jnp.asarray(RNG.uniform(0.3, 0.999, (2, 40, 64)), jnp.float32)
    b = jnp.asarray(RNG.normal(0, 0.3, (2, 40, 64)), jnp.float32)
    h0 = jnp.asarray(RNG.normal(0, 1, (2, 64)), jnp.float32)
    h_assoc, _ = linear_scan(a, b, h0)
    h_pallas = ops.rglru_scan(a, b, h0, chunk=8, width_block=64)
    np.testing.assert_allclose(np.asarray(h_pallas), np.asarray(h_assoc), atol=2e-5, rtol=2e-4)


# --------------------------------------------------------------------------
# paged decode attention + fused scatter epilogue
# --------------------------------------------------------------------------

def _paged_case(b, hkv, g, d, page, m, n_pages, quant):
    q = jnp.asarray(RNG.normal(0, 1, (b, hkv, g, d)), jnp.float32)
    table = jnp.asarray(
        RNG.choice(n_pages, size=(b, m), replace=False).reshape(b, m)
        if b * m <= n_pages else RNG.integers(0, n_pages, (b, m)),
        jnp.int32,
    )
    pos = jnp.asarray(RNG.integers(0, m * page, (b,)), jnp.int32)
    if quant:
        kp = jnp.asarray(RNG.integers(-127, 128, (n_pages, page, hkv, d)), jnp.int8)
        vp = jnp.asarray(RNG.integers(-127, 128, (n_pages, page, hkv, d)), jnp.int8)
        ks = jnp.asarray(RNG.uniform(1e-3, 0.1, (n_pages, page, hkv)), jnp.float32)
        vs = jnp.asarray(RNG.uniform(1e-3, 0.1, (n_pages, page, hkv)), jnp.float32)
        return q, kp, vp, table, pos, ks, vs
    kp = jnp.asarray(RNG.normal(0, 1, (n_pages, page, hkv, d)), jnp.float32)
    vp = jnp.asarray(RNG.normal(0, 1, (n_pages, page, hkv, d)), jnp.float32)
    return q, kp, vp, table, pos, None, None


@pytest.mark.parametrize(
    "b,hkv,g,d,page,m,window,quant",
    [
        (2, 2, 4, 32, 8, 4, 0, False),     # GQA
        (3, 1, 4, 32, 8, 5, 0, False),     # MQA, non-pow2 table width
        (2, 4, 1, 32, 16, 3, 0, False),    # MHA
        (2, 2, 2, 32, 8, 4, 12, False),    # sliding window
        (2, 2, 4, 32, 8, 5, 0, True),      # int8 pages, fused dequant
        (2, 2, 2, 32, 8, 4, 12, True),     # int8 + window
    ],
)
def test_paged_attention_matches_ref(b, hkv, g, d, page, m, window, quant):
    q, kp, vp, table, pos, ks, vs = _paged_case(b, hkv, g, d, page, m, 32, quant)
    if quant:
        out = ops.paged_attention_quant(q, kp, vp, ks, vs, table, pos, window=window)
    else:
        out = ops.paged_attention(q, kp, vp, table, pos, window=window)
    expect = ref.paged_attention_ref(
        q, kp, vp, table, pos, k_scale_pages=ks, v_scale_pages=vs, window=window
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5, rtol=2e-4)


def test_paged_scatter_bit_equal_to_at_set():
    """The fused epilogue's aliased page write must be bit-identical to the
    ``.at[page_idx, off].set()`` path — including every untouched page."""
    n_pages, page, hkv, d, b = 12, 8, 2, 16, 4
    kp = jnp.asarray(RNG.normal(0, 1, (n_pages, page, hkv, d)), jnp.float32)
    vp = jnp.asarray(RNG.normal(0, 1, (n_pages, page, hkv, d)), jnp.float32)
    k_new = jnp.asarray(RNG.normal(0, 1, (b, hkv, d)), jnp.float32)
    v_new = jnp.asarray(RNG.normal(0, 1, (b, hkv, d)), jnp.float32)
    page_idx = jnp.asarray([3, 7, 1, 10], jnp.int32)
    off = jnp.asarray([0, 5, 7, 2], jnp.int32)
    got_k, got_v = ops.paged_scatter(kp, vp, k_new, v_new, page_idx, off)
    np.testing.assert_array_equal(np.asarray(got_k),
                                  np.asarray(kp.at[page_idx, off].set(k_new)))
    np.testing.assert_array_equal(np.asarray(got_v),
                                  np.asarray(vp.at[page_idx, off].set(v_new)))


def test_paged_scatter_quant_bit_equal_to_at_set():
    n_pages, page, hkv, d, b = 10, 8, 2, 16, 3
    kp = jnp.asarray(RNG.integers(-127, 128, (n_pages, page, hkv, d)), jnp.int8)
    vp = jnp.asarray(RNG.integers(-127, 128, (n_pages, page, hkv, d)), jnp.int8)
    ks = jnp.asarray(RNG.uniform(0, 1, (n_pages, page, hkv)), jnp.float32)
    vs = jnp.asarray(RNG.uniform(0, 1, (n_pages, page, hkv)), jnp.float32)
    k_new = jnp.asarray(RNG.integers(-127, 128, (b, hkv, d)), jnp.int8)
    v_new = jnp.asarray(RNG.integers(-127, 128, (b, hkv, d)), jnp.int8)
    ks_new = jnp.asarray(RNG.uniform(0, 1, (b, hkv)), jnp.float32)
    vs_new = jnp.asarray(RNG.uniform(0, 1, (b, hkv)), jnp.float32)
    page_idx = jnp.asarray([2, 9, 5], jnp.int32)
    off = jnp.asarray([7, 0, 3], jnp.int32)
    got = ops.paged_scatter_quant(kp, vp, ks, vs, k_new, v_new, ks_new, vs_new,
                                  page_idx, off)
    want = (kp.at[page_idx, off].set(k_new), vp.at[page_idx, off].set(v_new),
            ks.at[page_idx, off].set(ks_new), vs.at[page_idx, off].set(vs_new))
    for g, w in zip(got, want):
        assert g.dtype == w.dtype
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@pytest.mark.parametrize("quant,window", [(False, 0), (False, 12), (True, 0)])
def test_paged_attention_scatter_fuses_bit_equal(quant, window):
    """The fused single-dispatch step (scatter prologue + page walk) must
    be bit-identical to standalone scatter followed by standalone
    attention — outputs AND every page of the updated pools."""
    b, hkv, g, d, page, m = 3, 2, 2, 32, 8, 4
    n_pages = b * m + 2                  # distinct live pages per slot
    q, kp, vp, table, pos, ks, vs = _paged_case(b, hkv, g, d, page, m, n_pages, quant)
    page_idx = table[jnp.arange(b), pos // page]
    off = pos % page
    if quant:
        k_new = jnp.asarray(RNG.integers(-127, 128, (b, hkv, d)), jnp.int8)
        v_new = jnp.asarray(RNG.integers(-127, 128, (b, hkv, d)), jnp.int8)
        ks_new = jnp.asarray(RNG.uniform(1e-3, 0.1, (b, hkv)), jnp.float32)
        vs_new = jnp.asarray(RNG.uniform(1e-3, 0.1, (b, hkv)), jnp.float32)
        want_pools = ops.paged_scatter_quant(
            kp, vp, ks, vs, k_new, v_new, ks_new, vs_new, page_idx, off)
        want_out = ops.paged_attention_quant(
            q, *want_pools, table, pos, window=window)
        got_out, got_pools = ops.paged_attention_scatter_quant(
            q, k_new, v_new, ks_new, vs_new, kp, vp, ks, vs,
            table, pos, page_idx, off, window=window)
    else:
        k_new = jnp.asarray(RNG.normal(0, 1, (b, hkv, d)), jnp.float32)
        v_new = jnp.asarray(RNG.normal(0, 1, (b, hkv, d)), jnp.float32)
        want_pools = ops.paged_scatter(kp, vp, k_new, v_new, page_idx, off)
        want_out = ops.paged_attention(q, *want_pools, table, pos, window=window)
        got_out, got_pools = ops.paged_attention_scatter(
            q, k_new, v_new, kp, vp, table, pos, page_idx, off, window=window)
    np.testing.assert_array_equal(np.asarray(got_out), np.asarray(want_out))
    for got, want in zip(got_pools, want_pools):
        assert got.dtype == want.dtype
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_interpret_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert ops._interpret() is True
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert ops._interpret() is False
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET")
    # platform default: interpret everywhere except a real TPU backend
    assert ops._interpret() is (jax.default_backend() != "tpu")
