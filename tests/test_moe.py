"""MoE layer: routing exactness, capacity behaviour, aux loss."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models.moe import capacity, init_moe, moe_forward


def _cfg(n_experts=4, top_k=2, cf=1.25):
    cfg = reduced(get_config("mixtral-8x22b"))
    return dataclasses.replace(cfg, n_experts=n_experts, top_k=top_k, capacity_factor=cf)


def _dense_topk_reference(cfg, p, x):
    """Exact dropless top-k: every expert computed densely, masked combine."""
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    vals, idx = jax.lax.top_k(probs, cfg.top_k)
    vals = vals / jnp.maximum(vals.sum(-1, keepdims=True), 1e-9)
    h = jnp.einsum("td,edf->tef", xf, p["w1"])
    g = jnp.einsum("td,edf->tef", xf, p["w3"])
    out_all = jnp.einsum("tef,efd->ted", jax.nn.silu(h) * g, p["w2"])
    weight = jnp.zeros((xf.shape[0], cfg.n_experts), jnp.float32)
    weight = jnp.take_along_axis(
        weight, idx, axis=1
    ) * 0  # noop to keep shapes clear
    w_full = jnp.zeros((xf.shape[0], cfg.n_experts), xf.dtype)
    w_full = w_full.at[jnp.arange(xf.shape[0])[:, None], idx].set(vals.astype(xf.dtype))
    out = jnp.einsum("te,ted->td", w_full, out_all)
    return out.reshape(b, s, d)


def test_dropless_matches_dense_reference(rng_key):
    cfg = _cfg()
    p = init_moe(cfg, rng_key, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 9, cfg.d_model))
    out, aux = moe_forward(cfg, p, x, cap_override=2 * 9)      # dropless
    expect = _dense_topk_reference(cfg, p, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5, rtol=2e-4)
    assert float(aux) >= 0.0


def test_capacity_drops_tokens_but_stays_finite(rng_key):
    cfg = _cfg(cf=0.25)                          # aggressively tight capacity
    p = init_moe(cfg, rng_key, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model))
    out, aux = moe_forward(cfg, p, x)
    dropless, _ = moe_forward(cfg, p, x, cap_override=32)
    assert bool(jnp.all(jnp.isfinite(out)))
    # tight capacity must actually change (drop) something
    assert float(jnp.max(jnp.abs(out - dropless))) > 1e-6


def test_capacity_formula():
    cfg = _cfg(n_experts=8, top_k=2, cf=1.25)
    assert capacity(cfg, 64) == int(np.ceil(2 * 64 / 8 * 1.25))
    assert capacity(cfg, 1) >= cfg.top_k


def test_aux_loss_increases_with_imbalance(rng_key):
    """Engineered routing: half the tokens to each of 2 experts (balanced)
    vs all tokens to one expert (skewed) — aux must rank them."""
    cfg = _cfg(n_experts=2, top_k=1)
    p = init_moe(cfg, rng_key, jnp.float32)
    d = cfg.d_model
    router = jnp.zeros((d, 2), jnp.float32).at[0, 0].set(2.0).at[0, 1].set(-2.0)
    p = dict(p, router=router)
    e0 = jnp.zeros((d,)).at[0].set(5.0)
    balanced = jnp.stack([e0, -e0, e0, -e0])[None]            # (1,4,d)
    skewed = jnp.stack([e0, e0, e0, e0])[None]
    _, aux_balanced = moe_forward(cfg, p, balanced)
    _, aux_skewed = moe_forward(cfg, p, skewed)
    assert float(aux_skewed) > float(aux_balanced)
