"""Serving engine: batched generation, determinism, MoE decode, profiler."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core.profiler import EventProfiler, TimeProfiler, hierarchical_report
from repro.core.policies import BASELINE
from repro.core.simulator import simulate
from repro.core.workloads import APPS, generate
from repro.models import init_params
from repro.models.inputs import make_batch
from repro.serve.engine import ServeEngine


def test_greedy_generation_deterministic(rng_key):
    cfg = reduced(get_config("llama3.2-1b"))
    params = init_params(cfg, rng_key)
    eng = ServeEngine(cfg, params, max_len=64)
    batch = make_batch(cfg, batch=3, seq_len=16, kind="prefill")
    out1 = eng.generate(batch, n_steps=5)
    out2 = eng.generate(batch, n_steps=5)
    assert out1.shape == (3, 5)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert int(out1.max()) < cfg.vocab


def test_sampled_generation_varies_with_key(rng_key):
    cfg = reduced(get_config("internlm2-1.8b"))
    params = init_params(cfg, rng_key)
    eng = ServeEngine(cfg, params, max_len=64, temperature=1.0)
    batch = make_batch(cfg, batch=2, seq_len=16, kind="prefill")
    a = eng.generate(batch, n_steps=8, key=jax.random.PRNGKey(1))
    b = eng.generate(batch, n_steps=8, key=jax.random.PRNGKey(2))
    assert not np.array_equal(np.asarray(a), np.asarray(b))


def test_moe_generation_finite(rng_key):
    cfg = reduced(get_config("granite-moe-3b-a800m"))
    params = init_params(cfg, rng_key)
    eng = ServeEngine(cfg, params, max_len=48)
    batch = make_batch(cfg, batch=2, seq_len=12, kind="prefill")
    out = eng.generate(batch, n_steps=4)
    assert out.shape == (2, 4) and int(out.min()) >= 0


def test_profiler_hierarchical_report():
    wl = generate(APPS["nas_mg.E.128"], seed=0)
    _, trace = simulate(wl, BASELINE, collect_trace=True)
    ep = EventProfiler()
    ep.ingest_trace(trace)
    tp = TimeProfiler(interval=0.05)
    tp.start()
    import time

    time.sleep(0.15)
    tp.stop()
    rep = hierarchical_report(ep, tp, n_ranks=wl.n_ranks, ranks_per_node=18)
    assert rep["summary"]["total_calls"] == wl.n_tasks * wl.n_ranks
    assert rep["summary"]["total_tslack_s"] > 0
    assert "node0" in rep["nodes"] and "node1" in rep["nodes"]
    assert len(rep["time_series"]) >= 2
    # per-node slack sums to the summary total
    total = sum(nd["tslack_s"] for nd in rep["nodes"].values())
    assert abs(total - rep["summary"]["total_tslack_s"]) < 1e-6
