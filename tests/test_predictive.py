"""The cntd_predictive hybrid: golden conformance, replay differential,
guard semantics.

Three layers, mirroring the tentpole's claims:

* **Golden conformance** — the predictive pair (hybrid + prediction-only
  strawman) on the 3 canned streams is frozen in its own fixture file
  (``tests/goldens/predictive.json``), so predictor/guard drift fails
  loudly without touching the fixed-policy goldens.
* **Replay differential** — a live predictive run on a recurring-site
  stream (pre-arms, mispredictions, AND a guard trip) saved as a v3 trace
  and replayed through a fresh governor re-derives the report, the
  actuation stream, every theta decision and every predictor decision
  bit-for-bit: the hybrid (tuner + guard + seeded, counter-triggered
  forest refits) is a pure function of the observation order.
* **Guard semantics** — a tripped site's tuner decisions are identical to
  a plain :class:`ThetaTuner`'s (property-tested over random streams), the
  budget and EV gates fire where constructed to, and the strawman
  configuration really has no bar.
"""
import json
import math
import os
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from golden_common import CANNED, PREDICTIVE_POLICY_NAMES, predictive_entry
from repro.core.governor import Governor
from repro.core.policies import ALL_POLICIES, CNTD_PREDICTIVE
from repro.core.pstate import DEFAULT_HW
from repro.core.timeout import PredictiveTuner, ThetaTuner
from test_golden import GOLDEN_DIR, _assert_close


def _load_fixture() -> dict:
    with open(os.path.join(GOLDEN_DIR, "predictive.json")) as f:
        return json.load(f)


# --------------------------------------------------------------------------
# golden conformance (satellite: fixtures for the predictive pair)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("policy_name", PREDICTIVE_POLICY_NAMES)
@pytest.mark.parametrize("kind", CANNED)
def test_predictive_report_matches_golden(kind, policy_name):
    fixture = _load_fixture()["policies"][policy_name][kind]
    live = json.loads(json.dumps(
        predictive_entry(ALL_POLICIES[policy_name], kind)))
    _assert_close(live, fixture, path=f"predictive/{kind}/{policy_name}")


def test_predictive_fixture_covers_both_policies():
    fixture = _load_fixture()
    assert sorted(fixture["policies"]) == sorted(PREDICTIVE_POLICY_NAMES)
    for name, streams in fixture["policies"].items():
        assert sorted(streams) == sorted(CANNED), name
    # the bursty stream's stable ingested site accrues history, so the
    # predictor path must actually fire somewhere in the frozen fixture
    assert any(
        e["n_predictor_decisions"] > 0
        for streams in fixture["policies"].values() for e in streams.values()
    )


# --------------------------------------------------------------------------
# replay differential (satellite: pre-arms + guard trips re-derive exactly)
# --------------------------------------------------------------------------

def _feed_recurring(gov: Governor, n_iters: int = 60) -> None:
    """Recurring call sites (ids recur across iterations — the rotation
    rule), built to drive every predictor path:

    * site 0 — ranks 0..2 always see ~3 ms slack (the EMA, then the
      forest, clears the bar: correct pre-arms), rank 3 is critical.
    * site 1 — slack alternates ~2 ms / ~50 us per iteration: the EMA
      settles ~1 ms (over the bar), so odd iterations mispredict below
      break-even and the guard books serialization residue until the
      site trips.
    """
    t = 1.0
    for it in range(n_iters):
        for site, lag in ((0, 3e-3), (1, 2e-3 if it % 2 == 0 else 50e-6)):
            arrivals = np.full(4, t)
            arrivals[3] += lag                   # rank 3 is always critical
            release = float(arrivals.max())
            for r in range(4):
                gov.sink(r, "barrier_enter", site, float(arrivals[r]))
            for r in range(4):
                gov.sink(r, "barrier_exit", site, release)
                gov.sink(r, "copy_exit", site, release + 0.6e-3)
            t = release + 5e-3


def test_predictive_replay_is_bitwise_exact():
    from repro.cluster.trace import TRACE_VERSION, TraceRecorder, load, replay

    rec = TraceRecorder(meta={"run": "predictive"})
    gov = Governor(policy=CNTD_PREDICTIVE, recorder=rec)
    _feed_recurring(gov)
    live = gov.finalize()
    kinds = {d.kind for d in gov.predictor_log}
    assert {"prearm", "mispredict", "trip"} <= kinds, kinds
    assert live.n_theta_decisions > 0

    with tempfile.TemporaryDirectory() as d:
        path = rec.save(os.path.join(d, "predictive.jsonl"))
        header, records = load(path)
    assert header["version"] == TRACE_VERSION == 3
    recorded_pred = [r for r in records if r["k"] == "pred"]
    assert len(recorded_pred) == len(gov.predictor_log)

    replayed_gov, rep = replay(records, policy=CNTD_PREDICTIVE)
    for f in ("total_slack", "total_copy", "exploited_slack",
              "energy_baseline", "energy_policy", "n_calls", "n_downshifts",
              "n_theta_decisions"):
        assert getattr(rep, f) == getattr(live, f), f
    assert replayed_gov.actuation_log == gov.actuation_log
    assert replayed_gov.theta_log == gov.theta_log
    # the re-derived predictor decisions match the recorded ones field by
    # field — pre-arms, guard bookings, and the trip, in order
    assert replayed_gov.predictor_log == gov.predictor_log
    for r, dec in zip(recorded_pred, replayed_gov.predictor_log):
        assert (r["site"], r["rank"], r["kind"], r["source"]) == (
            dec.site, dec.rank, dec.kind, dec.source)
        for key, got in (("t", dec.t), ("predicted", dec.predicted),
                         ("observed", dec.observed), ("cost", dec.cost)):
            if math.isnan(r[key]) if isinstance(r[key], float) else False:
                assert math.isnan(got)
            else:
                assert r[key] == got, (key, r)


def test_predictive_governor_trips_site_and_keeps_reactive_path():
    """The guard trips the alternating site but leaves the stable one armed;
    after the trip, downshifts still happen there (the reactive fallback)."""
    gov = Governor(policy=CNTD_PREDICTIVE)
    _feed_recurring(gov)
    rep = gov.finalize()
    guards = gov.tuner.guard_summary()
    assert guards[1]["tripped"] and not guards[0]["tripped"]
    assert guards[0]["n_mispredict"] == 0     # stable site never mispredicts
    assert guards[1]["n_mispredict"] > 0
    assert rep.n_downshifts > 0


# --------------------------------------------------------------------------
# guard semantics (satellite: tripped site == pure ThetaTuner, gates fire)
# --------------------------------------------------------------------------

slack_streams = st.integers(min_value=0, max_value=10_000).map(
    lambda seed: np.random.default_rng(seed).exponential(1e-3, 40))


@settings(max_examples=25, deadline=None)
@given(slack_streams)
def test_tripped_site_decisions_equal_pure_theta_tuner(slacks):
    """Property: once a site trips, the hybrid's theta evolution, decision
    records, and copy feedback are indistinguishable from a plain
    ThetaTuner fed the identical observation order — and it never arms."""
    hyb = PredictiveTuner()
    pure = ThetaTuner()
    site = 5
    hyb.trip_site(site)
    t = 0.0
    for i, s in enumerate(np.asarray(slacks, np.float64).tolist()):
        armed, _, src = hyb.decide(site, rank=i % 4)
        assert not armed and src == "tripped"
        assert not hyb.arm_mask(site, np.full(4, 1.0)).any()
        d_h = hyb.observe_slack(site, s, t=t, rank=i % 4, comp=3 * s)
        d_p = pure.observe_slack(site, s, t=t, rank=i % 4, comp=3 * s)
        assert d_h == d_p
        assert hyb.theta_for(site) == pure.theta_for(site)
        d_h = hyb.observe_copy(site, 0.8e-3 + s, t=t, downshifted=i % 3 == 0)
        d_p = pure.observe_copy(site, 0.8e-3 + s, t=t, downshifted=i % 3 == 0)
        assert d_h == d_p
        t += 10e-3
    assert hyb.decisions == pure.decisions


def test_guard_budget_gate_trips_and_is_permanent():
    hw = DEFAULT_HW
    tun = PredictiveTuner(hw=hw)
    site = 0
    # a little busy time so the 1% budget is tiny but nonzero
    tun.observe_slack_batch(site, np.full(4, 1e-3), t=0.0)
    preds = np.full(4, 1.0)                     # confidently wrong
    armed = tun.arm_mask(site, preds)
    assert armed.all()
    decs = tun.account_outcome_batch(site, preds, np.zeros(4), armed,
                                     t=1.0, source="ema")
    trips = [d for d in decs if d.kind == "trip"]
    assert len(trips) == 1 and trips[0].source == "budget"
    assert tun.tripped(site)
    assert not tun.arm_mask(site, preds).any()          # permanent
    armed2, pred2, src2 = tun.decide(site, 0)
    assert (armed2, src2) == (False, "tripped") and math.isnan(pred2)


def test_guard_ev_gate_trips_marginal_site():
    """A site whose pre-arms are all correct-but-marginal (tiny gain) and
    occasionally mispredict trips on the EV gate once cost > gain, even
    while the 1% budget (huge busy) never binds."""
    tun = PredictiveTuner(ev_min_armed=8)
    site = 3
    t = 0.0
    # enormous busy time: the budget gate can never fire
    tun.observe_slack_batch(site, np.full(4, 0.3), t=t, comp=np.full(4, 10.0))
    arm_eff = tun.hw.theta_eff(0.0)
    gate = None
    for i in range(40):
        preds = np.full(4, 1e-3)
        armed = tun.arm_mask(site, preds)
        if not armed.any():
            break
        # slack just above break-even: gain ~0; every 3rd round mispredicts
        s = 0.0 if i % 3 == 2 else arm_eff * 1.01
        decs = tun.account_outcome_batch(site, preds, np.full(4, s), armed,
                                         t=t, source="forest")
        trips = [d for d in decs if d.kind == "trip"]
        if trips:
            gate = trips[0].source
            break
        t += 1e-2
    assert tun.tripped(site) and gate == "ev"


def test_strawman_has_no_bar_and_no_guard():
    straw = PredictiveTuner(reactive=False, guarded=False)
    assert straw.arm_bar == 0.0
    # arms on ANY predicted slack, and never trips no matter the cost
    assert straw.arm_mask(0, np.array([1e-9, 5e-4])).all()
    for _ in range(50):
        straw.account_outcome_batch(0, np.full(2, 1.0), np.zeros(2),
                                    np.ones(2, bool), t=0.0, source="ema")
    assert not straw.tripped(0)
    assert straw.arm_mask(0, np.array([1e-9])).all()
    hybrid = PredictiveTuner()
    assert hybrid.arm_bar > hybrid.hw.theta_eff(0.0)


def test_simulator_predictive_counters_flow_to_simresult():
    """The vectorized engine surfaces pre-arm/mispredict/trip counts on
    SimResult, and the hybrid's overhead stays in the same regime as the
    adaptive baseline on a small stream (the guard's whole point)."""
    import dataclasses

    from repro.cluster.coschedule import MIX_SPECS
    from repro.core.policies import BASELINE, CNTD_ADAPTIVE
    from repro.core.simulator import simulate
    from repro.core.workloads import generate

    spec = dataclasses.replace(MIX_SPECS["bursty_serve"], n_tasks=150)
    wl = generate(spec, seed=0)
    base, _ = simulate(wl, BASELINE)
    hyb, _ = simulate(wl, CNTD_PREDICTIVE)
    ad, _ = simulate(wl, CNTD_ADAPTIVE)
    assert hyb.n_prearm > 0
    assert 0 <= hyb.n_mispredict <= hyb.n_prearm
    assert hyb.overhead_vs(base) < 1.0
    # pre-arming exploits at least as much f_min residency as reactive-only
    assert hyb.exploited_slack >= ad.exploited_slack
