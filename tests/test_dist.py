"""Distribution substrate: sharding rules, checkpoint manager semantics,
elastic mesh, straggler detector, optimizer correctness."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, get_config, reduced
from repro.dist import sharding as SH
from repro.dist.checkpoint import CheckpointManager
from repro.dist.elastic import ElasticMesh, FailureInjector
from repro.dist.straggler import StragglerDetector
from repro.models.transformer import init_params
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state, schedule


def test_sanitize_spec_drops_nondividing_axes():
    # size-1 axes divide everything -> kept
    mesh1 = jax.make_mesh((1,), ("data",))
    assert SH.sanitize_spec(mesh1, P("data"), (7,)) == P("data")
    # arithmetic check without multi-device hardware: fake axis sizes via
    # the helper's own size lookup on a 1-device mesh is trivial, so check
    # the pure function against a mesh-shaped namespace
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 2}

    fm = FakeMesh()
    assert SH.sanitize_spec(fm, P("data", "model"), (8, 6)) == P("data", "model")
    assert SH.sanitize_spec(fm, P("data", "model"), (7, 6)) == P(None, "model")
    assert SH.sanitize_spec(fm, P(("data", "model"), None), (16, 3)) == P(("data", "model"), None)
    assert SH.sanitize_spec(fm, P(("data", "model"), None), (4, 3)) == P(None, None)


@pytest.mark.parametrize("arch", list(ARCHS))
def test_param_shardings_cover_every_leaf(arch, rng_key):
    """Every parameter leaf gets a sharding whose axes divide its dims
    (guaranteed by sanitize) — checked on a 1-device mesh for all archs."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = reduced(get_config(arch))
    params = jax.eval_shape(lambda k: init_params(cfg, k), rng_key)
    sh = SH.param_shardings(mesh, params)
    n_leaves = len(jax.tree.leaves(params))
    assert len(jax.tree.leaves(sh, is_leaf=lambda x: hasattr(x, "spec"))) == n_leaves


def test_matrix_params_are_2d_sharded_on_production_spec():
    """On the production mesh spec, big matrices must shard both ways."""
    cfg = reduced(get_config("llama3.2-1b"))
    params = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    # fake mesh shape check via spec computation only (1-device mesh, but we
    # inspect the *requested* spec before sanitize drops axes)
    fsdp = ("data",)
    spec = SH._param_spec("w1", 2, "data")
    assert spec == P("data", "model")
    spec = SH._param_spec("w2", 3, ("pod", "data"))
    assert spec == P(None, "model", ("pod", "data"))


def test_checkpoint_manager_gc_and_latest():
    tree = {"w": np.arange(6, dtype=np.float32).reshape(2, 3)}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for step in [1, 5, 9]:
            mgr.save(step, tree)
        assert mgr.all_steps() == [5, 9]          # step 1 garbage-collected
        assert mgr.latest_step() == 9
        step, restored = mgr.restore_latest(tree)
        np.testing.assert_array_equal(restored["w"], tree["w"])


def test_checkpoint_atomicity_no_tmp_left_behind():
    tree = {"w": np.zeros((4,), np.float32)}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=3)
        mgr.save(3, tree)
        assert not any(n.endswith(".tmp") for n in os.listdir(d))


def test_checkpoint_async_save():
    tree = {"w": np.ones((8, 8), np.float32)}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2, async_save=True)
        mgr.save(1, tree)
        mgr.wait()
        assert mgr.latest_step() == 1


def test_elastic_mesh_failure_and_rebuild():
    em = ElasticMesh(axis_names=("data", "model"))
    mesh = em.build(model_parallel=1)
    n0 = int(np.prod(list(mesh.shape.values())))
    injector = FailureInjector(fail_at_steps=[10], device_ids=[jax.devices()[0].id])
    assert injector.check(9) is None
    failed = injector.check(10)
    assert failed is not None
    em.fail(failed)
    if n0 > 1:
        mesh2 = em.build(model_parallel=1)
        assert int(np.prod(list(mesh2.shape.values()))) == n0 - 1
    else:
        with pytest.raises(RuntimeError):
            em.build()


def test_straggler_detector_flags_slow_rank():
    det = StragglerDetector(min_samples=5)
    rng = np.random.default_rng(0)
    for _ in range(50):
        arrivals = {r: rng.normal(0, 0.01) for r in range(8)}
        arrivals[3] = 0.5                         # rank 3 always last
        det.observe_barrier(arrivals)
    flagged = [r for r, z in det.stragglers()]
    assert flagged == [3]


def test_adamw_converges_on_quadratic():
    opt_cfg = OptConfig(lr=0.1, weight_decay=0.0, warmup_steps=0, total_steps=200,
                        min_lr_ratio=1.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = init_opt_state(params, opt_cfg)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}            # d/dw ||w||^2
        params, state, metrics = adamw_update(params, grads, state, opt_cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05
    assert float(metrics["grad_norm"]) >= 0


def test_lr_schedule_shape():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    s0 = float(schedule(cfg, jnp.int32(0)))
    s10 = float(schedule(cfg, jnp.int32(10)))
    s100 = float(schedule(cfg, jnp.int32(100)))
    assert s0 < 0.2 and abs(s10 - 1.0) < 1e-6 and abs(s100 - 0.1) < 1e-3
