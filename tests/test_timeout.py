"""Unit tests for the theta auto-tuner (repro.core.timeout), its governor
wiring, the 5-phase overlap-aware event taxonomy, and the instrumentation
reset helper."""
import numpy as np
import pytest

from repro.core.governor import Governor
from repro.core.policies import CNTD_ADAPTIVE, COUNTDOWN_SLACK
from repro.core.pstate import DEFAULT_HW, HwModel
from repro.core.simulator import Workload, simulate
from repro.core.timeout import ThetaDecision, ThetaTuner


# --------------------------------------------------------------------------
# tuner dynamics
# --------------------------------------------------------------------------

def test_theta_for_unknown_site_is_clamped_theta0():
    tuner = ThetaTuner(theta0=500e-6)
    assert tuner.theta_for(42) == 500e-6
    lo, hi = DEFAULT_HW.theta_bounds()
    assert ThetaTuner(theta0=1e-9).theta_for(0) == lo       # clamped up
    assert ThetaTuner(theta0=10.0).theta_for(0) == hi       # clamped down


def test_heavy_slack_decays_theta_toward_floor():
    """A site with consistently huge slack can afford an aggressive theta:
    the CDF target sits at the histogram floor and theta relaxes to it."""
    tuner = ThetaTuner()
    for i in range(100):
        tuner.observe_slack(7, 20e-3, t=float(i))
    assert tuner.theta_for(7) < 300e-6
    assert tuner.theta_for(7) >= DEFAULT_HW.switch_latency / 2


def test_unprofitable_slack_keeps_theta_above_it():
    """300 us slacks with no compute to amortize against: the residue cost
    (75 us) dwarfs the 1% budget (3 us/call), so the CDF target lands ABOVE
    the slack — the tuner refuses to fire where a fixed 250 us theta would
    have pinned every call."""
    tuner = ThetaTuner()
    for i in range(100):
        tuner.observe_slack(3, 300e-6, t=float(i))
    assert tuner.theta_for(3) > 300e-6

    # the same slack backed by 30 ms of compute per call IS affordable
    rich = ThetaTuner()
    for i in range(100):
        rich.observe_slack(3, 300e-6, t=float(i), comp=30e-3)
    assert rich.theta_for(3) < 300e-6


def test_theta0_held_until_min_samples():
    tuner = ThetaTuner(min_samples=8)
    for i in range(7):
        dec = tuner.observe_slack(1, 20e-3, t=float(i))
        assert dec is None and tuner.theta_for(1) == tuner.theta0
    assert tuner.observe_slack(1, 20e-3, t=8.0) is not None
    assert tuner.theta_for(1) != tuner.theta0


def test_copy_slowdown_triggers_aimd_raise():
    tuner = ThetaTuner()
    # establish a copy EMA and busy mass at site 0
    for i in range(20):
        tuner.observe_slack(0, 2e-3, t=float(i))
        tuner.observe_copy(0, 1e-3, t=float(i), downshifted=False)
    before = tuner.theta_for(0)
    # a downshifted call whose copy ran 2x the reference and far over budget
    dec = tuner.observe_copy(0, 2e-3, t=30.0, downshifted=True)
    assert dec is not None and dec.reason == "raise"
    assert tuner.theta_for(0) == pytest.approx(
        min(before * tuner.raise_factor, tuner.theta_max))


def test_downshifted_copy_never_seeds_the_reference():
    """A site whose FIRST observed copy is already residue-stretched (the
    common case: long first slack -> immediate downshift) must not lock the
    reference at the stretched value and disarm the raise forever."""
    tuner = ThetaTuner()
    for i in range(20):
        tuner.observe_slack(0, 20e-3, t=float(i))
    # all copies downshifted: the min of them is the fallback reference
    assert tuner.observe_copy(0, 1.5e-3, t=21.0, downshifted=True) is None
    dec = tuner.observe_copy(0, 3e-3, t=22.0, downshifted=True)
    assert dec is not None and dec.reason == "raise"
    # a later clean copy still seeds the EMA at its own (unstretched) value
    tuner.observe_copy(0, 1.0e-3, t=23.0, downshifted=False)
    dec2 = tuner.observe_copy(0, 1.5e-3, t=24.0, downshifted=True)
    assert dec2 is not None and dec2.reason == "raise"   # vs clean 1.0 ms ref


def test_immaterial_copy_slowdown_does_not_raise():
    """Relatively slow but tiny: a 60 us excess on a site with 30 ms busy
    per call must not stampede theta upward."""
    tuner = ThetaTuner()
    for i in range(20):
        tuner.observe_slack(0, 25e-3, t=float(i))
        tuner.observe_copy(0, 100e-6, t=float(i), downshifted=False)
    assert tuner.observe_copy(0, 160e-6, t=30.0, downshifted=True) is None


def test_decisions_are_structured_and_suppressed_when_stable():
    tuner = ThetaTuner()
    for i in range(40):
        tuner.observe_slack(5, 15e-3, t=float(i))
    assert tuner.decisions, "adaptation must log decisions"
    d = tuner.decisions[0]
    assert isinstance(d, ThetaDecision) and d.site == 5 and d.reason == "decay"
    assert d.theta_after != d.theta_before
    # once converged to the clamped target, no-op decisions are suppressed
    n = len(tuner.decisions)
    for i in range(40, 60):
        tuner.observe_slack(5, 15e-3, t=float(i))
    assert len(tuner.decisions) == n


def test_batch_path_matches_scalar_direction():
    """The simulator's batched observe moves theta the same direction as the
    governor's scalar path on the same data (one decay step per batch)."""
    a, b = ThetaTuner(), ThetaTuner()
    slacks = np.full(8, 10e-3)
    for i in range(30):
        a.observe_slack_batch(0, slacks, t=float(i))
        for s in slacks:
            b.observe_slack(0, float(s), t=float(i))
    assert a.theta_for(0) < a.theta0 and b.theta_for(0) < b.theta0


# --------------------------------------------------------------------------
# governor wiring
# --------------------------------------------------------------------------

def _stream(gov, n_calls, slack, copy=1e-3, n_ranks=4, call_id=9):
    t = 1.0
    for _ in range(n_calls):
        for r in range(n_ranks):
            gov.sink(r, "barrier_enter", call_id, t if r == 0 else t - slack)
        for r in range(n_ranks):
            gov.sink(r, "barrier_exit", call_id, t)
            gov.sink(r, "copy_exit", call_id, t + copy)
        t += 10e-3


def test_adaptive_policy_autocreates_tuner_and_exploits_more():
    """600 us slack: fixed cntd_slack (theta_eff 750 us) rejects everything;
    the adaptive governor decays theta and starts exploiting."""
    fixed = Governor(policy=COUNTDOWN_SLACK)
    _stream(fixed, 60, slack=600e-6)
    adaptive = Governor(policy=CNTD_ADAPTIVE)
    assert adaptive.tuner is not None                 # auto-created
    _stream(adaptive, 60, slack=600e-6)
    rep_f, rep_a = fixed.finalize(), adaptive.finalize()
    assert rep_f.exploited_slack == 0.0
    assert rep_a.exploited_slack > 0.0
    assert rep_a.n_theta_decisions > 0
    assert rep_a.energy_policy < rep_f.energy_policy
    # priced downshifts follow the tuned threshold (600 us < fixed 750 us
    # eff, but above the adapted one)
    assert rep_a.n_downshifts > rep_f.n_downshifts == 0


def test_tuned_theta_priced_per_observation_not_retroactively():
    """Records priced before the tuner adapted keep the theta they were
    observed under (theta_eff is stored per rank at barrier_exit)."""
    gov = Governor(policy=CNTD_ADAPTIVE)
    _stream(gov, 1, slack=600e-6)                     # theta still ~theta0
    early = gov.finalize().exploited_slack
    assert early == 0.0                               # priced at 750 us eff
    _stream(gov, 59, slack=600e-6)
    rep = gov.finalize()
    # exploited accrues only from post-adaptation calls: strictly less than
    # pricing every call at the final theta would give
    final_eff = gov.tuner.theta_for(9) + 0.5 * gov.hw.switch_latency
    per_call_all = max(600e-6 - final_eff, 0.0) * 3 * 60
    assert 0.0 < rep.exploited_slack < per_call_all


def test_ingest_phase_site_keys_one_histogram():
    gov = Governor(policy=CNTD_ADAPTIVE)
    for i in range(40):
        t0 = float(i)
        gov.ingest_phase(0, 1000 + i, t0, t0 + 5e-3, t0 + 6e-3, site=77)
    assert list(gov.tuner.summary()) == [77]          # one site, not 40
    assert gov.finalize().n_theta_decisions > 0


def test_serve_meter_feeds_stable_sites():
    from repro.serve.slack import SITE_DECODE_STEP, SITE_IDLE_GAP, DecodeSlackMeter

    gov = Governor(policy=CNTD_ADAPTIVE)
    meter = DecodeSlackMeter(gov)
    t = 0.0
    for _ in range(30):
        meter.step(t, t + 4e-3, filled=1, capacity=4)  # 3 ms underfill slack
        meter.idle(t + 4e-3, t + 9e-3)                 # 5 ms idle gap
        t += 10e-3
    sites = set(gov.tuner.summary())
    assert sites == {SITE_DECODE_STEP, SITE_IDLE_GAP}
    assert gov.finalize().n_theta_decisions > 0


# --------------------------------------------------------------------------
# 5-phase taxonomy: overlap is not slack
# --------------------------------------------------------------------------

def test_async_overlap_accounted_as_non_slack():
    gov = Governor()
    t = 1.0
    for call in range(10):
        for r in range(2):
            gov.sink(r, "dispatch_enter", call, t)         # overlap start
        for r in range(2):
            gov.sink(r, "wait_enter", call, t + 2e-3)      # slack starts HERE
        for r in range(2):
            gov.sink(r, "barrier_exit", call, t + 3e-3)
            gov.sink(r, "copy_exit", call, t + 3.5e-3)
        t += 10e-3
    rep = gov.finalize()
    assert rep.total_overlap == pytest.approx(10 * 2 * 2e-3)
    assert rep.total_slack == pytest.approx(10 * 2 * 1e-3)  # wait->exit only
    assert rep.total_copy == pytest.approx(10 * 2 * 0.5e-3)
    # 3-phase-naive accounting would have booked 3 ms of "slack" per rank
    # and downshifted into the overlap; here only the true 1 ms is priced
    assert rep.n_downshifts == 20                           # 1 ms > 750 us eff


def test_async_redispatch_rotates_occurrence():
    gov = Governor()
    for occurrence in range(3):
        t = 1.0 + occurrence
        gov.sink(0, "dispatch_enter", 5, t)
        gov.sink(0, "wait_enter", 5, t + 1e-3)
        gov.sink(0, "barrier_exit", 5, t + 2e-3)
        gov.sink(0, "copy_exit", 5, t + 2.2e-3)
    rep = gov.finalize()
    assert rep.n_calls == 3
    assert rep.total_slack == pytest.approx(3 * 1e-3)


# --------------------------------------------------------------------------
# simulator: adaptive theta series + overlap isolation
# --------------------------------------------------------------------------

def _overlap_workload(n_tasks=50, n_ranks=4, slack=4e-3, overlap=2.5e-3):
    comp = np.full((n_tasks, n_ranks), 8e-3)
    comp[:, 0] += slack                               # rank 0 critical
    return Workload(
        name="ovl", n_ranks=n_ranks, comp=comp,
        copy=np.full(n_tasks, 0.5e-3), is_p2p=np.zeros(n_tasks, bool),
        partner=np.zeros((n_tasks, n_ranks), np.int64),
        site=np.zeros(n_tasks, np.int64), nbytes=np.zeros(n_tasks),
        beta_comp=0.8, beta_copy=0.1,
        overlap=np.full(n_tasks, overlap),
    )


def test_simulator_overlap_aware_books_overlap_not_slack():
    wl = _overlap_workload()
    aware, _ = simulate(wl, COUNTDOWN_SLACK, overlap_aware=True, power_dt=5e-3)
    naive, _ = simulate(wl, COUNTDOWN_SLACK, overlap_aware=False, power_dt=5e-3)
    # both accounting modes keep the power series energy-conserving (the
    # unaware payback window is binned after the copy, where it happens)
    for res in (aware, naive):
        assert res.power_series.sum() * 5e-3 == pytest.approx(res.energy, rel=1e-9)
    assert aware.toverlap > 0.0 and naive.toverlap == 0.0
    assert aware.tslack < naive.tslack                # naive inflates slack
    assert aware.exploited_slack < naive.exploited_slack
    # the naive view pins the core during overlapped compute and pays the
    # lost work back after the barrier: measurable wall-clock harm
    assert naive.time > aware.time


def test_simulator_adaptive_emits_theta_series():
    wl = _overlap_workload(overlap=0.0)
    res, _ = simulate(wl, CNTD_ADAPTIVE, power_dt=2e-3)
    assert res.theta_series is not None and len(res.theta_series) == wl.n_tasks
    lo, hi = DEFAULT_HW.theta_bounds()
    assert np.all(res.theta_series >= lo)             # theta_eff >= theta_min
    assert np.all(res.theta_series <= hi + 0.5 * DEFAULT_HW.switch_latency)
    # 4 ms slack every call: the tuner relaxes theta below theta0
    assert res.theta_series[-1] < res.theta_series[0]
    assert res.theta_bins is not None
    assert res.theta_bins.shape[0] == res.power_series.shape[0]


def test_simulator_fixed_policy_unchanged_by_taxonomy_fields():
    """No-overlap workloads: the new accounting is bit-identical."""
    wl = _overlap_workload(overlap=0.0)
    a, _ = simulate(wl, COUNTDOWN_SLACK, overlap_aware=True)
    b, _ = simulate(wl, COUNTDOWN_SLACK, overlap_aware=False)
    assert a.time == b.time and a.energy == b.energy
    assert a.tslack == b.tslack and a.toverlap == b.toverlap == 0.0


# --------------------------------------------------------------------------
# instrumentation reset helper
# --------------------------------------------------------------------------

def test_reset_instrumentation_restores_defaults():
    from repro.core import instrument

    seen = []
    instrument.set_mode("profile")
    instrument.enable_events(True)
    instrument.set_event_sink(lambda *a: seen.append(a))
    instrument.set_event_tee(lambda *a: None)
    instrument._next_call_id()
    assert instrument._CALL_COUNTER[0] > 0
    instrument.reset_instrumentation()
    assert instrument.get_mode() == "off"
    assert len(instrument.get_event_bus()) == 0
    assert instrument._EVENTS_ENABLED is False
    assert instrument._CALL_COUNTER[0] == 0
    instrument._emit(0, 0, 1)                         # sinkless: no-op
    assert seen == []


def test_async_pair_jax_numerics_and_event_order():
    """cd_psum_async/cd_wait under a real shard_map: same numbers as the
    blocking path, and the 5-phase event sequence in dispatch -> wait ->
    barrier_exit -> copy_exit order."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.core import instrument
    from repro.core.instrument import cd_psum_async, cd_wait
    from repro.dist.compat import set_mesh, shard_map

    mesh = jax.make_mesh((1,), ("r",))
    events = []
    instrument.set_mode("profile")
    instrument.enable_events(True)
    instrument.set_event_sink(lambda r, p, c, t: events.append(p))

    def f(x):
        h = cd_psum_async(x, "r")
        y = x * 2.0                                   # overlapped compute
        return cd_wait(h) + y

    with set_mesh(mesh):
        g = shard_map(f, mesh=mesh, in_specs=P("r"), out_specs=P("r"),
                      manual_axes=("r",))
        x = jnp.arange(4.0)
        res = jax.block_until_ready(jax.jit(g)(x))
    np.testing.assert_allclose(np.asarray(res), np.asarray(x) * 3.0)
    assert events == ["dispatch_enter", "wait_enter", "barrier_exit", "copy_exit"]


def test_blocking_wrappers_numerics_and_events_per_mode():
    """cd_psum/cd_pmean/cd_all_gather/cd_ppermute across off/barrier/profile:
    numerics never change; profile mode emits the 3-phase sequence."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.core import instrument
    from repro.core.instrument import cd_all_gather, cd_pmean, cd_ppermute, cd_psum
    from repro.dist.compat import set_mesh, shard_map

    mesh = jax.make_mesh((1,), ("r",))
    x = jnp.arange(4.0)
    events = []
    instrument.set_event_sink(lambda r, p, c, t: events.append(p))

    def make_fn():
        # a FRESH closure per mode: the ambient mode is read at trace time
        # and jax caches traces per function object
        def f(x):
            a = cd_psum(x, "r")
            b = cd_pmean(x, "r")
            c = cd_all_gather(x, "r", tiled=True)
            d = cd_ppermute(x, "r", [(0, 0)])
            return a + b + c + d

        return shard_map(f, mesh=mesh, in_specs=P("r"), out_specs=P("r"),
                         manual_axes=("r",))

    results = {}
    with set_mesh(mesh):
        for mode in ("off", "barrier", "profile"):
            instrument.set_mode(mode)
            instrument.enable_events(mode == "profile")
            events.clear()
            results[mode] = np.asarray(jax.block_until_ready(jax.jit(make_fn())(x)))
            if mode == "profile":
                # 4 wrappers x (enter, exit, copy_exit), in order per call
                assert events == ["barrier_enter", "barrier_exit", "copy_exit"] * 4
            else:
                assert events == []
    np.testing.assert_array_equal(results["off"], results["barrier"])
    np.testing.assert_array_equal(results["off"], results["profile"])


def test_compressed_psum_async_pair_matches_blocking():
    """Mode off: the start/wait pair is numerically the blocking path."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.dist.compat import set_mesh, shard_map
    from repro.dist.compression import (
        compressed_psum, compressed_psum_start, compressed_psum_wait,
    )

    mesh = jax.make_mesh((1,), ("r",))
    grads = {"w": jnp.linspace(-1.0, 1.0, 8), "b": jnp.ones((4,))}

    def blocking(g):
        return compressed_psum(g, "r")

    def split(g):
        h = compressed_psum_start(g, "r")
        return compressed_psum_wait(h)

    with set_mesh(mesh):
        spec = {"w": P(), "b": P()}
        a = shard_map(blocking, mesh=mesh, in_specs=(spec,), out_specs=spec,
                      manual_axes=("r",))(grads)
        b = shard_map(split, mesh=mesh, in_specs=(spec,), out_specs=spec,
                      manual_axes=("r",))(grads)
    for k in grads:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))


def test_emit_maps_all_five_phase_codes():
    from repro.core import instrument

    seen = []
    instrument.set_event_sink(lambda r, p, c, t: seen.append(p))
    try:
        for code in range(5):
            instrument._emit(0, code, 1)
    finally:
        instrument.set_event_sink(None)
    assert seen == ["barrier_enter", "barrier_exit", "copy_exit",
                    "dispatch_enter", "wait_enter"]
