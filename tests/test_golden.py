"""Golden-report conformance suite.

``GovernorReport.to_dict()`` for all 8 fixed-theta policies on 3 canned
workload streams is frozen as committed JSON fixtures
(``tests/goldens/*.json``).  Any core refactor that shifts slack, energy,
downshift or overlap numbers fails here loudly; intentional changes are
made by re-running ``scripts/regen_goldens.py`` and justifying the diff.

Comparison is tolerance-pinned: integers and strings must match exactly,
floats to ``REL_TOL`` (the accounting is pure float64 arithmetic on
identical inputs, so in practice the match is bitwise on one platform; the
tolerance absorbs libm/platform drift without letting real changes through).
"""
import json
import os

import pytest

from golden_common import CANNED, GOLDEN_POLICY_NAMES, report_dict
from repro.core.policies import ALL_POLICIES

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "goldens")
REL_TOL = 1e-9
ABS_TOL = 1e-12


def _load(kind: str) -> dict:
    with open(os.path.join(GOLDEN_DIR, f"{kind}.json")) as f:
        return json.load(f)


def _assert_close(got, want, path=""):
    if isinstance(want, dict):
        assert isinstance(got, dict), f"{path}: {type(got).__name__} != dict"
        assert set(got) == set(want), (
            f"{path}: keys {sorted(set(got) ^ set(want))} differ"
        )
        for k in want:
            _assert_close(got[k], want[k], f"{path}.{k}")
    elif isinstance(want, list):
        assert isinstance(got, list) and len(got) == len(want), (
            f"{path}: length {len(got)} != {len(want)}"
        )
        for i, (g, w) in enumerate(zip(got, want)):
            _assert_close(g, w, f"{path}[{i}]")
    elif isinstance(want, float) or isinstance(got, float):
        assert got == pytest.approx(want, rel=REL_TOL, abs=ABS_TOL), (
            f"{path}: {got!r} != {want!r}"
        )
    else:
        assert got == want, f"{path}: {got!r} != {want!r}"


@pytest.mark.parametrize("policy_name", GOLDEN_POLICY_NAMES)
@pytest.mark.parametrize("kind", CANNED)
def test_report_matches_golden(kind, policy_name):
    fixture = _load(kind)["policies"][policy_name]
    # JSON round-trip the live report so dict keys (straggler ranks) compare
    # as the same type the fixture stores
    live = json.loads(json.dumps(report_dict(ALL_POLICIES[policy_name], kind)))
    _assert_close(live, fixture, path=f"{kind}/{policy_name}")


@pytest.mark.parametrize("kind", CANNED)
def test_fixture_covers_all_fixed_policies(kind):
    """A policy added to (or renamed in) FIXED_POLICIES without regenerating
    the fixtures is itself a conformance failure."""
    fixture = _load(kind)
    assert fixture["workload"] == kind
    assert sorted(fixture["policies"]) == sorted(GOLDEN_POLICY_NAMES)
    for name, rep in fixture["policies"].items():
        assert rep["n_calls"] > 0, f"{kind}/{name}: empty fixture"
