"""Fleet subsystem: prefix cache, router, autoscaler, watt arbitration.

The two load-bearing guarantees pinned here:

* **determinism** — same trace + seed through :class:`FleetSim` gives the
  identical dispatch sequence and bit-identical per-replica
  ``GovernorReport`` dicts (the reproducibility contract the energy
  numbers rest on);
* **refcount safety** — the prefix cache's shared/retained pages never
  double-free or leak under arbitrary join / retire / pressure-eviction
  interleavings (property test).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, reduced
from repro.serve import PagedKVPool, Request
from repro.serve.fleet import (
    Autoscaler,
    FleetConfig,
    FleetRouter,
    FleetSim,
    PrefixCache,
    ReplicaView,
    SimReplica,
    diurnal_trace,
    flash_crowd_trace,
    session_reuse_trace,
)


def _cfg():
    return reduced(get_config("llama3.2-1b"))


# --------------------------------------------------------------------------
# prefix cache: trie residency, matching, eviction
# --------------------------------------------------------------------------

def test_prefix_match_insert_and_cow_partial():
    pool = PagedKVPool(_cfg(), n_slots=2, max_len=32, page=8, num_pages=17,
                       materialize=False)
    cache = PrefixCache(pool, max_pages=8)
    assert pool.reserve_pages("w", 3)
    pages = pool.alloc("w", 3)
    tokens = np.arange(1, 21)                       # 20 tokens: 2 full + 4 partial
    assert cache.insert(tokens, pages) == 3
    pool.release("w")
    # resident pages survived their writer's release
    assert all(pool.refcount(p) == 1 for p in pages)

    m = cache.match(np.arange(1, 41))               # same 20-token prefix
    assert m.n_tokens == 20
    assert m.full_pages == pages[:2]
    assert m.partial_page == pages[2] and m.partial_len == 4

    # the cap: a prompt equal to the resident sequence matches len-1 only,
    # so the partial page (4 written tokens > 3 usable) is refused
    m = cache.match(tokens)
    assert m.n_tokens == 16 and m.partial_page is None

    # peek is side-effect free
    lookups = cache.n_lookups
    assert cache.peek(np.arange(1, 41)) == 20
    assert cache.n_lookups == lookups


def test_prefix_pressure_eviction_unblocks_admission():
    pool = PagedKVPool(_cfg(), n_slots=2, max_len=32, page=8, num_pages=9,
                       materialize=False)
    cache = PrefixCache(pool, max_pages=8)
    assert pool.reserve_pages("w", 6)
    pages = pool.alloc("w", 6)
    cache.insert(np.arange(1, 49), pages)
    pool.release("w")
    assert cache.n_resident_pages == 6 and pool.free_pages == 2
    # a 5-page reservation only fits if the pool pressures the cache
    assert pool.reserve_pages("big", 5)
    assert cache.n_evictions >= 3
    pool.release("big")
    cache.clear()
    assert pool.free_pages == pool.capacity_pages


def _reachable_nodes(cache) -> int:
    """Resident trie nodes actually reachable from the root — must equal
    ``_n_resident`` or eviction can never reclaim the orphans' pages."""
    n = 0
    stack = [cache._root]
    while stack:
        node = stack.pop()
        kids = list(node.children.values()) + list(node.partials.values())
        n += len(kids)
        stack.extend(node.children.values())
    return n


def test_insert_at_capacity_never_detaches_own_path():
    """Extending a resident chain at max_pages must not evict the chain
    tip being extended: the new node would attach to a detached parent,
    unreachable from the root — a page leaked until process exit."""
    pool = PagedKVPool(_cfg(), n_slots=2, max_len=64, page=8, num_pages=17,
                       materialize=False)
    cache = PrefixCache(pool, max_pages=2)
    assert pool.reserve_pages("a", 2)
    cache.insert(np.arange(1, 17), pool.alloc("a", 2))    # 2 full pages
    pool.release("a")
    assert cache.n_resident_pages == 2
    # same lineage, longer: at capacity the only LRU leaves ARE the path,
    # so adoption must stop rather than evict its own parent chain
    assert pool.reserve_pages("b", 4)
    cache.insert(np.arange(1, 31), pool.alloc("b", 4))
    pool.release("b")
    assert _reachable_nodes(cache) == cache.n_resident_pages == 2
    cache.clear()                       # every resident page is reclaimable
    assert cache.n_resident_pages == 0
    assert pool.free_pages == pool.capacity_pages


def test_insert_at_capacity_evicts_off_path_leaf():
    """With an unrelated LRU leaf available, a capacity insert evicts
    *that* leaf (not its own path) and the new lineage is adopted."""
    pool = PagedKVPool(_cfg(), n_slots=2, max_len=64, page=8, num_pages=17,
                       materialize=False)
    cache = PrefixCache(pool, max_pages=2)
    assert pool.reserve_pages("cold", 1)
    cache.insert(np.arange(50, 58), pool.alloc("cold", 1))   # unrelated leaf
    pool.release("cold")
    assert pool.reserve_pages("hot", 2)
    assert cache.insert(np.arange(1, 17), pool.alloc("hot", 2)) == 2
    pool.release("hot")
    assert cache.n_evictions == 1       # the cold leaf made room
    assert _reachable_nodes(cache) == cache.n_resident_pages == 2
    cache.clear()
    assert pool.free_pages == pool.capacity_pages


def test_match_stats_commit_only_on_admission():
    """A trial match is stats-free; commit() books it exactly once — so a
    head-of-line-blocked request polling match() every round cannot
    deflate hit_rate or refresh LRU for a prefix it never joined."""
    pool = PagedKVPool(_cfg(), n_slots=2, max_len=32, page=8, num_pages=9,
                       materialize=False)
    cache = PrefixCache(pool, max_pages=4)
    assert pool.reserve_pages("w", 2)
    pages = pool.alloc("w", 2)
    cache.insert(np.arange(1, 17), pages)
    pool.release("w")
    clock = cache._clock
    for _ in range(5):                  # five failed-admission polls
        m = cache.match(np.arange(1, 25))
    assert m.n_tokens == 16
    assert cache.n_lookups == 0 and cache.tokens_looked_up == 0
    assert cache.n_hits == 0 and cache._clock == clock
    cache.commit(m)                     # the poll that finally admitted
    assert cache.n_lookups == 1 and cache.tokens_looked_up == 24
    assert cache.n_hits == 1 and cache.tokens_matched == 16
    assert cache.hit_rate == pytest.approx(16 / 24)


def test_prefix_shared_page_survives_eviction_until_release():
    pool = PagedKVPool(_cfg(), n_slots=2, max_len=32, page=8, num_pages=9,
                       materialize=False)
    cache = PrefixCache(pool, max_pages=4)
    assert pool.reserve_pages("w", 2)
    pages = pool.alloc("w", 2)
    cache.insert(np.arange(1, 17), pages)
    pool.release("w")
    # a reader shares the resident pages, then the cache is fully evicted:
    # the pages must stay allocated for the reader
    assert pool.reserve_pages("r", 0)
    pool.share("r", pages)
    cache.clear()
    assert all(pool.refcount(p) == 1 for p in pages)
    pool.release("r")
    assert pool.free_pages == pool.capacity_pages


# --------------------------------------------------------------------------
# router
# --------------------------------------------------------------------------

def test_router_prefers_prefix_then_free_pages_ties_to_lowest_id():
    pool = PagedKVPool(_cfg(), n_slots=2, max_len=32, page=8, num_pages=9,
                       materialize=False)
    cache = PrefixCache(pool)
    assert pool.reserve_pages("w", 2)
    cache.insert(np.arange(1, 17), pool.alloc("w", 2))
    pool.release("w")

    def view(rid, c, n_active=0):
        return ReplicaView(replica_id=rid, n_slots=4, n_active=n_active,
                           n_queued=0, free_pages=8, capacity_pages=8,
                           prefix_cache=c)

    empty = PrefixCache(PagedKVPool(_cfg(), 2, 32, 8, 9, materialize=False))
    router = FleetRouter()
    req = Request(prompt=np.arange(1, 25, dtype=np.int32), max_new=4,
                  arrival=0.0)
    dec = router.route(req, [view(0, empty), view(1, cache)])
    assert dec.replica_id == 1 and dec.matched_tokens == 16
    assert router.n_prefix_routed == 1
    # no prefix signal anywhere: load breaks the tie...
    req2 = Request(prompt=np.full(24, 999, np.int32), max_new=4, arrival=0.0)
    dec = router.route(req2, [view(0, empty, n_active=4), view(1, cache)])
    assert dec.replica_id == 1
    # ...and a dead tie goes to the lowest replica id (determinism)
    dec = router.route(req2, [view(1, empty), view(0, empty)])
    assert dec.replica_id == 0


def test_fleet_determinism_dispatch_and_bit_identical_reports():
    trace = flash_crowd_trace(duration_s=10, seed=3)
    runs = []
    for _ in range(2):
        fc = FleetConfig(cfg=_cfg(), n_replicas=2, autoscale=True,
                         min_replicas=1, cap_w=40.0, floor_w=4.0,
                         step_s=0.01, ttft_target=1.5)
        sim = FleetSim(fc)
        res = sim.run(trace)
        runs.append((
            [d.replica_id for d in sim.router.decisions],
            res.reports,
            res.energy_j,
        ))
    assert runs[0][0] == runs[1][0]          # identical dispatch sequence
    assert runs[0][1] == runs[1][1]          # bit-identical GovernorReports
    assert runs[0][2] == runs[1][2]


# --------------------------------------------------------------------------
# autoscaler
# --------------------------------------------------------------------------

def test_autoscaler_max_replicas_clamped_to_watt_floor():
    a = Autoscaler(max_replicas=10, cap_w=40.0, floor_w=6.0)
    assert a.max_replicas == 6               # floor(40/6): arbiter would raise
    assert Autoscaler(min_replicas=9, max_replicas=10, cap_w=40.0,
                      floor_w=6.0).min_replicas == 6


def test_autoscaler_hysteresis_and_cooldown():
    a = Autoscaler(max_replicas=4, ttft_target=0.5, cooldown_epochs=2,
                   down_consecutive=3)
    assert a.decide(0, 1, ttft_p95=0.9, fill_mean=0.9, n_queued=0) == +1
    # cooldown holds the next epoch even under pressure
    assert a.decide(1, 2, ttft_p95=0.9, fill_mean=0.9, n_queued=0) == 0
    # quiet epochs must accumulate before a down fires
    for e in (2, 3):
        assert a.decide(e, 2, ttft_p95=0.0, fill_mean=0.1, n_queued=0) == 0
    assert a.decide(4, 2, ttft_p95=0.0, fill_mean=0.1, n_queued=0) == -1
    # one hot epoch resets the streak
    for e in (5, 6):
        a.decide(e, 1, ttft_p95=0.0, fill_mean=0.1, n_queued=0)


def test_fleet_config_rejects_zero_min_replicas():
    """min_replicas == 0 would start an autoscaled fleet with no routable
    replica: the router raises on the very first arrival."""
    with pytest.raises(ValueError, match="min_replicas"):
        FleetConfig(cfg=_cfg(), n_replicas=2, autoscale=True, min_replicas=0)
    with pytest.raises(ValueError, match="min_replicas"):
        FleetConfig(cfg=_cfg(), n_replicas=1, min_replicas=2)


def test_autoscaled_fleet_caps_and_dynamics():
    """The bench headline invariants: ups AND downs fire on the diurnal
    trace, the granted watts never exceed the cluster cap across
    membership changes, and every request completes."""
    trace = diurnal_trace(duration_s=60, base_rate=2.0, peak_ratio=8, seed=0)
    fc = FleetConfig(cfg=_cfg(), n_replicas=3, autoscale=True, min_replicas=1,
                     cap_w=40.0, floor_w=4.0, step_s=0.01, ttft_target=1.5)
    res = FleetSim(fc).run(trace)
    assert res.n_completed == res.n_requests
    assert res.n_scale_ups > 0 and res.n_scale_downs > 0
    assert res.max_alloc_sum_w <= res.cap_w + 1e-9
    assert res.n_replicas_peak == 3
    assert all(e["alloc_sum_w"] <= res.cap_w + 1e-9 for e in res.epochs)


def test_session_reuse_hits_prefix_cache():
    fc = FleetConfig(cfg=_cfg(), n_replicas=2, autoscale=False,
                     cap_w=40.0, floor_w=4.0, step_s=0.01, ttft_target=1.5)
    res = FleetSim(fc).run(session_reuse_trace(seed=1))
    assert res.n_completed == res.n_requests
    assert res.prefix_hit_rate > 0.3         # dialogue resends are the point
    assert res.prefix_hits > 0


# --------------------------------------------------------------------------
# refcounted free list: never double-frees, never leaks (property test)
# --------------------------------------------------------------------------

@settings(max_examples=30)
@given(st.lists(st.tuples(st.integers(0, 5), st.integers(1, 30),
                          st.integers(0, 6)),
                min_size=1, max_size=30))
def test_refcount_free_list_never_double_frees(ops):
    """Arbitrary interleavings of prefix-aware admission (match -> pin ->
    reserve -> share -> CoW alloc -> insert -> release) with pressure
    eviction keep every page's refcount consistent: a double free raises
    inside the pool, and after teardown every page is back on the free
    list exactly once."""
    pool = PagedKVPool(_cfg(), n_slots=4, max_len=64, page=8, num_pages=17,
                       materialize=False)
    cache = PrefixCache(pool, max_pages=8)
    live = []
    rid = 0
    for base, length, evict_n in ops:
        if evict_n and len(live) > 2:        # retire the oldest live request
            old, pages, tokens = live.pop(0)
            cache.insert(tokens, pages)
            pool.release(old)
            # no insert may orphan a retained node from the root
            assert _reachable_nodes(cache) == cache.n_resident_pages
        # heavily colliding prompts so matches / shares / CoW all occur
        prompt = np.array([(base + j) % 7 + 1 for j in range(length)],
                          np.int32)
        m = cache.match(prompt)
        shared = list(m.full_pages)
        if m.partial_page is not None:
            shared.append(m.partial_page)
        need = pool.pages_needed(len(prompt)) - len(m.full_pages)
        pool.retain(shared)                  # pin across the pressure window
        rid += 1
        if not pool.reserve_pages(rid, need):
            pool.unretain(shared)
            continue
        pages = list(m.full_pages)
        if shared:
            pool.share(rid, shared)
            pool.unretain(shared)
        cache.commit(m)                      # stats/LRU move only on success
        if m.partial_page is not None:
            pages.extend(pool.alloc(rid, 1))          # CoW clone
        rest = pool.pages_needed(len(prompt)) - len(pages)
        if rest > 0:
            pages.extend(pool.alloc(rid, rest))
        live.append((rid, pages, prompt))
        # invariant: free pages carry zero refs, live pages positive refs
        for pid in pool._free:
            assert pool.refcount(pid) == 0
        assert all(n >= 0 for n in pool._ref.values())
    for old, pages, tokens in live:
        cache.insert(tokens, pages)
        pool.release(old)
        assert _reachable_nodes(cache) == cache.n_resident_pages
    cache.clear()
    assert cache.n_resident_pages == 0
    assert pool.free_pages == pool.capacity_pages
    assert sorted(pool._free) == list(range(1, pool.num_pages))


def test_pool_double_free_raises():
    pool = PagedKVPool(_cfg(), n_slots=2, max_len=32, page=8, num_pages=9,
                       materialize=False)
    assert pool.reserve_pages("a", 1)
    (pid,) = pool.alloc("a", 1)
    pool.release("a")
    with pytest.raises(RuntimeError, match="double free"):
        pool.unretain([pid])


# --------------------------------------------------------------------------
# replica lifecycle + arbiter sample surface
# --------------------------------------------------------------------------

def test_sim_replica_prefix_join_replays_suffix_and_reinserts():
    rep = SimReplica(0, _cfg(), n_slots=2, max_len=64, page=8, step_s=1e-3)
    prompt = np.arange(1, 25, dtype=np.int32)
    script = np.arange(100, 108, dtype=np.int32)
    rep.submit(Request(prompt=prompt, max_new=8, arrival=0.0,
                       out_script=script))
    rep.advance_to(1.0)
    assert rep.prefix_cache.n_insertions == 1
    # second identical prompt matches, replays the suffix forced, and
    # produces the same scripted output
    rep.submit(Request(prompt=prompt, max_new=8, arrival=1.0,
                       out_script=script))
    rep.advance_to(2.0)
    assert rep.prefix_cache.n_hits == 1
    assert [list(r.out) for r in rep.finished] == [list(script)] * 2


def test_job_sample_surfaces_slo_and_prefix_counters():
    rep = SimReplica(0, _cfg(), n_slots=2, max_len=64, page=8, step_s=1e-3)
    prompt = np.arange(1, 25, dtype=np.int32)
    for t in (0.0, 0.5):
        rep.submit(Request(prompt=prompt, max_new=4, arrival=t))
    rep.advance_to(1.0)
    s = rep.job_sample(0.25)
    assert s.ttft_p50 > 0.0 and s.tpot_p50 > 0.0
    assert s.prefix_lookups == 2 and s.prefix_hits == 1
    assert 0.0 < s.prefix_hit_rate <= 1.0
    d = s  # JobSample is the arbiter wire format: fields must exist
    for name in ("ttft_p99", "tpot_p99", "prefix_hit_rate"):
        assert hasattr(d, name)


def test_serve_job_sample_carries_slo_and_prefix(monkeypatch):
    from types import SimpleNamespace

    from repro.cluster.job import ServeJob
    from repro.core.governor import Governor
    from repro.serve import SLOTracker

    slo = SLOTracker()
    req = SimpleNamespace(arrival=0.0, t_first=None, t_prev=None, out=[1])
    slo.on_first_token(req, 0.125)
    cache = SimpleNamespace(n_hits=3, n_lookups=4, hit_rate=0.5)
    engine = SimpleNamespace(prefix_cache=cache)
    job = ServeJob("svc", engine, Governor(), cap_w=10.0, slo=slo)
    s = job.last_sample()
    assert s.ttft_p50 == pytest.approx(0.125)
    assert s.prefix_hits == 3 and s.prefix_lookups == 4
    assert s.prefix_hit_rate == 0.5


# --------------------------------------------------------------------------
# real engine: prefix-cache joins are output-equivalent to cold prefill
# --------------------------------------------------------------------------

def test_engine_prefix_cache_outputs_match_cold_path(rng_key):
    """Shared-prefix requests served through prefix joins (shared pages +
    CoW clone + forced suffix replay) must produce exactly the tokens the
    cache-off engine produces — the bitwise K/V-prefix claim, end to end."""
    from repro.models import init_params
    from repro.serve import ContinuousEngine

    cfg = _cfg()
    params = init_params(cfg, rng_key)
    shared = np.arange(1, 17, dtype=np.int32)
    prompts = [np.concatenate([shared, np.full(4, 40 + i, np.int32)])
               for i in range(3)]

    def serve(with_cache):
        eng = ContinuousEngine(cfg, params, n_slots=2, max_len=64, page=8,
                               temperature=0.0)
        if with_cache:
            eng.enable_prefix_cache()
        reqs = [Request(prompt=p, max_new=6, arrival=0.02 * i)
                for i, p in enumerate(prompts)]
        done = eng.serve(reqs)
        outs = {tuple(r.prompt.tolist()): list(r.out) for r in done}
        hits = eng.prefix_cache.n_hits if with_cache else 0
        return outs, hits

    cold, _ = serve(False)
    warm, hits = serve(True)
    assert hits > 0                          # the cache actually engaged
    assert warm == cold                      # token-for-token identical


# --------------------------------------------------------------------------
# lazy exports (PEP 562)
# --------------------------------------------------------------------------

def test_serve_lazy_exports_and_dir():
    import importlib

    import repro.serve as serve

    serve = importlib.reload(serve)
    listing = dir(serve)
    for name in ("ContinuousEngine", "FleetSim", "PrefixCache", "FleetRouter",
                 "Autoscaler", "diurnal_trace", "run_engine_fleet", "fleet",
                 "kvcache", "scheduler"):
        assert name in listing, name
    # lazy resolution works and is cached
    assert serve.FleetRouter is FleetRouter
    assert serve.fleet.FleetSim is FleetSim
    with pytest.raises(AttributeError):
        serve.not_a_symbol
