"""Governor <-> StragglerDetector integration: a synthetic event stream with
one deliberate laggard rank must surface in ``GovernorReport.stragglers``,
and the detector's view must stay consistent with the governor's slack
accounting (the laggard waits least, everyone else waits for it)."""
import numpy as np
import pytest

from repro.core.governor import Governor
from repro.core.policies import COUNTDOWN, COUNTDOWN_SLACK
from repro.dist.straggler import StragglerDetector


def _stream(gov, n_ranks=8, n_calls=40, laggard=5, lag=0.003, jitter=1e-4, seed=0):
    """Emit barrier_enter/exit + copy_exit events for ``n_calls`` barriers.

    Every rank arrives with small gaussian jitter; ``laggard`` always
    arrives ``lag`` seconds after the pack.  Exit = the last arrival (the
    barrier semantics), copy takes 0.5 ms at full speed.
    """
    rng = np.random.default_rng(seed)
    t = 10.0
    for call in range(n_calls):
        arrivals = {r: t + rng.normal(0.0, jitter) for r in range(n_ranks)}
        arrivals[laggard] = t + lag
        release = max(arrivals.values())
        for r, tr in arrivals.items():
            gov.sink(r, "barrier_enter", call, tr)
        for r in range(n_ranks):
            gov.sink(r, "barrier_exit", call, release)
            gov.sink(r, "copy_exit", call, release + 0.5e-3)
        t = release + 0.01


def test_laggard_rank_surfaces_in_report():
    gov = Governor(policy=COUNTDOWN_SLACK)
    _stream(gov, laggard=5)
    rep = gov.finalize()
    assert rep.n_calls == 40
    flagged = [r for r, z in rep.stragglers]
    assert flagged == [5]
    # the laggard's z-score for one outlier in 8 ranks approaches sqrt(7)
    z = dict(rep.stragglers)[5]
    assert 2.0 <= z <= np.sqrt(7) + 1e-6


def test_straggler_summary_orders_ranks_by_lateness():
    gov = Governor()
    _stream(gov, laggard=2, lag=0.004)
    rep = gov.finalize()
    # summary: laggard has the largest (positive) mean lateness; the others
    # sit slightly early (negative), since lateness is mean-relative
    worst = max(rep.straggler_summary, key=rep.straggler_summary.get)
    assert worst == 2
    assert rep.straggler_summary[2] > 0
    others = [v for r, v in rep.straggler_summary.items() if r != 2]
    assert all(v < 0 for v in others)


def test_laggard_slack_is_on_everyone_else():
    """The paper's critical-rank structure: the rank that arrives last is
    the one with (near) zero slack; the waiting is booked to the others."""
    det = StragglerDetector()
    gov = Governor(policy=COUNTDOWN, detector=det)
    n_ranks, n_calls, lag = 8, 30, 0.005
    _stream(gov, n_ranks=n_ranks, n_calls=n_calls, laggard=0, lag=lag, jitter=0.0)
    rep = gov.finalize()
    # each of the 7 non-critical ranks waits ~lag per call
    expected = n_calls * (n_ranks - 1) * lag
    assert rep.total_slack == pytest.approx(expected, rel=1e-3)
    # 5 ms slack >> 500 us theta: every non-critical wait is exploitable
    assert rep.n_downshifts == n_calls * (n_ranks - 1)
    assert rep.energy_saving_pct > 0
    assert [r for r, _ in rep.stragglers] == [0]
    # governor shares its detector with the caller
    assert det.n_barriers == n_calls


def test_balanced_ranks_flag_nothing():
    gov = Governor()
    rng = np.random.default_rng(1)
    for call in range(30):
        base = 5.0 + call * 0.01
        arrivals = {r: base + rng.normal(0.0, 1e-4) for r in range(8)}
        for r, tr in arrivals.items():
            gov.sink(r, "barrier_enter", call, tr)
        release = max(arrivals.values())
        for r in range(8):
            gov.sink(r, "barrier_exit", call, release)
    rep = gov.finalize()
    assert rep.stragglers == []
