"""Batched ingest spine: EventBatch/BatchAccumulator, publish_batch
fan-out + legacy fallback, drain queues, and — the conformance property
of this layer — bitwise equality between the governor's vectorized
``on_batch`` fold and the per-event ``sink`` path on arbitrary 5-phase
streams with rotations, carry across chunk boundaries, and mixed
per-event/batched feeding.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.events import (
    PHASE_CODES, PHASE_NAMES, BatchAccumulator, EventBatch, EventBus,
    PhaseEvent,
)
from repro.core.governor import Governor
from repro.core.policies import (
    BASELINE, CNTD_ADAPTIVE, COUNTDOWN, COUNTDOWN_SLACK, FERMATA_500US,
    MINFREQ,
)

# --------------------------------------------------------------------------
# EventBatch / BatchAccumulator
# --------------------------------------------------------------------------

def test_event_batch_roundtrip_and_occupancy():
    rows = [(0, "barrier_enter", 7, 1.0), (1, "barrier_exit", 7, 1.5),
            (2, 3, 9, 2.0)]                      # phase as name or code
    b = EventBatch.from_rows(rows, capacity=4)
    assert b.n == 3 and b.occupancy == 0.75
    assert b.rank.dtype == np.int32 and b.code.dtype == np.int8
    assert b.call_id.dtype == np.int64 and b.t.dtype == np.float64
    assert list(b.iter_events()) == [
        PhaseEvent(0, "barrier_enter", 7, 1.0),
        PhaseEvent(1, "barrier_exit", 7, 1.5),
        PhaseEvent(2, "dispatch_enter", 9, 2.0),
    ]
    assert EventBatch.from_rows([]).n == 0


def test_batch_accumulator_append_flush_cycle():
    acc = BatchAccumulator(capacity=3)
    assert not acc.append(0, 0, 1, 1.0)
    assert not acc.append(1, 1, 1, 2.0)
    assert acc.append(2, 2, 1, 3.0)              # True exactly when it fills
    assert acc.full and len(acc) == 3 and acc.free == 0
    b = acc.flush()
    assert b.n == 3 and b.capacity == 3 and b.occupancy == 1.0
    assert b.rank.tolist() == [0, 1, 2]
    assert len(acc) == 0 and acc.flush() is None  # buffer reusable, empty
    acc.append(5, 4, 2, 9.0)
    b2 = acc.flush()
    assert b2.rank.tolist() == [5] and b2.code.tolist() == [4]
    assert b.rank.tolist() == [0, 1, 2]          # flush copies: b unharmed


def test_batch_accumulator_extend_and_overflow():
    acc = BatchAccumulator(capacity=4)
    acc.extend([0, 1], [0, 0], [3, 3], [1.0, 1.1])
    with pytest.raises(ValueError):
        acc.extend([0, 1, 2], [1, 1, 1], [3, 3, 3], [2.0, 2.1, 2.2])
    acc.extend([2, 3], [0, 0], [3, 3], [1.2, 1.3])
    assert acc.full
    acc.clear()
    assert len(acc) == 0
    with pytest.raises(ValueError):
        BatchAccumulator(capacity=0)


# --------------------------------------------------------------------------
# EventBus: publish_batch fan-out, legacy fallback, queues, counters
# --------------------------------------------------------------------------

class _BatchListener:
    def __init__(self):
        self.batches = []

    def on_batch(self, batch):
        self.batches.append(batch)


class _EventListener:
    def __init__(self):
        self.events = []

    def on_event(self, rank, phase, call_id, t):
        self.events.append((rank, phase, call_id, t))


def _stream_rows():
    return [(0, "barrier_enter", 3, 1.0), (1, "barrier_enter", 3, 1.001),
            (0, "barrier_exit", 3, 1.002), (1, "barrier_exit", 3, 1.002),
            (0, "copy_exit", 3, 1.003), (1, "copy_exit", 3, 1.003)]


def test_publish_batch_fans_out_batch_and_legacy_views():
    bus = EventBus()
    fast, legacy = _BatchListener(), _EventListener()
    bus.subscribe(fast)
    bus.subscribe(legacy)
    batch = EventBatch.from_rows(_stream_rows(), capacity=8)
    bus.publish_batch(batch)
    assert len(fast.batches) == 1 and fast.batches[0] is batch
    # the legacy subscriber sees the identical stream, decoded, in order
    assert legacy.events == _stream_rows()
    stats = bus.ingest_stats()
    assert stats["events_total"] == 6 and stats["batches_total"] == 1
    assert stats["fallback_events_total"] == 6
    assert stats["mean_occupancy"] == pytest.approx(6 / 8)
    bus.publish_batch(EventBatch.from_rows([]))   # empty: no-op, no counters
    assert bus.ingest_stats()["batches_total"] == 1


def test_enqueue_drain_fifo_and_depth():
    bus = EventBus()
    seen = _EventListener()
    bus.subscribe(seen)
    b1 = EventBatch.from_rows(_stream_rows()[:2])
    b2 = EventBatch.from_rows(_stream_rows()[2:])
    bus.enqueue(b1)
    bus.enqueue(b2)
    bus.enqueue(EventBatch.from_rows([]))         # empty chunks not queued
    assert bus.queue_depth == 2 and bus.queued_events == 6
    assert bus.drain(max_batches=1) == 2
    assert bus.queue_depth == 1
    assert bus.drain() == 4
    assert bus.queue_depth == 0 and seen.events == _stream_rows()
    stats = bus.ingest_stats()
    assert stats["events_total"] == 6 and stats["queue_depth"] == 0


def test_bus_clear_resets_ingest_state():
    bus = EventBus()
    bus.enqueue(EventBatch.from_rows(_stream_rows()))
    bus.subscribe(_BatchListener())
    bus.publish_batch(EventBatch.from_rows(_stream_rows()))
    bus.clear()
    stats = bus.ingest_stats()
    assert stats == {"events_total": 0, "batches_total": 0,
                     "mean_occupancy": 0.0, "fallback_events_total": 0,
                     "queue_depth": 0, "queued_events": 0}


# --------------------------------------------------------------------------
# batched/per-event governor equivalence (the conformance property)
# --------------------------------------------------------------------------

_EQ_POLICIES = [BASELINE, MINFREQ, COUNTDOWN, COUNTDOWN_SLACK, FERMATA_500US]


def _random_events(seed, n_rounds=None):
    """Adversarial 5-phase stream as (rank, phase, call_id, t) rows: async
    and blocking occurrences, rotations, partial occurrences, stragglers."""
    rng = np.random.default_rng(seed)
    rows = []
    t = 1.0
    n_ranks = int(rng.integers(2, 7))
    call_ids = list(range(int(rng.integers(1, 5))))
    for _ in range(n_rounds or int(rng.integers(5, 40))):
        t += float(rng.uniform(1e-4, 5e-3))
        cid = int(rng.choice(call_ids))
        is_async = rng.random() < 0.35
        ranks = list(rng.permutation(n_ranks)[: int(rng.integers(1, n_ranks + 1))])
        arrivals = {r: t + float(rng.uniform(0.0, 2e-3)) for r in ranks}
        release = max(arrivals.values()) + float(rng.uniform(0.0, 1e-3))
        if is_async:
            for r in ranks:
                rows.append((r, "dispatch_enter", cid, arrivals[r] - 1e-3))
            for r in ranks:
                rows.append((r, "wait_enter", cid, arrivals[r]))
        else:
            for r in ranks:
                rows.append((r, "barrier_enter", cid, arrivals[r]))
        complete = rng.random()
        if complete < 0.85:                       # some never exit
            for r in ranks:
                rows.append((r, "barrier_exit", cid, release))
            if complete < 0.7:                    # some never copy
                for r in ranks:
                    rows.append((r, "copy_exit", cid,
                                 release + float(rng.uniform(0.0, 2e-3))))
        t = release
    return rows


def _chunks(rows, rng):
    """Cut a row stream into random-size EventBatch chunks (1..17 events),
    exercising carry of in-flight occurrences across chunk boundaries."""
    i = 0
    while i < len(rows):
        k = int(rng.integers(1, 18))
        yield EventBatch.from_rows(rows[i:i + k], capacity=32)
        i += k


def _fingerprint(gov):
    det = gov.detector
    return (
        gov.finalize().to_dict(),
        gov.actuation_log,
        gov.n_actuations,
        gov.n_inflight,
        [(r.call_id, r.enter, r.slack_end, r.copy_end, r.dispatch, r.observed)
         for r in gov.recent_records()],
        det._late_sum, list(det._late_sum), det._count, det.n_barriers,
    )


@given(st.integers(min_value=0, max_value=100_000))
@settings(max_examples=60, deadline=None)
def test_batched_report_bitwise_equals_per_event(seed):
    rows = _random_events(seed)
    pol = _EQ_POLICIES[seed % len(_EQ_POLICIES)]
    rng = np.random.default_rng(seed + 1)
    ref = Governor(policy=pol, retention=8)       # tiny ring: eviction + mix
    gov = Governor(policy=pol, retention=8)
    for r, p, c, t in rows:
        ref.sink(r, p, c, t)
    bus = EventBus()
    bus.subscribe(gov)
    for chunk in _chunks(rows, rng):
        bus.enqueue(chunk)
    assert bus.drain() == len(rows)
    assert _fingerprint(gov) == _fingerprint(ref)
    assert bus.ingest_stats()["fallback_events_total"] == 0


@given(st.integers(min_value=0, max_value=100_000))
@settings(max_examples=30, deadline=None)
def test_mixed_per_event_and_batched_feeding_equivalent(seed):
    """Chunks and stray per-event sink() calls interleave on one governor:
    the columnar tails must materialize/seed across the seam losslessly."""
    rows = _random_events(seed)
    pol = _EQ_POLICIES[seed % len(_EQ_POLICIES)]
    rng = np.random.default_rng(seed + 2)
    ref = Governor(policy=pol)
    gov = Governor(policy=pol)
    for r, p, c, t in rows:
        ref.sink(r, p, c, t)
    i = 0
    while i < len(rows):
        if rng.random() < 0.4:                    # per-event stretch
            k = int(rng.integers(1, 6))
            for r, p, c, t in rows[i:i + k]:
                gov.sink(r, p, c, t)
        else:
            k = int(rng.integers(1, 12))
            gov.on_batch(EventBatch.from_rows(rows[i:i + k]))
        i += k
    assert _fingerprint(gov) == _fingerprint(ref)


def test_midrun_finalize_between_chunks_matches_per_event():
    """finalize() between chunks materializes tails in place; later chunks
    must keep accounting (and re-observation) bitwise identical."""
    rows = _random_events(12345, n_rounds=30)
    ref = Governor()
    gov = Governor()
    for r, p, c, t in rows:
        ref.sink(r, p, c, t)
    cut = len(rows) // 2
    mid_ref_gov = Governor()
    for r, p, c, t in rows[:cut]:
        mid_ref_gov.sink(r, p, c, t)
    mid_ref = mid_ref_gov.finalize().to_dict()
    del mid_ref_gov
    gov.on_batch(EventBatch.from_rows(rows[:cut]))
    assert gov.finalize().to_dict() == mid_ref    # mid-run poll
    gov.on_batch(EventBatch.from_rows(rows[cut:]))
    # the per-event ref needs the same mid-run poll for observed parity
    ref2 = Governor()
    for r, p, c, t in rows[:cut]:
        ref2.sink(r, p, c, t)
    ref2.finalize()
    for r, p, c, t in rows[cut:]:
        ref2.sink(r, p, c, t)
    assert _fingerprint(gov) == _fingerprint(ref2)


def test_tuner_policy_falls_back_to_per_event_replay():
    """An adaptive policy (tuner = sequential feedback) must take the
    per-event replay path and still match sink() exactly."""
    rows = _random_events(777)
    ref = Governor(policy=CNTD_ADAPTIVE)
    gov = Governor(policy=CNTD_ADAPTIVE)
    for r, p, c, t in rows:
        ref.sink(r, p, c, t)
    gov.on_batch(EventBatch.from_rows(rows))
    assert gov.finalize().to_dict() == ref.finalize().to_dict()
    assert gov.theta_log == ref.theta_log


@pytest.mark.parametrize("rows", [
    # double barrier_exit for one rank in one occurrence (overwrite)
    [(0, "barrier_enter", 1, 1.0), (0, "barrier_exit", 1, 1.002),
     (0, "barrier_exit", 1, 1.003), (0, "copy_exit", 1, 1.004)],
    # enter overwritten by wait_enter without a rotation
    [(0, "barrier_enter", 1, 1.0), (0, "wait_enter", 1, 1.001),
     (0, "barrier_exit", 1, 1.004)],
    # duplicate copy_exit
    [(0, "barrier_enter", 1, 1.0), (1, "barrier_enter", 1, 1.0),
     (0, "barrier_exit", 1, 1.002), (0, "copy_exit", 1, 1.003),
     (0, "copy_exit", 1, 1.004)],
    # negative rank (ingest from a synthetic producer)
    [(-1, "barrier_enter", 1, 1.0), (-1, "barrier_exit", 1, 1.002)],
    # unknown phase code rides through untouched
    [(0, "barrier_enter", 1, 1.0), (0, "code_7", 1, 1.001),
     (0, "barrier_exit", 1, 1.002)],
])
def test_pathological_streams_fall_back_bitwise_equal(rows):
    rows = [(r, p, c, t) for r, p, c, t in rows]
    ref = Governor()
    gov = Governor()
    for r, p, c, t in rows:
        ref.sink(r, p, c, t)
    codes = [PHASE_CODES.get(p, 7) for _, p, _, _ in rows]
    batch = EventBatch(
        np.asarray([r for r, _, _, _ in rows], np.int32),
        np.asarray(codes, np.int8),
        np.asarray([c for _, _, c, _ in rows], np.int64),
        np.asarray([t for _, _, _, t in rows], np.float64),
    )
    gov.on_batch(batch)
    assert _fingerprint(gov) == _fingerprint(ref)


def test_legacy_recorder_subscriber_forces_fallback():
    """A recorder wanting per-event/per-retirement callbacks (on_event /
    on_retired without on_retired_batch) gets them, in stream order."""
    class _Rec:
        def __init__(self):
            self.events, self.retired = [], []

        def on_event(self, rank, phase, call_id, t):
            self.events.append((rank, phase, call_id, t))

    class _RetireRec:
        def __init__(self):
            self.retired = []

        def on_retired(self, rec):
            self.retired.append(rec.call_id)

    rows = _random_events(31337)
    rec1, rec2 = _Rec(), _RetireRec()
    g1 = Governor(recorder=rec1)
    g2 = Governor(recorder=rec2)
    ref = Governor()
    for r, p, c, t in rows:
        ref.sink(r, p, c, t)
    g1.on_batch(EventBatch.from_rows(rows))
    g2.on_batch(EventBatch.from_rows(rows))
    assert rec1.events == rows
    assert g1.finalize().to_dict() == ref.finalize().to_dict()
    assert g2.finalize().to_dict() == ref.finalize().to_dict()
    assert rec2.retired == [r.call_id for r in ref.recent_records()][
        -len(rec2.retired):] if rec2.retired else True


def test_retired_block_recorder_receives_blocks():
    class _BlockRec:
        def __init__(self):
            self.blocks = []

        def on_retired_batch(self, block):
            self.blocks.append(block)

        def on_retired(self, rec):                # must NOT be used
            raise AssertionError("batch-capable recorder got per-event hook")

    rows = _random_events(99, n_rounds=20)
    rec = _BlockRec()
    gov = Governor(recorder=rec)
    ref = Governor()
    for r, p, c, t in rows:
        ref.sink(r, p, c, t)
    gov.on_batch(EventBatch.from_rows(rows))
    assert gov.finalize().to_dict() == ref.finalize().to_dict()
    # blocks cover exactly the retired occurrences, in order, and
    # materialize to the same records the per-event ring retired
    ring_ref = ref.recent_records()
    mat = [r for b in rec.blocks for r in b.records()]
    assert [r.call_id for r in mat] == [r.call_id for r in ring_ref]
    assert [(r.enter, r.slack_end, r.copy_end, r.dispatch) for r in mat] == \
           [(r.enter, r.slack_end, r.copy_end, r.dispatch) for r in ring_ref]
    for b in rec.blocks:
        n_enter_rows = b.class_counts("enter")
        assert (n_enter_rows >= b.n_enter).all()  # enter class counts cover
        assert b.wait_counts().shape == (len(b),)


# --------------------------------------------------------------------------
# the spine across the other layers: instrument, simulator, obs stack
# --------------------------------------------------------------------------

def test_instrument_ingest_mode_buffers_and_flushes_in_order():
    """set_ingest_mode('batched') buffers host events in the ambient
    accumulator: full chunks queue (never deliver inline — io_callback
    context), flush_events() drains everything in stream order, and
    switching modes never drops or reorders events."""
    from repro.core import instrument

    seen = _EventListener()
    bus = instrument.get_event_bus()
    bus.subscribe(seen)
    assert instrument.get_ingest_mode() == "event"
    with pytest.raises(ValueError):
        instrument.set_ingest_mode("columnar")
    instrument.set_ingest_mode("batched", batch_size=4)
    assert instrument.get_ingest_mode() == "batched"
    for i in range(6):
        instrument._emit(i % 2, i % 5, 11)
    # one full chunk queued, two events still buffered, none delivered
    assert seen.events == []
    assert bus.queue_depth == 1 and bus.queued_events == 4
    assert instrument.flush_events() == 6
    assert [(r, p, c) for r, p, c, _ in seen.events] == \
           [(i % 2, PHASE_NAMES[i % 5], 11) for i in range(6)]
    ts = [t for _, _, _, t in seen.events]
    assert ts == sorted(ts)
    # mode switch flushes the partial buffer before changing path
    instrument._emit(0, 0, 12)
    instrument.set_ingest_mode("event")
    assert len(seen.events) == 7 and seen.events[-1][2] == 12
    instrument._emit(1, 1, 13)                  # per-event again: immediate
    assert len(seen.events) == 8
    assert instrument.flush_events() == 0       # event mode: drain is a no-op


def test_simulator_batched_ingest_is_the_same_stream():
    """simulate(bus=..., ingest='batched') publishes the identical event
    sequence as ingest='event' — a subscribed governor lands bit-for-bit
    on the same fingerprint, with zero legacy fallback."""
    from repro.core.simulator import Workload, simulate

    rng = np.random.default_rng(7)
    n_tasks, n_ranks = 10, 4
    wl = Workload(
        name="ing", n_ranks=n_ranks,
        comp=rng.uniform(1e-3, 4e-3, (n_tasks, n_ranks)),
        copy=rng.uniform(1e-4, 1e-3, n_tasks),
        is_p2p=np.zeros(n_tasks, bool),
        partner=np.zeros((n_tasks, n_ranks), np.int64),
        site=np.arange(n_tasks) % 3,
        nbytes=np.zeros(n_tasks),
        beta_comp=0.3, beta_copy=0.15,
        overlap=np.where(np.arange(n_tasks) % 4 == 0, 1e-3, 0.0),
    )
    with pytest.raises(ValueError):
        simulate(wl, BASELINE, ingest="chunked")
    bus_e, bus_b = EventBus(), EventBus()
    gov_e, gov_b = Governor(policy=BASELINE), Governor(policy=BASELINE)
    bus_e.subscribe(gov_e)
    bus_b.subscribe(gov_b)
    res_e, _ = simulate(wl, BASELINE, bus=bus_e, ingest="event")
    res_b, _ = simulate(wl, BASELINE, bus=bus_b, ingest="batched")
    assert res_b.time == res_e.time and res_b.energy == res_e.energy
    assert _fingerprint(gov_b) == _fingerprint(gov_e)
    st = bus_b.ingest_stats()
    assert st["batches_total"] >= 1 and st["fallback_events_total"] == 0
    # 3 events per blocking task per rank, 4 per overlapped task per rank
    # (per-event publish doesn't book ingest stats, so count from the wl)
    n_async = int((wl.overlap > 0).sum())
    assert st["events_total"] == (3 * n_tasks + n_async) * n_ranks


def test_ingest_metrics_exports_bus_counters():
    from repro.obs.metrics import IngestMetrics, MetricsRegistry

    reg = MetricsRegistry()
    bus = EventBus()
    bus.subscribe(_EventListener())             # legacy: forces fallback
    clock = [0.0]
    im = IngestMetrics(reg, bus, time_fn=lambda: clock[0])
    reg.snapshot()                              # arm the rate window
    bus.publish_batch(EventBatch.from_rows(_stream_rows(), capacity=8))
    bus.enqueue(EventBatch.from_rows(_stream_rows()[:2]))
    clock[0] = 2.0
    reg.snapshot()
    assert reg.get_value("ingest_events_total") == 6
    assert reg.get_value("ingest_batches_total") == 1
    assert reg.get_value("ingest_fallback_events_total") == 6
    assert reg.get_value("ingest_batch_occupancy") == pytest.approx(6 / 8)
    assert reg.get_value("ingest_events_per_second") == pytest.approx(3.0)
    assert reg.get_value("ingest_queue_depth") == 1
    assert reg.get_value("ingest_queued_events") == 2
    reg.snapshot()                              # counters are delta-synced
    assert reg.get_value("ingest_events_total") == 6


def test_bus_metrics_retired_batch_totals_equal_per_event():
    """BusMetrics.on_retired_batch reconstructs the same per-phase event
    counts from a RetiredBlock as on_retired does record by record."""
    from repro.obs.metrics import BusMetrics, MetricsRegistry

    class _RetOnly:                             # strips the batch hook
        def __init__(self, bm):
            self.on_retired = bm.on_retired

    class _BatchOnly:                           # BusMetrics also speaks
        def __init__(self, bm):                 # on_event, which would
            self.on_retired_batch = bm.on_retired_batch   # force fallback

    rows = _random_events(4242, n_rounds=40)
    reg_b, reg_e = MetricsRegistry(), MetricsRegistry()
    bm_b, bm_e = BusMetrics(reg_b), BusMetrics(reg_e)
    gov_b = Governor(recorder=_BatchOnly(bm_b))  # block path
    gov_e = Governor(recorder=_RetOnly(bm_e))    # per-record path
    gov_b.on_batch(EventBatch.from_rows(rows))
    gov_e.on_batch(EventBatch.from_rows(rows))
    reg_b.snapshot()
    reg_e.snapshot()
    for phase in PHASE_CODES:
        assert reg_b.get_value("bus_events_total", phase) == \
               reg_e.get_value("bus_events_total", phase), phase
    assert bm_b._ev_counts                      # stream actually counted


def test_span_tracer_retb_export_equals_per_record_capture():
    """A SpanTracer capturing whole RetiredBlocks ('retb') exports the
    same trace JSON as one capturing the records individually ('ret')."""
    from repro.obs.tracer import SpanTracer

    class _RetOnly:
        def __init__(self, tr):
            self.on_retired = tr.on_retired

    class _NoEvent:
        """SpanTracer also speaks on_event, which would force the
        per-event replay: strip it, keeping both retirement hooks (the
        production contract — chunks the fast path cannot serve retire
        scalar, through on_retired)."""

        def __init__(self, tr):
            self.on_retired = tr.on_retired
            self.on_retired_batch = tr.on_retired_batch

    rows = _random_events(2024, n_rounds=40)
    tr_b, tr_e = SpanTracer(), SpanTracer()
    gov_b = Governor(recorder=_NoEvent(tr_b))
    gov_e = Governor(recorder=_RetOnly(tr_e))
    rng = np.random.default_rng(9)
    bus = EventBus()
    bus.subscribe(gov_b)
    for chunk in _chunks(rows, rng):
        bus.publish_batch(chunk)                # many blocks, chunk carries
    gov_e.on_batch(EventBatch.from_rows(rows))
    assert any(kind == "retb" for kind, *_ in tr_b._raw)
    assert tr_b.build() == tr_e.build()


def test_fanout_retired_batch_mixed_children():
    """RecorderFanout hands blocks to batch-capable children and expands
    them per-record for on_retired-only children — same materialization
    the retention ring sees, in retirement order."""
    from repro.obs.tracer import GovernorTap, RecorderFanout

    class _Blocks:
        def __init__(self):
            self.blocks = []

        def on_retired_batch(self, block):
            self.blocks.append(block)

    class _Records:
        def __init__(self):
            self.recs = []

        def on_retired(self, rec):
            self.recs.append(rec)

    rows = _random_events(555, n_rounds=30)
    blk, recs = _Blocks(), _Records()
    gov = Governor(recorder=RecorderFanout([blk, recs]))
    ref = Governor()
    gov.on_batch(EventBatch.from_rows(rows))
    for r, p, c, t in rows:
        ref.sink(r, p, c, t)
    assert gov.finalize().to_dict() == ref.finalize().to_dict()
    mat = [r for b in blk.blocks for r in b.records()]
    assert [r.call_id for r in recs.recs] == [r.call_id for r in mat]
    assert [(r.enter, r.slack_end) for r in recs.recs] == \
           [(r.enter, r.slack_end) for r in mat]
    # GovernorTap: a tracer-shaped child without the batch hook is expanded
    tap_recs = _Records()
    tap = GovernorTap(tap_recs)
    gov2 = Governor(recorder=tap)
    gov2.on_batch(EventBatch.from_rows(rows))
    assert [r.call_id for r in tap_recs.recs] == [r.call_id for r in mat]


def test_reset_clears_batched_state():
    rows = _random_events(5)
    gov = Governor()
    gov.on_batch(EventBatch.from_rows(rows))
    gov.reset()
    rep = gov.finalize()
    assert rep.n_calls == 0 and gov.n_inflight == 0
    assert gov.recent_records() == [] and gov.actuation_log == []
    # a fresh identical run books identically to a never-used governor
    gov.on_batch(EventBatch.from_rows(rows))
    ref = Governor()
    ref.on_batch(EventBatch.from_rows(rows))
    assert _fingerprint(gov) == _fingerprint(ref)
