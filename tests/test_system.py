"""End-to-end behaviour: real training runs (loss decreases), fault-tolerant
restart resumes identically, and the multi-device distributed path
(FSDP jit + pod-explicit instrumented shard_map) in a subprocess."""
import os
import subprocess
import sys
import tempfile
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.dist.checkpoint import CheckpointManager
from repro.models.inputs import make_batch
from repro.train.data import DataLoader
from repro.train.loop import init_state, make_train_step
from repro.train.optimizer import OptConfig

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _tiny_cfg():
    return reduced(get_config("countdown-100m"), n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=128, vocab=256)


def test_training_reduces_loss():
    cfg = _tiny_cfg()
    opt_cfg = OptConfig(lr=3e-3, warmup_steps=5, total_steps=60)
    state = init_state(cfg, opt_cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, opt_cfg))
    loader = DataLoader(cfg, batch=8, seq_len=33, seed=0)
    losses = []
    for i, batch in zip(range(60), loader):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    loader.close()
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    assert last < first - 0.25, (first, last)


def test_checkpoint_restart_resumes_identically():
    cfg = _tiny_cfg()
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=2, total_steps=30)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    batch = make_batch(cfg, batch=4, seq_len=33, kind="train")
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        state = init_state(cfg, opt_cfg, jax.random.PRNGKey(0))
        for i in range(4):
            state, _ = step(state, batch)
        mgr.save(4, state)
        state_a = state
        for i in range(3):
            state_a, ma = step(state_a, batch)
        # simulated crash: reload from step 4 and replay
        _, state_b = mgr.restore_latest(jax.tree.map(jnp.zeros_like, state))
        for i in range(3):
            state_b, mb = step(state_b, batch)
        np.testing.assert_allclose(float(ma["loss"]), float(mb["loss"]), rtol=1e-5)


def test_microbatch_accumulation_matches_full_batch():
    cfg = _tiny_cfg()
    from repro.train.loop import TrainConfig

    opt_cfg = OptConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    batch = make_batch(cfg, batch=8, seq_len=33, kind="train")
    state = init_state(cfg, opt_cfg, jax.random.PRNGKey(0))
    full = jax.jit(make_train_step(cfg, opt_cfg))
    micro = jax.jit(make_train_step(cfg, opt_cfg, TrainConfig(microbatch=2)))
    _, mf = full(state, batch)
    _, mm = micro(state, batch)
    np.testing.assert_allclose(float(mf["loss"]), float(mm["loss"]), rtol=1e-4)
    np.testing.assert_allclose(
        float(mf["grad_norm"]), float(mm["grad_norm"]), rtol=1e-3
    )


@pytest.mark.slow
def test_multidevice_fsdp_and_instrumented_pod_step():
    """8 fake CPU devices in a subprocess: FSDP auto-jit step, pod-explicit
    instrumented step (artificial barriers in HLO), int8-compressed reduce."""
    script = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from repro.configs import get_config, reduced
        from repro.dist import sharding as SH
        from repro.models.hooks import install_constraint
        from repro.train.loop import make_train_step, make_pod_train_step, init_state, TrainConfig
        from repro.train.optimizer import OptConfig
        from repro.models.inputs import make_batch
        from repro.core import instrument
        from repro.dist.compat import set_mesh

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = reduced(get_config("llama3.2-1b"))
        opt_cfg = OptConfig(warmup_steps=2, total_steps=10)
        state = init_state(cfg, opt_cfg, jax.random.PRNGKey(0))
        batch = make_batch(cfg, batch=8, seq_len=32, kind="train")
        ps = SH.param_shardings(mesh, state["params"], include_pod=False, gather_safe=True)
        os_ = SH.opt_state_shardings(mesh, ps, state["opt"])
        bs = SH.batch_shardings(mesh, batch)
        state = {"params": jax.device_put(state["params"], ps),
                 "opt": jax.device_put(state["opt"], os_)}
        batch = jax.device_put(batch, bs)
        install_constraint(SH.activation_constraint_fn(mesh))
        with set_mesh(mesh):
            auto = jax.jit(make_train_step(cfg, opt_cfg))
            s1, m1 = auto(state, batch)
            assert jnp.isfinite(m1["loss"])
            instrument.set_mode("barrier")
            pstep = jax.jit(make_pod_train_step(cfg, opt_cfg, mesh, TrainConfig(pod_reduce="manual")),
                            in_shardings=({"params": ps, "opt": os_}, bs),
                            out_shardings=({"params": ps, "opt": os_}, None))
            comp = pstep.lower(state, batch).compile()
            txt = comp.as_text()
            assert "all-reduce" in txt
            s2, m2 = pstep(state, batch)
            assert jnp.isfinite(m2["loss"])
            import numpy as np
            np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-4)
            instrument.set_mode("off")
            cstep = jax.jit(make_pod_train_step(cfg, opt_cfg, mesh, TrainConfig(pod_reduce="compressed")),
                            in_shardings=({"params": ps, "opt": os_}, bs),
                            out_shardings=({"params": ps, "opt": os_}, None))
            s3, m3 = cstep(state, batch)
            np.testing.assert_allclose(float(m3["loss"]), float(m2["loss"]), rtol=1e-4)
        print("MULTIDEVICE-OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True, text=True,
        timeout=900,
    )
    assert "MULTIDEVICE-OK" in out.stdout, out.stderr[-3000:]


@pytest.mark.slow
def test_elastic_restart_on_smaller_mesh():
    """Checkpoint on 8 devices, simulated node failure, resume on 4."""
    script = textwrap.dedent("""
        import tempfile, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, reduced
        from repro.dist import sharding as SH
        from repro.dist.checkpoint import CheckpointManager
        from repro.dist.compat import set_mesh
        from repro.dist.elastic import ElasticMesh
        from repro.models.hooks import install_constraint
        from repro.train.loop import make_train_step, init_state
        from repro.train.optimizer import OptConfig
        from repro.models.inputs import make_batch

        cfg = reduced(get_config("olmo-1b"))
        opt_cfg = OptConfig(warmup_steps=2, total_steps=10)
        em = ElasticMesh(axis_names=("data", "model"))
        mesh = em.build(model_parallel=2)
        install_constraint(SH.activation_constraint_fn(mesh))
        state = init_state(cfg, opt_cfg, jax.random.PRNGKey(0))
        batch = make_batch(cfg, batch=8, seq_len=32, kind="train")
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d)
            with set_mesh(mesh):
                step = jax.jit(make_train_step(cfg, opt_cfg))
                state, m_before = step(state, batch)
                mgr.save(1, state)
            for dev in jax.devices()[4:]:
                em.fail(dev.id)
            mesh2 = em.build(model_parallel=2)
            assert int(np.prod(list(mesh2.shape.values()))) == 4
            install_constraint(SH.activation_constraint_fn(mesh2))
            skel = jax.tree.map(lambda a: np.zeros(a.shape, a.dtype), state)
            ps = SH.param_shardings(mesh2, state["params"])
            os_ = SH.opt_state_shardings(mesh2, ps, state["opt"])
            _, restored = mgr.restore_latest(skel, {"params": ps, "opt": os_})
            with set_mesh(mesh2):
                step2 = jax.jit(make_train_step(cfg, opt_cfg))
                restored, m_after = step2(restored, batch)
                assert jnp.isfinite(m_after["loss"])
        print("ELASTIC-OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True, text=True,
        timeout=900,
    )
    assert "ELASTIC-OK" in out.stdout, out.stderr[-3000:]
