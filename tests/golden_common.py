"""Canned event streams shared by the golden conformance suite and its
regeneration helper (``scripts/regen_goldens.py``).

Three deterministic workload-shaped governor event streams, chosen to
exercise every accounting path in ``GovernorReport.to_dict()``:

* ``balanced``  — 8 near-synchronous ranks, small jitter: slack mostly under
  theta, the timeout filter rejects almost everything.
* ``straggler`` — 6 ranks, one 3 ms laggard: large exploitable slack on the
  non-critical ranks, downshifts on every call.
* ``bursty``    — 4 ranks, heavy-tailed slack, plus async 5-phase
  occurrences (dispatch/wait: overlap accounting) and ingested single-rank
  phases with a stable site (the serve-meter path).

The streams are pure numpy (no jax) and are a function of nothing but the
fixed seeds below — feeding one through a ``Governor`` under any policy is
deterministic, which is what lets the fixtures pin the reports.
"""
from __future__ import annotations

import numpy as np

from repro.core.governor import Governor
from repro.core.policies import FIXED_POLICIES, Policy

CANNED = ("balanced", "straggler", "bursty")
GOLDEN_POLICY_NAMES = [p.name for p in FIXED_POLICIES]
# the predictive pair is pinned by its own fixture file
# (tests/goldens/predictive.json): the frozen GovernorReport stays
# byte-compatible with the fixed-policy goldens, and the predictor-path
# decision count rides alongside so silent pre-arm/guard drift fails too
PREDICTIVE_POLICY_NAMES = ["cntd_predictive", "cntd_predict_only"]


def _feed_balanced(gov: Governor) -> None:
    rng = np.random.default_rng(11)
    t = 1.0
    for call in range(30):
        arrivals = t + rng.uniform(0.0, 2e-4, 8)
        release = float(arrivals.max())
        copies = rng.uniform(0.5e-3, 1.5e-3, 8)
        for r in range(8):
            gov.sink(r, "barrier_enter", call, float(arrivals[r]))
        for r in range(8):
            gov.sink(r, "barrier_exit", call, release)
            gov.sink(r, "copy_exit", call, release + float(copies[r]))
        t = release + 5e-3


def _feed_straggler(gov: Governor) -> None:
    rng = np.random.default_rng(23)
    t = 2.0
    for call in range(25):
        arrivals = t + rng.uniform(0.0, 1e-4, 6)
        arrivals[3] = t + 3e-3                       # rank 3 always lags
        release = float(arrivals.max())
        for r in range(6):
            gov.sink(r, "barrier_enter", call, float(arrivals[r]))
        for r in range(6):
            gov.sink(r, "barrier_exit", call, release)
            gov.sink(r, "copy_exit", call, release + 0.8e-3)
        t = release + 8e-3


def _feed_bursty(gov: Governor) -> None:
    rng = np.random.default_rng(37)
    t = 3.0
    for call in range(40):
        slacks = np.exp(rng.normal(0.0, 1.5, 4)) * 1e-3
        arrivals = t + float(slacks.max()) - slacks
        release = t + float(slacks.max())
        copies = rng.uniform(0.1e-3, 2e-3, 4)
        if call % 5 == 0:
            # async occurrence: dispatch, overlap ~2 ms of compute under the
            # flying collective, then wait — slack starts at the wait
            for r in range(4):
                gov.sink(r, "dispatch_enter", call, float(arrivals[r]) - 2e-3)
            for r in range(4):
                gov.sink(r, "wait_enter", call, float(arrivals[r]))
        else:
            for r in range(4):
                gov.sink(r, "barrier_enter", call, float(arrivals[r]))
        for r in range(4):
            gov.sink(r, "barrier_exit", call, release)
            gov.sink(r, "copy_exit", call, release + float(copies[r]))
        t = release + 6e-3
    # serve-meter path: single-rank ingested phases with a stable site
    for i in range(5):
        t0 = t + i * 10e-3
        gov.ingest_phase(0, (1 << 20) + 2 + i, t0, t0 + 3e-3, t0 + 3.5e-3,
                         site=1 << 20)


_FEEDERS = {
    "balanced": _feed_balanced,
    "straggler": _feed_straggler,
    "bursty": _feed_bursty,
}


def feed(gov: Governor, kind: str) -> None:
    _FEEDERS[kind](gov)


def report_dict(policy: Policy, kind: str) -> dict:
    """The frozen quantity: a fresh governor under ``policy`` fed the canned
    ``kind`` stream, finalized, serialized."""
    gov = Governor(policy=policy)
    feed(gov, kind)
    return gov.finalize().to_dict()


def predictive_entry(policy: Policy, kind: str) -> dict:
    """The predictive fixture's frozen quantity: the report plus the
    predictor-path decision count (pre-arms, mispredictions, guard trips) —
    the report alone would miss a predictor that silently stopped arming."""
    gov = Governor(policy=policy)
    feed(gov, kind)
    rep = gov.finalize().to_dict()
    return {"report": rep,
            "n_predictor_decisions": int(gov.n_predictor_decisions)}
