"""Observability stack (repro.obs): registry/tracer/export/log contracts.

Three load-bearing guarantees live here:

* **Golden Perfetto fixture** — a canned multi-stream capture (raw rank
  events, phase records, actuations, theta decisions, serve lifecycle,
  counter samples) serializes to the committed ``tests/goldens/
  perfetto.json`` byte-for-byte.  Any change to span reconstruction,
  track layout, or export ordering fails loudly; intentional changes are
  made by re-running ``scripts/regen_goldens.py --perfetto``.
* **Histogram/accumulator equivalence** — over any ``publish_phase``
  stream, ``BusMetrics``' slack/copy histogram sums equal the governor's
  ``GovernorReport`` totals with exact ``==`` (same clamp, same addition
  order).  Property-tested on random streams.
* **Exact-report JSONL** — every ``MetricsJsonlWriter`` line embeds
  ``GovernorReport.to_dict()`` verbatim (modulo the JSON round-trip's
  int-key stringification), and ``validate_metrics_jsonl`` passes.
"""
import io
import json
import os

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.events import EventBus, PhaseRecord
from repro.core.governor import Actuation, Governor
from repro.core.timeout import ThetaDecision
from repro.obs import log as obslog
from repro.obs.export import (
    ConsoleDashboard,
    MetricsJsonlWriter,
    prometheus_text,
    validate_metrics_jsonl,
)
from repro.obs.metrics import (
    DEFAULT_EDGES,
    BusMetrics,
    GovernorCollector,
    MetricsRegistry,
)
from repro.obs.tracer import (
    TRACK_PIDS,
    GovernorTap,
    RecorderFanout,
    SpanTracer,
    validate_trace,
)

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "goldens")


# --------------------------------------------------------------------------
# golden Perfetto fixture
# --------------------------------------------------------------------------
def golden_tracer() -> SpanTracer:
    """The canned capture behind ``goldens/perfetto.json`` — every stream
    kind the tracer folds, with hand-picked times so each reconstruction
    path (rotation-rule spans, overlap spans, phase records, instants,
    counters) appears at least once.  Shared with the regeneration helper."""
    tr = SpanTracer(meta={"driver": "golden"})
    # rank 0/1, call 0: plain barrier -> slack + copy spans
    for r, t in ((0, 1.000), (1, 1.0002)):
        tr.on_event(r, "barrier_enter", 0, t)
    for r in (0, 1):
        tr.on_event(r, "barrier_exit", 0, 1.001)
        tr.on_event(r, "copy_exit", 0, 1.0015 + r * 1e-4)
    # call 1: async occurrence -> overlap span on rank 0
    tr.on_event(0, "dispatch_enter", 1, 1.002)
    tr.on_event(0, "wait_enter", 1, 1.0028)
    tr.on_event(0, "barrier_exit", 1, 1.0031)
    # a fully-formed phase record with a site tag (serve-meter shape)
    tr.on_phase(PhaseRecord(rank=1, call_id=7, t_enter=1.004,
                            t_slack_end=1.0052, t_copy_end=1.0055, site=3))
    # governor outputs
    tr.on_actuation(Actuation(t=1.0012, rank=1, action="set_pstate_min",
                              call_id=0, slack=8e-4))
    tr.on_actuation(Actuation(t=1.0019, rank=1, action="restore_pstate_max",
                              call_id=0, slack=8e-4))
    tr.on_theta(ThetaDecision(t=1.003, site=2, rank=0, theta_before=5e-4,
                              theta_after=3e-4, reason="decay", slack=1e-4))
    # serve lifecycle + driver counter samples
    tr.serve_event("join", 1.0005, rid=4, slot=1)
    tr.serve_event("evict", 1.0056, rid=4, slot=1)
    tr.sample("governor", "slack_ratio_pct", 1.006, 12.5)
    tr.sample("arbiter", "cap_w[train]", 1.006, 1500.0)
    tr.sample("slo", "ttft_p95_ms", 1.006, 41.0)
    return tr


def test_perfetto_golden_bytes():
    path = os.path.join(GOLDEN_DIR, "perfetto.json")
    got = json.dumps(golden_tracer().build(), sort_keys=True)
    with open(path) as f:
        want = f.read()
    assert got == want, "trace export drifted from goldens/perfetto.json " \
                        "(regen via scripts/regen_goldens.py --perfetto)"


def test_perfetto_golden_schema():
    probs = validate_trace(os.path.join(GOLDEN_DIR, "perfetto.json"),
                           require_tracks=tuple(TRACK_PIDS))
    assert probs == []


def test_perfetto_deterministic_rebuild():
    a = golden_tracer()
    assert json.dumps(a.build(), sort_keys=True) \
        == json.dumps(golden_tracer().build(), sort_keys=True)
    # build() is a pure function of the capture: rebuilding does not mutate
    assert json.dumps(a.build(), sort_keys=True) \
        == json.dumps(a.build(), sort_keys=True)


def test_trace_span_reconstruction_shapes():
    ev = golden_tracer().build()["traceEvents"]
    spans = [e for e in ev if e["ph"] == "X"]
    names = sorted(e["name"] for e in spans)
    # 2 slack + 2 copy from call 0, 1 overlap + 1 slack from call 1,
    # 1 slack + 1 copy from the phase record
    assert names == ["copy"] * 3 + ["overlap"] + ["slack"] * 4
    sited = [e for e in spans if e["args"].get("site") is not None]
    assert {e["args"]["site"] for e in sited} == {3}
    assert all(e["dur"] >= 0 for e in spans)
    insts = {e["name"] for e in ev if e["ph"] == "i"}
    assert {"set_pstate_min", "restore_pstate_max", "join", "evict"} <= insts
    ctrs = {e["name"] for e in ev if e["ph"] == "C"}
    assert {"theta_us[2]", "slack_ratio_pct", "cap_w[train]",
            "ttft_p95_ms"} <= ctrs


def test_validate_trace_catches_problems():
    bad = {"traceEvents": [
        {"ph": "X", "pid": 1, "tid": 0, "name": "slack", "ts": -1, "dur": -2},
        {"ph": "C", "pid": 2, "tid": 0, "name": "c", "ts": 0,
         "args": {"value": "nan-string"}},
        {"ph": "Z", "pid": 1, "tid": 0, "name": "?", "ts": 0},
    ]}
    probs = validate_trace(bad, require_tracks=("ranks",))
    assert len(probs) == 5  # bad ts, bad dur, bad C args, bad ph, no track
    assert validate_trace({"nope": 1}) == ["traceEvents missing or not a list"]


def test_tracer_bounded_capacity():
    tr = SpanTracer(capacity=10)
    for i in range(25):
        tr.on_event(0, "barrier_enter", i, float(i))
    assert tr.n_seen == 25 and tr.n_dropped == 15
    assert validate_trace(tr.build()) == []


# --------------------------------------------------------------------------
# recorder plumbing
# --------------------------------------------------------------------------
class _SpyRecorder:
    def __init__(self):
        self.events, self.phases, self.acts, self.thetas = [], [], [], []

    def on_event(self, rank, phase, call_id, t):
        self.events.append((rank, phase, call_id, t))

    def on_phase(self, record):
        self.phases.append(record)

    def on_actuation(self, act):
        self.acts.append(act)

    def on_theta(self, dec):
        self.thetas.append(dec)


def test_recorder_fanout_and_tap():
    spy = _SpyRecorder()
    tr = SpanTracer()
    fan = RecorderFanout([spy, GovernorTap(tr)])
    act = Actuation(t=0.0, rank=0, action="set_pstate_min", call_id=0, slack=0.1)
    dec = ThetaDecision(t=0.0, site=0, rank=0, theta_before=1e-3,
                        theta_after=5e-4, reason="decay", slack=1e-4)
    fan.on_event(0, "barrier_enter", 0, 0.0)
    fan.on_phase(PhaseRecord(0, 0, 0.0, 0.1, 0.2, None))
    fan.on_actuation(act)
    fan.on_theta(dec)
    assert len(spy.events) == 1 and len(spy.phases) == 1
    assert spy.acts == [act] and spy.thetas == [dec]
    # the tap forwards ingested phases and theta decisions but neither raw
    # events nor eager actuations — those stay off the telemetry hot path
    # (actuations are pulled from the governor's spine log at export)
    assert tr.n_seen == 2
    kinds = {rec[0] for rec in tr._raw}
    assert kinds == {"ph", "theta"}


def test_fanout_skips_missing_hooks():
    class ActsOnly:
        def __init__(self):
            self.acts = []

        def on_actuation(self, act):
            self.acts.append(act)

    partial, spy = ActsOnly(), _SpyRecorder()
    fan = RecorderFanout([partial, spy])
    fan.on_event(0, "barrier_enter", 0, 0.0)     # must not raise
    fan.on_actuation("a")
    assert partial.acts == ["a"] and len(spy.events) == 1


def test_fanout_expands_pairs_for_eager_children():
    # a spine pair reaching the fanout lands once (compact form) on
    # pair-aware children and as two eager Actuations on children that
    # only speak on_actuation (TraceRecorder and friends)
    spy = _SpyRecorder()
    tr = SpanTracer()
    fan = RecorderFanout([spy, tr])
    fan.on_actuation_pair(1.0, 2, 7, 3e-4)
    assert [a.action for a in spy.acts] == ["set_pstate_min",
                                            "restore_pstate_max"]
    assert spy.acts[0].rank == 2 and spy.acts[0].call_id == 7
    assert spy.acts[0].slack == 3e-4
    assert [rec[0] for rec in tr._raw] == ["actp"]


def _downshift_stream(sink, n_calls=6, n_ranks=3):
    """Raw 3-phase stream with 1 ms slack (over the 500 us default theta,
    so every occurrence downshifts) and recurring call ids (so every
    occurrence except the last per id retires by rotation)."""
    t = 0.0
    for c in range(n_calls):
        cid = c % 2
        for r in range(n_ranks):
            sink(r, "barrier_enter", cid, t + r * 1e-6)
        for r in range(n_ranks):
            sink(r, "barrier_exit", cid, t + 1e-3)
            sink(r, "copy_exit", cid, t + 1.2e-3)
        t += 2e-3
    return n_calls, n_ranks


def test_governor_tap_production_wiring():
    """The launch drivers' wiring end to end: governor with a GovernorTap
    recorder streaming raw events — spans come from retired occurrences,
    event counts from the metrics retire hook, actuation instants from the
    spine log pulled at export time (never the hot path)."""
    reg = MetricsRegistry()
    tr = SpanTracer()
    gov = Governor(recorder=GovernorTap(tr, metrics=BusMetrics(reg)))
    n_calls, n_ranks = _downshift_stream(gov.sink)
    n_retired = n_calls - 2                       # one in flight per call id

    assert sum(1 for rec in tr._raw if rec[0] == "ret") == n_retired
    # nothing actuation-shaped was streamed during the run
    assert not any(rec[0] in ("act", "actp") for rec in tr._raw)

    # retired-record event counts are exact (in-flight tail not yet booked)
    snap = reg.snapshot()
    assert "bus_events_total" in snap
    for phase in ("barrier_enter", "barrier_exit", "copy_exit"):
        assert reg.get_value("bus_events_total", phase) == n_ranks * n_retired

    # export: slack + copy span per (rank, retired occurrence), and the
    # spine pull adds two instants per booked pair
    tr.ingest_governor(gov)
    assert gov.n_actuations == 2 * n_ranks * n_calls
    trace = tr.build()
    spans = [e for e in trace["traceEvents"] if e["ph"] == "X"]
    assert len(spans) == 2 * n_ranks * n_retired
    instants = [e for e in trace["traceEvents"]
                if e["ph"] == "i" and e["pid"] == TRACK_PIDS["governor"]]
    assert len(instants) == gov.n_actuations
    assert validate_trace(trace, require_tracks=("ranks", "governor")) == []


# --------------------------------------------------------------------------
# metrics registry
# --------------------------------------------------------------------------
def test_registry_kind_and_label_conflicts():
    reg = MetricsRegistry()
    fam = reg.counter("x_total", "x", ("a",))
    assert reg.counter("x_total", "x", ("a",)) is fam       # get-or-create
    with pytest.raises(ValueError):
        reg.gauge("x_total", label_names=("a",))            # kind conflict
    with pytest.raises(ValueError):
        reg.counter("x_total", label_names=("b",))          # label conflict


def test_registry_label_stringify_and_get_value():
    reg = MetricsRegistry()
    g = reg.gauge("theta", "t", ("site",))
    g.labels(3).set(1.5)
    assert g.labels("3").value == 1.5
    assert reg.get_value("theta", 3) == 1.5
    assert reg.get_value("theta", 4) is None
    assert reg.get_value("missing") is None
    h = reg.histogram("h")
    h.observe(0.5)
    h.observe(-1.0)                               # clamps to 0.0
    assert reg.get_value("h") == 0.5              # histogram -> sum
    with pytest.raises(ValueError):
        g.labels()                                # label arity enforced


def test_default_edges_match_tuner_binning():
    np = pytest.importorskip("numpy")
    ref = np.geomspace(1e-6, 30.0, 97)
    assert len(DEFAULT_EDGES) == 97
    assert np.allclose(DEFAULT_EDGES, ref, rtol=1e-12, atol=0.0)


def test_histogram_bucket_edges_clamp():
    reg = MetricsRegistry()
    h = reg.histogram("h").labels()
    h.observe(0.0)            # below first edge -> first bucket
    h.observe(1e9)            # beyond last edge -> last bucket
    assert h.counts[0] == 1 and h.counts[-1] == 1 and h.count == 2


def test_bus_metrics_sync_is_delta_based():
    reg = MetricsRegistry()
    bm = BusMetrics(reg)
    bus = EventBus()
    bus.subscribe(bm)
    for i in range(5):
        bus.publish(0, "barrier_enter", i, float(i))
    snap = reg.snapshot()                       # collector hook syncs
    [cell] = snap["bus_events_total"]["values"]
    assert cell["labels"] == {"phase": "barrier_enter"} and cell["value"] == 5
    reg.snapshot()                              # re-sync: no double count
    assert reg.get_value("bus_events_total", "barrier_enter") == 5
    bus.publish(1, "barrier_enter", 9, 9.0)
    reg.snapshot()
    assert reg.get_value("bus_events_total", "barrier_enter") == 6


# --------------------------------------------------------------------------
# histogram sums == governor accumulators (exact), property-tested
# --------------------------------------------------------------------------
phase_streams = st.integers(min_value=0, max_value=10_000).map(lambda seed: seed)


def _random_records(seed):
    import numpy as np

    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 60))
    records, t = [], 1.0
    for i in range(n):
        slack = float(rng.uniform(-1e-4, 5e-3))   # negatives: clamp path
        copy = float(rng.uniform(0.0, 2e-3))
        site = int(rng.integers(0, 3)) if rng.random() < 0.5 else None
        records.append(PhaseRecord(
            rank=int(rng.integers(0, 4)), call_id=i, t_enter=t,
            t_slack_end=t + slack, t_copy_end=t + max(slack, 0.0) + copy,
            site=site))
        t += 1e-2
    return records


@given(phase_streams)
@settings(max_examples=30, deadline=None)
def test_histogram_totals_equal_governor_totals(seed):
    records = _random_records(seed)
    reg = MetricsRegistry()
    bm = BusMetrics(reg)
    gov = Governor()
    bus = EventBus()
    bus.subscribe(gov)
    bus.subscribe(bm)
    for rec in records:
        bus.publish_phase(rec)
    rep = gov.finalize()
    slack_cell = reg.histogram("phase_slack_seconds").labels()
    copy_cell = reg.histogram("phase_copy_seconds").labels()
    # exact float equality: same clamp, same addition order
    assert slack_cell.sum == rep.total_slack
    assert copy_cell.sum == rep.total_copy
    assert slack_cell.count == rep.n_calls == len(records)
    assert reg.get_value("bus_phase_records_total") == len(records)


# --------------------------------------------------------------------------
# governor collector + JSONL writer
# --------------------------------------------------------------------------
def _feed(gov_or_bus, n=20, seed=0):
    import numpy as np

    rng = np.random.default_rng(seed)
    t = 1.0
    for i in range(n):
        gov_or_bus.publish_phase(PhaseRecord(
            rank=0, call_id=i, t_enter=t, t_slack_end=t + rng.uniform(0, 2e-3),
            t_copy_end=t + rng.uniform(2e-3, 3e-3), site=int(i % 2)))
        t += 5e-3


def test_collector_exact_report_roundtrip(tmp_path):
    gov = Governor()
    bus = EventBus()
    bus.subscribe(gov)
    reg = MetricsRegistry()
    coll = GovernorCollector(reg, gov)
    path = str(tmp_path / "metrics.jsonl")
    with MetricsJsonlWriter(path, reg, coll) as w:
        _feed(bus, n=10, seed=1)
        w.write(step=0)
        _feed(bus, n=10, seed=2)
        w.write(step=1)
    assert validate_metrics_jsonl(path) == []
    lines = [json.loads(s) for s in open(path)]
    assert [r["step"] for r in lines] == [0, 1]
    # the embedded report is the exact cumulative finalize() at write time
    # (JSON round-trip stringifies straggler_summary's int keys, so compare
    # against the same round-trip of the live report)
    want = json.loads(json.dumps(gov.finalize().to_dict()))
    assert lines[-1]["report"] == want
    # cumulative counters track the report totals across interval polls
    assert reg.get_value("governor_slack_seconds_total") \
        == pytest.approx(want["total_slack"], rel=1e-12)
    assert reg.get_value("governor_calls_total") == want["n_calls"] == 20


def test_validate_metrics_jsonl_catches_problems(tmp_path):
    p = tmp_path / "bad.jsonl"
    p.write_text('{"t": 1}\nnot json\n'
                 '{"t": 1, "metrics": {"f": []}, "report": {"n_calls": 1}}\n')
    probs = validate_metrics_jsonl(str(p))
    assert any("envelope" in s for s in probs)
    assert any("not JSON" in s for s in probs)
    assert any("malformed" in s for s in probs)
    assert any("report missing" in s for s in probs)
    (tmp_path / "empty.jsonl").write_text("")
    assert validate_metrics_jsonl(str(tmp_path / "empty.jsonl")) \
        == ["no snapshot lines"]


def test_collector_single_poller_handoff():
    """collect() returns the IntervalStats it polled so a driver can hand
    it to GovernorJob.run_epoch(stats=...) — the governor keeps one
    snapshot mark, so double-polling would split the stream."""
    gov = Governor()
    bus = EventBus()
    bus.subscribe(gov)
    reg = MetricsRegistry()
    coll = GovernorCollector(reg, gov, auto_collect=False)
    _feed(bus, n=8)
    stats = coll.collect()
    assert stats.n_calls == 8
    # a second immediate poll sees an empty interval: the mark moved
    assert gov.interval_snapshot().n_calls == 0


# --------------------------------------------------------------------------
# prometheus text + dashboard
# --------------------------------------------------------------------------
def test_prometheus_text_exposition():
    reg = MetricsRegistry()
    reg.counter("a_total", "things", ("k",)).labels("x").inc(3)
    reg.gauge("b", 'quo"te').set(1.25)
    h = reg.histogram("h_seconds", "hist", edges=(0.0, 1.0, 2.0))
    h.observe(0.5)
    h.observe(1.5)
    text = prometheus_text(reg)
    assert 'a_total{k="x"} 3.0' in text
    assert "# TYPE b gauge" in text and "b 1.25" in text
    assert 'h_seconds_bucket{le="1"} 1' in text
    assert 'h_seconds_bucket{le="2"} 2' in text       # cumulative
    assert 'h_seconds_bucket{le="+Inf"} 2' in text
    assert "h_seconds_sum 2.0" in text and "h_seconds_count 2" in text
    assert text == prometheus_text(reg)               # deterministic


def test_dashboard_renders_available_sections():
    reg = MetricsRegistry()
    out = io.StringIO()
    dash = ConsoleDashboard(reg, title="t", stream=out)
    assert dash.render() == "== t =="                 # empty registry: header
    gov = Governor()
    coll = GovernorCollector(reg, gov)
    bus = EventBus()
    bus.subscribe(gov)
    _feed(bus, n=6)
    coll.collect()        # the driver's interval poll populates the gauges
    reg.gauge("job_cap_watts", "", ("job",)).labels("train").set(2000.0)
    reg.gauge("job_power_watts", "", ("job",)).labels("train").set(81.0)
    frame = dash.tick(step=3)
    assert "step 3" in frame and "slack" in frame and "energy saved" in frame
    assert "power[train]" in frame and "/2000W cap" in frame
    assert dash.n_renders == 1 and frame in out.getvalue()
    del coll


def test_dashboard_serve_rows():
    reg = MetricsRegistry()
    for q, v in (("p50", 0.01), ("p99", 0.05)):
        reg.gauge("serve_ttft_seconds", "", ("q",)).labels(q).set(v)
    reg.counter("serve_completed_total").inc(7)
    frame = ConsoleDashboard(reg).render()
    assert "ttft p50    10.0ms   p99    50.0ms" in frame
    assert "completed 7" in frame


# --------------------------------------------------------------------------
# profiler bus subscription (regression: EventProfiler as a subscriber)
# --------------------------------------------------------------------------
def test_event_profiler_consumes_phase_records():
    from repro.core.profiler import UNSITED, EventProfiler, hierarchical_report

    prof = EventProfiler()
    bus = EventBus()
    bus.subscribe(prof)
    bus.publish_phase(PhaseRecord(rank=2, call_id=0, t_enter=0.0,
                                  t_slack_end=0.5, t_copy_end=0.7, site=4))
    bus.publish_phase(PhaseRecord(rank=0, call_id=1, t_enter=1.0,
                                  t_slack_end=0.9, t_copy_end=1.2, site=None))
    assert prof.sites[4]["calls"] == 1 and prof.sites[4]["tslack"] == 0.5
    # negative slack clamps; site=None books under the UNSITED bucket
    assert prof.sites[UNSITED]["tslack"] == 0.0
    assert prof.sites[UNSITED]["tcopy"] == pytest.approx(0.3)
    rep = hierarchical_report(prof)               # n_ranks inferred = 3
    assert rep["summary"]["n_ranks"] == 3
    assert rep["summary"]["total_tslack_s"] == 0.5
    assert rep["nodes"]["node0"]["tslack_s"] == 0.5


# --------------------------------------------------------------------------
# structured logging
# --------------------------------------------------------------------------
@pytest.fixture
def _log_reset():
    yield
    obslog.configure()                            # restore defaults


def test_log_text_and_levels(_log_reset):
    out = io.StringIO()
    obslog.configure(level="info", stream=out)
    log = obslog.get_logger("train")
    log.debug("hidden", x=1)
    log.info("step", loss=1.23456789, note="two words")
    log.warning("careful", n=3)
    text = out.getvalue()
    assert "hidden" not in text
    assert "[train] step loss=1.23457 note='two words'" in text
    assert "[train] WARNING careful n=3" in text


def test_log_json_mode(_log_reset):
    out = io.StringIO()
    obslog.configure(level="info", json_logs=True, stream=out)
    obslog.get_logger("serve").info("done", tokens=42)
    rec = json.loads(out.getvalue())
    assert rec["logger"] == "serve" and rec["event"] == "done"
    assert rec["fields"] == {"tokens": 42} and rec["lvl"] == "info"


def test_log_flags_roundtrip(_log_reset):
    import argparse

    ap = argparse.ArgumentParser()
    obslog.add_flags(ap)
    args = ap.parse_args(["--quiet", "--json-logs"])
    obslog.configure_from_args(args)
    out = io.StringIO()
    obslog.configure(level="warning", json_logs=True, stream=out)
    log = obslog.get_logger("x")
    log.info("suppressed")
    log.error("boom")
    lines = [json.loads(s) for s in out.getvalue().splitlines()]
    assert [r["event"] for r in lines] == ["boom"]
    with pytest.raises(ValueError):
        obslog.configure(level="nope")
