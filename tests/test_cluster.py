"""Tier-1 tests for the ``repro.cluster`` power-budget layer.

The two contracts the subsystem rests on are asserted here:

* **record/replay is lossless** — a recorded run, replayed through a
  fresh Governor, reproduces the live slack/copy/energy totals
  *bit-for-bit* (not approximately);
* **the arbiter is safe** — property-tested: allocations never sum above
  the cluster cap and never drop an active job below its floor.
"""
import os
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.cluster.arbiter import JobSample, PowerBudgetArbiter, StaticEqualSplit
from repro.cluster.coschedule import make_job, run_coschedule
from repro.cluster.job import GovernorJob, SimJob
from repro.cluster.power import PowerCapActuator, aggregate_power, node_power_series
from repro.cluster.trace import TRACE_VERSION, TraceRecorder, load, replay, to_workload, what_if
from repro.core.governor import Governor, GovernorReport
from repro.core.policies import BASELINE, COUNTDOWN, COUNTDOWN_SLACK
from repro.core.pstate import DEFAULT_HW
from repro.core.simulator import simulate
from repro.core.workloads import APPS, generate


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------

def _synthetic_run(recorder=None, n_calls=25, n_ranks=4, seed=0, ingest=True):
    """A governor fed a deterministic barrier stream (+ one ingested phase)."""
    gov = Governor(recorder=recorder)
    rng = np.random.default_rng(seed)
    t = 1.0
    for call in range(n_calls):
        arrivals = t + rng.uniform(0.0, 3e-3, n_ranks)
        release = float(arrivals.max())
        copies = rng.uniform(0.2e-3, 2e-3, n_ranks)      # per-rank copy times
        for r in range(n_ranks):
            gov.sink(r, "barrier_enter", call, float(arrivals[r]))
        for r in range(n_ranks):
            gov.sink(r, "barrier_exit", call, release)
            gov.sink(r, "copy_exit", call, release + float(copies[r]))
        t = release + 4e-3
    if ingest:
        gov.ingest_phase(0, 1 << 20, t, t + 2e-3, t + 2.5e-3)
    return gov


# --------------------------------------------------------------------------
# trace: record -> save -> load -> replay, bit-for-bit
# --------------------------------------------------------------------------

def test_trace_roundtrip_is_bitwise_exact():
    rec = TraceRecorder(meta={"run": "test"})
    gov = _synthetic_run(recorder=rec)
    live = gov.finalize()

    with tempfile.TemporaryDirectory() as d:
        path = rec.save(os.path.join(d, "run.jsonl"))
        header, records = load(path)
    assert header["version"] == TRACE_VERSION
    assert header["meta"] == {"run": "test"}
    assert header["n_records"] == len(records) == rec.n_seen

    replayed_gov, rep = replay(records)
    # == on floats, deliberately: replay must reproduce the exact bits
    assert rep.total_slack == live.total_slack
    assert rep.total_copy == live.total_copy
    assert rep.exploited_slack == live.exploited_slack
    assert rep.energy_baseline == live.energy_baseline
    assert rep.energy_policy == live.energy_policy
    assert rep.n_calls == live.n_calls
    assert rep.n_downshifts == live.n_downshifts
    # the replayed governor re-derives the same actuation stream
    assert replayed_gov.actuation_log == gov.actuation_log


def test_trace_replay_under_other_policy_differs():
    rec = TraceRecorder()
    gov = _synthetic_run(recorder=rec)
    live = gov.finalize()
    _, rep = replay(rec.records(), policy=COUNTDOWN)     # comm scope, not slack
    assert rep.total_slack == live.total_slack           # same measured phases
    assert rep.energy_policy != live.energy_policy       # different pricing


def test_trace_ring_buffer_bounds_memory_and_load_refuses_truncation(tmp_path):
    rec = TraceRecorder(capacity=10)
    _synthetic_run(recorder=rec, n_calls=20)
    assert len(rec.records()) == 10
    assert rec.n_dropped == rec.n_seen - 10 > 0
    path = rec.save(str(tmp_path / "truncated.jsonl"))
    with pytest.raises(ValueError, match="dropped"):
        load(path)                                       # cannot replay exactly
    header, records = load(path, allow_truncated=True)
    assert header["n_dropped"] == rec.n_dropped and len(records) == 10


def _adaptive_5phase_run(recorder=None, n_calls=40, n_ranks=4, seed=7):
    """A live adaptive governor fed the full vocabulary: sync barriers,
    async 5-phase occurrences (dispatch/wait), and ingested phases with a
    stable site — the differential-test input."""
    from repro.core.policies import CNTD_ADAPTIVE

    gov = Governor(policy=CNTD_ADAPTIVE, recorder=recorder)
    rng = np.random.default_rng(seed)
    t = 1.0
    for call in range(n_calls):
        arrivals = t + rng.uniform(0.0, 4e-3, n_ranks)
        release = float(arrivals.max())
        copies = rng.uniform(0.2e-3, 1.5e-3, n_ranks)
        if call % 4 == 0:                                # async occurrence
            for r in range(n_ranks):
                gov.sink(r, "dispatch_enter", call, float(arrivals[r]) - 1e-3)
            for r in range(n_ranks):
                gov.sink(r, "wait_enter", call, float(arrivals[r]))
        else:
            for r in range(n_ranks):
                gov.sink(r, "barrier_enter", call, float(arrivals[r]))
        for r in range(n_ranks):
            gov.sink(r, "barrier_exit", call, release)
            gov.sink(r, "copy_exit", call, release + float(copies[r]))
        t = release + 12e-3
    for i in range(6):                                   # serve-meter path
        t0 = t + i * 10e-3
        gov.ingest_phase(0, (1 << 20) + 2 + i, t0, t0 + 5e-3, t0 + 5.5e-3,
                         site=1 << 20)
    return gov


def test_adaptive_trace_replay_is_bitwise_exact():
    """The differential test: a live ADAPTIVE run (tuner decisions, 5-phase
    events, ingested sites) replayed through a fresh governor+tuner
    reproduces the report, the actuation stream, and every recorded theta
    decision exactly — the tuner is a pure function of the event order."""
    from repro.core.policies import CNTD_ADAPTIVE

    rec = TraceRecorder(meta={"run": "adaptive"})
    gov = _adaptive_5phase_run(recorder=rec)
    live = gov.finalize()
    assert live.n_theta_decisions > 0 and live.total_overlap > 0.0

    with tempfile.TemporaryDirectory() as d:
        path = rec.save(os.path.join(d, "adaptive.jsonl"))
        header, records = load(path)
    assert header["version"] == TRACE_VERSION == 3

    replayed_gov, rep = replay(records, policy=CNTD_ADAPTIVE)
    for f in ("total_slack", "total_copy", "total_overlap", "exploited_slack",
              "energy_baseline", "energy_policy", "n_calls", "n_downshifts",
              "n_theta_decisions"):
        assert getattr(rep, f) == getattr(live, f), f
    assert replayed_gov.actuation_log == gov.actuation_log
    assert replayed_gov.theta_log == gov.theta_log
    # ... and the re-derived decisions match the records the recorder wrote
    recorded = [r for r in records if r["k"] == "theta"]
    assert len(recorded) == len(replayed_gov.theta_log)
    for r, dec in zip(recorded, replayed_gov.theta_log):
        assert (r["site"], r["rank"], r["before"], r["after"], r["reason"]) == (
            dec.site, dec.rank, dec.theta_before, dec.theta_after, dec.reason)


def test_adaptive_replay_under_fixed_policy_prices_differently():
    rec = TraceRecorder()
    gov = _adaptive_5phase_run(recorder=rec)
    live = gov.finalize()
    _, rep = replay(rec.records(), policy=COUNTDOWN_SLACK)   # fixed theta
    assert rep.total_slack == live.total_slack               # same phases
    assert rep.n_theta_decisions == 0
    assert rep.energy_policy != live.energy_policy           # different pricing


def test_v1_trace_still_loads(tmp_path):
    """Schema bump compatibility: v1 records are a strict subset of v2."""
    p = tmp_path / "v1.jsonl"
    p.write_text(
        '{"k": "hdr", "version": 1, "meta": {}, "n_records": 2, "n_dropped": 0}\n'
        '{"k": "ev", "rank": 0, "phase": "barrier_enter", "call": 1, "t": 1.0}\n'
        '{"k": "ev", "rank": 0, "phase": "barrier_exit", "call": 1, "t": 1.002}\n'
    )
    header, records = load(str(p))
    assert header["version"] == 1
    _, rep = replay(records)
    assert rep.n_calls == 1 and rep.total_slack == pytest.approx(2e-3)


def test_to_workload_lifts_async_overlap():
    rec = TraceRecorder()
    gov = _adaptive_5phase_run(recorder=rec)
    live = gov.finalize()
    wl = to_workload(rec.records())
    assert wl.overlap is not None and wl.overlap.max() > 0.0
    # every 4th collective call was async with ~1 ms dispatch->wait
    assert np.isclose(wl.overlap[wl.overlap > 0].max(), 1e-3, rtol=1e-6)
    res, _ = simulate(wl, COUNTDOWN_SLACK)
    # the lift conserves the live overlap EXACTLY, critical rank included —
    # clamping overlap by emergent slack would drop the last-dispatching
    # rank's dispatch->wait compute and undercount by ~(n-1)/n
    assert res.toverlap == pytest.approx(live.total_overlap, rel=1e-9)
    naive, _ = simulate(wl, COUNTDOWN_SLACK, overlap_aware=False)
    assert naive.toverlap == 0.0 and naive.tslack > res.tslack
    # the 6 ingested phases share one recorded site: they must collapse to
    # ONE workload site (40 collective call ids + 1), not one per phase —
    # else an adaptive what_if starts a cold histogram per phase
    assert wl.n_sites == 41


def test_trace_load_rejects_unknown_version(tmp_path):
    p = tmp_path / "bad.jsonl"
    p.write_text('{"k": "hdr", "version": 999, "meta": {}}\n')
    with pytest.raises(ValueError, match="version"):
        load(str(p))
    p2 = tmp_path / "headerless.jsonl"
    p2.write_text('{"k": "ev", "rank": 0, "phase": "barrier_enter", "call": 1, "t": 0.0}\n')
    with pytest.raises(ValueError, match="header"):
        load(str(p2))


def test_to_workload_reproduces_recorded_slack():
    rec = TraceRecorder()
    gov = _synthetic_run(recorder=rec, ingest=False)
    live = gov.finalize()
    wl = to_workload(rec.records())
    assert wl.n_ranks == 4 and wl.n_tasks == 25
    res, _ = simulate(wl, BASELINE)
    # baseline re-simulation of the lifted workload re-creates the same
    # emergent slack and copy time the live run measured
    assert res.tslack == pytest.approx(live.total_slack, rel=1e-9)
    assert res.tcopy == pytest.approx(live.total_copy, rel=1e-9)


def test_what_if_applies_policy_and_cap():
    rec = TraceRecorder()
    _synthetic_run(recorder=rec, ingest=False)
    free = what_if(rec.records(), COUNTDOWN_SLACK)
    n_ranks = 4
    capped = what_if(rec.records(), COUNTDOWN_SLACK,
                     power_cap=0.6 * n_ranks * DEFAULT_HW.watts_at_fmax)
    assert capped.energy < free.energy                   # cap sheds watts
    assert capped.time >= free.time                      # ... not for free


# --------------------------------------------------------------------------
# governor: interval snapshots, structured actuations, report dict
# --------------------------------------------------------------------------

def test_interval_snapshots_partition_the_run():
    gov = Governor()
    gov.ingest_phase(0, 1, 0.0, 10e-3, 12e-3)
    s1 = gov.interval_snapshot()
    gov.ingest_phase(0, 2, 1.0, 1.004, 1.005)
    gov.ingest_phase(1, 3, 2.0, 2.0002, 2.0002)          # under theta: no downshift
    s2 = gov.interval_snapshot()
    s3 = gov.interval_snapshot()                          # nothing new
    assert (s1.n_calls, s2.n_calls, s3.n_calls) == (1, 2, 0)
    assert s3.exploited_ratio == 0.0
    rep = gov.finalize()
    assert s1.slack + s2.slack == pytest.approx(rep.total_slack, rel=1e-12)
    assert s1.energy_policy + s2.energy_policy == pytest.approx(rep.energy_policy, rel=1e-12)
    assert s1.n_downshifts + s2.n_downshifts == rep.n_downshifts
    assert 0.0 < s1.exploited_ratio <= 1.0


def test_actuation_records_are_structured():
    gov = Governor()
    gov.ingest_phase(3, 7, 0.0, 5e-3, 6e-3)
    down, up = gov.actuation_log
    assert down.action == "set_pstate_min" and up.action == "restore_pstate_max"
    assert down.rank == 3 and down.call_id == 7
    assert down.slack == pytest.approx(5e-3)
    assert down[2] == "set_pstate_min"                   # legacy index layout


def test_report_to_dict_and_negative_energy_guard():
    gov = _synthetic_run()
    d = gov.finalize().to_dict()
    assert d["n_calls"] == 26 and "energy_saving_pct" in d
    assert isinstance(d["stragglers"], list)
    rep = GovernorReport(
        n_calls=1, n_downshifts=1, total_slack=1.0, total_copy=0.0,
        exploited_slack=1.0, energy_baseline=1.0, energy_policy=-1e-9,
        straggler_summary={}, stragglers=[],
    )
    assert rep.energy_saving_pct == 100.0                # clamped, not 100.0000001


# --------------------------------------------------------------------------
# power: aggregation, cap actuator, simulator power series/cap
# --------------------------------------------------------------------------

def test_aggregate_power_rolls_up_ragged_groups():
    series = np.arange(12.0).reshape(2, 6)
    nodes = aggregate_power(series, 4)                   # 6 ranks -> 2 nodes
    assert nodes.shape == (2, 2)
    np.testing.assert_allclose(nodes.sum(axis=1), series.sum(axis=1))
    with pytest.raises(ValueError):
        aggregate_power(series, 0)


def test_simulator_power_series_conserves_energy():
    wl = generate(APPS["nas_is.D.128"], seed=3)
    res, _ = simulate(wl, COUNTDOWN_SLACK, power_dt=0.1)
    assert res.power_series.shape[1] == wl.n_ranks
    assert res.power_series.shape[0] == int(np.ceil(res.time / 0.1))
    assert res.power_series.sum() * 0.1 == pytest.approx(res.energy, rel=1e-9)
    nodes = node_power_series(res, ranks_per_node=8)
    assert nodes.shape == (res.power_series.shape[0], 4)
    bare, _ = simulate(wl, COUNTDOWN_SLACK)
    with pytest.raises(ValueError, match="power series"):
        node_power_series(bare, 8)


def test_simulator_external_cap_sheds_power():
    wl = generate(APPS["nas_ft.E.1024"], seed=1)         # comm-bound: cheap to cap
    free, _ = simulate(wl, BASELINE)
    cap_w = 0.6 * wl.n_ranks * DEFAULT_HW.watts_at_fmax
    capped, _ = simulate(wl, BASELINE, power_cap=cap_w, power_dt=0.2)
    assert capped.energy < free.energy
    # enforced: binned aggregate watts never exceed the cap
    assert capped.power_series.sum(axis=1).max() <= cap_w * (1 + 1e-9)
    # a 0 W cap pins to f_min — it must not mean "uncapped" (falsy trap)
    zero, _ = simulate(wl, BASELINE, power_cap=0.0)
    pinned, _ = simulate(wl, BASELINE, power_cap=1e-9)
    assert zero.energy == pytest.approx(pinned.energy, rel=1e-12)
    assert zero.energy < free.energy


def test_f_for_power_inverts_watts():
    hw = DEFAULT_HW
    assert hw.f_for_power(hw.watts_at_fmax * 2, hw.act_comp) == hw.f_max
    assert hw.f_for_power(0.0, hw.act_comp) == hw.f_min
    for w in (6.0, 7.5, 9.0):
        f = float(hw.f_for_power(w, hw.act_comp))
        assert float(hw.watts(f, hw.act_comp)) <= w + 1e-9


def test_cap_actuator_latency_and_hysteresis():
    act = PowerCapActuator(cap_w=100.0, latency=500e-6, theta=500e-6,
                           deadband_w=1.0, floor_w=10.0)
    assert act.request(0.0, 80.0)
    assert act.cap_at(0.0) == 100.0                      # not yet committed
    assert act.cap_at(0.0 + 500e-6) == 80.0              # enforced after latency
    # inside theta_eff of the accepted request: rate-limited
    assert not act.request(100e-6, 50.0)
    # past theta_eff but within the watt deadband: suppressed
    assert not act.request(1.0, 80.5)
    assert act.n_suppressed == 2
    # floor clamp
    assert act.request(2.0, 0.0)
    assert act.cap_at(3.0) == 10.0
    assert len(act.commits) == 2


# --------------------------------------------------------------------------
# arbiter: property-tested invariants + directional behavior
# --------------------------------------------------------------------------

samples_strategy = st.tuples(
    st.integers(min_value=1, max_value=6),               # n_jobs
    st.integers(min_value=0, max_value=10_000),          # seed
    st.floats(min_value=50.0, max_value=500.0),          # cap
    st.floats(min_value=0.0, max_value=1.0),             # floor fraction of fair share
)


@given(samples_strategy)
@settings(max_examples=60, deadline=None)
def test_arbiter_never_exceeds_cap_nor_starves_floor(args):
    n_jobs, seed, cap, floor_frac = args
    rng = np.random.default_rng(seed)
    floor = floor_frac * cap / n_jobs
    arb = PowerBudgetArbiter(cap_w=cap, floor_w=floor,
                             alpha_w=float(rng.uniform(5.0, 100.0)),
                             beta=float(rng.uniform(0.1, 0.9)))
    ids = [f"job{i}" for i in range(n_jobs)]
    for _ in range(12):
        samples = [
            JobSample(j, power_w=float(rng.uniform(0, cap)),
                      exploited_ratio=float(rng.uniform(0, 1)),
                      done=bool(rng.random() < 0.1))
            for j in ids
        ]
        alloc = arb.step(samples)
        active = [s.job_id for s in samples if not s.done]
        assert set(alloc) == set(active)
        assert sum(alloc.values()) <= cap + 1e-6
        for j in active:
            assert alloc[j] >= floor - 1e-9


def test_arbiter_shifts_watts_to_critical_path():
    arb = PowerBudgetArbiter(cap_w=100.0, floor_w=10.0)
    for _ in range(8):
        alloc = arb.step([
            JobSample("critical", power_w=50.0, exploited_ratio=0.01),
            JobSample("slackful", power_w=50.0, exploited_ratio=0.60),
        ])
    assert alloc["critical"] > 70.0
    assert alloc["slackful"] == pytest.approx(10.0, abs=1.0)


def test_arbiter_frees_watts_on_departure():
    arb = PowerBudgetArbiter(cap_w=100.0, floor_w=10.0, alpha_w=50.0)
    arb.step([JobSample("a", 40.0, 0.0), JobSample("b", 40.0, 0.0)])
    alloc = arb.step([JobSample("a", 40.0, 0.0), JobSample("b", 40.0, 0.0, done=True)])
    assert set(alloc) == {"a"}
    alloc = arb.step([JobSample("a", 40.0, 0.0)])
    assert alloc["a"] > 80.0                             # climbed into freed watts


def test_arbiter_rejects_infeasible_floor():
    arb = PowerBudgetArbiter(cap_w=50.0, floor_w=30.0)
    with pytest.raises(ValueError, match="floor"):
        arb.step([JobSample("a", 1.0, 0.0), JobSample("b", 1.0, 0.0)])


# --------------------------------------------------------------------------
# jobs + co-scheduling
# --------------------------------------------------------------------------

def test_sim_job_consumes_workload_under_cap():
    job = make_job("comm_bound", seed=5, n_tasks=120, tasks_per_epoch=40)
    cap = 60.0
    reports = [job.run_epoch(cap) for _ in range(3)]
    assert job.done and job._cursor == 120
    for r in reports:
        assert r.cap_w == cap
        assert r.power_w <= cap * 1.02                   # enforced (act margin)
        assert 0.0 <= r.exploited_ratio <= 1.0
    assert job.total_wall_s == pytest.approx(sum(r.wall_s for r in reports))
    assert job.total_energy_j == pytest.approx(sum(r.energy_j for r in reports))


def test_governor_job_polls_live_interval():
    gov = Governor()
    job = GovernorJob("live", gov, n_ranks=4, cap_w=40.0)
    gov.ingest_phase(0, 1, 0.0, 5e-3, 6e-3)
    rep = job.run_epoch(35.0)
    assert rep.n_calls == 1
    assert 0.0 <= rep.exploited_ratio <= 1.0
    assert rep.power_w > 0.0
    assert job.last_sample().job_id == "live"
    assert len(job.actuator.commits) == 1                # cap request landed


def test_coschedule_arbiter_beats_static_split():
    """The acceptance mix: heterogeneous two-job workload under a tight
    cap — the slack-driven arbiter must save energy without violating the
    paper's performance-neutrality bar (<= 1% makespan overhead)."""
    cap, floor = 100.0, 15.0

    def mix():
        return [make_job("compute_bound", seed=1, floor_w=floor),
                make_job("bursty_serve", seed=2, floor_w=floor)]

    static = run_coschedule(mix(), cap, arbiter=StaticEqualSplit(cap_w=cap, floor_w=floor))
    arbited = run_coschedule(mix(), cap, arbiter=PowerBudgetArbiter(cap_w=cap, floor_w=floor))
    assert arbited.energy_j < static.energy_j
    assert arbited.makespan_s <= static.makespan_s * 1.01
    for alloc in arbited.allocations:
        assert sum(alloc.values()) <= cap + 1e-6
        for w in alloc.values():
            assert w >= floor - 1e-9


def test_instrument_tee_feeds_recorder():
    from repro.core import instrument

    rec = TraceRecorder()
    instrument.set_event_tee(rec.on_event)
    try:
        instrument._emit(0, 0, 42)
        instrument._emit(0, 1, 42)
    finally:
        instrument.set_event_tee(None)
    kinds = [(r["k"], r["phase"], r["call"]) for r in rec.records()]
    assert kinds == [("ev", "barrier_enter", 42), ("ev", "barrier_exit", 42)]
