"""Continuous-batching subsystem: paged pool, scheduler, slack bridge, SLO."""
import dataclasses
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.governor import Governor
from repro.models import init_params
from repro.models.inputs import make_batch
from repro.serve import (
    ContinuousEngine,
    PagedKVPool,
    Request,
    Scheduler,
    ServeEngine,
    SLOTracker,
    poisson_arrivals,
)


# --------------------------------------------------------------------------
# page pool accounting
# --------------------------------------------------------------------------

def test_pool_freelist_reserve_alloc_release():
    cfg = reduced(get_config("llama3.2-1b"))
    pool = PagedKVPool(cfg, n_slots=2, max_len=32, page=8, num_pages=9)
    assert pool.capacity_pages == 8 and pool.free_pages == 8
    assert pool.reserve("a", 20)                     # 3 pages
    assert pool.free_pages == 5
    got = pool.alloc("a", 2)
    assert len(got) == 2 and 0 not in got            # scratch page never handed out
    assert pool.reserve("b", 40)                     # 5 pages -> pool exactly full
    assert pool.free_pages == 0
    assert not pool.reserve("c", 8)                  # admission blocked
    with pytest.raises(RuntimeError):
        pool.alloc("a", 2)                           # beyond its reservation
    pool.release("a")
    assert pool.free_pages == 3                      # b's IOU still outstanding
    pool.release("b")
    assert pool.free_pages == 8
    with pytest.raises(ValueError):
        pool.reserve("huge", 1000)                   # can never fit


def test_scheduler_fifo_and_page_bounded_admission():
    cfg = reduced(get_config("llama3.2-1b"))
    pool = PagedKVPool(cfg, n_slots=2, max_len=32, page=8, num_pages=5)  # 4 usable
    sched = Scheduler(pool, n_slots=2)
    toks = np.arange(16, dtype=np.int32)
    r1 = Request(prompt=toks, max_new=8, arrival=0.0)   # needs 3 pages
    r2 = Request(prompt=toks, max_new=8, arrival=0.0)
    sched.submit(r1)
    sched.submit(r2)
    joins = sched.admit(now=0.0)
    assert [r.rid for r in joins] == [r1.rid]        # only one fits the pool
    assert sched.n_active == 1 and sched.n_queued == 1
    sched.release(r1)
    assert [r.rid for r in sched.admit(now=0.0)] == [r2.rid]
    with pytest.raises(ValueError):
        sched.submit(Request(prompt=np.zeros(40, np.int32), max_new=8))


# --------------------------------------------------------------------------
# legacy ServeEngine coverage (satellite)
# --------------------------------------------------------------------------

def test_legacy_greedy_vs_temperature_determinism(rng_key):
    cfg = reduced(get_config("llama3.2-1b"))
    params = init_params(cfg, rng_key)
    batch = make_batch(cfg, batch=2, seq_len=12, kind="prefill")
    greedy = ServeEngine(cfg, params, max_len=48)
    g1 = np.asarray(greedy.generate(batch, n_steps=6))
    # greedy ignores the key entirely
    g2 = np.asarray(greedy.generate(batch, n_steps=6, key=jax.random.PRNGKey(3)))
    np.testing.assert_array_equal(g1, g2)
    sampled = ServeEngine(cfg, params, max_len=48, temperature=1.0)
    s1 = np.asarray(sampled.generate(batch, n_steps=6, key=jax.random.PRNGKey(3)))
    s2 = np.asarray(sampled.generate(batch, n_steps=6, key=jax.random.PRNGKey(3)))
    np.testing.assert_array_equal(s1, s2)            # fixed key => deterministic
    assert not np.array_equal(s1, g1)                # and != greedy
    # temperature with no key falls back to greedy
    s3 = np.asarray(sampled.generate(batch, n_steps=6))
    np.testing.assert_array_equal(s3, g1)


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "mamba2-130m",
                                  "recurrentgemma-2b"])
def test_continuous_matches_serve_engine_token_for_token(rng_key, arch):
    cfg = reduced(get_config(arch))
    params = init_params(cfg, rng_key)
    batch = make_batch(cfg, batch=2, seq_len=16, kind="prefill")
    ref = np.asarray(ServeEngine(cfg, params, max_len=64).generate(batch, n_steps=8))
    eng = ContinuousEngine(cfg, params, n_slots=3, max_len=64, page=8)
    out = np.asarray(eng.generate(batch, n_steps=8))
    np.testing.assert_array_equal(ref, out)


def test_continuous_prefix_arch_parity_and_guard(rng_key):
    cfg = reduced(get_config("internvl2-1b"))         # n_prefix=8 frontend
    assert cfg.n_prefix > 0
    params = init_params(cfg, rng_key)
    batch = make_batch(cfg, batch=1, seq_len=12, kind="prefill")
    assert "prefix_embeds" in batch
    ref = np.asarray(ServeEngine(cfg, params, max_len=64).generate(batch, n_steps=6))
    eng = ContinuousEngine(cfg, params, n_slots=2, max_len=64, page=8)
    out = np.asarray(eng.generate(batch, n_steps=6))
    np.testing.assert_array_equal(ref, out)
    # a request without its prefix would attend phantom zero K/V: refused
    with pytest.raises(ValueError, match="prefix_embeds"):
        eng.serve([Request(prompt=np.arange(12, dtype=np.int32), max_new=4)])


def test_continuous_int8_pages_match_dense_kv_quant(rng_key):
    cfg = dataclasses.replace(reduced(get_config("llama3.2-1b")), kv_quant=True)
    params = init_params(cfg, rng_key)
    batch = make_batch(cfg, batch=1, seq_len=16, kind="prefill")
    ref = np.asarray(ServeEngine(cfg, params, max_len=64).generate(batch, n_steps=6))
    eng = ContinuousEngine(cfg, params, n_slots=2, max_len=64, page=8)
    out = np.asarray(eng.generate(batch, n_steps=6))
    np.testing.assert_array_equal(ref, out)
    assert eng.pool.blocks["stack"]["0"]["k_pages"].dtype == np.int8


# --------------------------------------------------------------------------
# continuous batching behavior
# --------------------------------------------------------------------------

def test_join_on_prefill_evict_on_eos_reuses_slots(rng_key):
    cfg = reduced(get_config("llama3.2-1b"))
    params = init_params(cfg, rng_key)
    eng = ContinuousEngine(cfg, params, n_slots=2, max_len=32, page=8)
    prompt = np.asarray(make_batch(cfg, batch=1, seq_len=8, kind="prefill")["tokens"])[0]
    reqs = [Request(prompt=prompt, max_new=m, arrival=0.0) for m in (2, 9, 3, 7)]
    done = eng.serve(reqs)
    assert sorted(len(r.out) for r in done) == [2, 3, 7, 9]
    # slots were reused: 4 requests through 2 slots, pool fully reclaimed
    assert eng.pool.free_pages == eng.pool.capacity_pages
    assert eng._last_meter is None                   # no governor attached
    for r in done:
        assert r.slot == -1 and not r.pages          # evicted + reclaimed


def test_eos_stops_generation_early(rng_key):
    cfg = reduced(get_config("llama3.2-1b"))
    params = init_params(cfg, rng_key)
    eng = ContinuousEngine(cfg, params, n_slots=1, max_len=32, page=8)
    prompt = np.arange(8, dtype=np.int32)
    free_run = eng.serve([Request(prompt=prompt, max_new=10)])[0]
    eos = free_run.out[2]                            # force EOS at the 3rd token
    capped = eng.serve([Request(prompt=prompt, max_new=10, eos_id=int(eos))])[0]
    assert len(capped.out) <= 3 and capped.out[-1] == eos


def test_decode_slack_priced_with_actuation_pairs(rng_key):
    cfg = reduced(get_config("llama3.2-1b"))
    params = init_params(cfg, rng_key)
    eng = ContinuousEngine(cfg, params, n_slots=4, max_len=32, page=8)
    prompt = np.arange(8, dtype=np.int32)
    eng.serve([Request(prompt=prompt, max_new=2)])   # warmup/compile
    gov = Governor()
    # one early request, a 60 ms idle gap, then a second: guarantees both
    # underfill (1 of 4 slots) and an idle interval >> theta_eff
    reqs = [Request(prompt=prompt, max_new=6, arrival=0.0),
            Request(prompt=prompt, max_new=6, arrival=0.06)]
    eng.serve(reqs, governor=gov)
    rep = gov.finalize()
    assert rep.total_slack > 0
    assert rep.energy_baseline > rep.energy_policy   # slack priced in joules
    downs = [a for a in gov.actuation_log if a[2] == "set_pstate_min"]
    restores = [a for a in gov.actuation_log if a[2] == "restore_pstate_max"]
    assert len(downs) >= 1 and len(downs) == len(restores)
    assert rep.n_downshifts >= 1
    meter = eng._last_meter
    assert meter.n_idle >= 1 and meter.fill_fraction < 1.0


def test_governor_ingest_phase_matches_sink_accounting():
    gov = Governor()
    # same phase through both paths: 2 ms slack, 1 ms copy
    gov.sink(0, "barrier_enter", 7, 1.000)
    gov.sink(0, "barrier_exit", 7, 1.002)
    gov.sink(0, "copy_exit", 7, 1.003)
    gov.ingest_phase(1, 1 << 20, 1.000, 1.002, 1.003)
    rep = gov.finalize()
    assert rep.n_calls == 2 and rep.n_downshifts == 2
    assert rep.total_slack == pytest.approx(0.004)
    assert rep.total_copy == pytest.approx(0.002)
    assert len(gov.actuation_log) == 4               # a pair per phase


# --------------------------------------------------------------------------
# SLO tracking
# --------------------------------------------------------------------------

def test_slo_percentiles_and_throttle():
    slo = SLOTracker(tpot_target=0.01, window=8, adjust_every=4)
    req = Request(prompt=np.zeros(4, np.int32), max_new=16, arrival=0.0)
    slo.on_first_token(req, 0.05)
    now = 0.05
    for _ in range(12):                              # sustained 20 ms TPOT
        now += 0.02
        slo.on_token(req, now)
    s = slo.summary()
    assert s["ttft"]["n"] == 1 and s["ttft"]["p95"] == pytest.approx(0.05)
    assert s["tpot"]["p50"] == pytest.approx(0.02)
    assert s["tpot"]["violations"] == 12
    assert slo.max_concurrency(4) < 4                # throttled below capacity
    for _ in range(40):                              # recovery: 1 ms TPOT
        now += 0.001
        slo.on_token(req, now)
        slo.max_concurrency(4)
    assert slo.max_concurrency(4) == 4               # additive regrowth


def test_slo_tracker_records_through_engine(rng_key):
    cfg = reduced(get_config("llama3.2-1b"))
    params = init_params(cfg, rng_key)
    eng = ContinuousEngine(cfg, params, n_slots=2, max_len=32, page=8)
    prompt = np.arange(8, dtype=np.int32)
    slo = SLOTracker()
    done = eng.serve([Request(prompt=prompt, max_new=5, arrival=0.0),
                      Request(prompt=prompt, max_new=5, arrival=0.01)], slo=slo)
    s = slo.summary()
    assert s["completed"] == 2 and s["ttft"]["n"] == 2
    assert s["tpot"]["n"] == 8                       # 4 decode tokens per request
    for r in done:
        assert r.t_first >= 0 and r.t_done >= r.t_first


def test_page_pool_shardings_rules():
    from jax.sharding import PartitionSpec as P

    from repro.dist.sharding import page_pool_shardings

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = reduced(get_config("llama3.2-1b"))
    attn = PagedKVPool(cfg, n_slots=2, max_len=32, page=8).blocks
    sh = page_pool_shardings(mesh, attn)
    # stacked page arrays: TP over the KV-head dim, pages replicated
    assert sh["stack"]["0"]["k_pages"].spec == P(None, None, None, "model", None)
    cfg_ssm = reduced(get_config("mamba2-130m"))
    state = PagedKVPool(cfg_ssm, n_slots=2, max_len=32, page=8).blocks
    sh = page_pool_shardings(mesh, state)
    # recurrent per-slot state: slot (batch) dim over the data axes
    assert sh["stack"]["0"]["conv"].spec[1] == ("data",)


def test_poisson_arrivals_shape_and_bursts():
    a = poisson_arrivals(8, rate=100.0, seed=0, burst_every=4, burst_gap=0.5)
    assert a.shape == (8,) and a[0] == 0.0
    assert np.all(np.diff(a) >= 0)
    assert a[4] - a[3] >= 0.5                        # burst gap inserted


# --------------------------------------------------------------------------
# Pallas paged-decode kernel: parity matrix vs the dense oracle
# --------------------------------------------------------------------------

# Every case uses page=8 with a 12- or 16-token prompt and 8 decode steps,
# so generation crosses a page boundary mid-decode (position 16 opens page
# 2 while slots are live), and max_len=40 gives a non-power-of-two table
# width (5 pages per request).
#
# The oracle is the dense-cache ServeEngine — except for MoE archs, where
# prefill expert capacity scales with total batch tokens, so the dense
# engine's batched prefill routes differently than the continuous
# engine's per-request prefill (pre-existing batching semantics, not an
# attention property).  MoE rows instead oracle against the XLA paged
# engine: identical batching discipline, so any divergence localizes to
# the kernel under test.
PALLAS_MATRIX = [
    # (arch, config overrides, prompt_len, max_len, oracle)
    ("llama3.2-1b", {}, 12, 40, "dense"),                                  # GQA
    ("llama3.2-1b", {"kv_quant": True}, 12, 40, "dense"),                  # int8
    ("llama3.2-1b", {"attention": "swa", "window": 16}, 12, 40, "dense"),  # window
    ("granite-moe-3b-a800m", {"kv_quant": True}, 12, 40, "xla"),           # MoE+int8
    ("granite-moe-3b-a800m", {}, 12, 40, "xla"),                           # MoE
    ("recurrentgemma-2b", {}, 16, 48, "dense"),                   # SSM-hybrid+local
    ("internlm2-1.8b", {"kv_quant": True}, 12, 40, "dense"),               # GQA+int8
]


@pytest.mark.parametrize("arch,mods,prompt_len,max_len,oracle", PALLAS_MATRIX)
def test_pallas_paged_decode_matches_oracle(rng_key, arch, mods,
                                            prompt_len, max_len, oracle):
    cfg = dataclasses.replace(reduced(get_config(arch)), **mods)
    params = init_params(cfg, rng_key)
    batch = make_batch(cfg, batch=2, seq_len=prompt_len, kind="prefill")
    if oracle == "dense":
        ref = np.asarray(
            ServeEngine(cfg, params, max_len=64).generate(batch, n_steps=8)
        )
    else:
        ref = np.asarray(
            ContinuousEngine(cfg, params, n_slots=3, max_len=max_len, page=8)
            .generate(batch, n_steps=8)
        )
    pal = ContinuousEngine(cfg, params, n_slots=3, max_len=max_len, page=8,
                           attn_kernel="pallas")
    np.testing.assert_array_equal(ref, np.asarray(pal.generate(batch, n_steps=8)))


def test_pallas_xla_dense_three_way_parity(rng_key):
    """One case asserting all three paths pairwise (the two paged engines
    share pool geometry, so any divergence localizes to the kernel)."""
    cfg = reduced(get_config("llama3.2-1b"))
    params = init_params(cfg, rng_key)
    batch = make_batch(cfg, batch=3, seq_len=14, kind="prefill")
    dense = np.asarray(ServeEngine(cfg, params, max_len=64).generate(batch, n_steps=10))
    xla = np.asarray(ContinuousEngine(cfg, params, n_slots=3, max_len=40, page=8)
                     .generate(batch, n_steps=10))
    pal = np.asarray(ContinuousEngine(cfg, params, n_slots=3, max_len=40, page=8,
                                      attn_kernel="pallas").generate(batch, n_steps=10))
    np.testing.assert_array_equal(dense, xla)
    np.testing.assert_array_equal(dense, pal)


def test_pallas_fused_sample_only_for_greedy(rng_key):
    """temperature > 0 needs host-side logits: the fused (B,) token step is
    reserved for greedy engines, and sampled output stays deterministic."""
    cfg = reduced(get_config("llama3.2-1b"))
    params = init_params(cfg, rng_key)
    greedy = ContinuousEngine(cfg, params, n_slots=2, max_len=40, page=8,
                              attn_kernel="pallas")
    assert greedy._fused_sample
    sampled = ContinuousEngine(cfg, params, n_slots=2, max_len=40, page=8,
                               attn_kernel="pallas", temperature=1.0)
    assert not sampled._fused_sample
    batch = make_batch(cfg, batch=2, seq_len=12, kind="prefill")
    s1 = np.asarray(sampled.generate(batch, n_steps=6, key=jax.random.PRNGKey(3)))
    s2 = np.asarray(sampled.generate(batch, n_steps=6, key=jax.random.PRNGKey(3)))
    np.testing.assert_array_equal(s1, s2)
    with pytest.raises(ValueError, match="attn_kernel"):
        ContinuousEngine(cfg, params, attn_kernel="mosaic")
