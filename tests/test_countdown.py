"""Unit tests for the COUNTDOWN Slack core: policies, simulator semantics,
timeout filter, slack isolation, governor event reconstruction."""
import numpy as np
import pytest

from repro.core.governor import Governor
from repro.core.policies import (
    ALL_POLICIES, BASELINE, COUNTDOWN, COUNTDOWN_SLACK, FERMATA_500US, MINFREQ,
)
from repro.core.pstate import DEFAULT_HW, HwModel
from repro.core.simulator import Workload, coverage_on_trace, simulate
from repro.core.workloads import APPS, generate


def _simple_workload(n_ranks=4, n_tasks=20, comp=1e-3, skew=2e-3, copy=0.5e-3, seed=0):
    """Rank 0 is the deterministic straggler: others wait ``skew`` seconds."""
    rng = np.random.default_rng(seed)
    comp_arr = np.full((n_tasks, n_ranks), comp)
    comp_arr[:, 0] += skew                       # rank 0 = critical everywhere
    return Workload(
        name="unit", n_ranks=n_ranks, comp=comp_arr,
        copy=np.full(n_tasks, copy), is_p2p=np.zeros(n_tasks, bool),
        partner=np.zeros((n_tasks, n_ranks), np.int64),
        site=rng.integers(0, 3, n_tasks), nbytes=np.full(n_tasks, 1e6),
        beta_comp=0.5, beta_copy=0.1,
    )


def test_baseline_slack_is_emergent():
    wl = _simple_workload()
    res, trace = simulate(wl, BASELINE, collect_trace=True)
    # non-critical ranks see ~skew of slack; the critical rank sees none
    assert np.allclose(trace.slack[:, 0], 0.0, atol=1e-12)
    assert np.all(trace.slack[:, 1:] > 1.5e-3)


def test_critical_rank_never_downshifted():
    """The timeout can only fire while waiting; the last arriver never waits."""
    wl = _simple_workload()
    base, _ = simulate(wl, BASELINE)
    res, _ = simulate(wl, COUNTDOWN_SLACK)
    # slack (2ms) > theta (0.5ms): downshifts happen on non-critical ranks,
    # energy drops, and the critical path is untouched (only fixed costs)
    assert res.energy < base.energy
    assert res.overhead_vs(base) < 0.5


def test_timeout_filters_short_slack():
    wl = _simple_workload(skew=0.3e-3)           # slack below 500us theta
    base, _ = simulate(wl, BASELINE)
    res, _ = simulate(wl, COUNTDOWN_SLACK)
    assert res.exploited_slack == 0.0            # filter rejected everything


def test_slack_scope_does_not_slow_copy():
    """COUNTDOWN slows copy (comm scope); COUNTDOWN Slack must not."""
    wl = _simple_workload(copy=5e-3, skew=3e-3)
    base, _ = simulate(wl, BASELINE)
    slack_res, _ = simulate(wl, COUNTDOWN_SLACK)
    comm_res, _ = simulate(wl, COUNTDOWN)
    assert comm_res.tcopy > slack_res.tcopy * 1.02   # copy visibly extended
    assert slack_res.overhead_vs(base) < comm_res.overhead_vs(base)


def test_minfreq_extremes():
    wl = _simple_workload()
    base, _ = simulate(wl, BASELINE)
    mf, _ = simulate(wl, MINFREQ)
    others = [simulate(wl, p)[0] for n, p in ALL_POLICIES.items() if n != "minfreq"]
    assert mf.time >= max(o.time for o in others)            # worst overhead
    p_save = mf.power_saving_vs(base)
    assert all(p_save >= o.power_saving_vs(base) - 1e-9 for o in others)


def test_coverage_ordering_slack_subset_of_comm():
    for name in ["nas_is.D.128", "omen_60p"]:
        wl = generate(APPS[name], seed=1)
        _, trace = simulate(wl, BASELINE, collect_trace=True)
        c_slack = coverage_on_trace(trace, COUNTDOWN_SLACK)
        c_comm = coverage_on_trace(trace, COUNTDOWN)
        c_min = coverage_on_trace(trace, MINFREQ)
        assert 0.0 <= c_slack <= c_comm <= c_min <= 100.0


def test_fermata_never_covers_first_encounter():
    wl = _simple_workload(skew=5e-3, n_tasks=1)  # single call per site
    _, trace = simulate(wl, BASELINE, collect_trace=True)
    assert coverage_on_trace(trace, FERMATA_500US) == 0.0


def test_paper_headline_claims_on_calibrated_apps():
    """The reproduction's core claims (Table 3 structure) hold per-app."""
    overheads, savings = [], []
    for name in ["nas_ft.E.1024", "nas_is.D.128", "omen_1056p"]:
        wl = generate(APPS[name], seed=0)
        base, _ = simulate(wl, BASELINE)
        res, _ = simulate(wl, ALL_POLICIES["cntd_slack"])
        overheads.append(res.overhead_vs(base))
        savings.append(res.energy_saving_vs(base))
    assert max(overheads) < 3.1                  # paper: worst case 3.02 %
    assert min(savings) > 3.0                    # slack-rich apps save energy
    assert max(savings) > 15.0                   # omen-scale saving


def test_governor_reconstructs_slack_and_flags_straggler():
    gov = Governor()
    t0 = 100.0
    n_ranks = 8                                  # z-score of one straggler in
    for call in range(12):                       # n ranks is bounded by
        base = t0 + call * 0.1                   # sqrt(n-1); need n >= 6
        for rank in range(n_ranks):
            enter = base if rank == 0 else base - 0.004   # rank0 arrives last
            gov.sink(rank, "barrier_enter", call, enter)
        for rank in range(n_ranks):
            gov.sink(rank, "barrier_exit", call, base)
            gov.sink(rank, "copy_exit", call, base + 0.001)
    rep = gov.finalize()
    assert rep.n_calls == 12
    assert rep.total_slack == pytest.approx(12 * (n_ranks - 1) * 0.004, rel=1e-6)
    assert rep.n_downshifts == 12 * (n_ranks - 1)   # 4ms slack >> 500us theta
    assert rep.energy_saving_pct > 0
    stragglers = [r for r, z in rep.stragglers]
    assert stragglers == [0]


def test_energy_model_calibration():
    hw = DEFAULT_HW
    full = hw.power(hw.f_max, hw.act_comp)
    low = hw.power(hw.f_min, hw.act_comp)
    saving = 1 - low / full
    assert 0.30 < saving < 0.50                  # paper Table 3: ~36% avg
    # slack spin at fmin is far cheaper than compute at fmax
    assert hw.power(hw.f_min, hw.act_slack) < 0.5 * full
