"""Per-architecture smoke tests: reduced same-family configs, one
forward/train step on CPU, asserting output shapes + no NaNs (assignment
requirement), plus prefill->decode consistency against full-sequence
scoring for one arch per family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, reduced
from repro.models import decode_step, init_cache, init_params, loss_fn, prefill
from repro.models.inputs import make_batch
from repro.models.transformer import forward, logits_fn, param_count

SMOKE_ARCHS = [a for a in ARCHS]


@pytest.mark.parametrize("arch", SMOKE_ARCHS)
def test_train_step_smoke(arch, rng_key):
    cfg = reduced(get_config(arch))
    params = init_params(cfg, rng_key)
    assert param_count(params) > 0
    batch = make_batch(cfg, batch=2, seq_len=32, kind="train")
    loss, metrics = loss_fn(cfg, params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    grads = jax.grad(lambda p: loss_fn(cfg, p, batch)[0])(params)
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert bool(jnp.all(jnp.isfinite(g))), f"{arch}: non-finite grad at {path}"


@pytest.mark.parametrize("arch", SMOKE_ARCHS)
def test_forward_shapes(arch, rng_key):
    cfg = reduced(get_config(arch))
    params = init_params(cfg, rng_key)
    batch = make_batch(cfg, batch=2, seq_len=32, kind="prefill")
    hidden, aux = forward(cfg, params, batch)
    assert hidden.shape == (2, 32, cfg.d_model)
    logits = logits_fn(cfg, params, hidden[:, -1:])
    assert logits.shape == (2, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize(
    "arch",
    ["llama3.2-1b", "mixtral-8x22b", "mamba2-130m", "recurrentgemma-2b",
     "musicgen-large", "internvl2-1b", "olmo-1b"],
)
def test_prefill_decode_consistency(arch, rng_key):
    cfg = reduced(get_config(arch))
    if cfg.is_moe:
        # dropless both paths so capacity dropping can't cause divergence
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts) / cfg.top_k)
    params = init_params(cfg, rng_key)
    b, s = 2, 24
    pf = make_batch(cfg, batch=b, seq_len=s, kind="prefill")
    extra = jnp.asarray(
        np.random.default_rng(3).integers(0, cfg.vocab, (b, 3)), jnp.int32
    )
    cache = init_cache(cfg, batch=b, max_len=s + 8)
    logits, cache = prefill(cfg, params, pf, cache)
    assert logits.shape == (b, cfg.vocab)
    for i in range(3):
        full_tokens = jnp.concatenate([pf["tokens"], extra[:, : i + 1]], axis=1)
        fb = {"tokens": full_tokens}
        if "prefix_embeds" in pf:
            fb["prefix_embeds"] = pf["prefix_embeds"]
        hid, _ = forward(cfg, params, fb)
        ref = logits_fn(cfg, params, hid[:, -1:])[:, 0]
        logits, cache = decode_step(cfg, params, extra[:, i], jnp.int32(s + i), cache)
        np.testing.assert_allclose(np.asarray(logits), np.asarray(ref), atol=2e-4, rtol=2e-3)


def test_windowed_ring_cache_matches_full_history(rng_key):
    """Decode beyond the window: ring cache must equal full-history windowed
    attention (recurrentgemma local attention, window smaller than history)."""
    cfg = reduced(get_config("recurrentgemma-2b"), window=16, n_layers=3)
    params = init_params(cfg, rng_key)
    b, s = 1, 20                                   # prompt longer than window
    pf = make_batch(cfg, batch=b, seq_len=s, kind="prefill")
    cache = init_cache(cfg, batch=b, max_len=64)
    logits, cache = prefill(cfg, params, pf, cache)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, 6)), jnp.int32)
    for i in range(6):
        full_tokens = jnp.concatenate([pf["tokens"], toks[:, : i + 1]], axis=1)
        hid, _ = forward(cfg, params, {"tokens": full_tokens})
        ref = logits_fn(cfg, params, hid[:, -1:])[:, 0]
        logits, cache = decode_step(cfg, params, toks[:, i], jnp.int32(s + i), cache)
        np.testing.assert_allclose(np.asarray(logits), np.asarray(ref), atol=2e-4, rtol=2e-3)


def test_param_count_analytic_close_to_actual(rng_key):
    for arch in ["llama3.2-1b", "mamba2-130m", "mixtral-8x22b"]:
        cfg = reduced(get_config(arch))
        actual = param_count(init_params(cfg, rng_key))
        analytic = cfg.param_count()
        assert abs(actual - analytic) / actual < 0.15, (arch, actual, analytic)


def test_int8_kv_cache_decode_close_to_exact(rng_key):
    """int8-quantized KV cache: logits within quantization tolerance and
    greedy tokens unchanged vs the exact full-forward reference."""
    import dataclasses

    cfg = reduced(get_config("musicgen-large"))
    cfgq = dataclasses.replace(cfg, kv_quant=True)
    params = init_params(cfg, rng_key)
    b, s = 2, 24
    pf = make_batch(cfg, batch=b, seq_len=s, kind="prefill")
    extra = jnp.asarray(
        np.random.default_rng(5).integers(0, cfg.vocab, (b, 3)), jnp.int32
    )
    cache = init_cache(cfgq, batch=b, max_len=s + 8)
    logits, cache = prefill(cfgq, params, pf, cache)
    for i in range(3):
        full_tokens = jnp.concatenate([pf["tokens"], extra[:, : i + 1]], axis=1)
        hid, _ = forward(cfg, params, {"tokens": full_tokens,
                                       "prefix_embeds": pf["prefix_embeds"]})
        ref = logits_fn(cfg, params, hid[:, -1:])[:, 0]
        logits, cache = decode_step(cfgq, params, extra[:, i], jnp.int32(s + i), cache)
        assert float(jnp.max(jnp.abs(logits - ref))) < 0.15
        assert bool(jnp.all(jnp.argmax(logits, -1) == jnp.argmax(ref, -1)))
