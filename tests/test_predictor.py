"""Random-forest predictor: regression quality, SMAPE, importance, dataset."""
import numpy as np

from repro.core.policies import BASELINE
from repro.core.predictor import (
    RandomForest, build_dataset, evaluate_predictability, smape,
)
from repro.core.simulator import simulate
from repro.core.workloads import APPS, generate


def test_smape_definition():
    assert smape(np.array([1.0]), np.array([1.0])) == 0.0
    assert abs(smape(np.array([3.0]), np.array([1.0])) - 50.0) < 1e-9


def test_forest_beats_mean_baseline():
    rng = np.random.default_rng(0)
    x = rng.normal(0, 1, (2000, 5))
    y = 3 * x[:, 0] - 2 * x[:, 1] ** 2 + 0.1 * rng.normal(size=2000)
    rf = RandomForest(n_trees=8, seed=0).fit(x[:1500], y[:1500])
    pred = rf.predict(x[1500:])
    mse_rf = float(np.mean((pred - y[1500:]) ** 2))
    mse_mean = float(np.mean((y[1500:].mean() - y[1500:]) ** 2))
    assert mse_rf < 0.35 * mse_mean


def test_dataset_prev_features_shift_history():
    wl = generate(APPS["nas_mg.E.128"], seed=0)
    _, trace = simulate(wl, BASELINE, collect_trace=True)
    x0, y0, n0 = build_dataset(trace, with_prev=False, max_rows=5000)
    x1, y1, n1 = build_dataset(trace, with_prev=True, max_rows=5000)
    assert x0.shape[1] == 7 and x1.shape[1] == 10
    assert len(n1) == 10 and n1[-3:] == ["prev_tcomp", "prev_tslack", "prev_tcopy"]
    assert len(x1) <= len(x0)                    # first encounters dropped


def test_prev_info_improves_tcomp_prediction():
    wl = generate(APPS["nas_is.D.128"], seed=0)
    _, trace = simulate(wl, BASELINE, collect_trace=True)
    r_no = evaluate_predictability("is", trace, with_prev=False, n_trees=4)
    r_yes = evaluate_predictability("is", trace, with_prev=True, n_trees=4)
    assert r_yes.smape["tcomp"] < r_no.smape["tcomp"]    # paper Table 1 trend
    assert all(0 <= v <= 100 for v in r_yes.smape.values())


def test_permutation_importance_normalized():
    wl = generate(APPS["nas_mg.E.128"], seed=0)
    _, trace = simulate(wl, BASELINE, collect_trace=True)
    r = evaluate_predictability("mg", trace, with_prev=True, n_trees=3, importance=True)
    for tgt, imps in r.importance.items():
        vals = list(imps.values())
        assert max(vals) <= 1.0 + 1e-9 and min(vals) >= 0.0
