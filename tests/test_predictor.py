"""Random-forest predictor: regression quality, SMAPE, importance, dataset."""
import numpy as np

from repro.core.policies import BASELINE
from repro.core.predictor import (
    RandomForest, build_dataset, evaluate_predictability, smape,
)
from repro.core.simulator import simulate
from repro.core.workloads import APPS, generate


def test_smape_definition():
    assert smape(np.array([1.0]), np.array([1.0])) == 0.0
    assert abs(smape(np.array([3.0]), np.array([1.0])) - 50.0) < 1e-9


def test_forest_beats_mean_baseline():
    rng = np.random.default_rng(0)
    x = rng.normal(0, 1, (2000, 5))
    y = 3 * x[:, 0] - 2 * x[:, 1] ** 2 + 0.1 * rng.normal(size=2000)
    rf = RandomForest(n_trees=8, seed=0).fit(x[:1500], y[:1500])
    pred = rf.predict(x[1500:])
    mse_rf = float(np.mean((pred - y[1500:]) ** 2))
    mse_mean = float(np.mean((y[1500:].mean() - y[1500:]) ** 2))
    assert mse_rf < 0.35 * mse_mean


def test_dataset_prev_features_shift_history():
    wl = generate(APPS["nas_mg.E.128"], seed=0)
    _, trace = simulate(wl, BASELINE, collect_trace=True)
    x0, y0, n0 = build_dataset(trace, with_prev=False, max_rows=5000)
    x1, y1, n1 = build_dataset(trace, with_prev=True, max_rows=5000)
    assert x0.shape[1] == 7 and x1.shape[1] == 10
    assert len(n1) == 10 and n1[-3:] == ["prev_tcomp", "prev_tslack", "prev_tcopy"]
    assert len(x1) <= len(x0)                    # first encounters dropped


def test_prev_info_improves_tcomp_prediction():
    wl = generate(APPS["nas_is.D.128"], seed=0)
    _, trace = simulate(wl, BASELINE, collect_trace=True)
    r_no = evaluate_predictability("is", trace, with_prev=False, n_trees=4)
    r_yes = evaluate_predictability("is", trace, with_prev=True, n_trees=4)
    assert r_yes.smape["tcomp"] < r_no.smape["tcomp"]    # paper Table 1 trend
    assert all(0 <= v <= 100 for v in r_yes.smape.values())


def test_permutation_importance_normalized():
    wl = generate(APPS["nas_mg.E.128"], seed=0)
    _, trace = simulate(wl, BASELINE, collect_trace=True)
    r = evaluate_predictability("mg", trace, with_prev=True, n_trees=3, importance=True)
    for tgt, imps in r.importance.items():
        vals = list(imps.values())
        assert max(vals) <= 1.0 + 1e-9 and min(vals) >= 0.0


# --------------------------------------------------------------------------
# PR satellites: dataset feature variance, SMAPE zero-denominator semantics,
# vectorized tree traversal, and the online predictor's regime machine
# --------------------------------------------------------------------------

def test_dataset_features_nondegenerate_on_p2p_workload():
    """Regression: p2p locality used to collapse to a constant (derived
    from the constant group size), zeroing its permutation importance.  On
    a p2p-heavy app every feature column must carry variance — locality
    now tells same-node pairs (1.0) from cross-node pairs (0.5) via the
    partner matrix."""
    import dataclasses

    spec = dataclasses.replace(APPS["nas_lu.E.1024"], n_tasks=600)
    wl = generate(spec, seed=0)
    _, trace = simulate(wl, BASELINE, collect_trace=True)
    assert trace.partner is not None
    x, _, names = build_dataset(trace, with_prev=True, max_rows=20_000)
    var = x.var(axis=0)
    for j, name in enumerate(names):
        assert var[j] > 0.0, f"degenerate feature column: {name}"
    # p2p rows must split into same-node (1.0) and cross-node (0.5) pairs;
    # collectives keep the fractional node-residency value
    p2p_loc = x[x[:, names.index("call_type")] == 1.0, names.index("locality")]
    assert {0.5, 1.0} <= set(np.unique(p2p_loc).tolist())


def test_smape_zero_denominator_counts_as_exact_hit():
    from repro.core.predictor import zero_denominator_fraction

    # all-zero pairs are exact hits, not dropped rows
    assert smape(np.zeros(4), np.zeros(4)) == 0.0
    # mixed: two exact zero hits dilute one 100%-wrong row to 25% overall
    pred = np.array([0.0, 0.0, 0.0, 1.0])
    act = np.array([0.0, 0.0, 1.0, 1.0])
    assert abs(smape(pred, act) - 25.0) < 1e-9
    assert zero_denominator_fraction(pred, act) == 0.5
    assert zero_denominator_fraction(np.array([]), np.array([])) == 0.0


def test_predictability_result_surfaces_zero_fraction():
    wl = generate(APPS["nas_is.D.128"], seed=0)
    _, trace = simulate(wl, BASELINE, collect_trace=True)
    r = evaluate_predictability("is", trace, with_prev=True, n_trees=3)
    assert sorted(r.zero_frac) == sorted(r.smape)
    assert all(0.0 <= v <= 1.0 for v in r.zero_frac.values())


def test_vectorized_tree_predict_matches_scalar_walk():
    """The packed level-order descent must route every row exactly as the
    recursive node walk would (same ``<=`` splits, same leaves)."""
    from repro.core.predictor import DecisionTree

    rng = np.random.default_rng(7)
    x = rng.normal(0, 1, (800, 6))
    y = x[:, 0] * 2 - np.abs(x[:, 2]) + 0.05 * rng.normal(size=800)
    tree = DecisionTree(max_depth=8, rng=np.random.default_rng(3)).fit(x, y)

    def walk_one(row):
        i = 0
        while tree.nodes[i].feature >= 0:
            n = tree.nodes[i]
            i = n.left if row[n.feature] <= n.threshold else n.right
        return tree.nodes[i].value

    xt = rng.normal(0, 1, (300, 6))
    fast = tree.predict(xt)
    slow = np.array([walk_one(r) for r in xt])
    np.testing.assert_array_equal(fast, slow)
    assert tree.predict(np.empty((0, 6))).shape == (0,)


def test_online_predictor_regime_transitions_and_determinism():
    from repro.core.predictor import OnlinePredictor

    def feed(p):
        rng = np.random.default_rng(42)
        for i in range(200):
            site = i % 2
            for r in range(4):
                p.observe(site, r, float(rng.uniform(0.5e-3, 2e-3)),
                          comp=3e-3)
            p.note_copy_ranks(site, rng.uniform(0.1e-3, 0.4e-3, 4))

    p = OnlinePredictor()
    val, src = p.predict(0, 0)
    assert src == "cold" and np.isnan(val)
    p.observe(0, 0, 1e-3)
    val, src = p.predict(0, 0)
    assert src == "ema" and val == 1e-3          # EMA seeds at first slack
    assert not p.warm
    feed(p)
    assert p.warm and p.n_refits >= 1
    val, src = p.predict(0, 0)
    assert src == "forest" and 0.0 <= val < 1.0
    preds, src = p.predict_ranks(0, 6)
    assert src == "forest"
    assert np.isnan(preds[4:]).all()             # never-seen ranks stay cold

    q = OnlinePredictor()
    q.observe(0, 0, 1e-3)
    feed(q)
    # seeded counter-triggered refits: same stream => bitwise-same model
    np.testing.assert_array_equal(p.predict_ranks(1, 4)[0],
                                  q.predict_ranks(1, 4)[0])
    p.reset()
    assert p.predict(0, 0)[1] == "cold" and not p.warm
