"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests must see the
host's real (single) device; multi-device integration tests spawn
subprocesses with their own flags (see test_multidevice.py)."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
