"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests must see the
host's real (single) device; multi-device integration tests spawn
subprocesses with their own flags (see test_multidevice.py)."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    import hypothesis  # noqa: F401 — the real library, when installed
except ImportError:
    # pip-frozen container: register the bundled mini-implementation so the
    # property suite still runs (see tests/_minihypothesis.py)
    import importlib.util

    _spec = importlib.util.spec_from_file_location(
        "hypothesis", os.path.join(os.path.dirname(__file__), "_minihypothesis.py")
    )
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _mod.strategies

import jax
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


@pytest.fixture(autouse=True)
def _fresh_instrumentation():
    """Instrumentation state is ambient (mode, sink, tee, call counter) and
    leaks across tests otherwise: a sink installed by one test would keep
    timestamping the next test's collectives, and the monotonically growing
    call counter makes event streams order-dependent.  Reset after every
    test (and once before, in case a previous process-level import left
    state behind)."""
    from repro.core import instrument

    instrument.reset_instrumentation()
    yield
    instrument.reset_instrumentation()
