"""Span tracer: fold the run's event streams into a Perfetto-loadable
Chrome-trace-event JSON.

One run produces several concurrent narratives — per-rank phase spans from
the instrumented collectives, the governor's P-state actuations and theta
decisions, arbiter watt grants, serve batch joins/evictions, SLO
percentiles — and the paper's whole argument is about *seeing* them on one
timeline.  :class:`SpanTracer` captures all of them with an O(1) hot path
(a bounded deque append per event; spans are reconstructed at export time,
mirroring :class:`~repro.cluster.trace.TraceRecorder`'s design) and
renders the Chrome trace-event flavor Perfetto loads natively:

* pid 1 ``ranks`` — one thread per rank; ``slack``/``copy``/``overlap``
  complete spans ("X") reconstructed with the governor's rotation rule.
* pid 2 ``governor`` — actuation instants per action, plus counter tracks
  ("C"): ``theta_us[site]`` from tuner decisions and anything the driver
  samples onto the ``governor`` track (cumulative slack, saving %).
* pid 3 ``serve`` — batch ``join``/``evict`` instants from the continuous
  engine.
* pid 4 ``arbiter`` — per-job watt-grant counter tracks.
* pid 5 ``slo`` — TTFT/TPOT percentile counter tracks.

Two capture wirings exist.  The production one (both launch drivers, the
bench guard) hangs the tracer off the governor's ``recorder=`` slot via
:class:`GovernorTap`: spans come from *retired* CallRecords and ingested
PhaseRecords (occurrence-granular — one hook call per ~3·n_ranks raw
events), and actuations are not streamed at all: the governor books its
compact spine log as if unobserved and :meth:`SpanTracer.ingest_governor`
reads it back once before export.  That is what keeps the full stack
inside the 10% ``sink_throughput`` budget.  Direct bus subscription
(``on_event``) still works and captures raw 5-phase streams — useful for
probes and tests — but pays a Python call per event, which the budget
does not cover.

Timestamps are host-monotonic seconds on capture and are rebased to the
earliest captured instant on export (Chrome traces want microseconds from
an arbitrary epoch).  Export ordering is deterministic: events are sorted
by ``(ts, pid, tid, ph, name)`` with a stable sort, so the same capture
always serializes to the same bytes — the golden-fixture property the
conformance test pins.

:func:`validate_trace` is the schema gate the tests and the CI smoke step
run against produced artifacts.
"""
from __future__ import annotations

import collections
import json
from typing import Any, Dict, List, Optional, Tuple

from repro.core.events import PhaseRecord

# fixed track layout (process ids in the Chrome trace)
PID_RANKS = 1
PID_GOVERNOR = 2
PID_SERVE = 3
PID_ARBITER = 4
PID_SLO = 5

TRACK_PIDS = {"ranks": PID_RANKS, "governor": PID_GOVERNOR,
              "serve": PID_SERVE, "arbiter": PID_ARBITER, "slo": PID_SLO}


class GovernorTap:
    """The obs stack's view of the governor's ``recorder=`` slot: forwards
    ingested phases, theta decisions, and retired occurrences to a
    :class:`SpanTracer` and/or a :class:`~repro.obs.metrics.BusMetrics`.

    Deliberately exposes **no** ``on_event`` and **no** actuation hook: a
    per-event (or per-downshift) recorder call is the cost the 10%
    telemetry budget cannot afford.  A retired
    :class:`~repro.core.governor.CallRecord` already carries every
    per-rank timestamp the spans need, and actuations already live in the
    governor's compact spine log — :meth:`SpanTracer.ingest_governor`
    reads them back once before export.  The governor pre-resolves
    recorder hooks, so each absent method costs it one ``None`` check and
    the hot path stays byte-for-byte the bare spine path."""

    __slots__ = ("_tracer", "_metrics")

    def __init__(self, tracer: Optional["SpanTracer"] = None, metrics=None):
        self._tracer = tracer
        self._metrics = metrics

    def on_phase(self, record: PhaseRecord) -> None:
        if self._tracer is not None:
            self._tracer.on_phase(record)
        if self._metrics is not None:
            self._metrics.on_phase(record)

    def on_theta(self, dec) -> None:
        if self._tracer is not None:
            self._tracer.on_theta(dec)

    def on_retired(self, rec) -> None:
        if self._tracer is not None:
            self._tracer.on_retired(rec)
        if self._metrics is not None:
            self._metrics.on_retired(rec)

    def on_retired_batch(self, block) -> None:
        """One :class:`~repro.core.governor.RetiredBlock` from the batched
        ingest path.  The tap advertising this hook is what lets the
        governor keep its vectorized fold while recording; a child that
        only speaks ``on_retired`` gets the block expanded to per-record
        calls (identical materialization to the retention ring)."""
        for child in (self._tracer, self._metrics):
            if child is None:
                continue
            cb = getattr(child, "on_retired_batch", None)
            if cb is not None:
                cb(block)
            else:
                for rec in block.records():
                    child.on_retired(rec)


class RecorderFanout:
    """Fan the governor's single ``recorder=`` slot out to N recorder-likes
    (e.g. a :class:`~repro.cluster.trace.TraceRecorder` and a
    :class:`GovernorTap`).  Children missing a hook are skipped for that
    hook; call lists are resolved once at construction so the per-event
    cost is one loop over bound methods."""

    def __init__(self, children):
        self.children = list(children)

        def hooks(name):
            # getattr-not-None, not hasattr: a nested fanout (or any
            # child using the None-shadowing convention below) carries
            # the attribute but may have disowned the hook
            return [cb for c in self.children
                    if (cb := getattr(c, name, None)) is not None]

        self._on_event = hooks("on_event")
        self._on_phase = hooks("on_phase")
        self._on_act = hooks("on_actuation")
        self._on_theta = hooks("on_theta")
        self._on_pair = hooks("on_actuation_pair")
        self._on_retired = hooks("on_retired")
        self._on_retired_batch = hooks("on_retired_batch")
        # children that speak only the per-record retirement form get
        # batched blocks expanded (same materialization as the ring)
        self._on_ret_only = [
            c.on_retired for c in self.children
            if getattr(c, "on_retired", None) is not None
            and getattr(c, "on_retired_batch", None) is None]
        # children that speak only the eager actuation form (TraceRecorder)
        # get expanded pairs when the governor uses the spine hook
        self._on_act_only = [
            c.on_actuation for c in self.children
            if getattr(c, "on_actuation", None) is not None
            and getattr(c, "on_actuation_pair", None) is None]
        # a hook no child subscribes to is *absent*, not a no-op: shadow
        # the class method with None so the governor's recorder
        # pre-resolution sees a missing hook — in particular, a fanout of
        # batch-capable children must not advertise ``on_event`` (which
        # would force the per-event replay and defeat the vectorized
        # batch path the children opted into)
        if not self._on_event:
            self.on_event = None
        if not self._on_phase:
            self.on_phase = None
        if not self._on_act:
            self.on_actuation = None
        if not self._on_theta:
            self.on_theta = None
        if not self._on_pair and not self._on_act_only:
            self.on_actuation_pair = None
        if not self._on_retired:
            self.on_retired = None
        if not self._on_retired_batch and not self._on_ret_only:
            self.on_retired_batch = None

    def on_event(self, rank, phase, call_id, t):
        for cb in self._on_event:
            cb(rank, phase, call_id, t)

    def on_phase(self, record):
        for cb in self._on_phase:
            cb(record)

    def on_actuation(self, act):
        for cb in self._on_act:
            cb(act)

    def on_actuation_pair(self, t, rank, call_id, slack):
        for cb in self._on_pair:
            cb(t, rank, call_id, slack)
        if self._on_act_only:
            from repro.core.governor import Actuation

            for act in (Actuation(t, rank, "set_pstate_min", call_id, slack),
                        Actuation(t, rank, "restore_pstate_max", call_id,
                                  slack)):
                for cb in self._on_act_only:
                    cb(act)

    def on_theta(self, dec):
        for cb in self._on_theta:
            cb(dec)

    def on_retired(self, rec):
        for cb in self._on_retired:
            cb(rec)

    def on_retired_batch(self, block):
        for cb in self._on_retired_batch:
            cb(block)
        if self._on_ret_only:
            for rec in block.records():
                for cb in self._on_ret_only:
                    cb(rec)


class SpanTracer:
    """Capture phase/actuation/decision/grant streams; export Chrome JSON.

    The capture side is an :class:`~repro.core.events.EventBus` subscriber
    (``on_event``/``on_phase``) plus the governor-output hooks
    (``on_actuation``/``on_theta`` — wire via :class:`GovernorTap`), the
    serve hook (``serve_event``), and a generic counter sampler
    (``sample``).  Everything lands in one bounded ring; ``n_dropped``
    reports evictions exactly like the trace recorder.
    """

    def __init__(self, capacity: int = 1_000_000,
                 meta: Optional[Dict[str, Any]] = None):
        self._raw: collections.deque = collections.deque(maxlen=capacity)
        self._append = self._raw.append
        self.capacity = capacity
        self.meta = dict(meta or {})
        self.n_seen = 0

    # ---- capture (hot path) ----------------------------------------------
    def on_event(self, rank: int, phase: str, call_id: int, t: float) -> None:
        self.n_seen += 1
        self._append(("ev", rank, phase, call_id, t))

    def on_phase(self, record: PhaseRecord) -> None:
        self.n_seen += 1
        self._append(("ph", record))

    def on_actuation_pair(self, t: float, rank: int, call_id: int,
                          slack: float) -> None:
        """Spine-form actuation pair from the governor's cheap path (one
        capture record; expands to the set/restore instants on export)."""
        self.n_seen += 1
        self._append(("actp", t, rank, call_id, slack))

    def on_retired(self, rec) -> None:
        """One retired :class:`~repro.core.governor.CallRecord`.  The
        record is immutable once retired (rotation mints a fresh object),
        so the capture is a reference append; per-rank slack/copy/overlap
        spans are reconstructed from it at export."""
        self.n_seen += 1
        self._append(("ret", rec))

    def on_retired_batch(self, block) -> None:
        """One :class:`~repro.core.governor.RetiredBlock` — the batched
        ingest form of :meth:`on_retired`: a single reference append
        carrying ``block.n`` retirements (it counts as one capture record
        for ring/drop accounting, like any other append); spans come out
        of the block's row arrays at export, identical to what the same
        stream's per-record captures would produce."""
        self.n_seen += 1
        self._append(("retb", block))

    # ---- capture (cold hooks) --------------------------------------------
    def ingest_governor(self, governor) -> None:
        """Pull the governor's actuation log into the capture.  Call once
        before :meth:`build`/:meth:`save`: actuations never ride the hot
        path — the governor books one compact spine tuple per downshift
        pair and the trace reads the log back here, in stream order, with
        original timestamps.  (Theta decisions arrive live via
        :class:`GovernorTap`; do not pull ``theta_log`` too or the counter
        track double-counts.)"""
        for act in governor.actuation_log:
            if act.action == "set_pstate_min":
                self.on_actuation_pair(act.t, act.rank, act.call_id,
                                       act.slack)

    def on_actuation(self, act) -> None:
        self.n_seen += 1
        self._append(("act", act))

    def on_theta(self, dec) -> None:
        self.n_seen += 1
        self._append(("theta", dec))

    def serve_event(self, kind: str, t: float, rid: int, slot: int) -> None:
        """A continuous-engine lifecycle instant: ``join`` or ``evict``."""
        self.n_seen += 1
        self._append(("serve", kind, t, rid, slot))

    def sample(self, track: str, name: str, t: float, value: float) -> None:
        """One counter sample on a named track (``governor`` | ``arbiter``
        | ``slo``): watt grants, cumulative slack, SLO percentiles, ..."""
        self.n_seen += 1
        self._append(("ctr", track, t, name, value))

    @property
    def n_dropped(self) -> int:
        return self.n_seen - len(self._raw)

    # ---- export ----------------------------------------------------------
    def _anchor(self) -> float:
        t0 = None
        for rec in self._raw:
            kind = rec[0]
            if kind == "ev" or kind == "serve":
                t = rec[4] if kind == "ev" else rec[2]
            elif kind == "ph":
                t = rec[1].t_enter
            elif kind == "ctr":
                t = rec[2]
            elif kind == "actp":
                t = rec[1]
            elif kind == "ret":
                r = rec[1]
                times = list(r.dispatch.values()) + list(r.enter.values())
                if not times:
                    continue
                t = min(times)
            elif kind == "retb":
                b = rec[1]
                t = float(b.row_t0.min()) if b.row_t0.size else None
                # dispatch-only ranks have no row; pull their times from
                # the dispatch class restricted to this block's segments
                sid_arr, _dr, dt_arr, _dp = b.classes["dispatch"]
                if sid_arr.size:
                    lo = sid_arr.searchsorted(b.sid_of_rid, "left")
                    hi = sid_arr.searchsorted(b.sid_of_rid, "right")
                    for l, h in zip(lo.tolist(), hi.tolist()):
                        if h > l:
                            td = float(dt_arr[l:h].min())
                            if t is None or td < t:
                                t = td
                if t is None:
                    continue
            else:                       # act / theta carry .t
                t = rec[1].t
            if t0 is None or t < t0:
                t0 = t
        return t0 or 0.0

    def build(self) -> Dict[str, Any]:
        """Assemble the Chrome trace dict (pure function of the capture)."""
        t0 = self._anchor()

        def us(t: float) -> float:
            return round((t - t0) * 1e6, 3)

        events: List[Dict[str, Any]] = []
        tracks_used = set()
        ranks_seen = set()

        def span(rank: int, name: str, ts: float, te: float,
                 args: Dict[str, Any]) -> None:
            tracks_used.add("ranks")
            ranks_seen.add(rank)
            events.append({"ph": "X", "pid": PID_RANKS, "tid": int(rank),
                           "name": name, "cat": "phase", "ts": us(ts),
                           "dur": round(max(te - ts, 0.0) * 1e6, 3),
                           "args": args})

        # span reconstruction state (the governor's rotation rule: a fresh
        # enter for an already-open (rank, call) restarts the occurrence)
        opens: Dict[Tuple[int, int], float] = {}
        disp: Dict[Tuple[int, int], float] = {}
        exits: Dict[Tuple[int, int], float] = {}
        for rec in self._raw:
            kind = rec[0]
            if kind == "ev":
                _, rank, phase, call_id, t = rec
                key = (rank, call_id)
                if phase == "barrier_enter":
                    opens[key] = t
                elif phase == "dispatch_enter":
                    disp[key] = t
                elif phase == "wait_enter":
                    td = disp.pop(key, None)
                    if td is not None and t > td:
                        span(rank, "overlap", td, t, {"call": call_id})
                    opens[key] = t
                elif phase == "barrier_exit":
                    ts = opens.pop(key, None)
                    if ts is not None:
                        span(rank, "slack", ts, t, {"call": call_id})
                    exits[key] = t
                elif phase == "copy_exit":
                    ts = exits.pop(key, None)
                    if ts is not None:
                        span(rank, "copy", ts, t, {"call": call_id})
            elif kind == "ph":
                r: PhaseRecord = rec[1]
                args: Dict[str, Any] = {"call": r.call_id}
                if r.site is not None:
                    args["site"] = r.site
                span(r.rank, "slack", r.t_enter, r.t_slack_end, args)
                if r.t_copy_end > r.t_slack_end:
                    span(r.rank, "copy", r.t_slack_end, r.t_copy_end, args)
            elif kind == "ret":
                # per-rank spans from a retired CallRecord — the governor's
                # own reconstruction, so spans match what was accounted
                r = rec[1]
                args = {"call": r.call_id}
                if r.site is not None:
                    args = {"call": r.call_id, "site": r.site}
                for rank, t0r in r.enter.items():
                    td = r.dispatch.get(rank)
                    if td is not None and t0r > td:
                        span(rank, "overlap", td, t0r, args)
                    t1 = r.slack_end.get(rank)
                    if t1 is None:
                        continue
                    span(rank, "slack", t0r, t1, args)
                    t2 = r.copy_end.get(rank)
                    if t2 is not None and t2 > t1:
                        span(rank, "copy", t1, t2, args)
            elif kind == "retb":
                # a RetiredBlock's row arrays are exactly the retired
                # records' entered ranks in per-record insertion order, so
                # walking them yields the same spans the "ret" branch
                # would over block.records() (NaN marks a missing phase)
                b = rec[1]
                cids_l = b.cids.tolist()
                rid_l = b.row_rid.tolist()
                rank_l = b.row_rank.tolist()
                t0_l = b.row_t0.tolist()
                t1_l = b.row_t1.tolist()
                t2_l = b.row_t2.tolist()
                td_l = b.row_td.tolist()
                for i in range(len(rid_l)):
                    args = {"call": cids_l[rid_l[i]]}
                    rank, t0r = rank_l[i], t0_l[i]
                    td = td_l[i]
                    if td == td and t0r > td:
                        span(rank, "overlap", td, t0r, args)
                    t1 = t1_l[i]
                    if t1 != t1:
                        continue
                    span(rank, "slack", t0r, t1, args)
                    t2 = t2_l[i]
                    if t2 == t2 and t2 > t1:
                        span(rank, "copy", t1, t2, args)
            elif kind == "act":
                act = rec[1]
                tracks_used.add("governor")
                events.append({"ph": "i", "pid": PID_GOVERNOR, "tid": 0,
                               "name": act.action, "cat": "actuation",
                               "ts": us(act.t), "s": "t",
                               "args": {"rank": act.rank, "call": act.call_id,
                                        "slack": act.slack}})
            elif kind == "actp":
                _, t, rank, call_id, slack = rec
                tracks_used.add("governor")
                for name in ("set_pstate_min", "restore_pstate_max"):
                    events.append({"ph": "i", "pid": PID_GOVERNOR, "tid": 0,
                                   "name": name, "cat": "actuation",
                                   "ts": us(t), "s": "t",
                                   "args": {"rank": rank, "call": call_id,
                                            "slack": slack}})
            elif kind == "theta":
                dec = rec[1]
                tracks_used.add("governor")
                events.append({"ph": "C", "pid": PID_GOVERNOR, "tid": 0,
                               "name": f"theta_us[{dec.site}]",
                               "ts": us(dec.t),
                               "args": {"theta_us": dec.theta_after * 1e6}})
            elif kind == "serve":
                _, skind, t, rid, slot = rec
                tracks_used.add("serve")
                events.append({"ph": "i", "pid": PID_SERVE, "tid": 0,
                               "name": skind, "cat": "serve", "ts": us(t),
                               "s": "t", "args": {"rid": rid, "slot": slot}})
            elif kind == "ctr":
                _, track, t, name, value = rec
                pid = TRACK_PIDS.get(track)
                if pid is None:
                    continue
                tracks_used.add(track)
                events.append({"ph": "C", "pid": pid, "tid": 0, "name": name,
                               "ts": us(t), "args": {"value": float(value)}})

        meta_events: List[Dict[str, Any]] = []
        for track in sorted(tracks_used):
            meta_events.append({"ph": "M", "pid": TRACK_PIDS[track], "tid": 0,
                                "name": "process_name",
                                "args": {"name": track}})
        for rank in sorted(ranks_seen):
            meta_events.append({"ph": "M", "pid": PID_RANKS, "tid": int(rank),
                                "name": "thread_name",
                                "args": {"name": f"rank {rank}"}})
        # deterministic ordering: stable sort on the event identity tuple —
        # identical captures serialize to identical bytes (golden fixture)
        events.sort(key=lambda e: (e["ts"], e["pid"], e["tid"], e["ph"],
                                   e["name"]))
        other = dict(self.meta)
        other["n_dropped"] = self.n_dropped
        return {"displayTimeUnit": "ms",
                "traceEvents": meta_events + events,
                "otherData": other}

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.build(), f, sort_keys=True)
        return path


def validate_trace(trace: Any, require_tracks: Tuple[str, ...] = ()) -> List[str]:
    """Schema-check a Chrome trace dict (or a path to one); returns the
    list of problems (empty = valid).  Checks the structural contract
    Perfetto needs — ``traceEvents`` with well-formed "X"/"i"/"C"/"M"
    events — plus the track-layout expectations of this tracer: every
    required track has its process_name metadata, per-rank spans carry
    non-negative durations, counter events carry numeric args.
    """
    if isinstance(trace, str):
        with open(trace) as f:
            trace = json.load(f)
    problems: List[str] = []
    events = trace.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    named_tracks = {}
    for i, e in enumerate(events):
        if not isinstance(e, dict):
            problems.append(f"event {i}: not an object")
            continue
        ph = e.get("ph")
        if ph not in ("X", "i", "C", "M"):
            problems.append(f"event {i}: unknown ph {ph!r}")
            continue
        for key in ("pid", "tid", "name"):
            if key not in e:
                problems.append(f"event {i} ({ph}): missing {key}")
        if ph == "M":
            if e.get("name") == "process_name":
                named_tracks[e.get("args", {}).get("name")] = e.get("pid")
            continue
        ts = e.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            problems.append(f"event {i} ({ph} {e.get('name')!r}): bad ts {ts!r}")
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"event {i} (X {e.get('name')!r}): bad dur {dur!r}")
        if ph == "C":
            args = e.get("args")
            if not isinstance(args, dict) or not args or not all(
                    isinstance(v, (int, float)) for v in args.values()):
                problems.append(f"event {i} (C {e.get('name')!r}): "
                                f"args must be a non-empty numeric map")
    for track in require_tracks:
        if track not in named_tracks:
            problems.append(f"required track {track!r} missing "
                            f"(have {sorted(named_tracks)})")
        elif named_tracks[track] != TRACK_PIDS.get(track):
            problems.append(f"track {track!r} on pid {named_tracks[track]} "
                            f"(expected {TRACK_PIDS.get(track)})")
    return problems
