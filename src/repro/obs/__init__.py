"""repro.obs — unified telemetry: metrics registry, Perfetto span tracing,
exporters/dashboards, and the structured driver logger.

Submodules are imported lazily (PEP 562) so that pulling one cheap piece
(``repro.obs.log`` in a driver, say) does not pay for the rest.
"""
from __future__ import annotations

_EXPORTS = {
    "MetricsRegistry": ("repro.obs.metrics", "MetricsRegistry"),
    "BusMetrics": ("repro.obs.metrics", "BusMetrics"),
    "GovernorCollector": ("repro.obs.metrics", "GovernorCollector"),
    "DEFAULT_EDGES": ("repro.obs.metrics", "DEFAULT_EDGES"),
    "SpanTracer": ("repro.obs.tracer", "SpanTracer"),
    "GovernorTap": ("repro.obs.tracer", "GovernorTap"),
    "RecorderFanout": ("repro.obs.tracer", "RecorderFanout"),
    "validate_trace": ("repro.obs.tracer", "validate_trace"),
    "MetricsJsonlWriter": ("repro.obs.export", "MetricsJsonlWriter"),
    "validate_metrics_jsonl": ("repro.obs.export", "validate_metrics_jsonl"),
    "prometheus_text": ("repro.obs.export", "prometheus_text"),
    "ConsoleDashboard": ("repro.obs.export", "ConsoleDashboard"),
    "get_logger": ("repro.obs.log", "get_logger"),
    "configure": ("repro.obs.log", "configure"),
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    try:
        mod_name, attr = _EXPORTS[name]
    except KeyError:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(mod_name), attr)


def __dir__():
    return sorted(set(globals()) | set(__all__))
