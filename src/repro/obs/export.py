"""Periodic sinks for the metrics registry: JSONL snapshots, Prometheus
text exposition, and the ``--dashboard`` console renderer.

All three are *pull* consumers of :class:`~repro.obs.metrics.
MetricsRegistry` — they cost nothing until a driver's report cadence asks
for a snapshot, keeping the telemetry overhead budget (bench-guarded at
10%) entirely on the event-bus side.

* :class:`MetricsJsonlWriter` — one JSON object per line, each embedding
  the full registry snapshot and (when a
  :class:`~repro.obs.metrics.GovernorCollector` is attached) the *exact*
  cumulative ``GovernorReport.to_dict()`` — the acceptance contract is
  that the last line's report equals the driver's end-of-run report
  bit-for-bit.
* :func:`prometheus_text` — the standard text exposition format, so a
  scrape endpoint (or a file_sd textfile collector) is one call away.
* :class:`ConsoleDashboard` — a compact fixed-layout block re-rendered at
  the driver's report cadence: slack/overlap/exploited ratios, energy
  saved, theta per site, serve TTFT/TPOT percentiles, fleet membership /
  routing / arbiter grants, watts vs cap.
"""
from __future__ import annotations

import json
import sys
import time
from typing import Any, Dict, List, Optional, TextIO, Tuple

from repro.obs.metrics import MetricsRegistry


# --------------------------------------------------------------------------
# JSONL snapshots
# --------------------------------------------------------------------------
class MetricsJsonlWriter:
    """Append one registry snapshot per :meth:`write` to a JSONL file.

    Each line: ``{"t", "step", "metrics", "report"?}`` where ``metrics`` is
    ``registry.snapshot()`` and ``report`` (when a governor collector is
    wired) is the exact cumulative ``GovernorReport.to_dict()``.
    """

    def __init__(self, path: str, registry: MetricsRegistry, collector=None):
        self.path = path
        self.registry = registry
        self.collector = collector
        self._f = open(path, "w")
        self.n_lines = 0

    def write(self, step: Optional[int] = None,
              t: Optional[float] = None) -> Dict[str, Any]:
        rec: Dict[str, Any] = {"t": time.time() if t is None else t,
                               "step": step,
                               "metrics": self.registry.snapshot()}
        if self.collector is not None:
            rec["report"] = self.collector.report().to_dict()
        self._f.write(json.dumps(rec) + "\n")
        self._f.flush()
        self.n_lines += 1
        return rec

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()

    def __enter__(self) -> "MetricsJsonlWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def validate_metrics_jsonl(path: str) -> List[str]:
    """Schema-check a snapshot file (CI smoke): every line parses, carries
    the snapshot envelope, and any embedded report has the GovernorReport
    keys.  Returns the list of problems (empty = valid)."""
    problems: List[str] = []
    report_keys = {"n_calls", "total_slack", "total_copy", "total_overlap",
                   "energy_baseline", "energy_policy", "energy_saving_pct"}
    n = 0
    with open(path) as f:
        for i, line in enumerate(f):
            if not line.strip():
                continue
            n += 1
            try:
                rec = json.loads(line)
            except ValueError as e:
                problems.append(f"line {i}: not JSON ({e})")
                continue
            if "t" not in rec or "metrics" not in rec:
                problems.append(f"line {i}: missing t/metrics envelope")
                continue
            if not isinstance(rec["metrics"], dict):
                problems.append(f"line {i}: metrics is not an object")
            for fam, body in rec.get("metrics", {}).items():
                if not isinstance(body, dict) or "kind" not in body \
                        or "values" not in body:
                    problems.append(f"line {i}: family {fam!r} malformed")
            if "report" in rec:
                missing = report_keys - set(rec["report"])
                if missing:
                    problems.append(f"line {i}: report missing {sorted(missing)}")
    if n == 0:
        problems.append("no snapshot lines")
    return problems


# --------------------------------------------------------------------------
# Prometheus text exposition
# --------------------------------------------------------------------------
def _label_str(labels: Dict[str, str], extra: Tuple[Tuple[str, str], ...] = ()) -> str:
    items = list(labels.items()) + list(extra)
    if not items:
        return ""
    body = ",".join(
        '%s="%s"' % (k, str(v).replace("\\", "\\\\").replace('"', '\\"'))
        for k, v in items)
    return "{%s}" % body


def prometheus_text(registry: MetricsRegistry) -> str:
    """Render the registry in the Prometheus text exposition format
    (counters/gauges as-is; histograms as cumulative ``_bucket`` series
    plus ``_sum``/``_count``).  Deterministic: families and children are
    emitted sorted."""
    snap = registry.snapshot()
    lines: List[str] = []
    for name in sorted(snap):
        body = snap[name]
        kind = body["kind"]
        if body["help"]:
            lines.append(f"# HELP {name} {body['help']}")
        lines.append(f"# TYPE {name} {kind}")
        for cell in body["values"]:
            labels = cell["labels"]
            if kind == "histogram":
                fam = registry._families[name]
                edges = None
                for key, child in fam.children():
                    if dict(zip(fam.label_names, key)) == labels:
                        edges = child.edges
                        break
                cum = 0
                if edges is not None:
                    for j, c in enumerate(cell["buckets"]):
                        cum += c
                        le = "%g" % edges[j + 1]
                        lines.append(f"{name}_bucket"
                                     f"{_label_str(labels, (('le', le),))} {cum}")
                lines.append(f"{name}_bucket"
                             f"{_label_str(labels, (('le', '+Inf'),))} "
                             f"{cell['count']}")
                lines.append(f"{name}_sum{_label_str(labels)} {cell['sum']!r}")
                lines.append(f"{name}_count{_label_str(labels)} {cell['count']}")
            else:
                lines.append(f"{name}{_label_str(labels)} {cell['value']!r}")
    return "\n".join(lines) + "\n"


# --------------------------------------------------------------------------
# console dashboard
# --------------------------------------------------------------------------
def _labeled(registry: MetricsRegistry, name: str) -> List[Tuple[Dict[str, str], float]]:
    fam = registry._families.get(name)
    if fam is None:
        return []
    out = []
    for key, child in fam.children():
        value = child.sum if fam.kind == "histogram" else child.value
        out.append((dict(zip(fam.label_names, key)), value))
    return out


class ConsoleDashboard:
    """Fixed-layout run dashboard re-rendered at the report cadence.

    Reads only the registry (plus whatever collectors sync into it), so the
    same renderer serves train (governor ratios, theta, watts vs cap) and
    serve (TTFT/TPOT percentiles) — rows for absent metrics are dropped.
    """

    def __init__(self, registry: MetricsRegistry, title: str = "run",
                 stream: Optional[TextIO] = None):
        self.registry = registry
        self.title = title
        self.stream = stream
        self.n_renders = 0

    # -- row builders ------------------------------------------------------
    def _governor_rows(self) -> List[str]:
        g = self.registry.get_value
        slack = g("governor_interval_slack_ratio")
        if slack is None:
            return []
        overlap = g("governor_interval_overlap_ratio") or 0.0
        expl = g("governor_interval_exploited_ratio") or 0.0
        saving = g("governor_energy_saving_pct") or 0.0
        calls = g("governor_calls_total") or 0.0
        downs = g("governor_downshifts_total") or 0.0
        rows = [
            f"  slack {100.0 * slack:5.1f}%   overlap {100.0 * overlap:5.1f}%"
            f"   exploited {100.0 * expl:5.1f}%",
            f"  energy saved {saving:5.2f}%   calls {int(calls)}"
            f"   downshifts {int(downs)}",
        ]
        thetas = _labeled(self.registry, "governor_theta_seconds")
        if thetas:
            cells = "  ".join(
                f"{lab.get('site', '?')}:{1e6 * v:.0f}us"
                for lab, v in thetas[:6])
            more = f" (+{len(thetas) - 6})" if len(thetas) > 6 else ""
            rows.append(f"  theta {cells}{more}")
        return rows

    def _serve_rows(self) -> List[str]:
        rows = []
        for metric, label in (("serve_ttft_seconds", "ttft"),
                              ("serve_tpot_seconds", "tpot")):
            cells = {lab.get("q"): v for lab, v in
                     _labeled(self.registry, metric)}
            if cells:
                rows.append(
                    f"  {label} p50 {1e3 * cells.get('p50', 0.0):7.1f}ms"
                    f"   p99 {1e3 * cells.get('p99', 0.0):7.1f}ms")
        done = self.registry.get_value("serve_completed_total")
        if done is not None:
            rows.append(f"  completed {int(done)}")
        return rows

    def _ingest_rows(self) -> List[str]:
        g = self.registry.get_value
        total = g("ingest_events_total")
        if total is None:
            return []
        rate = g("ingest_events_per_second") or 0.0
        occ = g("ingest_batch_occupancy") or 0.0
        depth = g("ingest_queue_depth") or 0.0
        fallback = g("ingest_fallback_events_total") or 0.0
        row = (f"  ingest {rate / 1e6:6.2f}M ev/s   occupancy "
               f"{100.0 * occ:5.1f}%   queue {int(depth)}")
        if fallback:
            row += f"   fallback {int(fallback)}"
        return [row]

    def _fleet_rows(self) -> List[str]:
        g = self.registry.get_value
        replicas = g("fleet_replicas")
        if replicas is None:
            return []
        rows = [f"  fleet {int(replicas)} replicas"]
        hit = g("fleet_prefix_hit_rate")
        routed = g("fleet_router_decisions")
        pref = g("fleet_router_prefix_routed")
        if routed is not None:
            frac = (pref or 0.0) / max(routed, 1.0)
            rows[0] += (f"   routed {int(routed)}"
                        f" ({100.0 * frac:.0f}% by prefix)")
        if hit is not None:
            rows[0] += f"   prefix hit {100.0 * hit:5.1f}%"
        ups, downs = g("fleet_scale_ups"), g("fleet_scale_downs")
        energy = g("fleet_energy_joules")
        if ups is not None or energy is not None:
            row = "  "
            if ups is not None:
                row += f"scale +{int(ups)}/-{int(downs or 0)}"
            if energy is not None:
                row += f"   energy {energy:8.1f}J"
            rows.append(row)
        cap = g("arbiter_cap_watts")
        if cap is not None:
            pool = g("arbiter_pool_watts") or 0.0
            rows.append(f"  arbiter cap {cap:.0f}W   granted "
                        f"{cap - pool:.1f}W   pool {pool:.1f}W")
        return rows

    def _power_rows(self) -> List[str]:
        caps = {lab.get("job"): v for lab, v in
                _labeled(self.registry, "job_cap_watts")}
        watts = {lab.get("job"): v for lab, v in
                 _labeled(self.registry, "job_power_watts")}
        rows = []
        for job in sorted(set(caps) | set(watts)):
            w, c = watts.get(job, 0.0), caps.get(job)
            cap_s = f"/{c:.0f}W cap" if c is not None else ""
            rows.append(f"  power[{job}] {w:7.1f}W{cap_s}")
        return rows

    def render(self, step: Optional[int] = None) -> str:
        head = f"== {self.title}"
        if step is not None:
            head += f" · step {step}"
        head += " =="
        rows = ([head] + self._governor_rows() + self._ingest_rows()
                + self._serve_rows() + self._fleet_rows()
                + self._power_rows())
        return "\n".join(rows)

    def tick(self, step: Optional[int] = None) -> str:
        """Render and print one dashboard frame; returns the frame."""
        frame = self.render(step)
        stream = self.stream or sys.stdout
        stream.write(frame + "\n")
        stream.flush()
        self.n_renders += 1
        return frame
