"""Low-overhead labeled metrics registry fed by the event bus and governor.

Three instrument kinds, Prometheus-shaped so the exposition layer in
:mod:`repro.obs.export` is a direct rendering:

* :class:`Counter` — monotone float per label set (``*_total`` names).
* :class:`Gauge` — last-write float per label set.
* :class:`Histogram` — fixed log-binned buckets over the same
  ``geomspace(1e-6, 30, 97)`` edges the :class:`~repro.core.timeout.
  ThetaTuner` uses for its slack CDFs, so a registry histogram and a tuner
  site histogram over the same stream are bucket-compatible.

Two bus-facing consumers sit on top:

* :class:`BusMetrics` — an :class:`~repro.core.events.EventBus` subscriber.
  The streamed-event path is the runtime's hottest loop, so ``on_event``
  is one dict increment (per-phase event counts); fully-formed
  :class:`~repro.core.events.PhaseRecord` phases additionally land their
  slack/copy durations in histograms using *the same clamp and addition
  order as the governor's accumulators* — ``sum(slack histogram)`` equals
  ``GovernorReport.total_slack`` bit-for-bit over any phase-record stream
  (property-tested in ``tests/test_obs.py``).
* :class:`GovernorCollector` — polls ``Governor.interval_snapshot()`` into
  counters/gauges (slack/copy/overlap/energy/downshifts per interval,
  cumulative totals), publishes the straggler detector and theta tuner
  state, and exposes the exact end-of-run ``GovernorReport`` for the JSONL
  snapshot writer.

The registry itself stays numpy-light (``bisect`` on the hot path) and
jax-free, like :mod:`repro.core.events`, so host-side tooling can import
it for pennies.
"""
from __future__ import annotations

import bisect
import math
import threading
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.events import PhaseRecord

# the ThetaTuner's slack binning (timeout.py): log-spaced 1 us .. 30 s
DEFAULT_EDGES: Tuple[float, ...] = tuple(
    math.exp(math.log(1e-6) + i * (math.log(30.0) - math.log(1e-6)) / 96)
    for i in range(97)
)


class _Child:
    """One (instrument, label values) cell; the hot-path handle."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v

    def set(self, v: float) -> None:
        self.value = float(v)


class _HistChild:
    """Histogram cell: fixed buckets + streaming sum/count.

    ``observe`` clamps negatives to zero exactly as the governor's
    accumulator does (``slack < 0 -> 0.0``) and accumulates ``sum`` by
    plain float addition in observation order — the two properties that
    make registry totals comparable ``==`` against governor totals.
    """

    __slots__ = ("edges", "counts", "sum", "count")

    def __init__(self, edges: Sequence[float]):
        self.edges = tuple(edges)
        self.counts = [0] * (len(self.edges) - 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        if v < 0.0:
            v = 0.0
        i = bisect.bisect_right(self.edges, v) - 1
        if i < 0:
            i = 0
        elif i >= len(self.counts):
            i = len(self.counts) - 1
        self.counts[i] += 1
        self.sum += v
        self.count += 1


class _Family:
    """One named instrument with 0+ labeled children."""

    __slots__ = ("name", "kind", "help", "label_names", "_children", "_edges")

    def __init__(self, name: str, kind: str, help: str,
                 label_names: Tuple[str, ...],
                 edges: Optional[Sequence[float]] = None):
        self.name = name
        self.kind = kind
        self.help = help
        self.label_names = label_names
        self._children: Dict[Tuple[str, ...], Any] = {}
        self._edges = tuple(edges) if edges is not None else None

    def labels(self, *values: Any) -> Any:
        """The child for one label-value tuple (created on first access;
        values are stringified so ``labels(3)`` and ``labels("3")`` are one
        cell).  Hot paths resolve the child once and keep the handle."""
        key = tuple(str(v) for v in values)
        if len(key) != len(self.label_names):
            raise ValueError(
                f"{self.name}: got {len(key)} label values for "
                f"label names {self.label_names}"
            )
        child = self._children.get(key)
        if child is None:
            child = (_HistChild(self._edges or DEFAULT_EDGES)
                     if self.kind == "histogram" else _Child())
            self._children[key] = child
        return child

    # unlabeled conveniences -------------------------------------------------
    def inc(self, v: float = 1.0) -> None:
        self.labels().inc(v)

    def set(self, v: float) -> None:
        self.labels().set(v)

    def observe(self, v: float) -> None:
        self.labels().observe(v)

    def children(self) -> List[Tuple[Tuple[str, ...], Any]]:
        return sorted(self._children.items())


class MetricsRegistry:
    """Named instruments + pre-snapshot collector hooks.

    ``counter``/``gauge``/``histogram`` are get-or-create and type-checked:
    re-registering a name with a different kind or label set is a bug, not
    a silent second family.  ``add_collector`` registers a zero-arg hook
    run at the top of :meth:`snapshot` — pull-model sources (governor
    polls, SLO trackers) sync themselves there instead of paying per-event.
    """

    def __init__(self) -> None:
        self._families: Dict[str, _Family] = {}
        self._collectors: List[Callable[[], None]] = []
        self._lock = threading.Lock()

    def _get(self, name: str, kind: str, help: str,
             label_names: Sequence[str],
             edges: Optional[Sequence[float]] = None) -> _Family:
        label_names = tuple(label_names)
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = _Family(name, kind, help, label_names, edges)
                self._families[name] = fam
            elif fam.kind != kind or fam.label_names != label_names:
                raise ValueError(
                    f"metric {name!r} already registered as {fam.kind} with "
                    f"labels {fam.label_names}; got {kind} / {label_names}"
                )
            return fam

    def counter(self, name: str, help: str = "",
                label_names: Sequence[str] = ()) -> _Family:
        return self._get(name, "counter", help, label_names)

    def gauge(self, name: str, help: str = "",
              label_names: Sequence[str] = ()) -> _Family:
        return self._get(name, "gauge", help, label_names)

    def histogram(self, name: str, help: str = "",
                  label_names: Sequence[str] = (),
                  edges: Optional[Sequence[float]] = None) -> _Family:
        return self._get(name, "histogram", help, label_names, edges)

    def add_collector(self, fn: Callable[[], None]) -> Callable[[], None]:
        self._collectors.append(fn)
        return fn

    def families(self) -> List[_Family]:
        return [self._families[k] for k in sorted(self._families)]

    def snapshot(self) -> Dict[str, Any]:
        """JSON-ready view of every family (collector hooks run first)."""
        for fn in self._collectors:
            fn()
        out: Dict[str, Any] = {}
        for fam in self.families():
            values = []
            for key, child in fam.children():
                labels = dict(zip(fam.label_names, key))
                if fam.kind == "histogram":
                    values.append({"labels": labels, "sum": child.sum,
                                   "count": child.count,
                                   "buckets": list(child.counts)})
                else:
                    values.append({"labels": labels, "value": child.value})
            out[fam.name] = {"kind": fam.kind, "help": fam.help,
                             "values": values}
        return out

    def get_value(self, name: str, *label_values: Any) -> Optional[float]:
        """Convenience read (dashboards): the scalar value of one cell, or
        ``None`` if the family/cell does not exist."""
        fam = self._families.get(name)
        if fam is None:
            return None
        key = tuple(str(v) for v in label_values)
        child = fam._children.get(key)
        if child is None:
            return None
        return child.sum if fam.kind == "histogram" else child.value


class BusMetrics:
    """Per-phase event counts + phase-duration histograms.

    Two attachment modes — pick exactly one per instance or events double-
    count:

    * **Governor tap** (production drivers): hang it off the governor via
      :class:`~repro.obs.tracer.GovernorTap` — ``on_retired`` reconstructs
      exact event counts from each retired occurrence (one call per
      occurrence, not per event: this is the wiring the 10% bench budget
      is measured on) and ``on_phase`` books ingested phases.
    * **Bus subscriber** (probes, tests, phase-record streams):
      ``on_event`` is one dict increment per streamed event — cheap, but a
      Python call per event, which the telemetry budget does not cover.

    Registry sync happens in the collector hook either way."""

    def __init__(self, registry: MetricsRegistry, rank_label: bool = False):
        self.registry = registry
        self._ev_counts: Dict[str, int] = {}
        self._ev_family = registry.counter(
            "bus_events_total", "streamed phase events seen on the bus",
            ("phase",))
        self._phases = registry.counter(
            "bus_phase_records_total", "fully-formed phase records seen")
        self._slack_hist = registry.histogram(
            "phase_slack_seconds", "slack durations of fully-formed phases")
        self._copy_hist = registry.histogram(
            "phase_copy_seconds", "copy durations of fully-formed phases")
        # pre-resolved unlabeled children: the on_phase path is per-record,
        # not per-event, but still should not pay dict lookups
        self._phases_c = self._phases.labels()
        self._slack_c = self._slack_hist.labels()
        self._copy_c = self._copy_hist.labels()
        registry.add_collector(self._sync)

    # hot path -------------------------------------------------------------
    def on_event(self, rank: int, phase: str, call_id: int, t: float) -> None:
        c = self._ev_counts
        c[phase] = c.get(phase, 0) + 1

    def on_phase(self, record: PhaseRecord) -> None:
        self._phases_c.inc()
        # identical clamp + addition order to Governor._accumulate, so the
        # histogram sums compare == against the governor's accumulators
        self._slack_c.observe(record.t_slack_end - record.t_enter)
        self._copy_c.observe(record.t_copy_end - record.t_slack_end)

    def on_retired(self, rec) -> None:
        """Event counts from one retired :class:`~repro.core.governor.
        CallRecord` (the :class:`~repro.obs.tracer.GovernorTap` wiring):
        each rank's raw events are reconstructed exactly from the record —
        a rank present in both ``dispatch`` and ``enter`` arrived via
        ``dispatch_enter``+``wait_enter``, not ``barrier_enter``.  Costs
        one call per *occurrence* instead of one per event, which is how
        the attached stack stays inside the 10% budget; counts for
        still-in-flight occurrences book at their retirement."""
        c = self._ev_counts
        n_enter = len(rec.enter)
        n_disp = len(rec.dispatch)
        if n_disp:
            enter = rec.enter
            n_wait = sum(1 for r in rec.dispatch if r in enter)
            c["dispatch_enter"] = c.get("dispatch_enter", 0) + n_disp
            if n_wait:
                c["wait_enter"] = c.get("wait_enter", 0) + n_wait
            n_enter -= n_wait
        if n_enter:
            c["barrier_enter"] = c.get("barrier_enter", 0) + n_enter
        if rec.slack_end:
            c["barrier_exit"] = c.get("barrier_exit", 0) + len(rec.slack_end)
        if rec.copy_end:
            c["copy_exit"] = c.get("copy_exit", 0) + len(rec.copy_end)

    def on_retired_batch(self, block) -> None:
        """Event counts from one :class:`~repro.core.governor.RetiredBlock`
        — the batched-ingest analogue of :meth:`on_retired`, identical
        totals (the equivalence suite compares them), one call per *chunk*
        of retirements instead of one per occurrence.  Pure column math:
        the block's enter rows carry a NaN-free dispatch join time exactly
        when the rank arrived via ``dispatch_enter``+``wait_enter``."""
        c = self._ev_counts
        n_enter = int(block.row_rid.shape[0])
        # row_td == row_td is the no-numpy-import NaN test
        n_wait = int((block.row_td == block.row_td).sum()) if n_enter else 0
        n_disp = int(block.class_counts("dispatch").sum())
        n_slack = int(block.class_counts("slack").sum())
        n_copy = int(block.class_counts("copy").sum())
        if n_disp:
            c["dispatch_enter"] = c.get("dispatch_enter", 0) + n_disp
        if n_wait:
            c["wait_enter"] = c.get("wait_enter", 0) + n_wait
        n_enter -= n_wait
        if n_enter:
            c["barrier_enter"] = c.get("barrier_enter", 0) + n_enter
        if n_slack:
            c["barrier_exit"] = c.get("barrier_exit", 0) + n_slack
        if n_copy:
            c["copy_exit"] = c.get("copy_exit", 0) + n_copy

    # cold path ------------------------------------------------------------
    def _sync(self) -> None:
        """Move the cheap per-phase tallies into registry counters (counters
        are monotone: we add the delta since the last sync)."""
        for phase, n in self._ev_counts.items():
            child = self._ev_family.labels(phase)
            delta = n - child.value
            if delta:
                child.inc(delta)


class IngestMetrics:
    """Batched-ingest health: the :class:`~repro.core.events.EventBus`
    ingest counters rendered as registry instruments, plus an events/sec
    rate gauge over the sync-to-sync window — the dashboard's "is the
    telemetry spine keeping up" panel (events/s, mean batch occupancy,
    drain-queue depth).

    Pull-model like :class:`GovernorCollector`: one ``ingest_stats()``
    read per registry snapshot, zero cost on the publish path."""

    def __init__(self, registry: MetricsRegistry, bus,
                 time_fn: Optional[Callable[[], float]] = None):
        import time as _time

        self.registry = registry
        self.bus = bus
        self._now = time_fn or _time.monotonic
        self._events = registry.counter(
            "ingest_events_total", "events published through the bus")
        self._batches = registry.counter(
            "ingest_batches_total", "columnar chunks published")
        self._fallback = registry.counter(
            "ingest_fallback_events_total",
            "events delivered via the per-event legacy-subscriber loop")
        self._occupancy = registry.gauge(
            "ingest_batch_occupancy", "mean fill fraction of published chunks")
        self._rate = registry.gauge(
            "ingest_events_per_second", "bus event throughput, last window")
        self._queue = registry.gauge(
            "ingest_queue_depth", "chunks waiting for a drain()")
        self._queued_ev = registry.gauge(
            "ingest_queued_events", "events inside queued chunks")
        self._last_t: Optional[float] = None
        self._last_events = 0
        registry.add_collector(self.collect)

    def collect(self) -> dict:
        st = self.bus.ingest_stats()
        now = self._now()
        ev = st["events_total"]
        if self._last_t is not None:
            dt = now - self._last_t
            if dt > 0:
                self._rate.set((ev - self._last_events) / dt)
        self._last_t = now
        self._last_events = ev
        # counters are monotone: book the delta since the last sync
        for fam, key in ((self._events, "events_total"),
                         (self._batches, "batches_total"),
                         (self._fallback, "fallback_events_total")):
            child = fam.labels()
            delta = st[key] - child.value
            if delta:
                child.inc(delta)
        self._occupancy.set(st["mean_occupancy"])
        self._queue.set(st["queue_depth"])
        self._queued_ev.set(st["queued_events"])
        return st


class GovernorCollector:
    """Pull-model governor telemetry: snapshot polls into the registry,
    straggler/tuner state as gauges, and the exact cumulative report.

    ``collect()`` is the per-interval poll (driver report cadence, or the
    registry's own snapshot hook); ``report()`` is the end-of-run /
    per-snapshot exact ``GovernorReport`` — ``finalize()`` is O(in-flight)
    and non-destructive, so calling it per JSONL snapshot is free and
    guarantees the written cumulative slack/copy/overlap/energy match
    ``GovernorReport.to_dict()`` bit-for-bit.
    """

    def __init__(self, registry: MetricsRegistry, governor,
                 auto_collect: bool = True):
        self.registry = registry
        self.governor = governor
        g = registry
        self._slack = g.counter("governor_slack_seconds_total",
                                "slack booked by the governor")
        self._copy = g.counter("governor_copy_seconds_total",
                               "copy booked by the governor")
        self._overlap = g.counter("governor_overlap_seconds_total",
                                  "dispatch->wait overlap booked non-slack")
        self._exploited = g.counter("governor_exploited_seconds_total",
                                    "slack spent at f_min")
        self._e_base = g.counter("governor_energy_baseline_joules_total",
                                 "baseline energy during instrumented phases")
        self._e_pol = g.counter("governor_energy_policy_joules_total",
                                "energy under the policy's P-state trajectory")
        self._calls = g.counter("governor_calls_total", "phases retired")
        self._downs = g.counter("governor_downshifts_total",
                                "timeout-armed downshifts")
        self._acts = g.gauge("governor_actuations", "P-state commands booked")
        self._slack_ratio = g.gauge("governor_interval_slack_ratio",
                                    "slack / busy over the last interval")
        self._overlap_ratio = g.gauge("governor_interval_overlap_ratio",
                                      "overlap / busy over the last interval")
        self._expl_ratio = g.gauge("governor_interval_exploited_ratio",
                                   "exploited / busy over the last interval")
        self._saving = g.gauge("governor_energy_saving_pct",
                               "cumulative energy saving vs baseline")
        self._theta = g.gauge("governor_theta_seconds",
                              "tuner theta per site", ("site",))
        self._late = g.gauge("straggler_mean_lateness_seconds",
                             "per-rank mean barrier lateness", ("rank",))
        self._strag = g.gauge("straggler_z_score",
                              "z-score of flagged straggler ranks", ("rank",))
        if auto_collect:
            registry.add_collector(self.collect)

    def collect(self):
        """Poll one interval; returns the :class:`~repro.core.governor.
        IntervalStats` so drivers can reuse the poll they already make."""
        gov = self.governor
        stats = gov.interval_snapshot()
        self._slack.inc(stats.slack)
        self._copy.inc(stats.copy)
        self._overlap.inc(stats.overlap)
        self._exploited.inc(stats.exploited)
        self._e_base.inc(stats.energy_baseline)
        self._e_pol.inc(stats.energy_policy)
        self._calls.inc(stats.n_calls)
        self._downs.inc(stats.n_downshifts)
        self._acts.set(gov.n_actuations)
        busy = stats.busy
        self._slack_ratio.set(stats.slack / busy if busy > 0 else 0.0)
        self._overlap_ratio.set(stats.overlap_ratio)
        self._expl_ratio.set(stats.exploited_ratio)
        base = self._e_base.labels().value
        pol = self._e_pol.labels().value
        self._saving.set(100.0 * (1.0 - max(pol, 0.0) / base) if base > 0 else 0.0)
        if gov.tuner is not None:
            for site, theta in gov.tuner.summary().items():
                self._theta.labels(site).set(theta)
        detector = getattr(gov, "detector", None)
        if detector is not None:
            detector.export_metrics(self.registry)
        return stats

    def report(self):
        """The exact cumulative :class:`~repro.core.governor.GovernorReport`."""
        return self.governor.finalize()
