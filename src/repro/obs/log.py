"""Structured, leveled logging for the launch drivers.

The drivers historically reported through raw ``print()`` — fine on a
terminal, useless to anything that wants to parse a run (CI log scrapers,
the dashboard replayer, fleet aggregation).  This module is the smallest
structured replacement that keeps the human-readable shape:

* ``get_logger("train")`` returns a named :class:`ObsLogger` whose
  ``info``/``warning``/... methods take one *event* string plus keyword
  *fields* — the machine-readable payload.
* Text mode renders ``[train] event key=value ...`` (what the drivers
  printed by hand); ``--json-logs`` switches every record to one JSON
  object per line; ``--quiet`` raises the threshold to warnings.
* Configuration is ambient (one process = one driver run) and explicit:
  ``configure(...)`` or the shared argparse helpers ``add_flags`` /
  ``configure_from_args`` that every driver routes through.

Deliberately not :mod:`logging`: no handler graph, no global registry
mutation that could collide with a host application embedding the
library — records go straight to the configured stream.
"""
from __future__ import annotations

import json
import sys
import time
from typing import Any, Dict, Optional, TextIO

LEVELS = {"debug": 10, "info": 20, "warning": 30, "error": 40}

_CONFIG: Dict[str, Any] = {"level": LEVELS["info"], "json": False, "stream": None}


def configure(level: str = "info", json_logs: bool = False,
              stream: Optional[TextIO] = None) -> None:
    """Set the ambient log configuration (level threshold, format, stream).

    ``stream=None`` resolves to ``sys.stdout`` at emit time, so pytest's
    capsys and shell redirection both see the records.
    """
    if level not in LEVELS:
        raise ValueError(f"unknown log level {level!r}; one of {sorted(LEVELS)}")
    _CONFIG["level"] = LEVELS[level]
    _CONFIG["json"] = bool(json_logs)
    _CONFIG["stream"] = stream


def add_flags(parser) -> None:
    """Install the shared driver flags (``--quiet``, ``--json-logs``)."""
    parser.add_argument("--quiet", action="store_true",
                        help="only warnings and errors on stdout")
    parser.add_argument("--json-logs", action="store_true",
                        help="one JSON object per log line (machine-parseable)")


def configure_from_args(args) -> None:
    configure(level="warning" if getattr(args, "quiet", False) else "info",
              json_logs=getattr(args, "json_logs", False))


def _fmt_value(v: Any) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    if isinstance(v, str) and (" " in v or not v):
        return repr(v)
    return str(v)


class ObsLogger:
    """One named logger; see the module docstring for the record shapes."""

    __slots__ = ("name",)

    def __init__(self, name: str):
        self.name = name

    def _emit(self, level: str, event: str, fields: Dict[str, Any]) -> None:
        if LEVELS[level] < _CONFIG["level"]:
            return
        stream = _CONFIG["stream"] or sys.stdout
        if _CONFIG["json"]:
            rec = {"t": time.time(), "lvl": level, "logger": self.name,
                   "event": event}
            if fields:
                rec["fields"] = fields
            stream.write(json.dumps(rec, default=str) + "\n")
        else:
            parts = [f"[{self.name}]"]
            if level not in ("info", "debug"):
                parts.append(level.upper())
            parts.append(event)
            parts.extend(f"{k}={_fmt_value(v)}" for k, v in fields.items())
            stream.write(" ".join(parts) + "\n")
        stream.flush()

    def debug(self, event: str, **fields: Any) -> None:
        self._emit("debug", event, fields)

    def info(self, event: str, **fields: Any) -> None:
        self._emit("info", event, fields)

    def warning(self, event: str, **fields: Any) -> None:
        self._emit("warning", event, fields)

    def error(self, event: str, **fields: Any) -> None:
        self._emit("error", event, fields)


def get_logger(name: str) -> ObsLogger:
    return ObsLogger(name)
