"""``repro.cluster`` — the power-budget layer above per-job governors.

The paper prices slack *within* one job; this package prices it *between*
jobs sharing a facility cap (DESIGN.md §7):

``power``      node/rack roll-ups of per-rank power series and the
               RAPL-style :class:`PowerCapActuator` (enforcement latency
               + the pstate theta/hysteresis discipline).
``arbiter``    :class:`PowerBudgetArbiter` — AIMD watt redistribution
               under a fixed cluster cap, driven by per-job exploited-
               slack ratios; :class:`StaticEqualSplit` baseline.
``trace``      versioned JSONL :class:`TraceRecorder` for the governor's
               event stream; ``replay()`` reproduces a live run's report
               bit-for-bit, ``what_if()`` re-runs the measured phases
               through ``core.simulator`` under a different policy/cap.
``job``        :class:`ManagedJob` tenants: simulated (``SimJob``), live
               train (``GovernorJob``), live serve (``ServeJob``) — one
               slack/power report interface for the arbiter.
``coschedule`` heterogeneous multi-job scenario driver + canonical
               compute-bound / comm-bound / bursty-serve mixes.
"""
from repro.cluster.arbiter import JobSample, PowerBudgetArbiter, StaticEqualSplit  # noqa: F401
from repro.cluster.coschedule import (  # noqa: F401
    MIX_SPECS,
    CoScheduleResult,
    compare_disciplines,
    make_job,
    run_coschedule,
)
from repro.cluster.job import EpochReport, GovernorJob, ManagedJob, ServeJob, SimJob  # noqa: F401
from repro.cluster.power import (  # noqa: F401
    CapCommit,
    PowerCapActuator,
    aggregate_power,
    node_power_series,
    rack_power_series,
)
from repro.cluster.trace import (  # noqa: F401
    TRACE_VERSION,
    TraceRecorder,
    load,
    replay,
    to_workload,
    what_if,
)

__all__ = [
    "CapCommit",
    "CoScheduleResult",
    "EpochReport",
    "GovernorJob",
    "JobSample",
    "MIX_SPECS",
    "ManagedJob",
    "PowerBudgetArbiter",
    "PowerCapActuator",
    "ServeJob",
    "SimJob",
    "StaticEqualSplit",
    "TRACE_VERSION",
    "TraceRecorder",
    "aggregate_power",
    "compare_disciplines",
    "load",
    "make_job",
    "node_power_series",
    "rack_power_series",
    "replay",
    "run_coschedule",
    "to_workload",
    "what_if",
]
