"""Durable run traces: record the governor's event stream, replay offline.

The live runtime is ephemeral — phase events stream through the governor
and are gone.  :class:`TraceRecorder` makes the stream durable: a bounded
ring buffer of exactly the records the governor consumes (instrument
phase events, fully-formed ingested phases) plus the actuations it
emits, serialized as versioned JSONL.  Because the capture *is* the
governor's input, :func:`replay` pushes a recorded trace through a fresh
:class:`~repro.core.governor.Governor` and reproduces the live run's
slack/copy/energy totals bit-for-bit (tier-1 asserted), and
:func:`to_workload` lifts the same records into a
``core.simulator.Workload`` so :func:`what_if` can re-run the measured
phases under a different policy, HwModel, or power cap — the offline
what-if loop the cap arbiter is tuned against.

Record kinds (one JSON object per line; line 1 is the header):

  {"k": "hdr", "version": 3, "meta": {...}}
  {"k": "ev",    "rank": R, "phase": P, "call": C, "t": T}
  {"k": "phase", "rank": R, "call": C, "t0": .., "t1": .., "t2": .., "site": S?}
  {"k": "act",   "t": T, "rank": R, "action": A, "call": C, "slack": S}
  {"k": "theta", "t": T, "site": S, "rank": R, "before": .., "after": ..,
                 "reason": "decay"|"raise", "obs": ..}
  {"k": "pred",  "t": T, "site": S, "rank": R, "kind": "prearm"|"mispredict"
                 |"trip", "predicted": .., "observed": .., "cost": ..,
                 "source": "forest"|"ema"|""}

Version history: v1 was the 3-phase taxonomy without tuner records; v2 adds
the 5-phase events (``dispatch_enter``/``wait_enter``), the optional
``site`` on ingested phases, and ``theta`` tuner-decision records; v3 adds
``pred`` predictor-decision records (pre-arms, guard bookings, guard trips
from the cntd_predictive hybrid).  v1/v2 traces still load (each is a
strict subset of its successor).  ``theta``, ``act`` and ``pred`` records
are *outputs* of the live governor: replay re-derives all three, and the
differential tests assert the re-derived streams match the recorded ones.

Floats round-trip through ``repr`` so replay sees the identical bits.
"""
from __future__ import annotations

import collections
import json
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.events import EventBus, PhaseRecord
from repro.core.governor import Actuation, Governor, GovernorReport
from repro.core.policies import COUNTDOWN_SLACK, Policy
from repro.core.pstate import DEFAULT_HW, HwModel
from repro.core.simulator import SimResult, Workload, simulate
from repro.core.timeout import PredictorDecision, ThetaDecision, ThetaTuner

TRACE_VERSION = 3
SUPPORTED_VERSIONS = (1, 2, 3)


class TraceRecorder:
    """Ring-buffered, versioned capture of a governor's event stream.

    Speaks the canonical :mod:`repro.core.events` subscriber protocol
    (``on_event``/``on_phase``), so it attaches anywhere in the pipeline:
    via ``Governor(recorder=rec)`` (captures sink events, ingested phases,
    actuations, and tuner decisions) or directly on the instrument bus —
    ``instrument.get_event_bus().subscribe(rec)`` — for sink-less capture
    of the raw collective events.
    """

    def __init__(self, capacity: int = 1_000_000, meta: Optional[Dict] = None):
        self._buf: collections.deque = collections.deque(maxlen=capacity)
        self.capacity = capacity
        self.meta = dict(meta or {})
        self.n_seen = 0

    # ---- capture hooks (the events.py subscriber protocol) ---------------
    def on_event(self, rank: int, phase: str, call_id: int, t: float) -> None:
        self._append({"k": "ev", "rank": int(rank), "phase": phase,
                      "call": int(call_id), "t": float(t)})

    def on_phase(self, record: PhaseRecord) -> None:
        rec = {"k": "phase", "rank": int(record.rank), "call": int(record.call_id),
               "t0": float(record.t_enter), "t1": float(record.t_slack_end),
               "t2": float(record.t_copy_end)}
        if record.site is not None:
            rec["site"] = int(record.site)
        self._append(rec)

    def on_actuation(self, act: Actuation) -> None:
        self._append({"k": "act", "t": float(act.t), "rank": int(act.rank),
                      "action": act.action, "call": int(act.call_id),
                      "slack": float(act.slack)})

    def on_theta(self, dec: ThetaDecision) -> None:
        self._append({"k": "theta", "t": float(dec.t), "site": int(dec.site),
                      "rank": int(dec.rank), "before": float(dec.theta_before),
                      "after": float(dec.theta_after), "reason": dec.reason,
                      "obs": float(dec.slack)})

    def on_predictor(self, dec: PredictorDecision) -> None:
        self._append({"k": "pred", "t": float(dec.t), "site": int(dec.site),
                      "rank": int(dec.rank), "kind": dec.kind,
                      "predicted": float(dec.predicted),
                      "observed": float(dec.observed),
                      "cost": float(dec.cost), "source": dec.source})

    def _append(self, rec: Dict) -> None:
        self.n_seen += 1
        self._buf.append(rec)

    # ---- access / persistence -------------------------------------------
    @property
    def n_dropped(self) -> int:
        """Records evicted by the ring bound (oldest-first)."""
        return self.n_seen - len(self._buf)

    def records(self) -> List[Dict]:
        return list(self._buf)

    def save(self, path: str) -> str:
        header = {"k": "hdr", "version": TRACE_VERSION, "meta": self.meta,
                  "n_records": len(self._buf), "n_dropped": self.n_dropped}
        with open(path, "w") as f:
            f.write(json.dumps(header) + "\n")
            for rec in self._buf:
                f.write(json.dumps(rec) + "\n")
        return path


def load(path: str, allow_truncated: bool = False) -> Tuple[Dict, List[Dict]]:
    """(header, records) from a JSONL trace; rejects unknown versions.

    A trace whose ring buffer evicted records (``n_dropped > 0`` in the
    header) cannot replay faithfully — enter events may be missing their
    exits — so it is refused unless ``allow_truncated`` is passed.
    """
    with open(path) as f:
        lines = [ln for ln in f.read().splitlines() if ln.strip()]
    if not lines:
        raise ValueError(f"empty trace file: {path}")
    header = json.loads(lines[0])
    if header.get("k") != "hdr":
        raise ValueError(f"{path}: first record is {header.get('k')!r}, not a header")
    if header.get("version") not in SUPPORTED_VERSIONS:
        raise ValueError(
            f"{path}: trace version {header.get('version')!r} not in "
            f"{SUPPORTED_VERSIONS}"
        )
    if header.get("n_dropped", 0) > 0 and not allow_truncated:
        raise ValueError(
            f"{path}: ring buffer dropped {header['n_dropped']} records — the "
            f"stream is truncated and will not replay exactly; pass "
            f"allow_truncated=True to load anyway"
        )
    return header, [json.loads(ln) for ln in lines[1:]]


def replay(
    records: List[Dict],
    policy: Policy = COUNTDOWN_SLACK,
    hw: HwModel = DEFAULT_HW,
    governor: Optional[Governor] = None,
    tuner: Optional[ThetaTuner] = None,
) -> Tuple[Governor, GovernorReport]:
    """Feed a recorded stream through a (fresh) governor, in capture order.

    With the same policy/hw as the live run this reproduces its report
    exactly; with a different policy/theta it is the cheapest what-if.
    ``act``, ``theta`` and ``pred`` records are outputs of the live
    governor and are skipped — the replayed governor re-derives its own (a
    fresh tuner — predictive included: seeded, counter-triggered refits —
    is a pure function of the observation order, so an adaptive or
    predictive run replayed under the same policy reproduces the recorded
    decisions bit-for-bit; pass ``tuner`` to replay under different tuner
    settings —
    mutually exclusive with ``governor``, which carries its own).
    """
    if governor is not None and tuner is not None:
        raise ValueError("pass either governor= or tuner=, not both — a "
                         "provided governor already carries its tuner")
    gov = governor if governor is not None else Governor(policy=policy, hw=hw,
                                                         tuner=tuner)
    # a private bus with the governor subscribed: replay is just another
    # producer of the canonical stream (identical to the live path, so the
    # reproduced report is bit-for-bit)
    bus = EventBus()
    bus.subscribe(gov)
    for r in records:
        if r["k"] == "ev":
            bus.publish(r["rank"], r["phase"], r["call"], r["t"])
        elif r["k"] == "phase":
            bus.publish_phase(PhaseRecord(r["rank"], r["call"], r["t0"],
                                          r["t1"], r["t2"], r.get("site")))
    return gov, gov.finalize()


def to_workload(records: List[Dict], name: str = "replayed",
                beta_comp: float = 0.3, beta_copy: float = 0.15) -> Workload:
    """Lift recorded phases into a ``Workload`` the simulator can re-run.

    Occurrences are reconstructed with the governor's rotation rule (a
    rank re-entering a call id starts a new occurrence); per-rank compute
    is the gap from that rank's previous phase end to its barrier enter
    (a rank's first phase anchors to the occurrence's earliest enter), so
    the simulator's emergent barrier reproduces the recorded arrival
    pattern, and recorded copy durations become copy work at f_max.
    Collective slack therefore survives the lift exactly; single-rank
    ingested phases (serve underfill/idle) have no arrival imbalance to
    re-emerge from and contribute compute+copy only.

    Async (5-phase) occurrences lift their ``dispatch_enter -> wait_enter``
    window into ``Workload.overlap``: the rank "arrives" at dispatch, and
    the overlapped seconds are marked so the simulator accounts them as
    busy compute rather than exploitable slack.
    """
    # normalize both record kinds into per-occurrence
    # {rank: [t0, t1, t2, overlap]} (t0 = slack-window anchor, i.e. the
    # dispatch for async pairs; overlap = dispatch->wait seconds).  The
    # grouping key for the lifted Workload.site honors a recorded ``site``
    # override (serve meters mint a fresh call id per phase but tag a
    # stable site — without the override every phase would become its own
    # one-observation site and an adaptive what_if could never adapt)
    open_calls: Dict[int, Dict[int, List[float]]] = {}
    order: List[Tuple[Tuple[str, int], Dict[int, List[float]]]] = []
    for r in records:
        if r["k"] == "phase":
            key = ("site", r["site"]) if "site" in r else ("call", r["call"])
            order.append((key, {r["rank"]: [r["t0"], r["t1"], r["t2"], 0.0]}))
        elif r["k"] == "ev":
            rank, call = r["rank"], r["call"]
            occ = open_calls.get(call)
            if r["phase"] in ("barrier_enter", "dispatch_enter"):
                if occ is None or rank in occ:
                    occ = {}
                    open_calls[call] = occ
                    order.append((("call", call), occ))
                occ[rank] = [r["t"], r["t"], r["t"], 0.0]
            elif occ is not None and rank in occ:
                if r["phase"] == "wait_enter":
                    occ[rank][3] = max(r["t"] - occ[rank][0], 0.0)
                    occ[rank][1] = occ[rank][2] = r["t"]
                elif r["phase"] == "barrier_exit":
                    occ[rank][1] = occ[rank][2] = r["t"]
                elif r["phase"] == "copy_exit":
                    occ[rank][2] = r["t"]

    ranks = sorted({rk for _, occ in order for rk in occ})
    if not ranks:
        raise ValueError("trace contains no phase records")
    rank_pos = {rk: i for i, rk in enumerate(ranks)}
    n, t_tasks = len(ranks), len(order)
    comp = np.zeros((t_tasks, n))
    copy = np.zeros(t_tasks)
    copy_rank = np.zeros((t_tasks, n))
    overlap = np.zeros(t_tasks)
    site = np.zeros(t_tasks, np.int64)
    site_of: Dict[int, int] = {}
    prev_end = {rk: None for rk in ranks}
    for k, (key, occ) in enumerate(order):
        site[k] = site_of.setdefault(key, len(site_of))
        t_base = min(t0 for t0, _, _, _ in occ.values())
        for rk, (t0, t1, t2, ov) in occ.items():
            start = prev_end[rk] if prev_end[rk] is not None else t_base
            comp[k, rank_pos[rk]] = max(t0 - start, 0.0)
            prev_end[rk] = t2
            copy_rank[k, rank_pos[rk]] = max(t2 - t1, 0.0)
        copy[k] = float(np.mean([copy_rank[k, rank_pos[rk]] for rk in occ])) if occ else 0.0
        overlap[k] = float(np.mean([occ[rk][3] for rk in occ])) if occ else 0.0
    # per-rank copy durations survive through the jitter channel, so the
    # simulated phase ends match each recorded t2, not just the task mean
    with np.errstate(invalid="ignore", divide="ignore"):
        copy_jitter = np.where(copy[:, None] > 0, copy_rank / copy[:, None], 1.0)
    return Workload(
        name=name, n_ranks=n, comp=comp, copy=copy,
        is_p2p=np.zeros(t_tasks, bool), partner=np.zeros((t_tasks, n), np.int64),
        site=site, nbytes=np.zeros(t_tasks),
        beta_comp=beta_comp, beta_copy=beta_copy,
        copy_jitter=copy_jitter,
        overlap=overlap if overlap.any() else None,
    )


def what_if(
    records: List[Dict],
    policy: Policy,
    hw: HwModel = DEFAULT_HW,
    power_cap: Optional[float] = None,
    beta_comp: float = 0.3,
    beta_copy: float = 0.15,
    power_dt: Optional[float] = None,
) -> SimResult:
    """Re-run a recorded trace through ``core.simulator`` under a different
    policy and/or cap: the offline answer to "what would this run have
    cost under theta X / cap Y" without touching the cluster."""
    wl = to_workload(records, beta_comp=beta_comp, beta_copy=beta_copy)
    res, _ = simulate(wl, policy, hw, power_dt=power_dt, power_cap=power_cap)
    return res
