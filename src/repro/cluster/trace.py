"""Durable run traces: record the governor's event stream, replay offline.

The live runtime is ephemeral — phase events stream through the governor
and are gone.  :class:`TraceRecorder` makes the stream durable: a bounded
ring buffer of exactly the records the governor consumes (instrument
phase events, fully-formed ingested phases) plus the actuations it
emits, serialized as versioned JSONL.  Because the capture *is* the
governor's input, :func:`replay` pushes a recorded trace through a fresh
:class:`~repro.core.governor.Governor` and reproduces the live run's
slack/copy/energy totals bit-for-bit (tier-1 asserted), and
:func:`to_workload` lifts the same records into a
``core.simulator.Workload`` so :func:`what_if` can re-run the measured
phases under a different policy, HwModel, or power cap — the offline
what-if loop the cap arbiter is tuned against.

Record kinds (one JSON object per line; line 1 is the header):

  {"k": "hdr", "version": 1, "meta": {...}}
  {"k": "ev",    "rank": R, "phase": P, "call": C, "t": T}
  {"k": "phase", "rank": R, "call": C, "t0": .., "t1": .., "t2": ..}
  {"k": "act",   "t": T, "rank": R, "action": A, "call": C, "slack": S}

Floats round-trip through ``repr`` so replay sees the identical bits.
"""
from __future__ import annotations

import collections
import json
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.governor import Actuation, Governor, GovernorReport
from repro.core.policies import COUNTDOWN_SLACK, Policy
from repro.core.pstate import DEFAULT_HW, HwModel
from repro.core.simulator import SimResult, Workload, simulate

TRACE_VERSION = 1


class TraceRecorder:
    """Ring-buffered, versioned capture of a governor's event stream.

    Attach via ``Governor(recorder=rec)`` (captures sink events, ingested
    phases, and actuations) or ``instrument.set_event_tee(rec.on_event)``
    (sink-less capture of the raw collective events).
    """

    def __init__(self, capacity: int = 1_000_000, meta: Optional[Dict] = None):
        self._buf: collections.deque = collections.deque(maxlen=capacity)
        self.capacity = capacity
        self.meta = dict(meta or {})
        self.n_seen = 0

    # ---- capture hooks (the Governor's recorder interface) ---------------
    def on_event(self, rank: int, phase: str, call_id: int, t: float) -> None:
        self._append({"k": "ev", "rank": int(rank), "phase": phase,
                      "call": int(call_id), "t": float(t)})

    def on_phase(self, rank: int, call_id: int, t0: float, t1: float, t2: float) -> None:
        self._append({"k": "phase", "rank": int(rank), "call": int(call_id),
                      "t0": float(t0), "t1": float(t1), "t2": float(t2)})

    def on_actuation(self, act: Actuation) -> None:
        self._append({"k": "act", "t": float(act.t), "rank": int(act.rank),
                      "action": act.action, "call": int(act.call_id),
                      "slack": float(act.slack)})

    def _append(self, rec: Dict) -> None:
        self.n_seen += 1
        self._buf.append(rec)

    # ---- access / persistence -------------------------------------------
    @property
    def n_dropped(self) -> int:
        """Records evicted by the ring bound (oldest-first)."""
        return self.n_seen - len(self._buf)

    def records(self) -> List[Dict]:
        return list(self._buf)

    def save(self, path: str) -> str:
        header = {"k": "hdr", "version": TRACE_VERSION, "meta": self.meta,
                  "n_records": len(self._buf), "n_dropped": self.n_dropped}
        with open(path, "w") as f:
            f.write(json.dumps(header) + "\n")
            for rec in self._buf:
                f.write(json.dumps(rec) + "\n")
        return path


def load(path: str, allow_truncated: bool = False) -> Tuple[Dict, List[Dict]]:
    """(header, records) from a JSONL trace; rejects unknown versions.

    A trace whose ring buffer evicted records (``n_dropped > 0`` in the
    header) cannot replay faithfully — enter events may be missing their
    exits — so it is refused unless ``allow_truncated`` is passed.
    """
    with open(path) as f:
        lines = [ln for ln in f.read().splitlines() if ln.strip()]
    if not lines:
        raise ValueError(f"empty trace file: {path}")
    header = json.loads(lines[0])
    if header.get("k") != "hdr":
        raise ValueError(f"{path}: first record is {header.get('k')!r}, not a header")
    if header.get("version") != TRACE_VERSION:
        raise ValueError(
            f"{path}: trace version {header.get('version')!r} != {TRACE_VERSION}"
        )
    if header.get("n_dropped", 0) > 0 and not allow_truncated:
        raise ValueError(
            f"{path}: ring buffer dropped {header['n_dropped']} records — the "
            f"stream is truncated and will not replay exactly; pass "
            f"allow_truncated=True to load anyway"
        )
    return header, [json.loads(ln) for ln in lines[1:]]


def replay(
    records: List[Dict],
    policy: Policy = COUNTDOWN_SLACK,
    hw: HwModel = DEFAULT_HW,
    governor: Optional[Governor] = None,
) -> Tuple[Governor, GovernorReport]:
    """Feed a recorded stream through a (fresh) governor, in capture order.

    With the same policy/hw as the live run this reproduces its report
    exactly; with a different policy/theta it is the cheapest what-if.
    ``act`` records are outputs of the live governor and are skipped —
    the replayed governor re-derives its own.
    """
    gov = governor if governor is not None else Governor(policy=policy, hw=hw)
    for r in records:
        if r["k"] == "ev":
            gov.sink(r["rank"], r["phase"], r["call"], r["t"])
        elif r["k"] == "phase":
            gov.ingest_phase(r["rank"], r["call"], r["t0"], r["t1"], r["t2"])
    return gov, gov.finalize()


def to_workload(records: List[Dict], name: str = "replayed",
                beta_comp: float = 0.3, beta_copy: float = 0.15) -> Workload:
    """Lift recorded phases into a ``Workload`` the simulator can re-run.

    Occurrences are reconstructed with the governor's rotation rule (a
    rank re-entering a call id starts a new occurrence); per-rank compute
    is the gap from that rank's previous phase end to its barrier enter
    (a rank's first phase anchors to the occurrence's earliest enter), so
    the simulator's emergent barrier reproduces the recorded arrival
    pattern, and recorded copy durations become copy work at f_max.
    Collective slack therefore survives the lift exactly; single-rank
    ingested phases (serve underfill/idle) have no arrival imbalance to
    re-emerge from and contribute compute+copy only.
    """
    # normalize both record kinds into per-occurrence {rank: [t0, t1, t2]}
    open_calls: Dict[int, Dict[int, List[float]]] = {}
    order: List[Tuple[int, Dict[int, List[float]]]] = []
    for r in records:
        if r["k"] == "phase":
            order.append((r["call"], {r["rank"]: [r["t0"], r["t1"], r["t2"]]}))
        elif r["k"] == "ev":
            rank, call = r["rank"], r["call"]
            occ = open_calls.get(call)
            if r["phase"] == "barrier_enter":
                if occ is None or rank in occ:
                    occ = {}
                    open_calls[call] = occ
                    order.append((call, occ))
                occ[rank] = [r["t"], r["t"], r["t"]]
            elif occ is not None and rank in occ:
                if r["phase"] == "barrier_exit":
                    occ[rank][1] = occ[rank][2] = r["t"]
                elif r["phase"] == "copy_exit":
                    occ[rank][2] = r["t"]

    ranks = sorted({rk for _, occ in order for rk in occ})
    if not ranks:
        raise ValueError("trace contains no phase records")
    rank_pos = {rk: i for i, rk in enumerate(ranks)}
    n, t_tasks = len(ranks), len(order)
    comp = np.zeros((t_tasks, n))
    copy = np.zeros(t_tasks)
    copy_rank = np.zeros((t_tasks, n))
    site = np.zeros(t_tasks, np.int64)
    site_of: Dict[int, int] = {}
    prev_end = {rk: None for rk in ranks}
    for k, (call, occ) in enumerate(order):
        site[k] = site_of.setdefault(call, len(site_of))
        t_base = min(t0 for t0, _, _ in occ.values())
        for rk, (t0, t1, t2) in occ.items():
            start = prev_end[rk] if prev_end[rk] is not None else t_base
            comp[k, rank_pos[rk]] = max(t0 - start, 0.0)
            prev_end[rk] = t2
            copy_rank[k, rank_pos[rk]] = max(t2 - t1, 0.0)
        copy[k] = float(np.mean([copy_rank[k, rank_pos[rk]] for rk in occ])) if occ else 0.0
    # per-rank copy durations survive through the jitter channel, so the
    # simulated phase ends match each recorded t2, not just the task mean
    with np.errstate(invalid="ignore", divide="ignore"):
        copy_jitter = np.where(copy[:, None] > 0, copy_rank / copy[:, None], 1.0)
    return Workload(
        name=name, n_ranks=n, comp=comp, copy=copy,
        is_p2p=np.zeros(t_tasks, bool), partner=np.zeros((t_tasks, n), np.int64),
        site=site, nbytes=np.zeros(t_tasks),
        beta_comp=beta_comp, beta_copy=beta_copy,
        copy_jitter=copy_jitter,
    )


def what_if(
    records: List[Dict],
    policy: Policy,
    hw: HwModel = DEFAULT_HW,
    power_cap: Optional[float] = None,
    beta_comp: float = 0.3,
    beta_copy: float = 0.15,
    power_dt: Optional[float] = None,
) -> SimResult:
    """Re-run a recorded trace through ``core.simulator`` under a different
    policy and/or cap: the offline answer to "what would this run have
    cost under theta X / cap Y" without touching the cluster."""
    wl = to_workload(records, beta_comp=beta_comp, beta_copy=beta_copy)
    res, _ = simulate(wl, policy, hw, power_dt=power_dt, power_cap=power_cap)
    return res
