"""Node/rack power aggregation + the RAPL-style cap actuator.

The simulator and the live governor both price energy per *rank*; a
facility budget is enforced per *package* (node) and planned per *rack*.
This module rolls per-rank power series up that hierarchy and models the
one piece of physics the arbiter must respect: a cap command is not
instantaneous.  :class:`PowerCapActuator` commits a requested cap only
after ``latency`` seconds (the PCU/RAPL analogue of
``HwModel.switch_latency``) and applies the same theta discipline as the
``core.pstate`` timeout policies — ``theta_eff = theta + latency/2`` —
as a hysteresis window: requests that arrive inside it, or that move the
cap by less than the watt deadband, are suppressed rather than committed,
so a flapping arbiter cannot thrash the PCU faster than it can act.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from repro.core.pstate import DEFAULT_HW, HwModel


def aggregate_power(series: np.ndarray, group_size: int) -> np.ndarray:
    """Sum a per-rank power series into per-group watts.

    ``series`` is ``(n_bins, n_ranks)`` (``SimResult.power_series``);
    returns ``(n_bins, n_groups)`` with a ragged final group when
    ``n_ranks % group_size != 0``.
    """
    if group_size <= 0:
        raise ValueError(f"group_size must be positive, got {group_size}")
    series = np.asarray(series, np.float64)
    n_bins, n_ranks = series.shape
    n_groups = -(-n_ranks // group_size)
    padded = np.zeros((n_bins, n_groups * group_size))
    padded[:, :n_ranks] = series
    return padded.reshape(n_bins, n_groups, group_size).sum(axis=2)


def node_power_series(result, ranks_per_node: int) -> np.ndarray:
    """Per-node watts from a ``SimResult`` run with ``power_dt`` set."""
    if result.power_series is None:
        raise ValueError(
            f"SimResult {result.name!r} has no power series — "
            f"run simulate(..., power_dt=...) to collect one"
        )
    return aggregate_power(result.power_series, ranks_per_node)


def rack_power_series(node_series: np.ndarray, nodes_per_rack: int) -> np.ndarray:
    """Per-rack watts from a per-node series (one more roll-up level)."""
    return aggregate_power(node_series, nodes_per_rack)


@dataclass
class CapCommit:
    """One committed cap change (requests that survive the hysteresis)."""

    t_request: float
    t_commit: float              # t_request + enforcement latency
    watts: float


@dataclass
class PowerCapActuator:
    """RAPL-style package/cluster cap with enforcement latency + hysteresis.

    ``request(t, watts)`` schedules a cap change that takes effect at
    ``t + latency``.  Two suppression rules (the pstate theta logic, turned
    around): a request inside ``theta_eff`` of the previous accepted
    request is dropped (rate limit — the PCU quantizes commits), and a
    request that moves the cap by less than ``deadband_w`` is dropped
    (watt hysteresis).  ``cap_at(t)`` is the enforced cap an observer —
    the simulator's ``power_cap`` input, a live governor — sees at ``t``.
    """

    cap_w: float                             # initial enforced cap
    latency: float = DEFAULT_HW.switch_latency
    theta: float = 500e-6
    deadband_w: float = 1.0
    floor_w: float = 0.0
    commits: List[CapCommit] = field(default_factory=list)
    n_suppressed: int = 0

    def __post_init__(self):
        self.theta_eff = self.theta + 0.5 * self.latency
        self._t_last_accept: Optional[float] = None

    @property
    def target_w(self) -> float:
        """The most recently accepted cap (committed or still in flight)."""
        return self.commits[-1].watts if self.commits else self.cap_w

    def request(self, t: float, watts: float) -> bool:
        """Ask for a new cap; returns True iff a commit was scheduled."""
        watts = max(float(watts), self.floor_w)
        if abs(watts - self.target_w) < self.deadband_w:
            self.n_suppressed += 1
            return False
        if self._t_last_accept is not None and t - self._t_last_accept < self.theta_eff:
            self.n_suppressed += 1
            return False
        self._t_last_accept = t
        self.commits.append(CapCommit(t, t + self.latency, watts))
        return True

    def cap_at(self, t: float) -> float:
        """The cap actually enforced at time ``t`` (commit-latency aware)."""
        cap = self.cap_w
        for c in self.commits:
            if c.t_commit <= t:
                cap = c.watts
            else:
                break
        return cap

    def f_cap_at(self, t: float, n_ranks: int, hw: HwModel = DEFAULT_HW) -> float:
        """The frequency clamp the enforced cap implies for ``n_ranks``."""
        return float(hw.f_for_power(self.cap_at(t) / max(n_ranks, 1), hw.act_comp))
