"""Multi-job co-scheduling scenarios over the discrete-event simulator.

Evaluates the arbiter on heterogeneous mixes without a cluster: each
tenant is a :class:`~repro.cluster.job.SimJob` on its own nodes (jobs run
concurrently, so cluster makespan is the slowest tenant and cluster
energy is the sum), the arbiter re-splits the shared cap once per epoch.

Three canonical tenant flavors (the mixes the paper's story spans):

* ``compute_bound`` — EP-like: frequency-sensitive (high beta), almost no
  slack.  Every watt above its floor is progress; capping it costs
  makespan 1:1.
* ``comm_bound``    — FT/LU-like: low beta, large emergent slack.  Watts
  above the floor are mostly stranded in busy-waiting.
* ``bursty_serve``  — decode-shaped: low beta with heavy-tailed task
  scales (bursts + underfill lulls), the simulator-space image of the
  serve engine's idle/underfill profile.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.cluster.arbiter import PowerBudgetArbiter, StaticEqualSplit
from repro.cluster.job import EpochReport, SimJob
from repro.core.policies import COUNTDOWN_SLACK, Policy
from repro.core.pstate import DEFAULT_HW, HwModel
from repro.core.workloads import AppSpec, generate

# scenario specs: calibrated generators, scaled to co-scheduling size
MIX_SPECS: Dict[str, AppSpec] = {
    "compute_bound": AppSpec(
        "compute_bound", 8, 400, comp_mean=30e-3, slack_mean=0.4e-3,
        copy_mean=0.3e-3, beta_comp=0.95, beta_copy=0.15,
        sigma_noise=0.08, sigma_rank=0.03, sigma_task=0.10, n_sites=6,
    ),
    "comm_bound": AppSpec(
        "comm_bound", 8, 400, comp_mean=18e-3, slack_mean=9e-3,
        copy_mean=6e-3, beta_comp=0.15, beta_copy=0.10,
        sigma_noise=0.45, sigma_rank=0.20, sigma_task=0.5, n_sites=10,
    ),
    "bursty_serve": AppSpec(
        "bursty_serve", 8, 400, comp_mean=12e-3, slack_mean=14e-3,
        copy_mean=2e-3, beta_comp=0.15, beta_copy=0.10,
        sigma_noise=0.70, sigma_rank=0.10, sigma_task=1.2, site_sigma=1.5,
        n_sites=8,
    ),
}


def make_job(kind: str, job_id: Optional[str] = None, seed: int = 0,
             policy: Policy = COUNTDOWN_SLACK, hw: HwModel = DEFAULT_HW,
             tasks_per_epoch: int = 40, floor_w: float = 0.0,
             n_tasks: Optional[int] = None) -> SimJob:
    """One simulated tenant of the named flavor (see ``MIX_SPECS``)."""
    spec = MIX_SPECS[kind]
    if n_tasks is not None:
        spec = dataclasses.replace(spec, n_tasks=n_tasks)
    wl = generate(spec, seed=seed, hw=hw)
    return SimJob(job_id or kind, wl, policy=policy, hw=hw,
                  tasks_per_epoch=tasks_per_epoch, floor_w=floor_w)


@dataclass
class CoScheduleResult:
    """What a mix did under one arbitration discipline."""

    discipline: str
    cap_w: float
    makespan_s: float                 # slowest tenant (jobs run concurrently)
    energy_j: float                   # summed over tenants
    per_job: Dict[str, Dict[str, float]]
    allocations: List[Dict[str, float]] = field(default_factory=list)
    reports: Dict[str, List[EpochReport]] = field(default_factory=dict)

    def summary(self) -> Dict[str, object]:
        return {
            "discipline": self.discipline,
            "cap_w": self.cap_w,
            "makespan_s": self.makespan_s,
            "energy_j": self.energy_j,
            "per_job": self.per_job,
            "n_epochs": len(self.allocations),
        }


def run_coschedule(
    jobs: List[SimJob],
    cap_w: float,
    arbiter=None,
    max_epochs: int = 10_000,
    on_epoch: Optional[Callable[[int, Dict[str, float]], None]] = None,
) -> CoScheduleResult:
    """Drive a mix of tenants to completion under a shared cap.

    ``arbiter`` is anything with the ``step(samples) -> {job: watts}``
    contract — :class:`PowerBudgetArbiter` (default) or
    :class:`StaticEqualSplit` for the baseline discipline.  Each epoch
    every unfinished tenant runs one chunk under its current cap, then the
    arbiter re-splits based on the fresh samples.
    """
    if arbiter is None:
        arbiter = PowerBudgetArbiter(cap_w=cap_w, floor_w=0.0)
    alloc = arbiter.step([j.last_sample() for j in jobs])
    for epoch in range(max_epochs):
        running = [j for j in jobs if not j.done]
        if not running:
            break
        for job in running:
            job.run_epoch(alloc.get(job.job_id, 0.0))
        alloc = arbiter.step([j.last_sample() for j in jobs])
        if on_epoch is not None:
            on_epoch(epoch, alloc)
    else:
        raise RuntimeError(f"mix did not finish within {max_epochs} epochs")

    per_job = {
        j.job_id: {
            "wall_s": j.total_wall_s,
            "energy_j": j.total_energy_j,
            "mean_power_w": j.total_energy_j / max(j.total_wall_s, 1e-30),
            "n_epochs": len(j.reports),
            "cap_commits": len(j.actuator.commits),
            "cap_suppressed": j.actuator.n_suppressed,
        }
        for j in jobs
    }
    return CoScheduleResult(
        discipline=type(arbiter).__name__,
        cap_w=cap_w,
        makespan_s=max(j.total_wall_s for j in jobs),
        energy_j=sum(j.total_energy_j for j in jobs),
        per_job=per_job,
        allocations=list(getattr(arbiter, "history", [])),
        reports={j.job_id: j.reports for j in jobs},
    )


def compare_disciplines(
    job_factory: Callable[[], List[SimJob]],
    cap_w: float,
    floor_w: float = 0.0,
    **arbiter_kw,
) -> Dict[str, CoScheduleResult]:
    """Run the same mix under static equal-split and the slack arbiter.

    ``job_factory`` must build fresh tenants per call (they are stateful).
    """
    static = run_coschedule(
        job_factory(), cap_w, arbiter=StaticEqualSplit(cap_w=cap_w, floor_w=floor_w)
    )
    arbited = run_coschedule(
        job_factory(), cap_w,
        arbiter=PowerBudgetArbiter(cap_w=cap_w, floor_w=floor_w, **arbiter_kw),
    )
    return {"static": static, "arbiter": arbited}
