"""Slack-driven power-budget arbitration across concurrent jobs.

The paper saves energy *inside* one job by spending measured slack at the
minimum P-state; at the cluster the same signal prices *watts between
jobs*: a job whose governor reports a high exploited-slack ratio is
demonstrably not frequency-bound — watts allocated to it above its floor
are stranded — while a job reporting near-zero slack is on the critical
path and converts every extra watt into progress (Medhat et al., power
redistribution for MPI clusters).

:class:`PowerBudgetArbiter` redistributes a fixed cluster cap each epoch
with AIMD convergence:

* **multiplicative decrease** — a job above ``target_ratio`` releases a
  ``beta`` fraction of its headroom above the per-job floor;
* **additive increase** — the freed pool (plus any unallocated cap) is
  shared among below-target jobs proportional to their slack deficit, at
  most ``alpha_w`` watts per job per epoch (the AIMD probe step);
* departed jobs return their entire allocation to the pool; new jobs
  enter at the floor and climb additively.

Invariants, property-tested in ``tests/test_cluster.py``: the sum of
allocations never exceeds ``cap_w`` and no active job is ever below
``floor_w``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

_EPS = 1e-9


@dataclass
class JobSample:
    """One epoch of telemetry from a power-managed tenant.

    ``exploited_ratio`` comes from ``Governor.interval_snapshot()`` (live
    jobs) or ``SimResult.exploited / rank-time`` (simulated jobs);
    ``power_w`` is the measured average draw over the epoch.
    ``overlap_ratio`` (dispatch->wait compute hidden under flying
    collectives, per rank-second) separates an overlap-heavy job — whose
    in-barrier time is busy compute that converts watts to progress —
    from a slack-heavy one whose watts are stranded.  Telemetry today:
    ``exploited_ratio`` already excludes overlap (the governor never books
    it as slack), so allocation is overlap-honest; the explicit ratio
    lets operators and future policies see the split directly.
    """

    job_id: str
    power_w: float
    exploited_ratio: float
    done: bool = False
    overlap_ratio: float = 0.0
    # serving health (zero for non-serving tenants): the arbiter allocates
    # on slack alone, but the dashboard and the fleet autoscaler read SLO
    # attainment and prefix reuse off the same sample stream
    ttft_p50: float = 0.0
    ttft_p99: float = 0.0
    tpot_p50: float = 0.0
    tpot_p99: float = 0.0
    prefix_hits: int = 0
    prefix_lookups: int = 0
    prefix_hit_rate: float = 0.0


@dataclass
class PowerBudgetArbiter:
    cap_w: float
    floor_w: float
    target_ratio: float = 0.10        # slack ratio above which watts move away
    beta: float = 0.5                 # multiplicative-decrease factor
    alpha_w: float = 25.0             # additive-increase step (W/job/epoch)
    alloc: Dict[str, float] = field(default_factory=dict)
    history: List[Dict[str, float]] = field(default_factory=list)
    # observability hook: called with the new grants at the end of every
    # step (epoch index, {job: watts}) — the tracer/registry wire here
    grant_hook: Optional[Callable[[int, Dict[str, float]], None]] = None

    def allocations(self) -> Dict[str, float]:
        return dict(self.alloc)

    def export_metrics(self, registry) -> None:
        """Publish the current grants into a :class:`repro.obs.metrics.
        MetricsRegistry`: ``arbiter_grant_watts{job=...}`` plus the fixed
        cluster cap and the unallocated pool."""
        grants = registry.gauge("arbiter_grant_watts",
                                "watts granted per job", ("job",))
        for job, w in self.alloc.items():
            grants.labels(job).set(w)
        registry.gauge("arbiter_cap_watts", "cluster cap").set(self.cap_w)
        registry.gauge("arbiter_pool_watts", "unallocated watts").set(
            self.cap_w - sum(self.alloc.values()))
        registry.counter("arbiter_epochs_total", "arbitration epochs").labels() \
            .set(float(len(self.history)))

    def step(self, samples: List[JobSample]) -> Dict[str, float]:
        """One arbitration epoch: consume telemetry, return new caps."""
        active = [s for s in samples if not s.done]
        ids = [s.job_id for s in active]
        if len(set(ids)) != len(ids):
            raise ValueError(f"duplicate job ids in samples: {ids}")
        if len(active) * self.floor_w > self.cap_w + _EPS:
            raise ValueError(
                f"{len(active)} jobs x floor {self.floor_w} W exceeds "
                f"cluster cap {self.cap_w} W"
            )
        # departures free their watts; arrivals enter at the floor
        self.alloc = {j: self.alloc.get(j, self.floor_w) for j in ids}
        if not self.alloc:
            self.history.append({})
            if self.grant_hook is not None:
                self.grant_hook(len(self.history) - 1, {})
            return {}

        # multiplicative decrease: slack-rich jobs release headroom
        by_id = {s.job_id: s for s in active}
        for j in ids:
            if by_id[j].exploited_ratio > self.target_ratio:
                self.alloc[j] = self.floor_w + self.beta * (self.alloc[j] - self.floor_w)

        # additive increase from the freed pool, weighted by slack deficit
        pool = self.cap_w - sum(self.alloc.values())
        needy = [j for j in ids if by_id[j].exploited_ratio <= self.target_ratio]
        if pool > _EPS and needy:
            weights = {
                j: (self.target_ratio - by_id[j].exploited_ratio) + _EPS for j in needy
            }
            w_sum = sum(weights.values())
            for j in needy:
                give = min(self.alpha_w, pool * weights[j] / w_sum)
                self.alloc[j] += give

        # float-safety normalization: scale headroom above the floors down
        # if rounding pushed the sum past the cap (invariant, not policy)
        total = sum(self.alloc.values())
        if total > self.cap_w:
            head = total - len(ids) * self.floor_w
            budget = self.cap_w - len(ids) * self.floor_w
            scale = 0.0 if head <= _EPS else max(budget, 0.0) / head
            self.alloc = {
                j: self.floor_w + (a - self.floor_w) * scale for j, a in self.alloc.items()
            }

        self.history.append(dict(self.alloc))
        if self.grant_hook is not None:
            self.grant_hook(len(self.history) - 1, dict(self.alloc))
        return dict(self.alloc)


@dataclass
class StaticEqualSplit:
    """The baseline discipline: cap / n_jobs forever, no redistribution.

    Same ``step`` interface as :class:`PowerBudgetArbiter` so the
    co-schedule driver and benchmark can swap them.
    """

    cap_w: float
    floor_w: float = 0.0
    alloc: Dict[str, float] = field(default_factory=dict)
    history: List[Dict[str, float]] = field(default_factory=list)
    _n_initial: int = 0

    def step(self, samples: List[JobSample]) -> Dict[str, float]:
        active = [s for s in samples if not s.done]
        if self._n_initial == 0:
            self._n_initial = max(len(active), 1)
        # watts of finished jobs stay stranded: that is the point of static
        self.alloc = {s.job_id: self.cap_w / self._n_initial for s in active}
        self.history.append(dict(self.alloc))
        return dict(self.alloc)
