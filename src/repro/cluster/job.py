"""Power-managed tenants: one report interface over train, serve, and sim.

The arbiter does not care whether a tenant is an instrumented training
job (collective phase events through ``Governor.sink``), a continuous-
batching serve engine (decode underfill through ``ingest_phase``), or a
discrete-event simulation — it needs one epoch-granular contract:

    report = job.run_epoch(cap_w)       # run/observe one epoch under cap
    sample = job.last_sample()          # -> arbiter.JobSample

``exploited_ratio`` is normalized identically everywhere — exploited
f_min time over *total rank-time* (``n_ranks * epoch_wall``) — so a
compute-bound job whose tiny comm happens to be all-slack does not
masquerade as slack-rich.

Every tenant owns a :class:`~repro.cluster.power.PowerCapActuator`; the
arbiter's cap lands through ``actuator.request`` so enforcement latency
and hysteresis apply before the job sees the new budget (live tenants
log the commit exactly like the governor logs P-state writes).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import List, Optional

from repro.cluster.arbiter import JobSample
from repro.cluster.power import PowerCapActuator
from repro.core.governor import Governor
from repro.core.policies import COUNTDOWN_SLACK, Policy
from repro.core.pstate import DEFAULT_HW, HwModel
from repro.core.simulator import Workload, simulate


@dataclass
class EpochReport:
    """What one tenant did during one arbitration epoch."""

    job_id: str
    epoch: int
    cap_w: float                 # cap in force (post-actuator)
    wall_s: float                # epoch duration for this tenant
    energy_j: float
    power_w: float               # energy_j / wall_s
    exploited_ratio: float       # f_min time / (n_ranks * wall_s)
    n_calls: int
    done: bool
    overlap_ratio: float = 0.0   # dispatch->wait overlap / (n_ranks * wall_s)


class ManagedJob:
    """Base tenant: cap plumbing + sample bookkeeping; subclasses run."""

    def __init__(self, job_id: str, n_ranks: int, cap_w: float,
                 hw: HwModel = DEFAULT_HW, floor_w: float = 0.0):
        self.job_id = job_id
        self.n_ranks = n_ranks
        self.hw = hw
        self.actuator = PowerCapActuator(cap_w, latency=hw.switch_latency,
                                         floor_w=floor_w)
        self.reports: List[EpochReport] = []
        self.total_energy_j = 0.0
        self.total_wall_s = 0.0
        self._obs = None                 # (gauges dict, tracer) once attached

    def attach_obs(self, registry, tracer=None, clock=None) -> None:
        """Publish every booked epoch into a :class:`repro.obs.metrics.
        MetricsRegistry` (``job_*{job=...}`` series) and, when a
        :class:`repro.obs.tracer.SpanTracer` is given, sample cap/power
        counter tracks on its ``arbiter`` track.

        ``clock`` sets the trace time base: live tenants pass
        ``time.monotonic`` so arbiter samples line up with the bus's phase
        events; the default (tenant wall clock) is right for simulated
        tenants, whose events live on their own clock anyway."""
        jid = self.job_id
        gauges = {
            "cap": registry.gauge("job_cap_watts",
                                  "cap in force (post-actuator)",
                                  ("job",)).labels(jid),
            "power": registry.gauge("job_power_watts",
                                    "epoch average draw", ("job",)).labels(jid),
            "exploited": registry.gauge("job_exploited_ratio",
                                        "f_min time per rank-second",
                                        ("job",)).labels(jid),
            "overlap": registry.gauge("job_overlap_ratio",
                                      "dispatch->wait overlap per rank-second",
                                      ("job",)).labels(jid),
            "energy": registry.counter("job_energy_joules_total",
                                       "energy booked across epochs",
                                       ("job",)).labels(jid),
            "epochs": registry.counter("job_epochs_total",
                                       "arbitration epochs booked",
                                       ("job",)).labels(jid),
        }
        self._obs = (gauges, tracer, clock)

    def _book(self, rep: EpochReport) -> EpochReport:
        self.reports.append(rep)
        self.total_energy_j += rep.energy_j
        self.total_wall_s += rep.wall_s
        if self._obs is not None:
            gauges, tracer, clock = self._obs
            gauges["cap"].set(rep.cap_w)
            gauges["power"].set(rep.power_w)
            gauges["exploited"].set(rep.exploited_ratio)
            gauges["overlap"].set(rep.overlap_ratio)
            gauges["energy"].inc(rep.energy_j)
            gauges["epochs"].inc()
            if tracer is not None:
                t = clock() if clock is not None else self.total_wall_s
                tracer.sample("arbiter", f"cap_w[{self.job_id}]", t, rep.cap_w)
                tracer.sample("arbiter", f"power_w[{self.job_id}]", t,
                              rep.power_w)
        return rep

    @property
    def done(self) -> bool:
        return bool(self.reports) and self.reports[-1].done

    def last_sample(self) -> JobSample:
        if not self.reports:
            return JobSample(self.job_id, 0.0, 0.0)
        r = self.reports[-1]
        return JobSample(self.job_id, r.power_w, r.exploited_ratio, done=r.done,
                         overlap_ratio=r.overlap_ratio)

    def run_epoch(self, cap_w: float) -> EpochReport:
        raise NotImplementedError


class SimJob(ManagedJob):
    """Simulator-backed tenant: consumes its workload in task chunks, each
    chunk simulated under the enforced cap (``simulate(power_cap=...)``).
    The co-schedule driver and ``benchmarks/bench_cluster.py`` run on
    these."""

    def __init__(self, job_id: str, workload: Workload,
                 policy: Policy = COUNTDOWN_SLACK, hw: HwModel = DEFAULT_HW,
                 tasks_per_epoch: int = 50, cap_w: Optional[float] = None,
                 floor_w: float = 0.0):
        full = workload.n_ranks * hw.watts_at_fmax
        super().__init__(job_id, workload.n_ranks,
                         cap_w if cap_w is not None else full, hw, floor_w)
        self.workload = workload
        self.policy = policy
        self.tasks_per_epoch = tasks_per_epoch
        self._cursor = 0
        self._t = 0.0                       # this tenant's own clock

    def _chunk(self, k0: int, k1: int) -> Workload:
        wl = self.workload
        return Workload(
            name=f"{wl.name}[{k0}:{k1}]", n_ranks=wl.n_ranks,
            comp=wl.comp[k0:k1], copy=wl.copy[k0:k1], is_p2p=wl.is_p2p[k0:k1],
            partner=wl.partner[k0:k1], site=wl.site[k0:k1],
            nbytes=wl.nbytes[k0:k1], beta_comp=wl.beta_comp,
            beta_copy=wl.beta_copy,
            copy_jitter=None if wl.copy_jitter is None else wl.copy_jitter[k0:k1],
        )

    def run_epoch(self, cap_w: float) -> EpochReport:
        self.actuator.request(self._t, cap_w)
        cap = self.actuator.cap_at(self._t + self.actuator.latency)
        k0 = self._cursor
        k1 = min(k0 + self.tasks_per_epoch, self.workload.n_tasks)
        self._cursor = k1
        res, _ = simulate(self._chunk(k0, k1), self.policy, self.hw,
                          power_cap=cap)
        self._t += res.time
        rank_s = max(self.n_ranks * res.time, 1e-30)
        return self._book(EpochReport(
            job_id=self.job_id, epoch=len(self.reports), cap_w=cap,
            wall_s=res.time, energy_j=res.energy,
            power_w=res.energy / max(res.time, 1e-30),
            exploited_ratio=res.exploited / rank_s, n_calls=res.calls,
            done=self._cursor >= self.workload.n_tasks,
            overlap_ratio=res.toverlap / rank_s,
        ))


class GovernorJob(ManagedJob):
    """Live tenant over a :class:`Governor` — the train loop's collective
    events or any ``ingest_phase`` producer.  ``run_epoch`` does not drive
    the job (the loop runs elsewhere); it polls the governor's interval
    snapshot, so call it on the arbiter's cadence.

    The governor only *sees* instrumented phases, so epoch power is
    modeled, not measured: every rank draws compute power at f_max except
    during exploited slack, which draws f_min slack power — the same
    accounting ``finalize()`` applies inside phases, extended to the
    epoch.
    """

    def __init__(self, job_id: str, governor: Governor, n_ranks: int,
                 cap_w: float, hw: HwModel = DEFAULT_HW, floor_w: float = 0.0):
        super().__init__(job_id, n_ranks, cap_w, hw, floor_w)
        self.governor = governor
        self._t0 = time.monotonic()
        self._t_prev = self._t0
        self.finished = False            # owner flips when the loop exits

    def run_epoch(self, cap_w: float, stats=None) -> EpochReport:
        """Book one epoch.  ``stats`` (an :class:`~repro.core.governor.
        IntervalStats`) lets a caller that already polls the governor —
        e.g. a :class:`repro.obs.metrics.GovernorCollector` on the same
        cadence — hand its poll over instead of double-polling: the
        governor keeps a single snapshot mark, so two independent pollers
        would each see only half the interval stream."""
        now = time.monotonic()
        self.actuator.request(now - self._t0, cap_w)
        cap = self.actuator.cap_at(now - self._t0 + self.actuator.latency)
        dt = max(now - self._t_prev, 1e-9)
        self._t_prev = now
        if stats is None:
            stats = self.governor.interval_snapshot()
        hw = self.hw
        rank_s = self.n_ranks * dt
        exploited = min(stats.exploited, rank_s)
        energy = (
            hw.watts(hw.f_max, hw.act_comp) * (rank_s - exploited)
            + hw.watts(hw.f_min, hw.act_slack) * exploited
        )
        return self._book(EpochReport(
            job_id=self.job_id, epoch=len(self.reports), cap_w=cap,
            wall_s=dt, energy_j=float(energy),
            power_w=float(energy) / dt,
            exploited_ratio=exploited / rank_s, n_calls=stats.n_calls,
            done=self.finished,
            # IntervalStats now carries the overlap term instead of
            # discarding it: overlap-heavy != slack-heavy to the arbiter
            overlap_ratio=min(stats.overlap, rank_s) / rank_s,
        ))


class ServeJob(GovernorJob):
    """:class:`repro.serve.ContinuousEngine` as a tenant: the engine's
    :class:`DecodeSlackMeter` already books underfill/idle into the
    governor, so the snapshot path is identical; the engine is kept (duck-
    typed, no serve import) to surface decode fill in the report stream.

    Given an :class:`~repro.serve.slo.SLOTracker`, ``last_sample``
    additionally carries TTFT/TPOT percentiles and — when the engine has a
    prefix cache attached — prefix-hit counters, so the arbiter's sample
    stream shows serving *health*, not just watts and slack.
    """

    def __init__(self, job_id: str, engine, governor: Governor,
                 cap_w: float, n_ranks: int = 1,
                 hw: HwModel = DEFAULT_HW, floor_w: float = 0.0,
                 slo=None):
        super().__init__(job_id, governor, n_ranks, cap_w, hw, floor_w)
        self.engine = engine
        self.slo = slo

    @property
    def fill_fraction(self) -> float:
        meter = getattr(self.engine, "_last_meter", None)
        return meter.fill_fraction if meter is not None else 1.0

    def last_sample(self) -> JobSample:
        sample = super().last_sample()
        if self.slo is not None:
            s = self.slo.summary()
            sample.ttft_p50 = s["ttft"]["p50"]
            sample.ttft_p99 = s["ttft"]["p99"]
            sample.tpot_p50 = s["tpot"]["p50"]
            sample.tpot_p99 = s["tpot"]["p99"]
        cache = getattr(self.engine, "prefix_cache", None)
        if cache is not None:
            sample.prefix_hits = cache.n_hits
            sample.prefix_lookups = cache.n_lookups
            sample.prefix_hit_rate = cache.hit_rate
        return sample
