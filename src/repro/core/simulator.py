"""Vectorized discrete-event engine for multi-rank MPI-style execution.

Semantics follow the paper's execution model (Fig. 1): each rank alternates
Tcomp -> (blocking comm = Tslack + Tcopy).  Collectives synchronize the whole
communicator; P2P synchronizes pairs.  Slack is *emergent*: the barrier
resolves when the critical rank arrives.  Policies act through

  * the compute P-state (Andante/Adagio/MinFreq),
  * a timeout during the comm (Fermata/COUNTDOWN: slack+copy;
    COUNTDOWN Slack/Adagio: barrier-isolated slack only),
  * per-call fixed costs (stack hash for proactive policies, artificial
    barrier for COUNTDOWN Slack / Andante / Adagio, timer syscalls),
  * the PCU commit latency: a restore issued at slack end leaves the core
    pinned at f_min for up to ``switch_latency`` into the next phase —
    the engine carries this residue (``ell``) across phases.

Everything is vectorized over ranks; one python-level loop over tasks.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.events import EventBus
from repro.core.policies import Policy
from repro.core.pstate import DEFAULT_HW, HwModel

HASH_COST = 25e-6       # stack walk + hash + table lookup per MPI call (§6.4)
BARRIER_COST = 1.5e-6   # artificial MPI_Barrier / Isend+Wait pair latency
TIMER_COST = 0.5e-6     # setitimer syscall
PMU_COST = 15e-6        # Andante: per-region PMU reads + P-state computation


@dataclass
class Workload:
    """A generated multi-rank trace (base durations measured at f_max)."""

    name: str
    n_ranks: int
    comp: np.ndarray            # (T, N) compute work, f_max-seconds
    copy: np.ndarray            # (T,)   copy work, f_max-seconds
    is_p2p: np.ndarray          # (T,)   bool
    partner: np.ndarray         # (T, N) pair partner (valid where is_p2p)
    site: np.ndarray            # (T,)   call-site id ("stack hash")
    nbytes: np.ndarray          # (T,)   message payload bytes
    beta_comp: float = 0.3      # CPU-bound fraction of compute
    beta_copy: float = 0.15     # CPU-bound fraction of copy
    copy_jitter: Optional[np.ndarray] = None    # (T,N) per-rank copy factor
    overlap: Optional[np.ndarray] = None        # (T,) async dispatch->wait secs:
                                                # compute hidden under the flying
                                                # collective (non-slack)

    @property
    def n_tasks(self) -> int:
        return self.comp.shape[0]

    @property
    def n_sites(self) -> int:
        return int(self.site.max()) + 1


@dataclass
class SimResult:
    name: str
    time: float                 # wall time (s) = slowest rank
    energy: float               # watt-seconds, summed over ranks
    tcomp: float                # per-rank-summed phase seconds
    tslack: float
    tcopy: float
    exploited: float            # seconds spent at f_min inside comm phases
    exploited_slack: float      # ... restricted to slack
    calls: int
    power_dt: float = 0.0                           # bin width (s), 0 = off
    power_series: Optional[np.ndarray] = None       # (n_bins, n_ranks) watts
    toverlap: float = 0.0                           # overlap booked non-slack (s)
    theta_series: Optional[np.ndarray] = None       # (T,) theta_eff armed per task
    theta_bins: Optional[np.ndarray] = None         # (n_bins,) theta_eff active
                                                    # per power_dt bin
    n_prearm: int = 0                               # predictive pre-arms issued
    n_mispredict: int = 0                           # ... whose slack fell short
    n_guard_trips: int = 0                          # sites tripped to pure tuner
    t_dvfs_stretch: float = 0.0                     # per-rank-summed seconds of
    # busy-phase stretch induced by DVFS actions (pinned residue bleeding
    # into compute/copy, and comm-scope copies run below f_run) — the cost
    # the runtime's rho budget bounds against busy time

    def overhead_vs(self, base: "SimResult") -> float:
        return 100.0 * (self.time / base.time - 1.0)

    def dvfs_cost_pct(self) -> float:
        """DVFS-induced busy-time cost, percent — the quantity the paper's
        1% budget (``rho``) actually constrains: per-rank stretch seconds
        from downshift residue over per-rank busy seconds.  Unlike
        :meth:`overhead_vs`, barrier absorption cannot hide it — a rank's
        stretch counts even when another rank's wait swallows it."""
        busy = self.tcomp + self.tslack + self.tcopy
        return 100.0 * self.t_dvfs_stretch / busy if busy > 0 else 0.0

    def energy_saving_vs(self, base: "SimResult") -> float:
        return 100.0 * (1.0 - self.energy / base.energy)

    def power_saving_vs(self, base: "SimResult") -> float:
        p_self = self.energy / self.time
        p_base = base.energy / base.time
        return 100.0 * (1.0 - p_self / p_base)


@dataclass
class TraceRecord:
    """Per-(task, rank) baseline trace for analysis / ML (paper §6.2)."""

    site: np.ndarray            # (T,)
    is_p2p: np.ndarray          # (T,)
    nbytes: np.ndarray          # (T,)
    comp: np.ndarray            # (T, N) realized durations at f_max
    slack: np.ndarray           # (T, N)
    copy: np.ndarray            # (T, N)
    partner: Optional[np.ndarray] = None    # (T, N) p2p pair partner — feeds
    # the locality feature (node distance of the pair) in predictor.py


def _phase(hw: HwModel, work, beta, f, ell, activity):
    """Run ``work`` f_max-seconds of work at frequency ``f`` with the first
    ``ell`` seconds pinned at f_min.  Returns (duration, energy, ell_left)."""
    work = np.asarray(work, dtype=np.float64)
    slow_min = hw.slowdown(hw.f_min, beta)
    slow_f = hw.slowdown(f, beta)
    w_pin = ell / slow_min                              # work done while pinned
    full_pin = w_pin >= work
    dur = np.where(full_pin, work * slow_min, ell + (work - w_pin) * slow_f)
    ell_left = np.where(full_pin, ell - work * slow_min, 0.0)
    t_min = np.minimum(ell, dur)
    energy = hw.watts(hw.f_min, activity) * t_min + hw.watts(f, activity) * np.maximum(
        dur - t_min, 0.0
    )
    return dur, energy, ell_left


def _two_rate_phase(hw: HwModel, work, beta, t_hi, f_hi, activity):
    """Work at ``f_hi`` for up to ``t_hi`` seconds, then f_min until done."""
    work = np.asarray(work, dtype=np.float64)
    t_hi = np.minimum(t_hi, 1e30)                       # keep inf out of arithmetic
    slow_hi = hw.slowdown(f_hi, beta)
    slow_min = hw.slowdown(hw.f_min, beta)
    w_hi = t_hi / slow_hi
    fits = w_hi >= work
    dur = np.where(fits, work * slow_hi, t_hi + (work - w_hi) * slow_min)
    t_at_hi = np.minimum(dur, t_hi)
    t_at_min = np.maximum(dur - t_hi, 0.0)
    energy = hw.watts(f_hi, activity) * t_at_hi + hw.watts(hw.f_min, activity) * t_at_min
    return dur, energy, t_at_min


def _bin_energy(series: np.ndarray, dt: float, t0, dur, e) -> None:
    """Deposit per-rank phase energies uniformly over their time spans into
    ``series`` (n_bins, n_ranks) watt bins.  Vectorized for the common case
    (phase inside one bin); only bin-spanning ranks take the python path."""
    n_bins = series.shape[0]
    t0 = np.asarray(t0, np.float64)
    dur = np.maximum(np.asarray(dur, np.float64), 0.0)
    e = np.asarray(e, np.float64)
    b0 = np.clip((t0 / dt).astype(np.int64), 0, n_bins - 1)
    b1 = np.clip(((t0 + dur) / dt).astype(np.int64), 0, n_bins - 1)
    same = b0 == b1
    idx = np.arange(series.shape[1])
    np.add.at(series, (b0[same], idx[same]), e[same] / dt)
    for r in np.nonzero(~same)[0]:
        bins = np.arange(b0[r], b1[r] + 1)
        lo = np.maximum(bins * dt, t0[r])
        hi = np.minimum((bins + 1) * dt, t0[r] + dur[r])
        series[bins, r] += e[r] * np.clip(hi - lo, 0.0, None) / dur[r] / dt


def simulate(
    wl: Workload,
    pol: Policy,
    hw: HwModel = DEFAULT_HW,
    collect_trace: bool = False,
    power_dt: Optional[float] = None,
    power_cap: Optional[float] = None,
    overlap_aware: bool = True,
    bus: Optional[EventBus] = None,
    ingest: str = "event",
) -> Tuple[SimResult, Optional[TraceRecord]]:
    """Run ``wl`` under ``pol``.

    ``power_dt`` turns on the per-interval power series: phase energies are
    binned into ``power_dt``-second buckets per rank and returned on
    ``SimResult.power_series`` (the cluster layer aggregates these into
    node/rack watts — DESIGN.md §7).

    ``power_cap`` is the external cap input in aggregate watts over this
    workload's ranks: the RAPL semantics, enforced by clamping every
    frequency the policy would choose to ``hw.f_for_power(cap / n_ranks)``
    (inverted at compute activity, the worst case).

    ``overlap_aware`` governs how ``Workload.overlap`` (async dispatch->wait
    compute hidden under a flying collective) is accounted.  Aware (the
    5-phase taxonomy, default): overlapped seconds are busy compute — priced
    at compute activity, excluded from slack, never downshifted.  Unaware
    (the legacy 3-phase view, for contrast): the whole in-barrier window
    counts as slack, so the timeout can pin the core *while it is computing*
    — the pinned overlap stalls the hidden compute and the rank pays the
    lost work back after the barrier (the "misprediction jeopardizes the
    benefit" failure mode, measurable).

    ``theta_mode="adaptive"`` policies run an online
    :class:`~repro.core.timeout.ThetaTuner`: theta for task ``k`` is the
    tuner's per-site value armed *before* observing task ``k`` (same
    causality as the live governor).  The per-task thresholds come back on
    ``SimResult.theta_series`` (and, with ``power_dt``, resampled onto the
    power bins as ``theta_bins``).

    ``bus`` makes the simulator a producer of the canonical event stream
    (:mod:`repro.core.events`): each task's realized per-rank phases are
    published as 5-phase events (``dispatch_enter``/``wait_enter`` for
    overlapped tasks, ``barrier_enter`` otherwise, then ``barrier_exit``
    and ``copy_exit``) with the task's *site* as the recurring call id, so
    a live :class:`~repro.core.governor.Governor`, a trace recorder, or
    any other subscriber consumes simulated runs through exactly the
    pipeline the instrumented collectives feed.  Zero cost when ``None``.

    ``ingest`` selects the production path when ``bus`` is set: ``"event"``
    publishes one call per event (the legacy path); ``"batched"`` buffers
    each task's per-rank phase columns in a :class:`~repro.core.events.
    BatchAccumulator` and publishes full columnar chunks through
    ``publish_batch`` — the same events in the same stream order, so any
    subscriber sees an identical stream either way (the batched-ingest
    equivalence suite holds the governor to bit-for-bit on this).
    """
    if ingest not in ("event", "batched"):
        raise ValueError(ingest)
    n, t_tasks = wl.n_ranks, wl.n_tasks
    fmax, fmin, lat = hw.f_max, hw.f_min, hw.switch_latency
    grid = hw.pstates()
    # `is not None`, not truthiness: a 0 W cap means "pin to f_min" (the
    # inverse maps it there), the opposite of uncapped
    f_cap = float(hw.f_for_power(power_cap / n, hw.act_comp)) if power_cap is not None else fmax
    f_run = min(fmax, f_cap)                            # capped "full speed"

    t = np.zeros(n)
    ell = np.zeros(n)                                   # pinned-at-fmin residue
    energy = np.zeros(n)
    tcomp = tslack = tcopy = 0.0
    exploited = exploited_slack = toverlap = 0.0
    t_stretch = 0.0              # DVFS-induced busy stretch (rho's denominator
    #                              is busy time; barriers cannot absorb this)

    tuner = None
    hybrid = None                # PredictiveTuner view of tuner, when predictive
    if pol.theta_mode == "adaptive" and pol.comm_mode == "timeout":
        from repro.core.timeout import ThetaTuner   # deferred: keeps import light

        tuner = ThetaTuner(hw=hw, theta0=pol.theta)
    elif pol.theta_mode in ("predictive", "predict_only") and pol.comm_mode == "timeout":
        from repro.core.timeout import PredictiveTuner

        # predict_only is the paper's prediction-only strawman: pre-arm on
        # ANY predicted slack, with no reactive fallback, no guard, and no
        # arm bar (PredictiveTuner zeroes the bar for that configuration)
        _hyb = pol.theta_mode == "predictive"
        tuner = hybrid = PredictiveTuner(
            hw=hw, theta0=pol.theta, reactive=_hyb, guarded=_hyb,
        )
    arm_eff = hw.theta_eff(0.0)  # a pre-armed downshift waits only for the
    # PCU commit quantization, not for any timer
    theta_series = np.full(t_tasks, np.nan)
    t_arm = np.zeros(t_tasks)                           # theta arm time per task

    # per-site last-value tables
    n_sites = wl.n_sites
    last_comm = np.full((n_sites, n), np.nan)           # fermata
    last_comp = np.full((n_sites, n), np.nan)           # andante (work units)
    last_slack = np.full((n_sites, n), np.nan)

    trace_comp = np.zeros((t_tasks, n)) if collect_trace else None
    trace_slack = np.zeros((t_tasks, n)) if collect_trace else None
    trace_copy = np.zeros((t_tasks, n)) if collect_trace else None

    # (start, duration, energy) per-rank segments for the power series
    segs: List[Tuple[np.ndarray, np.ndarray, np.ndarray]] = []

    acc = None
    ranks_col = None
    if bus is not None and ingest == "batched":
        from repro.core.events import BatchAccumulator

        acc = BatchAccumulator(max(65536, n))
        ranks_col = np.arange(n, dtype=np.int32)

        def push_phase(code: int, times: np.ndarray) -> None:
            if acc.free < n:
                bus.publish_batch(acc.flush())
            acc.extend(ranks_col, np.full(n, code, dtype=np.int8),
                       np.full(n, site, dtype=np.int64),
                       np.asarray(times, dtype=np.float64))

    for k in range(t_tasks):
        site = int(wl.site[k])
        work = wl.comp[k].astype(np.float64).copy()

        # ---- per-call fixed costs (CPU work at current frequency) ----
        if pol.uses_hash:
            work = work + HASH_COST
        if pol.uses_barrier:
            work = work + BARRIER_COST
        if pol.comm_mode in ("timeout", "predict_timeout"):
            work = work + TIMER_COST
        if pol.compute_mode == "andante":
            work = work + PMU_COST

        # ---- compute P-state ----
        if pol.compute_mode == "max":
            f_comp = np.full(n, fmax)
        elif pol.compute_mode == "min":
            f_comp = np.full(n, fmin)
        else:                                           # andante
            pred_w = last_comp[site]
            pred_s = last_slack[site]
            have = ~np.isnan(pred_w) & ~np.isnan(pred_s) & (pred_w > 0)
            # lowest f with W*slow(f) <= W + S  ->  f >= fmax / (1 + S/(W*beta))
            with np.errstate(divide="ignore", invalid="ignore"):
                f_req = fmax / (1.0 + pred_s / (pred_w * max(wl.beta_comp, 1e-9)))
            idx = np.searchsorted(grid, np.nan_to_num(f_req, nan=fmax))
            idx = np.clip(idx, 0, len(grid) - 1)
            f_comp = np.where(have, grid[idx], fmax)
        f_comp = np.minimum(f_comp, f_run)              # external cap clamp

        d_comp, e_comp, ell = _phase(hw, work, wl.beta_comp, f_comp, ell, hw.act_comp)
        # residue-free counterfactual is closed-form: work at f_comp
        t_stretch += float(np.sum(d_comp - work * hw.slowdown(f_comp, wl.beta_comp)))
        energy += e_comp
        tcomp += float(d_comp.sum())
        if power_dt:
            segs.append((t.copy(), d_comp, e_comp))
        arrival = t + d_comp

        # ---- barrier resolution ----
        if wl.is_p2p[k]:
            partner = wl.partner[k]
            t_bar = np.maximum(arrival, arrival[partner])
        else:
            t_bar = np.full(n, arrival.max())
        slack = t_bar - arrival

        # ---- overlap isolation (5-phase accounting) ----
        # dispatch->wait: EVERY rank (critical one included) computes ov_k
        # seconds under the flying collective before blocking on the wait,
        # so the barrier resolves ov_k later and per-rank slack is
        # unchanged — the overlap must not be clamped by emergent slack or
        # the critical rank's overlapped compute would vanish from time,
        # energy and toverlap
        ov_k = float(wl.overlap[k]) if wl.overlap is not None else 0.0
        if ov_k > 0.0:
            ov = np.full(n, ov_k)
            t_bar = t_bar + ov_k
            if overlap_aware:
                window = slack                          # t_bar - (arrival + ov)
                window_start = arrival + ov
                e_ov = hw.watts(f_comp, hw.act_comp) * ov
                energy += e_ov
                if power_dt:
                    segs.append((arrival, ov, e_ov))
                toverlap += float(ov.sum())
            else:
                # 3-phase view: slack measured from dispatch — inflated by
                # the busy overlap, which the timeout may then pin (energy
                # for the overlap span is priced below, once the pinned
                # split is known)
                window = slack + ov
                window_start = arrival
        else:
            ov = None
            window = slack
            window_start = arrival
        tslack += float(window.sum())

        # ---- per-task theta: the policy constant, or the tuner's value
        # armed before this task's slack is observed (online causality) ----
        theta_k = tuner.theta_for(site) if tuner is not None else pol.theta
        theta_eff = hw.theta_eff(theta_k)               # + PCU commit quantization
        if pol.comm_mode in ("timeout", "predict_timeout"):
            theta_series[k] = theta_eff
        t_arm[k] = float(arrival.min())

        # ---- slack trajectory ----
        preds = prearm = None
        if pol.comm_mode == "pin_min":                  # minfreq: already low
            armed = np.zeros(n, dtype=bool)
            t_hi = np.zeros(n)
            f_slack_hi = np.full(n, fmin)
        elif pol.comm_mode == "timeout":
            armed = np.ones(n, dtype=bool)
            if hybrid is not None:
                # pre-arm decision BEFORE this task's slack is observed
                # (same causality as the live governor's decide())
                preds, pred_src = hybrid.predict_ranks(site, n)
                prearm = hybrid.arm_mask(site, preds)
                hi_armed = np.minimum(window, arm_eff)
                if hybrid.reactive:                     # hybrid: timeout fallback
                    t_hi = np.where(prearm, hi_armed, np.minimum(window, theta_eff))
                else:                                   # prediction-only strawman
                    t_hi = np.where(prearm, hi_armed, window)
            else:
                t_hi = np.minimum(window, theta_eff)
            f_slack_hi = f_comp
        elif pol.comm_mode == "predict_timeout":        # fermata
            armed = np.nan_to_num(last_comm[site], nan=0.0) >= 2.0 * theta_k
            t_hi = np.where(armed, np.minimum(window, theta_eff), window)
            f_slack_hi = f_comp
        else:                                           # none
            armed = np.zeros(n, dtype=bool)
            t_hi = window
            f_slack_hi = f_comp
        t_lo = window - t_hi
        fired = t_lo > 0            # downshift engaged within the window
        # PCU serialization: the restore issued at slack end completes one
        # switch latency after the in-flight down leg commits, pinning the
        # next phase for max(lat, 2*lat - window).  Timer paths always have
        # window >= theta_eff >= lat when they fire (the down leg committed
        # long before the restore), which leaves the residue at lat — only
        # pre-armed short slacks pay the early-restore penalty
        resid = np.maximum(lat, 2.0 * lat - window)
        if prearm is not None:
            # a pre-armed rank issues the P-state command at comm entry
            # even if the slack ends mid-transition — the residue applies
            # regardless of whether t_lo ever opened
            fired = fired | prearm
        if ov is not None and not overlap_aware:
            # unaware contrast: the window's head is busy overlap, not idle.
            # The timer cannot tell: past theta_eff it pins the core WHILE
            # IT COMPUTES — the pinned overlap runs compute at f_min and
            # the lost work is paid back after the barrier (delaying this
            # rank); only the idle tail is true slack-activity time
            pinned_ov = np.maximum(ov - t_hi, 0.0)
            e_ov = hw.watts(f_comp, hw.act_comp) * (ov - pinned_ov)
            e_ov = e_ov + hw.watts(fmin, hw.act_comp) * pinned_ov
            energy += e_ov
            if power_dt:
                segs.append((arrival, ov, e_ov))
            t_hi_idle = np.maximum(t_hi - ov, 0.0)
            e_slack = hw.watts(f_slack_hi, hw.act_slack) * t_hi_idle
            e_slack = e_slack + hw.watts(fmin, hw.act_slack) * (slack - t_hi_idle)
            seg_start, seg_dur = arrival + ov, slack
            penalty = pinned_ov * (hw.slowdown(fmin, wl.beta_comp) - 1.0)
            e_pen = hw.watts(f_run, hw.act_comp) * penalty
            energy += e_pen
            # the payback window sits AFTER the copy phase — its power
            # series segment is appended once d_copy is known, so the bins
            # around t_bar don't stack copy + payback watts while the real
            # payback window reads zero
        else:
            e_slack = hw.watts(f_slack_hi, hw.act_slack) * t_hi
            e_slack = e_slack + hw.watts(fmin, hw.act_slack) * t_lo
            seg_start, seg_dur = window_start, window
            penalty = 0.0
            e_pen = None
        energy += e_slack
        if power_dt:
            segs.append((seg_start, seg_dur, e_slack))
        exploited += float(t_lo.sum())
        exploited_slack += float(t_lo.sum())
        if pol.comm_mode == "pin_min":
            exploited += float(window.sum())
            exploited_slack += float(window.sum())

        if tuner is not None:
            # busy denominator must match the live governor's: its comp gap
            # (enter minus previous phase end) spans the dispatch->wait
            # overlap, so count ov here too (unaware mode already carries
            # it inside the inflated window)
            comp_obs = d_comp + ov if (ov is not None and overlap_aware) else d_comp
            tuner.observe_slack_batch(site, window, t=float(t_bar.max()),
                                      comp=comp_obs)
            if hybrid is not None and prearm is not None:
                # guard bookings (c_down per mispredicted pre-arm) + the
                # predictor's training rows for this task
                hybrid.account_outcome_batch(site, preds, window, prearm,
                                             t=float(t_bar.max()),
                                             source=pred_src, comp=comp_obs)

        # ---- copy phase ----
        wc = float(wl.copy[k])
        jit = wl.copy_jitter[k] if wl.copy_jitter is not None else 1.0
        if wc > 0.0:
            wc_r = np.full(n, wc) * jit
            if pol.comm_mode == "pin_min":
                d_copy, e_copy, _ = _phase(
                    hw, wc_r, wl.beta_copy, np.full(n, fmin),
                    np.zeros(n), hw.act_copy,
                )
                t_min_in_copy = d_copy
            elif pol.comm_mode in ("timeout", "predict_timeout") and pol.comm_scope == "comm":
                # timer keeps running inside the MPI call: after theta_eff
                # total in-call time, frequency drops; copy may start below it
                if prearm is not None:
                    # pre-armed ranks committed the downshift at entry
                    # (effective after the arm quantization); the rest
                    # follow the reactive timer, or never fire for the
                    # prediction-only strawman
                    fallback = theta_eff if hybrid.reactive else np.inf
                    t_to_fire = np.maximum(
                        np.where(prearm, arm_eff, fallback) - window, 0.0
                    )
                else:
                    t_to_fire = np.where(armed, np.maximum(theta_eff - window, 0.0), np.inf)
                d_copy, e_copy, t_min_in_copy = _two_rate_phase(
                    hw, wc_r, wl.beta_copy, t_to_fire, f_run, hw.act_copy
                )
                # restore at MPI exit pins the next phase start at f_min
                ell = np.where(t_min_in_copy > 0, lat, ell)
            else:
                # slack scope: frequency restored at barrier exit; commit
                # latency pins the start of the copy at f_min
                ell = np.where(fired, resid, ell)
                d_copy, e_copy, ell = _phase(
                    hw, wc_r, wl.beta_copy, np.full(n, f_run),
                    ell, hw.act_copy,
                )
                t_min_in_copy = np.minimum(d_copy, np.where(fired, resid, 0.0))
            energy += e_copy
            tcopy += float(d_copy.sum())
            # any copy time beyond the full-speed copy is DVFS-induced
            # (residue bleed in slack scope, deliberate in comm scope)
            t_stretch += float(np.sum(d_copy - wc_r * hw.slowdown(f_run, wl.beta_copy)))
            if power_dt:
                segs.append((t_bar, d_copy, e_copy))
            exploited += float(np.sum(t_min_in_copy))
            t = t_bar + d_copy + penalty
            if power_dt and e_pen is not None:
                segs.append((t_bar + d_copy, penalty, e_pen))
            if tuner is not None:
                # feedback: realized copy slowdown of this task's downshifted
                # ranks vs the residue-free copy (known exactly offline, the
                # EMA estimate live) — the AIMD raise trigger
                base_copy = wc_r * hw.slowdown(f_run, wl.beta_copy)
                pinned = t_lo > 0
                extra = frac = 0.0
                if pinned.any():
                    extra = float(np.max(d_copy[pinned] - base_copy[pinned]))
                    frac = float(np.max(
                        d_copy[pinned] / np.maximum(base_copy[pinned], 1e-30) - 1.0
                    ))
                tuner.observe_copy_slowdown(site, float(d_copy.sum()), extra,
                                            frac, t=float(t.max()))
                if hybrid is not None:
                    hybrid.predictor.note_copy_ranks(site, d_copy)
                    if prearm is not None and prearm.any():
                        # stretch on ranks ONLY the pre-arm downshifted
                        # (reactive theta would not have fired) is
                        # misprediction cost — book it to the guard
                        mis = prearm & (window < theta_eff)
                        if mis.any():
                            extras = d_copy[mis] - base_copy[mis]
                            fracs = (d_copy[mis]
                                     / np.maximum(base_copy[mis], 1e-30) - 1.0)
                            hybrid.guard_copy_batch(site, extras, fracs,
                                                    t=float(t.max()))
        else:
            # pure synchronization primitive: restore pins next compute
            if pol.comm_scope == "slack" or pol.comm_mode in ("timeout", "predict_timeout"):
                ell = np.where(fired, resid, ell)
            t = t_bar + penalty
            if power_dt and e_pen is not None:
                segs.append((t_bar, penalty, e_pen))

        # ---- synthetic event production (the canonical vocabulary) ----
        if bus is not None:
            # the site is the recurring call id, so a governor subscriber
            # rotates occurrences exactly as with instrumented collectives.
            # The async split is published only in overlap-aware mode —
            # the naive 3-phase contrast prices the whole window as slack,
            # so its stream starts the barrier at the window start too
            # (subscriber reports track the SimResult they ride along with)
            if acc is not None:
                if ov_k > 0.0 and overlap_aware:
                    push_phase(3, arrival)
                    push_phase(4, arrival + ov_k)
                else:
                    push_phase(0, window_start)
                push_phase(1, t_bar)
                if wc > 0.0:
                    push_phase(2, t_bar + d_copy)
            elif ov_k > 0.0 and overlap_aware:
                for r in range(n):
                    bus.publish(r, "dispatch_enter", site, float(arrival[r]))
                for r in range(n):
                    bus.publish(r, "wait_enter", site, float(arrival[r] + ov_k))
            else:
                for r in range(n):
                    bus.publish(r, "barrier_enter", site, float(window_start[r]))
            if acc is None:
                for r in range(n):
                    bus.publish(r, "barrier_exit", site, float(t_bar[r]))
                if wc > 0.0:
                    copy_ends = t_bar + d_copy
                    for r in range(n):
                        bus.publish(r, "copy_exit", site, float(copy_ends[r]))

        # ---- table updates (what the runtime could actually measure) ----
        if pol.comm_mode == "predict_timeout":
            last_comm[site] = (t - arrival)             # slack + copy
        if pol.compute_mode == "andante":
            last_comp[site] = work
            last_slack[site] = slack

        if collect_trace:
            trace_comp[k] = d_comp
            trace_slack[k] = slack
            trace_copy[k] = t - t_bar

    if acc is not None and len(acc):
        bus.publish_batch(acc.flush())      # tail chunk: no event left behind

    power_series = None
    if power_dt:
        wall = float(t.max())
        n_bins = max(int(np.ceil(wall / power_dt)), 1)
        power_series = np.zeros((n_bins, n))
        for t0_seg, dur_seg, e_seg in segs:
            _bin_energy(power_series, power_dt, t0_seg, dur_seg, e_seg)

    has_theta = bool(np.isfinite(theta_series).any())
    theta_bins = None
    if power_series is not None and has_theta:
        # theta as a per-bin series: the threshold armed at each power bin
        # (piecewise-constant between task arm times)
        bin_end = (np.arange(power_series.shape[0]) + 1) * power_dt
        idx = np.clip(np.searchsorted(t_arm, bin_end, side="right") - 1,
                      0, t_tasks - 1)
        theta_bins = theta_series[idx]

    n_prearm = n_mispredict = n_trips = 0
    if hybrid is not None:
        for g in hybrid.guard_summary().values():
            n_prearm += int(g["n_armed"])
            n_mispredict += int(g["n_mispredict"])
            n_trips += int(g["tripped"])
    res = SimResult(
        name=pol.name,
        time=float(t.max()),
        energy=float(energy.sum()),
        tcomp=tcomp,
        tslack=tslack,
        tcopy=tcopy,
        exploited=exploited,
        exploited_slack=exploited_slack,
        calls=t_tasks,
        power_dt=power_dt or 0.0,
        power_series=power_series,
        toverlap=toverlap,
        theta_series=theta_series if has_theta else None,
        theta_bins=theta_bins,
        n_prearm=n_prearm,
        n_mispredict=n_mispredict,
        n_guard_trips=n_trips,
        t_dvfs_stretch=t_stretch,
    )
    trace = (
        TraceRecord(wl.site, wl.is_p2p, wl.nbytes, trace_comp, trace_slack,
                    trace_copy, partner=wl.partner)
        if collect_trace
        else None
    )
    return res, trace


# --------------------------------------------------------------------------
# trace-analysis mode (paper Table 2): coverage each policy achieves on the
# *baseline* trace, without timing feedback.
# --------------------------------------------------------------------------

def coverage_on_trace(trace: TraceRecord, pol: Policy, hw: HwModel = DEFAULT_HW) -> float:
    """Fraction [%] of total rank-time the policy would run at f_min."""
    theta_eff = hw.theta_eff(pol.theta)
    slack, copy = trace.slack, trace.copy
    total = trace.comp.sum() + slack.sum() + copy.sum()
    n_sites = int(trace.site.max()) + 1
    n = slack.shape[1]
    if pol.comm_mode == "pin_min":
        return 100.0          # min P-state everywhere, by definition
    if pol.comm_mode == "timeout":
        low_slack = np.maximum(slack - theta_eff, 0.0)
        if pol.comm_scope == "slack":
            return 100.0 * low_slack.sum() / total
        comm = slack + copy
        low = np.maximum(comm - theta_eff, 0.0)
        return 100.0 * low.sum() / total
    if pol.comm_mode == "predict_timeout":
        last = np.full((n_sites, n), np.nan)
        low_total = 0.0
        for k in range(slack.shape[0]):
            site = int(trace.site[k])
            comm = slack[k] + copy[k]
            armed = np.nan_to_num(last[site], nan=0.0) >= 2.0 * pol.theta
            low_total += np.where(armed, np.maximum(comm - theta_eff, 0.0), 0.0).sum()
            last[site] = comm
        return 100.0 * low_total / total
    return 0.0
