"""Power-management policies: COUNTDOWN Slack + all paper baselines (§4, §5).

Each policy is a declarative config consumed by the vectorized engine in
``repro.core.simulator``:

  baseline      — max P-state everywhere (paper's *Baseline*).
  minfreq       — min P-state everywhere (paper's *Min Freq*).
  fermata_100ms — proactive: arms a 100 ms timer only when the last comm at
                  this call site was >= 2x the threshold; slows the WHOLE
                  comm (slack+copy).  Stack-hash cost per call.
  fermata_500us — same, threshold tuned to the PCU latency.
  andante       — proactive: last-value predicts (Tcomp, Tslack) per call
                  site and picks the compute P-state that absorbs the slack.
  adagio        — andante + fermata-500us applied to the isolated slack.
  countdown     — reactive: arms a 500 us timer at EVERY comm entry; slows
                  slack+copy.  No hash, no tables.
  cntd_slack    — COUNTDOWN Slack (the paper): artificial barrier isolates
                  the slack; 500 us reactive timer applies min P-state to
                  slack ONLY; copy runs at max P-state.
  cntd_adaptive — cntd_slack with the fixed 500 us replaced by the online
                  ThetaTuner (repro.core.timeout): per-site slack-CDF decay
                  bounded by the 1% overhead budget, AIMD raise on observed
                  copy slowdown, clamped to [switch_latency/2, theta_max].
  cntd_predictive — cntd_adaptive plus the online duration predictor
                  (repro.core.predictor.OnlinePredictor): when predicted
                  slack clears the residue-cost bar the downshift is
                  pre-armed at comm entry (no theta wait), wrapped in a
                  per-site misprediction guard that falls back to the pure
                  tuner path when realized cost exceeds the 1% budget.
  cntd_predict_only — the paper's prediction-only strawman (Guermouche /
                  Fermata-style): pre-arms on ANY predicted slack and slows
                  the WHOLE comm (slack+copy, no artificial barrier), with
                  NO reactive timeout fallback and NO guard — the
                  configuration whose misprediction + copy-slowdown cost
                  the Table-3 bench shows overshooting the overhead budget.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Policy:
    name: str
    compute_mode: str = "max"       # max | min | andante
    comm_mode: str = "none"         # none | timeout | predict_timeout | pin_min
    comm_scope: str = "comm"        # comm (slack+copy) | slack (barrier-isolated)
    theta: float = 500e-6           # timeout duration (s); theta0 when adaptive
    uses_hash: bool = False         # per-call stack-hash + lookup cost
    uses_barrier: bool = False      # artificial barrier inserted (cost + isolation)
    theta_mode: str = "fixed"       # fixed | adaptive (online ThetaTuner)
    #                               | predictive (guarded hybrid PredictiveTuner)
    #                               | predict_only (unguarded, no timeout fallback)


BASELINE = Policy("baseline")
MINFREQ = Policy("minfreq", compute_mode="min", comm_mode="pin_min")
FERMATA_100MS = Policy(
    "fermata_100ms", comm_mode="predict_timeout", comm_scope="comm",
    theta=100e-3, uses_hash=True,
)
FERMATA_500US = Policy(
    "fermata_500us", comm_mode="predict_timeout", comm_scope="comm",
    theta=500e-6, uses_hash=True,
)
ANDANTE = Policy(
    "andante", compute_mode="andante", comm_mode="none",
    uses_hash=True, uses_barrier=True,
)
ADAGIO = Policy(
    "adagio", compute_mode="andante", comm_mode="timeout", comm_scope="slack",
    theta=500e-6, uses_hash=True, uses_barrier=True,
)
COUNTDOWN = Policy("countdown", comm_mode="timeout", comm_scope="comm", theta=500e-6)
COUNTDOWN_SLACK = Policy(
    "cntd_slack", comm_mode="timeout", comm_scope="slack",
    theta=500e-6, uses_barrier=True,
)
CNTD_ADAPTIVE = Policy(
    "cntd_adaptive", comm_mode="timeout", comm_scope="slack",
    theta=500e-6, uses_barrier=True, theta_mode="adaptive",
)
CNTD_PREDICTIVE = Policy(
    "cntd_predictive", comm_mode="timeout", comm_scope="slack",
    theta=500e-6, uses_barrier=True, theta_mode="predictive",
)
CNTD_PREDICT_ONLY = Policy(
    "cntd_predict_only", comm_mode="timeout", comm_scope="comm",
    theta=500e-6, uses_barrier=False, theta_mode="predict_only",
)

# the 8 fixed-theta policies the paper evaluates — frozen by the golden
# conformance suite (tests/test_golden.py); cntd_adaptive and the
# predictive pair ride on top (cntd_predictive has its own fixture file)
FIXED_POLICIES = [
    BASELINE, MINFREQ, FERMATA_100MS, FERMATA_500US,
    ANDANTE, ADAGIO, COUNTDOWN, COUNTDOWN_SLACK,
]

ALL_POLICIES = {
    p.name: p
    for p in FIXED_POLICIES + [CNTD_ADAPTIVE, CNTD_PREDICTIVE, CNTD_PREDICT_ONLY]
}


def policy_for_theta(theta: str, base: Policy = COUNTDOWN_SLACK) -> Policy:
    """Resolve a CLI ``--theta`` value against ``base``: ``""`` keeps it
    untouched, ``"auto"`` switches it to adaptive mode (the governor
    attaches an online :class:`~repro.core.timeout.ThetaTuner`; the base's
    scope/costs/theta0 are honored), ``"predictive"`` to the guarded
    predictor+timeout hybrid (a
    :class:`~repro.core.timeout.PredictiveTuner`), anything else parses as
    a fixed timeout in seconds."""
    if not theta:
        return base
    from dataclasses import replace

    if theta == "auto":
        return replace(base, theta_mode="adaptive", name="cntd_adaptive")
    if theta == "predictive":
        return replace(base, theta_mode="predictive", name="cntd_predictive")
    return replace(base, theta=float(theta))
