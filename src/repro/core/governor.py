"""Host-side governor: the live streaming engine that consumes phase events.

This is the analogue of the paper's timer+callback machinery (§4.3): the
instrumented collectives emit (rank, phase, call_id, t) events onto the
:class:`~repro.core.events.EventBus` (``repro.core.instrument`` owns the
ambient bus); the governor subscribes, reconstructs per-call slack/copy
durations, applies the configured policy's timeout decision, logs the
P-state actuation it *would* issue (on Intel: wrmsr via MSR_SAFE; on a
TPU host: SMC power capping — see DESIGN.md §2), estimates energy via the
calibrated HwModel, and feeds the straggler detector.

The accounting is **streaming and constant-memory** (DESIGN.md §9): the
runtime lives inside every MPI call on week-long runs, so it cannot
retain history.  Slack/copy/overlap/energy accumulate incrementally when
a call occurrence *retires* (a rank re-enters its call id — the rotation
rule — or an ingested phase closes); retired records are evicted into a
small bounded ring (``retention``, debugging only), the straggler
detector observes arrivals at retirement, and :meth:`finalize` /
:meth:`interval_snapshot` are O(in-flight) / O(1) reads of the
accumulators instead of re-walking the full history.  The accumulation
order is exactly the retirement order followed by the in-flight records,
i.e. the same float-addition sequence the historical batch tally
performed — reports are bit-for-bit identical (the golden conformance
suite and the streaming/batch property test in ``tests/test_events.py``
pin this down).

Consumers that hang off the same stream: an optional
:class:`~repro.cluster.trace.TraceRecorder` (``Governor(recorder=)``)
tees every event/phase/actuation the governor books so a run replays
offline bit-for-bit, and :meth:`interval_snapshot` reports the
slack/overlap/energy booked since the previous snapshot — the per-epoch
poll the :class:`~repro.cluster.arbiter.PowerBudgetArbiter` redistributes
watts on.

An optional :class:`~repro.core.timeout.ThetaTuner` (``Governor(tuner=)``,
auto-created for ``theta_mode="adaptive"`` policies) closes the timeout
feedback loop: each barrier_exit is priced against the tuner's per-site
theta instead of the policy constant, the observation feeds the site's
slack histogram, and every adjustment is logged as a structured
:class:`~repro.core.timeout.ThetaDecision` next to the actuations (and
into the trace, schema v2, so adaptive runs replay bit-for-bit).  The
5-phase taxonomy (``dispatch_enter``/``wait_enter`` from the async
collectives) books compute/communication overlap as *non-slack*: slack
for an async pair starts at the wait, and the overlap window is reported
separately on ``GovernorReport.total_overlap``.
"""
from __future__ import annotations

import collections
import threading
from dataclasses import dataclass
from typing import Dict, List, NamedTuple, Optional, Tuple

from repro.core.events import PhaseRecord
from repro.core.policies import COUNTDOWN_SLACK, Policy
from repro.core.pstate import DEFAULT_HW, HwModel
from repro.core.timeout import ThetaDecision, ThetaTuner
from repro.dist.straggler import StragglerDetector


class Actuation(NamedTuple):
    """One P-state command the runtime would issue (structured so the trace
    recorder and benchmarks can consume it without attribute scraping).
    Index layout keeps the legacy ``(t, rank, action)`` prefix."""

    t: float
    rank: int
    action: str              # "set_pstate_min" | "restore_pstate_max"
    call_id: int
    slack: float             # the slack duration that triggered the pair


class CallRecord:
    """Per-occurrence reconstruction state (one barrier/async pair).

    A plain ``__slots__`` class, not a dataclass: one instance is created
    per *occurrence* on the hot path and its construction cost is part of
    the per-event budget.
    """

    __slots__ = ("call_id", "enter", "slack_end", "copy_end", "dispatch",
                 "theta_used", "site", "observed")

    def __init__(self, call_id: int, site: Optional[int] = None):
        self.call_id = call_id
        self.enter: Dict[int, float] = {}       # rank -> t (slack start)
        self.slack_end: Dict[int, float] = {}
        self.copy_end: Dict[int, float] = {}
        self.dispatch: Dict[int, float] = {}    # async overlap start
        self.theta_used: Dict[int, float] = {}  # raw theta armed per rank at
        # slack end (only populated under a tuner; fixed policies price the
        # constant default, saving a dict store per event)
        self.site = site                        # tuner histogram key override
        self.observed = 0                       # arrival count already fed to
        # the straggler detector (a mid-run finalize() observes the record
        # partially; more ranks entering later re-qualify it)

    def __repr__(self) -> str:   # debugging aid for ring inspection
        return (f"CallRecord(call_id={self.call_id}, ranks={len(self.enter)}, "
                f"site={self.site})")


class _Accum:
    """Streaming counters behind reports and snapshots.

    ``add_record`` replays the historical batch tally's inner loop against
    *running* sums — feeding records through in the same order as the old
    one-shot walk performs the identical float-addition sequence, which is
    what keeps the golden fixtures bit-for-bit stable across the
    streaming refactor.
    """

    __slots__ = ("n_records", "n_down", "slack", "copy", "busy",
                 "exploited", "e_base", "e_pol", "overlap")

    def __init__(self) -> None:
        self.n_records = 0
        self.n_down = 0
        self.slack = 0.0
        self.copy = 0.0
        self.busy = 0.0
        self.exploited = 0.0
        self.e_base = 0.0
        self.e_pol = 0.0
        self.overlap = 0.0

    def clone(self) -> "_Accum":
        c = _Accum()
        for f in _Accum.__slots__:
            setattr(c, f, getattr(self, f))
        return c


@dataclass
class GovernorReport:
    n_calls: int
    n_downshifts: int
    total_slack: float
    total_copy: float
    exploited_slack: float
    energy_baseline: float           # J during instrumented phases, no policy
    energy_policy: float             # J with the policy's P-state trajectory
    straggler_summary: Dict[int, float]
    stragglers: List[Tuple[int, float]]
    total_overlap: float = 0.0       # dispatch->wait seconds, accounted NON-slack
    n_theta_decisions: int = 0       # tuner adjustments booked (0 = fixed theta)

    @property
    def energy_saving_pct(self) -> float:
        # energy_policy can dip epsilon-negative when float cancellation
        # meets zero-length phases; clamp both edges so the percentage
        # stays in [0, 100] instead of exceeding it by rounding artifacts
        if self.energy_baseline <= 0:
            return 0.0
        return 100.0 * (1.0 - max(self.energy_policy, 0.0) / self.energy_baseline)

    def to_dict(self) -> Dict[str, object]:
        """JSON-ready view (trace artifacts, benchmarks) — one place, not
        per-consumer attribute scraping."""
        return {
            "n_calls": int(self.n_calls),
            "n_downshifts": int(self.n_downshifts),
            "total_slack": float(self.total_slack),
            "total_copy": float(self.total_copy),
            "exploited_slack": float(self.exploited_slack),
            "energy_baseline": float(self.energy_baseline),
            "energy_policy": float(self.energy_policy),
            "energy_saving_pct": float(self.energy_saving_pct),
            "straggler_summary": {int(r): float(v) for r, v in self.straggler_summary.items()},
            "stragglers": [[int(r), float(z)] for r, z in self.stragglers],
            "total_overlap": float(self.total_overlap),
            "n_theta_decisions": int(self.n_theta_decisions),
        }


@dataclass
class IntervalStats:
    """Slack/energy booked between two ``interval_snapshot`` calls."""

    n_calls: int
    n_downshifts: int
    slack: float
    copy: float
    busy: float                      # sum over ranks of enter->copy_end spans
    exploited: float
    energy_baseline: float
    energy_policy: float
    overlap: float = 0.0             # dispatch->wait seconds booked non-slack

    @property
    def exploited_ratio(self) -> float:
        """Fraction of instrumented rank-time the policy spent at f_min —
        the arbiter's signal that this job has watts to give away."""
        return self.exploited / self.busy if self.busy > 0 else 0.0

    @property
    def overlap_ratio(self) -> float:
        """Overlap seconds per instrumented busy second — distinguishes an
        overlap-heavy job (compute hidden under flying collectives: watts
        convert to progress) from a slack-heavy one (watts stranded)."""
        return self.overlap / self.busy if self.busy > 0 else 0.0


class Governor:
    """Streaming engine: reconstructs phases from bus events, applies the
    policy, and keeps O(1)-memory accounting.

    Subscribe it to an :class:`~repro.core.events.EventBus` (it exposes the
    canonical ``on_event``/``on_phase`` consumer interface) or feed it
    directly through :meth:`sink` / :meth:`ingest_phase`.

    ``retention`` bounds the debugging ring of retired
    :class:`CallRecord` occurrences (``recent_records()``); accounting
    never needs them back.  ``log_retention`` optionally bounds the
    actuation/theta decision logs the same way — counts survive eviction
    (``n_actuations``, and ``n_theta_decisions`` on the report).
    """

    def __init__(
        self,
        policy: Policy = COUNTDOWN_SLACK,
        hw: HwModel = DEFAULT_HW,
        detector: Optional[StragglerDetector] = None,
        recorder=None,
        tuner: Optional[ThetaTuner] = None,
        retention: int = 256,
        log_retention: Optional[int] = None,
    ):
        self.policy = policy
        self.hw = hw
        self.detector = detector or StragglerDetector()
        self.recorder = recorder     # cluster.trace.TraceRecorder-compatible
        # Recorder hooks are resolved once: sink() runs per event, so an
        # absent hook must cost one None check, not a getattr + no-op call.
        # A recorder exposing the *spine* hooks (``on_actuation_pair``,
        # ``on_retired`` — see repro.obs.tracer.GovernorTap) keeps the
        # lazy/cheap paths the bare governor uses; one exposing only the
        # eager ``on_actuation`` (cluster.trace.TraceRecorder) still gets
        # fully-built Actuation values in stream order.
        self._rec_event = getattr(recorder, "on_event", None)
        self._rec_phase = getattr(recorder, "on_phase", None)
        self._rec_act = getattr(recorder, "on_actuation", None)
        self._rec_theta = getattr(recorder, "on_theta", None)
        self._rec_pair = getattr(recorder, "on_actuation_pair", None)
        self._rec_retire = getattr(recorder, "on_retired", None)
        if tuner is None and policy.theta_mode == "adaptive":
            tuner = ThetaTuner(hw=hw, theta0=policy.theta)
        self.tuner = tuner
        self.retention = int(retention)
        # call_ids are assigned at TRACE time, so the same id recurs on every
        # executed step: rotate to a fresh occurrence when a rank re-enters,
        # retiring the previous one into the accumulators + ring
        self._calls: Dict[int, CallRecord] = {}
        self._ring: collections.deque = collections.deque(maxlen=self.retention)
        self._acc = _Accum()         # cumulative, behind finalize()
        self._mark = _Accum()        # checkpoint of _acc at the last snapshot
        self._last_end: Dict[int, float] = {}   # rank -> last phase end (the
        # enter-minus-this gap is the rank's compute, widening the tuner's
        # overhead budget to the time-to-completion denominator)
        self._lock = threading.Lock()
        self.n_actuations = 0
        # the log materializes lazily: the hot path appends one compact
        # (t, rank, call_id, slack) spine tuple per pair and the
        # ``actuation_log`` property expands it on first read (eagerly only
        # under a recorder, which needs the pair in stream order).  Under
        # log_retention the spine is ring-bounded too — each entry expands
        # to a pair, so half the retention covers the whole window and an
        # unread governor stays bounded-RSS on week-long runs
        self._act_raw = (
            collections.deque(maxlen=(log_retention + 1) // 2)
            if log_retention is not None else []
        )
        self._act_log: List[Actuation] = (
            collections.deque(maxlen=log_retention) if log_retention is not None
            else []
        )
        self._n_theta = 0
        self._theta_log = (
            collections.deque(maxlen=log_retention) if log_retention is not None
            else []
        )
        # policy/hw are frozen for the governor's lifetime: pre-derive the
        # per-event constants off the hot path
        self._theta_default = policy.theta
        self._timeout_armed = policy.comm_mode in ("timeout", "predict_timeout")
        self._scope_comm = policy.comm_scope == "comm"
        # float() strips the numpy scalar wrapper: identical IEEE doubles,
        # faster accumulate arithmetic
        self._w_slack_hi = float(hw.watts(hw.f_max, hw.act_slack))
        self._w_slack_lo = float(hw.watts(hw.f_min, hw.act_slack))
        self._w_copy_hi = float(hw.watts(hw.f_max, hw.act_copy))
        self._w_copy_lo = float(hw.watts(hw.f_min, hw.act_copy))
        self._theta_eff: Dict[float, float] = {}     # theta -> hw.theta_eff

    def _actuate(self, t: float, rank: int, call_id: int, slack: float) -> None:
        self.n_actuations += 2
        rec_pair = self._rec_pair
        if rec_pair is not None:
            # spine-aware recorder: keep the lazy path (one tuple append)
            # and hand it the compact pair
            self._act_raw.append((t, rank, call_id, slack))
            rec_pair(t, rank, call_id, slack)
            return
        if self._rec_act is None:
            # no recorder, or one that (like the obs GovernorTap) reads
            # actuations back from the spine log after the run instead of
            # paying a per-downshift call on the hot path
            self._act_raw.append((t, rank, call_id, slack))
            return
        pair = (
            Actuation(t, rank, "set_pstate_min", call_id, slack),
            Actuation(t, rank, "restore_pstate_max", call_id, slack),
        )
        self._act_log.extend(pair)
        for act in pair:
            self._rec_act(act)

    @property
    def actuation_log(self) -> List[Actuation]:
        """Every P-state pair booked so far (cold read: pending spine
        tuples are expanded into :class:`Actuation` values on access).

        Always a ``list``: the live backing list when unbounded, a snapshot
        copy of the retention ring under ``log_retention`` (a deque would
        compare unequal to a replayed governor's list even element-for-
        element identical).
        """
        raw = self._act_raw
        if raw:
            with self._lock:
                log = self._act_log
                for t, rank, call_id, slack in raw:
                    log.append(Actuation(t, rank, "set_pstate_min", call_id, slack))
                    log.append(Actuation(t, rank, "restore_pstate_max", call_id, slack))
                raw.clear()
        log = self._act_log
        return log if type(log) is list else list(log)

    def _record_theta(self, dec: Optional[ThetaDecision]) -> None:
        if dec is None:
            return
        self._n_theta += 1
        self._theta_log.append(dec)
        if self._rec_theta is not None:
            self._rec_theta(dec)

    @property
    def theta_log(self) -> List[ThetaDecision]:
        """Tuner decisions booked so far — always a ``list`` (a snapshot
        copy of the retention ring under ``log_retention``), mirroring
        :attr:`actuation_log` so cross-governor comparisons stay honest."""
        log = self._theta_log
        return log if type(log) is list else list(log)

    def _close_slack(self, rec: CallRecord, rank: int, t: float) -> None:
        """Shared barrier_exit tail: price the slack against the (possibly
        tuned) threshold, book the actuation pair, feed the tuner."""
        rec.slack_end[rank] = t
        t0 = rec.enter.get(rank, t)
        slack = t - t0
        if self.tuner is None:
            theta = self._theta_default
        else:
            key = rec.site if rec.site is not None else rec.call_id
            theta = self.tuner.theta_for(key)   # threshold armed BEFORE this obs
            rec.theta_used[rank] = theta
            last = self._last_end.get(rank)
            comp = max(t0 - last, 0.0) if last is not None else 0.0
            self._record_theta(
                self.tuner.observe_slack(key, slack, t, rank=rank, comp=comp)
            )
        self._last_end[rank] = t
        if slack >= theta and self._timeout_armed:
            self._actuate(t, rank, rec.call_id, slack)

    def _close_copy(self, rec: CallRecord, rank: int, t: float) -> None:
        rec.copy_end[rank] = t
        self._last_end[rank] = t
        if self.tuner is None or rank not in rec.slack_end:
            return
        t1 = rec.slack_end[rank]
        slack = t1 - rec.enter.get(rank, t1)
        downshifted = slack >= rec.theta_used.get(rank, self._theta_default)
        key = rec.site if rec.site is not None else rec.call_id
        self._record_theta(
            self.tuner.observe_copy(key, t - t1, t, rank=rank, downshifted=downshifted)
        )

    # streaming accounting ----------------------------------------------------
    def _accumulate(self, rec: CallRecord, acc: _Accum) -> None:
        """Fold one record into running sums — the historical batch tally's
        inner loop, verbatim in addition order, against persistent
        accumulators (the sums ride in locals across the rank loop; same
        float sequence, one attribute write per field per record)."""
        acc.n_records += 1
        enter = rec.enter
        if not enter:
            return
        slack_end = rec.slack_end
        copy_end = rec.copy_end
        dispatch = rec.dispatch
        theta_used = rec.theta_used
        theta_eff_of = self._theta_eff
        default_theta = self._theta_default
        # fixed-theta records (no tuner) price one threshold: hoist the
        # two per-rank dict lookups out of the loop
        te_fixed = None
        if not theta_used:
            te_fixed = theta_eff_of.get(default_theta)
            if te_fixed is None:
                te_fixed = self.hw.theta_eff(default_theta)
                theta_eff_of[default_theta] = te_fixed
        w_slack_hi, w_slack_lo = self._w_slack_hi, self._w_slack_lo
        w_copy_hi, w_copy_lo = self._w_copy_hi, self._w_copy_lo
        scope_comm = self._scope_comm
        n_down = acc.n_down
        a_slack, a_copy, a_busy = acc.slack, acc.copy, acc.busy
        a_expl, a_ebase, a_epol, a_ov = (acc.exploited, acc.e_base,
                                         acc.e_pol, acc.overlap)
        for rank, t0 in enter.items():
            t1 = slack_end.get(rank)
            if t1 is None:
                continue
            # async pair: [dispatch, enter] is compute/comm overlap — the
            # core is busy, so it is *not* slack and is not priced here
            # (the caller's compute never is); it is reported separately
            if dispatch:
                td = dispatch.get(rank)
                if td is not None:
                    ov = t0 - td
                    if ov > 0.0:
                        a_ov += ov
            slack = t1 - t0
            if slack < 0.0:
                slack = 0.0
            a_slack += slack
            t2 = copy_end.get(rank)
            copy = 0.0 if t2 is None else t2 - t1
            if copy < 0.0:
                copy = 0.0
            a_copy += copy
            a_busy += slack + copy
            a_ebase += w_slack_hi * slack
            a_ebase += w_copy_hi * copy
            if te_fixed is not None:
                theta_eff = te_fixed
            else:
                theta = theta_used.get(rank, default_theta)
                theta_eff = theta_eff_of.get(theta)
                if theta_eff is None:
                    if len(theta_eff_of) >= 4096:
                        # adaptive tuners mint a fresh theta per decision;
                        # the memo must not become the history it replaces
                        theta_eff_of.clear()
                    theta_eff = self.hw.theta_eff(theta)
                    theta_eff_of[theta] = theta_eff
            low = slack - theta_eff
            if low > 0.0:
                n_down += 1
                a_expl += low
            else:
                low = 0.0
            a_epol += w_slack_hi * (slack - low)
            a_epol += w_slack_lo * low
            if scope_comm and low > 0.0:
                a_epol += w_copy_lo * copy
            else:
                a_epol += w_copy_hi * copy
        acc.n_down = n_down
        acc.slack, acc.copy, acc.busy = a_slack, a_copy, a_busy
        acc.exploited, acc.e_base, acc.e_pol, acc.overlap = (
            a_expl, a_ebase, a_epol, a_ov)

    def _observe(self, rec: CallRecord) -> None:
        """Feed an occurrence's arrivals to the straggler detector, at most
        once per arrival set: a record partially observed by a mid-run
        finalize() is observed again if new ranks entered since."""
        n = len(rec.enter)
        if n > rec.observed:
            rec.observed = n
            self.detector.observe_barrier(rec.enter)

    def _retire(self, rec: CallRecord) -> None:
        """A call occurrence is final: observe its arrivals, fold it into
        the cumulative accumulators, evict it into the bounded ring."""
        self._observe(rec)
        self._accumulate(rec, self._acc)
        self._ring.append(rec)

    # the bus consumer interface ----------------------------------------------
    def sink(self, rank: int, phase: str, call_id: int, t: float) -> None:
        with self._lock:
            # recorded under the lock: the trace order must be the order the
            # governor processed events in, or replay() loses bit-exactness
            if self._rec_event is not None:
                self._rec_event(rank, phase, call_id, t)
            calls = self._calls
            rec = calls.get(call_id)
            if rec is None:
                rec = CallRecord(call_id)
                calls[call_id] = rec
            if phase == "barrier_enter":
                if rank in rec.enter or rank in rec.dispatch:
                    self._retire(rec)                   # new occurrence
                    if self._rec_retire is not None:
                        self._rec_retire(rec)
                    rec = CallRecord(call_id)
                    calls[call_id] = rec
                rec.enter[rank] = t
            elif phase == "barrier_exit":
                if self.tuner is None:
                    # _close_slack without the tuner bookkeeping, inlined:
                    # this is the single hottest branch of the runtime
                    rec.slack_end[rank] = t
                    self._last_end[rank] = t
                    slack = t - rec.enter.get(rank, t)
                    if slack >= self._theta_default and self._timeout_armed:
                        self._actuate(t, rank, call_id, slack)
                else:
                    self._close_slack(rec, rank, t)
            elif phase == "copy_exit":
                if self.tuner is None:
                    rec.copy_end[rank] = t
                    self._last_end[rank] = t
                else:
                    self._close_copy(rec, rank, t)
            elif phase == "dispatch_enter":
                if rank in rec.enter or rank in rec.dispatch:
                    self._retire(rec)                   # new occurrence
                    if self._rec_retire is not None:
                        self._rec_retire(rec)
                    rec = CallRecord(call_id)
                    calls[call_id] = rec
                rec.dispatch[rank] = t                  # overlap starts
            elif phase == "wait_enter":
                rec.enter[rank] = t                     # slack starts at the wait

    on_event = sink          # canonical EventBus subscriber method

    def on_phase(self, record: PhaseRecord) -> None:
        """Book one fully-formed phase (the EventBus ``publish_phase``
        consumer): same CallRecord, same timeout-policy actuation, and
        immediate retirement — the occurrence is complete by construction.
        """
        rec = CallRecord(record.call_id, site=record.site)
        rec.enter[record.rank] = record.t_enter
        with self._lock:
            if self._rec_phase is not None:
                self._rec_phase(record)
            self._close_slack(rec, record.rank, record.t_slack_end)
            self._close_copy(rec, record.rank, record.t_copy_end)
            self._retire(rec)

    # non-collective event sources ---------------------------------------------
    def ingest_phase(
        self,
        rank: int,
        call_id: int,
        t_enter: float,
        t_slack_end: float,
        t_copy_end: Optional[float] = None,
        site: Optional[int] = None,
    ) -> None:
        """Book one fully-formed phase from a non-collective source.

        Kwargs-shaped convenience over :meth:`on_phase` — producers that
        already speak the canonical vocabulary publish a
        :class:`~repro.core.events.PhaseRecord` through the bus instead.
        """
        if t_copy_end is None:
            t_copy_end = t_slack_end
        self.on_phase(PhaseRecord(rank, call_id, t_enter, t_slack_end,
                                  t_copy_end, site))

    # accounting ---------------------------------------------------------------
    def recent_records(self) -> List[CallRecord]:
        """The last ``retention`` retired occurrences (debugging only —
        accounting never re-reads them)."""
        with self._lock:
            return list(self._ring)

    @property
    def n_inflight(self) -> int:
        return len(self._calls)

    def interval_snapshot(self) -> IntervalStats:
        """Stats over the phases retired since the previous snapshot.

        An O(1) read: the cumulative accumulators minus the checkpoint
        taken at the previous snapshot (clamped at zero — differencing
        two running float sums can produce a negative ulp).  Non-
        destructive for :meth:`finalize` and does not feed the straggler
        detector — it is the arbiter's per-epoch poll, not the end-of-run
        report.  In-flight occurrences are picked up by a later snapshot
        once they rotate into retirement.
        """
        with self._lock:
            acc, mark = self._acc, self._mark
            stats = IntervalStats(
                n_calls=acc.n_records - mark.n_records,
                n_downshifts=acc.n_down - mark.n_down,
                slack=max(acc.slack - mark.slack, 0.0),
                copy=max(acc.copy - mark.copy, 0.0),
                busy=max(acc.busy - mark.busy, 0.0),
                exploited=max(acc.exploited - mark.exploited, 0.0),
                energy_baseline=max(acc.e_base - mark.e_base, 0.0),
                energy_policy=max(acc.e_pol - mark.e_pol, 0.0),
                overlap=max(acc.overlap - mark.overlap, 0.0),
            )
            self._mark = acc.clone()
        return stats

    def finalize(self) -> GovernorReport:
        """End-of-run report: the cumulative accumulators plus the records
        still in flight — O(in-flight), however long the run was."""
        with self._lock:
            acc = self._acc.clone()
            for rec in self._calls.values():
                self._observe(rec)
                self._accumulate(rec, acc)
        return GovernorReport(
            n_calls=acc.n_records,
            n_downshifts=acc.n_down,
            total_slack=acc.slack,
            total_copy=acc.copy,
            exploited_slack=acc.exploited,
            energy_baseline=acc.e_base,
            energy_policy=acc.e_pol,
            straggler_summary=self.detector.summary(),
            stragglers=self.detector.stragglers(),
            total_overlap=acc.overlap,
            n_theta_decisions=self._n_theta,
        )

    def reset(self) -> None:
        """Return to the just-constructed state: in-flight records, ring,
        both accumulator sets, per-rank phase ends, logs and their
        counters, the straggler detector, and the tuner.  Two back-to-back
        identical runs on one governor produce identical reports (pinned
        by a regression test)."""
        with self._lock:
            self._calls.clear()
            self._ring.clear()
            self._acc = _Accum()
            self._mark = _Accum()
            self._last_end.clear()
            self.n_actuations = 0
            self._act_raw.clear()
            self._act_log.clear()
            self._n_theta = 0
            self._theta_log.clear()
            self.detector.reset()
            if self.tuner is not None:
                self.tuner.reset()
