"""Host-side governor: the live streaming engine that consumes phase events.

This is the analogue of the paper's timer+callback machinery (§4.3): the
instrumented collectives emit (rank, phase, call_id, t) events onto the
:class:`~repro.core.events.EventBus` (``repro.core.instrument`` owns the
ambient bus); the governor subscribes, reconstructs per-call slack/copy
durations, applies the configured policy's timeout decision, logs the
P-state actuation it *would* issue (on Intel: wrmsr via MSR_SAFE; on a
TPU host: SMC power capping — see DESIGN.md §2), estimates energy via the
calibrated HwModel, and feeds the straggler detector.

The accounting is **streaming and constant-memory** (DESIGN.md §9): the
runtime lives inside every MPI call on week-long runs, so it cannot
retain history.  Slack/copy/overlap/energy accumulate incrementally when
a call occurrence *retires* (a rank re-enters its call id — the rotation
rule — or an ingested phase closes); retired records are evicted into a
small bounded ring (``retention``, debugging only), the straggler
detector observes arrivals at retirement, and :meth:`finalize` /
:meth:`interval_snapshot` are O(in-flight) / O(1) reads of the
accumulators instead of re-walking the full history.  The accumulation
order is exactly the retirement order followed by the in-flight records,
i.e. the same float-addition sequence the historical batch tally
performed — reports are bit-for-bit identical (the golden conformance
suite and the streaming/batch property test in ``tests/test_events.py``
pin this down).

Consumers that hang off the same stream: an optional
:class:`~repro.cluster.trace.TraceRecorder` (``Governor(recorder=)``)
tees every event/phase/actuation the governor books so a run replays
offline bit-for-bit, and :meth:`interval_snapshot` reports the
slack/overlap/energy booked since the previous snapshot — the per-epoch
poll the :class:`~repro.cluster.arbiter.PowerBudgetArbiter` redistributes
watts on.

An optional :class:`~repro.core.timeout.ThetaTuner` (``Governor(tuner=)``,
auto-created for ``theta_mode="adaptive"`` policies) closes the timeout
feedback loop: each barrier_exit is priced against the tuner's per-site
theta instead of the policy constant, the observation feeds the site's
slack histogram, and every adjustment is logged as a structured
:class:`~repro.core.timeout.ThetaDecision` next to the actuations (and
into the trace, schema v2, so adaptive runs replay bit-for-bit).  The
5-phase taxonomy (``dispatch_enter``/``wait_enter`` from the async
collectives) books compute/communication overlap as *non-slack*: slack
for an async pair starts at the wait, and the overlap window is reported
separately on ``GovernorReport.total_overlap``.
"""
from __future__ import annotations

import collections
import threading
from dataclasses import dataclass
from typing import Dict, List, NamedTuple, Optional, Tuple

import numpy as np

from repro.core.events import PHASE_NAMES, EventBatch, PhaseRecord
from repro.core.policies import COUNTDOWN_SLACK, Policy
from repro.core.pstate import DEFAULT_HW, HwModel
from repro.core.timeout import (PredictiveTuner, PredictorDecision,
                                ThetaDecision, ThetaTuner)
from repro.dist.straggler import StragglerDetector


class Actuation(NamedTuple):
    """One P-state command the runtime would issue (structured so the trace
    recorder and benchmarks can consume it without attribute scraping).
    Index layout keeps the legacy ``(t, rank, action)`` prefix."""

    t: float
    rank: int
    action: str              # "set_pstate_min" | "restore_pstate_max"
    call_id: int
    slack: float             # the slack duration that triggered the pair


class CallRecord:
    """Per-occurrence reconstruction state (one barrier/async pair).

    A plain ``__slots__`` class, not a dataclass: one instance is created
    per *occurrence* on the hot path and its construction cost is part of
    the per-event budget.
    """

    __slots__ = ("call_id", "enter", "slack_end", "copy_end", "dispatch",
                 "theta_used", "site", "observed", "prearm")

    def __init__(self, call_id: int, site: Optional[int] = None):
        self.call_id = call_id
        self.enter: Dict[int, float] = {}       # rank -> t (slack start)
        self.slack_end: Dict[int, float] = {}
        self.copy_end: Dict[int, float] = {}
        self.dispatch: Dict[int, float] = {}    # async overlap start
        self.theta_used: Dict[int, float] = {}  # raw theta armed per rank at
        # slack end (only populated under a tuner; fixed policies price the
        # constant default, saving a dict store per event)
        self.prearm: Optional[Dict[int, float]] = None  # rank -> the reactive
        # threshold displaced by a predictive pre-arm (lazy: only predictive
        # tuners pay the dict; the copy close reads it for guard attribution)
        self.site = site                        # tuner histogram key override
        self.observed = 0                       # arrival count already fed to
        # the straggler detector (a mid-run finalize() observes the record
        # partially; more ranks entering later re-qualify it)

    def __repr__(self) -> str:   # debugging aid for ring inspection
        return (f"CallRecord(call_id={self.call_id}, ranks={len(self.enter)}, "
                f"site={self.site})")


_EMPTY_I = np.empty(0, dtype=np.int64)
_EMPTY_F = np.empty(0, dtype=np.float64)


class _Tail:
    """Columnar in-flight occurrence state under the batched path.

    The per-event path keeps one :class:`CallRecord` (four dicts) per
    in-flight call id; materializing those dicts per batch would put a
    Python loop right back on the hot path.  The batched engine instead
    carries the open tail of each call id as per-class ``(rank, t)``
    column pairs — array views cut from the batch, in first-write
    (insertion) order with last-write values, exactly the dict contents.
    A tail converts to/from a :class:`CallRecord` losslessly at the
    per-event/batched seams (a stray ``sink()`` call, ``finalize``).
    """

    __slots__ = ("e_rk", "e_t", "s_rk", "s_t", "c_rk", "c_t",
                 "d_rk", "d_t", "observed", "_seen")

    def __init__(self, e_rk=_EMPTY_I, e_t=_EMPTY_F, s_rk=_EMPTY_I,
                 s_t=_EMPTY_F, c_rk=_EMPTY_I, c_t=_EMPTY_F,
                 d_rk=_EMPTY_I, d_t=_EMPTY_F, observed: int = 0):
        self.e_rk, self.e_t = e_rk, e_t
        self.s_rk, self.s_t = s_rk, s_t
        self.c_rk, self.c_t = c_rk, c_t
        self.d_rk, self.d_t = d_rk, d_t
        self.observed = observed
        self._seen = None

    @property
    def seen(self) -> set:
        """Ranks in enter ∪ dispatch — the rotation rule's membership set."""
        s = self._seen
        if s is None:
            s = set(self.e_rk.tolist())
            s.update(self.d_rk.tolist())
            self._seen = s
        return s

    @staticmethod
    def from_record(rec: CallRecord) -> "_Tail":
        def cols(d: Dict[int, float]):
            if not d:
                return _EMPTY_I, _EMPTY_F
            return (np.fromiter(d.keys(), np.int64, len(d)),
                    np.fromiter(d.values(), np.float64, len(d)))

        e_rk, e_t = cols(rec.enter)
        s_rk, s_t = cols(rec.slack_end)
        c_rk, c_t = cols(rec.copy_end)
        d_rk, d_t = cols(rec.dispatch)
        return _Tail(e_rk, e_t, s_rk, s_t, c_rk, c_t, d_rk, d_t, rec.observed)

    def to_record(self, call_id: int) -> CallRecord:
        rec = CallRecord(call_id)
        rec.enter = dict(zip(self.e_rk.tolist(), self.e_t.tolist()))
        rec.slack_end = dict(zip(self.s_rk.tolist(), self.s_t.tolist()))
        rec.copy_end = dict(zip(self.c_rk.tolist(), self.c_t.tolist()))
        rec.dispatch = dict(zip(self.d_rk.tolist(), self.d_t.tolist()))
        rec.observed = self.observed
        return rec


class _ActBlock(NamedTuple):
    """One batch's qualifying actuation pairs, columnar, appended to the
    lazy spine log whole (expanding per pair would put a Python loop back
    on the batch path; :attr:`Governor.actuation_log` expands on read)."""

    t: np.ndarray
    rank: np.ndarray
    call_id: np.ndarray
    slack: np.ndarray


class RetiredBlock:
    """One batch's retired occurrences, columnar — the batch analogue of
    the sequence of :class:`CallRecord` values the per-event path would
    have retired, in the identical retirement order.

    Row arrays hold the *accounting view* (one row per entered rank, in
    per-record dict-insertion order; ``row_off[i]:row_off[i+1]`` is
    record ``i``): rank, enter/slack-end/copy-end/dispatch times (NaN
    when the phase is missing).  The class arrays hold the *full* per-
    class ``(rank, t)`` entries (exit-only ranks included) for lossless
    :meth:`record` materialization, which the retention ring and any
    debugging consumer use.  Everything is a view onto the batch-sized
    working arrays: building a block costs object construction, not
    copies.
    """

    __slots__ = ("n", "cids", "observed", "n_enter", "sid_of_rid",
                 "row_rid", "row_rank", "row_t0", "row_t1", "row_t2",
                 "row_td", "row_off", "classes")

    def __init__(self, n, cids, observed, n_enter, sid_of_rid,
                 row_rid, row_rank, row_t0, row_t1, row_t2, row_td,
                 row_off, classes):
        self.n = n
        self.cids = cids
        self.observed = observed
        self.n_enter = n_enter
        self.sid_of_rid = sid_of_rid
        self.row_rid = row_rid
        self.row_rank = row_rank
        self.row_t0 = row_t0
        self.row_t1 = row_t1
        self.row_t2 = row_t2
        self.row_td = row_td
        self.row_off = row_off
        self.classes = classes       # name -> (sid, rank, t, pos) key-sorted

    def __len__(self) -> int:
        return self.n

    def class_counts(self, name: str) -> np.ndarray:
        """Per-record entry count of one phase class (len ``n``)."""
        sid_arr = self.classes[name][0]
        counts = np.zeros(self.n, dtype=np.int64)
        if sid_arr.size:
            lo = np.searchsorted(sid_arr, self.sid_of_rid, side="left")
            hi = np.searchsorted(sid_arr, self.sid_of_rid, side="right")
            counts = hi - lo
        return counts

    def wait_counts(self) -> np.ndarray:
        """Per-record count of entered ranks that also dispatched (the
        async pairs — ``wait_enter`` rows in the 5-phase taxonomy)."""
        if self.row_rid.size == 0:
            return np.zeros(self.n, dtype=np.int64)
        return np.bincount(self.row_rid[~np.isnan(self.row_td)],
                           minlength=self.n)

    def record(self, i: int) -> CallRecord:
        """Materialize retired occurrence ``i`` as a :class:`CallRecord`
        (cold path: the ring/debug view)."""
        rec = CallRecord(int(self.cids[i]))
        sid = int(self.sid_of_rid[i])
        for name, target in (("enter", "enter"), ("slack", "slack_end"),
                             ("copy", "copy_end"), ("dispatch", "dispatch")):
            sid_arr, rank_arr, t_arr, pos_arr = self.classes[name]
            lo = np.searchsorted(sid_arr, sid, side="left")
            hi = np.searchsorted(sid_arr, sid, side="right")
            if hi > lo:
                o = np.argsort(pos_arr[lo:hi], kind="stable")
                setattr(rec, target,
                        dict(zip(rank_arr[lo:hi][o].tolist(),
                                 t_arr[lo:hi][o].tolist())))
        rec.observed = int(self.observed[i])
        return rec

    def records(self):
        for i in range(self.n):
            yield self.record(i)


class _Accum:
    """Streaming counters behind reports and snapshots.

    ``add_record`` replays the historical batch tally's inner loop against
    *running* sums — feeding records through in the same order as the old
    one-shot walk performs the identical float-addition sequence, which is
    what keeps the golden fixtures bit-for-bit stable across the
    streaming refactor.
    """

    __slots__ = ("n_records", "n_down", "slack", "copy", "busy",
                 "exploited", "e_base", "e_pol", "overlap")

    def __init__(self) -> None:
        self.n_records = 0
        self.n_down = 0
        self.slack = 0.0
        self.copy = 0.0
        self.busy = 0.0
        self.exploited = 0.0
        self.e_base = 0.0
        self.e_pol = 0.0
        self.overlap = 0.0

    def clone(self) -> "_Accum":
        c = _Accum()
        for f in _Accum.__slots__:
            setattr(c, f, getattr(self, f))
        return c


@dataclass
class GovernorReport:
    n_calls: int
    n_downshifts: int
    total_slack: float
    total_copy: float
    exploited_slack: float
    energy_baseline: float           # J during instrumented phases, no policy
    energy_policy: float             # J with the policy's P-state trajectory
    straggler_summary: Dict[int, float]
    stragglers: List[Tuple[int, float]]
    total_overlap: float = 0.0       # dispatch->wait seconds, accounted NON-slack
    n_theta_decisions: int = 0       # tuner adjustments booked (0 = fixed theta)

    @property
    def energy_saving_pct(self) -> float:
        # energy_policy can dip epsilon-negative when float cancellation
        # meets zero-length phases; clamp both edges so the percentage
        # stays in [0, 100] instead of exceeding it by rounding artifacts
        if self.energy_baseline <= 0:
            return 0.0
        return 100.0 * (1.0 - max(self.energy_policy, 0.0) / self.energy_baseline)

    def to_dict(self) -> Dict[str, object]:
        """JSON-ready view (trace artifacts, benchmarks) — one place, not
        per-consumer attribute scraping."""
        return {
            "n_calls": int(self.n_calls),
            "n_downshifts": int(self.n_downshifts),
            "total_slack": float(self.total_slack),
            "total_copy": float(self.total_copy),
            "exploited_slack": float(self.exploited_slack),
            "energy_baseline": float(self.energy_baseline),
            "energy_policy": float(self.energy_policy),
            "energy_saving_pct": float(self.energy_saving_pct),
            "straggler_summary": {int(r): float(v) for r, v in self.straggler_summary.items()},
            "stragglers": [[int(r), float(z)] for r, z in self.stragglers],
            "total_overlap": float(self.total_overlap),
            "n_theta_decisions": int(self.n_theta_decisions),
        }


@dataclass
class IntervalStats:
    """Slack/energy booked between two ``interval_snapshot`` calls."""

    n_calls: int
    n_downshifts: int
    slack: float
    copy: float
    busy: float                      # sum over ranks of enter->copy_end spans
    exploited: float
    energy_baseline: float
    energy_policy: float
    overlap: float = 0.0             # dispatch->wait seconds booked non-slack

    @property
    def exploited_ratio(self) -> float:
        """Fraction of instrumented rank-time the policy spent at f_min —
        the arbiter's signal that this job has watts to give away."""
        return self.exploited / self.busy if self.busy > 0 else 0.0

    @property
    def overlap_ratio(self) -> float:
        """Overlap seconds per instrumented busy second — distinguishes an
        overlap-heavy job (compute hidden under flying collectives: watts
        convert to progress) from a slack-heavy one (watts stranded)."""
        return self.overlap / self.busy if self.busy > 0 else 0.0


class Governor:
    """Streaming engine: reconstructs phases from bus events, applies the
    policy, and keeps O(1)-memory accounting.

    Subscribe it to an :class:`~repro.core.events.EventBus` (it exposes the
    canonical ``on_event``/``on_phase`` consumer interface) or feed it
    directly through :meth:`sink` / :meth:`ingest_phase`.

    ``retention`` bounds the debugging ring of retired
    :class:`CallRecord` occurrences (``recent_records()``); accounting
    never needs them back.  ``log_retention`` optionally bounds the
    actuation/theta decision logs the same way — counts survive eviction
    (``n_actuations``, and ``n_theta_decisions`` on the report).
    """

    def __init__(
        self,
        policy: Policy = COUNTDOWN_SLACK,
        hw: HwModel = DEFAULT_HW,
        detector: Optional[StragglerDetector] = None,
        recorder=None,
        tuner: Optional[ThetaTuner] = None,
        retention: int = 256,
        log_retention: Optional[int] = None,
    ):
        self.policy = policy
        self.hw = hw
        self.detector = detector or StragglerDetector()
        self.recorder = recorder     # cluster.trace.TraceRecorder-compatible
        # Recorder hooks are resolved once: sink() runs per event, so an
        # absent hook must cost one None check, not a getattr + no-op call.
        # A recorder exposing the *spine* hooks (``on_actuation_pair``,
        # ``on_retired`` — see repro.obs.tracer.GovernorTap) keeps the
        # lazy/cheap paths the bare governor uses; one exposing only the
        # eager ``on_actuation`` (cluster.trace.TraceRecorder) still gets
        # fully-built Actuation values in stream order.
        self._rec_event = getattr(recorder, "on_event", None)
        self._rec_phase = getattr(recorder, "on_phase", None)
        self._rec_act = getattr(recorder, "on_actuation", None)
        self._rec_theta = getattr(recorder, "on_theta", None)
        self._rec_pred = getattr(recorder, "on_predictor", None)
        self._rec_pair = getattr(recorder, "on_actuation_pair", None)
        self._rec_retire = getattr(recorder, "on_retired", None)
        self._rec_retire_batch = getattr(recorder, "on_retired_batch", None)
        if tuner is None and policy.theta_mode == "adaptive":
            tuner = ThetaTuner(hw=hw, theta0=policy.theta)
        elif tuner is None and policy.theta_mode in ("predictive", "predict_only"):
            # predict_only is the paper's strawman: pre-arm on ANY
            # predicted slack, no reactive fallback, no guard
            # (PredictiveTuner zeroes the arm bar for that configuration) —
            # the misprediction cost it incurs is the point
            hyb = policy.theta_mode == "predictive"
            tuner = PredictiveTuner(
                hw=hw, theta0=policy.theta, reactive=hyb, guarded=hyb,
            )
        self.tuner = tuner
        self._predictive = isinstance(tuner, PredictiveTuner)
        self.retention = int(retention)
        # call_ids are assigned at TRACE time, so the same id recurs on every
        # executed step: rotate to a fresh occurrence when a rank re-enters,
        # retiring the previous one into the accumulators + ring
        self._calls: Dict[int, CallRecord] = {}
        self._ring: collections.deque = collections.deque(maxlen=self.retention)
        self._acc = _Accum()         # cumulative, behind finalize()
        self._mark = _Accum()        # checkpoint of _acc at the last snapshot
        self._last_end: Dict[int, float] = {}   # rank -> last phase end (the
        # enter-minus-this gap is the rank's compute, widening the tuner's
        # overhead budget to the time-to-completion denominator)
        self._lock = threading.Lock()
        self.n_actuations = 0
        # the log materializes lazily: the hot path appends one compact
        # (t, rank, call_id, slack) spine tuple per pair and the
        # ``actuation_log`` property expands it on first read (eagerly only
        # under a recorder, which needs the pair in stream order).  Under
        # log_retention the spine is ring-bounded too — each entry expands
        # to a pair, so half the retention covers the whole window and an
        # unread governor stays bounded-RSS on week-long runs
        self._act_raw = (
            collections.deque(maxlen=(log_retention + 1) // 2)
            if log_retention is not None else []
        )
        self._act_log: List[Actuation] = (
            collections.deque(maxlen=log_retention) if log_retention is not None
            else []
        )
        self._n_theta = 0
        self._theta_log = (
            collections.deque(maxlen=log_retention) if log_retention is not None
            else []
        )
        self._n_pred = 0
        self._pred_log = (
            collections.deque(maxlen=log_retention) if log_retention is not None
            else []
        )
        # policy/hw are frozen for the governor's lifetime: pre-derive the
        # per-event constants off the hot path
        self._theta_default = policy.theta
        self._timeout_armed = policy.comm_mode in ("timeout", "predict_timeout")
        self._scope_comm = policy.comm_scope == "comm"
        # float() strips the numpy scalar wrapper: identical IEEE doubles,
        # faster accumulate arithmetic
        self._w_slack_hi = float(hw.watts(hw.f_max, hw.act_slack))
        self._w_slack_lo = float(hw.watts(hw.f_min, hw.act_slack))
        self._w_copy_hi = float(hw.watts(hw.f_max, hw.act_copy))
        self._w_copy_lo = float(hw.watts(hw.f_min, hw.act_copy))
        self._theta_eff: Dict[float, float] = {}     # theta -> hw.theta_eff

    def _actuate(self, t: float, rank: int, call_id: int, slack: float) -> None:
        self.n_actuations += 2
        rec_pair = self._rec_pair
        if rec_pair is not None:
            # spine-aware recorder: keep the lazy path (one tuple append)
            # and hand it the compact pair
            self._act_raw.append((t, rank, call_id, slack))
            rec_pair(t, rank, call_id, slack)
            return
        if self._rec_act is None:
            # no recorder, or one that (like the obs GovernorTap) reads
            # actuations back from the spine log after the run instead of
            # paying a per-downshift call on the hot path
            self._act_raw.append((t, rank, call_id, slack))
            return
        pair = (
            Actuation(t, rank, "set_pstate_min", call_id, slack),
            Actuation(t, rank, "restore_pstate_max", call_id, slack),
        )
        self._act_log.extend(pair)
        for act in pair:
            self._rec_act(act)

    @property
    def actuation_log(self) -> List[Actuation]:
        """Every P-state pair booked so far (cold read: pending spine
        tuples are expanded into :class:`Actuation` values on access).

        Always a ``list``: the live backing list when unbounded, a snapshot
        copy of the retention ring under ``log_retention`` (a deque would
        compare unequal to a replayed governor's list even element-for-
        element identical).
        """
        raw = self._act_raw
        if raw:
            with self._lock:
                log = self._act_log
                for entry in raw:
                    if type(entry) is _ActBlock:
                        # batched spine block: expand in stream order
                        for t, rank, call_id, slack in zip(
                                entry.t.tolist(), entry.rank.tolist(),
                                entry.call_id.tolist(), entry.slack.tolist()):
                            log.append(Actuation(t, rank, "set_pstate_min",
                                                 call_id, slack))
                            log.append(Actuation(t, rank, "restore_pstate_max",
                                                 call_id, slack))
                        continue
                    t, rank, call_id, slack = entry
                    log.append(Actuation(t, rank, "set_pstate_min", call_id, slack))
                    log.append(Actuation(t, rank, "restore_pstate_max", call_id, slack))
                raw.clear()
        log = self._act_log
        return log if type(log) is list else list(log)

    def _record_theta(self, dec: Optional[ThetaDecision]) -> None:
        if dec is None:
            return
        self._n_theta += 1
        self._theta_log.append(dec)
        if self._rec_theta is not None:
            self._rec_theta(dec)

    @property
    def theta_log(self) -> List[ThetaDecision]:
        """Tuner decisions booked so far — always a ``list`` (a snapshot
        copy of the retention ring under ``log_retention``), mirroring
        :attr:`actuation_log` so cross-governor comparisons stay honest."""
        log = self._theta_log
        return log if type(log) is list else list(log)

    def _record_pred(self, dec: PredictorDecision) -> None:
        self._n_pred += 1
        self._pred_log.append(dec)
        if self._rec_pred is not None:
            self._rec_pred(dec)

    @property
    def n_predictor_decisions(self) -> int:
        """Predictor-path records booked so far (pre-arms, mispredictions,
        guard trips) — survives ``log_retention`` eviction."""
        return self._n_pred

    @property
    def predictor_log(self) -> List[PredictorDecision]:
        """Predictor decisions booked so far — always a ``list``, mirroring
        :attr:`theta_log`."""
        log = self._pred_log
        return log if type(log) is list else list(log)

    def _close_slack(self, rec: CallRecord, rank: int, t: float) -> None:
        """Shared barrier_exit tail: price the slack against the (possibly
        tuned or pre-armed) threshold, book the actuation pair, feed the
        tuner (and, under a predictive tuner, the guard + predictor)."""
        rec.slack_end[rank] = t
        t0 = rec.enter.get(rank, t)
        slack = t - t0
        if self.tuner is None:
            theta = self._theta_default
        else:
            key = rec.site if rec.site is not None else rec.call_id
            theta = self.tuner.theta_for(key)   # threshold armed BEFORE this obs
            armed = False
            pred = float("nan")
            src = ""
            if self._predictive:
                # the pre-arm decision is causal: it consults predictor +
                # guard state from strictly before this occurrence
                armed, pred, src = self.tuner.decide(key, rank)
                if armed:
                    if rec.prearm is None:
                        rec.prearm = {}
                    rec.prearm[rank] = theta    # displaced reactive threshold
                    theta = 0.0                 # downshift issued at entry:
                    # only the PCU commit quantization (theta_eff(0)) gates it
                elif not self.tuner.reactive:
                    theta = float("inf")        # prediction-only: no fallback
            rec.theta_used[rank] = theta
            last = self._last_end.get(rank)
            comp = max(t0 - last, 0.0) if last is not None else 0.0
            self._record_theta(
                self.tuner.observe_slack(key, slack, t, rank=rank, comp=comp)
            )
            if self._predictive:
                for pdec in self.tuner.account_outcome(
                        key, rank, t, pred, slack, armed, src, comp=comp):
                    self._record_pred(pdec)
        self._last_end[rank] = t
        if slack >= theta and self._timeout_armed:
            self._actuate(t, rank, rec.call_id, slack)

    def _close_copy(self, rec: CallRecord, rank: int, t: float) -> None:
        rec.copy_end[rank] = t
        self._last_end[rank] = t
        if self.tuner is None or rank not in rec.slack_end:
            return
        t1 = rec.slack_end[rank]
        slack = t1 - rec.enter.get(rank, t1)
        downshifted = slack >= rec.theta_used.get(rank, self._theta_default)
        key = rec.site if rec.site is not None else rec.call_id
        if self._predictive:
            if rec.prearm is not None:
                reactive_theta = rec.prearm.get(rank)
                if reactive_theta is not None and slack < reactive_theta:
                    # this downshift exists only because of the pre-arm — its
                    # copy stretch is misprediction cost, booked to the guard
                    for pdec in self.tuner.guard_copy(key, t - t1, t, rank=rank):
                        self._record_pred(pdec)
            self.tuner.predictor.note_copy(key, rank, t - t1)
        self._record_theta(
            self.tuner.observe_copy(key, t - t1, t, rank=rank, downshifted=downshifted)
        )

    # streaming accounting ----------------------------------------------------
    def _accumulate(self, rec: CallRecord, acc: _Accum) -> None:
        """Fold one record into running sums — the historical batch tally's
        inner loop, verbatim in addition order, against persistent
        accumulators (the sums ride in locals across the rank loop; same
        float sequence, one attribute write per field per record)."""
        acc.n_records += 1
        enter = rec.enter
        if not enter:
            return
        slack_end = rec.slack_end
        copy_end = rec.copy_end
        dispatch = rec.dispatch
        theta_used = rec.theta_used
        theta_eff_of = self._theta_eff
        default_theta = self._theta_default
        # fixed-theta records (no tuner) price one threshold: hoist the
        # two per-rank dict lookups out of the loop
        te_fixed = None
        if not theta_used:
            te_fixed = theta_eff_of.get(default_theta)
            if te_fixed is None:
                te_fixed = self.hw.theta_eff(default_theta)
                theta_eff_of[default_theta] = te_fixed
        w_slack_hi, w_slack_lo = self._w_slack_hi, self._w_slack_lo
        w_copy_hi, w_copy_lo = self._w_copy_hi, self._w_copy_lo
        scope_comm = self._scope_comm
        n_down = acc.n_down
        a_slack, a_copy, a_busy = acc.slack, acc.copy, acc.busy
        a_expl, a_ebase, a_epol, a_ov = (acc.exploited, acc.e_base,
                                         acc.e_pol, acc.overlap)
        for rank, t0 in enter.items():
            t1 = slack_end.get(rank)
            if t1 is None:
                continue
            # async pair: [dispatch, enter] is compute/comm overlap — the
            # core is busy, so it is *not* slack and is not priced here
            # (the caller's compute never is); it is reported separately
            if dispatch:
                td = dispatch.get(rank)
                if td is not None:
                    ov = t0 - td
                    if ov > 0.0:
                        a_ov += ov
            slack = t1 - t0
            if slack < 0.0:
                slack = 0.0
            a_slack += slack
            t2 = copy_end.get(rank)
            copy = 0.0 if t2 is None else t2 - t1
            if copy < 0.0:
                copy = 0.0
            a_copy += copy
            a_busy += slack + copy
            a_ebase += w_slack_hi * slack
            a_ebase += w_copy_hi * copy
            if te_fixed is not None:
                theta_eff = te_fixed
            else:
                theta = theta_used.get(rank, default_theta)
                theta_eff = theta_eff_of.get(theta)
                if theta_eff is None:
                    if len(theta_eff_of) >= 4096:
                        # adaptive tuners mint a fresh theta per decision;
                        # the memo must not become the history it replaces
                        theta_eff_of.clear()
                    theta_eff = self.hw.theta_eff(theta)
                    theta_eff_of[theta] = theta_eff
            low = slack - theta_eff
            if low > 0.0:
                n_down += 1
                a_expl += low
            else:
                low = 0.0
            a_epol += w_slack_hi * (slack - low)
            a_epol += w_slack_lo * low
            if scope_comm and low > 0.0:
                a_epol += w_copy_lo * copy
            else:
                a_epol += w_copy_hi * copy
        acc.n_down = n_down
        acc.slack, acc.copy, acc.busy = a_slack, a_copy, a_busy
        acc.exploited, acc.e_base, acc.e_pol, acc.overlap = (
            a_expl, a_ebase, a_epol, a_ov)

    def _observe(self, rec: CallRecord) -> None:
        """Feed an occurrence's arrivals to the straggler detector, at most
        once per arrival set: a record partially observed by a mid-run
        finalize() is observed again if new ranks entered since."""
        n = len(rec.enter)
        if n > rec.observed:
            rec.observed = n
            self.detector.observe_barrier(rec.enter)

    def _retire(self, rec: CallRecord) -> None:
        """A call occurrence is final: observe its arrivals, fold it into
        the cumulative accumulators, evict it into the bounded ring."""
        self._observe(rec)
        self._accumulate(rec, self._acc)
        self._ring.append(rec)

    # the bus consumer interface ----------------------------------------------
    def sink(self, rank: int, phase: str, call_id: int, t: float) -> None:
        with self._lock:
            # recorded under the lock: the trace order must be the order the
            # governor processed events in, or replay() loses bit-exactness
            if self._rec_event is not None:
                self._rec_event(rank, phase, call_id, t)
            calls = self._calls
            rec = calls.get(call_id)
            if rec is None:
                rec = CallRecord(call_id)
                calls[call_id] = rec
            elif rec.__class__ is not CallRecord:
                # in-flight tail left columnar by the batched path: a
                # per-event producer is cutting in — materialize once
                rec = rec.to_record(call_id)
                calls[call_id] = rec
            if phase == "barrier_enter":
                if rank in rec.enter or rank in rec.dispatch:
                    self._retire(rec)                   # new occurrence
                    if self._rec_retire is not None:
                        self._rec_retire(rec)
                    rec = CallRecord(call_id)
                    calls[call_id] = rec
                rec.enter[rank] = t
            elif phase == "barrier_exit":
                if self.tuner is None:
                    # _close_slack without the tuner bookkeeping, inlined:
                    # this is the single hottest branch of the runtime
                    rec.slack_end[rank] = t
                    self._last_end[rank] = t
                    slack = t - rec.enter.get(rank, t)
                    if slack >= self._theta_default and self._timeout_armed:
                        self._actuate(t, rank, call_id, slack)
                else:
                    self._close_slack(rec, rank, t)
            elif phase == "copy_exit":
                if self.tuner is None:
                    rec.copy_end[rank] = t
                    self._last_end[rank] = t
                else:
                    self._close_copy(rec, rank, t)
            elif phase == "dispatch_enter":
                if rank in rec.enter or rank in rec.dispatch:
                    self._retire(rec)                   # new occurrence
                    if self._rec_retire is not None:
                        self._rec_retire(rec)
                    rec = CallRecord(call_id)
                    calls[call_id] = rec
                rec.dispatch[rank] = t                  # overlap starts
            elif phase == "wait_enter":
                rec.enter[rank] = t                     # slack starts at the wait

    on_event = sink          # canonical EventBus subscriber method

    # batched ingest ------------------------------------------------------------
    def on_batch(self, batch: EventBatch) -> None:
        """Consume one columnar event chunk (the EventBus ``publish_batch``
        consumer) — observably identical to feeding the same events through
        :meth:`sink` one at a time, bit for bit: reports, snapshots,
        actuation log, straggler state and the retention ring all match.

        The vectorized fast path folds the chunk with numpy in the exact
        float-addition order of the per-event path (``np.add.accumulate``
        is a strictly sequential left fold, so prepending the running
        accumulator replays the scalar ``+=`` chain).  It engages when
        nothing needs per-event callbacks: a tuner (sequential per-
        observation feedback), an ``on_event`` recorder, or an
        ``on_retired`` recorder without the batch-capable
        ``on_retired_batch`` hook all fall back to an internal per-event
        replay — as do pathologically malformed streams (duplicate
        same-phase events for one rank inside one occurrence), detected
        *before* any state is touched.
        """
        # rank/code keep their narrow dtypes: integer key arithmetic
        # upcasts where needed, and materialization always goes through
        # tolist() (python ints) -- no copies on the hot path
        rk = np.asarray(batch.rank)
        cd = np.asarray(batch.code)
        ci = np.asarray(batch.call_id).astype(np.int64, copy=False)
        ts = np.asarray(batch.t, dtype=np.float64)
        if rk.shape[0] == 0:
            return
        if (self.tuner is not None or self._rec_event is not None
                or (self._rec_retire is not None
                    and self._rec_retire_batch is None)):
            self._sink_loop(rk, cd, ci, ts)
            return
        with self._lock:
            ok = self._batch_fast(rk, cd, ci, ts)
        if not ok:
            self._sink_loop(rk, cd, ci, ts)

    def _sink_loop(self, rk, cd, ci, ts) -> None:
        """Per-event replay of a chunk: the correctness reference and the
        fallback for consumers/streams the fast path cannot serve."""
        names = PHASE_NAMES
        sink = self.sink
        for r, c, i, t in zip(rk.tolist(), cd.tolist(), ci.tolist(),
                              ts.tolist()):
            sink(r, names.get(c, f"code_{c}"), i, t)

    def _batch_fast(self, rk, cd, ci, ts) -> bool:
        """Vectorized chunk fold (lock held).  Returns False — with no
        state touched — when the stream needs the per-event replay.

        The pipeline: group events by call id; find occurrence-rotation
        boundaries (a rank re-entering — the per-event rule, via a
        segmented previous-same-rank-write scan); assign every retired
        segment a global retirement index ordered by its trigger event's
        stream position; join enter/slack/copy/dispatch per (segment,
        rank); then fold each accumulator chain with
        ``np.add.accumulate`` seeded by its running value, padding
        skipped terms with ``+0.0`` (bitwise identity: the accumulators
        are non-negative).  Open tails stay columnar in ``_calls`` as
        :class:`_Tail` views and seed the next chunk's first segments.
        """
        n = rk.shape[0]
        if int(rk.min()) < 0:
            return False             # negative ranks break the key packing
        if int(cd.min()) < 0 or int(cd.max()) > 4:
            return False             # unknown phase codes: replay per-event
            # (sink() ignores them but still creates the call record)
        # ---- 1. group by call id (stable sort: stream order within) ----
        # stable int argsort is a byte-wise LSD radix sort, so shifting the
        # ids into the narrowest unsigned dtype that holds their span cuts
        # radix passes; the order (hence the bitwise fold) is unchanged
        cmin, cmax = int(ci.min()), int(ci.max())
        span = cmax - cmin + 1
        if span <= 256:
            ord_c = (ci - cmin).astype(np.uint8).argsort(kind="stable")
        elif span <= 65536:
            ord_c = (ci - cmin).astype(np.uint16).argsort(kind="stable")
        elif -2 ** 31 <= cmin and cmax < 2 ** 31:
            ord_c = ci.astype(np.int32).argsort(kind="stable")
        else:
            ord_c = ci.argsort(kind="stable")
        ci_s = ci[ord_c]
        new_g = np.empty(n, dtype=bool)
        new_g[0] = True
        np.not_equal(ci_s[1:], ci_s[:-1], out=new_g[1:])
        gstart = np.nonzero(new_g)[0]
        n_groups = gstart.shape[0]
        gcids = ci_s[gstart]
        # group indices fit int32 (a chunk is memory-bounded far below
        # 2^31 events) — and int32 keys halve the radix sorts below
        gidx_s = np.cumsum(new_g, dtype=np.int32)
        gidx_s -= 1
        gidx = np.empty(n, dtype=np.int32)
        gidx[ord_c] = gidx_s
        gcids_l = gcids.tolist()
        calls = self._calls
        tails: List[Optional[_Tail]] = []
        for c in gcids_l:
            tl = calls.get(c)
            if tl is not None and tl.__class__ is CallRecord:
                tl = _Tail.from_record(tl)   # pure: not written back unless
                tails.append(tl)             # the batch commits
            else:
                tails.append(tl)
        carried = [(g, tl) for g, tl in enumerate(tails) if tl is not None]
        # the (segment, rank) packing key must cover carried-in ranks too —
        # a chunk touching only low ranks can inherit a tail from a wider one
        R = int(rk.max()) + 1
        if carried:
            c_rks = [a for _, tl in carried
                     for a in (tl.e_rk, tl.s_rk, tl.c_rk, tl.d_rk) if a.size]
            if c_rks:
                all_c = np.concatenate(c_rks)
                if int(all_c.min()) < 0:
                    return False
                hi = int(all_c.max()) + 1
                if hi > R:
                    R = hi
        # ---- 2. previous same-(group, rank) write (codes 0/3/4) ----
        # writes = events that put the rank into enter/dispatch (the
        # rotation rule's membership); only they need sorting, and a
        # write's predecessor within its (group, rank) run is simply the
        # previous element
        # integer index lists beat boolean-mask gathers ~6x here: a mask
        # gather rescans all n elements per column, nonzero pays that once
        w_idx = np.nonzero((cd == 0) | (cd >= 3))[0]
        w_pos = w_idx                  # pos is arange(n): pos[w_idx] == w_idx
        w_gi = gidx[w_idx]
        w_rk = rk[w_idx]
        if n_groups * R <= 65536:
            w_key = (w_gi * R
                     + w_rk.astype(np.int32, copy=False)).astype(np.uint16)
        elif n_groups * R < 2 ** 31:
            w_key = w_gi * R + w_rk.astype(np.int32, copy=False)
        else:
            w_key = w_gi.astype(np.int64) * R + w_rk
        nw = w_pos.shape[0]
        prev_w = np.empty(nw, dtype=np.int64)
        if nw:
            ow = w_key.argsort(kind="stable")
            k_s = w_key[ow]
            run_start = np.empty(nw, dtype=bool)
            run_start[0] = True
            np.not_equal(k_s[1:], k_s[:-1], out=run_start[1:])
            prev_s = np.empty(nw, dtype=np.int64)
            prev_s[0] = -1
            prev_s[1:] = w_pos[ow][:-1]
            prev_s[run_start] = -1
            prev_w[ow] = prev_s
        # ---- 3. boundary scan: rotations, per group in stream order ----
        w_cd = cd[w_idx]
        t_idx = np.nonzero(w_cd != 4)[0]     # codes 0 and 3 trigger rotation
        trig_g = w_gi[t_idx]
        if n_groups <= 256:
            t_ord = trig_g.astype(np.uint8).argsort(kind="stable")
        elif n_groups <= 65536:
            t_ord = trig_g.astype(np.uint16).argsort(kind="stable")
        else:
            t_ord = trig_g.argsort(kind="stable")
        tio = t_idx[t_ord]
        tg = trig_g[t_ord]
        t_lo = tg.searchsorted(np.arange(n_groups, dtype=np.int32))
        t_hi = np.append(t_lo[1:], tg.shape[0])
        tp_arr = w_pos[tio]
        tv_arr = prev_w[tio]
        tr_arr = w_rk[tio]
        t_lo_l, t_hi_l = t_lo.tolist(), t_hi.tolist()
        # A group whose trigger prev-write sequence is non-decreasing admits
        # a searchsorted boundary chain: the next boundary after seg_start
        # is the first trigger with prev >= seg_start, so the walk costs one
        # step per *boundary* instead of one per *trigger*.  Real streams
        # (ranks re-entering in a stable order) are monotone; anything else
        # drops to the literal per-trigger scan for that group.
        nonmono = np.zeros(n_groups, dtype=bool)
        any_nonmono = False
        if tv_arr.shape[0] > 1:
            bad = (tv_arr[1:] < tv_arr[:-1]) & (tg[1:] == tg[:-1])
            if bad.any():
                nonmono[tg[1:][bad]] = True
                any_nonmono = True
        if not any_nonmono and tg.shape[0]:
            # every group monotone: the chain of boundaries is pointer
            # jumping through "first trigger with prev >= p" successors,
            # and every group's chain advances in lockstep — one
            # vectorized searchsorted per *wave* (the w-th boundary of
            # every still-active group) over (group, prev)-packed keys,
            # so the walk costs O(max boundaries per group) searchsorteds
            # instead of one successor per trigger.  Keys partition by
            # group, so a miss lands at/after the next group's run and
            # the "< t_hi" liveness test simply retires the group.
            big2 = n + 1
            small_tv = n_groups * big2 < 2 ** 31
            if small_tv:
                kg = tg * np.int32(big2)
                key_tv = kg + (tv_arr + 1).astype(np.int32)
                j_cur = key_tv.searchsorted(
                    np.arange(n_groups, dtype=np.int32) * np.int32(big2) + 1)
            else:
                kg = tg.astype(np.int64) * big2
                key_tv = kg + (tv_arr + 1)
                j_cur = key_tv.searchsorted(
                    np.arange(n_groups, dtype=np.int64) * big2 + 1)
            if carried:
                # a pre-boundary trigger with no in-chunk prev still
                # rotates if its rank lives in the carried tail
                j_l = j_cur.tolist()
                for g, tl in carried:
                    lo, j = t_lo_l[g], j_l[g]
                    if j > lo:
                        seen = tl.seen
                        for jj in range(lo, j):
                            if int(tr_arr[jj]) in seen:
                                j_cur[g] = jj
                                break
            wave_g: List[np.ndarray] = []
            wave_p: List[np.ndarray] = []
            alive = np.nonzero(j_cur < t_hi)[0]
            while alive.size:
                j = j_cur[alive]
                p = tp_arr[j]                # strictly ascending per group:
                wave_g.append(alive)         # prev(j) < pos(j), so the
                wave_p.append(p)             # successor is always beyond j
                if small_tv:
                    nxt = key_tv.searchsorted(
                        kg[j] + (p + 1).astype(np.int32))
                else:
                    nxt = key_tv.searchsorted(kg[j] + (p + 1))
                j_cur[alive] = nxt
                alive = alive[nxt < t_hi[alive]]
            if wave_g:
                all_g = np.concatenate(wave_g)
                all_p = np.concatenate(wave_p)
                m = all_g.shape[0]
                nb_g = np.bincount(all_g, minlength=n_groups)
                # group-major boundary order == per-group chain order
                # (stable sort keeps the ascending wave order per group)
                if n_groups <= 256:
                    gor = all_g.astype(np.uint8).argsort(kind="stable")
                elif n_groups <= 65536:
                    gor = all_g.astype(np.uint16).argsort(kind="stable")
                else:
                    gor = all_g.argsort(kind="stable")
                sg_sorted = all_g[gor]
                p_sorted = all_p[gor]
            else:
                m = 0
                nb_g = np.zeros(n_groups, dtype=np.int64)
                sg_sorted = p_sorted = _EMPTY_I
            seg_cnt = nb_g + 1
            grp_lo_arr = np.zeros(n_groups + 1, dtype=np.int64)
            np.cumsum(seg_cnt, out=grp_lo_arr[1:])
            n_segs = int(grp_lo_arr[-1])
            seg_g = np.repeat(np.arange(n_groups, dtype=np.int64), seg_cnt)
            sp_arr = np.full(n_segs, -1, dtype=np.int64)
            if m:
                nb_lo = np.zeros(n_groups, dtype=np.int64)
                np.cumsum(nb_g[:-1], out=nb_lo[1:])
                # boundary w of group g retires segment grp_lo[g] + w and
                # opens grp_lo[g] + w + 1 at the trigger position
                rs_arr = (grp_lo_arr[sg_sorted]
                          + np.arange(m, dtype=np.int64) - nb_lo[sg_sorted])
                sp_arr[rs_arr + 1] = p_sorted
                rp_arr = p_sorted
            else:
                rs_arr = rp_arr = _EMPTY_I
            grp_seg_lo = grp_lo_arr.tolist()
        else:
            nonmono_l = nonmono.tolist()
            seg_gidx: List[int] = []
            seg_sp: List[int] = []           # segment start pos (-1: head)
            grp_seg_lo = [0] * (n_groups + 1)
            ret_pos: List[int] = []          # trigger pos per retired segment
            ret_seg: List[int] = []
            sg_append, sp_append = seg_gidx.append, seg_sp.append
            rp_append, rs_append = ret_pos.append, ret_seg.append
            for g in range(n_groups):
                grp_seg_lo[g] = len(seg_gidx)
                sg_append(g)
                sp_append(-1)
                tl = tails[g]
                carry_active = tl is not None
                lo, hi = t_lo_l[g], t_hi_l[g]
                if lo == hi:
                    continue
                if nonmono_l[g]:
                    seen = None              # built lazily: only a carried
                    seg_start = 0            # group's pre-boundary triggers
                    for j in range(lo, hi):  # consult it
                        pv = tv_arr[j]
                        if pv < seg_start:
                            if not carry_active:
                                continue
                            if seen is None:
                                seen = tl.seen
                            if int(tr_arr[j]) not in seen:
                                continue
                        p = int(tp_arr[j])
                        rp_append(p)
                        rs_append(len(seg_gidx) - 1)
                        sg_append(g)
                        sp_append(p)
                        seg_start = p
                        carry_active = False
                    continue
                # per-group successor table: if trigger j rotates at pos
                # p, the next boundary is the first trigger with
                # prev >= p -- then the chain is pure pointer jumping
                tvg = tv_arr[lo:hi]
                nxt_g = (tvg.searchsorted(tp_arr[lo:hi]) + lo).tolist()
                j = int(tvg.searchsorted(0)) + lo
                if carry_active and j > lo:
                    # a pre-boundary trigger with no in-chunk prev still
                    # rotates if its rank lives in the carried tail
                    seen = tl.seen
                    for jj in range(lo, j):
                        if int(tr_arr[jj]) in seen:
                            j = jj
                            break
                while j < hi:
                    p = int(tp_arr[j])
                    rp_append(p)
                    rs_append(len(seg_gidx) - 1)
                    sg_append(g)
                    sp_append(p)
                    j = nxt_g[j - lo]
            grp_seg_lo[n_groups] = len(seg_gidx)
            seg_g = np.asarray(seg_gidx, dtype=np.int64)
            n_segs = seg_g.shape[0]
            sp_arr = np.asarray(seg_sp, dtype=np.int64)
            m = len(ret_pos)
            rp_arr = np.asarray(ret_pos, dtype=np.int64)
            rs_arr = np.asarray(ret_seg, dtype=np.int64)
        # event -> segment in O(n): segments are emitted in (group, pos)
        # order and group-sorted events are pos-ordered within each group,
        # so each segment covers a contiguous run starting at its trigger's
        # group-sorted index (group head: the group's first event).  The
        # (group-major, pos-ascending) key over sorted events is strictly
        # monotone, so the few boundary lookups are binary searches
        # instead of a full inverse-permutation scatter.
        head = sp_arr < 0
        seg_start_ix = np.empty(n_segs, dtype=np.int64)
        seg_start_ix[head] = gstart
        kq = gidx_s.astype(np.int64) * n + ord_c
        nh = ~head
        seg_start_ix[nh] = kq.searchsorted(seg_g[nh] * n + sp_arr[nh])
        counts = np.diff(np.append(seg_start_ix, n))
        sid = np.empty(n, dtype=np.int64)
        sid[ord_c] = np.repeat(np.arange(n_segs, dtype=np.int64), counts)
        # ---- 4. retirement order: global trigger-position order ----
        rid_of_seg = np.full(n_segs, -1, dtype=np.int64)
        if m:
            rp = rp_arr.astype(np.int32, copy=False)   # positions < n
            rorder = rp.argsort(kind="stable")
            sid_of_rid = rs_arr[rorder]
            rid_of_seg[sid_of_rid] = np.arange(m, dtype=np.int64)
        else:
            sid_of_rid = _EMPTY_I
        # ---- 5. per-class (segment, rank) tables, carry first ----
        if carried:
            base_sids = np.asarray([grp_seg_lo[g] for g, _ in carried],
                                   dtype=np.int64)

        def carry_cols(attr_rk, attr_t):
            """Concatenate one class across every carried tail: sids by
            repeat, positions ``-k..-1`` per tail (before any batch event
            under the stable keysort) via one arange minus group ends."""
            if not carried:
                return None
            rks = [getattr(tl, attr_rk) for _, tl in carried]
            cnt = np.asarray([a.shape[0] for a in rks], dtype=np.int64)
            tot = int(cnt.sum())
            if tot == 0:
                return None
            s = np.repeat(base_sids, cnt)
            r = np.concatenate(rks)
            t = np.concatenate([getattr(tl, attr_t) for _, tl in carried])
            p = (np.arange(tot, dtype=np.int64)
                 - np.repeat(np.cumsum(cnt), cnt))
            return s, r, t, p

        small_key = n_segs * R < 2 ** 31
        if small_key:
            sid_k = sid.astype(np.int32)
            rk_k = rk.astype(np.int32, copy=False)
        else:
            sid_k, rk_k = sid, rk
        key_u16 = n_segs * R <= 65536

        def cls_table(idx, carry):
            ev_key = sid_k[idx] * R + rk_k[idx]
            if carry is not None:
                cs, cr, ct2, cp2 = carry
                s = np.concatenate((cs, sid[idx]))
                r = np.concatenate((cr, rk[idx]))
                t = np.concatenate((ct2, ts[idx]))
                p = np.concatenate((cp2, idx))
                c_key = cs * R + cr
                key = np.concatenate(
                    (c_key.astype(ev_key.dtype, copy=False), ev_key))
            else:
                s, r, t, p = sid[idx], rk[idx], ts[idx], idx
                key = ev_key
            if key_u16:
                o = key.astype(np.uint16).argsort(kind="stable")
            else:
                o = key.argsort(kind="stable")
            ks = key[o]
            if ks.shape[0] > 1 and (ks[1:] == ks[:-1]).any():
                return None          # same-phase duplicate inside one segment
            return ks, s[o], r[o], t[o], p[o]

        ew = cls_table(np.nonzero((cd == 0) | (cd == 4))[0],
                       carry_cols("e_rk", "e_t"))
        s_idx = np.nonzero(cd == 1)[0]
        sl = cls_table(s_idx, carry_cols("s_rk", "s_t"))
        cp = cls_table(np.nonzero(cd == 2)[0], carry_cols("c_rk", "c_t"))
        dp = cls_table(np.nonzero(cd == 3)[0], carry_cols("d_rk", "d_t"))
        if ew is None or sl is None or cp is None or dp is None:
            return False
        # ---------------- point of no return: state mutation below ----------------
        acc = self._acc
        acc.n_records += m
        ek, es, er, et, ep = ew
        has_disp = np.zeros(n_segs, dtype=bool)
        if dp[0].size:
            has_disp[dp[1]] = True
        observed_base = np.zeros(m, dtype=np.int64) if m else _EMPTY_I
        if m and carried:
            obs = np.asarray([tl.observed for _, tl in carried],
                             dtype=np.int64)
            rid0 = rid_of_seg[base_sids]
            omask = (rid0 >= 0) & (obs > 0)
            observed_base[rid0[omask]] = obs[omask]
        # rows: one per entered rank of a retired segment, ordered by
        # (retirement index, dict-insertion position) — the per-event
        # accumulation sequence, concatenated
        e_rid = rid_of_seg[es]
        r_ix = np.nonzero(e_rid >= 0)[0]
        r_rid = e_rid[r_ix]
        r_sid = es[r_ix]
        r_rank = er[r_ix]
        r_t0 = et[r_ix]
        r_pos = ep[r_ix]
        if r_pos.size:
            shift = max(0, -int(r_pos.min()))
            rkey_o = r_rid * (n + shift + 1) + (r_pos + shift)
            if m * (n + shift + 1) < 2 ** 31:
                rkey_o = rkey_o.astype(np.int32)
            row_o = rkey_o.argsort(kind="stable")
            r_rid = r_rid[row_o]
            r_sid = r_sid[row_o]
            r_rank = r_rank[row_o]
            r_t0 = r_t0[row_o]
            r_pos = r_pos[row_o]
        n_enter = (np.bincount(r_rid, minlength=m) if m
                   else np.zeros(0, dtype=np.int64))

        # (segment, rank) keys live in a dense domain < n_segs*R, so when
        # that domain is about chunk-sized a scatter/gather lookup table
        # (one write + one read per key) beats per-key binary search
        lut_ok = small_key and n_segs * R <= 4 * n + 4096

        def join(cls, keys):
            ks = cls[0]
            if ks.size == 0 or keys.size == 0:
                return np.full(keys.shape, np.nan)
            if lut_ok:
                lut = np.full(n_segs * R, np.nan)
                lut[ks] = cls[3]
                return lut[keys]
            ix = np.minimum(ks.searchsorted(keys), ks.size - 1)
            return np.where(ks[ix] == keys, cls[3][ix], np.nan)

        rkey = r_sid * R + r_rank
        t1 = join(sl, rkey)
        t2 = join(cp, rkey)
        td = join(dp, rkey) if dp[0].size else np.full(rkey.shape, np.nan)
        valid = ~np.isnan(t1)
        slack = np.where(valid, t1 - r_t0, 0.0)
        slack = np.where(slack > 0.0, slack, 0.0)
        copyv = np.where(valid & ~np.isnan(t2), t2 - t1, 0.0)
        copyv = np.where(copyv > 0.0, copyv, 0.0)
        if dp[0].size:
            ovv = np.where(valid & has_disp[r_sid] & ~np.isnan(td),
                           r_t0 - td, 0.0)
            ovv = np.where(ovv > 0.0, ovv, 0.0)
        else:
            # no dispatches in scope: every overlap term is the +0.0 the
            # per-event replay would add, and +0.0 is a bitwise identity
            ovv = _EMPTY_F
        te_fixed = self._theta_eff.get(self._theta_default)
        if te_fixed is None:
            te_fixed = self.hw.theta_eff(self._theta_default)
            self._theta_eff[self._theta_default] = te_fixed
        low = slack - te_fixed
        down = valid & (low > 0.0)
        low = np.where(down, low, 0.0)
        w_slack_hi, w_slack_lo = self._w_slack_hi, self._w_slack_lo
        w_copy_hi, w_copy_lo = self._w_copy_hi, self._w_copy_lo
        nrows = slack.shape[0]
        eb = np.empty((nrows, 2))
        eb[:, 0] = w_slack_hi * slack
        eb[:, 1] = w_copy_hi * copyv
        ep3 = np.empty((nrows, 3))
        ep3[:, 0] = w_slack_hi * (slack - low)
        ep3[:, 1] = w_slack_lo * low
        if self._scope_comm:
            ep3[:, 2] = np.where(down, w_copy_lo, w_copy_hi) * copyv
        else:
            ep3[:, 2] = w_copy_hi * copyv

        def fold(start: float, terms: np.ndarray) -> float:
            # ufunc.accumulate is a strictly sequential left fold: this
            # replays the scalar `+=` chain bit for bit.  It consumes the
            # (freshly-built, chunk-local) term array: seeding by one
            # scalar add to the head (IEEE addition commutes bitwise) and
            # accumulating in place skips an alloc + full copy per fold.
            if terms.size == 0:
                return start
            flat = terms.ravel()
            flat[0] += start
            return float(np.add.accumulate(flat, out=flat)[-1])

        busy_t = slack + copyv               # before fold() consumes them
        acc.overlap = fold(acc.overlap, ovv)
        acc.slack = fold(acc.slack, slack)
        acc.copy = fold(acc.copy, copyv)
        acc.busy = fold(acc.busy, busy_t)
        acc.e_base = fold(acc.e_base, eb)
        acc.n_down += int(np.count_nonzero(down))
        acc.exploited = fold(acc.exploited, low)
        acc.e_pol = fold(acc.e_pol, ep3)
        # ---- 6. straggler detector: retired records with new arrivals ----
        if m:
            det_rec = (n_enter >= 2) & (n_enter > observed_base)
            if det_rec.all():
                # the common shape — every retired record qualifies —
                # skips the gather entirely
                off = np.zeros(m + 1, dtype=np.int64)
                np.cumsum(n_enter, out=off[1:])
                self.detector.observe_barriers_cols(r_rank, r_t0, off)
            elif det_rec.any():
                off = np.zeros(m + 1, dtype=np.int64)
                np.cumsum(n_enter, out=off[1:])
                det_rids = np.nonzero(det_rec)[0]
                counts = n_enter[det_rids]
                doff = np.zeros(det_rids.size + 1, dtype=np.int64)
                np.cumsum(counts, out=doff[1:])
                take = np.concatenate([
                    np.arange(off[i], off[i] + c) for i, c in
                    zip(det_rids.tolist(), counts.tolist())
                ])
                self.detector.observe_barriers_cols(
                    r_rank[take], r_t0[take], doff)
            observed_fin = np.maximum(n_enter, observed_base)
        else:
            observed_fin = _EMPTY_I
        # ---- 7. actuations: qualifying barrier_exit events, stream order ----
        if self._timeout_armed:
            a_idx = s_idx                    # the barrier_exit events again
            a_t = ts[a_idx]
            if a_t.size:
                a_sid = sid[a_idx]
                a_rank = rk[a_idx]
                a_pos = a_idx                # pos is arange(n)
                akey = a_sid * R + a_rank
                if ek.size:
                    if lut_ok:
                        et_lut = np.full(n_segs * R, np.nan)
                        et_lut[ek] = et
                        ep_lut = np.full(n_segs * R, n, dtype=np.int64)
                        ep_lut[ek] = ep
                        fnd = ep_lut[akey] < a_pos
                        t0a = np.where(fnd, et_lut[akey], a_t)
                    else:
                        ix = np.minimum(ek.searchsorted(akey), ek.size - 1)
                        fnd = (ek[ix] == akey) & (ep[ix] < a_pos)
                        t0a = np.where(fnd, et[ix], a_t)
                else:
                    t0a = a_t
                slk = a_t - t0a
                q_ix = np.nonzero(slk >= self._theta_default)[0]
                nq = q_ix.shape[0]
                if nq:
                    self.n_actuations += 2 * nq
                    rec_pair, rec_act = self._rec_pair, self._rec_act
                    ring_cap = (None if rec_pair is not None
                                or rec_act is not None
                                or type(self._act_raw) is list
                                else self._act_raw.maxlen)
                    if ring_cap is not None and nq > ring_cap:
                        # bounded spine ring: entries past the capacity
                        # would be evicted on arrival — gather only the
                        # survivors
                        q_ix = q_ix[-ring_cap:]
                    qt = a_t[q_ix]
                    qr = a_rank[q_ix]
                    qc = ci[a_idx[q_ix]]
                    qs = slk[q_ix]
                    if rec_pair is not None:
                        raw = self._act_raw
                        for row in zip(qt.tolist(), qr.tolist(),
                                       qc.tolist(), qs.tolist()):
                            raw.append(row)
                            rec_pair(*row)
                    elif rec_act is not None:
                        log = self._act_log
                        for t_, r_, c_, s_ in zip(qt.tolist(), qr.tolist(),
                                                  qc.tolist(), qs.tolist()):
                            pair = (Actuation(t_, r_, "set_pstate_min", c_, s_),
                                    Actuation(t_, r_, "restore_pstate_max",
                                              c_, s_))
                            log.extend(pair)
                            rec_act(pair[0])
                            rec_act(pair[1])
                    elif type(self._act_raw) is list:
                        self._act_raw.append(_ActBlock(qt, qr, qc, qs))
                    else:
                        self._act_raw.extend(zip(qt.tolist(), qr.tolist(),
                                                 qc.tolist(), qs.tolist()))
        # ---- 8. ring + batch recorder ----
        if m:
            row_off = np.zeros(m + 1, dtype=np.int64)
            np.cumsum(n_enter, out=row_off[1:])
            block = RetiredBlock(
                m, gcids[seg_g[sid_of_rid]], observed_fin, n_enter,
                sid_of_rid, r_rid, r_rank, r_t0, t1, t2, td, row_off,
                {"enter": (es, er, et, ep), "slack": sl[1:],
                 "copy": cp[1:], "dispatch": dp[1:]},
            )
            ring = self._ring
            cap = ring.maxlen
            start = 0 if cap is None or m <= cap else m - cap
            for i in range(start, m):
                ring.append((block, i))
            if self._rec_retire_batch is not None:
                self._rec_retire_batch(block)
        # ---- 9. open tails back into _calls, columnar ----
        tail_sids = np.asarray([grp_seg_lo[g + 1] - 1 for g in range(n_groups)],
                               dtype=np.int64)
        new_tails: List[Optional[_Tail]] = [None] * n_groups
        cls_cols = []
        for cls in (ew, sl, cp, dp):
            c_sid, c_rank, c_t, c_pos = cls[1], cls[2], cls[3], cls[4]
            t_ix = (np.nonzero(rid_of_seg[c_sid] < 0)[0] if c_sid.size
                    else _EMPTY_I)
            t_sid = c_sid[t_ix]
            t_rank = c_rank[t_ix]
            t_t = c_t[t_ix]
            t_pos = c_pos[t_ix]
            if t_pos.size:
                shift = max(0, -int(t_pos.min()))
                tkey = t_sid * (n + shift + 1) + (t_pos + shift)
                if n_segs * (n + shift + 1) < 2 ** 31:
                    tkey = tkey.astype(np.int32)
                o3 = tkey.argsort(kind="stable")
                t_sid, t_rank, t_t = t_sid[o3], t_rank[o3], t_t[o3]
            lo = np.searchsorted(t_sid, tail_sids, side="left")
            hi = np.searchsorted(t_sid, tail_sids, side="right")
            cls_cols.append((t_rank, t_t, lo.tolist(), hi.tolist()))
        for g in range(n_groups):
            cols = []
            for t_rank, t_t, lo_l, hi_l in cls_cols:
                a, b = lo_l[g], hi_l[g]
                cols.append(t_rank[a:b])
                cols.append(t_t[a:b])
            nb = grp_seg_lo[g + 1] - grp_seg_lo[g] == 1   # no rotation: the
            tl = tails[g]                                 # carry stays open
            obs = tl.observed if (nb and tl is not None) else 0
            new_tails[g] = _Tail(*cols, observed=obs)
        forder = np.argsort(ord_c[gstart], kind="stable")
        for g in forder.tolist():
            calls[gcids_l[g]] = new_tails[g]
        return True

    def on_phase(self, record: PhaseRecord) -> None:
        """Book one fully-formed phase (the EventBus ``publish_phase``
        consumer): same CallRecord, same timeout-policy actuation, and
        immediate retirement — the occurrence is complete by construction.
        """
        rec = CallRecord(record.call_id, site=record.site)
        rec.enter[record.rank] = record.t_enter
        with self._lock:
            if self._rec_phase is not None:
                self._rec_phase(record)
            self._close_slack(rec, record.rank, record.t_slack_end)
            self._close_copy(rec, record.rank, record.t_copy_end)
            self._retire(rec)

    # non-collective event sources ---------------------------------------------
    def ingest_phase(
        self,
        rank: int,
        call_id: int,
        t_enter: float,
        t_slack_end: float,
        t_copy_end: Optional[float] = None,
        site: Optional[int] = None,
    ) -> None:
        """Book one fully-formed phase from a non-collective source.

        Kwargs-shaped convenience over :meth:`on_phase` — producers that
        already speak the canonical vocabulary publish a
        :class:`~repro.core.events.PhaseRecord` through the bus instead.
        """
        if t_copy_end is None:
            t_copy_end = t_slack_end
        self.on_phase(PhaseRecord(rank, call_id, t_enter, t_slack_end,
                                  t_copy_end, site))

    # accounting ---------------------------------------------------------------
    def recent_records(self) -> List[CallRecord]:
        """The last ``retention`` retired occurrences (debugging only —
        accounting never re-reads them).  Batched retirements sit in the
        ring as ``(RetiredBlock, i)`` views and materialize here."""
        with self._lock:
            return [r if r.__class__ is CallRecord else r[0].record(r[1])
                    for r in self._ring]

    @property
    def n_inflight(self) -> int:
        return len(self._calls)

    def interval_snapshot(self) -> IntervalStats:
        """Stats over the phases retired since the previous snapshot.

        An O(1) read: the cumulative accumulators minus the checkpoint
        taken at the previous snapshot (clamped at zero — differencing
        two running float sums can produce a negative ulp).  Non-
        destructive for :meth:`finalize` and does not feed the straggler
        detector — it is the arbiter's per-epoch poll, not the end-of-run
        report.  In-flight occurrences are picked up by a later snapshot
        once they rotate into retirement.
        """
        with self._lock:
            acc, mark = self._acc, self._mark
            stats = IntervalStats(
                n_calls=acc.n_records - mark.n_records,
                n_downshifts=acc.n_down - mark.n_down,
                slack=max(acc.slack - mark.slack, 0.0),
                copy=max(acc.copy - mark.copy, 0.0),
                busy=max(acc.busy - mark.busy, 0.0),
                exploited=max(acc.exploited - mark.exploited, 0.0),
                energy_baseline=max(acc.e_base - mark.e_base, 0.0),
                energy_policy=max(acc.e_pol - mark.e_pol, 0.0),
                overlap=max(acc.overlap - mark.overlap, 0.0),
            )
            self._mark = acc.clone()
        return stats

    def finalize(self) -> GovernorReport:
        """End-of-run report: the cumulative accumulators plus the records
        still in flight — O(in-flight), however long the run was."""
        with self._lock:
            acc = self._acc.clone()
            calls = self._calls
            for cid, rec in calls.items():
                if rec.__class__ is not CallRecord:
                    # columnar tail from the batched path: materialize in
                    # place (same key, so the dict position — and with it
                    # the accumulation order — is preserved)
                    rec = rec.to_record(cid)
                    calls[cid] = rec
                self._observe(rec)
                self._accumulate(rec, acc)
        return GovernorReport(
            n_calls=acc.n_records,
            n_downshifts=acc.n_down,
            total_slack=acc.slack,
            total_copy=acc.copy,
            exploited_slack=acc.exploited,
            energy_baseline=acc.e_base,
            energy_policy=acc.e_pol,
            straggler_summary=self.detector.summary(),
            stragglers=self.detector.stragglers(),
            total_overlap=acc.overlap,
            n_theta_decisions=self._n_theta,
        )

    def reset(self) -> None:
        """Return to the just-constructed state: in-flight records, ring,
        both accumulator sets, per-rank phase ends, logs and their
        counters, the straggler detector, and the tuner.  Two back-to-back
        identical runs on one governor produce identical reports (pinned
        by a regression test)."""
        with self._lock:
            self._calls.clear()
            self._ring.clear()
            self._acc = _Accum()
            self._mark = _Accum()
            self._last_end.clear()
            self.n_actuations = 0
            self._act_raw.clear()
            self._act_log.clear()
            self._n_theta = 0
            self._theta_log.clear()
            self._n_pred = 0
            self._pred_log.clear()
            self.detector.reset()
            if self.tuner is not None:
                self.tuner.reset()

