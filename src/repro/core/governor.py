"""Host-side governor: the live runtime that consumes phase events.

This is the analogue of the paper's timer+callback machinery (§4.3): the
instrumented collectives emit (rank, phase, call_id, t) events through
``repro.core.instrument.set_event_sink``; the governor reconstructs per-call
slack/copy durations, applies the configured policy's timeout decision, logs
the P-state actuation it *would* issue (on Intel: wrmsr via MSR_SAFE; on a
TPU host: SMC power capping — see DESIGN.md §2), estimates energy via the
calibrated HwModel, and feeds the straggler detector.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.policies import COUNTDOWN_SLACK, Policy
from repro.core.pstate import DEFAULT_HW, HwModel
from repro.dist.straggler import StragglerDetector


@dataclass
class CallRecord:
    call_id: int
    enter: Dict[int, float] = field(default_factory=dict)       # rank -> t
    slack_end: Dict[int, float] = field(default_factory=dict)
    copy_end: Dict[int, float] = field(default_factory=dict)


@dataclass
class GovernorReport:
    n_calls: int
    n_downshifts: int
    total_slack: float
    total_copy: float
    exploited_slack: float
    energy_baseline: float           # J during instrumented phases, no policy
    energy_policy: float             # J with the policy's P-state trajectory
    straggler_summary: Dict[int, float]
    stragglers: List[Tuple[int, float]]

    @property
    def energy_saving_pct(self) -> float:
        if self.energy_baseline <= 0:
            return 0.0
        return 100.0 * (1.0 - self.energy_policy / self.energy_baseline)


class Governor:
    """Reconstructs phases from instrument events and applies the policy."""

    def __init__(
        self,
        policy: Policy = COUNTDOWN_SLACK,
        hw: HwModel = DEFAULT_HW,
        detector: Optional[StragglerDetector] = None,
    ):
        self.policy = policy
        self.hw = hw
        self.detector = detector or StragglerDetector()
        # call_ids are assigned at TRACE time, so the same id recurs on every
        # executed step: rotate to a fresh occurrence when a rank re-enters
        self._calls: Dict[int, CallRecord] = {}
        self._done: List[CallRecord] = []
        self._lock = threading.Lock()
        self.actuation_log: List[Tuple[float, int, str]] = []   # (t, rank, action)

    # the instrument event sink ------------------------------------------------
    def sink(self, rank: int, phase: str, call_id: int, t: float) -> None:
        with self._lock:
            rec = self._calls.setdefault(call_id, CallRecord(call_id))
            if phase == "barrier_enter" and rank in rec.enter:
                self._done.append(rec)                          # new occurrence
                rec = CallRecord(call_id)
                self._calls[call_id] = rec
            if phase == "barrier_enter":
                rec.enter[rank] = t
            elif phase == "barrier_exit":
                rec.slack_end[rank] = t
                slack = t - rec.enter.get(rank, t)
                if slack >= self.policy.theta and self.policy.comm_mode in (
                    "timeout", "predict_timeout",
                ):
                    self.actuation_log.append((t, rank, "set_pstate_min"))
                    self.actuation_log.append((t, rank, "restore_pstate_max"))
            elif phase == "copy_exit":
                rec.copy_end[rank] = t

    # non-collective event sources ---------------------------------------------
    def ingest_phase(
        self,
        rank: int,
        call_id: int,
        t_enter: float,
        t_slack_end: float,
        t_copy_end: Optional[float] = None,
    ) -> None:
        """Book one fully-formed phase from a non-collective source.

        Serving-side producers (decode underfill, inter-arrival idle gaps —
        see :mod:`repro.serve.slack`) know the whole phase at once instead of
        streaming enter/exit events; this books the same CallRecord and the
        same timeout-policy actuation the event-sink path would.
        """
        rec = CallRecord(call_id)
        rec.enter[rank] = t_enter
        rec.slack_end[rank] = t_slack_end
        rec.copy_end[rank] = t_copy_end if t_copy_end is not None else t_slack_end
        with self._lock:
            self._done.append(rec)
            slack = t_slack_end - t_enter
            if slack >= self.policy.theta and self.policy.comm_mode in (
                "timeout", "predict_timeout",
            ):
                self.actuation_log.append((t_slack_end, rank, "set_pstate_min"))
                self.actuation_log.append((t_slack_end, rank, "restore_pstate_max"))

    def finalize(self) -> GovernorReport:
        hw, pol = self.hw, self.policy
        theta_eff = pol.theta + 0.5 * hw.switch_latency
        n_down = 0
        tot_slack = tot_copy = exploited = 0.0
        e_base = e_pol = 0.0
        all_records = self._done + list(self._calls.values())
        n_total = len(all_records)
        for rec in all_records:
            if rec.enter:
                self.detector.observe_barrier(rec.enter)
            for rank, t0 in rec.enter.items():
                t1 = rec.slack_end.get(rank)
                if t1 is None:
                    continue
                slack = max(t1 - t0, 0.0)
                tot_slack += slack
                copy = max(rec.copy_end.get(rank, t1) - t1, 0.0)
                tot_copy += copy
                e_base += hw.watts(hw.f_max, hw.act_slack) * slack
                e_base += hw.watts(hw.f_max, hw.act_copy) * copy
                low = max(slack - theta_eff, 0.0)
                if low > 0:
                    n_down += 1
                    exploited += low
                e_pol += hw.watts(hw.f_max, hw.act_slack) * (slack - low)
                e_pol += hw.watts(hw.f_min, hw.act_slack) * low
                if pol.comm_scope == "comm" and low > 0:
                    e_pol += hw.watts(hw.f_min, hw.act_copy) * copy
                else:
                    e_pol += hw.watts(hw.f_max, hw.act_copy) * copy
        return GovernorReport(
            n_calls=n_total,
            n_downshifts=n_down,
            total_slack=tot_slack,
            total_copy=tot_copy,
            exploited_slack=exploited,
            energy_baseline=e_base,
            energy_policy=e_pol,
            straggler_summary=self.detector.summary(),
            stragglers=self.detector.stragglers(),
        )

    def reset(self) -> None:
        with self._lock:
            self._calls.clear()
            self._done.clear()
            self.actuation_log.clear()
