"""Host-side governor: the live runtime that consumes phase events.

This is the analogue of the paper's timer+callback machinery (§4.3): the
instrumented collectives emit (rank, phase, call_id, t) events through
``repro.core.instrument.set_event_sink``; the governor reconstructs per-call
slack/copy durations, applies the configured policy's timeout decision, logs
the P-state actuation it *would* issue (on Intel: wrmsr via MSR_SAFE; on a
TPU host: SMC power capping — see DESIGN.md §2), estimates energy via the
calibrated HwModel, and feeds the straggler detector.

Two consumers added for the cluster layer (DESIGN.md §7) hang off the same
event stream: an optional :class:`~repro.cluster.trace.TraceRecorder` tees
every event/phase/actuation the governor books (so a run can be replayed
offline, bit-for-bit), and :meth:`Governor.interval_snapshot` reports the
slack/energy booked since the previous snapshot — the per-epoch
exploited-slack ratio the :class:`~repro.cluster.arbiter.PowerBudgetArbiter`
redistributes watts on.

An optional :class:`~repro.core.timeout.ThetaTuner` (``Governor(tuner=)``,
auto-created for ``theta_mode="adaptive"`` policies) closes the timeout
feedback loop: each barrier_exit is priced against the tuner's per-site
theta instead of the policy constant, the observation feeds the site's
slack histogram, and every adjustment is logged as a structured
:class:`~repro.core.timeout.ThetaDecision` next to the actuations (and into
the trace, schema v2, so adaptive runs replay bit-for-bit).  The 5-phase
taxonomy (``dispatch_enter``/``wait_enter`` from the async collectives)
books compute/communication overlap as *non-slack*: slack for an async
pair starts at the wait, and the overlap window is reported separately on
``GovernorReport.total_overlap``.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, List, NamedTuple, Optional, Tuple

from repro.core.policies import COUNTDOWN_SLACK, Policy
from repro.core.pstate import DEFAULT_HW, HwModel
from repro.core.timeout import ThetaDecision, ThetaTuner
from repro.dist.straggler import StragglerDetector


class Actuation(NamedTuple):
    """One P-state command the runtime would issue (structured so the trace
    recorder and benchmarks can consume it without attribute scraping).
    Index layout keeps the legacy ``(t, rank, action)`` prefix."""

    t: float
    rank: int
    action: str              # "set_pstate_min" | "restore_pstate_max"
    call_id: int
    slack: float             # the slack duration that triggered the pair


@dataclass
class CallRecord:
    call_id: int
    enter: Dict[int, float] = field(default_factory=dict)       # rank -> t (slack start)
    slack_end: Dict[int, float] = field(default_factory=dict)
    copy_end: Dict[int, float] = field(default_factory=dict)
    dispatch: Dict[int, float] = field(default_factory=dict)    # async overlap start
    theta_used: Dict[int, float] = field(default_factory=dict)  # raw theta armed per
    # rank at slack end (pricing derives theta_eff from it via HwModel)
    site: Optional[int] = None   # tuner histogram key override (ingested phases)


@dataclass
class GovernorReport:
    n_calls: int
    n_downshifts: int
    total_slack: float
    total_copy: float
    exploited_slack: float
    energy_baseline: float           # J during instrumented phases, no policy
    energy_policy: float             # J with the policy's P-state trajectory
    straggler_summary: Dict[int, float]
    stragglers: List[Tuple[int, float]]
    total_overlap: float = 0.0       # dispatch->wait seconds, accounted NON-slack
    n_theta_decisions: int = 0       # tuner adjustments booked (0 = fixed theta)

    @property
    def energy_saving_pct(self) -> float:
        # energy_policy can dip epsilon-negative when float cancellation
        # meets zero-length phases; clamp both edges so the percentage
        # stays in [0, 100] instead of exceeding it by rounding artifacts
        if self.energy_baseline <= 0:
            return 0.0
        return 100.0 * (1.0 - max(self.energy_policy, 0.0) / self.energy_baseline)

    def to_dict(self) -> Dict[str, object]:
        """JSON-ready view (trace artifacts, benchmarks) — one place, not
        per-consumer attribute scraping."""
        return {
            "n_calls": int(self.n_calls),
            "n_downshifts": int(self.n_downshifts),
            "total_slack": float(self.total_slack),
            "total_copy": float(self.total_copy),
            "exploited_slack": float(self.exploited_slack),
            "energy_baseline": float(self.energy_baseline),
            "energy_policy": float(self.energy_policy),
            "energy_saving_pct": float(self.energy_saving_pct),
            "straggler_summary": {int(r): float(v) for r, v in self.straggler_summary.items()},
            "stragglers": [[int(r), float(z)] for r, z in self.stragglers],
            "total_overlap": float(self.total_overlap),
            "n_theta_decisions": int(self.n_theta_decisions),
        }


@dataclass
class IntervalStats:
    """Slack/energy booked between two ``interval_snapshot`` calls."""

    n_calls: int
    n_downshifts: int
    slack: float
    copy: float
    busy: float                      # sum over ranks of enter->copy_end spans
    exploited: float
    energy_baseline: float
    energy_policy: float

    @property
    def exploited_ratio(self) -> float:
        """Fraction of instrumented rank-time the policy spent at f_min —
        the arbiter's signal that this job has watts to give away."""
        return self.exploited / self.busy if self.busy > 0 else 0.0


class Governor:
    """Reconstructs phases from instrument events and applies the policy."""

    def __init__(
        self,
        policy: Policy = COUNTDOWN_SLACK,
        hw: HwModel = DEFAULT_HW,
        detector: Optional[StragglerDetector] = None,
        recorder=None,
        tuner: Optional[ThetaTuner] = None,
    ):
        self.policy = policy
        self.hw = hw
        self.detector = detector or StragglerDetector()
        self.recorder = recorder     # cluster.trace.TraceRecorder-compatible
        if tuner is None and policy.theta_mode == "adaptive":
            tuner = ThetaTuner(hw=hw, theta0=policy.theta)
        self.tuner = tuner
        # call_ids are assigned at TRACE time, so the same id recurs on every
        # executed step: rotate to a fresh occurrence when a rank re-enters
        self._calls: Dict[int, CallRecord] = {}
        self._done: List[CallRecord] = []
        self._mark = 0               # interval_snapshot high-water mark
        self._last_end: Dict[int, float] = {}   # rank -> last phase end (the
        # enter-minus-this gap is the rank's compute, widening the tuner's
        # overhead budget to the time-to-completion denominator)
        self._lock = threading.Lock()
        self.actuation_log: List[Actuation] = []
        self.theta_log: List[ThetaDecision] = []

    def _actuate(self, t: float, rank: int, call_id: int, slack: float) -> None:
        pair = (
            Actuation(t, rank, "set_pstate_min", call_id, slack),
            Actuation(t, rank, "restore_pstate_max", call_id, slack),
        )
        self.actuation_log.extend(pair)
        if self.recorder is not None:
            for act in pair:
                self.recorder.on_actuation(act)

    def _record_theta(self, dec: Optional[ThetaDecision]) -> None:
        if dec is None:
            return
        self.theta_log.append(dec)
        if self.recorder is not None and hasattr(self.recorder, "on_theta"):
            self.recorder.on_theta(dec)

    def _close_slack(self, rec: CallRecord, rank: int, t: float) -> None:
        """Shared barrier_exit tail: price the slack against the (possibly
        tuned) threshold, book the actuation pair, feed the tuner."""
        rec.slack_end[rank] = t
        t0 = rec.enter.get(rank, t)
        slack = t - t0
        key = rec.site if rec.site is not None else rec.call_id
        theta = self.policy.theta
        if self.tuner is not None:
            theta = self.tuner.theta_for(key)   # threshold armed BEFORE this obs
        rec.theta_used[rank] = theta
        if self.tuner is not None:
            comp = max(t0 - self._last_end[rank], 0.0) if rank in self._last_end else 0.0
            self._record_theta(
                self.tuner.observe_slack(key, slack, t, rank=rank, comp=comp)
            )
        self._last_end[rank] = t
        if slack >= theta and self.policy.comm_mode in ("timeout", "predict_timeout"):
            self._actuate(t, rank, rec.call_id, slack)

    def _close_copy(self, rec: CallRecord, rank: int, t: float) -> None:
        rec.copy_end[rank] = t
        self._last_end[rank] = t
        if self.tuner is None or rank not in rec.slack_end:
            return
        t1 = rec.slack_end[rank]
        slack = t1 - rec.enter.get(rank, t1)
        downshifted = slack >= rec.theta_used.get(rank, self.policy.theta)
        key = rec.site if rec.site is not None else rec.call_id
        self._record_theta(
            self.tuner.observe_copy(key, t - t1, t, rank=rank, downshifted=downshifted)
        )

    # the instrument event sink ------------------------------------------------
    def sink(self, rank: int, phase: str, call_id: int, t: float) -> None:
        with self._lock:
            # recorded under the lock: the trace order must be the order the
            # governor processed events in, or replay() loses bit-exactness
            if self.recorder is not None:
                self.recorder.on_event(rank, phase, call_id, t)
            rec = self._calls.setdefault(call_id, CallRecord(call_id))
            if phase in ("barrier_enter", "dispatch_enter") and (
                rank in rec.enter or rank in rec.dispatch
            ):
                self._done.append(rec)                          # new occurrence
                rec = CallRecord(call_id)
                self._calls[call_id] = rec
            if phase == "barrier_enter":
                rec.enter[rank] = t
            elif phase == "dispatch_enter":
                rec.dispatch[rank] = t                          # overlap starts
            elif phase == "wait_enter":
                rec.enter[rank] = t                             # slack starts at the wait
            elif phase == "barrier_exit":
                self._close_slack(rec, rank, t)
            elif phase == "copy_exit":
                self._close_copy(rec, rank, t)

    # non-collective event sources ---------------------------------------------
    def ingest_phase(
        self,
        rank: int,
        call_id: int,
        t_enter: float,
        t_slack_end: float,
        t_copy_end: Optional[float] = None,
        site: Optional[int] = None,
    ) -> None:
        """Book one fully-formed phase from a non-collective source.

        Serving-side producers (decode underfill, inter-arrival idle gaps —
        see :mod:`repro.serve.slack`) know the whole phase at once instead of
        streaming enter/exit events; this books the same CallRecord and the
        same timeout-policy actuation the event-sink path would.

        ``site`` keys the theta tuner's histogram when the producer's call
        ids are unique per phase (serve meters mint a fresh id per step, so
        without a stable site every phase would start a cold histogram).
        """
        if t_copy_end is None:
            t_copy_end = t_slack_end
        rec = CallRecord(call_id, site=site)
        rec.enter[rank] = t_enter
        with self._lock:
            if self.recorder is not None:
                self.recorder.on_phase(rank, call_id, t_enter, t_slack_end,
                                       t_copy_end, site=site)
            self._done.append(rec)
            self._close_slack(rec, rank, t_slack_end)
            self._close_copy(rec, rank, t_copy_end)

    # accounting ---------------------------------------------------------------
    def _tally(self, records: List[CallRecord]) -> Tuple[int, float, float, float, float, float, float, float]:
        """(n_down, slack, copy, busy, exploited, e_base, e_policy, overlap)
        over ``records`` — the shared math behind finalize() and snapshots."""
        hw, pol = self.hw, self.policy
        default_theta = pol.theta
        n_down = 0
        tot_slack = tot_copy = busy = exploited = tot_overlap = 0.0
        e_base = e_pol = 0.0
        for rec in records:
            for rank, t0 in rec.enter.items():
                t1 = rec.slack_end.get(rank)
                if t1 is None:
                    continue
                # async pair: [dispatch, enter] is compute/comm overlap — the
                # core is busy, so it is *not* slack and is not priced here
                # (the caller's compute never is); it is reported separately
                if rank in rec.dispatch:
                    tot_overlap += max(t0 - rec.dispatch[rank], 0.0)
                slack = max(t1 - t0, 0.0)
                tot_slack += slack
                copy = max(rec.copy_end.get(rank, t1) - t1, 0.0)
                tot_copy += copy
                busy += slack + copy
                e_base += hw.watts(hw.f_max, hw.act_slack) * slack
                e_base += hw.watts(hw.f_max, hw.act_copy) * copy
                theta_eff = hw.theta_eff(rec.theta_used.get(rank, default_theta))
                low = max(slack - theta_eff, 0.0)
                if low > 0:
                    n_down += 1
                    exploited += low
                e_pol += hw.watts(hw.f_max, hw.act_slack) * (slack - low)
                e_pol += hw.watts(hw.f_min, hw.act_slack) * low
                if pol.comm_scope == "comm" and low > 0:
                    e_pol += hw.watts(hw.f_min, hw.act_copy) * copy
                else:
                    e_pol += hw.watts(hw.f_max, hw.act_copy) * copy
        return n_down, tot_slack, tot_copy, busy, exploited, e_base, e_pol, tot_overlap

    def interval_snapshot(self) -> IntervalStats:
        """Stats over the phases completed since the previous snapshot.

        Non-destructive (finalize() still sees everything) and does not
        feed the straggler detector — it is the arbiter's per-epoch poll,
        not the end-of-run report.  In-flight occurrences are picked up by
        a later snapshot once they rotate into the done list.
        """
        with self._lock:
            records = self._done[self._mark:]
            self._mark = len(self._done)
        n_down, slack, copy, busy, exploited, e_base, e_pol, _ = self._tally(records)
        return IntervalStats(
            n_calls=len(records),
            n_downshifts=n_down,
            slack=slack,
            copy=copy,
            busy=busy,
            exploited=exploited,
            energy_baseline=e_base,
            energy_policy=e_pol,
        )

    def finalize(self) -> GovernorReport:
        all_records = self._done + list(self._calls.values())
        for rec in all_records:
            if rec.enter:
                self.detector.observe_barrier(rec.enter)
        n_down, tot_slack, tot_copy, _, exploited, e_base, e_pol, overlap = self._tally(all_records)
        return GovernorReport(
            n_calls=len(all_records),
            n_downshifts=n_down,
            total_slack=tot_slack,
            total_copy=tot_copy,
            exploited_slack=exploited,
            energy_baseline=e_base,
            energy_policy=e_pol,
            straggler_summary=self.detector.summary(),
            stragglers=self.detector.stragglers(),
            total_overlap=overlap,
            n_theta_decisions=len(self.theta_log),
        )

    def reset(self) -> None:
        with self._lock:
            self._calls.clear()
            self._done.clear()
            self._mark = 0
            self._last_end.clear()
            self.actuation_log.clear()
            self.theta_log.clear()
            if self.tuner is not None:
                self.tuner.reset()
