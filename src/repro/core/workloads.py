"""Calibrated multi-rank workload generators (NPB suite + OMEN, §6.1).

Each application is parameterized by the paper's own measurements
(Table 2: Tcomm%, Tslack%, average MPI duration; Table 3: Min-Freq overhead
=> frequency-sensitivity beta) and the generator *self-calibrates*: it
draws the compute-imbalance sample, then solves the dispersion scale so the
simulated baseline reproduces the target slack/comm fractions.

Structure knobs that matter for the paper's story:
  * ``sigma_noise``   — task-to-task unpredictable variation (breaks
                        last-value prediction => Andante/Fermata overheads);
  * ``sigma_rank``    — persistent rank skew (predictable imbalance);
  * ``p2p_fraction``  — pairwise comms (pipelined solvers like LU);
  * ``n_sites``       — distinct call sites (stack-hash universe).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from repro.core.policies import BASELINE
from repro.core.pstate import DEFAULT_HW, HwModel
from repro.core.simulator import Workload, simulate

EFFECTIVE_BW = 5e9  # bytes/s: copy seconds -> message bytes (feature only)


@dataclass(frozen=True)
class AppSpec:
    name: str
    n_ranks: int
    n_tasks: int
    comp_mean: float            # seconds per task (f_max)
    slack_mean: float           # target mean slack per task
    copy_mean: float            # seconds per task
    beta_comp: float
    beta_copy: float
    sigma_noise: float = 0.25   # lognormal sigma, unpredictable part
    sigma_rank: float = 0.10    # persistent rank skew
    sigma_task: float = 0.30    # per-task shared scale (heavy tail => some
                                # calls far above the mean, exploitable slack
                                # even when the *average* MPI call is tiny)
    p2p_fraction: float = 0.0
    n_sites: int = 12
    site_sigma: float = 0.4     # dispersion of per-site scales (bimodality)
    copy_sigma: float = 0.3     # dispersion of copy durations (tail mass)
    unique_sites: bool = False  # every call a fresh stack (defeats prediction)
    # paper Table 2 reference values [% of execution time] for reporting
    ref_tcomm: float = 0.0
    ref_tslack: float = 0.0


# Derivation of comp/slack/copy means from Table 2 (see DESIGN.md): with
# avg-MPI = slack+copy and Tcomm%, Tslack% per the paper,
#   task_total = avgMPI / Tcomm%,  comp = task_total - avgMPI,
#   slack = Tslack% * task_total,  copy = avgMPI - slack.
# beta = MinFreq-overhead% / (100 * (fmax/fmin - 1)).
APPS: Dict[str, AppSpec] = {
    "nas_bt.E.1024": AppSpec(
        "nas_bt.E.1024", 32, 400, comp_mean=1.525, slack_mean=1.07e-3,
        copy_mean=0.76e-3, beta_comp=0.54, beta_copy=0.15,
        sigma_noise=0.35, sigma_rank=0.05, n_sites=16,
        ref_tcomm=0.12, ref_tslack=0.07,
    ),
    "nas_cg.E.1024": AppSpec(
        "nas_cg.E.1024", 32, 2000, comp_mean=3.868e-3, slack_mean=4.2e-6,
        copy_mean=2.064e-3, beta_comp=0.16, beta_copy=0.10,
        sigma_noise=0.10, sigma_rank=0.02, p2p_fraction=0.5, n_sites=10, copy_sigma=0.8,
        ref_tcomm=34.84, ref_tslack=0.07,
    ),
    "nas_ep.E.128": AppSpec(
        "nas_ep.E.128", 32, 3, comp_mean=298.0, slack_mean=24.38,
        copy_mean=1e-3, beta_comp=1.0, beta_copy=0.10,
        sigma_noise=0.06, sigma_rank=0.04, sigma_task=0.05, n_sites=3, unique_sites=True,
        ref_tcomm=7.56, ref_tslack=7.56,
    ),
    "nas_ft.E.1024": AppSpec(
        "nas_ft.E.1024", 32, 160, comp_mean=1.273, slack_mean=0.448,
        copy_mean=1.927, beta_comp=0.26, beta_copy=0.12,
        sigma_noise=0.30, sigma_rank=0.10, n_sites=8,
        ref_tcomm=65.10, ref_tslack=12.28,
    ),
    "nas_is.D.128": AppSpec(
        "nas_is.D.128", 32, 800, comp_mean=164.6e-3, slack_mean=121.1e-3,
        copy_mean=155.9e-3, beta_comp=0.22, beta_copy=0.12,
        sigma_noise=0.45, sigma_rank=0.15, sigma_task=0.6, site_sigma=1.5, n_sites=6,
        ref_tcomm=62.73, ref_tslack=27.42,
    ),
    "nas_lu.E.1024": AppSpec(
        "nas_lu.E.1024", 32, 10000, comp_mean=0.095e-3, slack_mean=0.0883e-3,
        copy_mean=0.0107e-3, beta_comp=0.58, beta_copy=0.20,
        sigma_noise=0.55, sigma_rank=0.20, sigma_task=2.2, site_sigma=1.2, p2p_fraction=0.9, n_sites=24,
        ref_tcomm=51.01, ref_tslack=45.51,
    ),
    "nas_mg.E.128": AppSpec(
        "nas_mg.E.128", 32, 2000, comp_mean=11.55e-3, slack_mean=0.0114e-3,
        copy_mean=1.12e-3, beta_comp=0.03, beta_copy=0.10,
        sigma_noise=0.12, sigma_rank=0.02, p2p_fraction=0.3, n_sites=14, copy_sigma=1.3,
        ref_tcomm=8.94, ref_tslack=0.09,
    ),
    "nas_sp.E.1024": AppSpec(
        "nas_sp.E.1024", 32, 200, comp_mean=2.893, slack_mean=0.58e-3,
        copy_mean=0.87e-3, beta_comp=0.09, beta_copy=0.10,
        sigma_noise=0.20, sigma_rank=0.03, n_sites=16,
        ref_tcomm=0.05, ref_tslack=0.02,
    ),
    "omen_60p": AppSpec(
        "omen_60p", 16, 2000, comp_mean=40.4e-3, slack_mean=56.2e-3,
        copy_mean=3.7e-3, beta_comp=0.91, beta_copy=0.15,
        sigma_noise=0.80, sigma_rank=0.30, sigma_task=1.0, site_sigma=2.0, n_sites=10,
        ref_tcomm=59.69, ref_tslack=56.00,
    ),
    "omen_1056p": AppSpec(
        "omen_1056p", 48, 2000, comp_mean=34.2e-3, slack_mean=52.1e-3,
        copy_mean=6.0e-3, beta_comp=0.32, beta_copy=0.15,
        sigma_noise=0.85, sigma_rank=0.35, sigma_task=1.0, site_sigma=2.0, n_sites=10,
        ref_tcomm=62.96, ref_tslack=56.42,
    ),
}


def generate(spec: AppSpec, seed: int = 0, calibrate: bool = True,
             hw: HwModel = DEFAULT_HW) -> Workload:
    rng = np.random.default_rng(seed)
    t_tasks, n = spec.n_tasks, spec.n_ranks

    if spec.unique_sites:
        site = np.arange(t_tasks)
        n_sites_eff = t_tasks
    else:
        site = rng.integers(0, spec.n_sites, t_tasks)
        n_sites_eff = spec.n_sites
    site_scale = np.exp(rng.normal(0.0, spec.site_sigma, n_sites_eff))
    task_scale = np.exp(rng.normal(0.0, spec.sigma_task, t_tasks))
    rank_skew = np.exp(rng.normal(0.0, spec.sigma_rank, n))
    noise = np.exp(rng.normal(0.0, spec.sigma_noise, (t_tasks, n)))

    x = (site_scale[site] * task_scale)[:, None] * rank_skew[None, :] * noise
    x = x / x.mean()                                             # (T,N)

    is_p2p = rng.random(t_tasks) < spec.p2p_fraction
    partner = np.zeros((t_tasks, n), dtype=np.int64)
    for k in np.where(is_p2p)[0]:
        perm = rng.permutation(n)
        pairs = perm.reshape(-1, 2)
        p = np.zeros(n, dtype=np.int64)
        p[pairs[:, 0]] = pairs[:, 1]
        p[pairs[:, 1]] = pairs[:, 0]
        partner[k] = p

    # dispersion that reproduces the target slack:  comp = c*((1-l) + l*x)
    if is_p2p.any():
        spread_p2p = np.abs(x - x[np.arange(t_tasks)[:, None], partner]).mean()
    else:
        spread_p2p = 0.0
    spread_coll = (x.max(axis=1, keepdims=True) - x).mean()
    frac_p2p = is_p2p.mean()
    # for p2p the slack of a pair is |x1-x2|/2 on average per rank
    spread = (1 - frac_p2p) * spread_coll + frac_p2p * 0.5 * spread_p2p
    lam = min(spec.slack_mean / max(spec.comp_mean * spread, 1e-30), 1.0)
    comp = spec.comp_mean * ((1.0 - lam) + lam * x)

    copy_scale = np.exp(rng.normal(0.0, spec.copy_sigma, n_sites_eff))
    copy = spec.copy_mean * copy_scale[site] * np.exp(rng.normal(0, 0.2, t_tasks))
    copy = copy * (spec.copy_mean / max(copy.mean(), 1e-30))

    copy_jitter = np.exp(rng.normal(0.0, 0.25, (t_tasks, n)))
    copy_jitter /= copy_jitter.mean()

    wl = Workload(
        name=spec.name, n_ranks=n, comp=comp, copy=copy, is_p2p=is_p2p,
        partner=partner, site=site, nbytes=np.maximum(copy, 0.0) * EFFECTIVE_BW,
        beta_comp=spec.beta_comp, beta_copy=spec.beta_copy,
        copy_jitter=copy_jitter,
    )

    if calibrate:
        # one fixed-point refinement of the dispersion against the simulator
        res, _ = simulate(wl, BASELINE, hw)
        measured_slack = res.tslack / max(res.calls * n, 1)
        if measured_slack > 0 and spec.slack_mean > 0:
            ratio = spec.slack_mean / measured_slack
            lam2 = min(lam * ratio, 1.0)
            wl.comp[:] = spec.comp_mean * ((1.0 - lam2) + lam2 * x)
    return wl


def make_all(seed: int = 0) -> Dict[str, Workload]:
    return {name: generate(spec, seed) for name, spec in APPS.items()}
