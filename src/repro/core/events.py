"""Canonical phase-event vocabulary and the one event bus every producer
and consumer shares.

Phase semantics used to live in four places at once: ``instrument``'s
ambient ``_SINK``/``_TEE`` globals (one consumer slot each), the
governor's ``ingest_phase`` kwargs, ``cluster.trace``'s JSONL record
shapes, and ad-hoc synthetic feeders.  This module is now the single
home:

* :class:`PhaseEvent` — one timestamped event of the 5-phase taxonomy
  (``barrier_enter``/``barrier_exit``/``copy_exit`` for blocking
  collectives, plus ``dispatch_enter``/``wait_enter`` for the async
  start/wait pairs).  On the hot path events travel as positional args,
  not objects — the NamedTuple exists for storage and tests.
* :class:`PhaseRecord` — one *fully-formed* single-rank phase from a
  producer that knows the whole span at once (serve decode underfill,
  idle gaps, trace replay): enter / slack-end / copy-end timestamps plus
  an optional stable ``site`` for the theta tuner's histograms.
* :class:`EventBus` — N registered subscribers fed the identical stream.
  A subscriber is any object with ``on_event(rank, phase, call_id, t)``
  and/or ``on_phase(record)`` methods (a bare callable subscribes as an
  ``on_event`` consumer).  The bus replaces the single-slot sink/tee
  globals: the governor, a :class:`~repro.cluster.trace.TraceRecorder`,
  a straggler probe and any future consumer attach side by side.
* :class:`EventBatch` / :class:`BatchAccumulator` — the batched ingest
  spine (DESIGN.md §9): producers accumulate events into fixed-dtype
  columns (rank ``int32``, phase code ``int8``, call id ``int64``,
  timestamp ``float64`` — 21 B/event) and publish whole chunks through
  :meth:`EventBus.publish_batch`, which hands the columns to
  batch-capable subscribers (``on_batch``) and falls back to a decoded
  per-event loop for legacy ``on_event`` subscribers.  One batch costs
  one callback per subscriber instead of one per event, which is what
  lifts the spine from ~0.6M ev/s to the multi-M ev/s a week-long,
  thousand-rank trace needs.

The module is deliberately jax-free so ``import repro.core.events`` stays
cheap for host-side tooling (recorders, replayers, benchmarks); numpy is
the only array dependency.
"""
from __future__ import annotations

import collections
import threading
from typing import Any, Callable, Iterable, List, NamedTuple, Optional, Tuple

import numpy as np

# the 5-phase event taxonomy (codes are what crosses the io_callback wire)
PHASE_NAMES = {
    0: "barrier_enter",      # blocking call entered; slack starts
    1: "barrier_exit",       # artificial barrier resolved; slack ends
    2: "copy_exit",          # real collective done; copy ends
    3: "dispatch_enter",     # async collective dispatched; overlap starts
    4: "wait_enter",         # caller blocks on the async handle; slack starts
}
PHASE_CODES = {name: code for code, name in PHASE_NAMES.items()}


class PhaseEvent(NamedTuple):
    """One timestamped phase event, as a value (storage/testing shape; the
    bus hot path passes the same four fields positionally)."""

    rank: int
    phase: str               # one of PHASE_NAMES.values()
    call_id: int
    t: float                 # host-side monotonic seconds


class PhaseRecord(NamedTuple):
    """One fully-formed single-rank phase from a non-streaming producer.

    ``t_enter <= t_slack_end <= t_copy_end``; ``site`` keys the theta
    tuner's per-callsite histogram when the producer mints a fresh
    ``call_id`` per phase (serve meters do) — without it every phase
    would start a cold histogram.
    """

    rank: int
    call_id: int
    t_enter: float
    t_slack_end: float
    t_copy_end: float
    site: Optional[int] = None


class EventBatch(NamedTuple):
    """A chunk of streamed events as fixed-dtype columns.

    Dtype layout (21 B/event; see DESIGN.md §9):

    ======== ========= =============================================
    column   dtype     meaning
    ======== ========= =============================================
    rank     int32     producing rank
    code     int8      phase code (:data:`PHASE_NAMES` key)
    call_id  int64     recurring call id / site (64-bit: serve meters
                       mint one id per phase, week-long runs overflow
                       int32)
    t        float64   host-monotonic seconds
    ======== ========= =============================================

    ``capacity`` carries the producer buffer size the chunk was cut
    from, so consumers can report batch occupancy (``n / capacity``)
    without knowing the producer.  Rows are in stream order — the batch
    is the same event sequence ``publish`` would have carried, just
    columnar.
    """

    rank: np.ndarray
    code: np.ndarray
    call_id: np.ndarray
    t: np.ndarray
    capacity: Optional[int] = None

    @property
    def n(self) -> int:
        return int(self.rank.shape[0])

    @property
    def occupancy(self) -> float:
        return self.n / self.capacity if self.capacity else 1.0

    @staticmethod
    def from_rows(rows: Iterable[Tuple[int, Any, int, float]],
                  capacity: Optional[int] = None) -> "EventBatch":
        """Build a batch from ``(rank, phase, call_id, t)`` rows (phase as
        name or code) — the tests'/replayers' convenience constructor."""
        rows = list(rows)
        codes = [PHASE_CODES.get(p, p) for _, p, _, _ in rows]
        return EventBatch(
            np.asarray([r for r, _, _, _ in rows], dtype=np.int32),
            np.asarray(codes, dtype=np.int8),
            np.asarray([c for _, _, c, _ in rows], dtype=np.int64),
            np.asarray([t for _, _, _, t in rows], dtype=np.float64),
            capacity,
        )

    def iter_events(self) -> Iterable[PhaseEvent]:
        """Decode back to per-event values (the legacy-subscriber view)."""
        names = PHASE_NAMES
        for r, c, i, t in zip(self.rank.tolist(), self.code.tolist(),
                              self.call_id.tolist(), self.t.tolist()):
            yield PhaseEvent(r, names.get(c, f"code_{c}"), i, t)


class BatchAccumulator:
    """Fixed-capacity columnar event buffer on the producer side.

    Producers call :meth:`append` per event (host callbacks) or
    :meth:`extend` with whole columns (vectorized producers — the
    simulator, device-side buffers fetched once per step), then
    :meth:`flush` cuts an :class:`EventBatch` copy and resets the write
    cursor.  ``full`` tells streaming producers when to flush; a final
    flush drains the remainder.  Not thread-safe — one producer owns one
    accumulator (the instrument layer's ordered ``io_callback`` already
    serializes its events).
    """

    __slots__ = ("capacity", "_rank", "_code", "_cid", "_t", "_n")

    def __init__(self, capacity: int = 8192):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = int(capacity)
        self._rank = np.empty(self.capacity, dtype=np.int32)
        self._code = np.empty(self.capacity, dtype=np.int8)
        self._cid = np.empty(self.capacity, dtype=np.int64)
        self._t = np.empty(self.capacity, dtype=np.float64)
        self._n = 0

    def __len__(self) -> int:
        return self._n

    @property
    def full(self) -> bool:
        return self._n >= self.capacity

    def append(self, rank: int, code: int, call_id: int, t: float) -> bool:
        """Buffer one event; returns True when the buffer just filled."""
        n = self._n
        self._rank[n] = rank
        self._code[n] = code
        self._cid[n] = call_id
        self._t[n] = t
        self._n = n + 1
        return self._n >= self.capacity

    def extend(self, ranks, codes, call_ids, ts) -> None:
        """Buffer whole columns (must fit the remaining capacity — block
        producers size their blocks or flush first)."""
        m = len(ranks)
        n = self._n
        if n + m > self.capacity:
            raise ValueError(
                f"extend of {m} events overflows capacity "
                f"{self.capacity} (cursor at {n}); flush first"
            )
        self._rank[n:n + m] = ranks
        self._code[n:n + m] = codes
        self._cid[n:n + m] = call_ids
        self._t[n:n + m] = ts
        self._n = n + m

    @property
    def free(self) -> int:
        return self.capacity - self._n

    def flush(self) -> Optional[EventBatch]:
        """Cut the buffered events into an :class:`EventBatch` (copied —
        the buffer is immediately reusable); None when empty."""
        n = self._n
        if n == 0:
            return None
        batch = EventBatch(
            self._rank[:n].copy(), self._code[:n].copy(),
            self._cid[:n].copy(), self._t[:n].copy(), self.capacity,
        )
        self._n = 0
        return batch

    def clear(self) -> None:
        self._n = 0


class _Entry(NamedTuple):
    name: Optional[str]
    subscriber: Any
    ident: Any               # stable identity key (bound methods resolve to
    # (owner id, function id): every attribute access mints a fresh bound-
    # method object, so `is` comparisons would silently never match)
    on_event: Optional[Callable[[int, str, int, float], None]]
    on_phase: Optional[Callable[[PhaseRecord], None]]
    on_batch: Optional[Callable[["EventBatch"], None]] = None


def _ident(subscriber: Any) -> Any:
    owner = getattr(subscriber, "__self__", None)
    func = getattr(subscriber, "__func__", None)
    if owner is not None and func is not None:
        return ("bound", id(owner), id(func))
    return id(subscriber)


class EventBus:
    """Fan one (rank, phase, call_id, t) / :class:`PhaseRecord` stream out
    to N subscribers, in subscription order.

    Subscription management takes a lock; ``publish``/``publish_phase``
    iterate an immutable snapshot tuple, so the hot path is a plain loop
    over bound methods with no locking of its own (per-subscriber
    consumers do their own locking — the governor does).
    """

    __slots__ = ("_entries", "_lock", "_event_cbs", "_phase_cbs",
                 "_batch_plan", "_queue", "_stat_events", "_stat_batches",
                 "_stat_occupancy", "_stat_fallback_events")

    def __init__(self) -> None:
        self._entries: List[_Entry] = []
        self._lock = threading.Lock()
        self._event_cbs: Tuple[Callable, ...] = ()
        self._phase_cbs: Tuple[Callable, ...] = ()
        # per-subscriber delivery plan for batches, in subscription order:
        # (on_batch, on_event) — exactly one is used per subscriber
        self._batch_plan: Tuple[Tuple[Optional[Callable], Optional[Callable]], ...] = ()
        self._queue: collections.deque = collections.deque()
        self._stat_events = 0            # events published via publish_batch
        self._stat_batches = 0
        self._stat_occupancy = 0.0       # sum of per-batch occupancy
        self._stat_fallback_events = 0   # events replayed per-event for
        # legacy (on_event-only) subscribers

    # ---- subscription management -----------------------------------------
    def _rebuild(self) -> None:
        self._event_cbs = tuple(e.on_event for e in self._entries
                                if e.on_event is not None)
        self._phase_cbs = tuple(e.on_phase for e in self._entries
                                if e.on_phase is not None)
        self._batch_plan = tuple(
            (e.on_batch, e.on_event) for e in self._entries
            if e.on_batch is not None or e.on_event is not None
        )

    @staticmethod
    def _resolve(subscriber: Any) -> Tuple[Optional[Callable], Optional[Callable],
                                           Optional[Callable]]:
        on_event = getattr(subscriber, "on_event", None)
        on_phase = getattr(subscriber, "on_phase", None)
        on_batch = getattr(subscriber, "on_batch", None)
        if on_event is None and on_phase is None and on_batch is None:
            if callable(subscriber):
                return subscriber, None, None
            raise TypeError(
                f"not a subscriber: {subscriber!r} has none of on_event / "
                f"on_phase / on_batch and is not callable"
            )
        return on_event, on_phase, on_batch

    def subscribe(self, subscriber: Any, *, name: Optional[str] = None) -> Any:
        """Register ``subscriber``; returns it (decorator-friendly).

        ``name`` creates a *named slot*: a later subscribe with the same
        name replaces the previous occupant and only it (the legacy
        single-slot ``set_event_sink``/``set_event_tee`` semantics ride on
        this — one callable may occupy both slots, and is then delivered
        twice, exactly as the two globals used to).  An *unnamed*
        re-subscribe of the same subscriber — object or bound method —
        replaces its previous unnamed entry rather than duplicating it.
        """
        on_event, on_phase, on_batch = self._resolve(subscriber)
        ident = _ident(subscriber)
        with self._lock:
            if name is not None:
                self._entries = [e for e in self._entries if e.name != name]
            else:
                self._entries = [
                    e for e in self._entries
                    if e.name is not None or e.ident != ident
                ]
            self._entries.append(_Entry(name, subscriber, ident,
                                        on_event, on_phase, on_batch))
            self._rebuild()
        return subscriber

    def unsubscribe(self, target: Any) -> bool:
        """Remove by subscriber identity (object or bound method — every
        entry it occupies, named or not) or by slot name; True if found.
        ``None`` is a no-op (it would otherwise match every unnamed
        entry's ``name``)."""
        if target is None:
            return False
        ident = _ident(target)
        with self._lock:
            before = len(self._entries)
            self._entries = [
                e for e in self._entries
                if e.ident != ident and e.name != target
            ]
            if len(self._entries) != before:
                self._rebuild()
                return True
            return False

    def clear(self) -> None:
        """Back to the just-constructed state: subscribers, the pending
        batch queue and the ingest counters (the ambient bus is reused
        across tests/runs — stats must not leak between them)."""
        with self._lock:
            self._entries = []
            self._rebuild()
            self._queue.clear()
            self._stat_events = 0
            self._stat_batches = 0
            self._stat_occupancy = 0.0
            self._stat_fallback_events = 0

    def subscribers(self) -> List[Any]:
        return [e.subscriber for e in self._entries]

    def __len__(self) -> int:
        return len(self._entries)

    def __bool__(self) -> bool:
        # truthiness == "anyone listening?" so producers can skip the
        # timestamp + publish entirely when nobody subscribed
        return bool(self._entries)

    # ---- publishing (hot path) -------------------------------------------
    def publish(self, rank: int, phase: str, call_id: int, t: float) -> None:
        """Fan one streamed event out to every on_event subscriber."""
        for cb in self._event_cbs:
            cb(rank, phase, call_id, t)

    def publish_event(self, event: PhaseEvent) -> None:
        """Value-shaped convenience over :meth:`publish`."""
        for cb in self._event_cbs:
            cb(event.rank, event.phase, event.call_id, event.t)

    def publish_phase(self, record: PhaseRecord) -> None:
        """Fan one fully-formed phase out to every on_phase subscriber."""
        for cb in self._phase_cbs:
            cb(record)

    # ---- batched ingest ----------------------------------------------------
    def publish_batch(self, batch: EventBatch) -> None:
        """Fan one columnar chunk out, in subscription order.

        Batch-capable subscribers (``on_batch``) get the columns whole —
        one callback per chunk.  Legacy ``on_event`` subscribers get the
        identical stream replayed as a decoded per-event loop, so mixing
        consumer generations on one bus stays correct (just not fast for
        the legacy ones).  The chunk carries the same stream order
        ``publish`` would have: a consumer cannot tell the paths apart by
        anything but wall-clock.
        """
        n = batch.rank.shape[0]
        if n == 0:
            return
        self._stat_events += n
        self._stat_batches += 1
        self._stat_occupancy += batch.occupancy
        plan = self._batch_plan
        decoded = None
        for on_batch, on_event in plan:
            if on_batch is not None:
                on_batch(batch)
                continue
            if decoded is None:
                names = PHASE_NAMES
                decoded = (batch.rank.tolist(),
                           [names.get(c, f"code_{c}") for c in batch.code.tolist()],
                           batch.call_id.tolist(), batch.t.tolist())
                self._stat_fallback_events += n
            ranks, phases, cids, ts = decoded
            for i in range(n):
                on_event(ranks[i], phases[i], cids[i], ts[i])

    def enqueue(self, batch: EventBatch) -> None:
        """Queue a chunk for a later :meth:`drain` — producers that must
        not run consumer code inline (a flush inside an ordered
        ``io_callback``, a device-buffer fetch loop) hand chunks over
        here and a drain point on the host loop delivers them."""
        if batch.rank.shape[0]:
            self._queue.append(batch)

    def drain(self, max_batches: Optional[int] = None) -> int:
        """Deliver queued chunks in FIFO order; returns events delivered.

        ``max_batches`` bounds one drain call so a latency-sensitive host
        loop can spread delivery over iterations."""
        delivered = 0
        budget = max_batches if max_batches is not None else -1
        while self._queue and budget != 0:
            batch = self._queue.popleft()
            self.publish_batch(batch)
            delivered += batch.rank.shape[0]
            budget -= 1
        return delivered

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    @property
    def queued_events(self) -> int:
        return sum(b.rank.shape[0] for b in self._queue)

    def ingest_stats(self) -> dict:
        """Cumulative batched-ingest counters (the obs layer's
        :class:`~repro.obs.metrics.IngestMetrics` collector derives rates
        and occupancy gauges from these)."""
        batches = self._stat_batches
        return {
            "events_total": self._stat_events,
            "batches_total": batches,
            "mean_occupancy": (self._stat_occupancy / batches) if batches else 0.0,
            "fallback_events_total": self._stat_fallback_events,
            "queue_depth": self.queue_depth,
            "queued_events": self.queued_events,
        }
