"""Canonical phase-event vocabulary and the one event bus every producer
and consumer shares.

Phase semantics used to live in four places at once: ``instrument``'s
ambient ``_SINK``/``_TEE`` globals (one consumer slot each), the
governor's ``ingest_phase`` kwargs, ``cluster.trace``'s JSONL record
shapes, and ad-hoc synthetic feeders.  This module is now the single
home:

* :class:`PhaseEvent` — one timestamped event of the 5-phase taxonomy
  (``barrier_enter``/``barrier_exit``/``copy_exit`` for blocking
  collectives, plus ``dispatch_enter``/``wait_enter`` for the async
  start/wait pairs).  On the hot path events travel as positional args,
  not objects — the NamedTuple exists for storage and tests.
* :class:`PhaseRecord` — one *fully-formed* single-rank phase from a
  producer that knows the whole span at once (serve decode underfill,
  idle gaps, trace replay): enter / slack-end / copy-end timestamps plus
  an optional stable ``site`` for the theta tuner's histograms.
* :class:`EventBus` — N registered subscribers fed the identical stream.
  A subscriber is any object with ``on_event(rank, phase, call_id, t)``
  and/or ``on_phase(record)`` methods (a bare callable subscribes as an
  ``on_event`` consumer).  The bus replaces the single-slot sink/tee
  globals: the governor, a :class:`~repro.cluster.trace.TraceRecorder`,
  a straggler probe and any future consumer attach side by side.

The module is deliberately jax-free so ``import repro.core.events`` stays
cheap for host-side tooling (recorders, replayers, benchmarks).
"""
from __future__ import annotations

import threading
from typing import Any, Callable, List, NamedTuple, Optional, Tuple

# the 5-phase event taxonomy (codes are what crosses the io_callback wire)
PHASE_NAMES = {
    0: "barrier_enter",      # blocking call entered; slack starts
    1: "barrier_exit",       # artificial barrier resolved; slack ends
    2: "copy_exit",          # real collective done; copy ends
    3: "dispatch_enter",     # async collective dispatched; overlap starts
    4: "wait_enter",         # caller blocks on the async handle; slack starts
}
PHASE_CODES = {name: code for code, name in PHASE_NAMES.items()}


class PhaseEvent(NamedTuple):
    """One timestamped phase event, as a value (storage/testing shape; the
    bus hot path passes the same four fields positionally)."""

    rank: int
    phase: str               # one of PHASE_NAMES.values()
    call_id: int
    t: float                 # host-side monotonic seconds


class PhaseRecord(NamedTuple):
    """One fully-formed single-rank phase from a non-streaming producer.

    ``t_enter <= t_slack_end <= t_copy_end``; ``site`` keys the theta
    tuner's per-callsite histogram when the producer mints a fresh
    ``call_id`` per phase (serve meters do) — without it every phase
    would start a cold histogram.
    """

    rank: int
    call_id: int
    t_enter: float
    t_slack_end: float
    t_copy_end: float
    site: Optional[int] = None


class _Entry(NamedTuple):
    name: Optional[str]
    subscriber: Any
    ident: Any               # stable identity key (bound methods resolve to
    # (owner id, function id): every attribute access mints a fresh bound-
    # method object, so `is` comparisons would silently never match)
    on_event: Optional[Callable[[int, str, int, float], None]]
    on_phase: Optional[Callable[[PhaseRecord], None]]


def _ident(subscriber: Any) -> Any:
    owner = getattr(subscriber, "__self__", None)
    func = getattr(subscriber, "__func__", None)
    if owner is not None and func is not None:
        return ("bound", id(owner), id(func))
    return id(subscriber)


class EventBus:
    """Fan one (rank, phase, call_id, t) / :class:`PhaseRecord` stream out
    to N subscribers, in subscription order.

    Subscription management takes a lock; ``publish``/``publish_phase``
    iterate an immutable snapshot tuple, so the hot path is a plain loop
    over bound methods with no locking of its own (per-subscriber
    consumers do their own locking — the governor does).
    """

    __slots__ = ("_entries", "_lock", "_event_cbs", "_phase_cbs")

    def __init__(self) -> None:
        self._entries: List[_Entry] = []
        self._lock = threading.Lock()
        self._event_cbs: Tuple[Callable, ...] = ()
        self._phase_cbs: Tuple[Callable, ...] = ()

    # ---- subscription management -----------------------------------------
    def _rebuild(self) -> None:
        self._event_cbs = tuple(e.on_event for e in self._entries
                                if e.on_event is not None)
        self._phase_cbs = tuple(e.on_phase for e in self._entries
                                if e.on_phase is not None)

    @staticmethod
    def _resolve(subscriber: Any) -> Tuple[Optional[Callable], Optional[Callable]]:
        on_event = getattr(subscriber, "on_event", None)
        on_phase = getattr(subscriber, "on_phase", None)
        if on_event is None and on_phase is None:
            if callable(subscriber):
                return subscriber, None
            raise TypeError(
                f"not a subscriber: {subscriber!r} has neither on_event nor "
                f"on_phase and is not callable"
            )
        return on_event, on_phase

    def subscribe(self, subscriber: Any, *, name: Optional[str] = None) -> Any:
        """Register ``subscriber``; returns it (decorator-friendly).

        ``name`` creates a *named slot*: a later subscribe with the same
        name replaces the previous occupant and only it (the legacy
        single-slot ``set_event_sink``/``set_event_tee`` semantics ride on
        this — one callable may occupy both slots, and is then delivered
        twice, exactly as the two globals used to).  An *unnamed*
        re-subscribe of the same subscriber — object or bound method —
        replaces its previous unnamed entry rather than duplicating it.
        """
        on_event, on_phase = self._resolve(subscriber)
        ident = _ident(subscriber)
        with self._lock:
            if name is not None:
                self._entries = [e for e in self._entries if e.name != name]
            else:
                self._entries = [
                    e for e in self._entries
                    if e.name is not None or e.ident != ident
                ]
            self._entries.append(_Entry(name, subscriber, ident,
                                        on_event, on_phase))
            self._rebuild()
        return subscriber

    def unsubscribe(self, target: Any) -> bool:
        """Remove by subscriber identity (object or bound method — every
        entry it occupies, named or not) or by slot name; True if found.
        ``None`` is a no-op (it would otherwise match every unnamed
        entry's ``name``)."""
        if target is None:
            return False
        ident = _ident(target)
        with self._lock:
            before = len(self._entries)
            self._entries = [
                e for e in self._entries
                if e.ident != ident and e.name != target
            ]
            if len(self._entries) != before:
                self._rebuild()
                return True
            return False

    def clear(self) -> None:
        with self._lock:
            self._entries = []
            self._rebuild()

    def subscribers(self) -> List[Any]:
        return [e.subscriber for e in self._entries]

    def __len__(self) -> int:
        return len(self._entries)

    def __bool__(self) -> bool:
        # truthiness == "anyone listening?" so producers can skip the
        # timestamp + publish entirely when nobody subscribed
        return bool(self._entries)

    # ---- publishing (hot path) -------------------------------------------
    def publish(self, rank: int, phase: str, call_id: int, t: float) -> None:
        """Fan one streamed event out to every on_event subscriber."""
        for cb in self._event_cbs:
            cb(rank, phase, call_id, t)

    def publish_event(self, event: PhaseEvent) -> None:
        """Value-shaped convenience over :meth:`publish`."""
        for cb in self._event_cbs:
            cb(event.rank, event.phase, event.call_id, event.t)

    def publish_phase(self, record: PhaseRecord) -> None:
        """Fan one fully-formed phase out to every on_phase subscriber."""
        for cb in self._phase_cbs:
            cb(record)
