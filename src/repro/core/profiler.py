"""Profiler module (paper §4.4): event profiler + time-based profiler +
hierarchical report.

* ``EventProfiler`` — per-MPI-call records (site, rank, durations, bytes),
  the analogue of the RDPMC fixed-counter path.  Sources: the simulator's
  ``TraceRecord``, or a live run via ``on_phase`` — the profiler is an
  :class:`~repro.core.events.EventBus` subscriber, so
  ``bus.subscribe(profiler)`` folds every fully-formed
  :class:`~repro.core.events.PhaseRecord` the governor reconstructs into
  the same per-site statistics.
* ``TimeProfiler``  — a sampling thread (default 1 s) that snapshots
  host-wide counters (process CPU time, wall time, rss), the analogue of the
  MSR_SAFE batch-mode node sampler.
* ``hierarchical_report`` — summary / per-MPI / per-node / per-socket /
  per-core JSON, mirroring the paper's report layout.
"""
from __future__ import annotations

import json
import os
import resource
import threading
import time
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core.events import PhaseRecord
from repro.core.simulator import TraceRecord

UNSITED = -1        # site bucket for phase records with no call-site tag


class EventProfiler:
    """Accumulates per-call events into per-site statistics."""

    def __init__(self):
        self.sites: Dict[int, Dict[str, float]] = defaultdict(
            lambda: {"calls": 0, "tslack": 0.0, "tcopy": 0.0, "bytes": 0.0}
        )
        self.per_rank_slack: Dict[int, float] = defaultdict(float)

    def record_call(self, site: int, rank: int, slack: float, copy: float, nbytes: float):
        s = self.sites[site]
        s["calls"] += 1
        s["tslack"] += slack
        s["tcopy"] += copy
        s["bytes"] += nbytes
        self.per_rank_slack[rank] += slack

    def on_phase(self, record: PhaseRecord) -> None:
        """EventBus subscription: fold one reconstructed phase.  Byte counts
        are not observable from the event stream (the instrument never sees
        payload sizes), so ``bytes`` stays 0 for live-sourced sites."""
        self.record_call(
            UNSITED if record.site is None else int(record.site),
            record.rank,
            max(record.t_slack_end - record.t_enter, 0.0),
            max(record.t_copy_end - record.t_slack_end, 0.0),
            0.0,
        )

    def ingest_trace(self, trace: TraceRecord) -> None:
        t_tasks, n = trace.slack.shape
        for k in range(t_tasks):
            site = int(trace.site[k])
            for r in range(n):
                self.record_call(
                    site, r, float(trace.slack[k, r]), float(trace.copy[k, r]),
                    float(trace.nbytes[k]),
                )

    def mpi_report(self) -> Dict[str, Any]:
        return {
            str(site): {k: round(v, 9) for k, v in stats.items()}
            for site, stats in sorted(self.sites.items())
        }


class TimeProfiler:
    """Per-interval host sampling on a daemon thread (default 1 s)."""

    def __init__(self, interval: float = 1.0):
        self.interval = interval
        self.samples: List[Dict[str, float]] = []
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def start(self) -> None:
        self._stop.clear()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self) -> None:
        while not self._stop.is_set():
            ru = resource.getrusage(resource.RUSAGE_SELF)
            self.samples.append(
                {
                    "t": time.monotonic(),
                    "cpu_user_s": ru.ru_utime,
                    "cpu_sys_s": ru.ru_stime,
                    "maxrss_kb": ru.ru_maxrss,
                }
            )
            self._stop.wait(self.interval)

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5)


def hierarchical_report(
    event: EventProfiler,
    timep: Optional[TimeProfiler] = None,
    n_ranks: Optional[int] = None,
    ranks_per_node: int = 36,
    sockets_per_node: int = 2,
    extra: Optional[Dict[str, Any]] = None,
) -> Dict[str, Any]:
    """The paper's summary/MPI/node/socket/core hierarchy as one dict.

    ``n_ranks=None`` infers the fleet size from the ranks actually seen —
    the natural mode for a live-governor-fed profiler, where the caller
    has no simulator config to quote.
    """
    if n_ranks is None:
        n_ranks = (max(event.per_rank_slack) + 1) if event.per_rank_slack else 1
    total_slack = sum(event.per_rank_slack.values())
    total_copy = sum(s["tcopy"] for s in event.sites.values())
    summary = {
        "n_ranks": n_ranks,
        "n_sites": len(event.sites),
        "total_calls": int(sum(s["calls"] for s in event.sites.values())),
        "total_tslack_s": total_slack,
        "total_tcopy_s": total_copy,
    }
    if extra:
        summary.update(extra)
    nodes: Dict[str, Any] = {}
    for rank in range(n_ranks):
        node = rank // ranks_per_node
        in_node = rank % ranks_per_node
        socket = in_node // max(1, ranks_per_node // sockets_per_node)
        nd = nodes.setdefault(f"node{node}", {"tslack_s": 0.0, "sockets": {}})
        sk = nd["sockets"].setdefault(f"socket{socket}", {"tslack_s": 0.0, "cores": {}})
        slack = event.per_rank_slack.get(rank, 0.0)
        nd["tslack_s"] += slack
        sk["tslack_s"] += slack
        sk["cores"][f"core{in_node}"] = {"rank": rank, "tslack_s": slack}
    report = {"summary": summary, "mpi": event.mpi_report(), "nodes": nodes}
    if timep is not None:
        report["time_series"] = timep.samples
    return report


def write_report(report: Dict[str, Any], path: str) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(report, f, indent=1)
