"""P-state (DVFS) actuator and power model.

Modeled on the paper's target (Intel Broadwell E5-2697 v4, §3.2/§6.1):
  * nominal 2.3 GHz, all-core turbo ~2.8 GHz (baseline), min 1.2 GHz;
  * the PCU commits frequency changes only every ~500 µs (Hackenberg) —
    the *reason* the timeout policy exists;
  * package+DRAM power ≈ static + dynamic·(f/fmax)^3·activity, calibrated so
    MinFreq power saving ≈ 36 % (paper Table 3 average).

Frequency-sensitivity of run time uses the standard two-component model:
  T(f) = T(fmax) · ((1-β) + β · fmax/f)
with β the CPU-bound fraction of the phase (β=0: memory/network-bound).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Tuple

import numpy as np


@dataclass(frozen=True)
class HwModel:
    f_min: float = 1.2e9
    f_nom: float = 2.3e9
    f_max: float = 2.8e9                 # all-core turbo (baseline)
    switch_latency: float = 500e-6       # PCU commit interval (Hackenberg)
    # three-component power model (relative to full-load at f_max = 1.0):
    #   P = p_base + p_uncore*mem_act + p_coredyn*core_act*(f/fmax)^3
    # calibrated so Min-Freq power saving under full load ~ 40 %
    # (paper Table 3 avg 36 %, range 26-51 %).
    p_base: float = 0.30                 # leakage + fixed uncore
    p_uncore: float = 0.25               # DRAM + LLC + fabric, ~ memory activity
    p_coredyn: float = 0.45              # core dynamic at f_max, activity 1
    watts_at_fmax: float = 10.1          # 145W TDP + ~36W DRAM over 18 cores
    # per-phase (core_activity, memory_activity):
    #   compute crunches (1,1); busy-wait spin has high issue rate but no
    #   memory traffic; copy stalls the core on DMA/NIC but keeps DRAM busy
    act_comp: Tuple[float, float] = (1.0, 1.0)
    act_slack: Tuple[float, float] = (0.6, 0.1)
    act_copy: Tuple[float, float] = (0.5, 0.9)

    def pstates(self) -> np.ndarray:
        """Available frequency grid (Hz): 1.2–2.3 in 100 MHz steps + turbo."""
        grid = np.arange(self.f_min, self.f_nom + 1e6, 0.1e9)
        return np.append(grid, self.f_max)

    # ---- power -----------------------------------------------------------
    def power(self, f, act: Tuple[float, float] = (1.0, 1.0)):
        """Relative package+DRAM power at frequency ``f`` (vectorized)."""
        f = np.asarray(f, dtype=np.float64)
        core_act, mem_act = act
        return (
            self.p_base
            + self.p_uncore * mem_act
            + self.p_coredyn * core_act * (f / self.f_max) ** 3
        )

    def watts(self, f, act: Tuple[float, float] = (1.0, 1.0)):
        return self.watts_at_fmax * self.power(f, act)

    def f_for_power(self, watts_per_rank, act: Tuple[float, float] = (1.0, 1.0)):
        """Largest frequency whose power stays under ``watts_per_rank``.

        The RAPL inverse of :meth:`watts`: a package cap is enforced by
        clamping the frequency, so a cap below the static + uncore floor
        maps to ``f_min`` (the PCU cannot shed leakage), and a cap above
        full-load power maps to ``f_max``.  Vectorized like the forward
        model.
        """
        rel = np.asarray(watts_per_rank, dtype=np.float64) / self.watts_at_fmax
        core_act, mem_act = act
        dyn = rel - self.p_base - self.p_uncore * mem_act
        f = self.f_max * np.cbrt(
            np.maximum(dyn, 0.0) / (self.p_coredyn * max(core_act, 1e-12))
        )
        return np.clip(f, self.f_min, self.f_max)

    # ---- timing ----------------------------------------------------------
    def slowdown(self, f, beta):
        """T(f)/T(fmax) for a phase with CPU-bound fraction ``beta``."""
        f = np.asarray(f, dtype=np.float64)
        return (1.0 - beta) + beta * (self.f_max / f)

    def theta_eff(self, theta: float) -> float:
        """Effective timeout threshold: timer expiry plus the expected PCU
        commit quantization (half the commit interval).  The one formula
        both the governor's pricing and the simulator's trajectory use —
        keep them identical or replay loses bit-exactness."""
        return theta + 0.5 * self.switch_latency

    def theta_bounds(self, theta_max: float = 50e-3) -> Tuple[float, float]:
        """Realizable reactive-timeout range ``[switch_latency/2, theta_max]``.

        Below half the PCU commit interval the timer fires faster than the
        hardware can commit the P-state change, so a smaller theta cannot
        be realized; above ``theta_max`` the timeout never fires in practice
        and the policy degenerates to baseline.  The :class:`~repro.core.
        timeout.ThetaTuner` clamps every adjustment to this interval.
        """
        return (self.switch_latency / 2.0, theta_max)


DEFAULT_HW = HwModel()
