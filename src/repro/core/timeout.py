"""Online theta auto-tuning: close the loop the fixed 500 us timeout leaves open.

The paper tunes its reactive timeout once, to one machine's PCU commit
latency (COUNTDOWN Slack §5).  That constant is the single shared knob of
every policy in :mod:`repro.core.policies` — and a misprediction in either
direction "jeopardizes the benefit": too low and the restore latency bleeds
into the copy/compute phases (overhead), too high and exploitable slack is
left on the table (lost saving).  :class:`ThetaTuner` replaces the constant
with a measured quantity per call site:

* **Slack CDF target (decay)** — each site keeps a log-binned histogram of
  its observed slack.  Downshifting a call costs one PCU residue: the
  restore pins the next phase at f_min for up to ``switch_latency``, which
  stretches that phase by ``c ~= residue_cost_frac * switch_latency``
  (the fraction is the time lost to running a partially CPU-bound phase at
  f_min — ~0.15 for the calibrated beta range; the AIMD loop below corrects
  the prior when a phase is hungrier).  The tuner picks the smallest
  threshold whose downshift cost stays under ``target_overhead`` of the
  busy time observed at that site::

      theta_target = min { theta : c * N_down(theta) <= rho * T_busy }

  with ``N_down(theta) = #{slack >= theta}``, ``T_busy`` the accumulated
  compute+slack+copy seconds observed at the site (the governor measures
  compute as the gap from a rank's previous phase end to its barrier
  enter, so the budget is a fraction of *time to completion*, the paper's
  bar — not of the comm window alone), and ``rho = target_overhead``
  (1 % by default).  ``theta_eff`` then relaxes toward the target
  geometrically: ``theta += decay * (theta_target - theta)``.

* **AIMD raise** — prediction is checked against the one signal the
  runtime can actually observe: the copy phase directly after a downshift.
  If a downshifted call's copy ran ``slow_tol`` slower than the site's
  reference (EMA live, exact offline) *and* the extra seconds are material
  against the per-call overhead budget (``rho * mean busy``), the model
  under-priced the residue — theta is raised multiplicatively
  (``raise_factor``) and allowed to decay back.  This is the classic
  congestion-control shape: gentle probing toward the CDF target, sharp
  backoff on observed harm.  The materiality condition keeps a relatively
  slow but tiny copy (60 us extra on a 30 ms task) from stampeding theta
  upward.

* **Hard bounds** — theta is always clamped to
  ``[switch_latency / 2, theta_max]`` (:meth:`HwModel.theta_bounds`): below
  half the commit interval the timer fires faster than the PCU can commit,
  so a lower theta cannot be realized in hardware; above ``theta_max`` the
  timeout never fires and the policy degenerates to baseline.

Every adjustment is a structured :class:`ThetaDecision`; the governor logs
them next to actuations and the trace recorder serializes them (schema v2),
so an adaptive run replays bit-for-bit: the tuner is a pure function of the
observation order.

:class:`PredictiveTuner` (the ``cntd_predictive`` policy) layers the online
:class:`~repro.core.predictor.OnlinePredictor` on top: when the predicted
slack for a (site, rank) clears the residue-cost bar, the P-state downshift
is *pre-armed* at comm entry — it no longer waits for theta to expire, so
the exploited window starts at the PCU commit quantization instead of
``theta_eff``.  The paper's central claim is that such prediction
mispredicts and slows applications (COUNTDOWN §2; Fermata/Adagio pay this
cost); the tuner therefore wraps every pre-arm in a **misprediction
guard**: realized costs per site (the ``c_down`` early-restore residue for
pre-arms whose slack never materialized, plus observed copy-stretch on
pre-arms the reactive path would not have issued) accumulate against the
same 1% overhead budget the CDF target uses, and a site whose cost exceeds
its budget falls back — permanently — to the pure :class:`ThetaTuner`
path.  Guard bookings and pre-arms are structured
:class:`PredictorDecision` records (trace schema v3), replayed
bit-for-bit like theta decisions.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, NamedTuple, Optional

import numpy as np

from repro.core.pstate import DEFAULT_HW, HwModel


class PredictorDecision(NamedTuple):
    """One predictor-path event (structured like :class:`ThetaDecision`, so
    recorders and benchmarks consume it without scraping).

    ``kind`` is one of:

    * ``"prearm"`` — the downshift was pre-armed and the slack cleared the
      bar; ``predicted``/``observed`` are the predicted and realized slack.
    * ``"mispredict"`` — pre-armed, but the realized slack fell short of
      the bar; ``cost`` seconds (the early-restore residue) were booked
      against the site's guard.
    * ``"trip"`` — the site's cumulative misprediction cost exceeded its
      overhead budget; the site falls back to the pure ThetaTuner path.
      ``predicted`` carries the cumulative booked cost, ``observed`` the
      budget at trip time.
    """

    t: float
    site: int
    rank: int                    # -1 for batched (simulator) observations
    kind: str                    # "prearm" | "mispredict" | "trip"
    predicted: float
    observed: float
    cost: float                  # seconds booked against the guard by this record
    source: str                  # prediction regime ("forest" | "ema"); for
    #                              trips, the gate that fired ("budget" | "ev")


class ThetaDecision(NamedTuple):
    """One tuner adjustment (structured like :class:`~repro.core.governor.
    Actuation`, so recorders and benchmarks consume it without scraping)."""

    t: float
    site: int
    rank: int                    # -1 for batched (simulator) observations
    theta_before: float
    theta_after: float
    reason: str                  # "decay" | "raise"
    slack: float                 # observation that triggered it (copy for raises)


@dataclass
class _SiteState:
    theta: float
    counts: np.ndarray           # histogram over the shared log bin edges
    busy: float = 0.0            # accumulated compute + slack + copy seconds
    n_slack: int = 0
    copy_ema: Optional[float] = None   # residue-free copy reference
    copy_min: Optional[float] = None   # least-stretched downshifted copy:
    # the fallback reference for sites where every call downshifts


@dataclass
class ThetaTuner:
    """Per-callsite online theta adaptation against the measured HwModel.

    Deterministic given the observation order — the property the trace
    replay differential test pins down.
    """

    hw: HwModel = DEFAULT_HW
    theta0: float = 500e-6
    theta_max: float = 50e-3
    target_overhead: float = 0.01    # rho: downshift cost bound vs busy time
    decay: float = 0.25              # geometric pull toward the CDF target
    raise_factor: float = 2.0        # AIMD multiplicative backoff
    slow_tol: float = 0.10           # relative copy slowdown raise trigger
    residue_cost_frac: float = 0.15  # expected time lost per pinned residue
    ema_alpha: float = 0.2           # copy reference EMA weight
    min_samples: int = 8             # observations before leaving theta0
    decision_tol: float = 1e-9       # suppress no-op decision records

    def __post_init__(self) -> None:
        self.theta_min, _ = self.hw.theta_bounds(self.theta_max)
        self.theta0 = self._clamp(self.theta0)
        # shared log-spaced slack bins: 1 us .. 30 s
        self._edges = np.geomspace(1e-6, 30.0, 97)
        self._sites: Dict[int, _SiteState] = {}
        self.decisions: List[ThetaDecision] = []
        # expected per-downshift cost: the pinned residue's time stretch
        self._c_down = self.residue_cost_frac * self.hw.switch_latency

    # ---- queries ---------------------------------------------------------
    def _clamp(self, theta: float) -> float:
        return float(min(max(theta, self.hw.switch_latency / 2.0), self.theta_max))

    def theta_for(self, site: int) -> float:
        """Current theta for ``site`` (theta0, clamped, when unseen)."""
        st = self._sites.get(site)
        return st.theta if st is not None else self.theta0

    def summary(self) -> Dict[int, float]:
        return {site: st.theta for site, st in self._sites.items()}

    # ---- internals -------------------------------------------------------
    def _state(self, site: int) -> _SiteState:
        st = self._sites.get(site)
        if st is None:
            st = _SiteState(theta=self.theta0,
                            counts=np.zeros(len(self._edges) - 1, np.int64))
            self._sites[site] = st
        return st

    def _target(self, st: _SiteState) -> float:
        """Smallest threshold whose worst-case downshift cost respects the
        overhead budget — the percentile of the slack CDF the docstring
        derives.  Conservative (theta0) until ``min_samples`` accrue."""
        if st.n_slack < self.min_samples or st.busy <= 0.0:
            return self.theta0
        total = int(st.counts.sum())
        budget = self.target_overhead * st.busy
        # N_down(edge[i]) = samples at or above edge i = total - cum[i]
        cum = np.concatenate(([0], np.cumsum(st.counts)))
        n_down = total - cum
        feasible = self._c_down * n_down <= budget
        idx = int(np.argmax(feasible)) if feasible.any() else len(self._edges) - 1
        return self._clamp(float(self._edges[idx]))

    def _decide(self, st: _SiteState, site: int, rank: int, t: float,
                new_theta: float, reason: str, obs: float) -> Optional[ThetaDecision]:
        new_theta = self._clamp(new_theta)
        # relative suppression: the geometric decay approaches its target
        # asymptotically — without this, every observation would log an
        # ever-smaller no-op decision into the trace forever
        if abs(new_theta - st.theta) <= self.decision_tol + 1e-4 * st.theta:
            st.theta = new_theta
            return None
        dec = ThetaDecision(t, site, rank, st.theta, new_theta, reason, obs)
        st.theta = new_theta
        self.decisions.append(dec)
        return dec

    # ---- observations (governor path: scalar, event-ordered) -------------
    def observe_slack(self, site: int, slack: float, t: float, rank: int = 0,
                      comp: float = 0.0) -> Optional[ThetaDecision]:
        """Account one measured slack (plus the ``comp`` seconds that led
        into the call, when the caller can measure them — they widen the
        overhead budget to the paper's time-to-completion denominator);
        relax theta toward the CDF target."""
        st = self._state(site)
        slack = max(float(slack), 0.0)
        b = int(np.clip(np.searchsorted(self._edges, slack, side="right") - 1,
                        0, len(st.counts) - 1))
        st.counts[b] += 1
        st.busy += slack + max(float(comp), 0.0)
        st.n_slack += 1
        target = self._target(st)
        return self._decide(st, site, rank, t,
                            st.theta + self.decay * (target - st.theta),
                            "decay", slack)

    def _raise_budget(self, st: _SiteState) -> float:
        """Extra seconds per call that breach the overhead target: rho times
        the mean per-observation busy time at this site."""
        return self.target_overhead * st.busy / max(st.n_slack, 1)

    def observe_copy(self, site: int, copy: float, t: float, rank: int = 0,
                     downshifted: bool = False) -> Optional[ThetaDecision]:
        """Account a copy phase; AIMD-raise if a downshifted call's copy ran
        ``slow_tol`` over the site's EMA reference (the residue bled) by a
        margin that matters against the overhead budget."""
        st = self._state(site)
        copy = max(float(copy), 0.0)
        st.busy += copy
        dec = None
        # the reference must stay residue-free: an EMA of clean copies when
        # the site has any, else the least-stretched downshifted copy seen
        # (a downshifted copy must never SEED the EMA — on a site whose
        # first call downshifts, that would lock the reference at the
        # stretched duration and permanently disarm the raise)
        ref = st.copy_ema if st.copy_ema is not None else st.copy_min
        if (downshifted and ref is not None
                and copy > ref * (1.0 + self.slow_tol)
                and copy - ref > self._raise_budget(st)):
            dec = self._decide(st, site, rank, t, st.theta * self.raise_factor,
                               "raise", copy)
        if downshifted:
            st.copy_min = copy if st.copy_min is None else min(st.copy_min, copy)
        elif st.copy_ema is None:
            st.copy_ema = copy
        else:
            st.copy_ema = (1.0 - self.ema_alpha) * st.copy_ema + self.ema_alpha * copy
        return dec

    # ---- observations (simulator path: one batch per task) ---------------
    def observe_slack_batch(self, site: int, slacks: np.ndarray, t: float,
                            comp: Optional[np.ndarray] = None) -> Optional[ThetaDecision]:
        """Vectorized :meth:`observe_slack`: histogram the whole rank vector,
        apply ONE decay step (the task is one decision epoch)."""
        st = self._state(site)
        slacks = np.maximum(np.asarray(slacks, np.float64), 0.0)
        hist, _ = np.histogram(np.clip(slacks, self._edges[0], self._edges[-1]),
                               bins=self._edges)
        st.counts += hist
        st.busy += float(slacks.sum())
        if comp is not None:
            st.busy += float(np.maximum(np.asarray(comp, np.float64), 0.0).sum())
        st.n_slack += int(slacks.size)
        target = self._target(st)
        return self._decide(st, site, -1, t,
                            st.theta + self.decay * (target - st.theta),
                            "decay", float(slacks.mean()) if slacks.size else 0.0)

    def observe_copy_slowdown(self, site: int, copy_busy: float, extra: float,
                              frac: float, t: float) -> Optional[ThetaDecision]:
        """Simulator feedback: the realized copy-phase slowdown of a
        downshifted task — ``extra`` seconds over the residue-free copy,
        ``frac`` relative (exactly known offline, EMA-estimated live)."""
        st = self._state(site)
        st.busy += max(float(copy_busy), 0.0)
        if frac > self.slow_tol and extra > self._raise_budget(st):
            return self._decide(st, site, -1, t, st.theta * self.raise_factor,
                                "raise", float(frac))
        return None

    def reset(self) -> None:
        self._sites.clear()
        self.decisions.clear()


@dataclass
class _GuardState:
    """Per-site misprediction ledger for :class:`PredictiveTuner`."""

    cost: float = 0.0            # booked misprediction seconds
    gain: float = 0.0            # booked extra f_min residency pre-arms won
    n_armed: int = 0             # pre-arms issued
    n_mispredict: int = 0        # pre-arms whose slack fell below break-even
    tripped: bool = False        # permanent fallback to the pure tuner path


@dataclass
class PredictiveTuner(ThetaTuner):
    """Hybrid predictor+timeout theta source (the ``cntd_predictive``
    policy): a :class:`ThetaTuner` whose per-occurrence decision may be
    *pre-armed* by the online predictor, under a per-site misprediction
    guard.

    ``reactive=True`` (the hybrid): a non-armed occurrence keeps the pure
    tuner threshold — prediction can only accelerate the downshift, never
    lose the reactive safety net.  ``reactive=False`` is the paper's
    prediction-only strawman (Fermata/Adagio-style): non-armed occurrences
    never downshift, and with ``guarded=False`` nothing bounds the
    misprediction cost — the configuration the Table-3 bench shows
    overshooting the 1% budget.

    The pre-arm bar: a predicted slack must at least cover the PCU commit
    quantization (``hw.theta_eff(0)`` — a shorter slack ends before the
    pinned P-state even commits) plus ``arm_margin`` expected residue
    costs.  The guard keeps a two-sided per-site ledger.  Cost: each
    mispredicted pre-arm books its *unabsorbed serialization residue* —
    the restore issued at slack end completes only after the in-flight
    down leg commits, pinning ``2*lat - min(slack, lat)`` seconds of the
    following copy/compute at f_min, of which the site's median slack
    (read off the tuner's own histogram) is typically re-absorbed by the
    next wait — floored at ``c_down``; realized copy-stretch seconds on
    pre-arms the reactive threshold would not have issued book on top.
    Gain: each correct pre-arm books the extra f_min residency it won over
    the reactive path, ``min(slack, theta_eff(theta)) - theta_eff(0)``.
    A site trips (permanently — :meth:`decide` returns the pure tuner path
    forever, making its decisions identical to a plain
    :class:`ThetaTuner`'s, property-tested) on either gate: booked cost
    exceeds ``target_overhead`` of its observed busy time (the 1% budget,
    the ISSUE's headline condition), or — after ``ev_min_armed`` pre-arms
    — booked cost exceeds booked gain (the site is negative-EV: the paper
    families where slack straddles the bar lose more to mispredicted
    residue than marginal pre-arms can ever win back).  Both gates share a
    small ``guard_grace`` floor so one early misprediction on a young site
    does not condemn it.

    Deterministic like its base: predictor refits are counter-triggered and
    seeded, so the whole hybrid remains a pure function of the observation
    order and replays bit-for-bit from a v3 trace.
    """

    reactive: bool = True        # keep the timeout fallback on non-armed calls
    guarded: bool = True         # False: the unguarded prediction-only strawman
    arm_margin: float = 4.0      # bar = theta_eff(0) + arm_margin * c_down
    guard_grace: float = 3.0     # min booked residues before a trip can fire
    ev_min_armed: int = 32       # pre-arms before the cost>gain gate can trip
    predictor: Optional[object] = None   # OnlinePredictor (built if absent)

    def __post_init__(self) -> None:
        super().__post_init__()
        if self.predictor is None:
            # deferred: predictor.py imports simulator; keep this module light
            from repro.core.predictor import OnlinePredictor

            self.predictor = OnlinePredictor()
        self._guards: Dict[int, _GuardState] = {}
        self.pred_decisions: List[PredictorDecision] = []
        self._arm_eff = self.hw.theta_eff(0.0)
        self._bar = self._arm_eff + self.arm_margin * self._c_down
        if not self.reactive and not self.guarded:
            # the naive strawman pre-arms on ANY predicted slack — no
            # break-even bar, no safety margin; the bar+margin (and the
            # guard) are exactly what the hybrid adds on top
            self._bar = 0.0

    # ---- queries ---------------------------------------------------------
    @property
    def arm_bar(self) -> float:
        """Predicted slack below this never pre-arms (seconds)."""
        return self._bar

    def guard_state(self, site: int) -> _GuardState:
        g = self._guards.get(site)
        if g is None:
            g = _GuardState()
            self._guards[site] = g
        return g

    def tripped(self, site: int) -> bool:
        g = self._guards.get(site)
        return g is not None and g.tripped

    def trip_site(self, site: int) -> None:
        """Force a site onto the pure ThetaTuner path (operator override;
        also how the fallback property test pins equivalence)."""
        self.guard_state(site).tripped = True

    def guard_summary(self) -> Dict[int, Dict[str, float]]:
        return {
            site: {"cost": g.cost, "gain": g.gain, "n_armed": g.n_armed,
                   "n_mispredict": g.n_mispredict, "tripped": g.tripped}
            for site, g in self._guards.items()
        }

    # ---- guard pricing ---------------------------------------------------
    def _slack_median(self, site: int) -> float:
        """Median of the site's observed slack, read off the tuner's own
        log-binned histogram (left edge of the median bin: conservative,
        deterministic)."""
        st = self._sites.get(site)
        if st is None or st.n_slack == 0:
            return 0.0
        total = int(st.counts.sum())
        if total == 0:
            return 0.0
        cum = np.cumsum(st.counts)
        idx = int(np.searchsorted(cum, (total + 1) // 2))
        return float(self._edges[min(idx, len(self._edges) - 1)])

    def _mispredict_cost(self, site: int, slack: float) -> float:
        """Seconds a mispredicted pre-arm costs: the serialization residue
        (the restore completes one switch latency after the in-flight down
        leg commits: ``2*lat - min(slack, lat)`` pinned at f_min) minus
        what the site's median slack typically re-absorbs at the next
        wait, floored at ``c_down`` (the booking a correct-but-marginal
        downshift would also pay)."""
        lat = self.hw.switch_latency
        resid = 2.0 * lat - min(max(slack, 0.0), lat)
        return max(self._c_down, resid - self._slack_median(site))

    def _prearm_gain(self, site: int, slack: float) -> float:
        """Seconds of extra f_min residency a correct pre-arm won over the
        reactive path (which waits out ``theta_eff(theta)`` first)."""
        reactive_eff = self.hw.theta_eff(self.theta_for(site))
        return max(0.0, min(slack, reactive_eff) - self._arm_eff)

    # ---- the pre-arm decision (BEFORE the occurrence is observed) --------
    def decide(self, site: int, rank: int):
        """(armed, predicted_slack, source) for one occurrence — consulted
        at comm entry, i.e. strictly before this occurrence's slack is
        observed (the same causality the live runtime has)."""
        if self.guarded and self.tripped(site):
            return False, float("nan"), "tripped"
        pred, src = self.predictor.predict(site, rank)
        armed = bool(pred >= self._bar) if pred == pred else False  # NaN-safe
        return armed, pred, src

    def predict_ranks(self, site: int, n: int):
        """Delegate to the predictor's vectorized per-rank prediction (the
        simulator path); returns ``(preds, source)`` with NaN for cold
        ranks."""
        return self.predictor.predict_ranks(site, n)

    def arm_mask(self, site: int, preds: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`decide` arm test over a rank vector of
        predictions (the simulator path)."""
        if self.guarded and self.tripped(site):
            return np.zeros(len(preds), dtype=bool)
        with np.errstate(invalid="ignore"):
            return np.asarray(preds, np.float64) >= self._bar

    # ---- outcome accounting (guard bookings + predictor training) -------
    def _check_trip(self, site: int, g: _GuardState, t: float,
                    rank: int) -> List[PredictorDecision]:
        if not self.guarded or g.tripped:
            return []
        if g.cost <= self.guard_grace * self._c_down:
            return []
        st = self._state(site)
        budget = self.target_overhead * st.busy
        gate = ""
        if g.cost > budget:
            gate = "budget"              # the 1% overhead bound
        elif g.n_armed >= self.ev_min_armed and g.cost > g.gain:
            gate = "ev"                  # negative expected value: cost > gain
        if not gate:
            return []
        g.tripped = True
        dec = PredictorDecision(t, site, rank, "trip", g.cost,
                                budget if gate == "budget" else g.gain,
                                0.0, gate)
        self.pred_decisions.append(dec)
        return [dec]

    def account_outcome(self, site: int, rank: int, t: float, predicted: float,
                        slack: float, armed: bool, source: str,
                        comp: float = 0.0) -> List[PredictorDecision]:
        """Book one occurrence's realized outcome against its pre-arm
        decision, then roll the predictor forward.  Returns the structured
        records this outcome produced (0–2: a prearm/mispredict, plus a
        trip when the booking crosses the budget)."""
        decs: List[PredictorDecision] = []
        slack = max(float(slack), 0.0)
        if armed:
            g = self.guard_state(site)
            g.n_armed += 1
            # a mispredict is a pre-arm whose slack fell below break-even
            # (theta_eff(0)): it ended before the pinned P-state committed
            if slack < self._arm_eff:
                g.n_mispredict += 1
                cost = self._mispredict_cost(site, slack)
                g.cost += cost
                dec = PredictorDecision(t, site, rank, "mispredict",
                                        float(predicted), slack, cost, source)
            else:
                g.gain += self._prearm_gain(site, slack)
                dec = PredictorDecision(t, site, rank, "prearm",
                                        float(predicted), slack, 0.0, source)
            self.pred_decisions.append(dec)
            decs.append(dec)
            decs.extend(self._check_trip(site, g, t, rank))
        self.predictor.observe(site, rank, slack, comp)
        return decs

    def account_outcome_batch(self, site: int, preds: np.ndarray,
                              slacks: np.ndarray, armed: np.ndarray, t: float,
                              source: str,
                              comp: Optional[np.ndarray] = None,
                              ) -> List[PredictorDecision]:
        """Vectorized :meth:`account_outcome` for one task's rank vector
        (the simulator path): guard bookings per armed rank in rank order,
        one trip check per booking, then the predictor rolls forward over
        the whole vector."""
        decs: List[PredictorDecision] = []
        slacks = np.maximum(np.asarray(slacks, np.float64), 0.0)
        if armed.any():
            g = self.guard_state(site)
            for r in np.nonzero(armed)[0].tolist():
                g.n_armed += 1
                s = float(slacks[r])
                if s < self._arm_eff:
                    g.n_mispredict += 1
                    cost = self._mispredict_cost(site, s)
                    g.cost += cost
                    dec = PredictorDecision(t, site, r, "mispredict",
                                            float(preds[r]), s, cost, source)
                else:
                    g.gain += self._prearm_gain(site, s)
                    dec = PredictorDecision(t, site, r, "prearm",
                                            float(preds[r]), s, 0.0, source)
                self.pred_decisions.append(dec)
                decs.append(dec)
                decs.extend(self._check_trip(site, g, t, r))
        self.predictor.observe_ranks(site, slacks, comp)
        return decs

    def copy_reference(self, site: int) -> Optional[float]:
        """The site's residue-free copy reference (EMA when clean copies
        exist, else the least-stretched downshifted copy) — read *before*
        ``observe_copy`` folds the current copy in."""
        st = self._sites.get(site)
        if st is None:
            return None
        return st.copy_ema if st.copy_ema is not None else st.copy_min

    def guard_copy(self, site: int, copy: float, t: float,
                   rank: int = -1) -> List[PredictorDecision]:
        """Book the realized copy-stretch of a pre-arm the reactive path
        would not have issued (the caller has established that: the
        occurrence was armed and its slack was below the reactive
        threshold).  Uses the same materiality test as the AIMD raise so a
        tiny stretch on a huge task cannot trip the guard."""
        if not self.guarded:
            return []
        g = self.guard_state(site)
        if g.tripped:
            return []
        ref = self.copy_reference(site)
        if ref is None or copy <= ref * (1.0 + self.slow_tol):
            return []
        g.cost += copy - ref
        return self._check_trip(site, g, t, rank)

    def guard_copy_batch(self, site: int, extras: np.ndarray,
                         fracs: np.ndarray, t: float) -> List[PredictorDecision]:
        """Simulator feedback: exact per-rank copy-stretch seconds of
        pre-armed ranks the reactive threshold would not have downshifted
        (``extras`` absolute, ``fracs`` relative).  Same materiality test
        as :meth:`guard_copy`, booked in rank order."""
        if not self.guarded:
            return []
        g = self.guard_state(site)
        decs: List[PredictorDecision] = []
        for extra, frac in zip(np.asarray(extras, np.float64).tolist(),
                               np.asarray(fracs, np.float64).tolist()):
            if g.tripped:
                break
            if frac > self.slow_tol and extra > 0.0:
                g.cost += extra
                decs.extend(self._check_trip(site, g, t, -1))
        return decs

    def reset(self) -> None:
        super().reset()
        self._guards.clear()
        self.pred_decisions.clear()
        self.predictor.reset()
