"""Online theta auto-tuning: close the loop the fixed 500 us timeout leaves open.

The paper tunes its reactive timeout once, to one machine's PCU commit
latency (COUNTDOWN Slack §5).  That constant is the single shared knob of
every policy in :mod:`repro.core.policies` — and a misprediction in either
direction "jeopardizes the benefit": too low and the restore latency bleeds
into the copy/compute phases (overhead), too high and exploitable slack is
left on the table (lost saving).  :class:`ThetaTuner` replaces the constant
with a measured quantity per call site:

* **Slack CDF target (decay)** — each site keeps a log-binned histogram of
  its observed slack.  Downshifting a call costs one PCU residue: the
  restore pins the next phase at f_min for up to ``switch_latency``, which
  stretches that phase by ``c ~= residue_cost_frac * switch_latency``
  (the fraction is the time lost to running a partially CPU-bound phase at
  f_min — ~0.15 for the calibrated beta range; the AIMD loop below corrects
  the prior when a phase is hungrier).  The tuner picks the smallest
  threshold whose downshift cost stays under ``target_overhead`` of the
  busy time observed at that site::

      theta_target = min { theta : c * N_down(theta) <= rho * T_busy }

  with ``N_down(theta) = #{slack >= theta}``, ``T_busy`` the accumulated
  compute+slack+copy seconds observed at the site (the governor measures
  compute as the gap from a rank's previous phase end to its barrier
  enter, so the budget is a fraction of *time to completion*, the paper's
  bar — not of the comm window alone), and ``rho = target_overhead``
  (1 % by default).  ``theta_eff`` then relaxes toward the target
  geometrically: ``theta += decay * (theta_target - theta)``.

* **AIMD raise** — prediction is checked against the one signal the
  runtime can actually observe: the copy phase directly after a downshift.
  If a downshifted call's copy ran ``slow_tol`` slower than the site's
  reference (EMA live, exact offline) *and* the extra seconds are material
  against the per-call overhead budget (``rho * mean busy``), the model
  under-priced the residue — theta is raised multiplicatively
  (``raise_factor``) and allowed to decay back.  This is the classic
  congestion-control shape: gentle probing toward the CDF target, sharp
  backoff on observed harm.  The materiality condition keeps a relatively
  slow but tiny copy (60 us extra on a 30 ms task) from stampeding theta
  upward.

* **Hard bounds** — theta is always clamped to
  ``[switch_latency / 2, theta_max]`` (:meth:`HwModel.theta_bounds`): below
  half the commit interval the timer fires faster than the PCU can commit,
  so a lower theta cannot be realized in hardware; above ``theta_max`` the
  timeout never fires and the policy degenerates to baseline.

Every adjustment is a structured :class:`ThetaDecision`; the governor logs
them next to actuations and the trace recorder serializes them (schema v2),
so an adaptive run replays bit-for-bit: the tuner is a pure function of the
observation order.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, NamedTuple, Optional

import numpy as np

from repro.core.pstate import DEFAULT_HW, HwModel


class ThetaDecision(NamedTuple):
    """One tuner adjustment (structured like :class:`~repro.core.governor.
    Actuation`, so recorders and benchmarks consume it without scraping)."""

    t: float
    site: int
    rank: int                    # -1 for batched (simulator) observations
    theta_before: float
    theta_after: float
    reason: str                  # "decay" | "raise"
    slack: float                 # observation that triggered it (copy for raises)


@dataclass
class _SiteState:
    theta: float
    counts: np.ndarray           # histogram over the shared log bin edges
    busy: float = 0.0            # accumulated compute + slack + copy seconds
    n_slack: int = 0
    copy_ema: Optional[float] = None   # residue-free copy reference
    copy_min: Optional[float] = None   # least-stretched downshifted copy:
    # the fallback reference for sites where every call downshifts


@dataclass
class ThetaTuner:
    """Per-callsite online theta adaptation against the measured HwModel.

    Deterministic given the observation order — the property the trace
    replay differential test pins down.
    """

    hw: HwModel = DEFAULT_HW
    theta0: float = 500e-6
    theta_max: float = 50e-3
    target_overhead: float = 0.01    # rho: downshift cost bound vs busy time
    decay: float = 0.25              # geometric pull toward the CDF target
    raise_factor: float = 2.0        # AIMD multiplicative backoff
    slow_tol: float = 0.10           # relative copy slowdown raise trigger
    residue_cost_frac: float = 0.15  # expected time lost per pinned residue
    ema_alpha: float = 0.2           # copy reference EMA weight
    min_samples: int = 8             # observations before leaving theta0
    decision_tol: float = 1e-9       # suppress no-op decision records

    def __post_init__(self) -> None:
        self.theta_min, _ = self.hw.theta_bounds(self.theta_max)
        self.theta0 = self._clamp(self.theta0)
        # shared log-spaced slack bins: 1 us .. 30 s
        self._edges = np.geomspace(1e-6, 30.0, 97)
        self._sites: Dict[int, _SiteState] = {}
        self.decisions: List[ThetaDecision] = []
        # expected per-downshift cost: the pinned residue's time stretch
        self._c_down = self.residue_cost_frac * self.hw.switch_latency

    # ---- queries ---------------------------------------------------------
    def _clamp(self, theta: float) -> float:
        return float(min(max(theta, self.hw.switch_latency / 2.0), self.theta_max))

    def theta_for(self, site: int) -> float:
        """Current theta for ``site`` (theta0, clamped, when unseen)."""
        st = self._sites.get(site)
        return st.theta if st is not None else self.theta0

    def summary(self) -> Dict[int, float]:
        return {site: st.theta for site, st in self._sites.items()}

    # ---- internals -------------------------------------------------------
    def _state(self, site: int) -> _SiteState:
        st = self._sites.get(site)
        if st is None:
            st = _SiteState(theta=self.theta0,
                            counts=np.zeros(len(self._edges) - 1, np.int64))
            self._sites[site] = st
        return st

    def _target(self, st: _SiteState) -> float:
        """Smallest threshold whose worst-case downshift cost respects the
        overhead budget — the percentile of the slack CDF the docstring
        derives.  Conservative (theta0) until ``min_samples`` accrue."""
        if st.n_slack < self.min_samples or st.busy <= 0.0:
            return self.theta0
        total = int(st.counts.sum())
        budget = self.target_overhead * st.busy
        # N_down(edge[i]) = samples at or above edge i = total - cum[i]
        cum = np.concatenate(([0], np.cumsum(st.counts)))
        n_down = total - cum
        feasible = self._c_down * n_down <= budget
        idx = int(np.argmax(feasible)) if feasible.any() else len(self._edges) - 1
        return self._clamp(float(self._edges[idx]))

    def _decide(self, st: _SiteState, site: int, rank: int, t: float,
                new_theta: float, reason: str, obs: float) -> Optional[ThetaDecision]:
        new_theta = self._clamp(new_theta)
        # relative suppression: the geometric decay approaches its target
        # asymptotically — without this, every observation would log an
        # ever-smaller no-op decision into the trace forever
        if abs(new_theta - st.theta) <= self.decision_tol + 1e-4 * st.theta:
            st.theta = new_theta
            return None
        dec = ThetaDecision(t, site, rank, st.theta, new_theta, reason, obs)
        st.theta = new_theta
        self.decisions.append(dec)
        return dec

    # ---- observations (governor path: scalar, event-ordered) -------------
    def observe_slack(self, site: int, slack: float, t: float, rank: int = 0,
                      comp: float = 0.0) -> Optional[ThetaDecision]:
        """Account one measured slack (plus the ``comp`` seconds that led
        into the call, when the caller can measure them — they widen the
        overhead budget to the paper's time-to-completion denominator);
        relax theta toward the CDF target."""
        st = self._state(site)
        slack = max(float(slack), 0.0)
        b = int(np.clip(np.searchsorted(self._edges, slack, side="right") - 1,
                        0, len(st.counts) - 1))
        st.counts[b] += 1
        st.busy += slack + max(float(comp), 0.0)
        st.n_slack += 1
        target = self._target(st)
        return self._decide(st, site, rank, t,
                            st.theta + self.decay * (target - st.theta),
                            "decay", slack)

    def _raise_budget(self, st: _SiteState) -> float:
        """Extra seconds per call that breach the overhead target: rho times
        the mean per-observation busy time at this site."""
        return self.target_overhead * st.busy / max(st.n_slack, 1)

    def observe_copy(self, site: int, copy: float, t: float, rank: int = 0,
                     downshifted: bool = False) -> Optional[ThetaDecision]:
        """Account a copy phase; AIMD-raise if a downshifted call's copy ran
        ``slow_tol`` over the site's EMA reference (the residue bled) by a
        margin that matters against the overhead budget."""
        st = self._state(site)
        copy = max(float(copy), 0.0)
        st.busy += copy
        dec = None
        # the reference must stay residue-free: an EMA of clean copies when
        # the site has any, else the least-stretched downshifted copy seen
        # (a downshifted copy must never SEED the EMA — on a site whose
        # first call downshifts, that would lock the reference at the
        # stretched duration and permanently disarm the raise)
        ref = st.copy_ema if st.copy_ema is not None else st.copy_min
        if (downshifted and ref is not None
                and copy > ref * (1.0 + self.slow_tol)
                and copy - ref > self._raise_budget(st)):
            dec = self._decide(st, site, rank, t, st.theta * self.raise_factor,
                               "raise", copy)
        if downshifted:
            st.copy_min = copy if st.copy_min is None else min(st.copy_min, copy)
        elif st.copy_ema is None:
            st.copy_ema = copy
        else:
            st.copy_ema = (1.0 - self.ema_alpha) * st.copy_ema + self.ema_alpha * copy
        return dec

    # ---- observations (simulator path: one batch per task) ---------------
    def observe_slack_batch(self, site: int, slacks: np.ndarray, t: float,
                            comp: Optional[np.ndarray] = None) -> Optional[ThetaDecision]:
        """Vectorized :meth:`observe_slack`: histogram the whole rank vector,
        apply ONE decay step (the task is one decision epoch)."""
        st = self._state(site)
        slacks = np.maximum(np.asarray(slacks, np.float64), 0.0)
        hist, _ = np.histogram(np.clip(slacks, self._edges[0], self._edges[-1]),
                               bins=self._edges)
        st.counts += hist
        st.busy += float(slacks.sum())
        if comp is not None:
            st.busy += float(np.maximum(np.asarray(comp, np.float64), 0.0).sum())
        st.n_slack += int(slacks.size)
        target = self._target(st)
        return self._decide(st, site, -1, t,
                            st.theta + self.decay * (target - st.theta),
                            "decay", float(slacks.mean()) if slacks.size else 0.0)

    def observe_copy_slowdown(self, site: int, copy_busy: float, extra: float,
                              frac: float, t: float) -> Optional[ThetaDecision]:
        """Simulator feedback: the realized copy-phase slowdown of a
        downshifted task — ``extra`` seconds over the residue-free copy,
        ``frac`` relative (exactly known offline, EMA-estimated live)."""
        st = self._state(site)
        st.busy += max(float(copy_busy), 0.0)
        if frac > self.slow_tol and extra > self._raise_budget(st):
            return self._decide(st, site, -1, t, st.theta * self.raise_factor,
                                "raise", float(frac))
        return None

    def reset(self) -> None:
        self._sites.clear()
        self.decisions.clear()
