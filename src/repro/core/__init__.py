"""COUNTDOWN Slack core: the paper's contribution as a composable JAX module.

The public surface, explicitly (the same treatment ``serve``/``train``/
``launch`` got): the :class:`Governor` pipeline, the instrument-mode
helpers (``cd_*`` collectives, ambient mode switches, event sink/tee), the
calibrated :class:`HwModel`, the policy table, and the simulator entry
points.  Symbols resolve lazily (PEP 562) so ``import repro.core`` stays
cheap for tooling — ``instrument`` in particular pulls in jax.
"""
import importlib

_EXPORTS = {
    # canonical event vocabulary + bus (pure python, jax-free)
    "EventBus": "repro.core.events",
    "PHASE_NAMES": "repro.core.events",
    "PhaseEvent": "repro.core.events",
    "PhaseRecord": "repro.core.events",
    # governor pipeline
    "Actuation": "repro.core.governor",
    "Governor": "repro.core.governor",
    "GovernorReport": "repro.core.governor",
    "IntervalStats": "repro.core.governor",
    # instrument mode helpers (jax-bearing; loaded on first touch)
    "AsyncCollective": "repro.core.instrument",
    "get_event_bus": "repro.core.instrument",
    "cd_all_gather": "repro.core.instrument",
    "cd_all_gather_async": "repro.core.instrument",
    "cd_pmean": "repro.core.instrument",
    "cd_ppermute": "repro.core.instrument",
    "cd_psum": "repro.core.instrument",
    "cd_psum_async": "repro.core.instrument",
    "cd_wait": "repro.core.instrument",
    "enable_events": "repro.core.instrument",
    "get_mode": "repro.core.instrument",
    "reset_instrumentation": "repro.core.instrument",
    "set_event_sink": "repro.core.instrument",
    "set_event_tee": "repro.core.instrument",
    "set_mode": "repro.core.instrument",
    # theta auto-tuning
    "ThetaDecision": "repro.core.timeout",
    "ThetaTuner": "repro.core.timeout",
    # hardware / power model
    "DEFAULT_HW": "repro.core.pstate",
    "HwModel": "repro.core.pstate",
    # policies
    "ALL_POLICIES": "repro.core.policies",
    "BASELINE": "repro.core.policies",
    "CNTD_ADAPTIVE": "repro.core.policies",
    "COUNTDOWN": "repro.core.policies",
    "COUNTDOWN_SLACK": "repro.core.policies",
    "FIXED_POLICIES": "repro.core.policies",
    "MINFREQ": "repro.core.policies",
    "Policy": "repro.core.policies",
    # simulator entry points
    "SimResult": "repro.core.simulator",
    "TraceRecord": "repro.core.simulator",
    "Workload": "repro.core.simulator",
    "coverage_on_trace": "repro.core.simulator",
    "simulate": "repro.core.simulator",
    # calibrated workload generators
    "APPS": "repro.core.workloads",
    "generate": "repro.core.workloads",
    "make_all": "repro.core.workloads",
}

_SUBMODULES = (
    "events", "governor", "instrument", "policies", "predictor", "profiler",
    "pstate", "simulator", "timeout", "workloads",
)

__all__ = sorted(_EXPORTS) + list(_SUBMODULES)


def __getattr__(name: str):
    if name in _EXPORTS:
        return getattr(importlib.import_module(_EXPORTS[name]), name)
    if name in _SUBMODULES:
        return importlib.import_module(f"repro.core.{name}")
    raise AttributeError(f"module 'repro.core' has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))
