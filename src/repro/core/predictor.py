"""Region-duration predictability study (paper §6.2, Table 1 + Fig. 3).

A from-scratch numpy random-forest regressor (no sklearn in this
environment): CART trees with variance-reduction splits over quantile
candidate thresholds, bootstrap bagging, feature subsampling.  Targets are
trained in log-space (the paper found this flattens duration peaks) and
evaluated with SMAPE on the raw scale.  Feature importance uses the
permutation method (the paper explicitly prefers it over impurity
importance).

Features (paper §6.2): rank id, MPI call type, bytes received, bytes sent,
group size, locality, task id (call-site hash) — plus, in the
"with previous info" variant, the last (Tcomp, Tslack, Tcopy) of the same
(site, rank).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.simulator import TraceRecord

FEATURES_BASE = [
    "rank", "call_type", "bytes_recv", "bytes_sent", "group_size",
    "locality", "task_id",
]
FEATURES_PREV = ["prev_tcomp", "prev_tslack", "prev_tcopy"]
TARGETS = ["tcomp", "tslack", "tcopy"]


# --------------------------------------------------------------------------
# dataset construction from a simulator trace
# --------------------------------------------------------------------------

def build_dataset(
    trace: TraceRecord,
    with_prev: bool,
    ranks_per_node: int = 18,
    max_rows: int = 60_000,
    seed: int = 0,
) -> Tuple[np.ndarray, np.ndarray, List[str]]:
    """Returns (X, Y[,3], feature_names).  Rows ordered rank-major then time."""
    t_tasks, n = trace.comp.shape
    rows: List[List[float]] = []
    targets: List[List[float]] = []
    last: Dict[Tuple[int, int], Tuple[float, float, float]] = {}
    for r in range(n):
        for k in range(t_tasks):
            site = int(trace.site[k])
            p2p = bool(trace.is_p2p[k])
            group = 2 if p2p else n
            # locality: fraction of the group on this rank's node
            if p2p:
                locality = 1.0 if group <= ranks_per_node else 0.5
            else:
                locality = min(1.0, ranks_per_node / n)
            nbytes = float(trace.nbytes[k])
            feat = [
                float(r), 1.0 if p2p else 0.0, nbytes, nbytes,
                float(group), locality, float(site),
            ]
            tgt = [
                float(trace.comp[k, r]),
                float(trace.slack[k, r]),
                float(trace.copy[k, r]),
            ]
            if with_prev:
                prev = last.get((site, r))
                if prev is None:
                    last[(site, r)] = tuple(tgt)
                    continue                      # paper: needs history
                feat = feat + list(prev)
                last[(site, r)] = tuple(tgt)
            rows.append(feat)
            targets.append(tgt)
    x = np.asarray(rows, dtype=np.float64)
    y = np.asarray(targets, dtype=np.float64)
    if len(x) > max_rows:
        idx = np.random.default_rng(seed).choice(len(x), max_rows, replace=False)
        x, y = x[idx], y[idx]
    names = FEATURES_BASE + (FEATURES_PREV if with_prev else [])
    return x, y, names


# --------------------------------------------------------------------------
# CART regression tree + random forest (numpy)
# --------------------------------------------------------------------------

@dataclass
class _Node:
    feature: int = -1
    threshold: float = 0.0
    left: int = -1
    right: int = -1
    value: float = 0.0


class DecisionTree:
    def __init__(self, max_depth=12, min_leaf=5, n_thresholds=16, rng=None):
        self.max_depth = max_depth
        self.min_leaf = min_leaf
        self.n_thresholds = n_thresholds
        self.rng = rng or np.random.default_rng()
        self.nodes: List[_Node] = []

    def fit(self, x: np.ndarray, y: np.ndarray) -> "DecisionTree":
        self.n_features = x.shape[1]
        self.k = max(1, int(np.sqrt(self.n_features)))
        self._grow(x, y, 0)
        return self

    def _grow(self, x, y, depth) -> int:
        idx = len(self.nodes)
        self.nodes.append(_Node(value=float(y.mean())))
        if depth >= self.max_depth or len(y) < 2 * self.min_leaf or np.ptp(y) == 0:
            return idx
        feats = self.rng.choice(self.n_features, self.k, replace=False)
        best = (0.0, -1, 0.0)                     # (gain, feature, threshold)
        base_sse = float(np.var(y)) * len(y)
        for f in feats:
            col = x[:, f]
            qs = np.quantile(col, np.linspace(0.05, 0.95, self.n_thresholds))
            for thr in np.unique(qs):
                mask = col <= thr
                nl = int(mask.sum())
                if nl < self.min_leaf or len(y) - nl < self.min_leaf:
                    continue
                sse = float(np.var(y[mask])) * nl + float(np.var(y[~mask])) * (len(y) - nl)
                gain = base_sse - sse
                if gain > best[0]:
                    best = (gain, f, float(thr))
        if best[1] < 0:
            return idx
        _, f, thr = best
        mask = x[:, f] <= thr
        node = self.nodes[idx]
        node.feature, node.threshold = f, thr
        node.left = self._grow(x[mask], y[mask], depth + 1)
        node.right = self._grow(x[~mask], y[~mask], depth + 1)
        return idx

    def predict(self, x: np.ndarray) -> np.ndarray:
        out = np.empty(len(x))
        for i, row in enumerate(x):
            j = 0
            while self.nodes[j].feature >= 0:
                n = self.nodes[j]
                j = n.left if row[n.feature] <= n.threshold else n.right
            out[i] = self.nodes[j].value
        return out


class RandomForest:
    def __init__(self, n_trees=20, max_depth=12, min_leaf=5, seed=0):
        self.n_trees = n_trees
        self.max_depth = max_depth
        self.min_leaf = min_leaf
        self.seed = seed
        self.trees: List[DecisionTree] = []

    def fit(self, x: np.ndarray, y: np.ndarray) -> "RandomForest":
        rng = np.random.default_rng(self.seed)
        self.trees = []
        for _ in range(self.n_trees):
            idx = rng.choice(len(x), len(x), replace=True)
            t = DecisionTree(self.max_depth, self.min_leaf, rng=rng).fit(x[idx], y[idx])
            self.trees.append(t)
        return self

    def predict(self, x: np.ndarray) -> np.ndarray:
        return np.mean([t.predict(x) for t in self.trees], axis=0)


# --------------------------------------------------------------------------
# evaluation
# --------------------------------------------------------------------------

def smape(pred: np.ndarray, actual: np.ndarray) -> float:
    """Paper footnote 3: 100 * |pred-actual| / (pred+actual)."""
    denom = np.abs(pred) + np.abs(actual)
    ok = denom > 0
    return float(np.mean(100.0 * np.abs(pred - actual)[ok] / denom[ok]))


@dataclass
class PredictabilityResult:
    app: str
    with_prev: bool
    smape: Dict[str, float]                       # target -> %
    importance: Dict[str, Dict[str, float]]       # target -> feature -> [0,1]


def evaluate_predictability(
    app: str,
    trace: TraceRecord,
    with_prev: bool,
    n_trees: int = 12,
    seed: int = 0,
    importance: bool = False,
) -> PredictabilityResult:
    x, y, names = build_dataset(trace, with_prev, seed=seed)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(len(x))
    n_train = int(0.7 * len(x))
    tr, te = perm[:n_train], perm[n_train:]
    out_smape: Dict[str, float] = {}
    out_imp: Dict[str, Dict[str, float]] = {}
    eps = 1e-9
    for j, tgt in enumerate(TARGETS):
        ylog = np.log(np.maximum(y[:, j], eps))
        rf = RandomForest(n_trees=n_trees, seed=seed).fit(x[tr], ylog[tr])
        pred = np.exp(rf.predict(x[te]))
        out_smape[tgt] = smape(pred, y[te, j])
        if importance:
            base = smape(pred, y[te, j])
            imps = {}
            for f, name in enumerate(names):
                xs = x[te].copy()
                xs[:, f] = rng.permutation(xs[:, f])
                imps[name] = max(smape(np.exp(rf.predict(xs)), y[te, j]) - base, 0.0)
            mx = max(imps.values()) or 1.0
            out_imp[tgt] = {k: v / mx for k, v in imps.items()}
    return PredictabilityResult(app, with_prev, out_smape, out_imp)
