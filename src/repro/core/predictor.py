"""Region-duration prediction: the Table 1 / Fig. 3 study + the online model.

A from-scratch numpy random-forest regressor (no sklearn in this
environment): CART trees with variance-reduction splits over quantile
candidate thresholds, bootstrap bagging, feature subsampling.  Targets are
trained in log-space (the paper found this flattens duration peaks) and
evaluated with SMAPE on the raw scale.  Feature importance uses the
permutation method (the paper explicitly prefers it over impurity
importance).

Features (paper §6.2): rank id, MPI call type, bytes received, bytes sent,
group size, locality, task id (call-site hash) — plus, in the
"with previous info" variant, the last (Tcomp, Tslack, Tcopy) of the same
(site, rank).

:class:`OnlinePredictor` is the live counterpart: the same forest,
incrementally refit on the governor's retired phase stream, with a cheap
per-(site, rank) EMA/last-value fallback while the forest is cold.  It is
what the ``cntd_predictive`` policy (repro.core.timeout.PredictiveTuner)
consults to pre-arm the P-state downshift before theta expires — and, per
the paper's central claim, what the misprediction guard polices.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.simulator import TraceRecord

FEATURES_BASE = [
    "rank", "call_type", "bytes_recv", "bytes_sent", "group_size",
    "locality", "task_id",
]
FEATURES_PREV = ["prev_tcomp", "prev_tslack", "prev_tcopy"]
TARGETS = ["tcomp", "tslack", "tcopy"]


# --------------------------------------------------------------------------
# dataset construction from a simulator trace
# --------------------------------------------------------------------------

def build_dataset(
    trace: TraceRecord,
    with_prev: bool,
    ranks_per_node: int = 18,
    max_rows: int = 60_000,
    seed: int = 0,
) -> Tuple[np.ndarray, np.ndarray, List[str]]:
    """Returns (X, Y[,3], feature_names).  Rows ordered rank-major then time."""
    t_tasks, n = trace.comp.shape
    rows: List[List[float]] = []
    targets: List[List[float]] = []
    last: Dict[Tuple[int, int], Tuple[float, float, float]] = {}
    coll_locality = min(1.0, ranks_per_node / n)
    for r in range(n):
        node_r = r // ranks_per_node
        for k in range(t_tasks):
            site = int(trace.site[k])
            p2p = bool(trace.is_p2p[k])
            group = 2 if p2p else n
            # locality: fraction of the group resident on this rank's node.
            # For p2p that is whether the *pair* shares a node — derived
            # from the partner's node index (the group size is constant 2,
            # so deriving it from the group would collapse the feature to a
            # constant and zero out its permutation importance)
            if p2p:
                if trace.partner is not None:
                    mate = int(trace.partner[k, r])
                    locality = 1.0 if mate // ranks_per_node == node_r else 0.5
                else:                       # legacy trace without partners
                    locality = 1.0 if n <= ranks_per_node else 0.5
            else:
                locality = coll_locality
            nbytes = float(trace.nbytes[k])
            feat = [
                float(r), 1.0 if p2p else 0.0, nbytes, nbytes,
                float(group), locality, float(site),
            ]
            tgt = [
                float(trace.comp[k, r]),
                float(trace.slack[k, r]),
                float(trace.copy[k, r]),
            ]
            if with_prev:
                prev = last.get((site, r))
                if prev is None:
                    last[(site, r)] = tuple(tgt)
                    continue                      # paper: needs history
                feat = feat + list(prev)
                last[(site, r)] = tuple(tgt)
            rows.append(feat)
            targets.append(tgt)
    x = np.asarray(rows, dtype=np.float64)
    y = np.asarray(targets, dtype=np.float64)
    if len(x) > max_rows:
        idx = np.random.default_rng(seed).choice(len(x), max_rows, replace=False)
        x, y = x[idx], y[idx]
    names = FEATURES_BASE + (FEATURES_PREV if with_prev else [])
    return x, y, names


# --------------------------------------------------------------------------
# CART regression tree + random forest (numpy)
# --------------------------------------------------------------------------

@dataclass
class _Node:
    feature: int = -1
    threshold: float = 0.0
    left: int = -1
    right: int = -1
    value: float = 0.0


class DecisionTree:
    def __init__(self, max_depth=12, min_leaf=5, n_thresholds=16, rng=None):
        self.max_depth = max_depth
        self.min_leaf = min_leaf
        self.n_thresholds = n_thresholds
        self.rng = rng or np.random.default_rng()
        self.nodes: List[_Node] = []

    def fit(self, x: np.ndarray, y: np.ndarray) -> "DecisionTree":
        self.n_features = x.shape[1]
        self.k = max(1, int(np.sqrt(self.n_features)))
        self._grow(x, y, 0)
        self._pack()
        return self

    def _grow(self, x, y, depth) -> int:
        idx = len(self.nodes)
        self.nodes.append(_Node(value=float(y.mean())))
        if depth >= self.max_depth or len(y) < 2 * self.min_leaf or np.ptp(y) == 0:
            return idx
        feats = self.rng.choice(self.n_features, self.k, replace=False)
        best = (0.0, -1, 0.0)                     # (gain, feature, threshold)
        base_sse = float(np.var(y)) * len(y)
        for f in feats:
            col = x[:, f]
            qs = np.quantile(col, np.linspace(0.05, 0.95, self.n_thresholds))
            for thr in np.unique(qs):
                mask = col <= thr
                nl = int(mask.sum())
                if nl < self.min_leaf or len(y) - nl < self.min_leaf:
                    continue
                sse = float(np.var(y[mask])) * nl + float(np.var(y[~mask])) * (len(y) - nl)
                gain = base_sse - sse
                if gain > best[0]:
                    best = (gain, f, float(thr))
        if best[1] < 0:
            return idx
        _, f, thr = best
        mask = x[:, f] <= thr
        node = self.nodes[idx]
        node.feature, node.threshold = f, thr
        node.left = self._grow(x[mask], y[mask], depth + 1)
        node.right = self._grow(x[~mask], y[~mask], depth + 1)
        return idx

    def _pack(self) -> None:
        """Flatten the node list into parallel arrays so predict() can run
        a level-order masked descent instead of a per-row Python walk."""
        nd = self.nodes
        m = len(nd)
        self._feat = np.fromiter((n.feature for n in nd), np.int64, m)
        self._thr = np.fromiter((n.threshold for n in nd), np.float64, m)
        self._left = np.fromiter((n.left for n in nd), np.int64, m)
        self._right = np.fromiter((n.right for n in nd), np.int64, m)
        self._value = np.fromiter((n.value for n in nd), np.float64, m)

    def predict(self, x: np.ndarray) -> np.ndarray:
        """Vectorized traversal: all rows descend one level per pass, rows
        that reached a leaf drop out of the active set.  At most
        ``max_depth`` numpy passes replace one Python ``while`` per row —
        bitwise-identical routing to the scalar walk (same ``<=`` splits)."""
        x = np.asarray(x, dtype=np.float64)
        pos = np.zeros(len(x), dtype=np.int64)
        if len(x) == 0 or self._feat[0] < 0:
            return self._value[pos] if len(x) else np.empty(0)
        active = np.arange(len(x))
        while active.size:
            node = pos[active]
            f = self._feat[node]
            go_left = x[active, f] <= self._thr[node]
            pos[active] = np.where(go_left, self._left[node], self._right[node])
            active = active[self._feat[pos[active]] >= 0]
        return self._value[pos]


class RandomForest:
    def __init__(self, n_trees=20, max_depth=12, min_leaf=5, seed=0):
        self.n_trees = n_trees
        self.max_depth = max_depth
        self.min_leaf = min_leaf
        self.seed = seed
        self.trees: List[DecisionTree] = []

    def fit(self, x: np.ndarray, y: np.ndarray) -> "RandomForest":
        rng = np.random.default_rng(self.seed)
        self.trees = []
        for _ in range(self.n_trees):
            idx = rng.choice(len(x), len(x), replace=True)
            t = DecisionTree(self.max_depth, self.min_leaf, rng=rng).fit(x[idx], y[idx])
            self.trees.append(t)
        return self

    def predict(self, x: np.ndarray) -> np.ndarray:
        return np.mean([t.predict(x) for t in self.trees], axis=0)


# --------------------------------------------------------------------------
# online predictor (the cntd_predictive policy's model)
# --------------------------------------------------------------------------

@dataclass
class OnlinePredictor:
    """Per-(site, rank) online slack predictor over the retired phase stream.

    Two regimes, switched automatically:

    * **cold** — until ``min_fit`` rows accrue, predictions fall back to a
      per-(site, rank) EMA of observed slack (last-value smoothed by
      ``ema_alpha``); a pair with no history at all predicts nothing
      (NaN), so the consumer never arms on a guess.
    * **warm** — a small :class:`RandomForest` refit every ``refit_every``
      observations on a bounded window of the most recent rows.  Features
      are exactly what the runtime can know *before* a call completes:
      (site, rank) plus the pair's previous (slack, comp, copy) and its
      slack EMA.  Targets are **linear-space** slack (unlike the offline
      Table-1 study's log targets): mean-leaf trees on linear targets
      estimate the arithmetic conditional mean, which is the quantity the
      arm decision prices — log targets yield the geometric mean, and on
      streams with frequent zero-slack occurrences (the critical rank of
      every task) that collapses toward zero and never clears the bar.

    Deterministic: refits are seeded from ``(seed, refit_index)`` and
    triggered purely by the observation counter, so the predictor — like
    the tuner it feeds — is a pure function of the observation order
    (trace replay stays bit-for-bit).
    """

    n_trees: int = 4
    max_depth: int = 6
    min_fit: int = 64                # rows before the first forest fit
    refit_every: int = 256          # observations between refits
    window: int = 4096              # training window of most recent rows
    ema_alpha: float = 0.3          # cold-path slack EMA weight
    seed: int = 0

    def __post_init__(self) -> None:
        # (site, rank) -> [last_slack, last_comp, last_copy, ema_slack]
        self._last: Dict[Tuple[int, int], List[float]] = {}
        self._rows: collections.deque = collections.deque(maxlen=self.window)
        self._tgts: collections.deque = collections.deque(maxlen=self.window)
        self._forest: Optional[RandomForest] = None
        self._n_obs = 0
        self._n_fits = 0
        self._next_fit = self.min_fit

    # ---- queries ---------------------------------------------------------
    @property
    def warm(self) -> bool:
        return self._forest is not None

    @property
    def n_observations(self) -> int:
        return self._n_obs

    @property
    def n_refits(self) -> int:
        return self._n_fits

    def _features(self, site: int, rank: int, st: Sequence[float]) -> List[float]:
        return [float(site), float(rank), st[0], st[1], st[2], st[3]]

    def predict(self, site: int, rank: int) -> Tuple[float, str]:
        """Predicted next slack (seconds) for this (site, rank), with the
        regime that produced it: ``(nan, "cold")`` when the pair has no
        history, ``(ema, "ema")`` before the first fit, ``(forest value,
        "forest")`` after."""
        st = self._last.get((site, rank))
        if st is None:
            return float("nan"), "cold"
        if self._forest is not None:
            x = np.asarray([self._features(site, rank, st)])
            return max(float(self._forest.predict(x)[0]), 0.0), "forest"
        return st[3], "ema"

    def predict_ranks(self, site: int, n: int) -> Tuple[np.ndarray, str]:
        """Vectorized :meth:`predict` over ranks ``0..n-1`` (the simulator
        path): one forest traversal for the whole rank vector.  Cold ranks
        stay NaN."""
        preds = np.full(n, np.nan)
        states = [self._last.get((site, r)) for r in range(n)]
        warm = [r for r, st in enumerate(states) if st is not None]
        if not warm:
            return preds, "cold"
        if self._forest is not None:
            x = np.asarray([self._features(site, r, states[r]) for r in warm])
            preds[warm] = np.maximum(self._forest.predict(x), 0.0)
            return preds, "forest"
        preds[warm] = [states[r][3] for r in warm]
        return preds, "ema"

    # ---- observations ----------------------------------------------------
    def observe(self, site: int, rank: int, slack: float,
                comp: float = 0.0) -> None:
        """Account one retired occurrence: the pair's *previous* state
        becomes a training row targeting this slack, then the state rolls
        forward.  Copy durations arrive later (:meth:`note_copy`) and only
        update the feature state — the target is always slack."""
        key = (site, rank)
        slack = max(float(slack), 0.0)
        comp = max(float(comp), 0.0)
        st = self._last.get(key)
        if st is None:
            self._last[key] = [slack, comp, 0.0, slack]
            return
        self._rows.append(tuple(self._features(site, rank, st)))
        self._tgts.append(slack)
        self._n_obs += 1
        st[0], st[1] = slack, comp
        st[3] = (1.0 - self.ema_alpha) * st[3] + self.ema_alpha * slack
        if self._n_obs >= self._next_fit:
            self._refit()

    def note_copy(self, site: int, rank: int, copy: float) -> None:
        st = self._last.get((site, rank))
        if st is not None:
            st[2] = max(float(copy), 0.0)

    def note_copy_ranks(self, site: int, copies: np.ndarray) -> None:
        for r, c in enumerate(np.asarray(copies, np.float64).tolist()):
            self.note_copy(site, r, c)

    def observe_ranks(self, site: int, slacks: np.ndarray,
                      comps: Optional[np.ndarray] = None) -> None:
        slacks = np.asarray(slacks, np.float64)
        comps = (np.asarray(comps, np.float64) if comps is not None
                 else np.zeros_like(slacks))
        for r in range(slacks.shape[0]):
            self.observe(site, r, float(slacks[r]), float(comps[r]))

    def _refit(self) -> None:
        x = np.asarray(self._rows, dtype=np.float64)
        y = np.asarray(self._tgts, dtype=np.float64)
        self._forest = RandomForest(
            n_trees=self.n_trees, max_depth=self.max_depth,
            seed=self.seed + self._n_fits,
        ).fit(x, y)
        self._n_fits += 1
        self._next_fit = self._n_obs + self.refit_every

    def reset(self) -> None:
        self._last.clear()
        self._rows.clear()
        self._tgts.clear()
        self._forest = None
        self._n_obs = 0
        self._n_fits = 0
        self._next_fit = self.min_fit


# --------------------------------------------------------------------------
# evaluation
# --------------------------------------------------------------------------

def smape(pred: np.ndarray, actual: np.ndarray) -> float:
    """Paper footnote 3: 100 * |pred-actual| / (pred+actual).

    Zero-denominator rows — a zero prediction of a zero-duration phase —
    are *exact hits* and count as 0% error.  (Dropping them, the old
    behavior, silently biased Table-1 SMAPE upward for apps with many
    zero-slack phases predicted correctly.)"""
    pred = np.asarray(pred, dtype=np.float64)
    actual = np.asarray(actual, dtype=np.float64)
    denom = np.abs(pred) + np.abs(actual)
    safe = np.where(denom > 0, denom, 1.0)
    err = np.where(denom > 0, 100.0 * np.abs(pred - actual) / safe, 0.0)
    return float(err.mean()) if err.size else 0.0


def zero_denominator_fraction(pred: np.ndarray, actual: np.ndarray) -> float:
    """Fraction of rows whose SMAPE denominator is zero (counted as exact
    hits by :func:`smape`) — surfaced so Table 1 readers can see how much
    of the score is zero-phase mass."""
    pred = np.asarray(pred, dtype=np.float64)
    actual = np.asarray(actual, dtype=np.float64)
    if pred.size == 0:
        return 0.0
    return float(np.mean((np.abs(pred) + np.abs(actual)) == 0))


@dataclass
class PredictabilityResult:
    app: str
    with_prev: bool
    smape: Dict[str, float]                       # target -> %
    importance: Dict[str, Dict[str, float]]       # target -> feature -> [0,1]
    zero_frac: Dict[str, float] = field(default_factory=dict)
    # target -> fraction of test rows counted as exact zero hits


def evaluate_predictability(
    app: str,
    trace: TraceRecord,
    with_prev: bool,
    n_trees: int = 12,
    seed: int = 0,
    importance: bool = False,
) -> PredictabilityResult:
    x, y, names = build_dataset(trace, with_prev, seed=seed)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(len(x))
    n_train = int(0.7 * len(x))
    tr, te = perm[:n_train], perm[n_train:]
    out_smape: Dict[str, float] = {}
    out_imp: Dict[str, Dict[str, float]] = {}
    out_zero: Dict[str, float] = {}
    eps = 1e-9
    for j, tgt in enumerate(TARGETS):
        ylog = np.log(np.maximum(y[:, j], eps))
        rf = RandomForest(n_trees=n_trees, seed=seed).fit(x[tr], ylog[tr])
        pred = np.exp(rf.predict(x[te]))
        out_smape[tgt] = smape(pred, y[te, j])
        out_zero[tgt] = zero_denominator_fraction(pred, y[te, j])
        if importance:
            base = smape(pred, y[te, j])
            imps = {}
            for f, name in enumerate(names):
                xs = x[te].copy()
                xs[:, f] = rng.permutation(xs[:, f])
                imps[name] = max(smape(np.exp(rf.predict(xs)), y[te, j]) - base, 0.0)
            mx = max(imps.values()) or 1.0
            out_imp[tgt] = {k: v / mx for k, v in imps.items()}
    return PredictabilityResult(app, with_prev, out_smape, out_imp, out_zero)
