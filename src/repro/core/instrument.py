"""Collective instrumentation: artificial barriers + host phase events.

This is the JAX analogue of the paper's PMPI interception layer (§4.1-4.2):

* ``cd_psum`` / ``cd_all_gather`` / ``cd_ppermute`` wrap the real collective
  with (i) an *artificial barrier* — a 1-element ``psum`` over the same axes,
  the ``MPI_Barrier``/``Isend+Wait`` analogue — that contains exactly the
  slack, and (ii) ordered ``io_callback`` phase events (barrier-enter,
  barrier-exit = slack end, collective-exit = copy end) that drive the host
  :class:`~repro.core.governor.Governor`, which applies the timeout policy.

* ``cd_psum_async`` / ``cd_all_gather_async`` + ``cd_wait`` are the
  nonblocking-collective analogue (``MPI_Iallreduce`` + ``MPI_Wait``).  They
  extend the 3-phase barrier/copy taxonomy to 5 phases: ``dispatch_enter``
  at the async start and ``wait_enter`` when the caller blocks.  The window
  ``[dispatch_enter, wait_enter]`` is compute/communication *overlap* — the
  core is busy, so the governor accounts it as non-slack instead of letting
  it silently inflate the slack (and get mispriced at the min P-state while
  the rank is actually computing).  Slack for an async pair starts at the
  wait, exactly as the paper's P2P ``Isend + Wait`` barrier starts at the
  wait.

* The instrumentation mode is ambient (``set_mode``), mirroring the paper's
  LD_PRELOAD transparency: model / optimizer code always calls the wrappers
  and pays zero cost when the mode is "off".

* Host events fan out through one ambient :class:`~repro.core.events.
  EventBus` (``get_event_bus()``): the governor, a trace recorder, and any
  further consumer subscribe side by side.  The legacy single-slot
  ``set_event_sink``/``set_event_tee`` setters are kept as thin shims over
  two named bus slots, so existing call sites (and the golden event
  ordering they rely on) keep working.

Modes:
  off      — wrapper == real collective (baseline).
  barrier  — artificial barrier emitted (dry-run visible, no host events).
  profile  — barrier + host phase events (live runs; energy accounting).
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, NamedTuple, Optional, Sequence, Union

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.events import PHASE_NAMES, BatchAccumulator, EventBus

AxisNames = Union[str, Sequence[str]]

_MODE = "off"
_EVENTS_ENABLED = False
_BUS = EventBus()
_LOCK = threading.Lock()
_CALL_COUNTER = [0]
_INGEST_MODE = "event"
_ACC: Optional[BatchAccumulator] = None
DEFAULT_BATCH_SIZE = 65536      # 65536 events x 21 B/event ~= 1.4 MB buffer;
# the size where the governor's vectorized fold peaks (DESIGN.md §10)


def set_mode(mode: str) -> None:
    """Set ambient instrumentation mode: off | barrier | profile."""
    global _MODE
    if mode not in ("off", "barrier", "profile"):
        raise ValueError(mode)
    _MODE = mode


def enable_events(on: bool) -> None:
    """Host phase events need a *fully manual* shard_map region (io_callback
    limitation under partial auto-sharding); callers in such regions opt in.
    """
    global _EVENTS_ENABLED
    _EVENTS_ENABLED = on


def get_mode() -> str:
    return _MODE


def get_event_bus() -> EventBus:
    """The ambient bus the instrumented collectives publish onto.

    Subscribe consumers directly: ``get_event_bus().subscribe(governor)``
    attaches anything exposing ``on_event``/``on_phase`` (the canonical
    subscriber protocol — see :mod:`repro.core.events`).
    """
    return _BUS


def set_ingest_mode(mode: str, batch_size: int = DEFAULT_BATCH_SIZE) -> None:
    """Choose how host phase events reach the bus: ``"event"`` publishes
    each event as it happens (the legacy low-latency path), ``"batched"``
    buffers events in a fixed-dtype :class:`~repro.core.events.
    BatchAccumulator` and publishes full columnar chunks — the vectorized
    telemetry spine for week-long, thousand-rank traces (launch drivers:
    ``--ingest batched``).

    Switching modes flushes any buffered partial batch first, so no event
    is lost or reordered across the switch.
    """
    global _INGEST_MODE, _ACC
    if mode not in ("event", "batched"):
        raise ValueError(mode)
    flush_events()
    with _LOCK:
        _INGEST_MODE = mode
        _ACC = BatchAccumulator(batch_size) if mode == "batched" else None


def get_ingest_mode() -> str:
    return _INGEST_MODE


def flush_events() -> int:
    """Deliver everything the batched ingest mode is holding: the partial
    accumulator batch is enqueued behind any already-queued full chunks,
    then the bus queue is drained in FIFO order (so flushing never
    reorders events around chunks still in flight).  Drivers call this at
    loop boundaries and end-of-run so the governor sees every event
    before ``finalize``.  Returns events delivered; in ``"event"`` mode
    it still drains the queue (normally a no-op)."""
    with _LOCK:
        acc = _ACC
        batch = acc.flush() if acc is not None else None
    if batch is not None:
        _BUS.enqueue(batch)
    return _BUS.drain()


def set_event_sink(sink: Optional[Callable[[int, str, int, float], None]]) -> None:
    """Deprecated single-slot shim over :func:`get_event_bus`.

    Occupies the bus's ``"sink"`` named slot: installing replaces the
    previous sink, ``None`` vacates it, and any other subscribers are
    untouched.  New code should subscribe to the bus directly.
    """
    if sink is None:
        _BUS.unsubscribe("sink")
    else:
        _BUS.subscribe(sink, name="sink")


def set_event_tee(tee: Optional[Callable[[int, str, int, float], None]]) -> None:
    """Deprecated single-slot shim over :func:`get_event_bus` (slot
    ``"tee"``) — historically a secondary consumer fed the identical
    stream, e.g. a :class:`repro.cluster.trace.TraceRecorder` recording a
    run the governor is not attached to.  The bus made the distinction
    moot (any number of consumers subscribe side by side); the setter
    stays for sink-less recording call sites.  When the recorder hangs
    off a live :class:`~repro.core.governor.Governor` instead, prefer the
    governor's ``recorder`` hook (it also captures ingested phases and
    actuations).
    """
    if tee is None:
        _BUS.unsubscribe("tee")
    else:
        _BUS.subscribe(tee, name="tee")


def reset_instrumentation() -> None:
    """Restore every piece of ambient instrumentation state to its default:
    mode off, events disabled, empty bus, call counter at zero.

    Ambient state otherwise leaks across tests (a subscriber installed by
    one test keeps timestamping the next test's collectives); the tier-1
    ``conftest.py`` calls this around every test.
    """
    global _MODE, _EVENTS_ENABLED, _INGEST_MODE, _ACC
    _MODE = "off"
    _EVENTS_ENABLED = False
    _BUS.clear()
    with _LOCK:
        _CALL_COUNTER[0] = 0
        _INGEST_MODE = "event"
        _ACC = None


def _emit(rank, phase_code, call_id) -> None:
    """Host-side callback: timestamp and publish onto the event bus —
    directly per event, or via the ingest accumulator when the batched
    spine is on (full buffers are queued, not delivered inline: an
    ordered ``io_callback`` must not run consumer code)."""
    if not _BUS:
        return
    t = time.monotonic()
    acc = _ACC
    if acc is None:
        _BUS.publish(int(rank), PHASE_NAMES[int(phase_code)], int(call_id), t)
        return
    with _LOCK:
        batch = acc.flush() if acc.append(
            int(rank), int(phase_code), int(call_id), t) else None
    if batch is not None:
        _BUS.enqueue(batch)


def _host_event(rank: jnp.ndarray, phase_code: int, call_id: int) -> None:
    jax.experimental.io_callback(
        _emit, None, rank, jnp.int32(phase_code), jnp.int32(call_id), ordered=True
    )


def _next_call_id() -> int:
    with _LOCK:
        _CALL_COUNTER[0] += 1
        return _CALL_COUNTER[0]


def _probe(tree: Any) -> jnp.ndarray:
    """A 1-element probe derived from live data, so the partitioner cannot
    constant-fold the barrier built on it away."""
    leaf = jax.tree.leaves(tree)[0]
    return jnp.real(jnp.ravel(leaf)[0]).astype(jnp.float32) * 0.0 + 1.0


def _barrier_token(tree: Any, axes: AxisNames) -> jnp.ndarray:
    """The artificial barrier: a 1-element all-reduce over ``axes``."""
    return lax.psum(_probe(tree), axes)


def _instrumented(real_op: Callable[[Any], Any], tree: Any, axes: AxisNames) -> Any:
    mode = _MODE
    if mode == "off":
        return real_op(tree)
    call_id = _next_call_id()
    profile = mode == "profile" and _EVENTS_ENABLED
    if profile:
        rank = lax.axis_index(axes if isinstance(axes, str) else axes[0])
        _host_event(rank, 0, call_id)                 # barrier enter (slack start)
    token = _barrier_token(tree, axes)                # ---- artificial barrier ----
    # order: real collective strictly after the barrier completes
    tree, token = lax.optimization_barrier((tree, token))
    if profile:
        _host_event(rank, 1, call_id)                 # barrier exit (slack end)
    out = real_op(tree)
    if profile:
        out, token = lax.optimization_barrier((out, token))
        _host_event(rank, 2, call_id)                 # copy exit
    return out


# --------------------------------------------------------------------------
# public wrappers (the "PMPI interface")
# --------------------------------------------------------------------------

def cd_psum(tree: Any, axes: AxisNames) -> Any:
    """Instrumented ``lax.psum`` (collective COUNTDOWN Slack barrier §4.2.1)."""
    return _instrumented(lambda t: jax.tree.map(lambda a: lax.psum(a, axes), t), tree, axes)


def cd_pmean(tree: Any, axes: AxisNames) -> Any:
    return _instrumented(lambda t: jax.tree.map(lambda a: lax.pmean(a, axes), t), tree, axes)


def cd_all_gather(tree: Any, axes: AxisNames, *, axis: int = 0, tiled: bool = True) -> Any:
    return _instrumented(
        lambda t: jax.tree.map(lambda a: lax.all_gather(a, axes, axis=axis, tiled=tiled), t),
        tree, axes,
    )


class AsyncCollective(NamedTuple):
    """Handle returned by ``cd_*_async``: the dispatched result plus the
    bookkeeping ``cd_wait`` needs to close the 5-phase event sequence."""

    result: Any
    axes: Any                    # AxisNames; static within the traced region
    call_id: int                 # 0 when mode is off (no events were armed)
    profile: bool
    rank: Any                    # traced axis index, None unless profiling
    probe: Any                   # 1-element probe from the INPUT tree: the
    # wait-side barrier must resolve on rank arrival, independent of the
    # in-flight payload (else the transfer would be booked as slack)


def _async_start(real_op: Callable[[Any], Any], tree: Any, axes: AxisNames) -> AsyncCollective:
    """Dispatch an async collective: emit ``dispatch_enter`` and launch the
    real op.  Whatever the caller computes between start and ``cd_wait`` is
    the overlap window — accounted as non-slack by the governor."""
    mode = _MODE
    if mode == "off":
        return AsyncCollective(real_op(tree), axes, 0, False, None, None)
    call_id = _next_call_id()
    profile = mode == "profile" and _EVENTS_ENABLED
    rank = None
    if profile:
        rank = lax.axis_index(axes if isinstance(axes, str) else axes[0])
        _host_event(rank, 3, call_id)                 # dispatch enter (overlap start)
    return AsyncCollective(real_op(tree), axes, call_id, profile, rank,
                           _probe(tree))


def cd_wait(handle: AsyncCollective) -> Any:
    """Block on an async collective (the ``MPI_Wait`` analogue).

    Emits ``wait_enter`` (slack starts HERE, not at dispatch), runs the
    artificial barrier that isolates the remaining wait, then forces the
    dispatched result: ``barrier_exit`` closes the slack, ``copy_exit``
    closes the copy remainder — same tail as the blocking wrappers, so the
    governor reconstructs async and sync calls with one code path.

    The barrier token is a 1-element psum over the *input* probe carried on
    the handle — deliberately independent of the dispatched payload, so it
    resolves on rank arrival at the wait (the slack the paper isolates).
    Deriving it from the result would tie the barrier to the in-flight
    transfer: the wire time would be priced as exploitable slack and the
    copy remainder would collapse to zero, losing the copy-at-full-speed
    protection the slack scope exists for.
    """
    if handle.call_id == 0:                           # dispatched with mode off
        return handle.result
    out = handle.result
    if handle.profile:
        _host_event(handle.rank, 4, handle.call_id)   # wait enter (slack start)
    token = lax.psum(handle.probe, handle.axes)       # ---- artificial barrier ----
    if handle.profile:
        token = lax.optimization_barrier(token)
        _host_event(handle.rank, 1, handle.call_id)   # barrier exit (slack end)
    # the payload is forced only after the barrier: what remains of the
    # transfer past this point is the copy phase
    out, token = lax.optimization_barrier((out, token))
    if handle.profile:
        _host_event(handle.rank, 2, handle.call_id)   # copy exit
    return out


def cd_psum_async(tree: Any, axes: AxisNames) -> AsyncCollective:
    """Nonblocking ``cd_psum``: start/wait pair (``MPI_Iallreduce`` analogue)."""
    return _async_start(
        lambda t: jax.tree.map(lambda a: lax.psum(a, axes), t), tree, axes
    )


def cd_all_gather_async(tree: Any, axes: AxisNames, *, axis: int = 0,
                        tiled: bool = True) -> AsyncCollective:
    """Nonblocking ``cd_all_gather``: start/wait pair."""
    return _async_start(
        lambda t: jax.tree.map(lambda a: lax.all_gather(a, axes, axis=axis, tiled=tiled), t),
        tree, axes,
    )


def cd_ppermute(tree: Any, axis_name: str, perm) -> Any:
    """Instrumented ``lax.ppermute`` (P2P COUNTDOWN Slack barrier §4.2.2).

    The artificial barrier for P2P is a 1-element ppermute over the same
    permutation — the non-blocking send/recv + wait analogue: it involves
    exactly the communicating pair, not the world.
    """
    mode = _MODE

    def real_op(t):
        return jax.tree.map(lambda a: lax.ppermute(a, axis_name, perm), t)

    if mode == "off":
        return real_op(tree)
    call_id = _next_call_id()
    profile = mode == "profile" and _EVENTS_ENABLED
    if profile:
        rank = lax.axis_index(axis_name)
        _host_event(rank, 0, call_id)
    leaf = jax.tree.leaves(tree)[0]
    probe = jnp.real(jnp.ravel(leaf)[0]).astype(jnp.float32) * 0.0 + 1.0
    token = lax.ppermute(probe, axis_name, perm)      # P2P artificial barrier
    tree, token = lax.optimization_barrier((tree, token))
    if profile:
        _host_event(rank, 1, call_id)
    out = real_op(tree)
    if profile:
        out, token = lax.optimization_barrier((out, token))
        _host_event(rank, 2, call_id)
    return out
