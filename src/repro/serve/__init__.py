"""``repro.serve`` — the continuous-batching serving subsystem.

The serving-side analogue of the paper's slack mechanism: decode-slot
underfill and inter-arrival idle gaps are isolated, measured, and priced
in joules by the same governor that prices MPI slack.

``kvcache``    block-paged KV pool: refcounted free-list allocation with
               admission reservations, per-request page tables, scratch
               page for idle slots, int8 pages via the ``kv_quant`` path,
               the paged single-token decode attention, and the
               copy-on-write page clone for prefix sharing.
``scheduler``  continuous batching: arrival queue, page-bounded (and
               prefix-aware) admission, join-on-prefill / evict-on-EOS
               slot lifecycle, synthetic Poisson arrival traces.
``slack``      the governor bridge: per-step filled-vs-capacity and idle
               gaps become canonical ``PhaseRecord`` phases published to
               a governor or ``repro.core.events.EventBus``.
``slo``        per-request TTFT/TPOT percentile tracking feeding the
               scheduler's concurrency cap.
``engine``     :class:`ContinuousEngine` (paged, continuous), the
               step-granular :class:`EngineSession` the fleet driver
               interleaves, and the legacy static-batch
               :class:`ServeEngine` wrapper.
``fleet``      multi-replica serving: prefix-cache-aware router, SLO
               autoscaler, watt arbitration, scenarios, and the
               deterministic fleet simulator.

Exports resolve lazily (PEP 562): importing ``repro.serve`` does not pull
in jax-heavy modules until a symbol is touched, and ``dir()`` lists
everything importable — symbols and submodules — so drivers can discover
the surface without try/except probing.
"""
import importlib

# symbol -> defining submodule (the lazy-import table; every name here is
# importable as `from repro.serve import <name>`)
_EXPORTS = {
    "ContinuousEngine": "repro.serve.engine",
    "EngineSession": "repro.serve.engine",
    "ServeEngine": "repro.serve.engine",
    "make_serve_steps": "repro.serve.engine",
    "PagedKVPool": "repro.serve.kvcache",
    "Request": "repro.serve.scheduler",
    "Scheduler": "repro.serve.scheduler",
    "poisson_arrivals": "repro.serve.scheduler",
    "DecodeSlackMeter": "repro.serve.slack",
    "SLOTracker": "repro.serve.slo",
    # fleet layer
    "Autoscaler": "repro.serve.fleet.autoscaler",
    "FleetConfig": "repro.serve.fleet.fleet",
    "FleetResult": "repro.serve.fleet.fleet",
    "FleetSim": "repro.serve.fleet.fleet",
    "run_engine_fleet": "repro.serve.fleet.fleet",
    "PrefixCache": "repro.serve.fleet.prefix",
    "PrefixMatch": "repro.serve.fleet.prefix",
    "SimReplica": "repro.serve.fleet.replica",
    "FleetRouter": "repro.serve.fleet.router",
    "ReplicaView": "repro.serve.fleet.router",
    "FleetTrace": "repro.serve.fleet.scenarios",
    "diurnal_trace": "repro.serve.fleet.scenarios",
    "flash_crowd_trace": "repro.serve.fleet.scenarios",
    "session_reuse_trace": "repro.serve.fleet.scenarios",
}

_SUBMODULES = ("engine", "fleet", "kvcache", "scheduler", "slack", "slo")

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    target = _EXPORTS.get(name)
    if target is not None:
        value = getattr(importlib.import_module(target), name)
        globals()[name] = value               # cache: resolve once
        return value
    if name in _SUBMODULES:
        module = importlib.import_module(f"repro.serve.{name}")
        globals()[name] = module
        return module
    raise AttributeError(f"module 'repro.serve' has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS) | set(_SUBMODULES))
