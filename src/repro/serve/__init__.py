"""``repro.serve`` — the continuous-batching serving subsystem.

The serving-side analogue of the paper's slack mechanism: decode-slot
underfill and inter-arrival idle gaps are isolated, measured, and priced
in joules by the same governor that prices MPI slack.

``kvcache``    block-paged KV pool: free-list allocation with admission
               reservations, per-request page tables, scratch page for
               idle slots, int8 pages via the ``kv_quant`` path, and the
               paged single-token decode attention.
``scheduler``  continuous batching: arrival queue, page-bounded
               admission, join-on-prefill / evict-on-EOS slot lifecycle,
               synthetic Poisson arrival traces.
``slack``      the governor bridge: per-step filled-vs-capacity and idle
               gaps become canonical ``PhaseRecord`` phases published to
               a governor or ``repro.core.events.EventBus``.
``slo``        per-request TTFT/TPOT percentile tracking feeding the
               scheduler's concurrency cap.
``engine``     :class:`ContinuousEngine` (paged, continuous) and the
               legacy static-batch :class:`ServeEngine` wrapper.
"""
from repro.serve.engine import ContinuousEngine, ServeEngine, make_serve_steps  # noqa: F401
from repro.serve.kvcache import PagedKVPool  # noqa: F401
from repro.serve.scheduler import Request, Scheduler, poisson_arrivals  # noqa: F401
from repro.serve.slack import DecodeSlackMeter  # noqa: F401
from repro.serve.slo import SLOTracker  # noqa: F401

__all__ = [
    "ContinuousEngine",
    "DecodeSlackMeter",
    "PagedKVPool",
    "Request",
    "Scheduler",
    "ServeEngine",
    "SLOTracker",
    "make_serve_steps",
    "poisson_arrivals",
]
