"""Continuous-batching scheduler: arrival queue, admission, slot lifecycle.

The decode analogue of the paper's slack story is *underfill*: a static
batch burns f_max on finished/padded slots and on idle waits between
arrivals.  The scheduler's one job is to keep the decode batch full:

* requests queue with their arrival timestamps (FIFO by arrival — no
  skip-ahead, so admission is SLO-fair and head-of-line need is bounded
  by the pool-capacity check at submit);
* **admission control** is bounded by free *pages*: a request joins only
  when a decode slot is free AND :meth:`PagedKVPool.reserve` can book its
  worst-case page need (prompt + max_new) — so lazy page growth during
  decode can never fail;
* **join-on-prefill**: admitted requests are handed to the engine to
  prefill straight into a free slot of the running batch;
* **evict-on-EOS**: a finished request releases its slot and pages in the
  same step, making room for the next arrival.

An optional :class:`~repro.serve.slo.SLOTracker` caps concurrency below
the slot count when decode-step latency (TPOT) blows its target.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np

from repro.serve.kvcache import PagedKVPool

_RID = itertools.count()


@dataclass
class Request:
    """One generation request plus its runtime bookkeeping."""

    prompt: np.ndarray                       # (S,) int32 token ids
    max_new: int
    arrival: float = 0.0                     # seconds, relative to trace start
    eos_id: Optional[int] = None
    key: Optional[Any] = None                # per-request PRNG key (sampling)
    prefix_embeds: Optional[np.ndarray] = None   # (P, d) frontend prefix
    session: Optional[int] = None            # session id (fleet traces)
    out_script: Optional[np.ndarray] = None  # scripted continuation tokens
    # (fleet *sim* replicas emit these instead of model logits; the real
    # engine ignores them)
    rid: int = field(default_factory=lambda: next(_RID))

    # runtime state (engine-owned)
    slot: int = -1
    pages: List[int] = field(default_factory=list)
    prefix_match: Optional[Any] = None       # PrefixMatch committed at admit
    out: List[int] = field(default_factory=list)
    t_admit: float = -1.0
    t_first: float = -1.0                    # first-token completion (TTFT end)
    t_prev: float = -1.0                     # last token completion (TPOT base)
    t_done: float = -1.0

    @property
    def n_generated(self) -> int:
        return len(self.out)

    def wants_more(self) -> bool:
        if self.out and self.eos_id is not None and self.out[-1] == self.eos_id:
            return False
        return self.n_generated < self.max_new


class Scheduler:
    """Arrival queue + slot/page admission for :class:`ContinuousEngine`."""

    def __init__(self, pool: PagedKVPool, n_slots: int, n_prefix: int = 0,
                 slo=None, prefix_cache=None):
        self.pool = pool
        self.n_slots = n_slots
        self.n_prefix = n_prefix
        self.slo = slo
        # optional repro.serve.fleet.prefix.PrefixCache: admission becomes
        # prefix-aware (matched full blocks are shared, not re-reserved)
        self.prefix_cache = prefix_cache
        self._heap: List = []                # (arrival, rid, Request)
        self._free_slots: List[int] = list(range(n_slots))
        self.active: Dict[int, Request] = {}  # slot -> request
        self.peak_active = 0

    # ---- queue -----------------------------------------------------------
    def submit(self, req: Request) -> None:
        need = len(req.prompt) + self.n_prefix + req.max_new
        if need > self.pool.max_len:
            raise ValueError(
                f"request {req.rid} needs {need} positions > max_len {self.pool.max_len}"
            )
        if self.pool.pages_needed(need) > self.pool.capacity_pages:
            raise ValueError(
                f"request {req.rid} needs {self.pool.pages_needed(need)} pages "
                f"> pool capacity {self.pool.capacity_pages}"
            )
        heapq.heappush(self._heap, (req.arrival, req.rid, req))

    @property
    def n_queued(self) -> int:
        return len(self._heap)

    @property
    def n_active(self) -> int:
        return len(self.active)

    @property
    def done(self) -> bool:
        return not self._heap and not self.active

    def next_arrival(self) -> Optional[float]:
        return self._heap[0][0] if self._heap else None

    # ---- admission (join-on-prefill) ------------------------------------
    def admit(self, now: float) -> List[Request]:
        """Pop every arrived request that fits a slot + page reservation."""
        limit = self.n_slots
        if self.slo is not None:
            limit = max(1, min(limit, self.slo.max_concurrency(self.n_slots)))
        joins: List[Request] = []
        while self._heap and self._heap[0][0] <= now and self._free_slots \
                and len(self.active) < limit:
            req = self._heap[0][2]
            need = len(req.prompt) + self.n_prefix + req.max_new
            need_pages = self.pool.pages_needed(need)
            match, shared = None, []
            if self.prefix_cache is not None:
                # prefix-aware admission: matched full blocks are shared
                # references, so only the unshared remainder is reserved
                # (the CoW clone of a partial hit is part of that remainder).
                # match() is a stats-free trial — a head-of-line-blocked
                # request re-tries it every poll without skewing hit_rate
                match = self.prefix_cache.match(req.prompt)
                shared = list(match.full_pages)
                if match.partial_page is not None:
                    shared.append(match.partial_page)
                need_pages -= len(match.full_pages)
                # pin the matched pages: reservation pressure may evict
                # their trie nodes, but the pages must outlive this window
                self.pool.retain(shared)
            if not self.pool.reserve_pages(req.rid, need_pages):
                if shared:
                    self.pool.unretain(shared)
                break                                  # FIFO: wait for pages
            if match is not None:
                # commit: one reference per shared page rides the request,
                # released with the rest of its pages; drop the pin.  Only
                # now do lookup/hit counters and LRU clocks move
                if shared:
                    self.pool.share(req.rid, shared)
                    self.pool.unretain(shared)
                self.prefix_cache.commit(match)
                req.prefix_match = match
            heapq.heappop(self._heap)
            req.slot = self._free_slots.pop()
            req.t_admit = now
            self.active[req.slot] = req
            joins.append(req)
        self.peak_active = max(self.peak_active, len(self.active))
        return joins

    # ---- completion (evict-on-EOS) --------------------------------------
    def release(self, req: Request) -> None:
        self.active.pop(req.slot, None)
        self._free_slots.append(req.slot)
        self.pool.release(req.rid)
        req.slot = -1
        req.pages = []


def poisson_arrivals(n: int, rate: float, seed: int = 0,
                     burst_every: int = 0, burst_gap: float = 0.0) -> np.ndarray:
    """Arrival offsets (s) for ``n`` requests at ``rate`` req/s.

    ``burst_every > 0`` inserts an extra ``burst_gap`` pause after every
    k-th request — the bursty trace that makes static batching idle.
    """
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n)
    gaps[0] = 0.0
    if burst_every:
        gaps[burst_every::burst_every] += burst_gap
    return np.cumsum(gaps)
