"""Fleet orchestration: router + replicas + autoscaler under one watt cap.

:class:`FleetSim` is the deterministic virtual-clock fleet: epoch by
epoch it (1) routes the trace's due arrivals through the
:class:`~repro.serve.fleet.router.FleetRouter` against live replica
state, (2) advances every replica's serving loop to the epoch boundary,
(3) lets the :class:`~repro.cluster.arbiter.PowerBudgetArbiter` reprice
watts from the replicas' governor snapshots (membership changes included
— a newcomer enters at the floor, a depart returns its grant to the
pool), and (4) asks the :class:`~repro.serve.fleet.autoscaler.Autoscaler`
whether fleet TTFT pressure or stranded fill justifies a membership
change.  Same trace + seed ⇒ identical dispatch log and bit-identical
per-replica ``GovernorReport``s (pinned by tests).

:func:`run_engine_fleet` is the same control loop over *real*
:class:`~repro.serve.engine.EngineSession` replicas on the wall clock —
the ``launch/serve.py --fleet`` path.  It shares the router and arbiter
epoch logic but not the clock, so it demonstrates wiring, not
reproducibility.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np

from repro.cluster.arbiter import PowerBudgetArbiter
from repro.core.policies import COUNTDOWN_SLACK, Policy
from repro.core.pstate import DEFAULT_HW, HwModel
from repro.serve.fleet.autoscaler import Autoscaler
from repro.serve.fleet.replica import (
    ACTIVE,
    DRAINING,
    STOPPED,
    WARMING,
    SimReplica,
)
from repro.serve.fleet.router import FleetRouter, ReplicaView
from repro.serve.fleet.scenarios import FleetTrace


def _pct(samples: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(samples), q)) if samples else 0.0


@dataclass
class FleetConfig:
    """Shape of one fleet run (sim or real)."""

    cfg: Any                              # arch config (page geometry source)
    n_replicas: int = 2                   # static size / autoscale maximum
    autoscale: bool = False
    min_replicas: int = 1
    n_slots: int = 4
    max_len: int = 128
    page: int = 16
    num_pages: Optional[int] = None
    cap_w: float = 40.0                   # cluster cap across the fleet
    floor_w: float = 4.0
    epoch_s: float = 0.25
    step_s: float = 2e-3
    prefill_tok_s: float = 1e-4
    warmup_s: float = 0.5
    ttft_target: float = 0.5
    tpot_target: float = 0.05
    # autoscaler trigger: scale up when recent TTFT p95 crosses this (None
    # ⇒ 60% of the SLO target — proactive, so capacity arrives *before*
    # the SLO is violated rather than after)
    scaleup_ttft_s: Optional[float] = None
    hw: HwModel = DEFAULT_HW
    policy: Policy = COUNTDOWN_SLACK
    max_epochs: int = 100_000

    def __post_init__(self):
        # min_replicas == 0 would start an autoscaled fleet with zero
        # routable replicas: the router raises on the first arrival long
        # before the autoscaler could warm anything
        if self.min_replicas < 1:
            raise ValueError(
                f"min_replicas must be >= 1, got {self.min_replicas}")
        if self.n_replicas < self.min_replicas:
            raise ValueError(
                f"n_replicas {self.n_replicas} < min_replicas "
                f"{self.min_replicas}")


@dataclass
class FleetResult:
    """What one fleet run produced, ready for the bench table."""

    trace: str
    autoscaled: bool
    n_requests: int
    n_completed: int
    tokens_out: int
    energy_j: float
    duration_s: float
    ttft: Dict[str, float]
    tpot: Dict[str, float]
    ttft_attainment: float                # fraction of samples within target
    tpot_attainment: float
    prefix_hit_rate: float
    prefix_lookups: int
    prefix_hits: int
    n_replicas_peak: int
    n_scale_ups: int
    n_scale_downs: int
    cap_w: float
    max_alloc_sum_w: float                # max over epochs of granted watts
    reports: Dict[str, Dict[str, Any]] = field(default_factory=dict)
    epochs: List[Dict[str, float]] = field(default_factory=list)

    @property
    def joules_per_token(self) -> float:
        return self.energy_j / max(self.tokens_out, 1)

    def to_dict(self) -> Dict[str, Any]:
        d = {k: getattr(self, k) for k in (
            "trace", "autoscaled", "n_requests", "n_completed", "tokens_out",
            "energy_j", "duration_s", "ttft", "tpot", "ttft_attainment",
            "tpot_attainment", "prefix_hit_rate", "prefix_lookups",
            "prefix_hits", "n_replicas_peak", "n_scale_ups", "n_scale_downs",
            "cap_w", "max_alloc_sum_w",
        )}
        d["joules_per_token"] = self.joules_per_token
        return d


class FleetSim:
    """Deterministic multi-replica serving fleet on a virtual clock."""

    def __init__(self, fc: FleetConfig, router: Optional[FleetRouter] = None):
        self.fc = fc
        self.router = router or FleetRouter()
        self.arbiter = PowerBudgetArbiter(cap_w=fc.cap_w, floor_w=fc.floor_w)
        trigger = (fc.scaleup_ttft_s if fc.scaleup_ttft_s is not None
                   else 0.6 * fc.ttft_target)
        self.autoscaler = Autoscaler(
            min_replicas=fc.min_replicas, max_replicas=fc.n_replicas,
            ttft_target=trigger, cap_w=fc.cap_w, floor_w=fc.floor_w,
        ) if fc.autoscale else None
        self.replicas: Dict[int, SimReplica] = {}
        self._next_id = 0
        self._activate_at: Dict[int, float] = {}
        n0 = fc.min_replicas if fc.autoscale else fc.n_replicas
        for _ in range(n0):
            self._spawn(t=0.0, state=ACTIVE)
        self.max_alloc_sum = 0.0
        self.energy_j = 0.0
        self.epoch_log: List[Dict[str, float]] = []
        # scaling signal: TTFT samples tagged with their landing epoch, so
        # pressure is judged on *recent* traffic — a count-based tail would
        # keep replaying peak-era latencies all through the valley
        self._ttft_seen: Dict[int, int] = {}
        self._ttft_recent: List[tuple] = []      # (epoch, ttft_s)
        self.signal_epochs = 8

    def _spawn(self, t: float, state: str) -> SimReplica:
        fc = self.fc
        rep = SimReplica(
            self._next_id, fc.cfg, n_slots=fc.n_slots, max_len=fc.max_len,
            page=fc.page, num_pages=fc.num_pages, hw=fc.hw, policy=fc.policy,
            step_s=fc.step_s, prefill_tok_s=fc.prefill_tok_s,
            ttft_target=fc.ttft_target, tpot_target=fc.tpot_target,
            t_created=t, state=state,
        )
        self.replicas[self._next_id] = rep
        if state == WARMING:
            self._activate_at[rep.replica_id] = t + fc.warmup_s
        self._next_id += 1
        return rep

    # ---- membership ------------------------------------------------------
    def _live(self) -> List[SimReplica]:
        return [r for r in self.replicas.values() if r.state != STOPPED]

    def _routable(self) -> List[SimReplica]:
        return [r for r in self.replicas.values() if r.state == ACTIVE]

    def _membership_count(self) -> int:
        return sum(1 for r in self.replicas.values()
                   if r.state in (ACTIVE, WARMING))

    def _scale_down_victim(self) -> Optional[SimReplica]:
        """Least-loaded active replica (ties: highest id — retire newest)."""
        cands = self._routable()
        if len(cands) <= 1:
            return None
        return min(cands, key=lambda r: (r.sched.n_active + r.sched.n_queued,
                                         -r.replica_id))

    def _ttft_signal(self, epoch: int) -> List[float]:
        """TTFT samples that landed within the last ``signal_epochs``
        epochs.  An idle valley therefore reads as *no* pressure — not as
        the peak's latencies replayed forever — which is what lets the
        scale-down branch ever fire."""
        for r in self._live():
            seen = self._ttft_seen.get(r.replica_id, 0)
            fresh = r.slo.ttft[seen:]
            if fresh:
                self._ttft_recent.extend((epoch, s) for s in fresh)
            self._ttft_seen[r.replica_id] = len(r.slo.ttft)
        cutoff = epoch - self.signal_epochs
        self._ttft_recent = [(e, s) for e, s in self._ttft_recent
                             if e >= cutoff]
        return [s for _, s in self._ttft_recent]

    # ---- main loop -------------------------------------------------------
    def run(self, trace: FleetTrace) -> FleetResult:
        fc = self.fc
        requests = trace.fresh_requests()
        i = 0
        t = 0.0
        epoch = 0
        while True:
            t_end = t + fc.epoch_s
            # 1) warmed replicas come online at the epoch boundary
            for rid, t_on in list(self._activate_at.items()):
                if t_on <= t:
                    rep = self.replicas[rid]
                    rep.state = ACTIVE
                    rep.now = max(rep.now, t)
                    del self._activate_at[rid]
            # 2) route the epoch's due arrivals against live replica state
            routable = self._routable()
            while i < len(requests) and requests[i].arrival < t_end:
                req = requests[i]
                dec = self.router.route(req, [r.view() for r in routable])
                self.replicas[dec.replica_id].submit(req)
                i += 1
            # 3) every serving replica advances to the epoch boundary
            for rep in self._live():
                rep.advance_to(t_end)
            for rep in self._live():
                if rep.state == DRAINING and rep.done:
                    rep.stop()
            # 4) arbiter reprices from governor snapshots (membership-aware)
            live = self._live()
            samples = [r.job_sample(fc.epoch_s) for r in live]
            alloc = self.arbiter.step(samples)
            self.max_alloc_sum = max(self.max_alloc_sum,
                                     sum(alloc.values(), 0.0))
            for rep, s in zip(live, samples):
                self.energy_j += s.power_w * fc.epoch_s
                if rep.job_id in alloc:
                    rep.set_cap(alloc[rep.job_id])
            # 5) autoscaler: TTFT pressure up, stranded fill down
            n_members = self._membership_count()
            if self.autoscaler is not None:
                recent = self._ttft_signal(epoch)
                fills = [r.sched.n_active / max(r.n_slots, 1)
                         for r in self._routable()]
                queued = sum(r.sched.n_queued for r in self._routable())
                action = self.autoscaler.decide(
                    epoch, n_members, _pct(recent, 95),
                    float(np.mean(fills)) if fills else 0.0, queued)
                if action > 0:
                    self._spawn(t=t_end, state=WARMING)
                elif action < 0:
                    victim = self._scale_down_victim()
                    if victim is not None:
                        victim.state = DRAINING
            self.epoch_log.append({
                "t": t_end, "n_replicas": float(n_members),
                "alloc_sum_w": sum(alloc.values(), 0.0),
                "queued": float(sum(r.sched.n_queued for r in live)),
                "active": float(sum(r.sched.n_active for r in live)),
            })
            t = t_end
            epoch += 1
            if i >= len(requests) and all(r.done for r in self._live()):
                break
            if epoch > fc.max_epochs:
                raise RuntimeError(f"fleet exceeded {fc.max_epochs} epochs")
        return self._result(trace, requests, t)

    # ---- reporting -------------------------------------------------------
    def _result(self, trace: FleetTrace, requests, duration: float) -> FleetResult:
        fc = self.fc
        reps = list(self.replicas.values())
        ttft = [s for r in reps for s in r.slo.ttft]
        tpot = [s for r in reps for s in r.slo.tpot]
        lookups = sum(r.prefix_cache.n_lookups for r in reps)
        hits = sum(r.prefix_cache.n_hits for r in reps)
        t_matched = sum(r.prefix_cache.tokens_matched for r in reps)
        t_looked = sum(r.prefix_cache.tokens_looked_up for r in reps)
        peak = max((int(e["n_replicas"]) for e in self.epoch_log), default=0)
        return FleetResult(
            trace=trace.name, autoscaled=fc.autoscale,
            n_requests=len(requests),
            n_completed=sum(len(r.finished) for r in reps),
            tokens_out=sum(r.tokens_out for r in reps),
            energy_j=self.energy_j, duration_s=duration,
            ttft={"n": len(ttft), "p50": _pct(ttft, 50),
                  "p95": _pct(ttft, 95), "p99": _pct(ttft, 99)},
            tpot={"n": len(tpot), "p50": _pct(tpot, 50),
                  "p95": _pct(tpot, 95), "p99": _pct(tpot, 99)},
            ttft_attainment=(
                sum(s <= fc.ttft_target for s in ttft) / len(ttft)
                if ttft else 1.0),
            tpot_attainment=(
                sum(s <= fc.tpot_target for s in tpot) / len(tpot)
                if tpot else 1.0),
            prefix_hit_rate=t_matched / max(t_looked, 1),
            prefix_lookups=lookups, prefix_hits=hits,
            n_replicas_peak=peak,
            n_scale_ups=(self.autoscaler.n_scale_ups
                         if self.autoscaler else 0),
            n_scale_downs=(self.autoscaler.n_scale_downs
                           if self.autoscaler else 0),
            cap_w=fc.cap_w, max_alloc_sum_w=self.max_alloc_sum,
            reports={r.job_id: r.governor.finalize().to_dict() for r in reps},
            epochs=self.epoch_log,
        )

    def export_metrics(self, registry) -> None:
        """Fleet-level series (``fleet_*``) plus router/arbiter exports."""
        registry.gauge("fleet_replicas", "live replicas").set(
            float(self._membership_count()))
        registry.gauge("fleet_energy_joules", "energy booked so far").set(
            self.energy_j)
        lookups = sum(r.prefix_cache.tokens_looked_up
                      for r in self.replicas.values())
        matched = sum(r.prefix_cache.tokens_matched
                      for r in self.replicas.values())
        registry.gauge("fleet_prefix_hit_rate",
                       "prompt tokens served from resident pages").set(
                           matched / max(lookups, 1))
        self.router.export_metrics(registry)
        if self.autoscaler is not None:
            self.autoscaler.export_metrics(registry)
        self.arbiter.export_metrics(registry)


# --------------------------------------------------------------------------
# real-engine fleet (wall clock)
# --------------------------------------------------------------------------

def session_view(session, replica_id: int) -> ReplicaView:
    """Router view over a live :class:`~repro.serve.engine.EngineSession`."""
    eng = session.engine
    return ReplicaView(
        replica_id=replica_id, n_slots=eng.n_slots,
        n_active=session.n_active, n_queued=session.n_queued,
        free_pages=eng.pool.free_pages,
        capacity_pages=eng.pool.capacity_pages,
        prefix_cache=eng.prefix_cache,
    )


def run_engine_fleet(engines, requests, *, cap_w: float, floor_w: float,
                     epoch_s: float = 0.25, slos=None, governors=None,
                     router: Optional[FleetRouter] = None,
                     hw: HwModel = DEFAULT_HW, max_steps: int = 200_000):
    """Drive N real :class:`~repro.serve.engine.ContinuousEngine` replicas
    as one fleet on the wall clock.

    Routing happens at arrival time against live prefix/pool/load state;
    replicas interleave one batched decode step per round (all idle ⇒ one
    metered sleep toward the next arrival); the arbiter reprices per
    epoch from each replica's governor snapshot, same power model as
    :class:`~repro.cluster.job.GovernorJob`.  Replicas may run either
    decode kernel (``attn_kernel="xla"``/``"pallas"``) — both are
    token-for-token identical, so routing/prefix decisions never depend
    on which replica serves a request.  Returns
    ``(finished, router, arbiter, sessions)``.
    """
    import time as _time

    from repro.cluster.arbiter import JobSample
    from repro.serve.engine import EngineSession

    slos = slos or [None] * len(engines)
    governors = governors or [None] * len(engines)
    t_start = _time.monotonic()
    sessions = [EngineSession(e, governor=g, slo=s, t_start=t_start)
                for e, g, s in zip(engines, governors, slos)]
    router = router or FleetRouter()
    arbiter = PowerBudgetArbiter(cap_w=cap_w, floor_w=floor_w)
    pending = sorted(requests, key=lambda r: (r.arrival, r.rid))
    i = 0
    next_epoch = epoch_s
    steps = 0
    stalls = 0
    while True:
        now = _time.monotonic() - t_start
        routed = False
        while i < len(pending) and pending[i].arrival <= now:
            req = pending[i]
            dec = router.route(
                req, [session_view(s, k) for k, s in enumerate(sessions)])
            sessions[dec.replica_id].submit(req)
            i += 1
            routed = True
        any_active = False
        for sess in sessions:
            sess.admit()
            if sess.n_active:
                any_active = True
                sess.decode_step()
                steps += 1
        if steps > max_steps:
            raise RuntimeError(f"fleet exceeded {max_steps} decode steps")
        if routed or any_active:
            stalls = 0
        if _time.monotonic() - t_start >= next_epoch:
            samples = []
            for k, gov in enumerate(governors):
                if gov is None:
                    continue
                stats = gov.interval_snapshot()
                exploited = min(stats.exploited, epoch_s)
                energy = (hw.watts(hw.f_max, hw.act_comp)
                          * (epoch_s - exploited)
                          + hw.watts(hw.f_min, hw.act_slack) * exploited)
                samples.append(JobSample(f"replica{k}", float(energy) / epoch_s,
                                         exploited / epoch_s))
            if samples:
                arbiter.step(samples)
            next_epoch += epoch_s
        if any_active:
            continue
        if i >= len(pending) and all(s.done for s in sessions):
            break
        # every replica idle: one metered sleep toward the next arrival
        # (routed-but-future ones live in session queues, unrouted in pending)
        targets = [s.next_arrival() for s in sessions]
        targets = [x for x in targets if x is not None]
        if i < len(pending):
            targets.append(pending[i].arrival)
        t0 = _time.monotonic()
        wait = (t_start + min(targets)) - t0
        if wait > 0:
            _time.sleep(min(wait, epoch_s))
            stalls = 0
        else:
            # every replica idle yet the next target is already due: only
            # routing or admission can make progress, and neither did this
            # round.  A queued request whose admission keeps failing (e.g.
            # pool pages pinned elsewhere) would otherwise busy-spin here
            # forever — decode steps never increment, so the max_steps
            # guard can't trip.  Bound the spin and fail loudly instead.
            stalls += 1
            if stalls > 10_000:
                queued = sum(s.n_queued for s in sessions)
                raise RuntimeError(
                    "fleet stalled: all replicas idle with a due arrival "
                    f"that cannot be admitted ({queued} queued, "
                    f"{len(pending) - i} unrouted) — likely page-pool "
                    "exhaustion by pinned/resident pages")
        t1 = _time.monotonic()
        for s in sessions:
            s.note_idle(t0, t1)
    finished: List[Any] = []
    for sess in sessions:
        finished.extend(sess.finished)
    return finished, router, arbiter, sessions
