"""Per-replica prefix cache: a radix trie of resident KV page prefixes.

Serving traffic is heavily self-similar — shared system prompts, few-shot
preambles, multi-turn sessions that resend the whole conversation — so
the K/V a replica computed for one request is very often a bit-exact
prefix of the next request's.  (K/V at position ``p`` depends only on
tokens ``<= p`` under causal attention with absolute RoPE, so identical
token prefixes imply identical page contents.)  This module keeps those
pages *resident* after their writer evicts and hands them to matching
joiners instead of recomputing prefill:

* the trie is keyed at **page granularity**: each node owns one physical
  page of the :class:`~repro.serve.kvcache.PagedKVPool` and the tuple of
  token ids written into it (full nodes carry exactly ``page`` tokens;
  *partial* leaves — a finished sequence's last, half-filled page — carry
  fewer);
* :meth:`match` walks the trie greedily and returns the longest resident
  prefix, capped one token short of the prompt so the joiner always has a
  suffix to run (the last prompt token's logits must be recomputed);
* full-block hits are **shared** (``PagedKVPool.share`` refcount, zero
  copies — the joiner's writes all land past them), a partial-block hit
  is **copy-on-write**: the joiner extends the page in place, so it gets
  a cloned page (``make_clone_pages``) while other referents keep
  reading the original;
* residency is refcounted through :meth:`PagedKVPool.retain`; when the
  pool cannot meet a reservation it calls :meth:`evict` (installed as
  ``pool.on_pressure``), which surrenders least-recently-used leaves
  until the pressure clears — so resident prefixes never block admission.

The cache is deliberately engine-agnostic: the same instance backs the
real :class:`~repro.serve.engine.ContinuousEngine` (device pages) and the
fleet simulator (accounting-only pool), and the
:class:`~repro.serve.fleet.router.FleetRouter` scores placement with
:meth:`peek` (no LRU side effects).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

import numpy as np

_NODE_IDS = itertools.count()


@dataclass
class _Node:
    """One resident page: the tokens written into it + trie links."""

    tokens: Tuple[int, ...]
    page_id: int
    parent: Optional["_Node"]
    n_tokens: int                       # == page for full nodes, < page partial
    last_use: int = 0
    node_id: int = field(default_factory=lambda: next(_NODE_IDS))
    children: Dict[Tuple[int, ...], "_Node"] = field(default_factory=dict)
    partials: Dict[Tuple[int, ...], "_Node"] = field(default_factory=dict)


@dataclass
class PrefixMatch:
    """Longest resident prefix for one prompt.

    ``n_tokens = page * len(full_pages) + partial_len``; ``partial_page``
    (when set) must be copy-on-write cloned by the joiner before writing.
    ``match`` is a side-effect-free trial — the caller passes the match
    back through :meth:`PrefixCache.commit` once the admission that used
    it actually succeeds, which is when lookup/hit counters and LRU
    clocks move.
    """

    n_tokens: int = 0
    full_pages: List[int] = field(default_factory=list)
    partial_page: Optional[int] = None
    partial_len: int = 0
    n_prompt: int = 0                       # looked-up prompt length
    nodes: List["_Node"] = field(default_factory=list)   # for commit's LRU


class PrefixCache:
    """Radix trie of resident page prefixes over one :class:`PagedKVPool`."""

    def __init__(self, pool, max_pages: Optional[int] = None):
        self.pool = pool
        self.page = pool.page
        # bound residency below pool capacity so the cache can never starve
        # admissions even before pressure eviction kicks in
        self.max_pages = max_pages if max_pages is not None \
            else max(pool.capacity_pages // 2, 1)
        self._root = _Node(tokens=(), page_id=-1, parent=None, n_tokens=0)
        self._n_resident = 0
        self._clock = 0
        # counters (exported as fleet_prefix_* metrics)
        self.n_lookups = 0
        self.n_hits = 0
        self.tokens_matched = 0
        self.tokens_looked_up = 0
        self.n_insertions = 0
        self.n_evictions = 0
        pool.on_pressure = self.evict

    # ---- introspection ---------------------------------------------------
    @property
    def n_resident_pages(self) -> int:
        return self._n_resident

    @property
    def hit_rate(self) -> float:
        """Fraction of looked-up prompt tokens served from resident pages."""
        return self.tokens_matched / max(self.tokens_looked_up, 1)

    # ---- matching --------------------------------------------------------
    def _walk(self, prompt: np.ndarray, limit: int) -> Tuple[List[_Node], Optional[_Node]]:
        """Greedy trie walk: full-block chain + an optional partial leaf,
        never matching past ``limit`` tokens."""
        chain: List[_Node] = []
        node = self._root
        pos = 0
        while pos + self.page <= limit:
            key = tuple(int(t) for t in prompt[pos:pos + self.page])
            child = node.children.get(key)
            if child is None:
                break
            chain.append(child)
            node = child
            pos += self.page
        best: Optional[_Node] = None
        for key, leaf in node.partials.items():
            if pos + leaf.n_tokens > limit:
                continue
            if tuple(int(t) for t in prompt[pos:pos + leaf.n_tokens]) == key:
                if best is None or leaf.n_tokens > best.n_tokens:
                    best = leaf
        return chain, best

    def peek(self, prompt: Sequence[int]) -> int:
        """Matched-token count for router scoring: no refcounts, no LRU."""
        prompt = np.asarray(prompt)
        chain, partial = self._walk(prompt, limit=len(prompt) - 1)
        return self.page * len(chain) + (partial.n_tokens if partial else 0)

    def match(self, prompt: Sequence[int]) -> PrefixMatch:
        """Longest resident prefix of ``prompt`` (capped at ``len - 1``).

        A side-effect-free *trial*: no references taken, no counters
        moved, no LRU touched.  The scheduler commits the match — pages
        via :meth:`PagedKVPool.share`, statistics and LRU clocks via
        :meth:`commit` — only once the request's reservation succeeds.
        A head-of-line-blocked request can therefore re-try its match
        every poll without deflating ``hit_rate`` or unfairly keeping
        its (blocked) prefix resident.
        """
        prompt = np.asarray(prompt)
        chain, partial = self._walk(prompt, limit=len(prompt) - 1)
        m = PrefixMatch(full_pages=[n.page_id for n in chain],
                        n_prompt=len(prompt), nodes=list(chain))
        m.n_tokens = self.page * len(chain)
        if partial is not None:
            m.nodes.append(partial)
            m.partial_page = partial.page_id
            m.partial_len = partial.n_tokens
            m.n_tokens += partial.n_tokens
        return m

    def commit(self, match: PrefixMatch) -> None:
        """Book a trial :meth:`match` that admission actually used: count
        the lookup (and hit, if any tokens matched) and refresh the
        matched nodes' LRU clocks.  Call exactly once per admitted
        request, after its page reservation succeeds.  Touching a node
        the reservation's pressure eviction already detached is a no-op —
        the shared pages themselves were pinned across that window."""
        self.n_lookups += 1
        self.tokens_looked_up += match.n_prompt
        self._clock += 1
        for node in match.nodes:
            node.last_use = self._clock
        if match.n_tokens:
            self.n_hits += 1
            self.tokens_matched += match.n_tokens

    # ---- insertion -------------------------------------------------------
    def insert(self, tokens: Sequence[int], pages: Sequence[int]) -> int:
        """Adopt a finished request's pages: ``tokens`` is its full written
        sequence (prompt + generated), ``pages`` its page table in order.
        Pages backing *new* trie nodes are retained (they survive the
        request's release); pages duplicating existing nodes are left to
        die with the request.  Nodes on the insertion path (walked or
        just created) are shielded from the capacity eviction below —
        evicting the chain tip about to receive a child would detach the
        child from the root, leaking its retained page forever.  When
        every evictable leaf is on the path, adoption stops instead.
        Returns the number of pages adopted."""
        tokens = np.asarray(tokens)
        adopted = 0
        node = self._root
        pos = 0
        self._clock += 1
        path: Set[int] = set()
        for i, pid in enumerate(pages):
            n_left = len(tokens) - pos
            if n_left <= 0:
                break
            if n_left >= self.page:
                key = tuple(int(t) for t in tokens[pos:pos + self.page])
                child = node.children.get(key)
                if child is None:
                    if self._n_resident >= self.max_pages \
                            and not self._evict_one(protect=path):
                        break
                    child = _Node(tokens=key, page_id=pid, parent=node,
                                  n_tokens=self.page)
                    node.children[key] = child
                    self.pool.retain([pid])
                    self._n_resident += 1
                    adopted += 1
                child.last_use = self._clock
                path.add(child.node_id)
                node = child
                pos += self.page
            else:
                key = tuple(int(t) for t in tokens[pos:])
                leaf = node.partials.get(key)
                if leaf is None:
                    if self._n_resident >= self.max_pages \
                            and not self._evict_one(protect=path):
                        break
                    leaf = _Node(tokens=key, page_id=pid, parent=node,
                                 n_tokens=n_left)
                    node.partials[key] = leaf
                    self.pool.retain([pid])
                    self._n_resident += 1
                    adopted += 1
                leaf.last_use = self._clock
                break
        if adopted:
            self.n_insertions += 1
        return adopted

    # ---- eviction --------------------------------------------------------
    def _leaves(self) -> List[_Node]:
        out: List[_Node] = []
        stack = [self._root]
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            out.extend(node.partials.values())
            for child in node.children.values():
                if not child.children and not child.partials:
                    out.append(child)
        return out

    _NO_PROTECT: FrozenSet[int] = frozenset()

    def _evict_one(self, protect: FrozenSet[int] = _NO_PROTECT) -> bool:
        """Drop the least-recently-used evictable leaf (ties: oldest node).
        ``protect`` names node_ids that must survive — the current
        insertion path, whose tip is about to be given a child."""
        leaves = [n for n in self._leaves() if n.node_id not in protect]
        if not leaves:
            return False
        victim = min(leaves, key=lambda n: (n.last_use, n.node_id))
        parent = victim.parent
        if victim.n_tokens < self.page:
            del parent.partials[victim.tokens]
        else:
            del parent.children[victim.tokens]
        self.pool.unretain([victim.page_id])
        self._n_resident -= 1
        self.n_evictions += 1
        return True

    def evict(self, n_pages: int) -> int:
        """Return >= ``n_pages`` pages to the pool's free list if residency
        allows (the ``pool.on_pressure`` hook).  A page still shared by a
        live request stays allocated when the cache's reference drops, so
        eviction keeps going until enough pages *actually* freed."""
        start_free = len(self.pool._free)
        while len(self.pool._free) - start_free < n_pages and self._evict_one():
            pass
        return len(self.pool._free) - start_free

    def clear(self) -> None:
        while self._evict_one():
            pass
