"""Virtual-clock fleet replica: the real serving stack minus the model.

A fleet energy study needs thousands of admission / join / decode / evict
decisions per replica, reproduced bit-identically across runs — which a
wall-clock engine cannot give.  :class:`SimReplica` therefore runs the
*real* accounting components — :class:`~repro.serve.scheduler.Scheduler`,
:class:`~repro.serve.kvcache.PagedKVPool` (``materialize=False``),
:class:`~repro.serve.fleet.prefix.PrefixCache`,
:class:`~repro.serve.slack.DecodeSlackMeter` into a live
:class:`~repro.core.governor.Governor`, and an
:class:`~repro.serve.slo.SLOTracker` — on a virtual clock, replacing only
the jitted forward passes with a cost model (``step_s`` per decode step,
``prefill_tok_s`` per prefill token) and the sampled tokens with each
request's scripted ``out_script``.  The step loop mirrors
:class:`~repro.serve.engine.EngineSession` exactly, including prefix
joins that replay their prompt suffix through *forced* decode steps.

The watt cap granted by the arbiter lands as a frequency clamp
(:meth:`HwModel.f_for_power`): a starved replica decodes slower, TTFT/
TPOT degrade, and the autoscaler sees it — the coupling the fleet story
is about.

Lifecycle: ``warming`` (spawned, paying warmup before it can serve) →
``active`` (routable) → ``draining`` (finishes what it has, gets no new
work) → ``stopped`` (resources dropped, zero watts).
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.cluster.arbiter import JobSample
from repro.core.governor import Governor
from repro.core.policies import COUNTDOWN_SLACK, Policy
from repro.core.pstate import DEFAULT_HW, HwModel
from repro.serve.fleet.prefix import PrefixCache
from repro.serve.fleet.router import ReplicaView
from repro.serve.kvcache import PagedKVPool
from repro.serve.scheduler import Request, Scheduler
from repro.serve.slack import DecodeSlackMeter
from repro.serve.slo import SLOTracker

WARMING, ACTIVE, DRAINING, STOPPED = "warming", "active", "draining", "stopped"


class SimReplica:
    """One simulated serving replica on a shared virtual clock."""

    def __init__(self, replica_id: int, cfg, *, n_slots: int = 4,
                 max_len: int = 128, page: int = 16,
                 num_pages: Optional[int] = None,
                 hw: HwModel = DEFAULT_HW, policy: Policy = COUNTDOWN_SLACK,
                 step_s: float = 2e-3, prefill_tok_s: float = 1e-4,
                 ttft_target: Optional[float] = None,
                 tpot_target: Optional[float] = None,
                 t_created: float = 0.0, state: str = ACTIVE):
        self.replica_id = replica_id
        self.job_id = f"replica{replica_id}"
        self.cfg = cfg
        self.n_slots = n_slots
        self.hw = hw
        self.step_s = step_s
        self.prefill_tok_s = prefill_tok_s
        self.pool = PagedKVPool(cfg, n_slots, max_len, page, num_pages,
                                materialize=False)
        self.prefix_cache = PrefixCache(self.pool)
        self.slo = SLOTracker(ttft_target=ttft_target, tpot_target=tpot_target)
        self.sched = Scheduler(self.pool, n_slots, n_prefix=0, slo=self.slo,
                               prefix_cache=self.prefix_cache)
        self.governor = Governor(policy=policy, hw=hw)
        self.meter = DecodeSlackMeter(self.governor, rank=0)
        self.now = t_created
        self.state = state
        self.cap_w = hw.watts_at_fmax
        self.f_eff = hw.f_max
        self.finished: List[Request] = []
        self.tokens_out = 0
        self._lengths: Dict[int, int] = {}      # slot -> written positions
        self._forced: Dict[int, int] = {}       # slot -> forced steps left

    # ---- arbiter coupling ------------------------------------------------
    def set_cap(self, watts: float) -> None:
        """Grant lands as a frequency clamp: decode slows under starvation."""
        self.cap_w = watts
        f = float(self.hw.f_for_power(watts, self.hw.act_comp))
        self.f_eff = min(max(f, self.hw.f_min), self.hw.f_max)

    @property
    def _step_s(self) -> float:
        return self.step_s * (self.hw.f_max / self.f_eff)

    # ---- router coupling -------------------------------------------------
    def view(self) -> ReplicaView:
        return ReplicaView(
            replica_id=self.replica_id, n_slots=self.n_slots,
            n_active=self.sched.n_active, n_queued=self.sched.n_queued,
            free_pages=self.pool.free_pages,
            capacity_pages=self.pool.capacity_pages,
            prefix_cache=self.prefix_cache,
        )

    def submit(self, req: Request) -> None:
        self.sched.submit(req)

    # ---- virtual-clock serving loop (mirrors EngineSession) --------------
    def _script_token(self, req: Request) -> int:
        if req.out_script is not None and req.n_generated < len(req.out_script):
            return int(req.out_script[req.n_generated])
        # deterministic fallback so unscripted requests still retire
        # reproducible sequences into the prefix trie
        return int((req.rid * 2654435761 + req.n_generated * 97 + 1) % 997) + 1

    def _join(self, req: Request) -> None:
        m = req.prefix_match
        slot = req.slot
        if m is not None and m.n_tokens > 0:
            pages = list(m.full_pages)
            if m.partial_page is not None:
                (pid,) = self.pool.alloc(req.rid, 1)   # CoW clone (accounting)
                pages.append(pid)
            req.pages = pages
            self._lengths[slot] = m.n_tokens
            n_forced = len(req.prompt) - m.n_tokens - 1
            if n_forced > 0:
                self._forced[slot] = n_forced
            return                                      # no prefill, no token
        n_used = self.pool.pages_needed(len(req.prompt))
        req.pages = self.pool.alloc(req.rid, n_used)
        self._lengths[slot] = len(req.prompt)
        self.now += self.prefill_tok_s * len(req.prompt) * (
            self.hw.f_max / self.f_eff)
        tok = self._script_token(req)
        req.out.append(tok)
        self.slo.on_first_token(req, self.now)

    def _grow_pages(self, req: Request) -> None:
        while self._lengths[req.slot] // self.pool.page >= len(req.pages):
            (pid,) = self.pool.alloc(req.rid, 1)
            req.pages.append(pid)

    def _retire(self, req: Request) -> None:
        self.slo.on_finish(req, self.now)
        slot = req.slot
        drained = self._forced.pop(slot, 0) == 0
        if req.pages and drained:
            n_written = self._lengths[slot]
            tokens = np.concatenate([
                np.asarray(req.prompt, np.int64),
                np.asarray(req.out, np.int64),
            ])[:n_written]
            self.prefix_cache.insert(tokens, req.pages)
        self._lengths.pop(slot, None)
        self.tokens_out += len(req.out)
        self.finished.append(req)
        self.sched.release(req)

    def _decode_step(self) -> None:
        for req in self.sched.active.values():
            self._grow_pages(req)
        t0 = self.now
        t1 = t0 + self._step_s
        self.meter.step(t0, t1, self.sched.n_active, self.n_slots)
        self.now = t1
        for slot, req in list(self.sched.active.items()):
            self._lengths[slot] += 1
            left = self._forced.get(slot, 0)
            if left > 0:
                if left == 1:
                    del self._forced[slot]
                else:
                    self._forced[slot] = left - 1
                continue
            tok = self._script_token(req)
            first = not req.out
            req.out.append(tok)
            if first:
                self.slo.on_first_token(req, t1)
            else:
                self.slo.on_token(req, t1)
            if not req.wants_more():
                self._retire(req)

    def advance_to(self, t_end: float) -> None:
        """Serve on the virtual clock until it reaches ``t_end``."""
        if self.state not in (ACTIVE, DRAINING):
            self.now = max(self.now, t_end)
            return
        while self.now < t_end:
            for req in self.sched.admit(self.now):
                self._join(req)
                if not req.wants_more():
                    self._retire(req)
            if self.sched.n_active == 0:
                nxt = self.sched.next_arrival()
                target = t_end if nxt is None else min(max(nxt, self.now), t_end)
                if target > self.now:
                    self.meter.idle(self.now, target)
                    self.now = target
                if nxt is None or nxt >= t_end:
                    break
                continue
            self._decode_step()

    # ---- lifecycle -------------------------------------------------------
    @property
    def done(self) -> bool:
        return self.sched.done

    def stop(self) -> None:
        """Drop all resources; the replica draws zero watts from here on."""
        self.prefix_cache.clear()
        self.state = STOPPED

    # ---- arbiter sample --------------------------------------------------
    def job_sample(self, epoch_dt: float) -> JobSample:
        """Model this epoch's draw from the governor's interval snapshot —
        the same accounting :class:`~repro.cluster.job.GovernorJob` applies
        to live tenants, on the virtual clock.  Warming replicas draw full
        compute power (model load) and report zero slack."""
        hw = self.hw
        if self.state == WARMING:
            w = hw.watts(hw.f_max, hw.act_comp)
            return JobSample(self.job_id, float(w), 0.0)
        stats = self.governor.interval_snapshot()
        exploited = min(stats.exploited, epoch_dt)
        energy = (hw.watts(self.f_eff, hw.act_comp) * (epoch_dt - exploited)
                  + hw.watts(hw.f_min, hw.act_slack) * exploited)
        s = self.slo.summary()
        return JobSample(
            self.job_id, float(energy) / max(epoch_dt, 1e-30),
            exploited / max(epoch_dt, 1e-30),
            done=self.state == STOPPED,
            ttft_p50=s["ttft"]["p50"], ttft_p99=s["ttft"]["p99"],
            tpot_p50=s["tpot"]["p50"], tpot_p99=s["tpot"]["p99"],
            prefix_hits=self.prefix_cache.n_hits,
            prefix_lookups=self.prefix_cache.n_lookups,
            prefix_hit_rate=self.prefix_cache.hit_rate,
        )
