"""repro.serve.fleet — multi-replica serving under one watt cap.

Prefix-cache-aware routing (:mod:`router`, :mod:`prefix`), SLO-driven
autoscaling (:mod:`autoscaler`), deterministic virtual-clock fleet
simulation plus a real-engine driver (:mod:`fleet`, :mod:`replica`), and
the arrival scenarios that exercise them (:mod:`scenarios`).
"""
from repro.serve.fleet.autoscaler import Autoscaler, ScaleDecision
from repro.serve.fleet.fleet import (
    FleetConfig,
    FleetResult,
    FleetSim,
    run_engine_fleet,
    session_view,
)
from repro.serve.fleet.prefix import PrefixCache, PrefixMatch
from repro.serve.fleet.replica import SimReplica
from repro.serve.fleet.router import FleetRouter, ReplicaView, RouteDecision
from repro.serve.fleet.scenarios import (
    FleetTrace,
    diurnal_trace,
    flash_crowd_trace,
    session_reuse_trace,
)

__all__ = [
    "Autoscaler",
    "ScaleDecision",
    "FleetConfig",
    "FleetResult",
    "FleetSim",
    "run_engine_fleet",
    "session_view",
    "PrefixCache",
    "PrefixMatch",
    "SimReplica",
    "FleetRouter",
    "ReplicaView",
    "RouteDecision",
    "FleetTrace",
    "diurnal_trace",
    "flash_crowd_trace",
    "session_reuse_trace",
]
