"""Fleet arrival scenarios: diurnal, flash-crowd, and session-reuse traces.

A fleet earns its energy story on *time-varying* load: a statically
provisioned fleet burns peak watts all day, an autoscaled one follows the
curve.  These generators produce deterministic request traces (a seed
fully pins arrivals, prompts, and scripted outputs) in three shapes:

* :func:`diurnal_trace` — a non-homogeneous Poisson process whose rate
  follows one sinusoidal "day": the headline static-vs-autoscaled
  comparison runs here, because off-peak is where static provisioning
  strands joules;
* :func:`flash_crowd_trace` — baseline Poisson with a short multiplied
  burst window: the autoscaler's reaction-time stressor (CI smoke runs a
  tiny one);
* :func:`session_reuse_trace` — multi-turn conversations that resend the
  whole dialogue each turn over a shared system prompt: the prefix
  cache's home turf (every turn's prompt is a served-before prefix plus
  a short tail).

Each request carries ``out_script`` — the tokens it would "generate" —
so the fleet *simulator* retires deterministic sequences into the prefix
trie (turn ``k+1`` can only hit resident pages if turn ``k``'s scripted
output is part of its prompt).  The real engine ignores scripts and
samples from the model.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional

import numpy as np

from repro.serve.scheduler import Request


@dataclass
class FleetTrace:
    """One scenario: arrival-sorted requests plus its shape metadata."""

    name: str
    requests: List[Request]
    duration_s: float
    seed: int

    @property
    def n_requests(self) -> int:
        return len(self.requests)

    def fresh_requests(self) -> List[Request]:
        """Re-instantiate every request (new rids, clean runtime state) so
        one trace can drive several fleets in the same process."""
        return [
            Request(prompt=r.prompt, max_new=r.max_new, arrival=r.arrival,
                    eos_id=r.eos_id, session=r.session,
                    out_script=r.out_script)
            for r in self.requests
        ]


def _thinned_arrivals(rate_fn: Callable[[float], float], rate_max: float,
                      duration_s: float, rng: np.random.Generator) -> np.ndarray:
    """Non-homogeneous Poisson arrivals on [0, duration) by thinning."""
    out: List[float] = []
    t = 0.0
    while True:
        t += rng.exponential(1.0 / rate_max)
        if t >= duration_s:
            break
        if rng.uniform() * rate_max <= rate_fn(t):
            out.append(t)
    return np.asarray(out)


def _mk_requests(arrivals: np.ndarray, rng: np.random.Generator, *,
                 vocab: int, prompt_len: int, max_new: int,
                 shared_prefix_len: int = 0,
                 shared_prefix: Optional[np.ndarray] = None) -> List[Request]:
    if shared_prefix is None and shared_prefix_len:
        shared_prefix = rng.integers(1, vocab, shared_prefix_len)
    reqs = []
    for t in arrivals:
        tail = rng.integers(1, vocab, prompt_len)
        prompt = tail if shared_prefix is None else np.concatenate(
            [shared_prefix, tail])
        script = rng.integers(1, vocab, max_new)
        reqs.append(Request(prompt=prompt.astype(np.int32), max_new=max_new,
                            arrival=float(t),
                            out_script=script.astype(np.int32)))
    return reqs


def diurnal_trace(duration_s: float = 60.0, base_rate: float = 2.0,
                  peak_ratio: float = 6.0, prompt_len: int = 24,
                  max_new: int = 16, shared_prefix_len: int = 16,
                  vocab: int = 1000, seed: int = 0) -> FleetTrace:
    """One sinusoidal "day": rate swings ``base_rate`` .. ``base_rate *
    peak_ratio`` with the peak at mid-trace.  All requests share a system
    prompt of ``shared_prefix_len`` tokens (realistic, and it gives the
    router a prefix signal even on fresh traffic)."""
    rng = np.random.default_rng(seed)
    peak = base_rate * peak_ratio

    def rate(t: float) -> float:
        # cosine valley at t=0 and t=duration, peak at duration/2
        return base_rate + (peak - base_rate) * 0.5 * (
            1.0 - np.cos(2.0 * np.pi * t / duration_s))

    arrivals = _thinned_arrivals(rate, peak, duration_s, rng)
    reqs = _mk_requests(arrivals, rng, vocab=vocab, prompt_len=prompt_len,
                        max_new=max_new, shared_prefix_len=shared_prefix_len)
    return FleetTrace("diurnal", reqs, duration_s, seed)


def flash_crowd_trace(duration_s: float = 20.0, base_rate: float = 2.0,
                      burst_ratio: float = 10.0, burst_start_frac: float = 0.4,
                      burst_width_frac: float = 0.15, prompt_len: int = 24,
                      max_new: int = 16, shared_prefix_len: int = 16,
                      vocab: int = 1000, seed: int = 0) -> FleetTrace:
    """Steady Poisson load with one ``burst_ratio``× window — the
    autoscaler reaction-time stressor."""
    rng = np.random.default_rng(seed)
    b0 = burst_start_frac * duration_s
    b1 = b0 + burst_width_frac * duration_s
    peak = base_rate * burst_ratio

    def rate(t: float) -> float:
        return peak if b0 <= t < b1 else base_rate

    arrivals = _thinned_arrivals(rate, peak, duration_s, rng)
    reqs = _mk_requests(arrivals, rng, vocab=vocab, prompt_len=prompt_len,
                        max_new=max_new, shared_prefix_len=shared_prefix_len)
    return FleetTrace("flash_crowd", reqs, duration_s, seed)


def session_reuse_trace(n_sessions: int = 8, turns: int = 4,
                        system_len: int = 24, turn_len: int = 8,
                        max_new: int = 8, session_rate: float = 1.0,
                        turn_gap_s: float = 2.0, vocab: int = 1000,
                        seed: int = 0) -> FleetTrace:
    """Multi-turn conversations over one shared system prompt.

    Turn ``k``'s prompt is ``system + (user_1 + reply_1) + ... + user_k``
    — the full dialogue resent, exactly the traffic prefix caching exists
    for.  Replies are the scripted ``out_script`` tokens, so the
    simulator's retired pages really are the next turn's prefix.
    """
    rng = np.random.default_rng(seed)
    system = rng.integers(1, vocab, system_len).astype(np.int32)
    starts = np.cumsum(rng.exponential(1.0 / session_rate, n_sessions))
    starts[0] = 0.0
    reqs: List[Request] = []
    t_last = 0.0
    for sid in range(n_sessions):
        history = system
        t = float(starts[sid])
        for k in range(turns):
            user = rng.integers(1, vocab, turn_len).astype(np.int32)
            prompt = np.concatenate([history, user])
            script = rng.integers(1, vocab, max_new).astype(np.int32)
            reqs.append(Request(prompt=prompt, max_new=max_new, arrival=t,
                                session=sid, out_script=script))
            # the reply the next turn's prompt includes is what the engine
            # *wrote*: the last scripted token's K/V never lands (it is
            # sampled, then the request retires), so resend all but it
            history = np.concatenate([prompt, script[:-1]])
            t_last = max(t_last, t)
            t += turn_gap_s * (0.5 + rng.uniform())
    reqs.sort(key=lambda r: (r.arrival, r.rid))
    return FleetTrace("session_reuse", reqs, t_last, seed)
