"""SLO-driven replica autoscaling, AIMD-coupled to the watt arbiter.

The scaling signal is the same one :class:`~repro.serve.slo.SLOTracker`
already feeds admission control: TTFT percentiles (queueing pressure —
scale **up**) and fleet fill fraction (stranded capacity — scale
**down**).  Decisions are additive in both directions (one replica per
cooldown window), because every membership change makes the
:class:`~repro.cluster.arbiter.PowerBudgetArbiter` reprice the whole
fleet: a newcomer enters at the floor and climbs additively, a departure
returns its watts to the pool — thrashing membership thrashes every
tenant's budget.

``max_replicas`` is clamped to ``floor(cap_w / floor_w)``: the arbiter
*raises* on a fleet whose floors alone exceed the cluster cap, so the
scaler must never propose one.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional


@dataclass
class ScaleDecision:
    epoch: int
    action: int                  # +1 scale up, -1 scale down, 0 hold
    n_replicas: int              # membership after the action
    reason: str


@dataclass
class Autoscaler:
    """Additive-increase/additive-decrease replica count controller."""

    min_replicas: int = 1
    max_replicas: int = 4
    ttft_target: float = 0.5         # seconds, p95 over the recent window
    scale_down_fill: float = 0.35    # mean fill below which capacity strands
    backlog_per_replica: float = 4.0 # queued/replica that also forces up
    cooldown_epochs: int = 3
    down_consecutive: int = 4        # low-fill epochs required before a down
    cap_w: Optional[float] = None    # clamp max_replicas to the watt floor
    floor_w: Optional[float] = None
    decisions: List[ScaleDecision] = field(default_factory=list)
    _last_action_epoch: int = field(default=-10**9, repr=False)
    _down_streak: int = field(default=0, repr=False)

    def __post_init__(self):
        if self.cap_w is not None and self.floor_w:
            affordable = int(math.floor(self.cap_w / self.floor_w))
            self.max_replicas = min(self.max_replicas, max(affordable, 1))
        self.min_replicas = min(self.min_replicas, self.max_replicas)

    def decide(self, epoch: int, n_replicas: int, ttft_p95: float,
               fill_mean: float, n_queued: int) -> int:
        """Return -1 / 0 / +1; records the decision either way."""
        action, reason = 0, "hold"
        in_cooldown = epoch - self._last_action_epoch < self.cooldown_epochs
        backlog = n_queued / max(n_replicas, 1)
        pressure = (ttft_p95 > self.ttft_target or
                    backlog > self.backlog_per_replica)
        # hysteresis: one hot epoch resets the down-streak, so a down needs
        # `down_consecutive` quiet epochs in a row — a momentary dip during
        # the ramp must not shed the replica it will want back next epoch
        if pressure or fill_mean >= self.scale_down_fill or n_queued:
            self._down_streak = 0
        else:
            self._down_streak += 1
        if not in_cooldown:
            if pressure:
                if n_replicas < self.max_replicas:
                    action = +1
                    reason = (f"ttft_p95={ttft_p95:.3f}s"
                              if ttft_p95 > self.ttft_target
                              else f"backlog={backlog:.1f}/replica")
                else:
                    reason = "at max_replicas"
            elif (self._down_streak >= self.down_consecutive
                    and n_replicas > self.min_replicas):
                action = -1
                reason = f"fill={fill_mean:.2f}"
        else:
            reason = "cooldown"
        if action:
            self._last_action_epoch = epoch
            self._down_streak = 0
        self.decisions.append(ScaleDecision(
            epoch=epoch, action=action, n_replicas=n_replicas + action,
            reason=reason))
        return action

    @property
    def n_scale_ups(self) -> int:
        return sum(1 for d in self.decisions if d.action > 0)

    @property
    def n_scale_downs(self) -> int:
        return sum(1 for d in self.decisions if d.action < 0)

    def export_metrics(self, registry) -> None:
        registry.gauge("fleet_scale_ups", "autoscaler scale-up events").set(
            float(self.n_scale_ups))
        registry.gauge("fleet_scale_downs",
                       "autoscaler scale-down events").set(
                           float(self.n_scale_downs))
