"""Prefix-aware + load-aware request routing across fleet replicas.

Placement is where prefix caching is won or lost in a fleet: pages are
resident *per replica*, so sending a session's next turn to a different
replica than its last one recomputes everything.  The router scores every
routable replica as

    score = w_prefix * matched_frac + w_free * free_frac - w_load * load_frac

where ``matched_frac`` is the longest resident prefix (``PrefixCache.
peek`` — no LRU side effects) over the prompt length, ``free_frac`` is
the pool's unreserved-page fraction, and ``load_frac`` is (active +
queued) over decode slots, allowed above 1 so backlog keeps repelling.
Ties (and the no-signal cold start) break to the **lowest replica id**,
which makes routing a pure function of replica state — the determinism
the fleet tests pin.

Replicas are duck-typed through :class:`ReplicaView` so the same router
fronts simulator replicas and real :class:`~repro.serve.engine.
EngineSession` wrappers.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, List, Optional, Sequence

_TIE_EPS = 1e-12


@dataclass
class ReplicaView:
    """What the router sees of one replica at decision time."""

    replica_id: int
    n_slots: int
    n_active: int
    n_queued: int
    free_pages: int
    capacity_pages: int
    prefix_cache: Any = None         # .peek(prompt) -> matched token count

    @property
    def load_frac(self) -> float:
        return (self.n_active + self.n_queued) / max(self.n_slots, 1)

    @property
    def free_frac(self) -> float:
        return self.free_pages / max(self.capacity_pages, 1)


@dataclass
class RouteDecision:
    """One dispatch: request -> replica, with the scores that chose it."""

    rid: int
    replica_id: int
    score: float
    matched_tokens: int
    scores: List[float] = field(default_factory=list)   # by candidate order


class FleetRouter:
    """Scores candidates, keeps the dispatch log, counts prefix affinity."""

    def __init__(self, w_prefix: float = 1.0, w_free: float = 0.3,
                 w_load: float = 0.5):
        self.w_prefix = w_prefix
        self.w_free = w_free
        self.w_load = w_load
        self.decisions: List[RouteDecision] = []
        self.n_prefix_routed = 0     # dispatches that followed a resident prefix

    def score(self, view: ReplicaView, prompt: Sequence[int]) -> float:
        matched = 0
        if view.prefix_cache is not None and len(prompt) > 1:
            matched = view.prefix_cache.peek(prompt)
        matched_frac = matched / max(len(prompt), 1)
        return (self.w_prefix * matched_frac
                + self.w_free * view.free_frac
                - self.w_load * view.load_frac)

    def route(self, req, views: List[ReplicaView]) -> RouteDecision:
        """Pick the best replica for ``req``; raises when none routable."""
        if not views:
            raise ValueError("no routable replicas")
        best: Optional[ReplicaView] = None
        best_score = -float("inf")
        best_matched = 0
        scores: List[float] = []
        # iterate in replica-id order so the < tie test is the lowest-id rule
        for view in sorted(views, key=lambda v: v.replica_id):
            matched = 0
            if view.prefix_cache is not None and len(req.prompt) > 1:
                matched = view.prefix_cache.peek(req.prompt)
            s = (self.w_prefix * matched / max(len(req.prompt), 1)
                 + self.w_free * view.free_frac
                 - self.w_load * view.load_frac)
            scores.append(s)
            if s > best_score + _TIE_EPS:
                best, best_score, best_matched = view, s, matched
        dec = RouteDecision(rid=req.rid, replica_id=best.replica_id,
                            score=best_score, matched_tokens=best_matched,
                            scores=scores)
        self.decisions.append(dec)
        if best_matched > 0:
            self.n_prefix_routed += 1
        return dec

    def export_metrics(self, registry) -> None:
        """``fleet_router_*`` series into a MetricsRegistry."""
        registry.gauge("fleet_router_decisions",
                       "requests dispatched").set(float(len(self.decisions)))
        registry.gauge("fleet_router_prefix_routed",
                       "dispatches that followed a resident prefix").set(
                           float(self.n_prefix_routed))
