"""Decode-slack governor bridge: price serving underfill like MPI slack.

The paper isolates the slack inside a blocking collective with an
artificial barrier and spends it at the minimum P-state.  A serving
engine has the exact analogue in two forms:

* **underfill** — a decode step dispatched with ``filled < capacity``
  slots does full-width work but only ``filled/capacity`` of it moves
  payload; the empty fraction of the step is slack;
* **idle gaps** — wall time between the last completion and the next
  arrival, a whole phase of pure slack.

:class:`DecodeSlackMeter` maps both onto the governor's phase-event
vocabulary through :meth:`repro.core.governor.Governor.ingest_phase`
(the non-collective event source): a decode step spanning ``[t0, t1]``
with ``f`` of ``C`` slots filled becomes ``barrier_enter`` at ``t0``,
``barrier_exit`` (slack end) at ``t0 + (t1-t0)·(1 - f/C)`` and
``copy_exit`` at ``t1`` — so ``finalize()`` prices underfill in joules
with the same ``theta_eff`` timeout filter, and idle intervals book
``set_pstate_min``/``restore_pstate_max`` actuation pairs, exactly as a
blocked MPI rank would.

Call ids live in a private namespace (upper bit set) so meter phases can
never collide with the instrumented-collective counter.  Because those ids
are minted fresh per phase, the meter also passes a *stable site* to
``ingest_phase`` (one for underfill steps, one for idle gaps): the
:class:`~repro.core.timeout.ThetaTuner` keys its slack histograms by site,
so decode slack accumulates into two long-lived distributions — the same
tuner the MPI-side collectives feed — instead of one cold histogram per
step.
"""
from __future__ import annotations

import itertools
from typing import Optional

from repro.core.governor import Governor

_CALL_ID_BASE = 1 << 20

# stable tuner sites (see module docstring); ids count from past them
SITE_DECODE_STEP = _CALL_ID_BASE
SITE_IDLE_GAP = _CALL_ID_BASE + 1


class DecodeSlackMeter:
    """Feeds decode underfill + idle gaps into a :class:`Governor`."""

    def __init__(self, governor: Governor, rank: int = 0):
        self.governor = governor
        self.rank = rank
        self._ids = itertools.count(_CALL_ID_BASE + 2)
        self.n_steps = 0
        self.n_idle = 0
        self.slot_steps_filled = 0
        self.slot_steps_total = 0

    def step(self, t0: float, t1: float, filled: int, capacity: int) -> None:
        """One decode step: the unfilled slot fraction of [t0, t1] is slack."""
        self.n_steps += 1
        self.slot_steps_filled += filled
        self.slot_steps_total += capacity
        underfill = 1.0 - filled / max(capacity, 1)
        t_slack_end = t0 + (t1 - t0) * underfill
        self.governor.ingest_phase(self.rank, next(self._ids), t0, t_slack_end, t1,
                                   site=SITE_DECODE_STEP)

    def idle(self, t0: float, t1: float) -> None:
        """An inter-arrival gap with zero active slots: pure slack."""
        self.n_idle += 1
        self.governor.ingest_phase(self.rank, next(self._ids), t0, t1, t1,
                                   site=SITE_IDLE_GAP)

    @property
    def fill_fraction(self) -> float:
        return self.slot_steps_filled / max(self.slot_steps_total, 1)
