"""Decode-slack governor bridge: price serving underfill like MPI slack.

The paper isolates the slack inside a blocking collective with an
artificial barrier and spends it at the minimum P-state.  A serving
engine has the exact analogue in two forms:

* **underfill** — a decode step dispatched with ``filled < capacity``
  slots does full-width work but only ``filled/capacity`` of it moves
  payload; the empty fraction of the step is slack;
* **idle gaps** — wall time between the last completion and the next
  arrival, a whole phase of pure slack.

:class:`DecodeSlackMeter` maps both onto the canonical phase vocabulary
as fully-formed :class:`~repro.core.events.PhaseRecord` values: a decode
step spanning ``[t0, t1]`` with ``f`` of ``C`` slots filled becomes a
phase entered at ``t0`` whose slack ends at ``t0 + (t1-t0)·(1 - f/C)``
and whose copy ends at ``t1`` — so ``finalize()`` prices underfill in
joules with the same ``theta_eff`` timeout filter, and idle intervals
book ``set_pstate_min``/``restore_pstate_max`` actuation pairs, exactly
as a blocked MPI rank would.  The meter targets either a
:class:`~repro.core.governor.Governor` directly (``on_phase``) or an
:class:`~repro.core.events.EventBus` (``publish_phase`` fan-out to N
subscribers) — it cannot tell the difference, which is the point.

Call ids live in a private namespace (upper bit set) so meter phases can
never collide with the instrumented-collective counter.  Because those ids
are minted fresh per phase, the meter also stamps a *stable site* on each
record (one for underfill steps, one for idle gaps): the
:class:`~repro.core.timeout.ThetaTuner` keys its slack histograms by site,
so decode slack accumulates into two long-lived distributions — the same
tuner the MPI-side collectives feed — instead of one cold histogram per
step.
"""
from __future__ import annotations

import itertools

from repro.core.events import PhaseRecord

_CALL_ID_BASE = 1 << 20

# stable tuner sites (see module docstring); ids count from past them
SITE_DECODE_STEP = _CALL_ID_BASE
SITE_IDLE_GAP = _CALL_ID_BASE + 1


class DecodeSlackMeter:
    """Feeds decode underfill + idle gaps into a governor or event bus."""

    def __init__(self, target, rank: int = 0):
        # duck-typed: an EventBus exposes publish_phase, a Governor (or any
        # canonical subscriber) exposes on_phase
        publish = getattr(target, "publish_phase", None)
        if publish is None:
            publish = target.on_phase
        self._publish = publish
        self.target = target
        self.rank = rank
        self._ids = itertools.count(_CALL_ID_BASE + 2)
        self.n_steps = 0
        self.n_idle = 0
        self.slot_steps_filled = 0
        self.slot_steps_total = 0

    def step(self, t0: float, t1: float, filled: int, capacity: int) -> None:
        """One decode step: the unfilled slot fraction of [t0, t1] is slack."""
        self.n_steps += 1
        self.slot_steps_filled += filled
        self.slot_steps_total += capacity
        underfill = 1.0 - filled / max(capacity, 1)
        t_slack_end = t0 + (t1 - t0) * underfill
        self._publish(PhaseRecord(self.rank, next(self._ids), t0, t_slack_end,
                                  t1, SITE_DECODE_STEP))

    def idle(self, t0: float, t1: float) -> None:
        """An inter-arrival gap with zero active slots: pure slack."""
        self.n_idle += 1
        self._publish(PhaseRecord(self.rank, next(self._ids), t0, t1, t1,
                                  SITE_IDLE_GAP))

    @property
    def fill_fraction(self) -> float:
        return self.slot_steps_filled / max(self.slot_steps_total, 1)
