"""Per-request SLO tracking: TTFT / TPOT percentiles + admission feedback.

TTFT (time to first token) measures queueing + prefill; TPOT (time per
output token) measures decode-step latency as seen by one request.  The
tracker keeps raw samples, reports percentile summaries, and drives one
admission decision: when recent TPOT blows its target — the batch is too
wide for the hardware — :meth:`max_concurrency` caps how many requests
the scheduler may keep active (additive decrease), and recovers one slot
at a time once latency clears (additive increase).
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np


def _pct(samples: List[float]) -> Dict[str, float]:
    if not samples:
        return {"n": 0, "mean": 0.0, "p50": 0.0, "p95": 0.0, "p99": 0.0}
    a = np.asarray(samples, dtype=np.float64)
    return {
        "n": int(a.size),
        "mean": float(a.mean()),
        "p50": float(np.percentile(a, 50)),
        "p95": float(np.percentile(a, 95)),
        "p99": float(np.percentile(a, 99)),
    }


class SLOTracker:
    """Collects TTFT/TPOT samples and throttles admission when TPOT slips."""

    def __init__(self, ttft_target: Optional[float] = None,
                 tpot_target: Optional[float] = None, window: int = 32,
                 adjust_every: int = 8):
        self.ttft_target = ttft_target
        self.tpot_target = tpot_target
        self.window = window
        self.adjust_every = adjust_every
        self.ttft: List[float] = []
        self.tpot: List[float] = []
        self.n_completed = 0
        self._limit: Optional[int] = None
        self._since_adjust = 0

    # ---- engine hooks ----------------------------------------------------
    def on_first_token(self, req, now: float) -> None:
        self.ttft.append(now - req.arrival)
        req.t_first = req.t_prev = now

    def on_token(self, req, now: float) -> None:
        if req.t_prev >= 0:
            self.tpot.append(now - req.t_prev)
            self._since_adjust += 1
        req.t_prev = now

    def on_finish(self, req, now: float) -> None:
        req.t_done = now
        self.n_completed += 1

    # ---- reporting -------------------------------------------------------
    def summary(self) -> Dict[str, Dict[str, float]]:
        out = {"ttft": _pct(self.ttft), "tpot": _pct(self.tpot),
               "completed": self.n_completed}
        if self.ttft_target is not None:
            out["ttft"]["target"] = self.ttft_target
            out["ttft"]["violations"] = sum(t > self.ttft_target for t in self.ttft)
        if self.tpot_target is not None:
            out["tpot"]["target"] = self.tpot_target
            out["tpot"]["violations"] = sum(t > self.tpot_target for t in self.tpot)
        return out

    def export_metrics(self, registry) -> None:
        """Publish the percentile summary into a :class:`repro.obs.metrics.
        MetricsRegistry` (``serve_ttft_seconds{q=...}`` and friends) — the
        dashboard and JSONL snapshot view of this tracker."""
        s = self.summary()
        for metric, name in (("ttft", "serve_ttft_seconds"),
                             ("tpot", "serve_tpot_seconds")):
            fam = registry.gauge(name, f"{metric} summary over the run", ("q",))
            for q in ("mean", "p50", "p95", "p99"):
                fam.labels(q).set(s[metric][q])
            target = s[metric].get("target")
            if target is not None:
                registry.gauge(f"serve_{metric}_target_seconds",
                               f"{metric} SLO target").set(target)
                registry.gauge(f"serve_{metric}_violations",
                               f"samples over the {metric} target").set(
                                   s[metric]["violations"])
        done = registry.counter("serve_completed_total",
                                "requests completed").labels()
        delta = s["completed"] - done.value
        if delta > 0:
            done.inc(delta)

    # ---- admission feedback ---------------------------------------------
    def max_concurrency(self, n_slots: int) -> int:
        """AIMD-style cap: shrink when recent p95 TPOT > target, regrow
        one slot at a time when it clears 70% of the target."""
        if self._limit is None:
            self._limit = n_slots
        self._limit = min(self._limit, n_slots)
        if self.tpot_target is None or self._since_adjust < self.adjust_every:
            return self._limit
        self._since_adjust = 0
        recent = self.tpot[-self.window:]
        p95 = float(np.percentile(np.asarray(recent), 95)) if recent else 0.0
        if p95 > self.tpot_target:
            self._limit = max(1, self._limit - 1)
        elif p95 < 0.7 * self.tpot_target:
            self._limit = min(n_slots, self._limit + 1)
        return self._limit
