"""Serving engines: continuous batching over the paged KV pool + legacy API.

``ContinuousEngine`` is the subsystem's production path: a fixed-width
decode batch of ``n_slots`` whose slots are continuously refilled —
arrived requests **join on prefill** (prefill runs through the stock
``transformer.prefill`` and is scattered into pool pages), finished
requests **evict on EOS** freeing their slot and pages in the same step.
Each decode step runs one jitted paged step for all slots (idle slots
write into the scratch page), and reports filled-vs-capacity plus
inter-arrival idle gaps to the governor through
:class:`~repro.serve.slack.DecodeSlackMeter`, so serving underfill is
priced in joules exactly like MPI slack.

``ServeEngine`` is the original static-batch engine, kept as a thin
compatibility wrapper: one prefill, a fixed batch, ``n_steps`` decode
steps for everyone.  ``ContinuousEngine.generate`` reproduces its
output token-for-token for greedy decoding (tier-1 asserted).
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import decode_step as _decode
from repro.models.transformer import init_cache, stack_layout
from repro.models.transformer import prefill as _prefill
from repro.serve.kvcache import (
    SCRATCH_PAGE,
    PagedKVPool,
    make_clone_pages,
    paged_attention_decode,
    scatter_prefill_attn,
)
from repro.serve.scheduler import Request, Scheduler
from repro.serve.slack import DecodeSlackMeter


# --------------------------------------------------------------------------
# legacy static-batch engine (compatibility wrapper)
# --------------------------------------------------------------------------

def make_serve_steps(cfg) -> Tuple[Callable, Callable]:
    """Returns (prefill_step(params, batch, cache), decode_step(params, token, pos, cache))."""

    def prefill_step(params, batch, cache):
        return _prefill(cfg, params, batch, cache)

    def decode_step(params, token, pos, cache):
        return _decode(cfg, params, token, pos, cache)

    return prefill_step, decode_step


@dataclass
class ServeEngine:
    cfg: Any
    params: Any
    max_len: int
    temperature: float = 0.0

    def __post_init__(self):
        self._prefill, self._decode = make_serve_steps(self.cfg)
        self._prefill = jax.jit(self._prefill)
        self._decode = jax.jit(self._decode)

    def generate(
        self,
        batch: Dict[str, Any],
        n_steps: int,
        key: Optional[jax.Array] = None,
    ) -> jnp.ndarray:
        """Greedy/sampled continuation of ``batch['tokens']`` for n_steps."""
        b, s = batch["tokens"].shape
        prompt_len = s + self.cfg.n_prefix
        cache = init_cache(self.cfg, b, self.max_len)
        logits, cache = self._prefill(self.params, batch, cache)
        out = []
        tok = self._select(logits, key, 0)
        out.append(tok)
        for i in range(1, n_steps):
            logits, cache = self._decode(
                self.params, tok, jnp.int32(prompt_len + i - 1), cache
            )
            tok = self._select(logits, key, i)
            out.append(tok)
        return jnp.stack(out, axis=1)                          # (B, n_steps)

    def _select(self, logits, key, i):
        if self.temperature <= 0.0 or key is None:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        sub = jax.random.fold_in(key, i)
        return jax.random.categorical(sub, logits / self.temperature).astype(jnp.int32)


# --------------------------------------------------------------------------
# paged step builders
# --------------------------------------------------------------------------

def make_paged_decode_step(cfg, attn_kernel: str = "xla",
                           fused_sample: bool = False) -> Callable:
    """decode(params, token (B,), pos (B,), table (B,M), blocks) -> (out, blocks).

    Reuses the stock ``transformer.decode_step`` walker (scan/rem stack,
    MoE dropless decode, SSM/RG-LRU state) and swaps only the attention:
    a closure over the page table routes it through the paged pool, via
    the XLA reference path or the Pallas paged kernel (``attn_kernel``).

    With ``fused_sample`` the greedy argmax runs inside the same jitted
    dispatch and ``out`` is the sampled ``(B,)`` int32 tokens — the step
    ships B words back to the host instead of a (B, vocab) logits block
    plus a second argmax dispatch.  Callers that need logits (sampling
    with temperature) keep the unfused step.
    """

    def step(params, token, pos, table, blocks):
        def paged_attn(p_attn, h, bc):
            return paged_attention_decode(
                cfg, p_attn, h, pos, table, bc, kernel=attn_kernel
            )

        logits, blocks = _decode(cfg, params, token, pos, blocks, attn_fn=paged_attn)
        if fused_sample:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), blocks
        return logits, blocks

    return step


def make_join_step(cfg) -> Callable:
    """join(blocks, prefill_cache, page_ids (n_used,), slot) -> blocks.

    Scatters a batch-1 prefill cache into the pool: attention K/V into the
    slot's freshly allocated pages, recurrent state into the slot's row.
    """

    def join(blocks, cache, page_ids, slot):
        new_stack = {}
        for j, kind in enumerate(cfg.pattern):
            pb, cb = blocks["stack"][str(j)], cache["stack"][str(j)]
            if kind == "attn":
                new_stack[str(j)] = scatter_prefill_attn(pb, cb, page_ids, stacked=True)
            else:
                new_stack[str(j)] = jax.tree.map(
                    lambda big, small: big.at[:, slot].set(small[:, 0]), pb, cb
                )
        new_blocks = {"stack": new_stack}
        _, rem_kinds = stack_layout(cfg)
        if rem_kinds:
            new_blocks["rem"] = {}
            for j, kind in enumerate(rem_kinds):
                pb, cb = blocks["rem"][str(j)], cache["rem"][str(j)]
                if kind == "attn":
                    new_blocks["rem"][str(j)] = scatter_prefill_attn(
                        pb, cb, page_ids, stacked=False
                    )
                else:
                    new_blocks["rem"][str(j)] = jax.tree.map(
                        lambda big, small: big.at[slot].set(small[0]), pb, cb
                    )
        return new_blocks

    return join


# --------------------------------------------------------------------------
# continuous-batching engine
# --------------------------------------------------------------------------

@dataclass
class ContinuousEngine:
    """Continuous batching over a paged KV pool with governor-priced slack.

    ``n_slots`` is the decode batch width, ``max_len`` the per-request
    position budget (multiple of ``page``), ``num_pages`` optionally
    shrinks the pool below full occupancy to exercise admission control.
    For windowed archs prompts must fit inside the window (the pool
    stores positions linearly and masks by window at read).

    ``attn_kernel`` selects the decode attention hot path: ``"xla"`` (the
    gather/scatter reference) or ``"pallas"`` (the paged kernel with the
    fused scatter epilogue; greedy decoding additionally samples inside
    the decode dispatch).  Both are token-for-token identical to the
    dense engine (tier-1 asserted).
    """

    cfg: Any
    params: Any
    n_slots: int = 4
    max_len: int = 128
    page: int = 16
    num_pages: Optional[int] = None
    temperature: float = 0.0
    attn_kernel: str = "xla"
    # optional repro.obs.tracer.SpanTracer (duck-typed: .serve_event):
    # batch join/evict instants land on the trace's serve track
    tracer: Any = None
    # optional repro.serve.fleet.prefix.PrefixCache over this engine's pool
    # (attach via enable_prefix_cache(); attn-only archs, n_prefix == 0)
    prefix_cache: Any = None

    def __post_init__(self):
        if self.attn_kernel not in ("xla", "pallas"):
            raise ValueError(f"unknown attn_kernel {self.attn_kernel!r}")
        self.pool = PagedKVPool(
            self.cfg, self.n_slots, self.max_len, self.page, self.num_pages
        )
        # sampling with temperature needs host-side logits; greedy decode
        # on the pallas path samples inside the decode dispatch
        self._fused_sample = (
            self.attn_kernel == "pallas" and self.temperature <= 0.0
        )
        self._prefill = jax.jit(partial(_prefill, self.cfg))
        self._decode = jax.jit(make_paged_decode_step(
            self.cfg, self.attn_kernel, self._fused_sample
        ))
        self._join = jax.jit(make_join_step(self.cfg))
        self._clone = jax.jit(make_clone_pages(self.cfg))
        m = self.pool.max_pages_per_req
        self._table = np.full((self.n_slots, m), SCRATCH_PAGE, np.int32)
        self._lengths = np.zeros((self.n_slots,), np.int32)
        self._tokens = np.zeros((self.n_slots,), np.int32)
        # slot -> deque of prompt-suffix tokens still to force-decode after
        # a prefix-cache join (no sampling/appending until drained)
        self._forced: Dict[int, collections.deque] = {}

    def enable_prefix_cache(self, max_pages: Optional[int] = None):
        """Attach a :class:`~repro.serve.fleet.prefix.PrefixCache` to the pool.

        Only attention K/V lives in shareable pages: recurrent state
        (SSM/RG-LRU) is per-slot and position-dependent, and frontend
        prefixes occupy positions the trie cannot key — so prefix reuse is
        restricted to all-attention archs with ``n_prefix == 0``.
        """
        if self.cfg.n_prefix:
            raise ValueError("prefix cache requires n_prefix == 0 "
                             "(frontend prefixes are not token-addressable)")
        if any(k != "attn" for k in self.cfg.pattern):
            raise ValueError("prefix cache requires an all-attention arch "
                             "(recurrent state is per-slot, not paged)")
        from repro.serve.fleet.prefix import PrefixCache
        self.prefix_cache = PrefixCache(self.pool, max_pages=max_pages)
        return self.prefix_cache

    # ---- request lifecycle ----------------------------------------------
    def _join_request(self, req: Request) -> None:
        m = req.prefix_match
        if m is not None and m.n_tokens > 0:
            self._join_via_prefix(req)
            return
        cfg = self.cfg
        prompt = np.asarray(req.prompt, np.int32)
        total = len(prompt) + cfg.n_prefix
        n_used = self.pool.pages_needed(total)
        lpad = n_used * self.pool.page
        if cfg.attention in ("swa", "local") and cfg.window and lpad > cfg.window:
            raise ValueError(
                f"paged serving stores positions linearly: prompt pages {lpad} "
                f"must fit the attention window {cfg.window}"
            )
        batch: Dict[str, Any] = {"tokens": jnp.asarray(prompt[None])}
        if req.prefix_embeds is not None:
            batch["prefix_embeds"] = jnp.asarray(np.asarray(req.prefix_embeds)[None])
        cache = init_cache(cfg, 1, lpad)
        logits, cache = self._prefill(self.params, batch, cache)
        req.pages = self.pool.alloc(req.rid, n_used)
        slot = req.slot
        self._table[slot] = SCRATCH_PAGE
        self._table[slot, :n_used] = req.pages
        self.pool.blocks = self._join(
            self.pool.blocks, cache, jnp.asarray(req.pages, jnp.int32), jnp.int32(slot)
        )
        tok = int(self._select_one(logits[0], req))
        req.out.append(tok)
        self._lengths[slot] = total
        self._tokens[slot] = tok

    def _join_via_prefix(self, req: Request) -> None:
        """Join without prefill: resident pages cover ``m.n_tokens`` prompt
        positions, the remaining suffix is replayed through the paged decode
        step as *forced* tokens (exact K/V, no sampling) — first sampled
        token only lands once the suffix drains."""
        m = req.prefix_match
        prompt = np.asarray(req.prompt, np.int32)
        pages = list(m.full_pages)
        if m.partial_page is not None:
            # copy-on-write: this request extends the half-filled page in
            # place, so it writes into a private clone while other referents
            # keep reading the shared original
            (pid,) = self.pool.alloc(req.rid, 1)
            self.pool.blocks = self._clone(
                self.pool.blocks, jnp.int32(m.partial_page), jnp.int32(pid)
            )
            pages.append(pid)
        req.pages = pages
        slot = req.slot
        self._table[slot] = SCRATCH_PAGE
        self._table[slot, :len(pages)] = pages
        self._lengths[slot] = m.n_tokens              # next write position
        self._tokens[slot] = int(prompt[m.n_tokens])  # next input token
        rest = prompt[m.n_tokens + 1:]
        if len(rest):
            self._forced[slot] = collections.deque(int(t) for t in rest)

    def _select_one(self, logits, req: Request) -> int:
        if self.temperature <= 0.0 or req.key is None:
            return int(jnp.argmax(logits))
        sub = jax.random.fold_in(req.key, req.n_generated)
        return int(jax.random.categorical(sub, logits / self.temperature))

    def _grow_pages(self, req: Request) -> None:
        pos = int(self._lengths[req.slot])
        while pos // self.pool.page >= len(req.pages):
            (pid,) = self.pool.alloc(req.rid, 1)
            self._table[req.slot, len(req.pages)] = pid
            req.pages.append(pid)

    def _retire(self, req: Request, sched: Scheduler, slo, now: float) -> None:
        if slo is not None:
            slo.on_finish(req, now)
        else:
            req.t_done = now
        if self.tracer is not None:
            self.tracer.serve_event("evict", now, req.rid, req.slot)
        drained = self._forced.pop(req.slot, None) is None
        if self.prefix_cache is not None and req.pages and drained:
            # adopt this request's written pages into the resident trie:
            # positions 0..lengths-1 hold K/V of prompt + out[:-1] (the last
            # sampled token was never decoded, so its K/V was never written)
            n_written = int(self._lengths[req.slot])
            tokens = np.concatenate([
                np.asarray(req.prompt, np.int64), np.asarray(req.out, np.int64)
            ])[:n_written]
            self.prefix_cache.insert(tokens, req.pages)
        self._table[req.slot] = SCRATCH_PAGE
        self._tokens[req.slot] = 0
        self._lengths[req.slot] = 0
        sched.release(req)

    # ---- driving loop ----------------------------------------------------
    def serve(
        self,
        requests: List[Request],
        governor=None,
        slo=None,
        max_steps: int = 100_000,
    ) -> List[Request]:
        """Run all requests to completion; returns them with outputs filled.

        Arrival offsets are honored against a wall clock started at call
        time; idle waits and per-step underfill are published as
        :class:`~repro.core.events.PhaseRecord` phases to ``governor`` — a
        :class:`repro.core.governor.Governor` or an
        :class:`~repro.core.events.EventBus` with any subscriber set —
        when given.
        """
        sess = EngineSession(self, governor=governor, slo=slo)
        for r in requests:
            sess.submit(r)
        steps = 0
        while not sess.done:
            sess.admit()
            if sess.n_active == 0:
                if not sess.sleep_until_next():
                    break
                continue
            sess.decode_step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError(f"serve() exceeded {max_steps} decode steps")
        return sess.finished

    # ---- ServeEngine-compatible entry point ------------------------------
    def generate(
        self,
        batch: Dict[str, Any],
        n_steps: int,
        key: Optional[jax.Array] = None,
    ) -> jnp.ndarray:
        """Static-batch compatibility: all rows arrive at t=0, run to n_steps.

        Greedy output matches ``ServeEngine.generate`` token for token.
        (Sampled output uses per-request keys — ``fold_in(key, row)`` —
        rather than the legacy shared per-step key.)
        """
        tokens = np.asarray(batch["tokens"])
        b = tokens.shape[0]
        if b > self.n_slots:
            raise ValueError(f"batch {b} exceeds n_slots {self.n_slots}")
        reqs = []
        for i in range(b):
            req = Request(
                prompt=tokens[i], max_new=n_steps, arrival=0.0,
                key=None if key is None else jax.random.fold_in(key, i),
            )
            if "prefix_embeds" in batch:
                req.prefix_embeds = np.asarray(batch["prefix_embeds"][i])
            reqs.append(req)
        order = {r.rid: i for i, r in enumerate(reqs)}
        done = sorted(self.serve(reqs), key=lambda r: order[r.rid])
        return jnp.asarray(
            np.stack([np.asarray(r.out[:n_steps], np.int32) for r in done])
        )


# --------------------------------------------------------------------------
# step-granular session (fleet driver entry point)
# --------------------------------------------------------------------------

class EngineSession:
    """One engine's serving loop, exposed a step at a time.

    ``ContinuousEngine.serve`` is this session driven to completion; the
    fleet driver instead interleaves N sessions — submit routed requests,
    ``admit()`` + ``decode_step()`` each replica in turn, and only
    ``sleep_until_next()`` when *every* replica is idle.  All timestamps
    are relative to ``t_start`` (shareable across a fleet so SLO clocks
    agree).
    """

    def __init__(self, engine: "ContinuousEngine", governor=None, slo=None,
                 t_start: Optional[float] = None):
        self.engine = engine
        self.slo = slo
        self.sched = Scheduler(
            engine.pool, engine.n_slots, n_prefix=engine.cfg.n_prefix,
            slo=slo, prefix_cache=engine.prefix_cache,
        )
        self.meter = DecodeSlackMeter(governor) if governor is not None else None
        engine._last_meter = self.meter
        self.finished: List[Request] = []
        self.t_start = time.monotonic() if t_start is None else t_start
        self.steps = 0

    # ---- clock -----------------------------------------------------------
    def now(self) -> float:
        return time.monotonic() - self.t_start

    # ---- queue state -----------------------------------------------------
    @property
    def done(self) -> bool:
        return self.sched.done

    @property
    def n_active(self) -> int:
        return self.sched.n_active

    @property
    def n_queued(self) -> int:
        return self.sched.n_queued

    def next_arrival(self) -> Optional[float]:
        return self.sched.next_arrival()

    def fill_fraction(self) -> float:
        return self.sched.n_active / max(self.engine.n_slots, 1)

    # ---- lifecycle -------------------------------------------------------
    def submit(self, req: Request) -> None:
        eng = self.engine
        if eng.cfg.n_prefix and req.prefix_embeds is None:
            # without the prefix, positions [S, S+n_prefix) would never be
            # written and the page mask (unlike the dense slot_pos mask)
            # would attend their zero K/V — refuse up front
            raise ValueError(
                f"arch {eng.cfg.name!r} has n_prefix={eng.cfg.n_prefix}: "
                f"request {req.rid} must carry prefix_embeds"
            )
        self.sched.submit(req)

    def admit(self, now: Optional[float] = None) -> List[Request]:
        """Join every arrived request that fits; returns the joins."""
        eng = self.engine
        joins = self.sched.admit(self.now() if now is None else now)
        for req in joins:
            eng._join_request(req)
            tnow = self.now()
            if eng.tracer is not None:
                eng.tracer.serve_event("join", tnow, req.rid, req.slot)
            if req.out:
                # prefill joins produce the first token immediately; prefix
                # joins stay silent until the forced suffix drains
                if self.slo is not None:
                    self.slo.on_first_token(req, tnow)
                else:
                    req.t_first = req.t_prev = tnow
            if not req.wants_more():
                eng._retire(req, self.sched, self.slo, tnow)
                self.finished.append(req)
        return joins

    def sleep_until_next(self) -> bool:
        """Idle until the next arrival (metered); False when queue is empty."""
        nxt = self.sched.next_arrival()
        if nxt is None:
            return False
        t0 = time.monotonic()
        wait = (self.t_start + nxt) - t0
        if wait > 0:
            time.sleep(wait)
        t1 = time.monotonic()
        self.note_idle(t0, t1)
        return True

    def note_idle(self, t0: float, t1: float) -> None:
        if self.meter is not None and t1 > t0:
            self.meter.idle(t0, t1)

    def decode_step(self) -> None:
        """One batched decode step over all active slots."""
        eng = self.engine
        sched = self.sched
        for req in sched.active.values():
            eng._grow_pages(req)
        # clamp the table to the live pages: no request's K/V extends past
        # ceil((max_pos + 1) / page) pages, so neither the XLA gather nor
        # the pallas grid should pay O(max_len) per token.  (Each distinct
        # width is its own jit bucket — widths only grow, and there are at
        # most max_pages_per_req of them.)
        max_pos = int(eng._lengths.max())
        m_live = min(eng._table.shape[1], max_pos // eng.pool.page + 1)
        t0 = time.monotonic()
        out, blocks = eng._decode(
            eng.params,
            jnp.asarray(eng._tokens),
            jnp.asarray(eng._lengths),
            jnp.asarray(eng._table[:, :m_live]),
            eng.pool.blocks,
        )
        out = jax.block_until_ready(out)
        t1 = time.monotonic()
        eng.pool.blocks = blocks
        if self.meter is not None:
            self.meter.step(t0, t1, sched.n_active, eng.n_slots)
        if eng._fused_sample:
            logits, greedy = None, np.asarray(out, np.int32)
        else:
            logits = out
            greedy = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        tnow = self.now()
        for slot, req in list(sched.active.items()):
            eng._lengths[slot] += 1
            forced = eng._forced.get(slot)
            if forced:
                # prompt-suffix replay after a prefix join: exact K/V was
                # just written for the fed token, next one goes in verbatim
                eng._tokens[slot] = forced.popleft()
                if not forced:
                    del eng._forced[slot]
                continue
            if eng.temperature <= 0.0 or req.key is None:
                tok = int(greedy[slot])
            else:
                tok = eng._select_one(logits[slot], req)
            first = not req.out
            req.out.append(tok)
            eng._tokens[slot] = tok
            if self.slo is not None:
                if first:
                    self.slo.on_first_token(req, tnow)
                else:
                    self.slo.on_token(req, tnow)
            else:
                if first:
                    req.t_first = tnow
                req.t_prev = tnow
            if not req.wants_more():
                eng._retire(req, sched, self.slo, tnow)
                self.finished.append(req)
        self.steps += 1
