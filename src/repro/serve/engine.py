"""Batched serving engine: prefill/decode step builders + a simple scheduler.

``make_serve_steps`` produces the jit-able ``prefill_step`` and
``decode_step`` the dry-run lowers for the ``prefill_*`` / ``decode_*`` /
``long_*`` shape cells.  ``ServeEngine`` drives real batched generation on
this container (greedy or temperature sampling) for the examples/tests.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.transformer import decode_step as _decode
from repro.models.transformer import init_cache, prefill as _prefill


def make_serve_steps(cfg) -> Tuple[Callable, Callable]:
    """Returns (prefill_step(params, batch, cache), decode_step(params, token, pos, cache))."""

    def prefill_step(params, batch, cache):
        return _prefill(cfg, params, batch, cache)

    def decode_step(params, token, pos, cache):
        return _decode(cfg, params, token, pos, cache)

    return prefill_step, decode_step


@dataclass
class ServeEngine:
    cfg: Any
    params: Any
    max_len: int
    temperature: float = 0.0

    def __post_init__(self):
        self._prefill, self._decode = make_serve_steps(self.cfg)
        self._prefill = jax.jit(self._prefill)
        self._decode = jax.jit(self._decode)

    def generate(
        self,
        batch: Dict[str, Any],
        n_steps: int,
        key: Optional[jax.Array] = None,
    ) -> jnp.ndarray:
        """Greedy/sampled continuation of ``batch['tokens']`` for n_steps."""
        b, s = batch["tokens"].shape
        prompt_len = s + self.cfg.n_prefix
        cache = init_cache(self.cfg, b, self.max_len)
        logits, cache = self._prefill(self.params, batch, cache)
        out = []
        tok = self._select(logits, key, 0)
        out.append(tok)
        for i in range(1, n_steps):
            logits, cache = self._decode(
                self.params, tok, jnp.int32(prompt_len + i - 1), cache
            )
            tok = self._select(logits, key, i)
            out.append(tok)
        return jnp.stack(out, axis=1)                          # (B, n_steps)

    def _select(self, logits, key, i):
        if self.temperature <= 0.0 or key is None:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        sub = jax.random.fold_in(key, i)
        return jax.random.categorical(sub, logits / self.temperature).astype(jnp.int32)
