"""Block-paged KV cache pool for continuous-batching serving.

The serving analogue of the paper's slack isolation needs decode batches
that stay full, and decode batches stay full only if KV memory is handed
out in small reclaimable units instead of one max-length strip per slot.
This module provides exactly that:

* :class:`PagedKVPool` — one physical pool of fixed-size pages per
  attention layer (``k_pages``/``v_pages``: ``(n_pages, page, Hkv, D)``,
  int8 + per-(token, head) scale pages when ``cfg.kv_quant``, reusing the
  ``_kv_quantize`` path from :mod:`repro.models.layers`), a host-side
  free-list allocator with *reservations* (admission control books the
  worst-case page need up front, physical pages are allocated lazily, so
  a lazily-grown request can never hit an empty free list), and
  per-request page tables.  Page id 0 is the scratch page: idle decode
  slots write into it and nothing ever reads it.
* ``paged_attention_decode`` — single-token decode attention over the
  pool: scatter the new K/V into ``table[b, pos // page]``, gather the
  request's pages back into a ``(B, T, Hkv, D)`` view (the gather *is*
  the KV read every decode step pays anyway), and run the same
  fp32-accumulation attention as ``layers.attention_decode`` with a
  per-request validity mask — so a single request matches the dense-cache
  engine token for token.

Recurrent state (SSM / RG-LRU blocks) is O(1) per request and is *not*
paged: the pool keeps a per-slot state tree next to the page arrays.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import rglru as R
from repro.models import ssm as S
from repro.models.layers import NEG_INF, _kv_dequantize, _kv_quantize, _project_qkv
from repro.models.transformer import stack_layout

Params = Dict[str, Any]

SCRATCH_PAGE = 0          # page id reserved for idle slots; never read


def rope_at(x: jnp.ndarray, pos: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Per-request RoPE for single-token decode.  x: (B,1,H,D); pos: (B,)."""
    d = x.shape[-1]
    freqs = L.rope_frequencies(d, theta)                       # (D/2,)
    angles = pos[:, None].astype(jnp.float32) * freqs          # (B, D/2)
    cos = jnp.cos(angles)[:, None, None, :]
    sin = jnp.sin(angles)[:, None, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# device-side pool construction (mirrors transformer.init_cache structure)
# --------------------------------------------------------------------------

def _attn_page_block(cfg, num_pages: int, page: int, dtype) -> Params:
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    kv_dtype = jnp.int8 if cfg.kv_quant else dtype
    block = {
        "k_pages": jnp.zeros((num_pages, page, hkv, hd), kv_dtype),
        "v_pages": jnp.zeros((num_pages, page, hkv, hd), kv_dtype),
    }
    if cfg.kv_quant:
        block["k_scale_pages"] = jnp.zeros((num_pages, page, hkv), jnp.float32)
        block["v_scale_pages"] = jnp.zeros((num_pages, page, hkv), jnp.float32)
    return block


def _block_pool(cfg, kind: str, num_pages: int, page: int, n_slots: int, dtype) -> Params:
    if kind == "attn":
        return _attn_page_block(cfg, num_pages, page, dtype)
    if kind == "ssm":
        return S.init_ssm_state(cfg, n_slots, dtype)
    if kind == "rglru":
        return R.init_rglru_state(cfg, n_slots, dtype)
    raise ValueError(kind)


def init_pool_blocks(cfg, num_pages: int, page: int, n_slots: int) -> Params:
    """Device tree mirroring ``init_cache``: {"stack": {j: block}, "rem": ...}.

    Attention blocks hold shared page arrays; SSM/RG-LRU blocks hold
    per-slot recurrent state.  Stacked entries carry the scan layer axis.
    """
    dtype = L.dtype_of(cfg.compute_dtype)
    n_full, rem_kinds = stack_layout(cfg)
    proto = {
        str(j): _block_pool(cfg, kind, num_pages, page, n_slots, dtype)
        for j, kind in enumerate(cfg.pattern)
    }
    stack = jax.tree.map(lambda a: jnp.tile(a[None], (n_full,) + (1,) * a.ndim), proto)
    blocks: Params = {"stack": stack}
    if rem_kinds:
        blocks["rem"] = {
            str(j): _block_pool(cfg, kind, num_pages, page, n_slots, dtype)
            for j, kind in enumerate(rem_kinds)
        }
    return blocks


# --------------------------------------------------------------------------
# paged decode attention
# --------------------------------------------------------------------------

def paged_attention_decode(cfg, p, x, pos, table, block, kernel: str = "xla"):
    """Single-token attention over paged KV.

    x: (B,1,d); pos: (B,) int32 write positions (the new token's absolute
    position per request); table: (B, M) int32 page table (0 = scratch);
    block: one attention page block.  Returns (out (B,1,d), new block).

    ``kernel`` selects the hot path: ``"xla"`` scatters with
    ``.at[].set()`` and gathers a contiguous ``(B, M*page, Hkv, D)`` view
    (the reference oracle — callers bound its cost by passing a table
    clamped to the live pages); ``"pallas"`` routes through
    :mod:`repro.kernels.ops` — one fused dispatch whose prologue lands
    the new K/V row in its page (aliased, in place) and whose body walks
    the page table block-by-block, with int8 dequant fused into the page
    loads.  Both paths quantize the new token's K/V in XLA first, so the
    *stored* pages are bit-identical.
    """
    b = x.shape[0]
    page = block["k_pages"].shape[1]
    m = table.shape[1]
    q, k, v = _project_qkv(cfg, p, x)                          # (B,1,H*,D)
    q = rope_at(q, pos, cfg.rope_theta)
    k = rope_at(k, pos, cfg.rope_theta)

    page_idx = table[jnp.arange(b), jnp.minimum(pos // page, m - 1)]  # (B,)
    off = pos % page
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    window = cfg.window if cfg.attention in ("swa", "local") and cfg.window else 0
    new_block = dict(block)
    if cfg.kv_quant:
        kq, k_sc = _kv_quantize(k)                             # (B,1,H,D),(B,1,H)
        vq, v_sc = _kv_quantize(v)
        k, v = kq, vq

    if kernel == "pallas":
        from repro.kernels import ops as pallas_ops

        qg = L._gqa_reshape(q, hkv)[:, 0]                      # (B,Hkv,G,D)
        if cfg.kv_quant:
            out, (kp, vp, ksp, vsp) = pallas_ops.paged_attention_scatter_quant(
                qg, k[:, 0], v[:, 0], k_sc[:, 0], v_sc[:, 0],
                block["k_pages"], block["v_pages"],
                block["k_scale_pages"], block["v_scale_pages"],
                table, pos, page_idx, off, window=window,
            )
            new_block.update(k_pages=kp, v_pages=vp,
                             k_scale_pages=ksp, v_scale_pages=vsp)
        else:
            out, (kp, vp) = pallas_ops.paged_attention_scatter(
                qg, k[:, 0], v[:, 0], block["k_pages"], block["v_pages"],
                table, pos, page_idx, off, window=window,
            )
            new_block.update(k_pages=kp, v_pages=vp)
        out = out.astype(x.dtype).reshape(b, 1, cfg.n_heads * hd) @ p["wo"]
        return out, new_block
    if kernel != "xla":
        raise ValueError(f"unknown attention kernel {kernel!r}")

    if cfg.kv_quant:
        new_block["k_scale_pages"] = block["k_scale_pages"].at[page_idx, off].set(k_sc[:, 0])
        new_block["v_scale_pages"] = block["v_scale_pages"].at[page_idx, off].set(v_sc[:, 0])
    new_block["k_pages"] = block["k_pages"].at[page_idx, off].set(k[:, 0])
    new_block["v_pages"] = block["v_pages"].at[page_idx, off].set(v[:, 0])

    # gather this batch's logical KV views: (B, M, page, H, D) -> (B, T, H, D)
    t = m * page
    ck = new_block["k_pages"][table].reshape(b, t, hkv, hd)
    cv = new_block["v_pages"][table].reshape(b, t, hkv, hd)
    if cfg.kv_quant:
        k_sc = new_block["k_scale_pages"][table].reshape(b, t, hkv)
        v_sc = new_block["v_scale_pages"][table].reshape(b, t, hkv)
        ck = _kv_dequantize(ck, k_sc, x.dtype)
        cv = _kv_dequantize(cv, v_sc, x.dtype)

    qg = L._gqa_reshape(q, hkv)                                # (B,1,Hkv,G,D)
    s = jnp.einsum("bqkgd,btkd->bqkgt", qg, ck, preferred_element_type=jnp.float32)
    s *= 1.0 / math.sqrt(hd)
    k_pos = jnp.arange(t, dtype=jnp.int32)
    valid = k_pos[None, :] <= pos[:, None]                     # (B, T)
    if window:
        valid &= k_pos[None, :] > pos[:, None] - window
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    prob = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bqkgt,btkd->bqkgd", prob.astype(cv.dtype), cv,
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)
    out = out.reshape(b, 1, cfg.n_heads * cfg.head_dim) @ p["wo"]
    return out, new_block


def make_clone_pages(cfg) -> "Any":
    """clone(blocks, src (int32), dst (int32)) -> blocks with page dst a copy
    of page src in every attention leaf.

    The copy-on-write primitive for prefix sharing: a joiner extending a
    *partially filled* cached page writes into fresh storage while every
    other referent keeps reading the original.  Recurrent-state blocks are
    per-slot, not paged, so only attention leaves participate (the prefix
    cache is gated to attention-only archs anyway).
    """

    page_leaves = ("k_pages", "v_pages", "k_scale_pages", "v_scale_pages")

    def clone_block(block, src, dst, *, stacked: bool):
        new = dict(block)
        for name in page_leaves:
            if name not in block:
                continue
            leaf = block[name]
            if stacked:
                new[name] = leaf.at[:, dst].set(leaf[:, src])
            else:
                new[name] = leaf.at[dst].set(leaf[src])
        return new

    def clone(blocks, src, dst):
        new_stack = {}
        for j, kind in enumerate(cfg.pattern):
            b = blocks["stack"][str(j)]
            new_stack[str(j)] = (
                clone_block(b, src, dst, stacked=True) if kind == "attn" else b
            )
        out: Params = {"stack": new_stack}
        if "rem" in blocks:
            _, rem_kinds = stack_layout(cfg)
            out["rem"] = {}
            for j, kind in enumerate(rem_kinds):
                b = blocks["rem"][str(j)]
                out["rem"][str(j)] = (
                    clone_block(b, src, dst, stacked=False) if kind == "attn" else b
                )
        return out

    return clone


def scatter_prefill_attn(block, cache_block, page_ids, *, stacked: bool):
    """Scatter a contiguous prefill cache into pool pages.

    cache_block leaves come from ``transformer.prefill`` with batch 1 and
    a linear layout of ``n_used * page`` positions; ``page_ids``:
    (n_used,) int32 physical destinations.  ``stacked`` marks entries
    under the scan layer axis (leaves lead with n_full).
    """
    page = block["k_pages"].shape[-3]
    n_used = page_ids.shape[0]
    new = dict(block)
    pairs = [("k", "k_pages"), ("v", "v_pages")]
    if "k_scale_pages" in block:
        pairs += [("k_scale", "k_scale_pages"), ("v_scale", "v_scale_pages")]
    for name, pname in pairs:
        if stacked:
            leaf = cache_block[name][:, 0]                     # (n_full, Lpad, ...)
            chunks = leaf.reshape(leaf.shape[0], n_used, page, *leaf.shape[2:])
            new[pname] = block[pname].at[:, page_ids].set(
                chunks.astype(block[pname].dtype)
            )
        else:
            leaf = cache_block[name][0]                        # (Lpad, ...)
            chunks = leaf.reshape(n_used, page, *leaf.shape[1:])
            new[pname] = block[pname].at[page_ids].set(chunks.astype(block[pname].dtype))
    return new


# --------------------------------------------------------------------------
# host-side pool accounting
# --------------------------------------------------------------------------

class PagedKVPool:
    """Fixed-size page pool: refcounted free-list + admission reservations.

    ``reserve`` is the admission-control primitive: it books a request's
    *worst-case* page need against the pool; ``alloc`` then hands out
    physical pages lazily (prefill pages at join, one page per crossed
    boundary during decode).  Because allocations never exceed the sum of
    reservations, lazy growth can never fail after admission succeeded.
    ``release`` drops one reference per attached page on completion
    (evict-on-EOS); a page returns to the free list only when its last
    referent lets go.

    Prefix sharing adds two reference paths on top of ``alloc``'s owning
    reference: :meth:`share` attaches an *existing* page to another
    request (refcount +1, no free-list traffic), and :meth:`retain` /
    :meth:`unretain` let a resident :class:`~repro.serve.fleet.prefix.
    PrefixCache` keep pages alive after their writer finished.  When a
    reservation cannot be met, the optional ``on_pressure`` hook (the
    cache's LRU evictor) is asked to surrender resident pages before
    admission fails.

    ``materialize=False`` skips building the device arrays — the fleet
    simulator runs thousands of admission/join/evict decisions through the
    *real* accounting (this class, the scheduler, the prefix cache)
    without paying for KV storage it never reads.
    """

    def __init__(self, cfg, n_slots: int, max_len: int, page: int = 16,
                 num_pages: Optional[int] = None, materialize: bool = True):
        if max_len % page:
            raise ValueError(f"max_len {max_len} must be a multiple of page {page}")
        self.cfg = cfg
        self.page = page
        self.n_slots = n_slots
        self.max_len = max_len
        self.max_pages_per_req = max_len // page
        # +1 for the scratch page idle slots write into
        self.num_pages = num_pages or n_slots * self.max_pages_per_req + 1
        if self.num_pages < 2:
            raise ValueError("pool needs at least one non-scratch page")
        self._free: List[int] = list(range(self.num_pages - 1, SCRATCH_PAGE, -1))
        self._reserved: Dict[Any, int] = {}    # rid -> pages still reservable
        self._allocated: Dict[Any, List[int]] = {}
        self._ref: Dict[int, int] = {}         # page id -> reference count
        # asked to free >= n resident pages; returns how many it freed
        self.on_pressure: Optional[Any] = None
        self.blocks = (
            init_pool_blocks(cfg, self.num_pages, page, n_slots)
            if materialize else None
        )

    # ---- accounting ------------------------------------------------------
    def pages_needed(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page)

    @property
    def capacity_pages(self) -> int:
        return self.num_pages - 1

    @property
    def free_pages(self) -> int:
        """Pages available to NEW reservations (free minus outstanding IOUs)."""
        outstanding = sum(self._reserved.values())
        return len(self._free) - outstanding

    @property
    def utilization(self) -> float:
        in_use = self.capacity_pages - len(self._free)
        return in_use / max(self.capacity_pages, 1)

    def refcount(self, page_id: int) -> int:
        return self._ref.get(page_id, 0)

    def can_admit(self, n_tokens: int) -> bool:
        return self.pages_needed(n_tokens) <= self.free_pages

    def reserve(self, rid, n_tokens: int) -> bool:
        return self.reserve_pages(rid, self.pages_needed(n_tokens))

    def reserve_pages(self, rid, need: int) -> bool:
        """Book ``need`` physical pages for ``rid`` (prefix-aware admission
        reserves only the *unshared* remainder).  Under pressure the
        resident-prefix evictor is asked to free pages before giving up."""
        if need > self.capacity_pages:
            raise ValueError(
                f"request {rid!r} needs {need} pages, pool holds {self.capacity_pages}"
            )
        if need > self.free_pages and self.on_pressure is not None:
            self.on_pressure(need - self.free_pages)
        if need > self.free_pages:
            return False
        self._reserved[rid] = need
        self._allocated[rid] = []
        return True

    def alloc(self, rid, n: int = 1) -> List[int]:
        if self._reserved.get(rid, 0) < n:
            raise RuntimeError(f"request {rid!r} exceeded its page reservation")
        ids = [self._free.pop() for _ in range(n)]
        self._reserved[rid] -= n
        self._allocated[rid].extend(ids)
        for pid in ids:
            self._ref[pid] = 1
        return ids

    def share(self, rid, page_ids: List[int]) -> None:
        """Attach already-allocated pages to ``rid`` (prefix reuse): one
        reference each, released with the rest of ``rid``'s pages."""
        if rid not in self._allocated:
            raise RuntimeError(f"request {rid!r} has no reservation to share into")
        for pid in page_ids:
            if self._ref.get(pid, 0) <= 0:
                raise RuntimeError(f"page {pid} is not live; cannot share")
            self._ref[pid] += 1
        self._allocated[rid].extend(page_ids)

    def retain(self, page_ids: List[int]) -> None:
        """Anonymous reference (prefix-cache residency): keeps pages out of
        the free list after their writer releases."""
        for pid in page_ids:
            if self._ref.get(pid, 0) <= 0:
                raise RuntimeError(f"page {pid} is not live; cannot retain")
            self._ref[pid] += 1

    def unretain(self, page_ids: List[int]) -> None:
        for pid in page_ids:
            self._drop_ref(pid)

    def _drop_ref(self, pid: int) -> None:
        n = self._ref.get(pid, 0)
        if n <= 0:
            raise RuntimeError(f"double free of page {pid}")
        if n == 1:
            del self._ref[pid]
            self._free.append(pid)
        else:
            self._ref[pid] = n - 1

    def release(self, rid) -> None:
        for pid in reversed(self._allocated.pop(rid, [])):
            self._drop_ref(pid)
        self._reserved.pop(rid, None)
