"""Elastic device mesh + deterministic failure injection.

The recovery model is *mesh epochs* (see launch/train.py): training runs
under one mesh until a device fails; the driver then drains in-flight work,
marks the device failed on the :class:`ElasticMesh`, rebuilds a (smaller)
mesh from the survivors, restores the latest checkpoint and resumes.  This
is the 1000-node recovery path scaled down to whatever this host has — the
mesh factory, sharding rules and checkpoint protocol are identical at both
scales.

:class:`FailureInjector` drives the same path deterministically in tests
and demos: each configured (step, device) failure fires exactly once, so a
resume that replays the failing step does not re-fail forever.
"""
from __future__ import annotations

import itertools
from typing import List, Optional, Sequence, Tuple

import jax
import numpy as np


class ElasticMesh:
    """A device mesh factory that remembers failed devices across rebuilds.

    ``build(model_parallel=k)`` lays the surviving devices out as a
    (data, model) mesh with the largest model-parallel degree <= k that
    divides the survivor count (model parallelism degrades gracefully as
    devices die rather than refusing to build).
    """

    def __init__(self, axis_names: Sequence[str] = ("data", "model")):
        if len(axis_names) != 2:
            raise ValueError("ElasticMesh lays devices out over exactly two axes")
        self.axis_names: Tuple[str, ...] = tuple(axis_names)
        self._failed: set = set()

    def healthy_devices(self) -> List[jax.Device]:
        return [d for d in jax.devices() if d.id not in self._failed]

    def fail(self, device_id: int) -> None:
        """Mark a device as failed; it is excluded from every later build."""
        self._failed.add(int(device_id))

    def failed_ids(self) -> List[int]:
        return sorted(self._failed)

    def build(self, model_parallel: int = 1):
        devs = self.healthy_devices()
        n = len(devs)
        if n == 0:
            raise RuntimeError("ElasticMesh: no healthy devices left to build from")
        mp = max(g for g in range(1, min(model_parallel, n) + 1) if n % g == 0)
        grid = np.empty((n // mp, mp), dtype=object)
        for i, d in enumerate(devs):
            grid[i // mp, i % mp] = d
        return jax.sharding.Mesh(grid, self.axis_names)


class FailureInjector:
    """Deterministic one-shot device failures at configured steps.

    ``check(step)`` returns the failing device id the first time ``step``
    matches a configured failure, and ``None`` otherwise.  Each failure is
    consumed when it fires — after recovery rewinds the step counter to the
    last checkpoint, replaying the same step does not re-kill the device.
    """

    def __init__(self, fail_at_steps: Sequence[int], device_ids: Sequence[int]):
        if fail_at_steps and not device_ids:
            raise ValueError("fail_at_steps given but no device_ids to fail")
        self._pending = dict(zip(fail_at_steps, itertools.cycle(device_ids))) \
            if fail_at_steps else {}

    def check(self, step: int) -> Optional[int]:
        return self._pending.pop(step, None)

    def pending(self) -> dict:
        return dict(self._pending)
