"""Version shims over jax APIs that moved between releases.

The drivers target the current jax spelling (``jax.set_mesh``,
``jax.shard_map(..., axis_names=...)``); the pinned container ships an older
jax where the same functionality lives under ``with mesh:`` and
``jax.experimental.shard_map.shard_map(..., auto=...)``.  Call sites import
from here so the rest of the codebase stays version-agnostic.
"""
from __future__ import annotations

from typing import Any, Callable, Iterable

import jax

# True when this jax predates the top-level ``jax.shard_map`` API.  On these
# versions the XLA bundled with jaxlib hard-aborts (Check failed:
# sharding.IsManualSubgroup()) when a ``lax.scan`` carries auto-sharded
# operands inside a *partial-manual* shard_map region; callers consult this
# flag to unroll scans in such regions (see train.loop.make_pod_train_step).
LEGACY_PARTIAL_MANUAL = not hasattr(jax, "shard_map")


def set_mesh(mesh) -> Any:
    """Context manager installing ``mesh`` as the ambient mesh."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    # jax 0.4.x: Mesh is itself a context manager with the same effect.
    return mesh


def shard_map(
    f: Callable,
    *,
    mesh,
    in_specs,
    out_specs,
    manual_axes: Iterable[str],
) -> Callable:
    """``shard_map`` with ``manual_axes`` manual and every other mesh axis
    left to the auto partitioner (the partial-manual pod-reduction pattern).
    """
    manual = frozenset(manual_axes)
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False, axis_names=set(manual),
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    auto = frozenset(mesh.axis_names) - manual
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=False, auto=auto,
    )
