"""int8 gradient compression for cross-pod reductions.

The cross-pod (DCN) hop is the slowest wire in a multi-pod system — exactly
where the paper finds the longest slack.  ``compressed_psum`` cuts that wire
4x by quantizing each gradient leaf to int8 with one per-leaf fp32 scale,
all-gathering the (int8, scale) pairs over the axis, and dequantize-summing
locally.  The gather goes through the COUNTDOWN-instrumented
``cd_all_gather``, so the artificial barrier + slack accounting apply to the
compressed path too (the energy story and the bandwidth story compose).

Quantization is symmetric round-to-nearest at ``scale = max|g| / 127``:
the roundtrip error per element is at most ``scale / 2`` (1/2 LSB), which
is enforced by a property test.  Gradient *sums* stay exact in fp32 after
dequantization; only the per-pod representation is lossy.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.instrument import (
    AsyncCollective, cd_all_gather, cd_all_gather_async, cd_wait,
)

AxisNames = Any


def _quantize(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """g -> (int8 codes, fp32 scale) with |codes * scale - g| <= scale/2."""
    g32 = jnp.asarray(g).astype(jnp.float32)
    scale = jnp.max(jnp.abs(g32)) / 127.0
    scale = jnp.maximum(scale, jnp.float32(1e-30))
    q = jnp.clip(jnp.round(g32 / scale), -127.0, 127.0).astype(jnp.int8)
    return q, scale


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def _dequantize_sum(flat, treedef, gathered, mean: bool) -> Any:
    """Dequantize the gathered (codes, scales) pairs and reduce in fp32."""
    n_leaf = len(flat)
    codes, scales = gathered[:n_leaf], gathered[n_leaf:]
    out = []
    for g, q_all, s_all in zip(flat, codes, scales):
        n_shards = q_all.shape[0]
        w = s_all.reshape((n_shards,) + (1,) * g.ndim)
        total = jnp.sum(q_all.astype(jnp.float32) * w, axis=0)
        if mean:
            total = total / n_shards
        out.append(total.astype(g.dtype))
    return jax.tree.unflatten(treedef, out)


def compressed_psum(grads: Any, axis: AxisNames, mean: bool = False) -> Any:
    """Sum (or mean) a gradient pytree over ``axis`` on an int8 wire.

    Per leaf: quantize locally, all-gather codes+scales over ``axis`` (one
    instrumented collective for the whole tree — a single barrier, like the
    fused flat all-reduce it replaces), then dequantize and reduce in fp32.
    Leaves come back in their original dtype.
    """
    flat, treedef = jax.tree.flatten(grads)
    qs = [_quantize(g) for g in flat]
    gathered = cd_all_gather(
        [q for q, _ in qs] + [s for _, s in qs], axis, tiled=False
    )
    return _dequantize_sum(flat, treedef, gathered, mean)


class CompressedPsumHandle(NamedTuple):
    """In-flight :func:`compressed_psum_start`; close with ``_wait``."""

    gather: AsyncCollective
    flat: Any
    treedef: Any
    mean: bool


def compressed_psum_start(grads: Any, axis: AxisNames,
                          mean: bool = False) -> CompressedPsumHandle:
    """Nonblocking :func:`compressed_psum`: quantize and *dispatch* the
    int8 gather through the async 5-phase pair (``cd_all_gather_async``).

    The caller overlaps independent compute between start and
    :func:`compressed_psum_wait` — e.g. the backward pass of the next
    microbatch while the cross-pod DCN hop flies.  The instrumented events
    mark that window ``dispatch_enter -> wait_enter`` on the ambient
    :class:`~repro.core.events.EventBus`, so every subscriber (governor,
    trace recorder, ...) accounts it as busy overlap, not slack: without
    the taxonomy split the whole flight would inflate the measured slack
    and invite a downshift while the core is at full tilt.
    """
    flat, treedef = jax.tree.flatten(grads)
    qs = [_quantize(g) for g in flat]
    gather = cd_all_gather_async(
        [q for q, _ in qs] + [s for _, s in qs], axis, tiled=False
    )
    return CompressedPsumHandle(gather, flat, treedef, mean)


def compressed_psum_wait(handle: CompressedPsumHandle) -> Any:
    """Block on a :func:`compressed_psum_start` and finish the reduction."""
    gathered = cd_wait(handle.gather)
    return _dequantize_sum(handle.flat, handle.treedef, gathered, handle.mean)


def compression_ratio(grads: Any) -> float:
    """Wire-bytes ratio of the int8 codec vs the raw dtype (for benchmarks)."""
    flat = jax.tree.leaves(grads)
    raw = sum(g.size * g.dtype.itemsize for g in flat)
    comp = sum(g.size * 1 + 4 for g in flat)          # int8 codes + fp32 scale
    return raw / max(comp, 1)
