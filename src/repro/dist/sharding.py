"""Partition rules: parameters, optimizer state, batches, caches, activations.

One table of *requested* specs plus one safety pass:

* ``_param_spec(name, ndim, fsdp)`` — the Megatron/FSDP rule table, keyed by
  the leaf's name.  Column-parallel matrices (``wq/wk/wv/w1/w3/...``) put
  tensor-parallel ``'model'`` on the output dim and FSDP axes on the input
  dim; row-parallel ones (``wo/w2/w_out``) the reverse; the embedding shards
  its (padded) vocab over ``'model'``.  Extra leading dims (the scan-stacked
  layer axis, the MoE expert axis) are left unsharded by left-padding the
  base rule with ``None``.
* ``sanitize_spec(mesh, spec, shape)`` — drops any spec entry whose mesh-axis
  product does not divide the corresponding dim, so every *requested* layout
  degrades to a legal one on any mesh (1-device smoke runs, 7-survivor
  elastic rebuilds, 512-device dry-runs) instead of failing to compile.

Entry points (all return pytrees of ``NamedSharding`` matching the input):

  ``param_shardings``       2-d FSDPxTP (default) or ``mode="zero3"``;
                            ``include_pod=False`` keeps parameters replicated
                            over the pod axis (the explicit cross-pod-reduce
                            step); ``gather_safe=True`` additionally drops
                            tensor-parallel entries so each leaf is sharded
                            along at most the FSDP axes — the layout whose
                            all-gathers stay legal inside a partial-manual
                            ``shard_map`` region.
  ``opt_state_shardings``   mirrors the parameter rules onto m/v/master.
  ``batch_shardings``       batch dim over the data axes (all axes in zero3).
  ``cache_shardings``       KV/recurrent-state layout; ``serve_tp=True``
                            shards heads/channels over ``'model'``.
  ``serve_param_shardings`` pure tensor-parallel serving rules (no FSDP).
  ``activation_constraint_fn``  the hook installed into the model layer
                            (see repro.models.hooks): constrains residuals /
                            logits under a mesh, excluding any manual axes.
"""
from __future__ import annotations

from typing import Any, FrozenSet, Iterable, Optional, Tuple, Union

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

Axes = Union[None, str, Tuple[str, ...]]

# leaf-name rule tables -------------------------------------------------------
# column-parallel: (d_in -> fsdp, d_out -> model)
_COL = frozenset({
    "wq", "wk", "wv", "w1", "w3", "w_in", "w_gelu", "router", "head",
})
# row-parallel: (d_in -> model, d_out -> fsdp)
_ROW = frozenset({"wo", "w2", "w_out", "w_r", "w_i"})


def _axis_sizes(mesh) -> dict:
    return dict(mesh.shape)


def sanitize_spec(mesh, spec: P, shape: Tuple[int, ...]) -> P:
    """Drop spec entries whose mesh-axis product does not divide the dim.

    Partial sharding of a non-dividing dim is never attempted: the whole
    entry (including grouped ``(a, b)`` tuples) falls back to ``None``.
    """
    sizes = _axis_sizes(mesh)
    out = []
    for i, entry in enumerate(tuple(spec)):
        if entry is None:
            out.append(None)
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        if any(n not in sizes for n in names):
            out.append(None)                 # axis absent from this mesh
            continue
        prod = 1
        for n in names:
            prod *= int(sizes[n])
        if i < len(shape) and shape[i] % prod == 0:
            out.append(entry)
        else:
            out.append(None)
    return P(*out)


def _param_spec(name: str, ndim: int, fsdp: Axes) -> P:
    """Requested spec for a parameter leaf called ``name`` with ``ndim`` dims.

    The base rule is 2-d; higher ranks (scan-stacked layers, MoE expert
    axes) left-pad with ``None`` so only the trailing matrix is sharded.
    """
    if name == "embed":
        base: Tuple[Axes, ...] = ("model", fsdp)        # (padded vocab, d)
    elif name in _COL:
        base = (fsdp, "model")
    elif name in _ROW:
        base = ("model", fsdp)
    elif name == "conv_w":
        base = (None, "model")                          # (K, channels)
    else:                                               # vectors / scalars
        return P(*([None] * ndim))
    if ndim < len(base):
        return P(*([None] * ndim))
    return P(*(((None,) * (ndim - len(base))) + base))


def _leaf_name(path) -> str:
    for entry in reversed(path):
        key = getattr(entry, "key", None)
        if key is None:
            key = getattr(entry, "name", None)
        if isinstance(key, str) and not key.isdigit():
            return key
    return ""


def _zero3_spec(mesh, shape: Tuple[int, ...], axes: Tuple[str, ...]) -> P:
    """Pure ZeRO-3: flat-shard the first dim the full axis product divides."""
    sizes = _axis_sizes(mesh)
    prod = 1
    for a in axes:
        prod *= int(sizes[a])
    for i, dim in enumerate(shape):
        if dim % prod == 0 and dim >= prod:
            return P(*([None] * i + [axes] + [None] * (len(shape) - i - 1)))
    return P(*([None] * len(shape)))


def _fsdp_axes(mesh, include_pod: bool) -> Axes:
    if "pod" in mesh.axis_names and include_pod:
        return ("pod", "data")
    return "data"


def _data_axes(mesh, exclude: FrozenSet[str] = frozenset()) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model" and a not in exclude)


def param_shardings(
    mesh,
    params: Any,
    *,
    mode: str = "2d",
    include_pod: bool = True,
    gather_safe: bool = False,
) -> Any:
    """Pytree of NamedSharding for a parameter tree (see module docstring)."""
    fsdp = _fsdp_axes(mesh, include_pod)
    zero3_axes = tuple(
        a for a in mesh.axis_names if include_pod or a != "pod"
    )

    def leaf(path, x):
        if mode == "zero3":
            spec = _zero3_spec(mesh, x.shape, zero3_axes)
        else:
            spec = _param_spec(_leaf_name(path), x.ndim, fsdp)
            if gather_safe:
                spec = P(*(None if e == "model" else e for e in tuple(spec)))
        return NamedSharding(mesh, sanitize_spec(mesh, spec, x.shape))

    return jax.tree_util.tree_map_with_path(leaf, params)


def opt_state_shardings(mesh, param_sh: Any, opt_state: Any) -> Any:
    """Optimizer-state shardings: m/v/master mirror the parameter layout
    (FSDP over optimizer state is what makes 100B+ models fit per-chip HBM);
    scalars like ``step`` replicate."""
    repl = NamedSharding(mesh, P())
    out = {}
    for key, sub in opt_state.items():
        if key in ("m", "v", "master"):
            out[key] = jax.tree.map(
                lambda s: s, param_sh, is_leaf=lambda x: hasattr(x, "spec")
            )
        else:
            out[key] = jax.tree.map(lambda _: repl, sub)
    return out


def batch_shardings(mesh, batch: Any, mode: str = "2d") -> Any:
    """Batch-dim data parallelism: dim 0 over the data(+pod) axes — over
    *every* axis in zero3 mode (no tensor parallelism to reserve 'model')."""
    if mode == "zero3":
        data = tuple(mesh.axis_names)
    else:
        data = _data_axes(mesh)

    def leaf(x):
        if x.ndim == 0:
            return NamedSharding(mesh, P())
        spec = P(*((data,) + (None,) * (x.ndim - 1)))
        return NamedSharding(mesh, sanitize_spec(mesh, spec, x.shape))

    return jax.tree.map(leaf, batch)


# cache leaf rules: name -> (batch-dim index, tp-dim index) with the leading
# scan-stack axis (if any) stripped before indexing.
_CACHE_RULES = {
    "k": (0, 2),          # (B, T, H, D)
    "v": (0, 2),
    "k_scale": (0, 2),    # (B, T, H)
    "v_scale": (0, 2),
    "conv": (0, 2),       # (B, K, C)
    "h": (0, 1),          # (B, heads/width, ...)
    "slot_pos": (None, None),
}


def cache_shardings(mesh, cache: Any, *, serve_tp: bool = False) -> Any:
    """Decode/prefill cache layout: batch over data axes; with ``serve_tp``
    the KV-head / state-channel dim additionally shards over 'model'."""
    data = _data_axes(mesh)

    def leaf(path, x):
        name = _leaf_name(path)
        stacked = bool(path) and getattr(path[0], "key", None) == "stack"
        offset = 1 if stacked else 0
        b_dim, tp_dim = _CACHE_RULES.get(name, (0, None))
        entries: list = [None] * x.ndim
        if b_dim is not None and b_dim + offset < x.ndim:
            entries[b_dim + offset] = data
        if serve_tp and tp_dim is not None and tp_dim + offset < x.ndim:
            entries[tp_dim + offset] = "model"
        return NamedSharding(mesh, sanitize_spec(mesh, P(*entries), x.shape))

    return jax.tree_util.tree_map_with_path(leaf, cache)


# page-pool leaf rules: name -> KV-head dim index with the leading scan-stack
# axis (if any) stripped.  Page arrays are (n_pages, page, Hkv, D) / scale
# pages (n_pages, page, Hkv); recurrent-state leaves fall through to
# _CACHE_RULES (per-slot batch dim over the data axes).
_PAGE_RULES = {
    "k_pages": 2,
    "v_pages": 2,
    "k_scale_pages": 2,
    "v_scale_pages": 2,
}


def page_pool_shardings(mesh, blocks: Any, *, serve_tp: bool = True) -> Any:
    """Paged serving-pool layout (see ``repro.serve.kvcache``).

    Page arrays replicate over the data axes — any decode slot must reach
    any physical page, so the pool cannot shard over requests — and with
    ``serve_tp`` split the KV-head dim over ``'model'``, matching the
    tensor-parallel head split of ``serve_param_shardings``.  Recurrent
    per-slot state shards its slot (batch) dim over the data axes like the
    dense cache.
    """
    data = _data_axes(mesh)

    def leaf(path, x):
        name = _leaf_name(path)
        stacked = bool(path) and getattr(path[0], "key", None) == "stack"
        offset = 1 if stacked else 0
        entries: list = [None] * x.ndim
        if name in _PAGE_RULES:
            if serve_tp:
                entries[_PAGE_RULES[name] + offset] = "model"
        else:
            b_dim, tp_dim = _CACHE_RULES.get(name, (0, None))
            if b_dim is not None and b_dim + offset < x.ndim:
                entries[b_dim + offset] = data
            if serve_tp and tp_dim is not None and tp_dim + offset < x.ndim:
                entries[tp_dim + offset] = "model"
        return NamedSharding(mesh, sanitize_spec(mesh, P(*entries), x.shape))

    return jax.tree_util.tree_map_with_path(leaf, blocks)


def serve_param_shardings(mesh, params: Any) -> Any:
    """Pure tensor-parallel serving rules: weights replicated over 'data'
    (throughput replicas), matrices Megatron-split over 'model' only."""

    def leaf(path, x):
        name = _leaf_name(path)
        if name == "embed":
            base: Tuple[Axes, ...] = ("model", None)
        elif name in _COL:
            base = (None, "model")
        elif name in _ROW:
            base = ("model", None)
        else:
            base = ()
        if len(base) > x.ndim:
            base = ()
        spec = P(*(((None,) * (x.ndim - len(base))) + base))
        return NamedSharding(mesh, sanitize_spec(mesh, spec, x.shape))

    return jax.tree_util.tree_map_with_path(leaf, params)


def activation_constraint_fn(
    mesh,
    exclude: Optional[Iterable[str]] = None,
    mode: str = "2d",
):
    """Build the hook for ``repro.models.hooks.install_constraint``.

    Maps the model's logical activation names onto specs under ``mesh``:

      residual  (B, S, d)    batch over data(+pod) axes
      logits    (B, C, V)    batch over data axes, vocab over 'model'

    ``exclude`` removes axes that are *manual* in the calling context (the
    pod-explicit train step runs the model inside a shard_map over 'pod',
    where constraints must not name 'pod').  Specs are sanitized per call,
    so odd batch remainders after an elastic rebuild simply replicate.
    """
    excluded = frozenset(exclude or ())
    data = _data_axes(mesh, excluded)
    if mode == "zero3":
        data = tuple(a for a in mesh.axis_names if a not in excluded)
        tp = None
    else:
        tp = "model" if ("model" in mesh.axis_names and "model" not in excluded) else None
    batch_axes: Axes = data if data else None

    def constrain(x, name: str):
        if x.ndim < 2:
            return x
        if name == "logits":
            entries = (batch_axes,) + (None,) * (x.ndim - 2) + (tp,)
        elif name == "residual":
            entries = (batch_axes,) + (None,) * (x.ndim - 1)
        else:
            return x
        spec = sanitize_spec(mesh, P(*entries), x.shape)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return constrain
