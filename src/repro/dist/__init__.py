"""``repro.dist`` — the distributed-runtime substrate.

The COUNTDOWN Slack core (``repro.core``) reasons about *when* ranks wait;
this package provides the machinery that makes ranks exist at scale:

``sharding``     partition rules: FSDP/TP parameter shardings (2-d and
                 ZeRO-3), optimizer-state mirroring, batch/cache layouts,
                 tensor-parallel serving rules, activation constraints.
``elastic``      :class:`ElasticMesh` (rebuildable device mesh that survives
                 node failures) and :class:`FailureInjector` (deterministic
                 fault injection for the recovery path).
``checkpoint``   :class:`CheckpointManager` — atomic, optionally async
                 save/restore with retention pruning; the restart substrate
                 for the mesh-epoch recovery loop.
``compression``  int8 gradient codec + :func:`compressed_psum`, the
                 wire-thrifty cross-pod reduction (beyond-paper knob).
``straggler``    :class:`StragglerDetector` — turns barrier-arrival events
                 into a per-rank laggard signal (the paper's critical-rank
                 analysis, §5, made online).
``compat``       small shims over jax API renames (``set_mesh``,
                 ``shard_map``) so the same drivers run on the pinned
                 container jax and on current releases.

See DESIGN.md §3 for how these compose into the train/serve launchers.
"""
from repro.dist import sharding  # noqa: F401
from repro.dist.checkpoint import CheckpointManager  # noqa: F401
from repro.dist.compression import compressed_psum  # noqa: F401
from repro.dist.elastic import ElasticMesh, FailureInjector  # noqa: F401
from repro.dist.straggler import StragglerDetector  # noqa: F401
