"""Atomic, optionally-async checkpointing for arbitrary pytrees.

Design goals, in order:

1. **Crash-atomic** — a checkpoint either exists completely or not at all.
   Every save writes to a ``*.tmp`` file and ``os.replace``s it into place;
   a crash mid-write leaves at most a tmp file that the next manager
   construction sweeps away.
2. **Skeleton-typed restore** — files store leaves positionally; the caller
   supplies a skeleton pytree (the same structure, any leaf values) and gets
   back leaves with the *file's* data in the *skeleton's* structure.  This
   is what lets a mesh-epoch restart restore onto a different device layout:
   pass per-leaf shardings and every leaf is ``device_put`` directly to its
   new home.
3. **Bounded retention** — ``keep`` most-recent steps survive; older files
   are pruned after each successful save (the GC that keeps a 3-day run from
   filling the disk).
4. **Async option** — ``async_save=True`` snapshots the tree to host memory
   synchronously (correctness) and does the file I/O on a single background
   worker (training never blocks on the disk); ``wait()`` drains the queue.

Leaves are stored with ``np.savez``; bfloat16 / float8 leaves (which numpy
cannot serialize natively) are bit-cast to a same-width unsigned integer on
write and cast back on read using a recorded dtype table.
"""
from __future__ import annotations

import concurrent.futures
import json
import os
import re
from typing import Any, List, Optional, Tuple

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)\.npz$")

# numpy-unserializable dtypes -> (bitcast dtype, ml_dtypes name)
_BITCAST = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8, "float8_e5m2": np.uint8}


def _to_host(leaf) -> np.ndarray:
    return np.asarray(jax.device_get(leaf))


def _encode(leaf: np.ndarray) -> Tuple[np.ndarray, str]:
    name = leaf.dtype.name
    if name in _BITCAST:
        return leaf.view(_BITCAST[name]), name
    return leaf, ""


def _decode(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if not dtype_name:
        return arr
    import ml_dtypes

    return arr.view(np.dtype(getattr(ml_dtypes, dtype_name)))


class CheckpointManager:
    """Save/restore pytrees under ``directory`` with retention pruning."""

    def __init__(self, directory: str, keep: int = 3, async_save: bool = False):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        # sweep tmp litter from a previous crash (atomicity guarantee #1)
        for name in os.listdir(directory):
            if name.endswith(".tmp"):
                try:
                    os.remove(os.path.join(directory, name))
                except OSError:
                    pass
        self._pool: Optional[concurrent.futures.ThreadPoolExecutor] = (
            concurrent.futures.ThreadPoolExecutor(max_workers=1)
            if async_save else None
        )
        self._futures: List[concurrent.futures.Future] = []

    # -- paths -------------------------------------------------------------
    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{int(step):08d}.npz")

    def all_steps(self) -> List[int]:
        steps = []
        for name in os.listdir(self.directory):
            m = _STEP_RE.match(name)
            if m:
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # -- save --------------------------------------------------------------
    def save(self, step: int, tree: Any) -> None:
        """Checkpoint ``tree`` at ``step`` (atomic; prunes beyond ``keep``).

        With ``async_save`` the device->host snapshot happens here (so the
        caller may mutate/donate the tree immediately) and the write is
        queued on the background worker.
        """
        leaves = [_to_host(x) for x in jax.tree.leaves(tree)]
        if self._pool is not None:
            self._futures.append(self._pool.submit(self._write, step, leaves))
        else:
            self._write(step, leaves)

    def _write(self, step: int, leaves: List[np.ndarray]) -> None:
        payload = {}
        dtypes = []
        for i, leaf in enumerate(leaves):
            arr, dtype_name = _encode(leaf)
            payload[f"l{i:06d}"] = arr
            dtypes.append(dtype_name)
        payload["dtypes"] = np.frombuffer(
            json.dumps(dtypes).encode(), dtype=np.uint8
        ).copy()
        final = self._path(step)
        tmp = final + ".tmp"
        with open(tmp, "wb") as f:
            np.savez(f, **payload)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, final)
        self._prune()

    def _prune(self) -> None:
        steps = self.all_steps()
        for step in steps[: max(0, len(steps) - self.keep)]:
            try:
                os.remove(self._path(step))
            except OSError:
                pass

    def wait(self) -> None:
        """Block until all queued async saves hit the disk (re-raises errors)."""
        for fut in self._futures:
            fut.result()
        self._futures.clear()

    # -- restore -----------------------------------------------------------
    def load(self, step: int, skeleton: Any, shardings: Any = None) -> Any:
        """Restore the step's leaves into ``skeleton``'s structure.

        ``shardings`` (optional) is a matching pytree of shardings; each
        restored leaf is ``device_put`` onto its sharding — the elastic
        restart path restores straight onto the *new* mesh.  A missing step
        raises: silently returning the skeleton would hand callers whatever
        placeholder values it was built from.
        """
        path = self._path(step)
        if not os.path.exists(path):
            raise FileNotFoundError(f"no checkpoint for step {step} in {self.directory}")
        flat, treedef = jax.tree.flatten(skeleton)
        with np.load(path) as z:
            dtypes = json.loads(bytes(z["dtypes"]).decode())
            loaded = [
                _decode(z[f"l{i:06d}"], dtypes[i]) for i in range(len(dtypes))
            ]
        if len(loaded) != len(flat):
            raise ValueError(
                f"checkpoint step {step} has {len(loaded)} leaves, "
                f"skeleton has {len(flat)}"
            )
        if shardings is not None:
            sh_flat = jax.tree.leaves(
                shardings, is_leaf=lambda x: hasattr(x, "spec")
            )
            if len(sh_flat) != len(loaded):
                raise ValueError(
                    f"shardings tree has {len(sh_flat)} leaves, "
                    f"checkpoint has {len(loaded)}"
                )
            loaded = [jax.device_put(a, s) for a, s in zip(loaded, sh_flat)]
        return jax.tree.unflatten(treedef, loaded)

    def restore_latest(self, skeleton: Any, shardings: Any = None):
        """-> (step, tree) for the newest checkpoint on disk."""
        step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        return step, self.load(step, skeleton, shardings)
