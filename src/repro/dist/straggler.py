"""Online straggler detection from barrier-arrival events.

The paper's post-hoc critical-rank analysis (§5) observes that in slack-rich
applications the *same* ranks keep arriving last — the application has a
persistent critical path.  This module makes that analysis online: the
governor feeds every reconstructed barrier's per-rank enter times into
:class:`StragglerDetector`, which accumulates each rank's mean arrival
lateness and flags ranks whose lateness is a statistical outlier across the
fleet.  On a real cluster the flagged ranks are the ones a scheduler should
migrate (or the only ranks that must *not* be downshifted — they carry the
critical path, see DESIGN.md §2).

Lateness is measured relative to the per-barrier mean arrival time, so the
detector is invariant to the absolute epoch of each barrier and to drift in
the global step rate.  The outlier test is a z-score over per-rank mean
lateness; with one extreme laggard among ``n`` ranks the laggard's z-score
approaches ``sqrt(n - 1)``, so the default threshold of 2.0 resolves a
single straggler for fleets of 6+ ranks while staying quiet on balanced
arrival noise.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np


class StragglerDetector:
    """Accumulates per-rank barrier lateness; flags statistical laggards.

    Args:
      min_samples: a rank needs at least this many observed barriers before
        it can be flagged (guards against cold-start noise).
      z_threshold: per-rank mean-lateness z-score above which a rank is
        reported by :meth:`stragglers`.
    """

    def __init__(self, min_samples: int = 5, z_threshold: float = 2.0):
        self.min_samples = min_samples
        self.z_threshold = z_threshold
        self._late_sum: Dict[int, float] = {}
        self._count: Dict[int, int] = {}
        self.n_barriers = 0

    def observe_barrier(self, arrivals: Dict[int, float]) -> None:
        """Record one barrier: ``arrivals`` maps rank -> arrival time (s).

        The last arriver (largest t) is the barrier's critical rank; every
        rank's lateness is its arrival relative to the barrier mean.
        """
        n = len(arrivals)
        if n < 2:
            return
        mean_t = sum(arrivals.values()) / n
        late_sum, count = self._late_sum, self._count
        for rank, t in arrivals.items():
            late_sum[rank] = late_sum.get(rank, 0.0) + (t - mean_t)
            count[rank] = count.get(rank, 0) + 1
        self.n_barriers += 1

    def observe_barriers_cols(self, ranks: np.ndarray, ts: np.ndarray,
                              offsets: np.ndarray) -> None:
        """Record many barriers at once from columnar arrival rows (the
        governor's batched ingest path).

        ``ranks``/``ts`` hold the arrival rows of ``len(offsets) - 1``
        barriers back to back — barrier ``i`` is ``offsets[i]:offsets[i+1]``,
        rows in the per-barrier insertion order the per-event dict walk
        would have used.  Detector state afterwards is bit-for-bit what the
        equivalent :meth:`observe_barrier` sequence leaves: per-barrier
        means and per-rank lateness sums are folded as strictly sequential
        left-to-right chains (same-length chains fold column by column —
        elementwise float64 adds are the scalar adds), never pairwise
        reductions.  Every barrier must have >= 2 arrivals; the caller
        filters (:meth:`observe_barrier` drops them silently, so passing
        one here would desynchronize ``n_barriers``).
        """
        nb = int(offsets.shape[0]) - 1
        if nb <= 0:
            return
        sizes = np.diff(offsets)
        if int(sizes.min()) < 2:
            raise ValueError("observe_barriers_cols: every barrier needs "
                             ">= 2 arrivals (caller must filter)")
        starts = offsets[:-1]
        means = np.empty(nb)
        for k in np.unique(sizes).tolist():
            gm = sizes == k
            idx = starts[gm][:, None] + np.arange(k)
            # ufunc.accumulate is a strictly sequential left fold, so one
            # accumulate per row == the 0.0-seeded scalar add chain
            rows = np.empty((int(np.count_nonzero(gm)), k + 1))
            rows[:, 0] = 0.0
            rows[:, 1:] = ts[idx]
            means[gm] = np.add.accumulate(rows, axis=1)[:, -1] / k
        dev = ts - np.repeat(means, sizes)
        # per-rank lateness chains, in global row order (the stable sort
        # keeps each rank's rows in barrier-processing order); rank ids
        # are small, so narrowing the sort key cuts radix passes
        rmax = int(ranks.max())
        if 0 <= int(ranks.min()) and rmax < 256:
            o = ranks.astype(np.uint8).argsort(kind="stable")
        elif rmax < 2 ** 15 and int(ranks.min()) >= 0:
            o = ranks.astype(np.int16).argsort(kind="stable")
        else:
            o = np.argsort(ranks, kind="stable")
        r_s = ranks[o]
        d_s = dev[o]
        n_rows = r_s.shape[0]
        run_start = np.empty(n_rows, dtype=bool)
        run_start[0] = True
        np.not_equal(r_s[1:], r_s[:-1], out=run_start[1:])
        run_lo = np.nonzero(run_start)[0]
        run_hi = np.append(run_lo[1:], n_rows)
        ur_l = r_s[run_lo].tolist()
        late_sum, count = self._late_sum, self._count
        seeds = np.empty(len(ur_l))
        # dict insertion order is observable (summary(), straggler
        # tie-breaks): pin new ranks in global first-appearance order
        counts_l = (run_hi - run_lo).tolist()
        for oi in np.argsort(o[run_lo], kind="stable").tolist():
            r = ur_l[oi]
            seeds[oi] = late_sum.get(r, 0.0)
            count[r] = count.get(r, 0) + counts_l[oi]
            late_sum.setdefault(r, 0.0)
        counts_r = run_hi - run_lo
        vals = np.empty(len(ur_l))
        for k in np.unique(counts_r).tolist():
            gm = counts_r == k
            idx = run_lo[gm][:, None] + np.arange(k)
            rows = np.empty((int(np.count_nonzero(gm)), k + 1))
            rows[:, 0] = seeds[gm]
            rows[:, 1:] = d_s[idx]
            vals[gm] = np.add.accumulate(rows, axis=1)[:, -1]
        for r, v in zip(ur_l, vals.tolist()):
            late_sum[r] = v
        self.n_barriers += nb

    def summary(self) -> Dict[int, float]:
        """rank -> mean lateness (s; positive = habitually late)."""
        return {
            r: self._late_sum[r] / c for r, c in self._count.items() if c > 0
        }

    def stragglers(self) -> List[Tuple[int, float]]:
        """Ranks whose mean lateness is a z-score outlier, worst first.

        Returns ``[(rank, z_score), ...]`` for ranks with at least
        ``min_samples`` observations and ``z >= z_threshold``.
        """
        eligible = {
            r: s for r, s in self.summary().items()
            if self._count[r] >= self.min_samples
        }
        if len(eligible) < 3:
            return []          # z-scores are meaningless on <3 ranks
        vals = np.asarray(list(eligible.values()), dtype=np.float64)
        mu, sd = float(vals.mean()), float(vals.std())
        if sd <= 0.0:
            return []
        out = [
            (r, (s - mu) / sd)
            for r, s in eligible.items()
            if (s - mu) / sd >= self.z_threshold
        ]
        out.sort(key=lambda rz: -rz[1])
        return out

    def export_metrics(self, registry) -> None:
        """Publish detector state into a :class:`repro.obs.metrics.
        MetricsRegistry`: mean lateness per rank, plus the z-score of every
        currently-flagged straggler (ranks no longer flagged drop to 0 so a
        dashboard shows recovery, not a stale alarm)."""
        late = registry.gauge("straggler_mean_lateness_seconds",
                              "per-rank mean barrier lateness", ("rank",))
        zscore = registry.gauge("straggler_z_score",
                                "z-score of flagged straggler ranks", ("rank",))
        for rank, mean in self.summary().items():
            late.labels(rank).set(mean)
        flagged = dict(self.stragglers())
        for rank in self._count:
            zscore.labels(rank).set(flagged.get(rank, 0.0))

    def reset(self) -> None:
        self._late_sum.clear()
        self._count.clear()
        self.n_barriers = 0
