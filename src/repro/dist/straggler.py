"""Online straggler detection from barrier-arrival events.

The paper's post-hoc critical-rank analysis (§5) observes that in slack-rich
applications the *same* ranks keep arriving last — the application has a
persistent critical path.  This module makes that analysis online: the
governor feeds every reconstructed barrier's per-rank enter times into
:class:`StragglerDetector`, which accumulates each rank's mean arrival
lateness and flags ranks whose lateness is a statistical outlier across the
fleet.  On a real cluster the flagged ranks are the ones a scheduler should
migrate (or the only ranks that must *not* be downshifted — they carry the
critical path, see DESIGN.md §2).

Lateness is measured relative to the per-barrier mean arrival time, so the
detector is invariant to the absolute epoch of each barrier and to drift in
the global step rate.  The outlier test is a z-score over per-rank mean
lateness; with one extreme laggard among ``n`` ranks the laggard's z-score
approaches ``sqrt(n - 1)``, so the default threshold of 2.0 resolves a
single straggler for fleets of 6+ ranks while staying quiet on balanced
arrival noise.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np


class StragglerDetector:
    """Accumulates per-rank barrier lateness; flags statistical laggards.

    Args:
      min_samples: a rank needs at least this many observed barriers before
        it can be flagged (guards against cold-start noise).
      z_threshold: per-rank mean-lateness z-score above which a rank is
        reported by :meth:`stragglers`.
    """

    def __init__(self, min_samples: int = 5, z_threshold: float = 2.0):
        self.min_samples = min_samples
        self.z_threshold = z_threshold
        self._late_sum: Dict[int, float] = {}
        self._count: Dict[int, int] = {}
        self.n_barriers = 0

    def observe_barrier(self, arrivals: Dict[int, float]) -> None:
        """Record one barrier: ``arrivals`` maps rank -> arrival time (s).

        The last arriver (largest t) is the barrier's critical rank; every
        rank's lateness is its arrival relative to the barrier mean.
        """
        n = len(arrivals)
        if n < 2:
            return
        mean_t = sum(arrivals.values()) / n
        late_sum, count = self._late_sum, self._count
        for rank, t in arrivals.items():
            late_sum[rank] = late_sum.get(rank, 0.0) + (t - mean_t)
            count[rank] = count.get(rank, 0) + 1
        self.n_barriers += 1

    def summary(self) -> Dict[int, float]:
        """rank -> mean lateness (s; positive = habitually late)."""
        return {
            r: self._late_sum[r] / c for r, c in self._count.items() if c > 0
        }

    def stragglers(self) -> List[Tuple[int, float]]:
        """Ranks whose mean lateness is a z-score outlier, worst first.

        Returns ``[(rank, z_score), ...]`` for ranks with at least
        ``min_samples`` observations and ``z >= z_threshold``.
        """
        eligible = {
            r: s for r, s in self.summary().items()
            if self._count[r] >= self.min_samples
        }
        if len(eligible) < 3:
            return []          # z-scores are meaningless on <3 ranks
        vals = np.asarray(list(eligible.values()), dtype=np.float64)
        mu, sd = float(vals.mean()), float(vals.std())
        if sd <= 0.0:
            return []
        out = [
            (r, (s - mu) / sd)
            for r, s in eligible.items()
            if (s - mu) / sd >= self.z_threshold
        ]
        out.sort(key=lambda rz: -rz[1])
        return out

    def export_metrics(self, registry) -> None:
        """Publish detector state into a :class:`repro.obs.metrics.
        MetricsRegistry`: mean lateness per rank, plus the z-score of every
        currently-flagged straggler (ranks no longer flagged drop to 0 so a
        dashboard shows recovery, not a stale alarm)."""
        late = registry.gauge("straggler_mean_lateness_seconds",
                              "per-rank mean barrier lateness", ("rank",))
        zscore = registry.gauge("straggler_z_score",
                                "z-score of flagged straggler ranks", ("rank",))
        for rank, mean in self.summary().items():
            late.labels(rank).set(mean)
        flagged = dict(self.stragglers())
        for rank in self._count:
            zscore.labels(rank).set(flagged.get(rank, 0.0))

    def reset(self) -> None:
        self._late_sum.clear()
        self._count.clear()
        self.n_barriers = 0
