"""Mamba-2 130M [arXiv:2405.21060] — attention-free SSD (state-space duality)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,                 # Mamba-2 blocks replace the MLP
    vocab=50280,
    attention="none",
    ssm_state=128,
    ssm_d_head=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=128,
    tie_embeddings=True,
)
