"""Architecture registry: ``get_config(name)`` / ``ARCHS``."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import (
    FULL_ATTENTION_ONLY,
    SHAPES,
    ModelConfig,
    ShapeConfig,
    cell_is_runnable,
    reduced,
)

ARCHS = (
    "musicgen-large",
    "granite-moe-3b-a800m",
    "mixtral-8x22b",
    "internvl2-1b",
    "recurrentgemma-2b",
    "llama3.2-1b",
    "glm4-9b",
    "olmo-1b",
    "internlm2-1.8b",
    "mamba2-130m",
    # the paper's own evaluation vehicle: a ~100M dense LM used by examples/
    "countdown-100m",
)

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCHS}


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCHS}


__all__ = [
    "ARCHS",
    "SHAPES",
    "FULL_ATTENTION_ONLY",
    "ModelConfig",
    "ShapeConfig",
    "get_config",
    "all_configs",
    "cell_is_runnable",
    "reduced",
]
