"""The paper's own evaluation vehicle: a ~100M dense LM.

Used by ``examples/train_lm.py`` and ``examples/energy_aware_training.py``
to exercise the COUNTDOWN Slack runtime end-to-end on this container.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="countdown-100m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=4,
    d_ff=3072,
    vocab=32768,
    attention="full",
    tie_embeddings=True,
)
