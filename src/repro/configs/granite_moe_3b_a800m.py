"""IBM Granite 3.0 MoE (3b-a800m class) [hf:ibm-granite].

Fine-grained MoE: 40 experts, top-8, narrow (512-wide) expert FFNs.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,               # per-expert width (mirrored in moe_d_ff)
    vocab=49155,
    attention="full",
    n_experts=40,
    top_k=8,
    moe_d_ff=512,
    capacity_factor=1.25,
    tie_embeddings=True,
)
