"""InternVL2-1B [arXiv:2404.16821] — InternLM2 LM backbone of the VLM.

Backbone only: InternViT patch embeddings arrive precomputed via the
``input_specs`` vision stub as a 256-token prefix.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab=151655,
    attention="full",
    frontend="vision",
    n_prefix=256,           # ViT patch embeddings (stub)
    tie_embeddings=True,
)
