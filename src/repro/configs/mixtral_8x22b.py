"""Mixtral 8x22B [arXiv:2401.04088] — 8-expert top-2 MoE with SWA."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=32768,
    attention="swa",
    window=4096,            # sliding-window attention
    n_experts=8,
    top_k=2,
    moe_d_ff=16384,
    capacity_factor=1.25,
    rope_theta=1_000_000.0,
)
