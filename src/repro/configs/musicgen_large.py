"""MusicGen-Large — decoder-only LM over EnCodec tokens [arXiv:2306.05284].

Backbone only: the EnCodec/conditioning frontend is an ``input_specs`` stub
providing precomputed frame embeddings as a prefix (see assignment note).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,          # MHA
    d_ff=8192,
    vocab=2048,             # EnCodec codebook size
    attention="full",
    norm="layernorm",
    frontend="audio",
    n_prefix=64,            # conditioning frame embeddings (stub)
)
