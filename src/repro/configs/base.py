"""Configuration dataclasses for models, shapes and runs.

Every assigned architecture is expressed as a ``ModelConfig``; the four
assigned input shapes as ``ShapeConfig``.  Configs are plain frozen
dataclasses so they can be hashed, compared and serialized trivially.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    """Unified architecture description covering dense / MoE / SSM / hybrid LMs."""

    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    # --- attention ---
    d_head: int = 0                  # 0 -> d_model // n_heads
    attention: str = "full"          # full | swa | local | none
    window: int = 0                  # window size for swa/local
    rope_theta: float = 10_000.0
    qk_norm: bool = False

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                # per-expert FFN width (granite: 512)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01    # load-balance auxiliary loss

    # --- SSM (Mamba-2 / SSD) ---
    ssm_state: int = 0               # d_state (N)
    ssm_d_head: int = 64             # P
    ssm_expand: int = 2              # d_inner = expand * d_model
    ssm_conv: int = 4                # depthwise conv kernel width
    ssm_chunk: int = 128             # SSD chunk length

    # --- RG-LRU (RecurrentGemma) ---
    rglru_width: int = 0             # 0 -> d_model
    rglru_c: float = 8.0

    # --- hybrid stacking ---
    # repeating pattern of block kinds; () means homogeneous:
    #   dense/moe -> ("attn",), ssm -> ("ssm",)
    block_pattern: Tuple[str, ...] = ()

    # --- misc ---
    norm: str = "rmsnorm"            # rmsnorm | layernorm | nonparametric
    kv_quant: bool = False           # int8 KV cache (serving memory knob)
    pad_vocab_to: int = 0            # pad embedding rows to a multiple (TP):
                                     # odd vocabs (151655, 49155) otherwise
                                     # defeat vocab sharding entirely
    tie_embeddings: bool = False
    frontend: str = "none"           # none | vision | audio
    n_prefix: int = 0                # frontend prefix embedding length
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    logits_softcap: float = 0.0
    # scan-over-layers keeps HLO small (production default).  The dry-run
    # unrolls instead: XLA cost_analysis counts while-loop bodies ONCE, so
    # scanned modules under-report FLOPs/bytes/collectives for the roofline.
    scan_layers: bool = True

    # ---- derived helpers -------------------------------------------------
    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def padded_vocab(self) -> int:
        if not self.pad_vocab_to:
            return self.vocab
        m = self.pad_vocab_to
        return ((self.vocab + m - 1) // m) * m

    @property
    def pattern(self) -> Tuple[str, ...]:
        if self.block_pattern:
            return self.block_pattern
        if self.family == "ssm":
            return ("ssm",)
        return ("attn",)

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind, repeating ``pattern`` to n_layers."""
        p = self.pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_d_head

    @property
    def lru_width(self) -> int:
        return self.rglru_width or self.d_model

    # ---- parameter count (for roofline MODEL_FLOPS = 6*N*D) --------------
    def param_count(self, active_only: bool = False) -> int:
        """Total (or MoE-active) parameter count, embeddings included."""
        d, V = self.d_model, self.vocab
        total = V * d                          # token embedding
        if not self.tie_embeddings:
            total += V * d                     # output head
        hd = self.head_dim
        for kind in self.layer_kinds():
            total += 2 * d                     # two norms (rms weights), ~0 for nonparametric
            if kind == "attn":
                total += d * self.n_heads * hd           # q
                total += 2 * d * self.n_kv_heads * hd    # k, v
                total += self.n_heads * hd * d           # o
                if self.is_moe:
                    e = self.top_k if active_only else self.n_experts
                    total += d * self.n_experts          # router (always dense)
                    total += e * 3 * d * self.moe_d_ff   # gated ffn per expert
                else:
                    total += 3 * d * self.d_ff           # swiglu
            elif kind == "ssm":
                di, N, H = self.d_inner, self.ssm_state, self.ssm_heads
                total += d * (2 * di + 2 * N + H)        # in_proj (z,x,B,C,dt)
                total += self.ssm_conv * (di + 2 * N)    # depthwise conv
                total += H + H + H * self.ssm_d_head * 0 # A_log, D
                total += di * d                          # out_proj
            elif kind == "rglru":
                w = self.lru_width
                total += 2 * d * w                       # two in-projections
                total += self.ssm_conv * w               # temporal conv
                total += w                               # Lambda (a parameter)
                total += 2 * w * w                       # input/recurrence gate projections
                total += w * d                           # out projection
                total += 3 * d * self.d_ff               # hybrid blocks keep a SwiGLU MLP
        return int(total)


@dataclass(frozen=True)
class ShapeConfig:
    """An assigned (seq_len, global_batch, kind) input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# Archs whose *every* attention layer is unwindowed full attention must skip
# long_500k (assignment rule: sub-quadratic attention required).
FULL_ATTENTION_ONLY = frozenset(
    {
        "musicgen-large",
        "granite-moe-3b-a800m",
        "internvl2-1b",
        "llama3.2-1b",
        "glm4-9b",
        "olmo-1b",
        "internlm2-1.8b",
    }
)


def cell_is_runnable(arch: str, shape: str) -> bool:
    if shape == "long_500k" and arch in FULL_ATTENTION_ONLY:
        return False
    return True


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family variant of ``cfg`` for CPU smoke tests."""
    small = dict(
        n_layers=min(cfg.n_layers, len(cfg.pattern) * 2),
        d_model=128,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads * 4 // max(cfg.n_heads, 1), 4)),
        d_ff=256,
        vocab=512,
        d_head=32,
        window=min(cfg.window, 64) if cfg.window else 0,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        moe_d_ff=64 if cfg.n_experts else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_d_head=16,
        ssm_chunk=16,
        rglru_width=64 if cfg.family == "hybrid" else 0,
        n_prefix=8 if cfg.n_prefix else 0,
        param_dtype="float32",
        compute_dtype="float32",
        name=cfg.name + "-smoke",
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
