"""RecurrentGemma-2B (Griffin) [arXiv:2402.19427].

Hybrid stack: repeating (RG-LRU, RG-LRU, local-attention) — 1 attention per
2 recurrent blocks; local attention window 2048; MQA (1 KV head).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab=256000,
    d_head=256,
    attention="local",
    window=2048,
    block_pattern=("rglru", "rglru", "attn"),
    rglru_width=2560,
    tie_embeddings=True,
    logits_softcap=30.0,
)
