"""OLMo-1B [arXiv:2402.00838] — non-parametric LayerNorm, MHA."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,          # MHA
    d_ff=8192,
    vocab=50304,
    attention="full",
    norm="nonparametric",
    tie_embeddings=True,
)
