"""GLM-4-9B [hf:THUDM/glm-4-9b] — RoPE, GQA (2 KV heads)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab=151552,
    attention="full",
)
