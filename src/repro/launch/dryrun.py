import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    # dry-run compiles CPU code it never runs: skip expensive LLVM passes
    # (post-HLO, so memory/cost/collective analyses are unaffected)
    "--xla_llvm_disable_expensive_passes=true"
)

"""Multi-pod dry-run: AOT-lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. eval_shape's the model/optimizer/cache state (no allocation),
  3. jits the right step function with explicit in/out shardings,
  4. ``.lower().compile()``s it — proving the distribution config is
     coherent (shardings divide, collectives legal, memory fits),
  5. records ``memory_analysis()``, ``cost_analysis()`` and the per-op
     collective schedule (parsed from post-SPMD HLO) into
     ``artifacts/dryrun/<cell>.json`` for the roofline harness.

Artifacts are cached: finished cells are skipped on re-run, so the full
sweep is resumable.  ``--instrument barrier`` lowers the paper-faithful
variant (artificial barriers in the collective schedule).

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all [--mesh pod|multipod|both]
  python -m repro.launch.dryrun --list
"""
import argparse
import dataclasses
import json
import re
import sys
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, SHAPES, cell_is_runnable, get_config
from repro.core import instrument
from repro.dist import sharding as SH
from repro.dist.compat import set_mesh
from repro.launch.mesh import make_production_mesh
from repro.obs import log as obslog

log = obslog.get_logger("dryrun")
from repro.models.hooks import install_constraint
from repro.models.inputs import decode_inputs_specs, input_specs
from repro.models.transformer import init_cache, init_params
from repro.serve.engine import make_serve_steps
from repro.train.loop import TrainConfig, make_pod_train_step, make_train_step
from repro.train.optimizer import OptConfig, init_opt_state

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun")

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8, "s32": 4,
    "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "c64": 8, "c128": 16,
}


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of every typed shape in an HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Per-op-type: count, per-device result bytes, estimated wire bytes.

    Wire-bytes model (ring algorithms, per chip):
      all-gather:        out*(g-1)/g      reduce-scatter: out*(g-1)
      all-reduce:        2*size*(g-1)/g   all-to-all:     size*(g-1)/g
      collective-permute: size
    """
    out = {op: {"count": 0, "result_bytes": 0, "wire_bytes": 0.0} for op in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s*((?:[\w\-]+)-start|[\w\-]+)\(", ls)
        if not m:
            continue
        opname = m.group(2).replace("-start", "")
        if opname not in COLLECTIVE_OPS:
            continue
        result_bytes = _shape_bytes(m.group(1))
        g = 1
        rg = re.search(r"replica_groups=\{?\{([^}]*)\}", ls)
        if rg:
            g = len(rg.group(1).split(","))
        else:
            rg2 = re.search(r"replica_groups=\[(\d+),(\d+)\]", ls)
            if rg2:
                g = int(rg2.group(2))
        g = max(g, 1)
        if opname == "all-gather":
            wire = result_bytes * (g - 1) / g
        elif opname == "reduce-scatter":
            wire = result_bytes * (g - 1)
        elif opname == "all-reduce":
            wire = 2 * result_bytes * (g - 1) / g
        elif opname == "all-to-all":
            wire = result_bytes * (g - 1) / g
        else:  # collective-permute
            wire = result_bytes
        rec = out[opname]
        rec["count"] += 1
        rec["result_bytes"] += result_bytes
        rec["wire_bytes"] += wire
    return out


_SKIP_OPS = {
    "parameter", "bitcast", "get-tuple-element", "constant", "tuple",
    "after-all", "iota",
}


def parse_memory_traffic(hlo_text: str) -> dict:
    """HBM-traffic proxy from post-fusion HLO: unique top-level tensor bytes.

    ``cost_analysis()['bytes accessed']`` counts every op inside fusion
    computations (logical bytes) plus CPU-backend bf16->f32 convert
    materializations that a TPU's MXU never performs — a 10-100x
    overestimate.  Here we count only tensors that exist between fusions
    (each written once, read >= once): entry parameters once, plus the
    output of every instruction in non-fusion-internal computations.
    """
    # pass 1: computations called by fusions / reducers (skip their bodies)
    fused = set(re.findall(r"(?:calls|to_apply)=%?([\w.\-]+)", hlo_text))
    total = 0
    params = 0
    current_skipped = False
    in_entry = False
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"(ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*\{$", s)
        if m:
            name = m.group(2)
            in_entry = bool(m.group(1))
            current_skipped = name in fused
            continue
        if s == "}":
            current_skipped = False
            in_entry = False
            continue
        if current_skipped:
            continue
        im = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", s)
        if not im:
            continue
        ty, op = im.groups()
        op = op.replace("-start", "")
        if op == "parameter":
            if in_entry:
                params += _shape_bytes(ty)
            continue
        if op in _SKIP_OPS:
            continue
        total += _shape_bytes(ty)
    return {"tensor_bytes": total, "param_bytes": params,
            "traffic_bytes": total + params}


def _specs(tree):
    return jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)


def production_config(arch: str, *, unroll: bool = False):
    """Production dtypes; ``unroll`` trades HLO size for cost fidelity.

    Two compiles per cell: the *scanned* module is what production runs and
    gives faithful ``memory_analysis`` (XLA reuses the loop body buffers);
    the *unrolled* module gives faithful ``cost_analysis`` + collective
    counts (XLA counts while-loop bodies exactly once, a 1/n_layers
    undercount).  The CPU buffer assigner does not reuse buffers across
    unrolled layers, so unrolled memory numbers are ignored.
    """
    cfg = get_config(arch)
    return dataclasses.replace(
        cfg, param_dtype="bfloat16", compute_dtype="bfloat16",
        scan_layers=not unroll,
        # production trick (Megatron-style): pad the embedding table so the
        # vocab dim shards over 'model'; odd vocabs otherwise replicate the
        # (B,C,V) fp32 loss chunks on every chip
        pad_vocab_to=256,
    )


def build_cell(arch: str, shape_name: str, mesh, *, microbatch: int = 0,
               unroll: bool = False, serve_tp: bool = False, kv_int8: bool = False,
               grad_bf16: bool = False, zero3: bool = False):
    """Returns (fn, args_specs, in_shardings, out_shardings, donate_argnums).

    Donation mirrors production: the train state and the KV/recurrent caches
    are donated (updated in place), so memory_analysis reflects the real
    footprint instead of double-counting input+output buffers.

    ``serve_tp`` switches prefill/decode cells to the tensor-parallel
    serving partition rules (hillclimb; see dist.sharding).
    """
    cfg = production_config(arch, unroll=unroll)
    if kv_int8:
        cfg = dataclasses.replace(cfg, kv_quant=True)
    shape = SHAPES[shape_name]
    opt_cfg = OptConfig()

    params_s = jax.eval_shape(partial(init_params, cfg), jax.random.PRNGKey(0))
    if serve_tp and shape.kind != "train":
        psh = SH.serve_param_shardings(mesh, params_s)
    elif zero3:
        psh = SH.param_shardings(mesh, params_s, mode="zero3")
    else:
        psh = SH.param_shardings(mesh, params_s)

    if shape.kind == "train":
        opt_s = jax.eval_shape(partial(init_opt_state, cfg=opt_cfg), params_s)
        osh = SH.opt_state_shardings(mesh, psh, opt_s)
        batch_s = input_specs(cfg, shape)
        bsh = SH.batch_shardings(mesh, batch_s, mode="zero3" if zero3 else "2d")
        state_s = {"params": params_s, "opt": opt_s}
        ssh = {"params": psh, "opt": osh}
        if "pod" in mesh.axis_names and instrument.get_mode() != "off":
            # paper-faithful multi-pod step: explicit cross-pod reduce
            psh2 = SH.param_shardings(mesh, params_s, include_pod=False, gather_safe=True)
            osh2 = SH.opt_state_shardings(mesh, psh2, opt_s)
            ssh = {"params": psh2, "opt": osh2}
            fn = make_pod_train_step(cfg, opt_cfg, mesh, TrainConfig(pod_reduce="manual"))
        else:
            fn = make_train_step(cfg, opt_cfg, TrainConfig(
                microbatch=microbatch,
                grad_reduce_dtype="bfloat16" if grad_bf16 else "",
            ))
        return fn, (state_s, batch_s), (ssh, bsh), (ssh, None), (0,)

    prefill_step, decode_step = make_serve_steps(cfg)
    cache_s = jax.eval_shape(
        partial(init_cache, cfg, shape.global_batch, shape.seq_len)
    )
    csh = SH.cache_shardings(mesh, cache_s, serve_tp=serve_tp)
    if shape.kind == "prefill":
        batch_s = input_specs(cfg, shape)
        bsh = SH.batch_shardings(mesh, batch_s)
        return (
            prefill_step,
            (params_s, batch_s, cache_s),
            (psh, bsh, csh),
            (None, csh),
            (2,),
        )
    # decode
    token_s, pos_s = decode_inputs_specs(cfg, shape)
    tsh = SH.batch_shardings(mesh, token_s)
    return (
        decode_step,
        (params_s, token_s, pos_s, cache_s),
        (psh, tsh, None, csh),
        (None, csh),
        (3,),
    )


def run_cell(arch: str, shape_name: str, mesh_kind: str, *, force=False,
             instrument_mode: str = "off", tag: str = "", microbatch: int = 0,
             serve_tp: bool = False, kv_int8: bool = False,
             skip_unroll: bool = False, grad_bf16: bool = False,
             zero3: bool = False) -> dict:
    os.makedirs(ART_DIR, exist_ok=True)
    suffix = f"-{tag}" if tag else ""
    path = os.path.join(ART_DIR, f"{arch}__{shape_name}__{mesh_kind}{suffix}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "tag": tag,
        "instrument": instrument_mode, "status": "started",
    }
    if not cell_is_runnable(arch, shape_name):
        record["status"] = "skipped"
        record["reason"] = "long_500k requires sub-quadratic attention (see DESIGN.md)"
        with open(path, "w") as f:
            json.dump(record, f, indent=1)
        return record

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    install_constraint(SH.activation_constraint_fn(
        mesh, mode="zero3" if zero3 else "2d"))
    instrument.set_mode(instrument_mode)
    try:
        with set_mesh(mesh):
            # ---- phase 1: scanned module -> memory analysis (production) --
            t0 = time.time()
            fn, args_s, in_sh, out_sh, donate = build_cell(
                arch, shape_name, mesh, microbatch=microbatch, unroll=False,
                serve_tp=serve_tp, kv_int8=kv_int8, grad_bf16=grad_bf16,
                zero3=zero3,
            )
            compiled = (
                jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                        donate_argnums=donate)
                .lower(*args_s)
                .compile()
            )
            t_scan = time.time() - t0
            ma = compiled.memory_analysis()
            log.debug("memory_analysis", cell=f"{arch}/{shape_name}/{mesh_kind}",
                      analysis=str(ma))
            record.update(
                status="ok",
                compile_scan_s=round(t_scan, 2),
                n_devices=int(np.prod(list(mesh.shape.values()))),
                memory={
                    "argument_bytes": ma.argument_size_in_bytes,
                    "output_bytes": ma.output_size_in_bytes,
                    "temp_bytes": ma.temp_size_in_bytes,
                    "alias_bytes": ma.alias_size_in_bytes,
                    # args already include donated buffers; outputs alias
                    # into them, so only the non-aliased output remainder
                    # adds to the physical peak
                    "peak_args_plus_temp": ma.argument_size_in_bytes
                    + ma.temp_size_in_bytes
                    + max(0, ma.output_size_in_bytes - ma.alias_size_in_bytes),
                },
            )
            del compiled

            # ---- phase 2: unrolled module -> cost + collective analysis ---
            # (skippable for multi-pod cells: compile success + memory are
            # the deliverable there; the roofline table is single-pod)
            if skip_unroll:
                record["cost_phase"] = "skipped"
                with open(path, "w") as f:
                    json.dump(record, f, indent=1)
                return record
            t0 = time.time()
            fn, args_s, in_sh, out_sh, donate = build_cell(
                arch, shape_name, mesh, microbatch=microbatch, unroll=True,
                serve_tp=serve_tp, kv_int8=kv_int8, grad_bf16=grad_bf16,
                zero3=zero3,
            )
            compiled = (
                jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                        donate_argnums=donate)
                .lower(*args_s)
                .compile()
            )
            t_unroll = time.time() - t0
            ca = compiled.cost_analysis()
            log.debug("cost_analysis", cell=f"{arch}/{shape_name}/{mesh_kind}",
                      flops=ca.get("flops"),
                      bytes_accessed=ca.get("bytes accessed"))
            hlo = compiled.as_text()
            record.update(
                compile_unroll_s=round(t_unroll, 2),
                cost={
                    "flops": ca.get("flops", 0.0),
                    "bytes_accessed": ca.get("bytes accessed", 0.0),
                    "transcendentals": ca.get("transcendentals", 0.0),
                },
                collectives=parse_collectives(hlo),
                traffic=parse_memory_traffic(hlo),
                hlo_bytes=len(hlo),
            )
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      trace=traceback.format_exc()[-4000:])
    finally:
        instrument.set_mode("off")
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    return record


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list(ARCHS), default=None)
    ap.add_argument("--shape", choices=list(SHAPES), default=None)
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"], default="pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--instrument", choices=["off", "barrier"], default="off")
    ap.add_argument("--tag", default="")
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--serve-tp", action="store_true",
                    help="TP serving shardings for prefill/decode (hillclimb)")
    ap.add_argument("--kv-int8", action="store_true",
                    help="int8-quantized KV cache (hillclimb)")
    ap.add_argument("--skip-unroll", action="store_true",
                    help="phase 1 only: prove compile + memory (multipod)")
    ap.add_argument("--grad-bf16", action="store_true",
                    help="bf16 gradient reduction (hillclimb)")
    ap.add_argument("--zero3", action="store_true",
                    help="pure ZeRO-3 sharding, no TP (hillclimb)")
    obslog.add_flags(ap)
    args = ap.parse_args()
    obslog.configure_from_args(args)

    cells = []
    archs = list(ARCHS[:10]) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    for a in archs:
        for s in shapes:
            for m in meshes:
                cells.append((a, s, m))
    if args.list:
        for c in cells:
            print(*c)
        return
    ok = err = skip = 0
    for a, s, m in cells:
        rec = run_cell(a, s, m, force=args.force, instrument_mode=args.instrument,
                       tag=args.tag, microbatch=args.microbatch,
                       serve_tp=args.serve_tp, kv_int8=args.kv_int8,
                       skip_unroll=args.skip_unroll, grad_bf16=args.grad_bf16,
                       zero3=args.zero3)
        status = rec["status"]
        ok += status == "ok"
        err += status == "error"
        skip += status == "skipped"
        fields = {"cell": f"{a} {s} {m}", "status": status}
        if status == "ok":
            fields["peak_gib"] = rec["memory"]["peak_args_plus_temp"] / 2**30
            fields["compile_s"] = (f"{rec.get('compile_scan_s')}+"
                                   f"{rec.get('compile_unroll_s')}")
            log.info("cell", **fields)
        elif status == "error":
            fields["error"] = rec["error"][:120]
            log.error("cell", **fields)
        else:
            log.info("cell", **fields)
    log.info("done", ok=ok, skipped=skip, errors=err)
    sys.exit(1 if err else 0)


if __name__ == "__main__":
    main()
