"""``repro.launch`` — runnable drivers (each is a ``python -m`` entry point).

``train``   elastic production training: checkpoint/restart + mesh-epoch
            recovery from injected node failures.
``serve``   serving driver: legacy static batch or continuous batching
            with paged KV, Poisson arrivals, and governor-priced slack.
``dryrun``  AOT sweep: lower + compile every (arch x shape x mesh) cell.
``mesh``    production/host mesh constructors.

Submodules import jax and are loaded lazily (PEP 562) so that
``import repro.launch`` stays cheap for tooling.
"""
import importlib

_SUBMODULES = ("dryrun", "mesh", "serve", "train")

__all__ = list(_SUBMODULES)


def __getattr__(name: str):
    if name in _SUBMODULES:
        return importlib.import_module(f"repro.launch.{name}")
    raise AttributeError(f"module 'repro.launch' has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_SUBMODULES))
