"""Production training driver: checkpoint/restart, simulated node failures,
elastic re-meshing, straggler telemetry, COUNTDOWN instrumentation.

Example (this container):
  PYTHONPATH=src python -m repro.launch.train --arch countdown-100m \
      --steps 20 --batch 8 --seq 128 --checkpoint-dir /tmp/ckpt \
      --save-every 5 --fail-at 12

On a real cluster the same driver runs under one process per host with
jax.distributed.initialize(); the mesh factory, sharding rules, checkpoint
protocol and failure path are identical.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core import instrument
from repro.core.governor import Governor
from repro.core.policies import policy_for_theta
from repro.dist import sharding as SH
from repro.dist.checkpoint import CheckpointManager
from repro.dist.compat import set_mesh
from repro.dist.elastic import ElasticMesh, FailureInjector
from repro.models.hooks import install_constraint
from repro.train.data import DataLoader
from repro.train.loop import TrainConfig, init_state, make_train_step
from repro.train.optimizer import OptConfig


def build(mesh, cfg, opt_cfg, state_host):
    install_constraint(SH.activation_constraint_fn(mesh))
    ps = SH.param_shardings(mesh, state_host["params"])
    osd = SH.opt_state_shardings(mesh, ps, state_host["opt"])
    sh = {"params": ps, "opt": osd}
    state = jax.tree.map(lambda a, s: jax.device_put(a, s), state_host, sh)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0,))
    return state, step_fn


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="countdown-100m")
    ap.add_argument("--reduced", action="store_true", help="smoke-size config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at", type=int, default=0,
                    help="simulate a node failure at this step (fault-tolerance demo)")
    ap.add_argument("--instrument", choices=["off", "barrier", "profile"], default="off")
    ap.add_argument("--theta", default="",
                    help="governor timeout: seconds (e.g. 500e-6), or 'auto' for "
                         "the online ThetaTuner (cntd_adaptive policy); empty = "
                         "the policy default (500 us fixed)")
    ap.add_argument("--trace-out", default="",
                    help="record the governor's event stream to this JSONL file "
                         "(replayable via repro.cluster.trace; implies --instrument profile)")
    ap.add_argument("--power-cap", type=float, default=0.0,
                    help="job power cap in watts: attach a cluster.GovernorJob tenant "
                         "+ RAPL-style cap actuator and report per-interval power "
                         "(implies --instrument profile)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    cfg = dataclasses.replace(cfg, remat=True)
    opt_cfg = OptConfig(lr=args.lr, warmup_steps=min(100, args.steps // 10 + 1),
                        total_steps=args.steps)

    recorder = None
    if args.trace_out:
        from repro.cluster.trace import TraceRecorder

        recorder = TraceRecorder(meta={"driver": "train", "arch": args.arch,
                                       "steps": args.steps,
                                       "theta": args.theta or "default"})
    if (args.trace_out or args.power_cap > 0 or args.theta) and args.instrument != "profile":
        # the recorder records events, the tenant polls interval snapshots,
        # and the governor/tuner consumes them: all are empty (a silent
        # no-op) without the profile-mode event stream
        print(f"[train] --trace-out/--power-cap/--theta need phase events: "
              f"instrument {args.instrument!r} -> 'profile'")
        args.instrument = "profile"
    governor = Governor(policy=policy_for_theta(args.theta), recorder=recorder)
    tenant = None
    if args.power_cap > 0:
        from repro.cluster.job import GovernorJob

        tenant = GovernorJob("train", governor, n_ranks=len(jax.devices()),
                             cap_w=args.power_cap)
    if args.instrument != "off":
        instrument.set_mode(args.instrument)
        if args.instrument == "profile":
            # the governor is one bus subscriber among N (trace recorders,
            # probes, ... attach beside it without displacing anything)
            instrument.get_event_bus().subscribe(governor)

    em = ElasticMesh(axis_names=("data", "model"))
    mesh = em.build(model_parallel=args.model_parallel)
    injector = FailureInjector(
        fail_at_steps=[args.fail_at] if args.fail_at else [],
        device_ids=[jax.devices()[-1].id],
    )
    mgr = CheckpointManager(args.checkpoint_dir, keep=3) if args.checkpoint_dir else None

    state_host = init_state(cfg, opt_cfg, jax.random.PRNGKey(args.seed))
    start_step = 0
    if mgr and args.resume:
        latest = mgr.latest_step()
        if latest is not None:
            skel = jax.tree.map(lambda a: np.zeros(a.shape, a.dtype), state_host)
            start_step, state_host = latest, mgr.load(latest, skel)
            print(f"[train] resumed from step {latest}")

    state, step_fn = build(mesh, cfg, opt_cfg, state_host)
    loader = DataLoader(cfg, batch=args.batch, seq_len=args.seq, seed=args.seed)

    t_start = time.time()
    step = start_step
    # mesh-epoch loop: each epoch runs under one mesh; a failure breaks out,
    # rebuilds the mesh from the surviving devices and restores the latest
    # checkpoint (the 1000-node recovery path, scaled down)
    while step < args.steps:
        failed_device = None
        with set_mesh(mesh):
            while step < args.steps:
                failed_device = injector.check(step)
                if failed_device is not None:
                    break
                batch = next(loader)
                state, metrics = step_fn(state, batch)
                step += 1
                if mgr and step % args.save_every == 0:
                    mgr.save(step, jax.device_get(state))
                if step % max(1, args.steps // 20) == 0 or step == args.steps:
                    print(
                        f"[train] step {step:5d} loss={float(metrics['loss']):.4f} "
                        f"gnorm={float(metrics['grad_norm']):.3f} "
                        f"lr={float(metrics['lr']):.2e} "
                        f"({(time.time() - t_start) / max(step - start_step, 1):.2f}s/step)",
                        flush=True,
                    )
                    if tenant is not None:
                        er = tenant.run_epoch(args.power_cap)
                        print(f"[power] cap={er.cap_w:.1f}W draw={er.power_w:.1f}W "
                              f"exploited={100 * er.exploited_ratio:.1f}% "
                              f"({er.n_calls} phases)", flush=True)
        if failed_device is not None:
            print(f"[train] step {step}: device {failed_device} FAILED; re-meshing")
            jax.block_until_ready(state)            # drain in-flight work
            em.fail(failed_device)
            if mgr is None:
                raise RuntimeError("node failure without checkpointing enabled")
            latest = mgr.latest_step()
            host_state = jax.device_get(state)
            del state
            jax.clear_caches()                      # old-mesh executables out
            if latest is None:
                # failed before the first checkpoint: cold restart from init
                latest = 0
                state_host = init_state(cfg, opt_cfg, jax.random.PRNGKey(args.seed))
            else:
                skel = jax.tree.map(
                    lambda a: np.zeros(a.shape, a.dtype), host_state
                )
                state_host = mgr.load(latest, skel)
            mesh = em.build(model_parallel=args.model_parallel)
            state, step_fn = build(mesh, cfg, opt_cfg, state_host)
            step = latest
            print(f"[train] resumed on {len(em.healthy_devices())} devices "
                  f"from step {latest}")
    loader.close()
    if args.instrument == "profile":
        rep = governor.finalize()
        print(f"[governor] calls={rep.n_calls} downshifts={rep.n_downshifts} "
              f"slack={rep.total_slack:.4f}s exploited={rep.exploited_slack:.4f}s "
              f"overlap={rep.total_overlap:.4f}s "
              f"energy_saving={rep.energy_saving_pct:.2f}% "
              f"stragglers={rep.stragglers}")
        if governor.tuner is not None:
            thetas = sorted(governor.tuner.summary().values())
            print(f"[governor] theta auto: {rep.n_theta_decisions} decisions, "
                  f"{len(thetas)} sites, theta_eff "
                  f"{thetas[0] * 1e6:.0f}-{thetas[-1] * 1e6:.0f} us"
                  if thetas else "[governor] theta auto: no sites observed")
    if tenant is not None:
        print(f"[power] job total: {tenant.total_energy_j:.1f}J over "
              f"{tenant.total_wall_s:.1f}s, cap commits "
              f"{len(tenant.actuator.commits)} (suppressed {tenant.actuator.n_suppressed})")
    if recorder is not None:
        if args.instrument == "profile":
            recorder.meta["report"] = rep.to_dict()
        path = recorder.save(args.trace_out)
        print(f"[trace] {recorder.n_seen} records ({recorder.n_dropped} dropped) -> {path}")
    instrument.set_mode("off")
    instrument.get_event_bus().unsubscribe(governor)


if __name__ == "__main__":
    main()
