"""Production training driver: checkpoint/restart, simulated node failures,
elastic re-meshing, straggler telemetry, COUNTDOWN instrumentation.

Example (this container):
  PYTHONPATH=src python -m repro.launch.train --arch countdown-100m \
      --steps 20 --batch 8 --seq 128 --checkpoint-dir /tmp/ckpt \
      --save-every 5 --fail-at 12

On a real cluster the same driver runs under one process per host with
jax.distributed.initialize(); the mesh factory, sharding rules, checkpoint
protocol and failure path are identical.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core import instrument
from repro.core.governor import Governor
from repro.core.policies import policy_for_theta
from repro.dist import sharding as SH
from repro.dist.checkpoint import CheckpointManager
from repro.dist.compat import set_mesh
from repro.dist.elastic import ElasticMesh, FailureInjector
from repro.models.hooks import install_constraint
from repro.obs import log as obslog
from repro.train.data import DataLoader
from repro.train.loop import TrainConfig, init_state, make_train_step
from repro.train.optimizer import OptConfig

log = obslog.get_logger("train")


def build(mesh, cfg, opt_cfg, state_host):
    install_constraint(SH.activation_constraint_fn(mesh))
    ps = SH.param_shardings(mesh, state_host["params"])
    osd = SH.opt_state_shardings(mesh, ps, state_host["opt"])
    sh = {"params": ps, "opt": osd}
    state = jax.tree.map(lambda a, s: jax.device_put(a, s), state_host, sh)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0,))
    return state, step_fn


def build_live(mesh, cfg, opt_cfg, state_host):
    """Fully-manual data-parallel step with instrumented collectives.

    The production ``build`` path partitions with jit + sharding rules; its
    collectives are XLA-inserted, so the host phase events (io_callback)
    that feed the governor/telemetry never fire.  ``--live-events`` swaps in
    this step: replicated params/opt, batch split over "data", and the
    gradient/loss all-reduce routed through ``cd_psum`` — the artificial
    barrier + 3-phase event sequence of the paper's PMPI layer, legal here
    because the whole region is manual over every mesh axis.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.instrument import cd_psum
    from repro.dist.compat import shard_map
    from repro.models.transformer import loss_fn
    from repro.train.optimizer import adamw_update

    n_data = int(mesh.shape["data"])

    def per_device_step(state, batch):
        params, opt = state["params"], state["opt"]
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch)[0])(params)
        grads = cd_psum(grads, "data")
        grads = jax.tree.map(lambda g: g / n_data, grads)
        loss = cd_psum(loss, "data") / n_data
        params, opt, m = adamw_update(params, grads, opt, opt_cfg)
        return {"params": params, "opt": opt}, {**m, "loss": loss}

    repl = NamedSharding(mesh, P())
    dsh = NamedSharding(mesh, P("data"))
    state = jax.device_put(state_host,
                           jax.tree.map(lambda _: repl, state_host))
    # fully-specified jit shardings: required on the pinned container jax
    # (the profile-mode io_callback token otherwise desyncs XLA's
    # sharding-propagation parameter vector)
    step_fn = jax.jit(
        shard_map(
            per_device_step, mesh=mesh,
            in_specs=(P(), P("data")), out_specs=(P(), P()),
            manual_axes=set(mesh.axis_names),
        ),
        in_shardings=(
            jax.tree.map(lambda _: repl, state),
            {"tokens": dsh, "labels": dsh, "mask": dsh},
        ),
        out_shardings=(jax.tree.map(lambda _: repl, state),
                       {"grad_norm": repl, "lr": repl, "loss": repl}),
    )
    return state, step_fn


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="countdown-100m")
    ap.add_argument("--reduced", action="store_true", help="smoke-size config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at", type=int, default=0,
                    help="simulate a node failure at this step (fault-tolerance demo)")
    ap.add_argument("--instrument", choices=["off", "barrier", "profile"], default="off")
    ap.add_argument("--live-events", action="store_true",
                    help="run the step as a fully-manual data-parallel shard_map "
                         "with cd_psum gradient reduction, so host phase events "
                         "actually fire (the jit path's XLA-inserted collectives "
                         "cannot emit them); implies --instrument profile and "
                         "data parallelism only")
    ap.add_argument("--theta", default="",
                    help="governor timeout: seconds (e.g. 500e-6), 'auto' for "
                         "the online ThetaTuner (cntd_adaptive policy), or "
                         "'predictive' for the guarded predictor+timeout "
                         "hybrid (cntd_predictive: pre-arms the downshift "
                         "when predicted slack clears the residue-cost bar); "
                         "empty = the policy default (500 us fixed)")
    ap.add_argument("--trace-out", default="",
                    help="record the governor's event stream to this JSONL file "
                         "(replayable via repro.cluster.trace; implies --instrument profile)")
    ap.add_argument("--power-cap", type=float, default=0.0,
                    help="job power cap in watts: attach a cluster.GovernorJob tenant "
                         "+ RAPL-style cap actuator and report per-interval power "
                         "(implies --instrument profile)")
    ap.add_argument("--perfetto-out", default="",
                    help="write a Chrome-trace/Perfetto JSON of the run (per-rank "
                         "phase tracks + governor/arbiter counter tracks; implies "
                         "--instrument profile)")
    ap.add_argument("--metrics-out", default="",
                    help="append one metrics-registry snapshot per report interval "
                         "to this JSONL file, each embedding the exact cumulative "
                         "GovernorReport (implies --instrument profile)")
    ap.add_argument("--dashboard", action="store_true",
                    help="render a console telemetry dashboard at the report "
                         "cadence (implies --instrument profile)")
    ap.add_argument("--ingest", choices=["event", "batched"], default="event",
                    help="event-bus ingestion: 'event' publishes each phase "
                         "event as it fires; 'batched' accumulates fixed-dtype "
                         "EventBatch columns (21 B/event) and delivers them "
                         "chunk-at-a-time to batch-capable subscribers — same "
                         "stream order, bit-identical governor report, ~8x the "
                         "sink throughput")
    obslog.add_flags(ap)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    obslog.configure_from_args(args)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    cfg = dataclasses.replace(cfg, remat=True)
    opt_cfg = OptConfig(lr=args.lr, warmup_steps=min(100, args.steps // 10 + 1),
                        total_steps=args.steps)

    recorder = None
    if args.trace_out:
        from repro.cluster.trace import TraceRecorder

        recorder = TraceRecorder(meta={"driver": "train", "arch": args.arch,
                                       "steps": args.steps,
                                       "theta": args.theta or "default"})
    obs_on = bool(args.perfetto_out or args.metrics_out or args.dashboard)
    if args.live_events and args.model_parallel != 1:
        log.warning("live_events_dp_only", model_parallel=args.model_parallel,
                    using=1)
        args.model_parallel = 1
    if (args.trace_out or args.power_cap > 0 or args.theta or obs_on
            or args.live_events) and args.instrument != "profile":
        # the recorder records events, the tenant polls interval snapshots,
        # the telemetry stack consumes both, and the governor/tuner feeds
        # them all: everything is empty (a silent no-op) without the
        # profile-mode event stream
        log.info("instrument_upgrade", requested=args.instrument, using="profile",
                 why="--trace-out/--power-cap/--theta/telemetry need phase events")
        args.instrument = "profile"

    registry = tracer = collector = busmetrics = writer = dash = None
    if obs_on:
        from repro.obs.export import ConsoleDashboard, MetricsJsonlWriter
        from repro.obs.metrics import BusMetrics, GovernorCollector, MetricsRegistry
        from repro.obs.tracer import GovernorTap, RecorderFanout, SpanTracer

        registry = MetricsRegistry()
        busmetrics = BusMetrics(registry)
        if args.perfetto_out:
            tracer = SpanTracer(meta={"driver": "train", "arch": args.arch,
                                      "steps": args.steps})
        # production wiring: the whole obs stack rides the governor's
        # recorder slot (retired occurrences + theta decisions), never the
        # per-event bus — that is the 10% bench budget's contract
        tap = GovernorTap(tracer, metrics=busmetrics)
        recorder = RecorderFanout([recorder, tap]) if recorder is not None \
            else tap
    governor = Governor(policy=policy_for_theta(args.theta), recorder=recorder)
    if registry is not None:
        collector = GovernorCollector(registry, governor)
        if args.ingest == "batched":
            from repro.obs.metrics import IngestMetrics

            IngestMetrics(registry, instrument.get_event_bus())
        if args.metrics_out:
            writer = MetricsJsonlWriter(args.metrics_out, registry, collector)
        if args.dashboard:
            dash = ConsoleDashboard(registry, title=f"train {args.arch}")
    tenant = None
    if args.power_cap > 0:
        from repro.cluster.job import GovernorJob

        tenant = GovernorJob("train", governor, n_ranks=len(jax.devices()),
                             cap_w=args.power_cap)
        if registry is not None:
            tenant.attach_obs(registry, tracer, clock=time.monotonic)
    if args.instrument != "off":
        instrument.set_mode(args.instrument)
        if args.live_events:
            instrument.enable_events(True)   # fully-manual mesh: events legal
        if args.instrument == "profile":
            # the governor is one bus subscriber among N (probes attach
            # beside it without displacing anything); telemetry hangs off
            # the governor's recorder slot, not the bus
            bus = instrument.get_event_bus()
            bus.subscribe(governor)
        if args.ingest == "batched":
            instrument.set_ingest_mode("batched")

    em = ElasticMesh(axis_names=("data", "model"))
    mesh = em.build(model_parallel=args.model_parallel)
    injector = FailureInjector(
        fail_at_steps=[args.fail_at] if args.fail_at else [],
        device_ids=[jax.devices()[-1].id],
    )
    mgr = CheckpointManager(args.checkpoint_dir, keep=3) if args.checkpoint_dir else None

    state_host = init_state(cfg, opt_cfg, jax.random.PRNGKey(args.seed))
    start_step = 0
    if mgr and args.resume:
        latest = mgr.latest_step()
        if latest is not None:
            skel = jax.tree.map(lambda a: np.zeros(a.shape, a.dtype), state_host)
            start_step, state_host = latest, mgr.load(latest, skel)
            log.info("resumed", step=latest)

    builder = build_live if args.live_events else build
    state, step_fn = builder(mesh, cfg, opt_cfg, state_host)
    loader = DataLoader(cfg, batch=args.batch, seq_len=args.seq, seed=args.seed)

    t_start = time.time()
    step = start_step
    # mesh-epoch loop: each epoch runs under one mesh; a failure breaks out,
    # rebuilds the mesh from the surviving devices and restores the latest
    # checkpoint (the 1000-node recovery path, scaled down)
    while step < args.steps:
        failed_device = None
        with set_mesh(mesh):
            while step < args.steps:
                failed_device = injector.check(step)
                if failed_device is not None:
                    break
                batch = next(loader)
                state, metrics = step_fn(state, batch)
                step += 1
                if mgr and step % args.save_every == 0:
                    mgr.save(step, jax.device_get(state))
                if step % max(1, args.steps // 20) == 0 or step == args.steps:
                    log.info(
                        "step", step=step, loss=float(metrics["loss"]),
                        grad_norm=float(metrics["grad_norm"]),
                        lr=float(metrics["lr"]),
                        s_per_step=(time.time() - t_start)
                        / max(step - start_step, 1),
                    )
                    stats = collector.collect() if collector is not None else None
                    if tenant is not None:
                        # hand the collector's poll over: the governor keeps
                        # one snapshot mark, so tenant + collector must share
                        # a single interval stream
                        er = tenant.run_epoch(args.power_cap, stats=stats)
                        log.info("power", cap_w=er.cap_w, draw_w=er.power_w,
                                 exploited_pct=100 * er.exploited_ratio,
                                 phases=er.n_calls)
                    if tracer is not None and stats is not None:
                        tnow = time.monotonic()
                        busy = max(stats.busy, 1e-30)
                        tracer.sample("governor", "slack_ratio_pct", tnow,
                                      100.0 * stats.slack / busy)
                        tracer.sample("governor", "overlap_ratio_pct", tnow,
                                      100.0 * stats.overlap / busy)
                        saving = registry.get_value("governor_energy_saving_pct")
                        tracer.sample("governor", "energy_saving_pct", tnow,
                                      saving or 0.0)
                    if writer is not None:
                        writer.write(step=step)
                    if dash is not None:
                        dash.tick(step=step)
        if failed_device is not None:
            log.warning("device_failed", step=step, device=failed_device,
                        action="re-meshing")
            jax.block_until_ready(state)            # drain in-flight work
            em.fail(failed_device)
            if mgr is None:
                raise RuntimeError("node failure without checkpointing enabled")
            latest = mgr.latest_step()
            host_state = jax.device_get(state)
            del state
            jax.clear_caches()                      # old-mesh executables out
            if latest is None:
                # failed before the first checkpoint: cold restart from init
                latest = 0
                state_host = init_state(cfg, opt_cfg, jax.random.PRNGKey(args.seed))
            else:
                skel = jax.tree.map(
                    lambda a: np.zeros(a.shape, a.dtype), host_state
                )
                state_host = mgr.load(latest, skel)
            mesh = em.build(model_parallel=args.model_parallel)
            state, step_fn = builder(mesh, cfg, opt_cfg, state_host)
            step = latest
            log.info("resumed", devices=len(em.healthy_devices()), step=latest)
    loader.close()
    if args.ingest == "batched":
        # drain the partial accumulator + any queued chunks while the
        # governor is still subscribed, then drop back to per-event mode
        instrument.flush_events()
        instrument.set_ingest_mode("event")
    if args.instrument == "profile":
        rep = governor.finalize()
        log.info("governor", calls=rep.n_calls, downshifts=rep.n_downshifts,
                 slack_s=rep.total_slack, exploited_s=rep.exploited_slack,
                 overlap_s=rep.total_overlap,
                 energy_saving_pct=rep.energy_saving_pct,
                 stragglers=rep.stragglers)
        if governor.tuner is not None:
            thetas = sorted(governor.tuner.summary().values())
            if thetas:
                log.info("theta_auto", decisions=rep.n_theta_decisions,
                         sites=len(thetas), theta_lo_us=thetas[0] * 1e6,
                         theta_hi_us=thetas[-1] * 1e6)
            else:
                log.info("theta_auto", sites=0)
    if tenant is not None:
        log.info("power_total", energy_j=tenant.total_energy_j,
                 wall_s=tenant.total_wall_s,
                 cap_commits=len(tenant.actuator.commits),
                 suppressed=tenant.actuator.n_suppressed)
    if writer is not None:
        # one terminal snapshot: the acceptance contract is that this
        # line's embedded report equals the run's final GovernorReport
        writer.write(step=step)
        writer.close()
        log.info("metrics_out", path=args.metrics_out, lines=writer.n_lines)
    if dash is not None:
        dash.tick(step=step)
    if tracer is not None:
        tracer.ingest_governor(governor)    # spine-log actuations, once
        path = tracer.save(args.perfetto_out)
        log.info("perfetto_out", path=path, events=tracer.n_seen,
                 dropped=tracer.n_dropped)
    if recorder is not None and args.trace_out:
        trace_rec = recorder.children[0] if hasattr(recorder, "children") \
            else recorder
        if args.instrument == "profile":
            trace_rec.meta["report"] = rep.to_dict()
        path = trace_rec.save(args.trace_out)
        log.info("trace_out", records=trace_rec.n_seen,
                 dropped=trace_rec.n_dropped, path=path)
    instrument.set_mode("off")
    if args.live_events:
        instrument.enable_events(False)
    bus = instrument.get_event_bus()
    bus.unsubscribe(governor)


if __name__ == "__main__":
    main()
