"""Production mesh factories.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init, and nothing here may run earlier.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e target: 16x16 (256 chips) per pod; 2 pods over DCN."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1, pods: int = 1):
    """Small mesh over whatever devices this host actually has (examples)."""
    n = len(jax.devices())
    mp = max(g for g in range(1, model_parallel + 1) if n % g == 0)
    rest = n // mp
    if pods > 1 and rest % pods == 0:
        return jax.make_mesh((pods, rest // pods, mp), ("pod", "data", "model"))
    return jax.make_mesh((rest, mp), ("data", "model"))
