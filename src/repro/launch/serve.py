"""Serving driver: batched generation with the serving partition rules.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \
      --batch 4 --prompt-len 32 --steps 16 [--kv-int8]

On a multi-chip host this applies ``serve_param_shardings`` (TP weights,
flash-decoding cache layout); on this container it runs single-device.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax

from repro.configs import get_config, reduced
from repro.dist import sharding as SH
from repro.dist.compat import set_mesh
from repro.models import init_params
from repro.models.hooks import install_constraint
from repro.models.inputs import make_batch
from repro.serve.engine import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if args.kv_int8:
        cfg = dataclasses.replace(cfg, kv_quant=True)

    n = len(jax.devices())
    mp = max(g for g in range(1, args.model_parallel + 1) if n % g == 0)
    mesh = jax.make_mesh((n // mp, mp), ("data", "model"))
    install_constraint(SH.activation_constraint_fn(mesh))

    params = init_params(cfg, jax.random.PRNGKey(0))
    if mp > 1 or n > 1:
        psh = SH.serve_param_shardings(mesh, params)
        params = jax.tree.map(lambda a, s: jax.device_put(a, s), params, psh)

    with set_mesh(mesh):
        eng = ServeEngine(cfg, params, max_len=args.prompt_len + args.steps + 8,
                          temperature=args.temperature)
        batch = make_batch(cfg, batch=args.batch, seq_len=args.prompt_len,
                           kind="prefill")
        t0 = time.time()
        out = eng.generate(batch, n_steps=args.steps, key=jax.random.PRNGKey(1))
        dt = time.time() - t0
    print(f"[serve] {args.arch}: {out.shape} tokens in {dt:.2f}s "
          f"({args.batch * args.steps / dt:.1f} tok/s incl. compile, "
          f"kv_int8={args.kv_int8})")
    print(f"[serve] sample: {out[0].tolist()}")


if __name__ == "__main__":
    main()
