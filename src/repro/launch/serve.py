"""Serving driver: static batch, continuous batching, or a replica fleet.

  # legacy static batch (TP partition rules on a multi-chip host)
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \
      --batch 4 --prompt-len 32 --steps 16 [--kv-int8]

  # continuous batching: paged KV pool, Poisson arrivals, governor report
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \
      --continuous --n-requests 8 --arrival-rate 40 --slots 4 --page-size 8

  # replica fleet: N real engines behind the prefix-aware router, watt
  # arbitration per epoch (wall clock); add --autoscale for the
  # deterministic virtual-clock fleet with SLO-driven membership
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \
      --fleet 2 --fleet-trace flash-crowd --fleet-duration 3 \
      [--autoscale] [--metrics-out fleet.jsonl]

Timing excludes compilation: one warmup generate runs before the clock
starts and the compile time is printed separately.  On a multi-chip host
this applies ``serve_param_shardings`` (TP weights) and, in continuous
mode, ``page_pool_shardings`` for the paged KV pool; on this container it
runs single-device.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core.events import EventBus
from repro.core.governor import Governor
from repro.core.policies import policy_for_theta
from repro.dist import sharding as SH
from repro.dist.compat import set_mesh
from repro.models import init_params
from repro.models.hooks import install_constraint
from repro.models.inputs import make_batch
from repro.obs import log as obslog
from repro.serve import (
    ContinuousEngine,
    Request,
    ServeEngine,
    SLOTracker,
    poisson_arrivals,
)

log = obslog.get_logger("serve")


def _run_static(args, cfg, params) -> None:
    eng = ServeEngine(cfg, params, max_len=args.prompt_len + args.steps + 8,
                      temperature=args.temperature)
    batch = make_batch(cfg, batch=args.batch, seq_len=args.prompt_len,
                       kind="prefill")
    t0 = time.time()
    jax.block_until_ready(eng.generate(batch, n_steps=args.steps,
                                       key=jax.random.PRNGKey(1)))
    t_compile = time.time() - t0
    t0 = time.time()
    out = jax.block_until_ready(eng.generate(batch, n_steps=args.steps,
                                             key=jax.random.PRNGKey(1)))
    dt = time.time() - t0
    log.info("static_done", arch=args.arch, shape=str(out.shape), wall_s=dt,
             tok_per_s=args.batch * args.steps / dt, compile_s=t_compile,
             kv_int8=args.kv_int8)
    log.info("sample", tokens=out[0].tolist())


def _make_requests(args, cfg) -> list:
    rng = np.random.default_rng(args.seed)
    arrivals = poisson_arrivals(args.n_requests, args.arrival_rate, seed=args.seed,
                                burst_every=max(args.slots, 2), burst_gap=0.05)
    base_key = jax.random.PRNGKey(args.seed) if args.temperature > 0 else None
    reqs = []
    for i in range(args.n_requests):
        prompt = rng.integers(0, cfg.vocab, size=args.prompt_len).astype(np.int32)
        max_new = int(rng.integers(max(2, args.steps // 2), args.steps + 1))
        req = Request(prompt=prompt, max_new=max_new, arrival=float(arrivals[i]),
                      key=None if base_key is None else jax.random.fold_in(base_key, i))
        if cfg.n_prefix:
            req.prefix_embeds = rng.normal(
                0, 0.02, size=(cfg.n_prefix, cfg.d_model)
            ).astype(np.float32)
        reqs.append(req)
    return reqs


def _run_continuous(args, cfg, params, mesh, n_dev: int, mp: int) -> None:
    max_len = args.prompt_len + args.steps + args.page_size
    max_len += (-max_len) % args.page_size
    eng = ContinuousEngine(cfg, params, n_slots=args.slots, max_len=max_len,
                           page=args.page_size, temperature=args.temperature,
                           attn_kernel=args.attn_kernel)
    if mp > 1 or n_dev > 1:
        eng.pool.blocks = jax.device_put(
            eng.pool.blocks, SH.page_pool_shardings(mesh, eng.pool.blocks)
        )
    # warmup: compile prefill bucket + join + decode before the clock starts
    warm = make_batch(cfg, batch=1, seq_len=args.prompt_len, kind="prefill")
    t0 = time.time()
    eng.generate(warm, n_steps=2)
    t_compile = time.time() - t0

    recorder = trace_rec = None
    if args.trace_out:
        from repro.cluster.trace import TraceRecorder

        recorder = trace_rec = TraceRecorder(
            meta={"driver": "serve", "arch": args.arch,
                  "n_requests": args.n_requests,
                  "theta": args.theta or "default"})

    registry = tracer = collector = busmetrics = writer = dash = None
    obs_on = bool(args.perfetto_out or args.metrics_out or args.dashboard)
    if obs_on:
        from repro.obs.export import ConsoleDashboard, MetricsJsonlWriter
        from repro.obs.metrics import BusMetrics, GovernorCollector, MetricsRegistry
        from repro.obs.tracer import GovernorTap, RecorderFanout, SpanTracer

        registry = MetricsRegistry()
        busmetrics = BusMetrics(registry)
        if args.perfetto_out:
            tracer = SpanTracer(meta={"driver": "serve", "arch": args.arch,
                                      "n_requests": args.n_requests})
            eng.tracer = tracer
        # production wiring: metrics + tracer ride the governor's recorder
        # slot (ingested phases, retired occurrences, theta decisions) —
        # exactly one phase source each, never a second bus subscription,
        # or every phase would double-count
        tap = GovernorTap(tracer, metrics=busmetrics)
        recorder = RecorderFanout([recorder, tap]) if recorder is not None \
            else tap

    gov = Governor(policy=policy_for_theta(args.theta), recorder=recorder)
    # the engine publishes decode phases onto a bus, not into a governor:
    # the governor is just the first subscriber (add probes beside it)
    bus = EventBus()
    bus.subscribe(gov)
    if args.ingest == "batched":
        from repro.core import instrument

        instrument.set_ingest_mode("batched")
    if registry is not None:
        collector = GovernorCollector(registry, gov)
        if args.ingest == "batched":
            from repro.obs.metrics import IngestMetrics

            IngestMetrics(registry, bus)
        if args.metrics_out:
            writer = MetricsJsonlWriter(args.metrics_out, registry, collector)
        if args.dashboard:
            dash = ConsoleDashboard(registry, title=f"serve {args.arch}")
    tenant = None
    if args.power_cap > 0:
        from repro.cluster.job import ServeJob

        tenant = ServeJob("serve", eng, gov, cap_w=args.power_cap, n_ranks=n_dev)
        if registry is not None:
            tenant.attach_obs(registry, tracer, clock=time.monotonic)
    slo = SLOTracker(tpot_target=args.tpot_target or None)
    if registry is not None:
        registry.add_collector(lambda: slo.export_metrics(registry))
    reqs = _make_requests(args, cfg)
    t0 = time.time()
    done = eng.serve(reqs, governor=bus, slo=slo)
    dt = time.time() - t0
    if args.ingest == "batched":
        from repro.core import instrument

        instrument.flush_events()
        instrument.set_ingest_mode("event")
    n_tok = sum(len(r.out) for r in done)
    rep = gov.finalize()
    meter = eng._last_meter
    log.info("continuous_done", arch=args.arch, requests=len(done),
             tokens=n_tok, wall_s=dt, tok_per_s=n_tok / dt,
             compile_s=t_compile, fill=meter.fill_fraction,
             kv_int8=args.kv_int8)
    log.info("slack", priced_ms=rep.total_slack * 1e3, phases=rep.n_calls,
             downshifts=rep.n_downshifts, actuations=len(gov.actuation_log),
             energy_saving_pct=rep.energy_saving_pct)
    if gov.tuner is not None:
        per_site = {s: f"{th * 1e6:.0f}us" for s, th in gov.tuner.summary().items()}
        log.info("theta_auto", decisions=rep.n_theta_decisions,
                 theta_per_site=per_site)
    s = slo.summary()
    log.info("slo", ttft_p95_ms=s["ttft"]["p95"] * 1e3,
             tpot_p95_ms=s["tpot"]["p95"] * 1e3, completed=s["completed"])
    if tracer is not None:
        tnow = time.monotonic()
        tracer.sample("slo", "ttft_p95_ms", tnow, s["ttft"]["p95"] * 1e3)
        tracer.sample("slo", "tpot_p95_ms", tnow, s["tpot"]["p95"] * 1e3)
    if tenant is not None:
        stats = collector.collect() if collector is not None else None
        er = tenant.run_epoch(args.power_cap, stats=stats)
        log.info("power", cap_w=er.cap_w, draw_w=er.power_w,
                 exploited_pct=100 * er.exploited_ratio,
                 fill=tenant.fill_fraction)
    if writer is not None:
        writer.write()
        writer.close()
        log.info("metrics_out", path=args.metrics_out, lines=writer.n_lines)
    if dash is not None:
        dash.tick()
    if tracer is not None:
        tracer.ingest_governor(gov)         # spine-log actuations, once
        path = tracer.save(args.perfetto_out)
        log.info("perfetto_out", path=path, events=tracer.n_seen,
                 dropped=tracer.n_dropped)
    if trace_rec is not None:
        trace_rec.meta["report"] = rep.to_dict()
        path = trace_rec.save(args.trace_out)
        log.info("trace_out", records=trace_rec.n_seen,
                 dropped=trace_rec.n_dropped, path=path)


def _fleet_trace(args):
    from repro.serve.fleet import (
        diurnal_trace,
        flash_crowd_trace,
        session_reuse_trace,
    )

    if args.fleet_trace == "diurnal":
        return diurnal_trace(duration_s=args.fleet_duration, seed=args.seed)
    if args.fleet_trace == "session-reuse":
        return session_reuse_trace(seed=args.seed)
    return flash_crowd_trace(duration_s=args.fleet_duration, seed=args.seed)


def _fleet_metrics_out(args, fill_registry) -> None:
    """Export fleet metrics (and validate-able snapshots) if asked."""
    if not (args.metrics_out or args.dashboard):
        return
    from repro.obs.export import ConsoleDashboard, MetricsJsonlWriter
    from repro.obs.metrics import MetricsRegistry

    registry = MetricsRegistry()
    fill_registry(registry)
    if args.metrics_out:
        with MetricsJsonlWriter(args.metrics_out, registry) as writer:
            writer.write()
            log.info("metrics_out", path=args.metrics_out,
                     lines=writer.n_lines)
    if args.dashboard:
        ConsoleDashboard(registry, title=f"fleet {args.arch}").tick()


def _run_fleet_sim(args, cfg) -> None:
    """Deterministic virtual-clock fleet: membership changes allowed, so
    this is the ``--autoscale`` path (spawning a real engine mid-run would
    recompile; the sim replica warms up in ``warmup_s`` of virtual time)."""
    from repro.serve.fleet import FleetConfig, FleetSim

    cap_w = args.power_cap if args.power_cap > 0 else 40.0
    fc = FleetConfig(cfg=cfg, n_replicas=args.fleet,
                     autoscale=args.autoscale, min_replicas=1,
                     n_slots=args.slots, cap_w=cap_w, floor_w=4.0,
                     step_s=0.01, ttft_target=1.5)
    sim = FleetSim(fc)
    trace = _fleet_trace(args)
    res = sim.run(trace)
    log.info("fleet_done", trace=trace.name, autoscaled=args.autoscale,
             requests=res.n_completed, tokens=res.tokens_out,
             joules_per_token=res.joules_per_token,
             ttft_attainment=res.ttft_attainment,
             prefix_hit_rate=res.prefix_hit_rate,
             peak_replicas=res.n_replicas_peak,
             scale_ups=res.n_scale_ups, scale_downs=res.n_scale_downs,
             cap_w=res.cap_w, max_alloc_sum_w=res.max_alloc_sum_w)
    _fleet_metrics_out(args, sim.export_metrics)


def _run_fleet_real(args, cfg, params) -> None:
    """N real engines behind the router on the wall clock: fixed
    membership, per-epoch watt arbitration from each replica's governor."""
    from repro.serve import ContinuousEngine
    from repro.serve.fleet import run_engine_fleet

    trace = _fleet_trace(args)
    reqs = trace.fresh_requests()
    longest = max(len(r.prompt) + r.max_new for r in reqs)
    max_len = longest + args.page_size
    max_len += (-max_len) % args.page_size
    cap_w = args.power_cap if args.power_cap > 0 else 40.0

    if args.ingest == "batched":
        from repro.core import instrument

        instrument.set_ingest_mode("batched")
    engines, governors, slos = [], [], []
    t0 = time.time()
    for _ in range(args.fleet):
        eng = ContinuousEngine(cfg, params, n_slots=args.slots,
                               max_len=max_len, page=args.page_size,
                               temperature=args.temperature,
                               attn_kernel=args.attn_kernel)
        eng.enable_prefix_cache()
        warm = make_batch(cfg, batch=1, seq_len=len(reqs[0].prompt),
                          kind="prefill")
        eng.generate(warm, n_steps=2)
        engines.append(eng)
        governors.append(Governor(policy=policy_for_theta(args.theta)))
        slos.append(SLOTracker())
    t_compile = time.time() - t0

    t0 = time.time()
    finished, router, arbiter, _ = run_engine_fleet(
        engines, reqs, cap_w=cap_w, floor_w=4.0,
        governors=governors, slos=slos)
    dt = time.time() - t0
    if args.ingest == "batched":
        from repro.core import instrument

        instrument.flush_events()
        instrument.set_ingest_mode("event")
    n_tok = sum(len(r.out) for r in finished)
    hits = sum(e.prefix_cache.n_hits for e in engines)
    lookups = sum(e.prefix_cache.n_lookups for e in engines)
    log.info("fleet_done", trace=trace.name, replicas=args.fleet,
             requests=len(finished), tokens=n_tok, wall_s=dt,
             tok_per_s=n_tok / dt, compile_s=t_compile,
             routed=len(router.decisions),
             prefix_routed=router.n_prefix_routed,
             prefix_hits=hits, prefix_lookups=lookups, cap_w=cap_w)

    def fill(registry):
        router.export_metrics(registry)
        arbiter.export_metrics(registry)
        for k, (gov, slo) in enumerate(zip(governors, slos)):
            if k == 0:
                # one replica's SLO percentiles as the fleet sample; the
                # registry families are unlabelled per-run singletons
                slo.export_metrics(registry)
        registry.gauge("fleet_replicas", "live replicas").set(
            float(args.fleet))
        registry.gauge("fleet_prefix_hit_rate",
                       "prompt tokens served from resident pages").set(
                           sum(e.prefix_cache.tokens_matched
                               for e in engines)
                           / max(sum(e.prefix_cache.tokens_looked_up
                                     for e in engines), 1))

    _fleet_metrics_out(args, fill)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching over the paged KV pool")
    ap.add_argument("--fleet", type=int, default=0,
                    help="run N serving replicas behind the prefix-aware "
                         "router with per-epoch watt arbitration (real "
                         "engines on the wall clock; N is the static size, "
                         "or the autoscale maximum with --autoscale)")
    ap.add_argument("--autoscale", action="store_true",
                    help="fleet mode: SLO-driven membership on the "
                         "deterministic virtual-clock fleet simulator "
                         "(scale-ups/-downs reprice every replica's watts)")
    ap.add_argument("--fleet-trace",
                    choices=["flash-crowd", "diurnal", "session-reuse"],
                    default="flash-crowd",
                    help="fleet mode arrival scenario")
    ap.add_argument("--fleet-duration", type=float, default=10.0,
                    help="fleet trace duration in seconds (wall-clock for "
                         "--fleet, virtual for --autoscale)")
    ap.add_argument("--n-requests", type=int, default=8)
    ap.add_argument("--arrival-rate", type=float, default=40.0,
                    help="Poisson arrival rate (req/s)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--attn-kernel", choices=["xla", "pallas"], default="xla",
                    help="decode attention hot path: XLA gather/scatter "
                         "reference or the Pallas paged kernel (fused "
                         "dequant + scatter/sample epilogue)")
    ap.add_argument("--tpot-target", type=float, default=0.0,
                    help="TPOT SLO target (s); 0 disables throttling")
    ap.add_argument("--theta", default="",
                    help="governor timeout (continuous mode only): seconds, "
                         "'auto' for the online ThetaTuner (decode underfill/"
                         "idle feed its per-site histograms), or 'predictive' "
                         "for the guarded predictor+timeout hybrid "
                         "(cntd_predictive); empty = the policy default")
    ap.add_argument("--trace-out", default="",
                    help="record the governor's event stream to this JSONL file "
                         "(continuous mode; replayable via repro.cluster.trace)")
    ap.add_argument("--power-cap", type=float, default=0.0,
                    help="job power cap in watts: attach a cluster.ServeJob tenant "
                         "+ RAPL-style cap actuator and report draw vs cap")
    ap.add_argument("--perfetto-out", default="",
                    help="write a Chrome-trace/Perfetto JSON of the run "
                         "(continuous mode: decode phase track, governor "
                         "counters, serve join/evict instants)")
    ap.add_argument("--metrics-out", default="",
                    help="write metrics-registry snapshots (with the exact "
                         "cumulative GovernorReport) to this JSONL file "
                         "(continuous mode)")
    ap.add_argument("--dashboard", action="store_true",
                    help="render the telemetry dashboard after the run "
                         "(continuous mode)")
    ap.add_argument("--ingest", choices=["event", "batched"], default="event",
                    help="instrument-layer event ingestion: 'batched' "
                         "accumulates raw 5-phase events into fixed-dtype "
                         "EventBatch chunks and exports ingest-health metrics "
                         "(events/s, occupancy, queue depth); the continuous "
                         "engine's own phase stream is occurrence-granular "
                         "and unaffected")
    obslog.add_flags(ap)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    obslog.configure_from_args(args)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if args.kv_int8:
        cfg = dataclasses.replace(cfg, kv_quant=True)

    n = len(jax.devices())
    mp = max(g for g in range(1, args.model_parallel + 1) if n % g == 0)
    mesh = jax.make_mesh((n // mp, mp), ("data", "model"))
    install_constraint(SH.activation_constraint_fn(mesh))

    params = init_params(cfg, jax.random.PRNGKey(0))
    if mp > 1 or n > 1:
        psh = SH.serve_param_shardings(mesh, params)
        params = jax.tree.map(lambda a, s: jax.device_put(a, s), params, psh)

    if not args.continuous and not args.fleet and (
            args.theta or args.trace_out or args.power_cap > 0
            or args.perfetto_out or args.metrics_out
            or args.dashboard):
        # static mode builds no governor: these flags would be silent no-ops
        log.warning("flags_ignored",
                    why="--theta/--trace-out/--power-cap/telemetry need the "
                        "continuous engine's governor (add --continuous)")

    with set_mesh(mesh):
        if args.fleet:
            if args.autoscale:
                _run_fleet_sim(args, cfg)
            else:
                _run_fleet_real(args, cfg, params)
        elif args.continuous:
            _run_continuous(args, cfg, params, mesh, n, mp)
        else:
            _run_static(args, cfg, params)


if __name__ == "__main__":
    main()
