"""Synthetic LM data pipeline with background host prefetch.

Produces next-token-prediction batches from a deterministic synthetic corpus
(a mixture of Zipfian unigrams and repeated n-gram motifs so a real model
exhibits a real learning curve), double-buffered on a worker thread.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


class SyntheticCorpus:
    """Deterministic pseudo-corpus: Zipf unigrams + injected repeating motifs."""

    def __init__(self, vocab: int, seed: int = 0, motif_len: int = 16, n_motifs: int = 64):
        self.vocab = vocab
        self.rng = np.random.default_rng(seed)
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        probs = 1.0 / ranks ** 1.1
        self.probs = probs / probs.sum()
        self.motifs = self.rng.integers(0, vocab, (n_motifs, motif_len))

    def sample(self, batch: int, seq_len: int) -> np.ndarray:
        toks = self.rng.choice(self.vocab, size=(batch, seq_len + 1), p=self.probs)
        # splice motifs so there is learnable structure
        n_splice = max(1, seq_len // 64)
        for b in range(batch):
            for _ in range(n_splice):
                m = self.motifs[self.rng.integers(0, len(self.motifs))]
                start = self.rng.integers(0, seq_len + 1 - len(m))
                toks[b, start : start + len(m)] = m
        return toks.astype(np.int32)


class DataLoader:
    """Background-thread prefetching loader yielding model-ready batches."""

    def __init__(
        self,
        cfg: ModelConfig,
        batch: int,
        seq_len: int,
        seed: int = 0,
        prefetch: int = 2,
        sharding: Optional[Any] = None,
    ):
        self.cfg = cfg
        self.batch = batch
        self.seq_len = seq_len
        self.corpus = SyntheticCorpus(cfg.vocab, seed)
        self.sharding = sharding
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _make(self) -> Dict[str, Any]:
        """Hidden length S = n_prefix + T; tokens: (B,T); labels/mask: (B,S)."""
        cfg = self.cfg
        t = self.seq_len - cfg.n_prefix
        toks = self.corpus.sample(self.batch, t)              # (B, T+1)
        prefix_zeros = np.zeros((self.batch, cfg.n_prefix), np.int32)
        batch: Dict[str, Any] = {
            "tokens": toks[:, :t],
            "labels": np.concatenate([prefix_zeros, toks[:, 1 : t + 1]], axis=1),
        }
        mask = np.ones((self.batch, self.seq_len), np.float32)
        if cfg.n_prefix:
            mask[:, : cfg.n_prefix] = 0.0
            batch["prefix_embeds"] = np.asarray(
                self.corpus.rng.normal(0, 0.02, (self.batch, cfg.n_prefix, cfg.d_model)),
                np.float32,
            )
        batch["mask"] = mask
        return batch

    def _worker(self) -> None:
        while not self._stop.is_set():
            b = self._make()
            try:
                self._q.put(b, timeout=1.0)
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[Dict[str, Any]]:
        return self

    def __next__(self) -> Dict[str, Any]:
        host = self._q.get()
        if self.sharding is not None:
            return jax.tree.map(
                lambda a, s: jax.device_put(a, s), host, self.sharding
            )
        return jax.tree.map(jnp.asarray, host)

    def close(self) -> None:
        self._stop.set()
