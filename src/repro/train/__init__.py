"""``repro.train`` — step builders, optimizer, and synthetic data.

``loop``       :func:`make_train_step` (jit) and :func:`make_pod_train_step`
               (pod-explicit shard_map with instrumented collectives), plus
               :class:`TrainConfig` / :func:`init_state`.
``optimizer``  pure-pytree AdamW: :class:`OptConfig`, :func:`adamw_update`,
               warmup-cosine :func:`schedule`, :func:`global_norm`.
``data``       :class:`SyntheticCorpus` / :class:`DataLoader` deterministic
               token streams for smoke and benchmark runs.
"""
from repro.train.data import DataLoader, SyntheticCorpus  # noqa: F401
from repro.train.loop import (  # noqa: F401
    TrainConfig,
    init_state,
    make_pod_train_step,
    make_train_step,
)
from repro.train.optimizer import (  # noqa: F401
    OptConfig,
    adamw_update,
    global_norm,
    init_opt_state,
    schedule,
)

__all__ = [
    "DataLoader",
    "OptConfig",
    "SyntheticCorpus",
    "TrainConfig",
    "adamw_update",
    "global_norm",
    "init_opt_state",
    "init_state",
    "make_pod_train_step",
    "make_train_step",
    "schedule",
]
