"""AdamW with fp32 state and optional fp32 master weights (pure pytrees).

No optax dependency: init/update are plain functions so the whole optimizer
state shards with the parameter partition rules (FSDP over optimizer state
is what makes 100B+ models fit 16 GB/chip).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    master_fp32: bool = True
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_opt_state(params: Any, cfg: OptConfig) -> Dict[str, Any]:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "m": jax.tree.map(zeros32, params),
        "v": jax.tree.map(zeros32, params),
        "step": jnp.zeros((), jnp.int32),
    }
    all_fp32 = all(p.dtype == jnp.float32 for p in jax.tree.leaves(params))
    if cfg.master_fp32 and not all_fp32:
        # NOTE: only materialize masters for low-precision params; for fp32
        # params astype() would alias the SAME buffer into the state twice,
        # which breaks buffer donation (donate(a), donate(a)) and wastes HBM.
        state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return state


def global_norm(tree: Any) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def adamw_update(
    params: Any, grads: Any, state: Dict[str, Any], cfg: OptConfig
) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) if cfg.grad_clip else 1.0
    lr = schedule(cfg, step)
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    masters = state.get("master", params)

    def upd(p, master, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        mf = master.astype(jnp.float32)
        # decay only matrices (norm scales / biases / vectors exempt)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        new_master = mf - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + wd * mf)
        return new_master.astype(p.dtype), new_master, m, v

    flat = jax.tree.map(upd, params, masters, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_master = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[3], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step}
    if "master" in state:
        new_state["master"] = new_master
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
