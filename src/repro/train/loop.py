"""Training step builders: auto-sharded (jit) and pod-explicit (shard_map).

``make_train_step``      — pure-jit step; XLA inserts all collectives
                           (FSDP all-gathers, grad reduce-scatters).
``make_pod_train_step``  — multi-pod production step: within-pod sharding is
                           auto (XLA over data/model axes) while the cross-pod
                           gradient reduction is *explicit*, goes through the
                           COUNTDOWN-instrumented ``cd_psum`` (artificial
                           barrier + timeout-governed slack, per the paper),
                           and can be int8-compressed (beyond-paper knob).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.instrument import cd_psum
from repro.dist.compat import LEGACY_PARTIAL_MANUAL, shard_map
from repro.dist.compression import compressed_psum
from repro.models.transformer import loss_fn
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state


@dataclass(frozen=True)
class TrainConfig:
    microbatch: int = 0          # 0 = no accumulation; else per-step microbatch
    pod_reduce: str = "auto"     # auto | manual | compressed
    instrument_axis: str = "pod"
    grad_reduce_dtype: str = ""  # "" = grads keep their natural dtype;
                                 # "bfloat16" halves cross-device reduce wire


def _grads(cfg, params, batch, reduce_dtype: str = ""):
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, batch), has_aux=True
    )(params)
    if reduce_dtype:
        # cast before XLA inserts the cross-device reduction: halves the
        # all-reduce/reduce-scatter wire bytes (AdamW re-ups to fp32)
        from repro.models.layers import dtype_of

        dt = dtype_of(reduce_dtype)
        grads = jax.tree.map(lambda g: g.astype(dt), grads)
    return loss, metrics, grads


def _accumulated_grads(cfg, params, batch, microbatch: int):
    """lax.scan over microbatches — memory-bounded gradient accumulation."""
    b = batch["tokens"].shape[0]
    n = b // microbatch
    assert n * microbatch == b, "global batch must be divisible by microbatch"
    split = jax.tree.map(
        lambda a: a.reshape((n, microbatch) + a.shape[1:]) if a.ndim >= 1 else a, batch
    )

    def body(carry, mb):
        acc, loss_sum = carry
        loss, _, g = _grads(cfg, params, mb)
        acc = jax.tree.map(jnp.add, acc, g)
        return (acc, loss_sum + loss), None

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (acc, loss_sum), _ = jax.lax.scan(body, (zeros, jnp.zeros((), jnp.float32)), split)
    grads = jax.tree.map(lambda g: g / n, acc)
    return loss_sum / n, {}, grads


def make_train_step(
    cfg, opt_cfg: OptConfig, train_cfg: TrainConfig = TrainConfig()
) -> Callable:
    """(state, batch) -> (state, metrics); state = {params, opt}."""

    def train_step(state: Dict[str, Any], batch: Dict[str, Any]):
        params = state["params"]
        if train_cfg.microbatch:
            loss, metrics, grads = _accumulated_grads(cfg, params, batch, train_cfg.microbatch)
        else:
            loss, metrics, grads = _grads(cfg, params, batch, train_cfg.grad_reduce_dtype)
        new_params, new_opt, opt_metrics = adamw_update(params, grads, state["opt"], opt_cfg)
        out_metrics = {"loss": loss, **metrics, **opt_metrics}
        return {"params": new_params, "opt": new_opt}, out_metrics

    return train_step


def make_pod_train_step(
    cfg, opt_cfg: OptConfig, mesh: Mesh, train_cfg: TrainConfig = TrainConfig()
) -> Callable:
    """Cross-pod-explicit train step (requires a 'pod' mesh axis).

    Gradients are computed per pod (auto-sharded over data/model inside),
    then explicitly reduced over 'pod' via the instrumented collective.
    """
    if "pod" not in mesh.axis_names:
        raise ValueError("make_pod_train_step needs a mesh with a 'pod' axis")
    npod = mesh.shape["pod"]

    def reduce_grads(grads):
        if train_cfg.pod_reduce == "compressed":
            return compressed_psum(grads, "pod", mean=True)
        summed = cd_psum(grads, "pod")
        return jax.tree.map(lambda g: g / npod, summed)

    def _local_grads(params, batch, constraint=None):
        """Per-shard forward/backward under ``constraint`` (None = no
        activation hints: required in fully-manual regions, where wsc would
        name manual axes)."""
        from repro.models import hooks

        old = hooks._CONSTRAIN
        hooks.install_constraint(constraint)
        try:
            if train_cfg.microbatch:
                return _accumulated_grads(cfg, params, batch, train_cfg.microbatch)
            return _grads(cfg, params, batch)
        finally:
            hooks.install_constraint(old)

    if LEGACY_PARTIAL_MANUAL:
        # Legacy XLA aborts (IsManualSubgroup checks) whenever auto-sharded
        # operands cross a *partial*-manual shard_map boundary, so on these
        # versions the region is FULLY manual: parameters are gathered at
        # entry (the gather_safe layouts keep that a plain FSDP all-gather)
        # and each device computes its pod's full batch shard — split over
        # 'pod' only, exactly like the partial-manual path, so per-region
        # batch semantics (e.g. the microbatch divisibility contract) are
        # identical across jax versions; intra-pod replicas are then
        # reconciled with an explicit pmean.  The cross-pod reduction is
        # the same instrumented collective in both variants.
        intra = tuple(a for a in mesh.axis_names if a != "pod")

        def per_device(params, batch):
            loss, _, grads = _local_grads(params, batch)
            grads = reduce_grads(grads)                      # cross-pod, cd_*
            grads = jax.tree.map(lambda g: jax.lax.pmean(g, intra), grads)
            loss = jax.lax.pmean(loss, ("pod",) + intra)
            return loss, grads

        region = shard_map(
            per_device,
            mesh=mesh,
            in_specs=(P(), P("pod")),
            out_specs=(P(), P()),
            manual_axes=set(mesh.axis_names),
        )
    else:

        def per_pod(params, batch):
            # within-pod sharding is auto (XLA over data/model); only the
            # cross-pod reduction is explicit + instrumented.  Constraints
            # inside the manual-'pod' region must not name 'pod'.
            from repro.dist.sharding import activation_constraint_fn

            loss, _, grads = _local_grads(
                params, batch, activation_constraint_fn(mesh, exclude={"pod"})
            )
            grads = reduce_grads(grads)
            loss = jax.lax.pmean(loss, "pod")
            return loss, grads

        region = shard_map(
            per_pod,
            mesh=mesh,
            in_specs=(P(), P("pod")),
            out_specs=(P(), P()),
            manual_axes={"pod"},
        )

    def train_step(state: Dict[str, Any], batch: Dict[str, Any]):
        loss, grads = region(state["params"], batch)
        new_params, new_opt, opt_metrics = adamw_update(
            state["params"], grads, state["opt"], opt_cfg
        )
        return {"params": new_params, "opt": new_opt}, {"loss": loss, **opt_metrics}

    return train_step


def init_state(cfg, opt_cfg: OptConfig, key) -> Dict[str, Any]:
    from repro.models.transformer import init_params

    params = init_params(cfg, key)
    return {"params": params, "opt": init_opt_state(params, opt_cfg)}
