"""Shared neural-net layers: norms, RoPE, attention (full / banded / decode),
MLPs and the chunked cross-entropy.

Everything is a pure function over explicit parameter pytrees — no framework
dependency.  Attention is implemented three ways:

* ``naive``   — materialized scores, used for tiny smoke shapes;
* ``chunked`` — online-softmax over KV chunks (flash-equivalent in XLA), the
  default for long sequences and the semantics the Pallas kernel mirrors;
* ``banded``  — chunk-local attention for SWA / local-attention archs
  (sub-quadratic: each chunk attends to itself + the previous chunk).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

Params = Dict[str, Any]

NEG_INF = -1e30


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}[name]


# --------------------------------------------------------------------------
# initializers
# --------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def init_norm(cfg, d: int, dtype):
    if cfg.norm == "nonparametric":
        return {}
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype)}


def apply_norm(cfg, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm" or cfg.norm == "nonparametric":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * lax.rsqrt(var + 1e-5)
        if cfg.norm == "layernorm":
            y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * lax.rsqrt(ms + 1e-6) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope_frequencies(d_head: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D); positions: (S,) absolute positions."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                       # (D/2,)
    angles = positions[:, None].astype(jnp.float32) * freqs  # (S, D/2)
    cos = jnp.cos(angles)[None, :, None, :]
    sin = jnp.sin(angles)[None, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention cores
# --------------------------------------------------------------------------

def _gqa_reshape(q: jnp.ndarray, n_kv: int) -> jnp.ndarray:
    """(B,S,Hq,D) -> (B,S,Hkv,G,D)."""
    b, s, hq, d = q.shape
    return q.reshape(b, s, n_kv, hq // n_kv, d)


def naive_causal_attention(q, k, v, q_pos, k_pos, window: int = 0):
    """Materialized-scores attention.  q: (B,Sq,Hkv,G,D); k/v: (B,T,Hkv,D)."""
    d = q.shape[-1]
    s = jnp.einsum("bqkgd,btkd->bqkgt", q.astype(jnp.float32), k.astype(jnp.float32))
    s *= 1.0 / math.sqrt(d)
    mask = k_pos[None, :] <= q_pos[:, None]
    if window:
        mask &= k_pos[None, :] > (q_pos[:, None] - window)
    s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqkgt,btkd->bqkgd", p.astype(v.dtype), v)
    return out


def chunked_causal_attention(q, k, v, q_pos, k_pos, kv_chunk: int = 1024,
                             use_scan: bool = False):
    """Online-softmax attention over KV chunks (flash-equivalent, pure XLA).

    q: (B,Sq,Hkv,G,D); k/v: (B,T,Hkv,D); q_pos: (Sq,), k_pos: (T,).
    ``use_scan``: loop chunks with lax.scan (production: one reused score
    buffer) vs python-unrolled (cost-analysis module: while bodies are
    counted once by XLA, see launch/dryrun.py).
    """
    b, sq, hkv, g, d = q.shape
    t = k.shape[1]
    kv_chunk = min(kv_chunk, t)
    n = t // kv_chunk
    rem = t - n * kv_chunk
    scale = 1.0 / math.sqrt(d)

    def chunk_update(carry, kc, vc, kposc):
        m, l, acc = carry
        # bf16 operands, fp32 accumulation (no materialized fp32 copies)
        s = jnp.einsum(
            "bqkgd,btkd->bqkgt", q, kc, preferred_element_type=jnp.float32
        ) * scale
        mask = kposc[None, :] <= q_pos[:, None]                     # (Sq, Tc)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bqkgt,btkd->bqkgd", p.astype(vc.dtype), vc,
            preferred_element_type=jnp.float32,
        )
        return m_new, l, acc

    init = (
        jnp.full((b, sq, hkv, g), NEG_INF, jnp.float32),
        jnp.zeros((b, sq, hkv, g), jnp.float32),
        jnp.zeros((b, sq, hkv, g, d), jnp.float32),
    )
    if use_scan and n > 1:
        ks = k[:, : n * kv_chunk].reshape(b, n, kv_chunk, hkv, d).transpose(1, 0, 2, 3, 4)
        vs = v[:, : n * kv_chunk].reshape(b, n, kv_chunk, hkv, d).transpose(1, 0, 2, 3, 4)
        kps = k_pos[: n * kv_chunk].reshape(n, kv_chunk)

        def body(carry, xs):
            kc, vc, kpc = xs
            return chunk_update(carry, kc, vc, kpc), None

        init, _ = lax.scan(body, init, (ks, vs, kps))
    else:
        for i in range(n):
            sl = slice(i * kv_chunk, (i + 1) * kv_chunk)
            init = chunk_update(init, k[:, sl], v[:, sl], k_pos[sl])
    if rem:
        init = chunk_update(init, k[:, n * kv_chunk:], v[:, n * kv_chunk:], k_pos[n * kv_chunk:])
    m, l, acc = init
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    return out.astype(v.dtype)


def banded_attention(q, k, v, positions, window: int):
    """Sub-quadratic sliding-window attention.

    Sequence is cut into chunks of ``window``; each query chunk attends to
    (previous chunk ++ own chunk) with a causal + window mask.  O(S * 2W).
    q: (B,S,Hkv,G,D); k/v: (B,S,Hkv,D); positions: (S,).
    """
    b, s, hkv, g, d = q.shape
    w = min(window, s)
    pad = (-s) % w
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        positions = jnp.concatenate(
            [positions, positions[-1] + 1 + jnp.arange(pad, dtype=positions.dtype)]
        )
    sp = s + pad
    nc = sp // w
    qc = q.reshape(b, nc, w, hkv, g, d)
    kc = k.reshape(b, nc, w, hkv, d)
    vc = v.reshape(b, nc, w, hkv, d)
    pc = positions.reshape(nc, w)
    # previous chunk (chunk -1 is all-masked via position trick)
    k_prev = jnp.concatenate([jnp.zeros_like(kc[:, :1]), kc[:, :-1]], axis=1)
    v_prev = jnp.concatenate([jnp.zeros_like(vc[:, :1]), vc[:, :-1]], axis=1)
    p_prev = jnp.concatenate(
        [jnp.full_like(pc[:1], -(10 ** 9)), pc[:-1]], axis=0
    )
    k2 = jnp.concatenate([k_prev, kc], axis=2)               # (B,nc,2W,Hkv,D)
    v2 = jnp.concatenate([v_prev, vc], axis=2)
    p2 = jnp.concatenate([p_prev, pc], axis=1)               # (nc, 2W)
    scale = 1.0 / math.sqrt(d)
    sco = jnp.einsum(
        "bcqkgd,bctkd->bcqkgt", qc, k2, preferred_element_type=jnp.float32
    )
    sco *= scale
    mask = (p2[:, None, :] <= pc[:, :, None]) & (p2[:, None, :] > pc[:, :, None] - window)
    sco = jnp.where(mask[None, :, :, None, None, :], sco, NEG_INF)
    prob = jax.nn.softmax(sco, axis=-1)
    out = jnp.einsum(
        "bcqkgt,bctkd->bcqkgd", prob.astype(v2.dtype), v2,
        preferred_element_type=jnp.float32,
    ).astype(v2.dtype)
    out = out.reshape(b, sp, hkv, g, d)
    return out[:, :s]


# --------------------------------------------------------------------------
# attention block (projections + dispatch + cache handling)
# --------------------------------------------------------------------------

def init_attention(cfg, key, dtype) -> Params:
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, d, hq * hd, dtype),
        "wk": dense_init(k2, d, hkv * hd, dtype),
        "wv": dense_init(k3, d, hkv * hd, dtype),
        "wo": dense_init(k4, hq * hd, d, dtype, scale=1.0 / math.sqrt(hq * hd)),
    }


def _project_qkv(cfg, p, x):
    b, s, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, s, hq, hd)
    k = (x @ p["wk"]).reshape(b, s, hkv, hd)
    v = (x @ p["wv"]).reshape(b, s, hkv, hd)
    return q, k, v


def attention_forward(cfg, p, x, positions, *, impl: str = "auto"):
    """Training / prefill attention over a full sequence.  x: (B,S,d)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    qg = _gqa_reshape(q, cfg.n_kv_heads)
    windowed = cfg.attention in ("swa", "local") and cfg.window
    if impl == "auto":
        if windowed and s > cfg.window:
            impl = "banded"
        elif s > 512:
            impl = "chunked"
        else:
            impl = "naive"
    if impl == "banded" and windowed:
        out = banded_attention(qg, k, v, positions, cfg.window)
    elif impl == "chunked":
        # production (scanned) path: small chunks bound the f32 score tile
        # (VMEM/HBM working set); the unrolled cost-analysis module instead
        # bounds the CHUNK COUNT to keep HLO size / compile time tractable
        if cfg.scan_layers:
            kv_chunk = min(1024, max(512, s // 32))
        else:
            kv_chunk = max(1024, s // 8)
        out = chunked_causal_attention(
            qg, k, v, positions, positions, kv_chunk=kv_chunk,
            use_scan=cfg.scan_layers,
        )
        if windowed and s > cfg.window:
            raise ValueError("use banded impl for windowed attention on long seqs")
    else:
        out = naive_causal_attention(qg, k, v, positions, positions, window=cfg.window if windowed else 0)
    out = out.reshape(b, s, cfg.n_heads * cfg.head_dim)
    return out @ p["wo"]


def init_kv_cache(cfg, batch: int, max_len: int, dtype) -> Params:
    """Ring cache for windowed attention; linear cache otherwise.

    With ``cfg.kv_quant`` the cache is int8 with a per-(token, head) scale —
    halves the dominant decode memory (cache) at ~1 LSB/127 error.
    """
    windowed = cfg.attention in ("swa", "local") and cfg.window
    t = min(cfg.window, max_len) if windowed else max_len
    kv_dtype = jnp.int8 if cfg.kv_quant else dtype
    cache = {
        "k": jnp.zeros((batch, t, cfg.n_kv_heads, cfg.head_dim), kv_dtype),
        "v": jnp.zeros((batch, t, cfg.n_kv_heads, cfg.head_dim), kv_dtype),
        "slot_pos": jnp.full((t,), -1, jnp.int32),
    }
    if cfg.kv_quant:
        cache["k_scale"] = jnp.zeros((batch, t, cfg.n_kv_heads), jnp.float32)
        cache["v_scale"] = jnp.zeros((batch, t, cfg.n_kv_heads), jnp.float32)
    return cache


def _kv_quantize(x: jnp.ndarray):
    """x: (..., D) -> (int8 values, per-(...,) scale multiplier)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.round(x.astype(jnp.float32) / scale[..., None])
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale


def _kv_dequantize(q: jnp.ndarray, scale: jnp.ndarray, dtype) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def attention_prefill(cfg, p, x, positions, cache):
    """Run full-sequence attention and fill the cache.  Returns (out, cache)."""
    out = attention_forward(cfg, p, x, positions)
    _, k, v = _project_qkv(cfg, p, x)
    k = apply_rope(k, positions, cfg.rope_theta)
    if cfg.kv_quant:
        k, k_sc = _kv_quantize(k)
        v, v_sc = _kv_quantize(v)
    t = cache["k"].shape[1]
    s = x.shape[1]
    new_cache = dict(cache)
    if s >= t:
        # keep the last t entries (ring fully covered)
        slots = (positions[-t:] % t)
        new_cache["k"] = jnp.zeros_like(cache["k"]).at[:, slots].set(k[:, -t:])
        new_cache["v"] = jnp.zeros_like(cache["v"]).at[:, slots].set(v[:, -t:])
        new_cache["slot_pos"] = (
            jnp.full_like(cache["slot_pos"], -1)
            .at[slots].set(positions[-t:].astype(jnp.int32))
        )
        if cfg.kv_quant:
            new_cache["k_scale"] = jnp.zeros_like(cache["k_scale"]).at[:, slots].set(k_sc[:, -t:])
            new_cache["v_scale"] = jnp.zeros_like(cache["v_scale"]).at[:, slots].set(v_sc[:, -t:])
    else:
        slots = positions % t
        new_cache["k"] = cache["k"].at[:, slots].set(k)
        new_cache["v"] = cache["v"].at[:, slots].set(v)
        new_cache["slot_pos"] = cache["slot_pos"].at[slots].set(positions.astype(jnp.int32))
        if cfg.kv_quant:
            new_cache["k_scale"] = cache["k_scale"].at[:, slots].set(k_sc)
            new_cache["v_scale"] = cache["v_scale"].at[:, slots].set(v_sc)
    return out, new_cache


def attention_decode(cfg, p, x, pos, cache):
    """Single-token decode.  x: (B,1,d); pos: scalar int32 position."""
    b = x.shape[0]
    q, k, v = _project_qkv(cfg, p, x)                          # (B,1,H,D)
    pos_arr = jnp.reshape(pos, (1,)).astype(jnp.int32)
    q = apply_rope(q, pos_arr, cfg.rope_theta)
    k = apply_rope(k, pos_arr, cfg.rope_theta)
    t = cache["k"].shape[1]
    slot = (pos % t).astype(jnp.int32)
    new_cache = dict(cache)
    if cfg.kv_quant:
        kq, k_sc = _kv_quantize(k)
        vq, v_sc = _kv_quantize(v)
        new_cache["k_scale"] = lax.dynamic_update_slice(cache["k_scale"], k_sc, (0, slot, 0))
        new_cache["v_scale"] = lax.dynamic_update_slice(cache["v_scale"], v_sc, (0, slot, 0))
        k, v = kq, vq
    ck = lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
    cv = lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
    cp = lax.dynamic_update_slice(cache["slot_pos"], pos_arr, (slot,))
    new_cache.update(k=ck, v=cv, slot_pos=cp)
    if cfg.kv_quant:
        ck = _kv_dequantize(ck, new_cache["k_scale"], x.dtype)
        cv = _kv_dequantize(cv, new_cache["v_scale"], x.dtype)
    qg = _gqa_reshape(q, cfg.n_kv_heads)                       # (B,1,Hkv,G,D)
    d = cfg.head_dim
    # bf16 operands + fp32 accumulation: casting the cache to fp32 would
    # materialize a 2x-sized copy of the (dominant) KV traffic per step
    s = jnp.einsum(
        "bqkgd,btkd->bqkgt", qg, ck, preferred_element_type=jnp.float32
    )
    s *= 1.0 / math.sqrt(d)
    valid = (cp >= 0) & (cp <= pos)
    if cfg.attention in ("swa", "local") and cfg.window:
        valid &= cp > pos - cfg.window
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    prob = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bqkgt,btkd->bqkgd", prob.astype(cv.dtype), cv,
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)
    out = out.reshape(b, 1, cfg.n_heads * cfg.head_dim) @ p["wo"]
    return out, new_cache


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------

def init_mlp(cfg, key, dtype) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.family == "audio":  # musicgen: classic GELU MLP
        k1, k2 = jax.random.split(key)
        return {"w1": dense_init(k1, d, f, dtype), "w2": dense_init(k2, f, d, dtype)}
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": dense_init(k1, d, f, dtype),
        "w3": dense_init(k2, d, f, dtype),
        "w2": dense_init(k3, f, d, dtype),
    }


def mlp_forward(cfg, p, x):
    if "w3" not in p:
        return jax.nn.gelu(x @ p["w1"]) @ p["w2"]
    return (jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])) @ p["w2"]


# --------------------------------------------------------------------------
# chunked cross-entropy (never materializes full (B,S,V) logits)
# --------------------------------------------------------------------------

def chunked_cross_entropy(x, embed_t, labels, mask, chunk: int = 512,
                          use_scan: bool = False):
    """x: (B,S,d); embed_t: (d,V); labels,mask: (B,S).  Mean NLL over mask.

    ``use_scan`` as in chunked_causal_attention: production modules scan
    (one reused logits buffer); cost modules unroll.
    """
    b, s, d = x.shape
    chunk = min(chunk, s)
    n = s // chunk
    rem = s - n * chunk

    def chunk_loss(xc, lc, mc):
        from repro.models.hooks import constrain

        logits = constrain(xc @ embed_t, "logits").astype(jnp.float32)  # (B,C,V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = (lse - tgt) * mc
        return jnp.sum(nll), jnp.sum(mc)

    total, count = jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)
    if use_scan and n > 1:
        xs = x[:, : n * chunk].reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
        ls = labels[:, : n * chunk].reshape(b, n, chunk).transpose(1, 0, 2)
        ms = mask[:, : n * chunk].reshape(b, n, chunk).transpose(1, 0, 2)

        def body(carry, inp):
            t0, c0 = carry
            tl, cl = chunk_loss(*inp)
            return (t0 + tl, c0 + cl), None

        (total, count), _ = lax.scan(body, (total, count), (xs, ls, ms))
    else:
        for i in range(n):
            sl = slice(i * chunk, (i + 1) * chunk)
            tl, cl = chunk_loss(x[:, sl], labels[:, sl], mask[:, sl])
            total, count = total + tl, count + cl
    if rem:
        tl, cl = chunk_loss(x[:, n * chunk:], labels[:, n * chunk:], mask[:, n * chunk:])
        total, count = total + tl, count + cl
    return total / jnp.maximum(count, 1.0)
