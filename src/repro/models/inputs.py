"""Input specs and synthetic batch construction for every (arch × shape) cell.

``input_specs`` returns ``jax.ShapeDtypeStruct`` stand-ins (no allocation) —
the dry-run lowers against these.  ``make_batch`` materializes small real
batches for smoke tests and examples.  Modality frontends (audio frames /
vision patches) are stubs: precomputed prefix embeddings, per assignment.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


def _token_len(cfg: ModelConfig, seq_len: int) -> int:
    return seq_len - cfg.n_prefix


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig, batch_override: int = 0) -> Dict[str, Any]:
    b = batch_override or shape.global_batch
    s = shape.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, _token_len(cfg, s)), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "mask": jax.ShapeDtypeStruct((b, s), jnp.float32),
    }
    if cfg.n_prefix:
        specs["prefix_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.n_prefix, cfg.d_model), jnp.bfloat16
        )
    return specs


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig, batch_override: int = 0) -> Dict[str, Any]:
    b = batch_override or shape.global_batch
    s = shape.seq_len
    specs = {"tokens": jax.ShapeDtypeStruct((b, _token_len(cfg, s)), jnp.int32)}
    if cfg.n_prefix:
        specs["prefix_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.n_prefix, cfg.d_model), jnp.bfloat16
        )
    return specs


def decode_inputs_specs(cfg: ModelConfig, shape: ShapeConfig, batch_override: int = 0):
    b = batch_override or shape.global_batch
    token = jax.ShapeDtypeStruct((b,), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return token, pos


def input_specs(cfg: ModelConfig, shape: ShapeConfig, batch_override: int = 0) -> Dict[str, Any]:
    """Shape-spec pytree for the step function of this cell's kind."""
    if shape.kind == "train":
        return train_batch_specs(cfg, shape, batch_override)
    if shape.kind == "prefill":
        return prefill_batch_specs(cfg, shape, batch_override)
    token, pos = decode_inputs_specs(cfg, shape, batch_override)
    return {"token": token, "pos": pos}


def make_batch(cfg: ModelConfig, batch: int, seq_len: int, seed: int = 0,
               kind: str = "train") -> Dict[str, Any]:
    """Materialized synthetic batch (smoke tests / examples)."""
    rng = np.random.default_rng(seed)
    tl = _token_len(cfg, seq_len)
    out: Dict[str, Any] = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (batch, tl)), jnp.int32)
    }
    if cfg.n_prefix:
        out["prefix_embeds"] = jnp.asarray(
            rng.normal(0, 0.02, (batch, cfg.n_prefix, cfg.d_model)), jnp.float32
        )
    if kind == "train":
        out["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (batch, seq_len)), jnp.int32)
        mask = np.ones((batch, seq_len), np.float32)
        mask[:, : cfg.n_prefix] = 0.0
        out["mask"] = jnp.asarray(mask)
    return out
