"""Activation-sharding hooks.

The model code is distribution-agnostic; the dist layer installs a
constraint function (``with_sharding_constraint`` under a mesh) keyed by a
logical activation name.  Default is identity so models run anywhere.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax.numpy as jnp

_CONSTRAIN: Optional[Callable[[jnp.ndarray, str], jnp.ndarray]] = None


def install_constraint(fn: Optional[Callable[[jnp.ndarray, str], jnp.ndarray]]) -> None:
    global _CONSTRAIN
    _CONSTRAIN = fn


def constrain(x: jnp.ndarray, name: str) -> jnp.ndarray:
    if _CONSTRAIN is None:
        return x
    return _CONSTRAIN(x, name)
