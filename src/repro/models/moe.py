"""Top-k mixture-of-experts FFN with capacity-based scatter/gather dispatch.

GShard-style semantics (top-k routing, capacity factor, load-balance aux
loss) but implemented with scatter/gather instead of giant one-hot einsums so
the dispatch buffers stay O(E * C * d) — the variant that actually fits on a
16 GB v5e chip.  Token routing skew is exactly the "rank imbalance" the
paper's slack mechanism exploits at the all-to-all (see DESIGN.md §4).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def init_moe(cfg, key, dtype) -> Params:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    kr, k1, k2, k3 = jax.random.split(key, 4)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(f)
    return {
        "router": (jax.random.normal(kr, (d, e)) * s_in).astype(jnp.float32),
        "w1": (jax.random.normal(k1, (e, d, f)) * s_in).astype(dtype),
        "w3": (jax.random.normal(k2, (e, d, f)) * s_in).astype(dtype),
        "w2": (jax.random.normal(k3, (e, f, d)) * s_out).astype(dtype),
    }


def capacity(cfg, n_tokens: int) -> int:
    c = int(math.ceil(cfg.top_k * n_tokens / cfg.n_experts * cfg.capacity_factor))
    return max(c, cfg.top_k)


def moe_forward(
    cfg, p: Params, x: jnp.ndarray, cap_override: int = 0
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B,S,d) -> (out (B,S,d), aux load-balance loss scalar).

    ``cap_override`` sets an explicit capacity; decode passes T for a
    dropless (exact top-k) path, which is the serving-correct behaviour.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    xf = x.reshape(t, d)

    logits = (xf.astype(jnp.float32) @ p["router"])            # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)              # (T,k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    # ---- load-balance auxiliary loss (Switch/GShard) ----
    me = jnp.mean(probs, axis=0)                               # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(gate_idx, e, dtype=jnp.float32), axis=1), axis=0
    )
    aux = e * jnp.sum(me * ce) * cfg.router_aux_coef

    # ---- position-in-expert via running count (token order priority) ----
    flat_e = gate_idx.reshape(-1)                              # (T*k,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)        # (T*k,E)
    pos_all = jnp.cumsum(onehot, axis=0) - 1                   # (T*k,E)
    my_pos = jnp.take_along_axis(pos_all, flat_e[:, None], axis=1)[:, 0]
    cap = cap_override or capacity(cfg, t)
    keep = my_pos < cap

    # dropped assignments go to a trash expert row e (scatter stays static)
    dest_e = jnp.where(keep, flat_e, e)
    dest_c = jnp.where(keep, my_pos, 0)
    tok_of = jnp.arange(t * k, dtype=jnp.int32) // k
    xd = jnp.take(xf, tok_of, axis=0)                          # (T*k,d)

    buf = jnp.zeros((e + 1, cap, d), xf.dtype)
    buf = buf.at[dest_e, dest_c].add(xd)
    buf = buf[:e]                                              # (E,C,d)

    # ---- expert computation (SwiGLU) ----
    h = jnp.einsum("ecd,edf->ecf", buf, p["w1"])
    g = jnp.einsum("ecd,edf->ecf", buf, p["w3"])
    act = jax.nn.silu(h) * g
    out_buf = jnp.einsum("ecf,efd->ecd", act, p["w2"])         # (E,C,d)

    # ---- combine ----
    safe_pos = jnp.where(keep, my_pos, 0)
    gathered = out_buf[jnp.where(keep, flat_e, 0), safe_pos]   # (T*k,d)
    w = (gate_vals.reshape(-1) * keep.astype(jnp.float32)).astype(xf.dtype)
    out = jnp.sum((gathered * w[:, None]).reshape(t, k, d), axis=1)
    return out.reshape(b, s, d), aux
