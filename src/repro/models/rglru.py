"""RG-LRU recurrent block (RecurrentGemma / Griffin).

h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t ⊙ u_t)
a_t = exp(-c * softplus(Λ) * r_t),  r/i = input-dependent sigmoid gates.

Training uses an associative scan (log-depth); decode is a single-step
update.  The Pallas kernel (``repro.kernels.rglru_scan``) mirrors the
sequential semantics and is validated against ``linear_scan`` here.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

Params = Dict[str, Any]


def init_rglru(cfg, key, dtype) -> Params:
    d, w = cfg.d_model, cfg.lru_width
    kg, ki, kc, kr, kii, klam, ko = jax.random.split(key, 7)
    s_d = 1.0 / math.sqrt(d)
    s_w = 1.0 / math.sqrt(w)
    return {
        "w_gelu": (jax.random.normal(kg, (d, w)) * s_d).astype(dtype),
        "w_in": (jax.random.normal(ki, (d, w)) * s_d).astype(dtype),
        "conv_w": (jax.random.normal(kc, (cfg.ssm_conv, w)) * 0.1).astype(dtype),
        "w_r": (jax.random.normal(kr, (w, w)) * s_w).astype(dtype),
        "w_i": (jax.random.normal(kii, (w, w)) * s_w).astype(dtype),
        # softplus(lam) ~ U[2.5, 4.3] -> a^c in a useful range (Griffin init)
        "lam": jax.random.uniform(klam, (w,), jnp.float32, minval=2.5, maxval=4.3),
        "w_out": (jax.random.normal(ko, (w, d)) * s_w).astype(dtype),
    }


def linear_scan(a: jnp.ndarray, b: jnp.ndarray, h0: Optional[jnp.ndarray] = None):
    """h_t = a_t * h_{t-1} + b_t along axis 1.  a,b: (B,S,W) fp32.

    Returns (h (B,S,W), final_state (B,W)).
    """
    if h0 is not None:
        # fold initial state into the first step
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = lax.associative_scan(combine, (a, b), axis=1)
    return h, h[:, -1]


def _gates(cfg, p, u):
    r = jax.nn.sigmoid(u @ p["w_r"])
    i = jax.nn.sigmoid(u @ p["w_i"])
    log_a = -cfg.rglru_c * jax.nn.softplus(p["lam"]) * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = beta * (i.astype(jnp.float32) * u.astype(jnp.float32))
    return a, b


def rglru_forward(cfg, p: Params, x: jnp.ndarray, state: Optional[Params] = None):
    """x: (B,S,d) -> (out, new_state|None)."""
    from repro.models.ssm import causal_depthwise_conv

    g = jax.nn.gelu(x @ p["w_gelu"])
    u = x @ p["w_in"]
    if state is not None:
        u_full = jnp.concatenate([state["conv"].astype(u.dtype), u], axis=1)
        u_conv = causal_depthwise_conv(u_full, p["conv_w"])[:, cfg.ssm_conv - 1 :]
    else:
        u_conv = causal_depthwise_conv(u, p["conv_w"])
    a, b = _gates(cfg, p, u_conv)
    h0 = state["h"] if state is not None else None
    h, h_last = linear_scan(a, b, h0)
    out = (g.astype(jnp.float32) * h).astype(x.dtype) @ p["w_out"]
    if state is None:
        return out, None
    new_conv = jnp.concatenate([state["conv"], u], axis=1)[:, -(cfg.ssm_conv - 1) :]
    return out, {"conv": new_conv, "h": h_last}


def init_rglru_state(cfg, batch: int, dtype) -> Params:
    w = cfg.lru_width
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, w), dtype),
        "h": jnp.zeros((batch, w), jnp.float32),
    }


def rglru_decode(cfg, p: Params, x: jnp.ndarray, state: Params):
    """Single-token step.  x: (B,1,d)."""
    g = jax.nn.gelu(x[:, 0] @ p["w_gelu"])                     # (B,W)
    u = x[:, 0] @ p["w_in"]
    window = jnp.concatenate([state["conv"].astype(u.dtype), u[:, None]], axis=1)
    u_conv = jnp.einsum("bkc,kc->bc", window, p["conv_w"])
    a, b = _gates(cfg, p, u_conv)
    h = a * state["h"] + b                                     # (B,W)
    out = ((g.astype(jnp.float32) * h).astype(x.dtype) @ p["w_out"])[:, None]
    return out, {"conv": window[:, 1:].astype(state["conv"].dtype), "h": h}
