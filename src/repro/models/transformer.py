"""Model assembly: scan-over-layers stack covering all assigned families.

The layer stack is organized as *periods* of the config's block pattern
(dense/moe: ("attn",); ssm: ("ssm",); recurrentgemma: ("rglru","rglru","attn")).
``n_full = n_layers // len(pattern)`` periods are executed under one
``lax.scan`` with parameters stacked on a leading axis — essential to keep
HLO size and 512-device compile times tractable — plus an unrolled remainder.

Public API:
    init_params(cfg, key)             -> params
    forward(cfg, params, batch)       -> (hidden (B,S,d), aux)
    loss_fn(cfg, params, batch)       -> (loss, metrics)
    init_cache(cfg, batch, max_len)   -> cache
    prefill(cfg, params, batch, cache)-> (logits_last (B,V), cache)
    decode_step(cfg, params, tok, pos, cache) -> (logits (B,V), cache)
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models import moe as M
from repro.models import rglru as R
from repro.models import ssm as S
from repro.models.hooks import constrain

Params = Dict[str, Any]


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _init_block(cfg, kind: str, key, dtype) -> Params:
    d = cfg.d_model
    if kind == "attn":
        k1, k2 = jax.random.split(key)
        p = {
            "ln1": L.init_norm(cfg, d, dtype),
            "attn": L.init_attention(cfg, k1, dtype),
            "ln2": L.init_norm(cfg, d, dtype),
        }
        p["ffn"] = M.init_moe(cfg, k2, dtype) if cfg.is_moe else L.init_mlp(cfg, k2, dtype)
        return p
    if kind == "ssm":
        return {"ln1": L.init_norm(cfg, d, dtype), "ssm": S.init_ssm(cfg, key, dtype)}
    if kind == "rglru":
        k1, k2 = jax.random.split(key)
        return {
            "ln1": L.init_norm(cfg, d, dtype),
            "rglru": R.init_rglru(cfg, k1, dtype),
            "ln2": L.init_norm(cfg, d, dtype),
            "ffn": L.init_mlp(cfg, k2, dtype),
        }
    raise ValueError(f"unknown block kind {kind!r}")


def _init_period(cfg, key, dtype) -> Params:
    keys = jax.random.split(key, len(cfg.pattern))
    return {str(j): _init_block(cfg, kind, keys[j], dtype) for j, kind in enumerate(cfg.pattern)}


def stack_layout(cfg) -> Tuple[int, Tuple[str, ...]]:
    """(n_full periods, remainder block kinds)."""
    plen = len(cfg.pattern)
    n_full = cfg.n_layers // plen
    rem = cfg.n_layers % plen
    return n_full, cfg.pattern[:rem]


def init_params(cfg, key) -> Params:
    dtype = L.dtype_of(cfg.param_dtype)
    n_full, rem_kinds = stack_layout(cfg)
    k_emb, k_stack, k_rem, k_head = jax.random.split(key, 4)
    params: Params = {
        "embed": L.embed_init(k_emb, cfg.padded_vocab, cfg.d_model, dtype),
        "final_norm": L.init_norm(cfg, cfg.d_model, dtype),
    }
    stack_keys = jax.random.split(k_stack, n_full)
    params["stack"] = jax.vmap(lambda k: _init_period(cfg, k, dtype))(stack_keys)
    if rem_kinds:
        rks = jax.random.split(k_rem, len(rem_kinds))
        params["rem"] = {
            str(j): _init_block(cfg, kind, rks[j], dtype) for j, kind in enumerate(rem_kinds)
        }
    if not cfg.tie_embeddings:
        params["head"] = L.dense_init(k_head, cfg.d_model, cfg.padded_vocab, dtype)
    return params


def param_count(params: Params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))


# --------------------------------------------------------------------------
# forward (train / scoring)
# --------------------------------------------------------------------------

def _block_forward(cfg, kind: str, p: Params, x, positions):
    """One block, full-sequence.  Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "attn":
        h = L.apply_norm(cfg, p["ln1"], x)
        x = x + L.attention_forward(cfg, p["attn"], h, positions)
        x = constrain(x, "residual")
        h = L.apply_norm(cfg, p["ln2"], x)
        if cfg.is_moe:
            y, aux = M.moe_forward(cfg, p["ffn"], h)
        else:
            y = L.mlp_forward(cfg, p["ffn"], h)
        x = x + y
    elif kind == "ssm":
        h = L.apply_norm(cfg, p["ln1"], x)
        y, _ = S.ssm_forward(cfg, p["ssm"], h)
        x = x + y
    elif kind == "rglru":
        h = L.apply_norm(cfg, p["ln1"], x)
        y, _ = R.rglru_forward(cfg, p["rglru"], h)
        x = x + y
        h = L.apply_norm(cfg, p["ln2"], x)
        x = x + L.mlp_forward(cfg, p["ffn"], h)
    x = constrain(x, "residual")
    return x, aux


def _embed_inputs(cfg, params, batch) -> jnp.ndarray:
    """Token embeddings, with frontend prefix embeddings when configured."""
    dtype = L.dtype_of(cfg.compute_dtype)
    tok = batch["tokens"]
    x = jnp.take(params["embed"], tok, axis=0).astype(dtype)
    if cfg.n_prefix and "prefix_embeds" in batch:
        pre = batch["prefix_embeds"].astype(dtype)             # (B, P, d)
        x = jnp.concatenate([pre, x], axis=1)
    return x * math.sqrt(cfg.d_model)


def forward(cfg, params, batch) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """-> (final hidden states (B,S,d), aux loss)."""
    x = _embed_inputs(cfg, params, batch)
    x = constrain(x, "residual")
    s = x.shape[1]
    positions = jnp.arange(s, dtype=jnp.int32)
    n_full, rem_kinds = stack_layout(cfg)

    def period_forward(x, pp):
        aux = jnp.zeros((), jnp.float32)
        for j, kind in enumerate(cfg.pattern):
            x, a = _block_forward(cfg, kind, pp[str(j)], x, positions)
            aux = aux + a
        return x, aux

    body = jax.checkpoint(period_forward) if cfg.remat else period_forward

    aux = jnp.zeros((), jnp.float32)
    if cfg.scan_layers:

        def scan_body(carry, pp):
            x, aux = carry
            x, a = body(x, pp)
            return (x, aux + a), None

        (x, aux), _ = lax.scan(scan_body, (x, aux), params["stack"])
    else:
        for i in range(n_full):
            pp = jax.tree.map(lambda a: a[i], params["stack"])
            x, a = body(x, pp)
            aux = aux + a
    for j, kind in enumerate(rem_kinds):
        x, a = _block_forward(cfg, kind, params["rem"][str(j)], x, positions)
        aux = aux + a
    x = L.apply_norm(cfg, params["final_norm"], x)
    return x, aux


def _unembed_matrix(cfg, params) -> jnp.ndarray:
    dtype = L.dtype_of(cfg.compute_dtype)
    if cfg.tie_embeddings:
        return params["embed"].T.astype(dtype)
    return params["head"].astype(dtype)


def logits_fn(cfg, params, hidden) -> jnp.ndarray:
    logits = hidden @ _unembed_matrix(cfg, params)
    if cfg.logits_softcap:
        c = cfg.logits_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


def loss_fn(cfg, params, batch) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """batch: tokens (B,S'), labels (B,S), mask (B,S) [, prefix_embeds]."""
    hidden, aux = forward(cfg, params, batch)
    if cfg.logits_softcap:
        # softcap requires materialized logits; cap archs have small B*S*V
        logits = logits_fn(cfg, params, hidden)
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        tgt = jnp.take_along_axis(
            logits.astype(jnp.float32), batch["labels"][..., None], axis=-1
        )[..., 0]
        mask = batch["mask"].astype(jnp.float32)
        nll = jnp.sum((lse - tgt) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    else:
        nll = L.chunked_cross_entropy(
            hidden, _unembed_matrix(cfg, params), batch["labels"],
            batch["mask"].astype(jnp.float32), use_scan=cfg.scan_layers,
        )
    loss = nll + aux
    return loss, {"nll": nll, "aux": aux}


# --------------------------------------------------------------------------
# cache / prefill / decode
# --------------------------------------------------------------------------

def _init_block_cache(cfg, kind: str, batch: int, max_len: int, dtype) -> Params:
    if kind == "attn":
        return L.init_kv_cache(cfg, batch, max_len, dtype)
    if kind == "ssm":
        return S.init_ssm_state(cfg, batch, dtype)
    if kind == "rglru":
        return R.init_rglru_state(cfg, batch, dtype)
    raise ValueError(kind)


def init_cache(cfg, batch: int, max_len: int) -> Params:
    dtype = L.dtype_of(cfg.compute_dtype)
    n_full, rem_kinds = stack_layout(cfg)
    proto = {
        str(j): _init_block_cache(cfg, kind, batch, max_len, dtype)
        for j, kind in enumerate(cfg.pattern)
    }
    stack = jax.tree.map(lambda a: jnp.tile(a[None], (n_full,) + (1,) * a.ndim), proto)
    cache: Params = {"stack": stack}
    if rem_kinds:
        cache["rem"] = {
            str(j): _init_block_cache(cfg, kind, batch, max_len, dtype)
            for j, kind in enumerate(rem_kinds)
        }
    return cache


def _block_prefill(cfg, kind, p, x, positions, bc):
    if kind == "attn":
        h = L.apply_norm(cfg, p["ln1"], x)
        y, bc = L.attention_prefill(cfg, p["attn"], h, positions, bc)
        x = x + y
        h = L.apply_norm(cfg, p["ln2"], x)
        if cfg.is_moe:
            y, _ = M.moe_forward(cfg, p["ffn"], h)
        else:
            y = L.mlp_forward(cfg, p["ffn"], h)
        x = x + y
    elif kind == "ssm":
        h = L.apply_norm(cfg, p["ln1"], x)
        y, bc = S.ssm_forward(cfg, p["ssm"], h, bc)
        x = x + y
    elif kind == "rglru":
        h = L.apply_norm(cfg, p["ln1"], x)
        y, bc = R.rglru_forward(cfg, p["rglru"], h, bc)
        x = x + y
        h = L.apply_norm(cfg, p["ln2"], x)
        x = x + L.mlp_forward(cfg, p["ffn"], h)
    x = constrain(x, "residual")
    return x, bc


def _block_decode(cfg, kind, p, x, pos, bc, attn_fn=None):
    """One block's single-token step.  ``attn_fn(p_attn, h, bc) -> (y, bc)``
    overrides the dense-cache attention (the paged serving engine passes a
    page-table closure); everything else is shared."""
    if kind == "attn":
        h = L.apply_norm(cfg, p["ln1"], x)
        if attn_fn is None:
            y, bc = L.attention_decode(cfg, p["attn"], h, pos, bc)
        else:
            y, bc = attn_fn(p["attn"], h, bc)
        x = x + y
        h = L.apply_norm(cfg, p["ln2"], x)
        if cfg.is_moe:
            # dropless exact routing for decode (serving-correct)
            y, _ = M.moe_forward(cfg, p["ffn"], h, cap_override=h.shape[0] * h.shape[1])
        else:
            y = L.mlp_forward(cfg, p["ffn"], h)
        x = x + y
    elif kind == "ssm":
        h = L.apply_norm(cfg, p["ln1"], x)
        y, bc = S.ssm_decode(cfg, p["ssm"], h, bc)
        x = x + y
    elif kind == "rglru":
        h = L.apply_norm(cfg, p["ln1"], x)
        y, bc = R.rglru_decode(cfg, p["rglru"], h, bc)
        x = x + y
        h = L.apply_norm(cfg, p["ln2"], x)
        x = x + L.mlp_forward(cfg, p["ffn"], h)
    return x, bc


def prefill(cfg, params, batch, cache) -> Tuple[jnp.ndarray, Params]:
    """Full-sequence prefill.  Returns (last-token logits (B,V), cache)."""
    x = _embed_inputs(cfg, params, batch)
    s = x.shape[1]
    positions = jnp.arange(s, dtype=jnp.int32)

    def period_prefill(x, pp, pc):
        new_pc = {}
        for j, kind in enumerate(cfg.pattern):
            x, new_pc[str(j)] = _block_prefill(cfg, kind, pp[str(j)], x, positions, pc[str(j)])
        return x, new_pc

    body = jax.checkpoint(period_prefill) if cfg.remat else period_prefill

    if cfg.scan_layers:

        def scan_body(x, inp):
            pp, pc = inp
            x, new_pc = body(x, pp, pc)
            return x, new_pc

        x, new_stack = lax.scan(scan_body, x, (params["stack"], cache["stack"]))
    else:
        n_full, _ = stack_layout(cfg)
        outs = []
        for i in range(n_full):
            pp = jax.tree.map(lambda a: a[i], params["stack"])
            pc = jax.tree.map(lambda a: a[i], cache["stack"])
            x, new_pc = body(x, pp, pc)
            outs.append(new_pc)
        new_stack = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    new_cache: Params = {"stack": new_stack}
    _, rem_kinds = stack_layout(cfg)
    if rem_kinds:
        new_cache["rem"] = {}
        for j, kind in enumerate(rem_kinds):
            x, bc = _block_prefill(
                cfg, kind, params["rem"][str(j)], x, positions, cache["rem"][str(j)]
            )
            new_cache["rem"][str(j)] = bc
    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = logits_fn(cfg, params, x[:, -1:])[:, 0]
    return logits, new_cache


def decode_step(cfg, params, token, pos, cache, *, attn_fn=None) -> Tuple[jnp.ndarray, Params]:
    """One decode step.  token: (B,) int32; pos: scalar int32 position
    (or (B,) per-request positions when ``attn_fn`` handles them).

    ``cache`` may be the dense per-slot cache from :func:`init_cache`, or
    any tree with the same stack/rem block structure whose attention
    entries are consumed by ``attn_fn`` (see ``repro.serve.engine``)."""
    dtype = L.dtype_of(cfg.compute_dtype)
    x = jnp.take(params["embed"], token[:, None], axis=0).astype(dtype)
    x = x * math.sqrt(cfg.d_model)

    def decode_period(x, pp, pc):
        new_pc = {}
        for j, kind in enumerate(cfg.pattern):
            x, new_pc[str(j)] = _block_decode(
                cfg, kind, pp[str(j)], x, pos, pc[str(j)], attn_fn
            )
        return x, new_pc

    if cfg.scan_layers:

        def scan_body(x, inp):
            pp, pc = inp
            return decode_period(x, pp, pc)

        x, new_stack = lax.scan(scan_body, x, (params["stack"], cache["stack"]))
    else:
        n_full, _ = stack_layout(cfg)
        outs = []
        for i in range(n_full):
            pp = jax.tree.map(lambda a: a[i], params["stack"])
            pc = jax.tree.map(lambda a: a[i], cache["stack"])
            x, new_pc = decode_period(x, pp, pc)
            outs.append(new_pc)
        new_stack = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    new_cache: Params = {"stack": new_stack}
    _, rem_kinds = stack_layout(cfg)
    if rem_kinds:
        new_cache["rem"] = {}
        for j, kind in enumerate(rem_kinds):
            x, bc = _block_decode(
                cfg, kind, params["rem"][str(j)], x, pos, cache["rem"][str(j)], attn_fn
            )
            new_cache["rem"][str(j)] = bc
    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = logits_fn(cfg, params, x)[:, 0]
    return logits, new_cache
